"""Benchmark harness entry point — one module per paper figure/table plus
kernel micro-benches and the roofline report.

Usage:
    PYTHONPATH=src python -m benchmarks.run [--full|--quick] [fig1 fig5 ...]

Prints ``name,us_per_call,derived`` CSV rows (also collected in
benchmarks.common.ROWS).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    estimates_bench,
    fig1_scaling,
    fig2_failures,
    fig3_dynamics,
    fig4_estimates,
    fig5_vsteady,
    fig6_env,
    fig7_constant_data,
    fig8_churn,
    fig9_async,
    fig10_scaling,
    fig11_elastic,
    fig12_compress,
    fig13_serve,
    kernels_bench,
    roofline_report,
    rounds_bench,
)
from .common import emit

MODULES = {
    "fig1": fig1_scaling,
    "fig2": fig2_failures,
    "fig3": fig3_dynamics,
    "fig4": fig4_estimates,
    "fig5": fig5_vsteady,
    "fig6": fig6_env,
    "fig7": fig7_constant_data,
    "fig8": fig8_churn,
    "fig9": fig9_async,
    "fig10": fig10_scaling,
    "fig11": fig11_elastic,
    "fig12": fig12_compress,
    "fig13": fig13_serve,
    "kernels": kernels_bench,
    "roofline": roofline_report,
    "rounds": rounds_bench,
    "estimates": estimates_bench,
}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--full", action="store_true", help="paper-scale (slow) settings")
    p.add_argument("--quick", action="store_true", help="CI-scale settings (the default)")
    p.add_argument("--only", type=str, default=None, help="comma-separated subset")
    p.add_argument("modules", nargs="*", help="module subset (same names as --only)")
    args = p.parse_args()
    if args.full and args.quick:
        p.error("--full and --quick are mutually exclusive")
    quick = args.quick or not args.full
    if args.modules and args.only:
        p.error("give modules positionally or via --only, not both")

    names = args.modules or (
        list(MODULES) if not args.only else [s.strip() for s in args.only.split(",")]
    )
    unknown = [x for x in names if x not in MODULES]
    if unknown:
        p.error(f"unknown modules {unknown}; available: {list(MODULES)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.time()
        try:
            MODULES[name].run(quick=quick)
        except Exception as e:  # noqa: BLE001 — keep the harness sweeping
            failures += 1
            emit(f"{name}.FAILED", 0.0, f"{type(e).__name__}: {e}")
            traceback.print_exc(file=sys.stderr)
        print(f"# {name} done in {time.time() - t0:.0f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

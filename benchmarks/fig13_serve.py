"""Figure 13: live serving under gossip — latency/staleness surface by router.

DFL never converges to one artifact: every node holds its own parameters,
equal only up to the consensus noise floor.  Serving therefore routes each
query to a *node*, and the router choice trades the staleness of the
answering parameters against locality and queueing (DESIGN.md §19).  This
benchmark maps that surface: for each topology family and size, an
interleaved train+serve run (``fed.serve.run_serve_trajectory`` — gossip
and query events merged into one scanned envelope, no barrier) is swept
over qps × router policy:

* ``uniform`` — any node, ignores both staleness and distance (baseline),
* ``local``   — always the home node (zero hops, whatever its clock says),
* ``consensus`` — argmin of staleness + weighted hops + weighted queue wait.

Per cell: served-query latency quantiles (virtual time, open-loop queueing
model), mean served staleness (time since the answering node last mixed),
mean hop distance, final train/test loss (training must be unperturbed by
load — the serve path is bit-parity with the plain event executor), and
per-event executor cost split into compile vs steady-state via
``ChunkTimer``.

The committed ``BENCH_serve.json`` is quick-mode so the CI bench gate
(``tools/check_bench.py --compare``) diffs like against like.  The run
aborts if the consensus router fails to beat uniform on mean served
staleness at comparable (≤1.05×) p50 latency on at least one family —
the acceptance bar for the router actually using the virtual clocks.

Schema (``BENCH_serve.json``): ``{device, cpu_count, quick, consensus_wins,
records: [{family, n, router, qps, horizon, n_events, n_queries, served,
p50_latency, p95_latency, mean_latency, mean_staleness_served, mean_hops,
final_train_loss, final_test_loss, queries_per_wall_second,
us_per_event_steady, compile_seconds}]}`` — validated (and
regression-gated) by ``tools/check_bench.py`` in CI.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax

from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.data.pipeline import batch_index_schedule
from repro.fed import init_fl_state
from repro.fed.router import ROUTER_POLICIES, make_router, poisson_query_stream
from repro.fed.serve import run_serve_trajectory, serve_summary

from .common import ChunkTimer, _mlp_setup, emit, gain_from_graph

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"

FAMILIES = {
    "ring": lambda n, seed: T.ring(n),
    "kreg": lambda n, seed: T.random_k_regular(n, 8, seed=seed),
}

SERVICE_TIME = 0.2
HOP_LATENCY = 0.05


def run(quick: bool = True) -> None:
    sizes = (16,) if quick else (16, 64)
    horizon = 30.0 if quick else 60.0
    qps_grid = (2.0, 8.0) if quick else (2.0, 8.0, 32.0)
    per_node = 64 if quick else 128
    b_local, batch_size, n_bins, seed = 2, 16, 10, 0
    records = []

    for family, build in FAMILIES.items():
        for n in sizes:
            graph, xs, ys, test, loss_fn, opt, eval_fn, init_one = _mlp_setup(
                n, build(n, 0), per_node, (128, 64), "sgd", seed, 512
            )
            state = init_fl_state(
                jax.random.PRNGKey(seed), n, init_one(gain_from_graph(graph)), opt
            )
            plan = compile_plan(graph)
            stream = T.poisson_event_stream(graph, horizon=horizon, rate=1.0, seed=seed + 1)
            sched = batch_index_schedule(
                per_node, n, batch_size, max(int(horizon), 1) * b_local, seed=seed
            )
            for qps in qps_grid:
                queries = poisson_query_stream(n, horizon, qps, seed=seed + 2)
                for router_name in ROUTER_POLICIES:
                    router = make_router(graph, router_name)
                    env = stream.envelope + queries.envelope
                    timer = ChunkTimer()
                    t0 = time.time()
                    _, hist, serve, _ = run_serve_trajectory(
                        state,
                        loss_fn,
                        opt,
                        plan,
                        stream,
                        queries,
                        router,
                        xs,
                        ys,
                        sched,
                        b_local=b_local,
                        n_bins=n_bins,
                        eval_fn=eval_fn,
                        eval_batch=test,
                        service_time=SERVICE_TIME,
                        hop_latency=HOP_LATENCY,
                        chunk_events=max(env // 8, 1),
                        on_chunk=timer,
                    )
                    wall = time.time() - t0
                    compile_s, steady = timer.split()
                    summ = serve_summary(serve)
                    rec = {
                        "family": family,
                        "n": n,
                        "router": router_name,
                        "qps": qps,
                        "horizon": int(horizon),
                        "n_events": stream.n_events,
                        "n_queries": queries.n_queries,
                        "served": summ["served"],
                        "p50_latency": summ["p50_latency"],
                        "p95_latency": summ["p95_latency"],
                        "mean_latency": summ["mean_latency"],
                        "mean_staleness_served": summ["mean_staleness"],
                        "mean_hops": summ["mean_hops"],
                        "final_train_loss": float(hist["train_loss"][-1]),
                        "final_test_loss": float(hist["test_loss"][-1]),
                        "queries_per_wall_second": summ["served"] / max(wall, 1e-9),
                        "us_per_event_steady": steady * 1e6,
                        "compile_seconds": compile_s,
                    }
                    records.append(rec)
                    emit(
                        f"fig13.{family}.n{n}.{router_name}.qps{qps:g}",
                        rec["us_per_event_steady"],
                        f"p50={rec['p50_latency']:.3f};"
                        f"stale={rec['mean_staleness_served']:.3f};"
                        f"hops={rec['mean_hops']:.2f};"
                        f"test={rec['final_test_loss']:.3f}",
                    )

    # acceptance: the consensus router must dominate uniform on served-model
    # staleness at comparable p50 latency for at least one topology family
    cells: dict = {}
    for r in records:
        cells.setdefault((r["family"], r["n"]), {}).setdefault(r["qps"], {})[r["router"]] = r
    wins = []
    for (family, n), by_qps in cells.items():
        ok = all(
            c["consensus"]["mean_staleness_served"] < c["uniform"]["mean_staleness_served"]
            and c["consensus"]["p50_latency"] <= 1.05 * c["uniform"]["p50_latency"]
            for c in by_qps.values()
        )
        if ok:
            wins.append(f"{family}.n{n}")
    if not wins:
        raise AssertionError(
            "consensus router failed to beat uniform on staleness at equal p50 "
            "latency on every family — the router is not using the virtual clocks"
        )

    OUT.write_text(
        json.dumps(
            {
                "device": str(jax.devices()[0]),
                "cpu_count": __import__("os").cpu_count(),
                "quick": quick,
                "consensus_wins": wins,
                "records": records,
            },
            indent=2,
        )
    )
    print(f"# wrote {OUT} (consensus wins on: {', '.join(wins)})", flush=True)


if __name__ == "__main__":
    run()

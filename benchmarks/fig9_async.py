"""Figure 9: synchronous vs event-driven gossip — convergence per message.

The paper's uncoordinated setting (and Valerio et al.'s coordination-free
DFL) has no global round barrier; this repo's event rendering (DESIGN.md
§14) replaces the barrier with per-edge Poisson clocks realised host-side
into a static ``EventStream`` envelope and scanned on device.  This
benchmark asks the question the async literature cares about: **at an equal
transmitted-message budget, does the barrier matter?**

* Per family (ring / k-regular / BA) and size, a synchronous run of R
  rounds (2·|E| messages per round) is compared against an event-driven run
  with rate-1 clocks over horizon R — the same expected message budget, no
  coordination.  Both start from the same gain-corrected init.
* ``final_test_loss_*`` at the matched budget plus per-event executor cost
  (``us_per_event``) and the per-bin staleness the virtual clocks measure.

Full mode sweeps n ∈ {64, 256}; quick (CI) mode n ∈ {16, 32} — the
committed ``BENCH_async.json`` is quick-mode so the CI bench-regression
gate (``tools/check_bench.py --compare``) diffs like against like.

Schema (``BENCH_async.json``): ``{device, cpu_count, quick, records: [
{family, n, horizon, messages_sync, messages_event, final_test_loss_sync,
final_test_loss_event, us_per_event, sec_per_round_sync, ...}]}`` —
validated (and regression-gated) by ``tools/check_bench.py`` in CI.
"""
from __future__ import annotations

import json
import pathlib

import jax
import numpy as np

from repro.core import topology as T

from .common import emit, run_dfl_mlp, run_dfl_mlp_async

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_async.json"

FAMILIES = {
    "ring": lambda n, seed: T.ring(n),
    "kreg": lambda n, seed: T.random_k_regular(n, 8, seed=seed),
    "ba": lambda n, seed: T.barabasi_albert(n, 4, seed=seed),
}


def run(quick: bool = True) -> None:
    sizes = (16, 32) if quick else (64, 256)
    rounds = 30 if quick else 60
    per_node = 64 if quick else 128
    records = []

    for family, build in FAMILIES.items():
        for n in sizes:
            graph = build(n, 0)
            m = graph.n_edges
            hist_sync, t_sync = run_dfl_mlp(
                n_nodes=n, graph=graph, rounds=rounds, per_node=per_node,
                eval_every=max(rounds // 10, 1), timing=True,
            )
            hist_ev, t_ev, stream = run_dfl_mlp_async(
                n_nodes=n, graph=graph, horizon=float(rounds), rate=1.0,
                per_node=per_node, n_bins=10, timing=True,
            )
            rec = {
                "family": family,
                "n": n,
                "horizon": rounds,
                "n_edges": m,
                "n_events": stream.n_events,
                "messages_sync": 2 * m * rounds,
                "messages_event": 2 * stream.n_events,
                "final_test_loss_sync": hist_sync["test_loss"][-1],
                "final_test_loss_event": hist_ev["test_loss"][-1],
                "mean_staleness": float(np.mean(hist_ev["staleness"])),
                "us_per_event": t_ev["sec_per_event"] * 1e6,
                "us_per_event_steady": t_ev["us_per_event_steady"],
                "compile_seconds_event": t_ev["compile_seconds"],
                "sec_per_round_sync": t_sync["sec_per_round"],
                "us_per_round_steady_sync": t_sync["us_per_round_steady"],
                "compile_seconds_sync": t_sync["compile_seconds"],
                # bytes-on-the-wire (repro.obs.wirecost): clean sync plans are
                # constant per round; the event total sums delivered exchanges
                "wire_bytes_per_round_sync": hist_sync["wire_bytes"][0],
                "wire_bytes_event_total": int(sum(hist_ev["wire_bytes"])),
            }
            records.append(rec)
            emit(
                f"fig9.{family}.n{n}",
                rec["us_per_event"],
                f"event={rec['final_test_loss_event']:.3f};"
                f"sync={rec['final_test_loss_sync']:.3f};"
                f"msgs={rec['messages_event']};"
                f"stale={rec['mean_staleness']:.2f}",
            )

    OUT.write_text(
        json.dumps(
            {
                "device": str(jax.devices()[0]),
                "cpu_count": __import__("os").cpu_count(),
                "quick": quick,
                "records": records,
            },
            indent=2,
        )
    )
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    run()

"""Figure 7: constant TOTAL data spread over more nodes — per-node
computation to reach a given loss stays roughly constant (trajectory over
wall-clock-equivalent is consistent, including the isolated single node).
"""
from __future__ import annotations

from repro.core import topology as T

from .common import emit, run_dfl_mlp


def run(quick: bool = True) -> None:
    total = 2048 if quick else 8192
    rounds = 60 if quick else 200
    base_final = None
    for n in (1, 4, 16):
        per = total // n
        graph = T.complete(n) if n > 1 else None
        if n == 1:
            # isolated node: no aggregation (the centralised reference)
            hist, spr = run_dfl_mlp(n_nodes=1, per_node=per, rounds=rounds, aggregate=False, gain=1.0)
        else:
            hist, spr = run_dfl_mlp(n_nodes=n, graph=graph, per_node=per, rounds=rounds)
        if base_final is None:
            base_final = hist["test_loss"][-1]
        emit(
            f"fig7.n{n}_per{per}",
            spr * 1e6,
            f"final={hist['test_loss'][-1]:.3f};isolated_ref={base_final:.3f}",
        )


if __name__ == "__main__":
    run()

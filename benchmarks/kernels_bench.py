"""Kernel micro-benchmarks.

CPU container caveat: the Pallas kernels target TPU; ``interpret=True``
executes the kernel bodies in Python (correctness, not speed).  The
*timed* numbers here are the jitted XLA paths the kernels replace —
``decavg_mix_ref`` / ``attention_ref`` / ``rwkv6_ref`` — giving the CPU
baseline and the derived GFLOP counts the TPU kernels would run at;
interpret-mode allclose is re-verified per shape.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core import topology as T
from repro.core.commplan import BACKENDS, compile_plan
from repro.kernels.flash.flash import flash_mha
from repro.kernels.flash.ref import attention_ref
from repro.kernels.mix.mix import mix_matmul
from repro.kernels.mix.ref import decavg_mix_ref
from repro.kernels.rwkv.rwkv import rwkv6_chunked
from repro.kernels.rwkv.ref import rwkv6_ref

from .common import emit


def _time(f, *args, iters=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters


_MIX_FAMILIES = {
    "ring": lambda n: T.ring(n),
    "kreg": lambda n: T.random_k_regular(n, 4, seed=0),
    "ba": lambda n: T.barabasi_albert(n, 4, seed=0),
    "heavytail": lambda n: T.configuration_heavy_tail(n, 2.2, seed=0),
}


def run_mixing(
    ns=(16, 64, 256, 1024),
    d: int = 4096,
    iters: int = 5,
    out_path: str | pathlib.Path = "BENCH_mixing.json",
) -> dict:
    """Sweep the three CommPlan backends over n × topology family.

    Times one jitted DecAvg round of an (n, d) node-stacked pytree per
    backend and writes a throughput record to ``out_path``.  The headline
    row is (ba, 1024): the dense path's O(n²·d) einsum against the sparse
    path's O(E·d) gather-scatter — the crossover the CommPlan refactor
    exists to exploit.  Reports best-of-``iters`` (min), the standard
    noise-robust estimator on shared-CPU runners.
    """

    def _best_of(f, *args):
        jax.block_until_ready(f(*args))  # compile + warm caches
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            out = f(*args)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        return best

    records = []
    for family, build in _MIX_FAMILIES.items():
        for n in ns:
            g = build(n)
            params = {"w": jax.random.normal(jax.random.PRNGKey(0), (n, d), jnp.float32)}
            row: dict = {
                "family": family,
                "n": n,
                "d": d,
                "n_edges": g.n_edges,
                "mean_degree": g.mean_degree,
            }
            for backend in BACKENDS:
                plan = compile_plan(g, backend)
                f = jax.jit(plan.mix)
                sec = _best_of(f, params)
                row[f"us_{backend}"] = sec * 1e6
                emit(
                    f"mixing.{backend}",
                    sec * 1e6,
                    f"family={family};n={n};d={d};bytes_moved~={'n*d*4' if backend == 'dense' else 'deg*d*4'}",
                )
            row["sparse_speedup_vs_dense"] = row["us_dense"] / row["us_sparse"]
            row["ppermute_speedup_vs_dense"] = row["us_dense"] / row["us_ppermute"]
            records.append(row)
    result = {
        "d": d,
        "iters": iters,
        "device": str(jax.devices()[0]),
        "records": records,
    }
    path = pathlib.Path(out_path)
    path.write_text(json.dumps(result, indent=2) + "\n")
    print(f"wrote {path} ({len(records)} rows)", flush=True)
    return result


def run(quick: bool = True) -> None:
    # ---- mix ----------------------------------------------------------
    n, d = (16, 1_000_000) if quick else (32, 10_000_000)
    m = jax.random.uniform(jax.random.PRNGKey(0), (n, n))
    m = m / m.sum(1, keepdims=True)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, d), jnp.float32)
    ref = jax.jit(decavg_mix_ref)
    sec = _time(ref, m, w)
    flops = 2 * n * n * d
    got = mix_matmul(m, w[:, :4096], interpret=True)
    err = float(jnp.abs(got - decavg_mix_ref(m, w[:, :4096])).max())
    emit("kernels.mix", sec * 1e6, f"gflops={flops / sec / 1e9:.1f};interpret_allclose_err={err:.1e}")

    # ---- flash --------------------------------------------------------
    b, h, kvh, s, hd = (1, 4, 2, 1024, 64) if quick else (2, 8, 4, 4096, 128)
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, kvh, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, kvh, s, hd), jnp.float32)
    ref_f = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    sec = _time(ref_f, q, k, v)
    flops = 4 * b * h * s * s * hd / 2  # causal half
    sub = 256
    err = float(
        jnp.abs(
            flash_mha(q[:, :, :sub], k[:, :, :sub], v[:, :, :sub], causal=True, interpret=True)
            - attention_ref(q[:, :, :sub], k[:, :, :sub], v[:, :, :sub], causal=True)
        ).max()
    )
    emit("kernels.flash", sec * 1e6, f"gflops={flops / sec / 1e9:.1f};interpret_allclose_err={err:.1e}")
    # sliding-window early-exit factor
    ref_w = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True, window=128))
    sec_w = _time(ref_w, q, k, v)
    emit("kernels.flash_swa", sec_w * 1e6, f"xla_window_speedup={sec / sec_w:.2f}x_(kernel_skips_blocks_on_tpu)")

    # ---- rwkv ---------------------------------------------------------
    bh, l, m_ = (8, 2048, 64) if quick else (32, 8192, 64)
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (bh, l, m_))
    k2 = jax.random.normal(ks[1], (bh, l, m_)) * 0.3
    v2 = jax.random.normal(ks[2], (bh, l, m_))
    w2 = jnp.exp(-jnp.exp(jnp.clip(jax.random.normal(ks[3], (bh, l, m_)), -8, 1)))
    u2 = jnp.abs(jax.random.normal(ks[4], (bh, m_))) * 0.3
    ref_r = jax.jit(rwkv6_ref)
    sec = _time(ref_r, r, k2, v2, w2, u2)
    # chunked form flops: per chunk c: 3 matmuls ≈ 2c²M + 4cM²
    c = 32
    flops = (l // c) * (2 * c * c * m_ + 4 * c * m_ * m_) * bh
    sub = 128
    err = float(
        jnp.abs(
            rwkv6_chunked(r[:2, :sub], k2[:2, :sub], v2[:2, :sub], w2[:2, :sub], u2[:2], interpret=True)
            - rwkv6_ref(r[:2, :sub], k2[:2, :sub], v2[:2, :sub], w2[:2, :sub], u2[:2])
        ).max()
    )
    emit("kernels.rwkv6", sec * 1e6, f"gflops={flops / sec / 1e9:.1f};interpret_allclose_err={err:.1e}")


if __name__ == "__main__":
    run()
    run_mixing()

"""Figure 12: compressed gossip — bytes on the wire vs final loss.

The compression layer (``core.compress``, DESIGN.md §18) makes wire bytes an
optimisable axis; this benchmark measures the trade it buys on three fronts,
with bytes and time as co-equal measurements:

* **codec sweep on the paper's fig1 setup** (complete graph, the MLP) —
  final test loss and wire bytes per round for none / int8 / fp8 / topk /
  qtopk with the error-feedback mirror carry.  The headline acceptance:
  ``bytes_reduction_vs_fp32 >= 4`` at ``<= 2%`` final-loss degradation for
  at least one codec.  qtopk at frac 0.3 carries it (4.43x): int8's scale
  overhead caps it at 3.99x, and plain fp32-valued topk only clears 4x at
  fractions aggressive enough to cost ~8% loss at this horizon.
* **codec x topology** — the sparse families (ring, k-regular) where the
  damped sparsifier's gamma trade-off actually bites.
* **transformer-block trajectory** — a reduced transformer LM gossiped
  through the same fused executor on windowed token data, codec none vs
  int8: the payload class the codecs exist for, measured end to end
  (compile + steady us/round + wire bytes).

Schema (``BENCH_compress.json``): ``{device, cpu_count, quick, records: [
{kind: "codec", codec, family, n, model, rounds, gamma,
wire_bytes_per_round, bytes_reduction_vs_fp32, final_test_loss,
loss_delta_vs_fp32_pct, compile_seconds, us_per_round_steady,
meets_4x_2pct} | {kind: "transformer", codec, ...same measurement fields...,
params_per_node, curve_round, curve_test_loss}]}`` — validated and
regression-gated by ``tools/check_bench.py`` in CI.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core import topology as T
from repro.core.compress import Compression
from repro.data import batch_index_schedule, make_token_stream
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, run_trajectory
from repro.models import transformer as TF
from repro.core.initialisation import InitConfig

from .common import ChunkTimer, emit, run_dfl_mlp

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_compress.json"

# gamma: quantisers contract at 1.0; sparsifiers need damping, and the
# stability boundary tightens with the horizon — frac 0.1 needs gamma
# <= 0.2 to stay stable over hundreds of training rounds (the pure-mixing
# contraction tests in tests/test_compress.py tolerate 0.3), while the
# milder frac 0.3 sparsifier holds at 0.5
CODECS = {
    "none": None,
    "int8": Compression(codec="int8"),
    "fp8": Compression(codec="fp8"),
    "topk": Compression(codec="topk", topk_frac=0.1, gamma=0.2),
    "qtopk": Compression(codec="qtopk", topk_frac=0.3, gamma=0.5),
}


def _wire_per_round(hist) -> int:
    wb = np.asarray(hist.get("wire_bytes", [0]))
    return int(np.median(wb)) if wb.size else 0


def _codec_record(codec, comp, family, graph, n, rounds, base, **kw):
    hist, t = run_dfl_mlp(
        n_nodes=n, graph=graph, rounds=rounds, timing=True, compression=comp, **kw
    )
    wire = _wire_per_round(hist)
    base_wire, base_loss = base if base is not None else (wire, hist["test_loss"][-1])
    reduction = base_wire / max(wire, 1)
    delta_pct = 100.0 * (hist["test_loss"][-1] - base_loss) / base_loss
    rec = {
        "kind": "codec",
        "codec": codec,
        "family": family,
        "n": n,
        "model": "mlp",
        "rounds": rounds,
        "gamma": comp.gamma if comp is not None else 1.0,
        "wire_bytes_per_round": wire,
        "bytes_reduction_vs_fp32": reduction,
        "final_test_loss": hist["test_loss"][-1],
        "loss_delta_vs_fp32_pct": delta_pct,
        "compile_seconds": t["compile_seconds"],
        "us_per_round_steady": t["us_per_round_steady"],
        "meets_4x_2pct": bool(reduction >= 4.0 and delta_pct <= 2.0),
    }
    emit(
        f"fig12.{family}.{codec}.n{n}",
        t["us_per_round_steady"],
        f"wire={wire}B;x{reduction:.2f};loss={rec['final_test_loss']:.4f};"
        f"delta={delta_pct:+.2f}%",
    )
    return rec, (base_wire, base_loss)


def _fig1_codec_records(quick: bool):
    """Codec sweep on the paper's fig1 setup (complete graph) + the sparse
    families where the topology resistance shows."""
    # the horizon must leave the baseline meaningfully below chance or the
    # relative loss delta is pure noise — 400 rounds of the quick MLP gets
    # the fp32 baseline to ~0.93 (chance is ln 10 ≈ 2.30)
    rounds = 400 if quick else 600
    n = 16 if quick else 32
    records = []
    sweeps = [("complete", T.complete(n))]
    sweeps.append(("kregular", T.random_k_regular(n, 4, seed=0)))
    if not quick:
        sweeps.append(("ring", T.ring(n)))
    for family, graph in sweeps:
        base = None
        for codec, comp in CODECS.items():
            rec, base = _codec_record(
                codec,
                comp,
                family,
                graph,
                n,
                rounds,
                base,
                per_node=64 if quick else 128,
                hidden=(64, 32) if quick else (128, 64),
                eval_every=max(rounds // 10, 1),
            )
            records.append(rec)
    return records


def _transformer_records(quick: bool):
    """Reduced transformer LM through the fused executor: the measured
    transformer-block trajectory, codec none vs int8."""
    n = 8
    rounds = 8 if quick else 24
    seq = 32 if quick else 64
    items = 32 if quick else 128
    bs, b_local = 4, 2
    cfg = get_reduced_config("qwen2.5-3b")
    win = (np.arange(items) * seq)[:, None] + np.arange(seq + 1)

    def windows(seed):
        t = make_token_stream(items * seq + 1, cfg.vocab_size, seed=seed)[win]
        return t[:, :-1].astype(np.int32), t[:, 1:].astype(np.int32)

    per_node = [windows(i) for i in range(n)]
    xs = np.stack([x for x, _ in per_node])
    ys = np.stack([y for _, y in per_node])
    ex_, ey_ = windows(n)
    test = (ex_[:16], ey_[:16])

    def loss_fn(params, batch):
        x, y = batch
        hidden, aux = TF.forward(params, cfg, x)
        return TF.lm_loss(params, cfg, hidden, y) + 0.01 * aux

    from repro.optim import sgd

    graph = T.ring(n)
    opt = sgd(1e-3, 0.5)
    icfg = InitConfig("trunc_normal", 2.0)
    init_one = lambda k: TF.init_params(k, cfg, icfg)
    state = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)
    d_node = sum(
        int(np.prod(l.shape[1:])) for l in jax.tree_util.tree_leaves(state.params)
    )
    sched = batch_index_schedule(items, n, bs, rounds * b_local, seed=0)
    eval_fn = make_eval_fn(loss_fn)

    records, base = [], None
    for codec in ("none", "int8"):
        comp = CODECS[codec]
        rf = make_round_fn(loss_fn, opt, graph, compression=comp)
        timer = ChunkTimer()
        t0 = time.time()
        _, hist = run_trajectory(
            state,
            rf,
            xs,
            ys,
            sched,
            n_rounds=rounds,
            eval_every=max(rounds // 4, 1),
            eval_fn=eval_fn,
            eval_batch=test,
            b_local=b_local,
            chunk_size=max(rounds // 4, 1),
            on_chunk=timer,
        )
        sec = (time.time() - t0) / rounds
        compile_s, steady = timer.split()
        wire = _wire_per_round(hist)
        if base is None:
            base = (wire, hist["test_loss"][-1])
        reduction = base[0] / max(wire, 1)
        delta_pct = 100.0 * (hist["test_loss"][-1] - base[1]) / base[1]
        rec = {
            "kind": "transformer",
            "codec": codec,
            "family": "ring",
            "n": n,
            "model": cfg.name,
            "rounds": rounds,
            "params_per_node": d_node,
            "gamma": comp.gamma if comp is not None else 1.0,
            "wire_bytes_per_round": wire,
            "bytes_reduction_vs_fp32": reduction,
            "final_test_loss": hist["test_loss"][-1],
            "loss_delta_vs_fp32_pct": delta_pct,
            "compile_seconds": compile_s,
            "us_per_round_steady": steady * 1e6,
            "sec_per_round": sec,
            "curve_round": hist["round"],
            "curve_test_loss": hist["test_loss"],
        }
        records.append(rec)
        emit(
            f"fig12.transformer.{codec}.n{n}",
            steady * 1e6,
            f"params={d_node};wire={wire}B;x{reduction:.2f};"
            f"loss={rec['final_test_loss']:.4f};delta={delta_pct:+.2f}%",
        )
    return records


def run(quick: bool = True) -> None:
    records = _fig1_codec_records(quick)
    records += _transformer_records(quick)
    winners = [
        r for r in records
        if r["kind"] == "codec" and r["family"] == "complete" and r["meets_4x_2pct"]
    ]
    emit(
        "fig12.acceptance",
        0.0,
        f"codecs_meeting_4x_2pct={','.join(r['codec'] for r in winners) or 'NONE'}",
    )
    OUT.write_text(
        json.dumps(
            {
                "device": str(jax.devices()[0]),
                "cpu_count": __import__("os").cpu_count(),
                "quick": quick,
                "records": records,
            },
            indent=2,
        )
    )
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    run()

"""Figure 10: weak scaling of the node-sharded CommPlan rendering.

The sharded rendering (``core.shardplan``, DESIGN.md §15) partitions the FL
node axis contiguously across a mesh axis: intra-shard edges run as local
segment-sums / HYB slot chains, cross-shard edges as a static halo plan
moved by ONE padded ``all_to_all`` per round.  This benchmark asks the
question that decides whether the rendering is worth its collectives:
**does per-round time stay flat as nodes and shards grow together?**

* Weak scaling: nodes-per-shard is fixed, shards sweep {1, 2, 4, 8} (n
  grows with the mesh), per family (ring / k-regular / BA).
* Per point: the sharded round's raw wall time, the static cross-shard
  traffic (``cross_shard_rows_per_round`` × row bytes), and a
  sharded-vs-single-device parity check (bit-exact mixing at every n).

**Timing model.** The CI host is one oversubscribed core emulating the
8-device mesh, so S simulated shards serialise and every collective pays a
thread-rendezvous cost that no real mesh has — raw wall time measures the
emulation, not the rendering.  ``us_per_round`` therefore models the
parallel round as

    us_per_round(S) = us_compute + n_collectives·LAT + bytes_per_shard/BW

where ``us_compute`` is the *measured* per-round wall of the S=1 point
(exactly one shard's workload — that is what weak scaling holds fixed),
``n_collectives``/``bytes_per_shard`` are the rendering's real static
counts, and LAT/BW are documented ICI-class constants (`model_*` fields).
The raw serialised wall is kept alongside as ``us_per_round_serialized``.

The worker re-execs itself with ``--xla_force_host_platform_device_count=8``
(the flag must be set before jax initialises), mirroring
``tests/test_distributed.py``; the parent just streams its output.

Schema (``BENCH_scaling.json``): ``{device, cpu_count, quick,
model_bw_gbps, model_collective_lat_us, records: [{family, n, n_shards,
nodes_per_shard, d, rounds, backend, us_per_round, us_per_round_serialized,
us_compute_per_round, collectives_per_round, cross_shard_bytes_per_round,
parity_bitexact, parity_max_abs_err}]}`` — validated and regression-gated
by ``tools/check_bench.py`` in CI.
"""

from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_scaling.json"

SHARDS = (1, 2, 4, 8)
MODEL_BW_GBPS = 100.0  # ICI-class per-device interconnect bandwidth
MODEL_LAT_US = 1.0  # per-collective launch/sync latency


def run(quick: bool = True) -> None:
    """Spawn the 8-device worker (XLA device flags bind at jax import)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
    ).strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = os.pathsep.join([str(root / "src"), str(root), env.get("PYTHONPATH", "")])
    cmd = [sys.executable, "-m", "benchmarks.fig10_scaling", "--worker"]
    if quick:
        cmd.append("--quick")
    subprocess.run(cmd, check=True, cwd=root, env=env)


def _worker(quick: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import topology as T
    from repro.core.commplan import compile_plan
    from repro.core.shardplan import shard_plan

    from .common import emit

    families = {
        "ring": lambda n, seed: T.ring(n),
        "kreg": lambda n, seed: T.random_k_regular(n, 4, seed=seed),
        "ba": lambda n, seed: T.barabasi_albert(n, 3, seed=seed),
    }
    nps = 64 if quick else 256
    d = 256 if quick else 512
    rounds = 10 if quick else 50
    reps = 3 if quick else 5
    records = []

    def time_rounds(mix, params):
        def scan_rounds(p):
            def body(x, _):
                return mix(x), None

            return jax.lax.scan(body, p, None, length=rounds)[0]

        f = jax.jit(scan_rounds)
        jax.block_until_ready(f(params))  # compile
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(f(params))
            best = min(best, time.perf_counter() - t0)
        return best / rounds * 1e6

    for family, build in families.items():
        us_compute = None  # the measured S=1 per-shard workload
        for n_shards in SHARDS:
            n = nps * n_shards
            graph = build(n, 0)
            plan = compile_plan(graph, backend="sparse")
            sp = shard_plan(plan, n_shards=n_shards)

            params = {
                "w": jnp.asarray(np.random.default_rng(0).normal(size=(n, d)).astype(np.float32)),
            }

            # parity: one sharded round vs the single-device operator
            ref = plan.mix(params)
            got = sp.mix(params)
            err = float(jnp.abs(ref["w"] - got["w"]).max())
            bit = bool(np.array_equal(np.asarray(ref["w"]), np.asarray(got["w"])))
            assert bit, f"sharded mix not bit-exact: {family} S={n_shards} err={err}"

            us_serial = time_rounds(sp.mix, params)
            if n_shards == 1:
                us_compute = us_serial
            n_coll = sp.collectives_per_round("mix")
            xbytes = sp.cross_shard_bytes_per_round(d * 4)
            bytes_per_shard = xbytes / n_shards
            us_round = (
                us_compute + n_coll * MODEL_LAT_US + bytes_per_shard / (MODEL_BW_GBPS * 1e3)
            )
            rec = {
                "family": family,
                "n": n,
                "n_shards": n_shards,
                "nodes_per_shard": nps,
                "d": d,
                "rounds": rounds,
                "backend": "sparse",
                "us_per_round": us_round,
                "us_per_round_serialized": us_serial,
                "us_compute_per_round": us_compute,
                "collectives_per_round": n_coll,
                "cross_shard_bytes_per_round": xbytes,
                "parity_bitexact": bit,
                "parity_max_abs_err": err,
            }
            records.append(rec)
            emit(
                f"fig10.{family}.S{n_shards}",
                us_round,
                f"n={n};serial={us_serial:.1f};xbytes={xbytes};bit={bit}",
            )
        base = next(r for r in records if r["family"] == family and r["n_shards"] == 1)
        top = next(r for r in records if r["family"] == family and r["n_shards"] == SHARDS[-1])
        ratio = top["us_per_round"] / base["us_per_round"]
        print(f"# fig10.{family}: 1→{SHARDS[-1]} shards modeled growth {ratio:.2f}x", flush=True)
        # the weak-scaling acceptance, enforced where it is noise-stable: a
        # slower host *shrinks* the ratio (compute grows, the modeled comm
        # term is fixed), so this only trips on real comm/compute blow-ups
        assert ratio <= 1.5, f"weak scaling broke: {family} 1→{SHARDS[-1]} grew {ratio:.2f}x"

    OUT.write_text(
        json.dumps(
            {
                "device": str(jax.devices()[0]),
                "cpu_count": os.cpu_count(),
                "quick": quick,
                "model_bw_gbps": MODEL_BW_GBPS,
                "model_collective_lat_us": MODEL_LAT_US,
                "records": records,
            },
            indent=2,
        )
    )
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    if "--worker" in sys.argv:
        _worker(quick="--quick" in sys.argv)
    else:
        run(quick="--quick" in sys.argv or "--full" not in sys.argv)

"""Figure 11: elastic membership + fault injection — recovery curves and
preemption-safety overhead.

The paper trains a fixed population; DESIGN.md §16 makes membership a
per-round mask axis (nodes join, leave, crash, resume) and layers seeded
fault scenarios (``core.faults``) plus chunk-boundary checkpointing on top.
This benchmark measures the three claims that stack makes:

* **recovery curves** — for each fault scenario (correlated crash burst,
  degree-targeted hub outage, and a mid-run cohort join), test loss and the
  live population per round against the uninterrupted baseline:
  ``delta_vs_uninterrupted`` at the horizon and ``rounds_to_recover`` (first
  post-fault round whose test loss is back within 10% of the baseline's).
* **checkpoint overhead** — durable save + restore of the full mid-scan
  carry at n = 64 against the per-chunk scan wall (``overhead_ratio``; the
  §16 budget is ≤ 10%).
* **resume parity** — a checkpointed elastic run resumed from its mid-run
  snapshot must be bit-identical to the uninterrupted one
  (``parity_bitexact``).

Schema (``BENCH_elastic.json``): ``{device, cpu_count, quick, records: [
{scenario, n, rounds, final_test_loss, delta_vs_uninterrupted,
rounds_to_recover, sec_per_round, curve_round, curve_test_loss,
curve_n_active} | {scenario: "ckpt-overhead", save_ms, restore_ms,
sec_per_chunk, overhead_ratio} | {scenario: "resume-parity",
parity_bitexact}]}`` — validated and regression-gated by
``tools/check_bench.py`` in CI.
"""
from __future__ import annotations

import json
import pathlib
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint import restore_train_state, save_train_state
from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.core.faults import crash_burst, hub_outage
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.core.membership import membership_schedule
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import (
    CheckpointPolicy,
    init_fl_state,
    make_eval_fn,
    make_round_fn,
    run_elastic_trajectory,
    run_trajectory,
)
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

from .common import emit

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_elastic.json"

BS, B_LOCAL = 16, 2


def _setup(n, per_node, hidden, seed=0):
    graph = T.random_k_regular(n, 8, seed=seed)
    ds = mnist_like(n * per_node + 512, seed=seed)
    parts = [np.arange(i * per_node, (i + 1) * per_node) for i in range(n)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-512:], ds.y[-512:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    gain = gain_from_graph(graph)
    init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k, hidden=hidden)
    init_one_g = lambda k, gn: init_mlp(InitConfig("he_normal", gn), k, hidden=hidden)
    return graph, xs, ys, test, loss_fn, opt, init_one, init_one_g


def _elastic(graph, setup, mem, faults, rounds, eval_every):
    _, xs, ys, test, loss_fn, opt, init_one, init_one_g = setup
    sched = batch_index_schedule(xs.shape[1], graph.n, BS, rounds * B_LOCAL, seed=0)
    state = init_fl_state(jax.random.PRNGKey(0), graph.n, init_one, opt)
    t0 = time.perf_counter()
    _, hist, _ = run_elastic_trajectory(
        state, loss_fn, opt, compile_plan(graph), mem, xs, ys, sched,
        n_rounds=rounds, eval_every=eval_every, eval_fn=make_eval_fn(loss_fn),
        eval_batch=test, b_local=B_LOCAL, init_one=init_one_g,
        faults=faults,
    )
    return hist, (time.perf_counter() - t0) / rounds


def _recovery(hist, base_hist, fault_end):
    """First recorded post-fault round whose test loss is back within 10%
    of the uninterrupted baseline's at the same round; -1.0 if never."""
    for r, loss, ref in zip(hist["round"], hist["test_loss"], base_hist["test_loss"]):
        if r >= fault_end and loss <= ref * 1.10:
            return float(r - fault_end)
    return -1.0


def _scenario_records(n, rounds, per_node, hidden):
    setup = _setup(n, per_node, hidden)
    graph = setup[0]
    eval_every = max(rounds // 20, 1)
    trivial = membership_schedule(n, rounds)
    at, dur = rounds // 3, max(rounds // 10, 1)

    cohort = list(range(n - n // 8, n))
    scenarios = {
        "none": (trivial, None),
        "crash": (trivial, crash_burst(graph, rounds, at=at, size=n // 8, duration=dur, seed=0)),
        "hub": (trivial, hub_outage(graph, rounds, at=at, duration=dur, k=max(n // 16, 1))),
        "join": (
            membership_schedule(n, rounds, initial=n - n // 8,
                                arrivals={at: cohort}, join_warmup=8),
            None,
        ),
    }
    records, base_hist = [], None
    for name, (mem, faults) in scenarios.items():
        hist, spr = _elastic(graph, setup, mem, faults, rounds, eval_every)
        if name == "none":
            base_hist = hist
        fault_end = at + dur if faults is not None else at + mem.join_warmup
        rec = {
            "scenario": name,
            "n": n,
            "rounds": rounds,
            "final_test_loss": hist["test_loss"][-1],
            "delta_vs_uninterrupted": hist["test_loss"][-1] - base_hist["test_loss"][-1],
            "rounds_to_recover": 0.0 if name == "none" else _recovery(hist, base_hist, fault_end),
            "sec_per_round": spr,
            "curve_round": hist["round"],
            "curve_test_loss": hist["test_loss"],
            "curve_n_active": hist["n_active"],
        }
        records.append(rec)
        emit(
            f"fig11.{name}.n{n}",
            spr * 1e6,
            f"final={rec['final_test_loss']:.3f};"
            f"delta={rec['delta_vs_uninterrupted']:+.3f};"
            f"recover={rec['rounds_to_recover']:.0f};"
            f"min_active={min(hist['n_active'])}",
        )
    return records


def _ckpt_overhead_record(n, rounds, per_node, hidden, chunk_size):
    """Durable save + restore of the full carry vs the per-chunk scan wall."""
    setup = _setup(n, per_node, hidden)
    graph, xs, ys, test, loss_fn, opt, init_one, _ = setup
    sched = batch_index_schedule(per_node, n, BS, rounds * B_LOCAL, seed=0)
    rf = make_round_fn(loss_fn, opt, compile_plan(graph))
    kw = dict(n_rounds=rounds, eval_every=max(rounds // 4, 1),
              eval_fn=make_eval_fn(loss_fn), eval_batch=test,
              chunk_size=chunk_size, b_local=B_LOCAL)
    state = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)
    run_trajectory(state, rf, xs, ys, sched, **kw)  # compile
    t0 = time.perf_counter()
    final, _ = run_trajectory(state, rf, xs, ys, sched, **kw)
    n_chunks = -(-rounds // chunk_size)
    sec_per_chunk = (time.perf_counter() - t0) / n_chunks

    payload = {
        "carry": [np.asarray(l) for l in jax.tree_util.tree_leaves(final)],
        "outs": [],
    }
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        for s in range(3):
            save_train_state(d, s, payload, meta={"chunk": s}, keep_last=2)
        save_s = (time.perf_counter() - t0) / 3
        t0 = time.perf_counter()
        for _ in range(3):
            restore_train_state(d)
        restore_s = (time.perf_counter() - t0) / 3
    ckpt_bytes = sum(a.nbytes for a in payload["carry"])
    rec = {
        "scenario": "ckpt-overhead",
        "n": n,
        "rounds": rounds,
        "chunk_rounds": chunk_size,
        "ckpt_bytes": ckpt_bytes,
        "save_ms": save_s * 1e3,
        "restore_ms": restore_s * 1e3,
        "sec_per_chunk": sec_per_chunk,
        "overhead_ratio": save_s / sec_per_chunk,
    }
    emit(
        f"fig11.ckpt.n{n}",
        save_s * 1e6,
        f"save={rec['save_ms']:.1f}ms;restore={rec['restore_ms']:.1f}ms;"
        f"chunk={sec_per_chunk:.2f}s;overhead={rec['overhead_ratio'] * 100:.1f}%",
    )
    return rec


def _resume_parity_record(n, rounds, per_node, hidden):
    """Checkpoint → resume from the mid-run snapshot → bitwise compare."""
    setup = _setup(n, per_node, hidden)
    graph, xs, ys, _, loss_fn, opt, init_one, init_one_g = setup
    sched = batch_index_schedule(per_node, n, BS, rounds * B_LOCAL, seed=0)
    plan = compile_plan(graph)
    mem = membership_schedule(n, rounds, initial=n - 2,
                              arrivals={1: [n - 2, n - 1]}, join_warmup=3)
    kw = dict(n_rounds=rounds, eval_every=2, chunk_size=max(rounds // 3, 1),
              b_local=B_LOCAL, init_one=init_one_g)

    s0 = init_fl_state(jax.random.PRNGKey(1), n, init_one, opt)
    ref, h_ref, _ = run_elastic_trajectory(s0, loss_fn, opt, plan, mem, xs, ys, sched, **kw)
    with tempfile.TemporaryDirectory() as d:
        s1 = init_fl_state(jax.random.PRNGKey(1), n, init_one, opt)
        run_elastic_trajectory(s1, loss_fn, opt, plan, mem, xs, ys, sched,
                               checkpoint=CheckpointPolicy(d, every=1), **kw)
        s2 = init_fl_state(jax.random.PRNGKey(1), n, init_one, opt)
        got, h_got, _ = run_elastic_trajectory(
            s2, loss_fn, opt, plan, mem, xs, ys, sched,
            resume_from=str(pathlib.Path(d) / "step_00000000.ckpt"), **kw,
        )
    bit = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(got))
    ) and h_ref == h_got
    rec = {"scenario": "resume-parity", "n": n, "rounds": rounds, "parity_bitexact": bool(bit)}
    emit(f"fig11.resume.n{n}", 0.0, f"bitexact={bit}")
    return rec


def run(quick: bool = True) -> None:
    n = 32 if quick else 64
    rounds = 40 if quick else 120
    per_node = 64 if quick else 128
    hidden = (64, 32) if quick else (128, 64)

    records = _scenario_records(n, rounds, per_node, hidden)
    # overhead is save-cost / chunk-wall, so the chunking matters as much as
    # the model: 48-round chunks (the executor's auto default is ≥ n_rounds
    # at these scales) amortise one durable ~56 MB write per chunk
    records.append(_ckpt_overhead_record(
        64, 96, 64, (128, 64), chunk_size=48
    ))
    records.append(_resume_parity_record(16, 12, 32, (32,)))

    OUT.write_text(
        json.dumps(
            {
                "device": str(jax.devices()[0]),
                "cpu_count": __import__("os").cpu_count(),
                "quick": quick,
                "records": records,
            },
            indent=2,
        )
    )
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    run()

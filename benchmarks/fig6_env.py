"""Figure 6: environmental parameters under the proposed init —
(a) network density k, (b) training samples per node, (c) system size with
proportional data, (d) communication frequency (local epochs b).

Paper claims: trajectories are consistent across densities well above the
connectivity threshold; more data per node → approaches the centralised
limit; larger systems utilise proportional data; more frequent communication
→ faster convergence and lower final loss.
"""
from __future__ import annotations

from repro.core import topology as T

from .common import emit, run_dfl_mlp


def run(quick: bool = True) -> None:
    n = 16
    rounds = 50 if quick else 150

    # (a) density
    for k in (2, 4, 8):
        g = T.random_k_regular(n, k, seed=0)
        hist, spr = run_dfl_mlp(n_nodes=n, graph=g, rounds=rounds)
        emit(f"fig6a.k{k}", spr * 1e6, f"final={hist['test_loss'][-1]:.3f}")

    # (b) samples per node
    for per in (32, 128, 512) if not quick else (32, 128):
        hist, spr = run_dfl_mlp(n_nodes=n, per_node=per, rounds=rounds)
        emit(f"fig6b.samples{per}", spr * 1e6, f"final={hist['test_loss'][-1]:.3f}")

    # (c) system size with proportional total data
    for nn in (8, 16, 32):
        g = T.random_k_regular(nn, 8, seed=0) if nn > 8 else T.complete(8)
        hist, spr = run_dfl_mlp(n_nodes=nn, graph=g, per_node=128, rounds=rounds)
        emit(f"fig6c.n{nn}", spr * 1e6, f"final={hist['test_loss'][-1]:.3f}")

    # (d) communication frequency: b minibatches between aggregations,
    # wall-clock-equivalent = rounds × b held constant
    for b in (1, 2, 4):
        hist, spr = run_dfl_mlp(n_nodes=n, b_local=b, rounds=max(10, rounds * 2 // b) if quick else rounds * 4 // b)
        emit(f"fig6d.freq_b{b}", spr * 1e6, f"final={hist['test_loss'][-1]:.3f}")


if __name__ == "__main__":
    run()

"""Gossip estimation engine throughput (BENCH_estimates.json).

Times the two warmup protocols of ``repro.gossip`` — push-sum consensus and
the power-iteration ‖v_steady‖ estimator — as jitted 64-round scan blocks
over n × topology family, on the dense and sparse CommPlan backends.  The
estimation phase precedes *every* uncoordinated training run, so its
rounds/sec is a first-class number: the headline row is (heavytail, 1024),
where the sparse backend's O(E) spread must beat the dense O(n²) operator
for warmup to stay negligible at production ensemble sizes.

Schema: ``{device, cpu_count, quick, rounds_block, records: [{family, n,
n_edges, us_dense, us_sparse, us_pi_dense, us_pi_sparse,
sparse_speedup_vs_dense}]}`` — us_* are per *gossip round* (block time /
rounds).  ``tools/check_bench.py`` validates the checked-in artifact in CI.
"""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import numpy as np

from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.gossip import power_iteration_norm, push_sum

from .common import emit

_FAMILIES = {
    "ring": lambda n: T.ring(n),
    "kreg": lambda n: T.random_k_regular(n, 4, seed=0),
    "ba": lambda n: T.barabasi_albert(n, 4, seed=0),
    "heavytail": lambda n: T.configuration_heavy_tail(n, 2.2, seed=0),
}

BLOCK = 64  # rounds per jitted call: times the scan body, not dispatch


def _best_of(f, *args, iters=3):
    jax.block_until_ready(f(*args))  # compile + warm caches
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = f(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run(
    quick: bool = True,
    ns=None,
    out_path: str | pathlib.Path = "BENCH_estimates.json",
) -> dict:
    ns = ns if ns is not None else ((16, 64, 256) if quick else (16, 64, 256, 1024))
    records = []
    for family, build in _FAMILIES.items():
        for n in ns:
            g = build(n)
            vals = np.asarray(g.degrees, np.float32)
            row: dict = {
                "family": family,
                "n": n,
                "n_edges": g.n_edges,
                "rounds_block": BLOCK,
            }
            for backend in ("dense", "sparse"):
                plan = compile_plan(g, backend)
                sec = _best_of(
                    jax.jit(lambda v, p=plan: push_sum(p, v, BLOCK)), vals
                )
                row[f"us_{backend}"] = sec / BLOCK * 1e6
                emit(
                    f"estimates.push_sum.{backend}",
                    sec / BLOCK * 1e6,
                    f"family={family};n={n};rounds_per_sec={BLOCK / sec:.0f}",
                )
                sec_pi = _best_of(
                    jax.jit(
                        lambda p=plan: power_iteration_norm(p, BLOCK // 2, BLOCK // 2)
                    )
                )
                row[f"us_pi_{backend}"] = sec_pi / BLOCK * 1e6
                emit(
                    f"estimates.power_iter.{backend}",
                    sec_pi / BLOCK * 1e6,
                    f"family={family};n={n};rounds_per_sec={BLOCK / sec_pi:.0f}",
                )
            row["sparse_speedup_vs_dense"] = row["us_dense"] / row["us_sparse"]
            records.append(row)
    result = {
        "device": jax.devices()[0].device_kind,
        "cpu_count": os.cpu_count(),
        "quick": quick,
        "rounds_block": BLOCK,
        "records": records,
    }
    pathlib.Path(out_path).write_text(json.dumps(result, indent=1))
    return result


if __name__ == "__main__":
    run(quick=False)

"""Shared DFL experiment runner for the per-figure benchmarks.

Scale note (DESIGN.md §6): the paper's sweeps used 3500 GPU-hours; these
benches reproduce each figure's *claim* at CPU scale (n ≤ 64, MLP on
MNIST-like synthetic data, a few hundred rounds).  Every module prints
``name,us_per_call,derived`` CSV rows via ``emit``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import mnist_like, node_batch_iterator, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, train_loop
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import adamw, sgd

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def run_dfl_mlp(
    *,
    n_nodes: int,
    graph=None,
    gain: float | None = None,
    rounds: int = 60,
    per_node: int = 128,
    batch_size: int = 16,
    b_local: int = 2,
    hidden=(128, 64),
    optimizer="sgd",
    link_p: float = 1.0,
    node_p: float = 1.0,
    eval_every: int = 5,
    seed: int = 0,
    track_sigmas: bool = False,
    aggregate: bool = True,
    test_size: int = 512,
):
    """One DFL run of the paper's MLP config on MNIST-like data.

    Returns (history, seconds_per_round).
    """
    graph = graph if graph is not None else T.complete(n_nodes)
    gain = gain if gain is not None else gain_from_graph(graph)
    ds = mnist_like(n_nodes * per_node + test_size, seed=seed)
    parts = [np.arange(i * per_node, (i + 1) * per_node) for i in range(n_nodes)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-test_size:], ds.y[-test_size:])

    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5) if optimizer == "sgd" else adamw(1e-3)
    eval_fn = make_eval_fn(loss_fn)
    icfg = InitConfig("he_normal", gain)
    init_one = lambda k: init_mlp(icfg, k, hidden=hidden)
    state = init_fl_state(jax.random.PRNGKey(seed), n_nodes, init_one, opt)
    rf = make_round_fn(loss_fn, opt, graph, link_p=link_p, node_p=node_p, aggregate=aggregate)

    def batches():
        it = node_batch_iterator(xs, ys, batch_size, seed=seed)
        while True:
            bs = [next(it) for _ in range(b_local)]
            yield (
                np.stack([b.x for b in bs], axis=1),
                np.stack([b.y for b in bs], axis=1),
            )

    t0 = time.time()
    state, hist = train_loop(
        state, rf, batches(), n_rounds=rounds, eval_every=eval_every,
        eval_fn=eval_fn, eval_batch=test, track_sigmas=track_sigmas,
    )
    sec_per_round = (time.time() - t0) / rounds
    return hist, sec_per_round


def rounds_to_loss(hist: dict, threshold: float) -> float:
    """First recorded round where mean test loss drops below threshold."""
    for r, l in zip(hist["round"], hist["test_loss"]):
        if l < threshold:
            return r
    return float("inf")

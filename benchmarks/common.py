"""Shared DFL experiment runner for the per-figure benchmarks.

Scale note (DESIGN.md §6): the paper's sweeps used 3500 GPU-hours; these
benches reproduce each figure's *claim* at CPU scale (n ≤ 64, MLP on
MNIST-like synthetic data, a few hundred rounds).  Every module prints
``name,us_per_call,derived`` CSV rows via ``emit``.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import batch_index_schedule, mnist_like, node_batch_iterator, node_datasets
from repro.fed import (
    init_fl_state,
    make_eval_fn,
    make_round_fn,
    run_event_trajectory,
    run_sweep,
    run_trajectory,
    stack_states,
    train_loop,
)
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import adamw, sgd

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


class ChunkTimer:
    """Wall-clock per executor chunk via the ``on_chunk`` hook.

    Separates jit compile from steady-state throughput: the first chunk's
    wall carries compilation, later equal-size chunks measure the pure
    per-round (or per-event) cost.  A single ``total / rounds`` average
    conflates the two — compile is O(1) while the steady rate is what
    scales, so the conflated number misranks backends at small round
    counts.  ``split()`` returns ``(compile_seconds, steady_sec_per_item)``
    with compile = first-chunk wall minus its steady prediction, clamped
    at 0; a single-chunk run can't separate them and reports compile 0.
    """

    def __init__(self):
        self.t0 = time.time()
        self.walls: list[float] = []
        self.sizes: list[int] = []

    def __call__(self, *args):
        # run_trajectory-style hooks pass (r0, r1, hist); the event executor
        # passes (ci, i0, i1, acc) — either way the bounds lead.  The payload
        # may still be in flight (the event path hands over device buffers):
        # block, or the wall would measure dispatch instead of compute.
        for leaf in jax.tree_util.tree_leaves(args[-1]):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        now = time.time()
        lo, hi = (args[0], args[1]) if len(args) == 3 else (args[1], args[2])
        self.walls.append(now - self.t0)
        self.sizes.append(int(hi) - int(lo))
        self.t0 = now

    def split(self) -> tuple[float, float]:
        if not self.walls:
            return 0.0, 0.0
        full = self.sizes[0]
        # a trailing short chunk recompiles (new scan length) — exclude it
        steady_samples = [
            w / s for w, s in zip(self.walls[1:], self.sizes[1:]) if s == full
        ]
        if not steady_samples:
            return 0.0, self.walls[0] / max(full, 1)
        steady = float(np.median(steady_samples))
        return max(self.walls[0] - steady * full, 0.0), steady


def _mlp_setup(n_nodes, graph, per_node, hidden, optimizer, seed, test_size):
    """Shared dataset/model/optimizer setup for the MLP benchmark runs."""
    graph = graph if graph is not None else T.complete(n_nodes)
    ds = mnist_like(n_nodes * per_node + test_size, seed=seed)
    parts = [np.arange(i * per_node, (i + 1) * per_node) for i in range(n_nodes)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-test_size:], ds.y[-test_size:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5) if optimizer == "sgd" else adamw(1e-3)
    eval_fn = make_eval_fn(loss_fn)
    init_one = lambda gain: lambda k: init_mlp(InitConfig("he_normal", gain), k, hidden=hidden)
    return graph, xs, ys, test, loss_fn, opt, eval_fn, init_one


def run_dfl_mlp(
    *,
    n_nodes: int,
    graph=None,
    plan=None,
    gain: float | None = None,
    rounds: int = 60,
    per_node: int = 128,
    batch_size: int = 16,
    b_local: int = 2,
    hidden=(128, 64),
    optimizer="sgd",
    link_p: float = 1.0,
    node_p: float = 1.0,
    eval_every: int = 5,
    seed: int = 0,
    track_sigmas: bool = False,
    aggregate: bool = True,
    test_size: int = 512,
    executor: bool = True,
    timing: bool = False,
    compression=None,
):
    """One DFL run of the paper's MLP config on MNIST-like data.

    Runs through the fused round executor by default; ``executor=False``
    takes the legacy per-round ``train_loop`` (the BENCH_rounds baseline).
    ``plan`` overrides the mixing operator (a compiled ``CommPlan`` or a
    time-varying ``PlanSchedule``) while ``graph`` keeps describing the
    dataset/gain anchor.  Returns (history, seconds_per_round) — or, with
    ``timing=True`` (fused executor only), (history, timing_dict) where the
    dict splits the conflated average into ``compile_seconds`` and
    ``us_per_round_steady`` via :class:`ChunkTimer`.
    """
    if timing and not executor:
        raise ValueError("timing split needs the fused executor (chunk hook)")
    graph, xs, ys, test, loss_fn, opt, eval_fn, init_one = _mlp_setup(
        n_nodes, graph, per_node, hidden, optimizer, seed, test_size
    )
    gain = gain if gain is not None else gain_from_graph(graph)
    state = init_fl_state(jax.random.PRNGKey(seed), n_nodes, init_one(gain), opt)
    rf = make_round_fn(
        loss_fn, opt, plan if plan is not None else graph,
        link_p=link_p, node_p=node_p, aggregate=aggregate,
        compression=compression,
    )

    t0 = time.time()
    if executor:
        sched = batch_index_schedule(per_node, n_nodes, batch_size, rounds * b_local, seed=seed)
        timer = ChunkTimer() if timing else None
        state, hist = run_trajectory(
            state, rf, xs, ys, sched, n_rounds=rounds, eval_every=eval_every,
            eval_fn=eval_fn, eval_batch=test, track_sigmas=track_sigmas,
            b_local=b_local, chunk_size=max(rounds // 8, 1) if timing else 0,
            on_chunk=timer,
        )
    else:
        def batches():
            it = node_batch_iterator(xs, ys, batch_size, seed=seed)
            while True:
                bs = [next(it) for _ in range(b_local)]
                yield (
                    np.stack([b.x for b in bs], axis=1),
                    np.stack([b.y for b in bs], axis=1),
                )

        state, hist = train_loop(
            state, rf, batches(), n_rounds=rounds, eval_every=eval_every,
            eval_fn=eval_fn, eval_batch=test, track_sigmas=track_sigmas,
        )
    sec_per_round = (time.time() - t0) / rounds
    if timing:
        compile_s, steady = timer.split()
        return hist, {
            "sec_per_round": sec_per_round,
            "compile_seconds": compile_s,
            "us_per_round_steady": steady * 1e6,
        }
    return hist, sec_per_round


def run_dfl_mlp_sweep(
    *,
    n_nodes: int,
    gains,
    seeds=(0,),
    graph=None,
    rounds: int = 60,
    per_node: int = 128,
    batch_size: int = 16,
    b_local: int = 2,
    hidden=(128, 64),
    optimizer="sgd",
    eval_every: int = 5,
    data_seed: int = 0,
    track_sigmas: bool = False,
    test_size: int = 512,
):
    """Vmapped grid of MLP trajectories: one compiled program per call.

    Sweeps the (gain × seed) grid over a shared dataset/topology/batch order
    (exactly what fig1's per-n {He, corrected} pair needs).  Returns
    (histories, seconds_per_run) where ``histories[i][j]`` is the history for
    gains[i] × seeds[j].
    """
    graph, xs, ys, test, loss_fn, opt, eval_fn, init_one = _mlp_setup(
        n_nodes, graph, per_node, hidden, optimizer, data_seed, test_size
    )
    states = [
        init_fl_state(jax.random.PRNGKey(s), n_nodes, init_one(g), opt)
        for g in gains
        for s in seeds
    ]
    rf = make_round_fn(loss_fn, opt, graph)
    sched = batch_index_schedule(per_node, n_nodes, batch_size, rounds * b_local, seed=data_seed)
    t0 = time.time()
    _, hists = run_sweep(
        stack_states(states), rf, xs, ys, sched, n_rounds=rounds,
        eval_every=eval_every, eval_fn=eval_fn, eval_batch=test,
        track_sigmas=track_sigmas, b_local=b_local,
    )
    sec_per_run = (time.time() - t0) / len(states)
    grid = [
        [hists[i * len(seeds) + j] for j in range(len(seeds))] for i in range(len(gains))
    ]
    return grid, sec_per_run


def run_dfl_mlp_async(
    *,
    n_nodes: int,
    horizon: float,
    rate: float = 1.0,
    graph=None,
    gain: float | None = None,
    per_node: int = 128,
    batch_size: int = 16,
    b_local: int = 2,
    hidden=(128, 64),
    optimizer="sgd",
    n_bins: int = 10,
    link_p: float = 1.0,
    node_p: float = 1.0,
    seed: int = 0,
    test_size: int = 512,
    timing: bool = False,
):
    """One event-driven DFL run of the paper's MLP config: per-edge Poisson
    clocks at ``rate`` over ``horizon`` units of virtual time, executed as
    one scanned program (``fed.executor.run_event_trajectory``).  Rate 1
    with ``horizon = R`` is the message-budget-matched peer of R synchronous
    rounds.  Returns (history, seconds_per_event, stream); with
    ``timing=True`` the middle element is instead a dict splitting the
    average into ``compile_seconds`` and ``us_per_event_steady``.
    """
    from repro.core.commplan import FailureModel, compile_plan

    graph, xs, ys, test, loss_fn, opt, eval_fn, init_one = _mlp_setup(
        n_nodes, graph, per_node, hidden, optimizer, seed, test_size
    )
    gain = gain if gain is not None else gain_from_graph(graph)
    state = init_fl_state(jax.random.PRNGKey(seed), n_nodes, init_one(gain), opt)
    plan = compile_plan(graph, failures=FailureModel(link_p=link_p, node_p=node_p))
    stream = T.poisson_event_stream(graph, horizon=horizon, rate=rate, seed=seed + 1)
    sched = batch_index_schedule(
        per_node, n_nodes, batch_size, max(int(horizon), 1) * b_local, seed=seed
    )
    t0 = time.time()
    timer = ChunkTimer() if timing else None
    _, hist, _ = run_event_trajectory(
        state, loss_fn, opt, plan, stream, xs, ys, sched,
        b_local=b_local, n_bins=n_bins, eval_fn=eval_fn, eval_batch=test,
        chunk_events=max(stream.n_events // 8, 1) if timing else 0,
        on_chunk=timer,
    )
    sec_per_event = (time.time() - t0) / max(stream.n_events, 1)
    if timing:
        compile_s, steady = timer.split()
        return hist, {
            "sec_per_event": sec_per_event,
            "compile_seconds": compile_s,
            "us_per_event_steady": steady * 1e6,
        }, stream
    return hist, sec_per_event, stream


def run_dfl_mlp_uncoordinated(
    *,
    n_nodes: int,
    est_rounds: int,
    graph=None,
    plan=None,
    rounds: int = 60,
    per_node: int = 128,
    batch_size: int = 16,
    b_local: int = 2,
    hidden=(128, 64),
    optimizer="sgd",
    mode: str = "vnorm",
    leaderless: bool = False,
    eval_every: int = 5,
    seed: int = 0,
    test_size: int = 512,
):
    """One truly-uncoordinated DFL run: per-node gains from the on-device
    gossip engine with a budget of ``est_rounds`` rounds each for the
    power-iteration and push-sum phases, fused into the training program via
    ``run_warmup_trajectory`` (estimate → per-node init → train, one jit).
    ``plan`` (a ``CommPlan`` or time-varying ``PlanSchedule``) overrides the
    operator both phases ride — fig8's churned end-to-end path.

    Returns (history, seconds_per_round, gains) — ``gains`` is the realised
    (n,) per-node vector, so callers can report estimation noise.
    """
    from repro.core.commplan import compile_plan
    from repro.fed import run_warmup_trajectory
    from repro.gossip import make_gain_estimator

    graph, xs, ys, test, loss_fn, opt, eval_fn, init_one = _mlp_setup(
        n_nodes, graph, per_node, hidden, optimizer, seed, test_size
    )
    init_one_g = lambda k, gn: init_one(gn)(k)
    mix_plan = plan if plan is not None else graph
    estimate_fn = make_gain_estimator(
        plan if plan is not None else compile_plan(graph),
        pi_rounds=est_rounds, ps_rounds=est_rounds, mode=mode, leaderless=leaderless,
    )
    rf = make_round_fn(loss_fn, opt, mix_plan)
    sched = batch_index_schedule(per_node, n_nodes, batch_size, rounds * b_local, seed=seed)
    t0 = time.time()
    state, hist, gains = run_warmup_trajectory(
        jax.random.PRNGKey(seed), rf, xs, ys, sched, n_nodes=n_nodes,
        init_one=init_one_g, optimizer=opt, estimate_gains=estimate_fn,
        n_rounds=rounds, eval_every=eval_every, eval_fn=eval_fn, eval_batch=test,
        b_local=b_local,
    )
    sec_per_round = (time.time() - t0) / rounds
    return hist, sec_per_round, gains


def run_dfl_mlp_uncoordinated_sweep(
    *,
    n_nodes: int,
    budgets,
    seeds=(0,),
    graph=None,
    plan=None,
    rounds: int = 60,
    per_node: int = 128,
    batch_size: int = 16,
    b_local: int = 2,
    hidden=(128, 64),
    optimizer="sgd",
    mode: str = "vnorm",
    leaderless: bool = False,
    eval_every: int = 5,
    data_seed: int = 0,
    test_size: int = 512,
):
    """The (gossip budget × seed) grid of uncoordinated runs as ONE vmapped
    program (fig4's primary sweep): a single gain estimator is built at the
    max budget and each run masks its tail rounds, so every (budget, seed)
    cell shares one program shape (``fed.executor.run_warmup_sweep``).

    Returns (grid, seconds_per_run) where ``grid[i][j]`` is
    ``(history, gains)`` for budgets[i] × seeds[j].
    """
    from repro.core.commplan import compile_plan
    from repro.fed import run_warmup_sweep
    from repro.gossip import make_gain_estimator

    graph, xs, ys, test, loss_fn, opt, eval_fn, init_one = _mlp_setup(
        n_nodes, graph, per_node, hidden, optimizer, data_seed, test_size
    )
    init_one_g = lambda k, gn: init_one(gn)(k)
    max_b = int(max(budgets))
    estimate_fn = make_gain_estimator(
        plan if plan is not None else compile_plan(graph),
        pi_rounds=max_b, ps_rounds=max_b, mode=mode, leaderless=leaderless,
    )
    rf = make_round_fn(loss_fn, opt, plan if plan is not None else graph)
    sched = batch_index_schedule(per_node, n_nodes, batch_size, rounds * b_local, seed=data_seed)
    keys = [jax.random.PRNGKey(s) for _b in budgets for s in seeds]
    buds = [b for b in budgets for _s in seeds]
    t0 = time.time()
    _, hists, gains = run_warmup_sweep(
        keys, rf, xs, ys, sched, n_nodes=n_nodes, init_one=init_one_g,
        optimizer=opt, estimate_gains=estimate_fn, budgets=buds,
        n_rounds=rounds, eval_every=eval_every, eval_fn=eval_fn, eval_batch=test,
        b_local=b_local,
    )
    sec_per_run = (time.time() - t0) / len(keys)
    grid = [
        [
            (hists[i * len(seeds) + j], gains[i * len(seeds) + j])
            for j in range(len(seeds))
        ]
        for i in range(len(budgets))
    ]
    return grid, sec_per_run


def rounds_to_loss(hist: dict, threshold: float) -> float:
    """First recorded round where mean test loss drops below threshold."""
    for r, l in zip(hist["round"], hist["test_loss"]):
        if l < threshold:
            return r
    return float("inf")

"""Figure 5: ‖v_steady‖ scaling with n per network family (a,b) and its
invariance under degree-preserving assortativity rewiring (c).

Paper claims: homogeneous families (ER, k-regular) give ‖v‖ = n^-1/2;
BA / heavy-tail configuration models give smaller exponents depending on γ;
assortativity rewiring leaves ‖v‖ unchanged.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import mixing as M
from repro.core import topology as T

from .common import emit


def run(quick: bool = True) -> None:
    ns = [128, 512, 2048] if quick else [128, 512, 2048, 8192]
    fams = {
        "kregular8": lambda n: T.random_k_regular(n, 8, seed=0),
        "er_gnm": lambda n: T.erdos_renyi_gnm(n, 4 * n, seed=0),
        "ba_m4": lambda n: T.barabasi_albert(n, 4, seed=0),
        "conf_g2.2": lambda n: T.configuration_heavy_tail(n, 2.2, seed=0),
        "conf_g3.0": lambda n: T.configuration_heavy_tail(n, 3.0, seed=0),
    }
    for fam, build in fams.items():
        t0 = time.time()
        vs = [M.v_steady_norm(build(n)) for n in ns]
        alpha = -float(np.polyfit(np.log(ns), np.log(vs), 1)[0])
        emit(
            f"fig5.{fam}",
            (time.time() - t0) * 1e6 / len(ns),
            f"alpha={alpha:.3f};vnorm_n{ns[-1]}={vs[-1]:.4f}",
        )

    # (c) assortativity invariance
    g = T.erdos_renyi_gnp(512 if quick else 2048, 8 / (512 if quick else 2048), seed=5)
    before = M.v_steady_norm(g)
    t0 = time.time()
    drift = 0.0
    for rho in (-0.3, 0.0, 0.3):
        g2 = M.rewire_to_assortativity(g, rho, steps=40000, seed=1)
        drift = max(drift, abs(M.v_steady_norm(g2) - before))
    emit("fig5.assortativity_invariance", (time.time() - t0) * 1e6 / 3, f"max_vnorm_drift={drift:.2e}")


if __name__ == "__main__":
    run()

"""Figure 8: DFL under topology churn — the PlanSchedule end-to-end story.

Real deployments have link churn and mobility; the paper's analysis assumes
a static graph.  This benchmark (DESIGN.md §13) measures what the
``PlanSchedule`` machinery costs and what churn does to the paper's claims:

* **churn sweep** (family × churn rate): a Markov chain of edge up/down
  rewired snapshots (``topology.churn_sequence``) compiled into one
  ``PlanSchedule`` and driven END-TO-END — leaderless gossip estimation →
  per-node gains → init → training — inside ONE jitted scan, with the
  operator switching by round index every ``PERIOD`` rounds.  The static
  (churn-free) run of the same family anchors the comparison.
* **envelope row**: per-round executor cost of a K=8 schedule vs the static
  plan at n=256 on the sparse backend — the gather-over-stacked-buffers
  overhead the schedule adds to the round body.  Acceptance: ≤ 1.3×.

Schema (``BENCH_churn.json``): ``{device, cpu_count, quick, records: [
{family, n, k_plans, churn_rate, rounds, sec_per_round_static,
sec_per_round_schedule, overhead_vs_static, ...}]}`` — validated by
``tools/check_bench.py`` in CI.  The envelope row also carries the
``ChunkTimer`` compile/steady split (``compile_seconds_*`` +
``us_per_round_steady_*``); the committed artifact is quick-mode so the
CI bench-regression gate diffs like against like (a full-mode committed
copy would never identity-match the quick regeneration, silently
disabling the timing gate).
"""
from __future__ import annotations

import json
import pathlib

import jax

from repro.core import topology as T
from repro.core.commplan import compile_schedule, cyclic_map

from .common import emit, run_dfl_mlp, run_dfl_mlp_uncoordinated

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_churn.json"

FAMILIES = {
    "kreg": lambda n, seed: T.random_k_regular(n, 8, seed=seed),
    "ba": lambda n, seed: T.barabasi_albert(n, 4, seed=seed),
}

PERIOD = 2  # rounds each snapshot stays active


def _schedule(base, k_plans, rate, backend="sparse"):
    graphs = T.churn_sequence(base, k_plans, rate, seed=1)
    return compile_schedule(graphs, backend=backend, round_map=cyclic_map(PERIOD))


def run(quick: bool = True) -> None:
    n = 32 if quick else 64
    rounds = 40 if quick else 150
    k_plans = 4 if quick else 8
    est_rounds = 16 if quick else 32
    records = []

    for family, build in FAMILIES.items():
        base = build(n, 0)
        # static anchor: same family, same fused warmup path, K = 1
        hist_st, spr_st, gains_st = run_dfl_mlp_uncoordinated(
            n_nodes=n, graph=base, plan=_schedule(base, 1, 0.0),
            est_rounds=est_rounds, rounds=rounds, leaderless=True,
        )
        for rate in (0.05, 0.2):
            sched = _schedule(base, k_plans, rate)
            hist, spr, gains = run_dfl_mlp_uncoordinated(
                n_nodes=n, graph=base, plan=sched,
                est_rounds=est_rounds, rounds=rounds, leaderless=True,
            )
            rec = {
                "family": family,
                "n": n,
                "k_plans": k_plans,
                "churn_rate": rate,
                "rounds": rounds,
                "sec_per_round_static": spr_st,
                "sec_per_round_schedule": spr,
                "overhead_vs_static": spr / spr_st,
                "final_test_loss_static": hist_st["test_loss"][-1],
                "final_test_loss_schedule": hist["test_loss"][-1],
                "gain_mean": float(gains.mean()),
                "gain_spread": float(gains.max() - gains.min()),
            }
            records.append(rec)
            emit(
                f"fig8.{family}.churn{rate:g}",
                spr * 1e6,
                f"final={rec['final_test_loss_schedule']:.3f};"
                f"static={rec['final_test_loss_static']:.3f};"
                f"overhead={rec['overhead_vs_static']:.2f}x;"
                f"gain_mean={rec['gain_mean']:.2f}",
            )

    # ---- envelope row: schedule-machinery cost at scale (acceptance) ------
    n_big = 128 if quick else 256
    big_rounds = 20 if quick else 40
    base = T.random_k_regular(n_big, 8, seed=0)
    sched = _schedule(base, 8, 0.1)

    def timed(plan):
        best = None
        for _ in range(2):
            _, t = run_dfl_mlp(
                n_nodes=n_big, graph=base, plan=plan, rounds=big_rounds,
                eval_every=0, per_node=64, timing=True,
            )
            if best is None or t["us_per_round_steady"] < best["us_per_round_steady"]:
                best = t
        return best

    t_st = timed(None)  # graph → auto backend = sparse at this n
    t_sc = timed(sched)
    rec = {
        "family": "kreg",
        "n": n_big,
        "k_plans": 8,
        "churn_rate": 0.1,
        "rounds": big_rounds,
        "sec_per_round_static": t_st["sec_per_round"],
        "sec_per_round_schedule": t_sc["sec_per_round"],
        "us_per_round_steady_static": t_st["us_per_round_steady"],
        "us_per_round_steady_schedule": t_sc["us_per_round_steady"],
        "compile_seconds_static": t_st["compile_seconds"],
        "compile_seconds_schedule": t_sc["compile_seconds"],
        # the acceptance ratio gates steady throughput only — the conflated
        # sec_per_round_* walls (kept for continuity) fold compile in and
        # overstate the schedule's cost at small round counts
        "overhead_vs_static": t_sc["us_per_round_steady"] / t_st["us_per_round_steady"],
        "config": "envelope_sparse",
    }
    records.append(rec)
    emit(
        f"fig8.envelope_n{n_big}_k8",
        rec["us_per_round_steady_schedule"],
        f"overhead={rec['overhead_vs_static']:.2f}x;"
        f"static_us={rec['us_per_round_steady_static']:.0f};"
        f"schedule_us={rec['us_per_round_steady_schedule']:.0f};"
        f"compile_s={rec['compile_seconds_schedule']:.1f}",
    )

    OUT.write_text(
        json.dumps(
            {
                "device": str(jax.devices()[0]),
                "cpu_count": __import__("os").cpu_count(),
                "quick": quick,
                "records": records,
            },
            indent=2,
        )
    )
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    run()

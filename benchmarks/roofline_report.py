"""Roofline table from the dry-run artifacts (deliverable g).

Reads results/dryrun/*.json written by ``python -m repro.launch.dryrun`` and
emits one row per (arch × shape × mesh) with the three roofline terms, the
dominant bottleneck and the useful-FLOPs ratio.
"""
from __future__ import annotations

import glob
import json
import os

from .common import emit

RESULTS_DIR = os.environ.get("DRYRUN_RESULTS", "results/dryrun")


def run(quick: bool = True) -> None:
    files = sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json")))
    if not files:
        emit("roofline.NOTE", 0.0, f"no dry-run artifacts in {RESULTS_DIR}; run python -m repro.launch.dryrun --all")
        return
    n_ok = n_err = 0
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        tag = f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        if rec.get("mixing") and rec["mixing"] != "dense":
            tag += f".{rec['mixing']}"
        if rec["status"] != "ok":
            n_err += 1
            emit(tag, 0.0, f"ERROR={rec.get('error','?')[:80]}")
            continue
        n_ok += 1
        t = rec["terms"]
        emit(
            tag,
            rec.get("lower_compile_s", 0.0) * 1e6,
            f"dominant={t['dominant']};compute_s={t['compute_s']:.3e};"
            f"memory_s={t['memory_s']:.3e};collective_s={t['collective_s']:.3e};"
            f"useful_ratio={rec.get('useful_flops_ratio', 0):.2f}",
        )
    emit("roofline.summary", 0.0, f"ok={n_ok};errors={n_err}")


if __name__ == "__main__":
    run()

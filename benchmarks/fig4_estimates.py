"""Figure 4: robustness of the proposed init to imperfect knowledge.

Two renderings of mis-estimation:

* **gossip-budget sweep (primary)** — noise produced the way §4.4 actually
  produces it: every node runs the on-device gossip engine
  (``repro.gossip``) for a budget of B power-iteration + B push-sum rounds
  over a random 4-regular graph, and its *own* noisy ``‖v̂_steady‖⁻¹``
  feeds the fused estimate→init→train warmup trajectory.  Small budgets →
  genuinely per-node, genuinely wrong gains; the claim is that training
  still beats the unscaled He baseline by a wide margin.  The whole budget
  grid compiles to ONE vmapped program (``fed.executor.run_warmup_sweep``):
  a single estimator built at the max budget masks each run's tail rounds.
* **hand-fabricated reference (``fig4.ref.*``)** — the original controlled
  n × factor / exponent distortions of a single global gain, kept as the
  labelled reference curve the gossip sweep is read against.
"""
from __future__ import annotations

from repro.core import topology as T
from repro.core.initialisation import gain_from_estimates

from .common import emit, run_dfl_mlp, run_dfl_mlp_uncoordinated_sweep


def run(quick: bool = True) -> None:
    n = 16
    rounds = 60 if quick else 150
    # a sparse graph: gossip needs multiple rounds to converge there, so
    # small budgets yield honest per-node noise (on the complete graph one
    # round is already exact)
    g = T.random_k_regular(n, 4, seed=0)

    # anchors: perfect-knowledge gain and the unscaled He baseline
    hist_exact, spr = run_dfl_mlp(n_nodes=n, graph=g, rounds=rounds)
    emit("fig4.exact_gain", spr * 1e6, f"final={hist_exact['test_loss'][-1]:.3f}")
    hist_he, spr = run_dfl_mlp(n_nodes=n, graph=g, gain=1.0, rounds=rounds)
    emit("fig4.he_baseline", spr * 1e6, f"final={hist_he['test_loss'][-1]:.3f}")

    # primary: estimation budget → per-node noisy gains → fused warmup runs,
    # the whole budget grid as one vmapped program (budgets start at the
    # graph diameter: below it some nodes have not yet heard from the leader
    # and no size estimate exists at all)
    budgets = (4, 8, 16) if quick else (4, 8, 16, 32, 64)
    grid, spr = run_dfl_mlp_uncoordinated_sweep(
        n_nodes=n, graph=g, budgets=budgets, rounds=rounds
    )
    for budget, row in zip(budgets, grid):
        hist, gains = row[0]
        emit(
            f"fig4.gossip_budget{budget}",
            spr / rounds * 1e6,  # per-round µs, same unit as every other row
            f"gain_mean={gains.mean():.2f};gain_spread={gains.max() - gains.min():.3f};"
            f"final={hist['test_loss'][-1]:.3f}",
        )

    # reference: the original hand-fabricated mis-estimation sweep
    base = None
    for factor in (0.25, 0.5, 1.0, 2.0, 4.0):
        gain = gain_from_estimates(n * factor)
        hist, spr = run_dfl_mlp(n_nodes=n, graph=g, gain=gain, rounds=rounds)
        if factor == 1.0:
            base = hist["test_loss"][-1]
        emit(
            f"fig4.ref.n_estimate_x{factor:g}",
            spr * 1e6,
            f"gain={gain:.2f};final={hist['test_loss'][-1]:.3f}",
        )
    # exponent mis-estimation (α = 0.25 vs the true 0.5 for k-regular graphs)
    for alpha in (0.25, 0.5, 0.75):
        gain = gain_from_estimates(n, family_exponent=alpha)
        hist, spr = run_dfl_mlp(n_nodes=n, graph=g, gain=gain, rounds=rounds)
        emit(
            f"fig4.ref.alpha{alpha:g}",
            spr * 1e6,
            f"gain={gain:.2f};final={hist['test_loss'][-1]:.3f};proposed_exact={base:.3f}",
        )


if __name__ == "__main__":
    run()

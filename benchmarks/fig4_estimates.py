"""Figure 4: robustness of the proposed init to imperfect knowledge —
over/under-estimating n (a) or the scaling exponent (b) still beats the
unscaled He baseline by a wide margin.
"""
from __future__ import annotations

from repro.core.initialisation import gain_from_estimates

from .common import emit, run_dfl_mlp


def run(quick: bool = True) -> None:
    n = 16
    rounds = 60 if quick else 150
    base = None
    for factor in (0.25, 0.5, 1.0, 2.0, 4.0):
        gain = gain_from_estimates(n * factor)
        hist, spr = run_dfl_mlp(n_nodes=n, gain=gain, rounds=rounds)
        if factor == 1.0:
            base = hist["test_loss"][-1]
        emit(
            f"fig4.n_estimate_x{factor:g}",
            spr * 1e6,
            f"gain={gain:.2f};final={hist['test_loss'][-1]:.3f}",
        )
    # exponent mis-estimation (α = 0.25 vs the true 0.5 for complete graphs)
    for alpha in (0.25, 0.5, 0.75):
        gain = gain_from_estimates(n, family_exponent=alpha)
        hist, spr = run_dfl_mlp(n_nodes=n, gain=gain, rounds=rounds)
        emit(
            f"fig4.alpha{alpha:g}",
            spr * 1e6,
            f"gain={gain:.2f};final={hist['test_loss'][-1]:.3f}",
        )
    hist_he, spr = run_dfl_mlp(n_nodes=n, gain=1.0, rounds=rounds)
    emit("fig4.he_baseline", spr * 1e6, f"final={hist_he['test_loss'][-1]:.3f};proposed_exact={base:.3f}")


if __name__ == "__main__":
    run()

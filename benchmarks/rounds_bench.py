"""Round-loop benchmark: legacy per-round dispatch vs the fused executor.

Measures the repo's true hot path — whole training trajectories — on the
fig1 quick configuration and writes ``BENCH_rounds.json``.  Three renderings
per system size:

* ``legacy``   — per-round ``train_loop`` dispatch (host batch assembly,
                 one jitted call per round, separate eval dispatches).
* ``executor`` — one fused scan-over-rounds program (on-device sampling,
                 in-scan metrics).
* ``sweep``    — fig1's actual workload: the {He, corrected} init pair run
                 as ONE vmapped program over the executor's sweep axis,
                 compared against the two sequential legacy runs the old
                 driver performed.

Wall-clock context (DESIGN.md §10.2): on CPU hosts with few cores the round
body is compute-bound, so the end-to-end ratio approaches the dispatch/host
overhead share rather than the ≥5× seen where rounds are dispatch-bound; the
``sec_per_round`` columns record both so the split is visible.
"""
from __future__ import annotations

import json
import pathlib

import jax

from repro.core import topology as T
from repro.core.initialisation import gain_from_graph

from .common import emit, run_dfl_mlp, run_dfl_mlp_sweep

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_rounds.json"


def _best_of(fn, reps: int):
    """(best trajectory seconds, last history) for a runner returning
    (history, trajectory_seconds).  Timing comes from the runner itself, so
    host-side dataset synthesis / state init (identical on every path) stay
    out of the ratio; the min over reps filters scheduler noise on shared
    hosts, where single-shot timings drift by ~2×."""
    best, hist = float("inf"), None
    for _ in range(reps):
        hist, sec = fn()
        best = min(best, sec)
    return best, hist


def run(quick: bool = True) -> None:
    rounds = 400 if quick else 1000
    reps = 2
    records = []

    for n in ([8, 16, 32] if quick else [8, 16, 32, 64]):
        cfg = dict(n_nodes=n, rounds=rounds, eval_every=4)

        def one(executor, gain=None):
            hist, spr = run_dfl_mlp(executor=executor, gain=gain, **cfg)
            return hist, spr * rounds

        s_ex, hist_ex = _best_of(lambda: one(True), reps)
        s_lg, hist_lg = _best_of(lambda: one(False), reps)  # corrected gain
        s_lg_he, _ = _best_of(lambda: one(False, gain=1.0), reps)

        # fig1's real per-n workload: both inits.  legacy = the corrected +
        # He runs timed above, sequential; executor = one vmapped pair
        # sharing data/schedule/compile.
        gains = [1.0, gain_from_graph(T.complete(n))]

        def pair_sweep():
            _, sec_per_run = run_dfl_mlp_sweep(
                n_nodes=n, gains=gains, rounds=rounds, eval_every=4
            )
            return None, sec_per_run * len(gains)

        s_pair_lg = s_lg + s_lg_he
        s_pair_ex, _ = _best_of(pair_sweep, reps)

        rec = {
            "config": f"fig1_quick_n{n}",
            "n_nodes": n,
            "rounds": rounds,
            "sec_legacy": s_lg,
            "sec_executor": s_ex,
            "speedup": s_lg / s_ex,
            "sec_fig1_pair_legacy": s_pair_lg,
            "sec_fig1_pair_sweep": s_pair_ex,
            "speedup_fig1_pair": s_pair_lg / s_pair_ex,
            "final_test_loss_legacy": hist_lg["test_loss"][-1],
            "final_test_loss_executor": hist_ex["test_loss"][-1],
        }
        records.append(rec)
        emit(
            f"rounds.fig1_n{n}",
            s_ex / rounds * 1e6,
            f"speedup={rec['speedup']:.1f}x;pair_speedup={rec['speedup_fig1_pair']:.1f}x;"
            f"sec_legacy={s_lg:.1f};sec_executor={s_ex:.1f}",
        )

    # ---- previously-impractical scale: n=128 on a sparse backend ------
    n_big = 128 if quick else 256
    big_rounds = rounds // 2
    g = T.random_k_regular(n_big, 8, seed=0)

    def big():
        hist, spr = run_dfl_mlp(
            executor=True, n_nodes=n_big, graph=g, rounds=big_rounds,
            eval_every=8, track_sigmas=True,
        )
        return hist, spr * big_rounds

    s_big, hist_big = _best_of(big, 1)
    records.append(
        {
            "config": f"kreg8_n{n_big}",
            "n_nodes": n_big,
            "rounds": big_rounds,
            "sec_executor": s_big,
            "sec_per_round": s_big / big_rounds,
            "final_test_loss_executor": hist_big["test_loss"][-1],
        }
    )
    emit(
        f"rounds.kreg8_n{n_big}",
        s_big / big_rounds * 1e6,
        f"sec_total={s_big:.1f};final={hist_big['test_loss'][-1]:.3f}",
    )

    OUT.write_text(
        json.dumps(
            {
                "device": str(jax.devices()[0]),
                "cpu_count": __import__("os").cpu_count(),
                "quick": quick,
                "records": records,
            },
            indent=2,
        )
    )
    print(f"# wrote {OUT}", flush=True)


if __name__ == "__main__":
    run()

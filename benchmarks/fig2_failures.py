"""Figure 2: robustness to link/node failures (activation probability p).

Paper claim: with the proposed init the system maintains a much better
learning trajectory than He-init even at low p; inactive nodes still train
locally.
"""
from __future__ import annotations

from .common import emit, run_dfl_mlp


def run(quick: bool = True) -> None:
    n = 16
    rounds = 60 if quick else 150
    for mode in ("link", "node"):
        for p in (0.2, 0.5, 1.0):
            kw = {"link_p": p} if mode == "link" else {"node_p": p}
            hist_prop, spr = run_dfl_mlp(n_nodes=n, rounds=rounds, **kw)
            hist_he, _ = run_dfl_mlp(n_nodes=n, gain=1.0, rounds=rounds, **kw)
            emit(
                f"fig2.{mode}_p{p:g}",
                spr * 1e6,
                f"final_proposed={hist_prop['test_loss'][-1]:.3f};"
                f"final_he={hist_he['test_loss'][-1]:.3f}",
            )


if __name__ == "__main__":
    run()

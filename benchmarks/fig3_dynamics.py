"""Figure 3: early-stage dynamics — aggregation dominates training; σ_an
collapses to the noise floor while σ_ap compresses to σ_init‖v_steady‖.

(a) magnitude of parameter change due to aggregation vs local training,
(b) σ_an/σ_ap on the real ANN system, (c) the simplified numerical model.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topology as T
from repro.core.diffusion import run_diffusion
from repro.core.initialisation import InitConfig
from repro.core.mixing import receive_matrix, v_steady_norm
from repro.core.decavg import mix_pytree
from repro.data import mnist_like, node_batch_iterator, node_datasets
from repro.fed import init_fl_state, sigma_metrics
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

from .common import emit


def run(quick: bool = True) -> None:
    n, k = (32, 8) if quick else (256, 32)
    graph = T.random_k_regular(n, k, seed=0)

    # ---- (c) numerical model -----------------------------------------
    t0 = time.time()
    res = run_diffusion(graph, d=1024, sigma_noise=1e-4, rounds=150, seed=0)
    emit(
        "fig3.numerical_model",
        (time.time() - t0) * 1e6 / 150,
        f"sigma_ap_final={res.sigma_ap[-1]:.4f};prediction={res.sigma_ap_prediction:.4f};"
        f"sigma_an_final={res.sigma_an[-1]:.2e}",
    )

    # ---- (a,b) real ANN system ----------------------------------------
    per_node = 80  # paper: 80 samples/node for this figure
    ds = mnist_like(n * per_node + 128, seed=0)
    parts = [np.arange(i * per_node, (i + 1) * per_node) for i in range(n)]
    xs, ys = node_datasets(ds, parts)
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    icfg = InitConfig("he_normal", 1.0)  # paper panel uses the He baseline
    state = init_fl_state(jax.random.PRNGKey(0), n, init_one=lambda key: init_mlp(icfg, key, hidden=(128, 64)), optimizer=opt)
    m = jnp.asarray(receive_matrix(graph), jnp.float32)
    it = node_batch_iterator(xs, ys, 16, seed=0)

    flat = lambda tree: jnp.concatenate([l.reshape(n, -1) for l in jax.tree_util.tree_leaves(tree)], axis=1)

    @jax.jit
    def one_round(params, opt_state, bx, by):
        def local(p, s, x, y):
            loss, g = jax.value_and_grad(loss_fn)(p, (x, y))
            upd, s = opt.update(g, s, p)
            return jax.tree_util.tree_map(lambda a, u: a + u, p, upd), s

        p_trained, opt_state = jax.vmap(local)(params, opt_state, bx, by)
        p_mixed = mix_pytree(m, p_trained)
        d_train = jnp.linalg.norm(flat(p_trained) - flat(params), axis=1).mean()
        d_agg = jnp.linalg.norm(flat(p_mixed) - flat(p_trained), axis=1).mean()
        v1 = flat(p_trained) - flat(params)
        v2 = flat(p_mixed) - flat(p_trained)
        cos = (jnp.sum(v1 * v2, axis=1) / (jnp.linalg.norm(v1, axis=1) * jnp.linalg.norm(v2, axis=1) + 1e-12)).mean()
        return p_mixed, opt_state, d_train, d_agg, cos

    params, opt_state = state.params, state.opt_state
    s0 = sigma_metrics(params)
    rounds = 40 if quick else 100
    d_tr_first = d_ag_first = cos_first = None
    t0 = time.time()
    for r in range(rounds):
        b = next(it)
        params, opt_state, d_tr, d_ag, cos = one_round(params, opt_state, b.x, b.y)
        opt_state = jax.vmap(opt.init)(params)
        if r == 0:
            d_tr_first, d_ag_first, cos_first = float(d_tr), float(d_ag), float(cos)
    spr = (time.time() - t0) / rounds
    s1 = sigma_metrics(params)
    emit(
        "fig3.agg_vs_train_magnitude",
        spr * 1e6,
        f"round0_agg_over_train={d_ag_first / max(d_tr_first, 1e-12):.1f};cos_sim_round0={cos_first:.3f}",
    )
    emit(
        "fig3.ann_sigmas",
        spr * 1e6,
        f"sigma_ap_ratio={float(s1['sigma_ap']) / float(s0['sigma_ap']):.4f};"
        f"v_steady_norm={v_steady_norm(graph):.4f};"
        f"sigma_an_final={float(s1['sigma_an']):.2e}",
    )


if __name__ == "__main__":
    run()

"""Figure 1: plateau of the uncorrected init scales with system size n^μ;
the proposed ‖v_steady‖⁻¹ gain removes it.

Paper claim: dashed (He) curves plateau for a number of rounds growing as
n^μ, 0.4 ≤ μ ≤ 1; solid (proposed) curves descend immediately.  We measure
rounds-to-(loss < threshold) for both inits at several n on the complete
graph (cfg A) and fit μ.
"""
from __future__ import annotations

import numpy as np

from repro.core import topology as T
from repro.core.initialisation import gain_from_graph

from .common import emit, rounds_to_loss, run_dfl_mlp_sweep


def run(quick: bool = True) -> None:
    ns = [8, 16, 32] if quick else [8, 16, 32, 64]
    rounds = 400 if quick else 1000  # the He plateau at n=32 runs past 300 rounds
    threshold = 2.25  # just below the log(10) = 2.303 plateau
    plateau_rounds = []
    for n in ns:
        # both inits as one vmapped program over the executor's sweep axis
        grid, spr = run_dfl_mlp_sweep(
            n_nodes=n, gains=[1.0, gain_from_graph(T.complete(n))],
            rounds=rounds, eval_every=4,
        )
        hist_plain, hist_corr = grid[0][0], grid[1][0]
        r_plain = rounds_to_loss(hist_plain, threshold)
        r_corr = rounds_to_loss(hist_corr, threshold)
        plateau_rounds.append(r_plain)
        emit(
            f"fig1.n{n}",
            spr / rounds * 1e6,  # µs per round per trajectory, like fig2-fig7
            f"plateau_he={r_plain};plateau_proposed={r_corr};"
            f"final_he={hist_plain['test_loss'][-1]:.3f};final_proposed={hist_corr['test_loss'][-1]:.3f}",
        )
    finite = [(n, r) for n, r in zip(ns, plateau_rounds) if np.isfinite(r) and r > 0]
    if len(finite) >= 2:
        xs = np.log([n for n, _ in finite])
        ys = np.log([r for _, r in finite])
        mu = float(np.polyfit(xs, ys, 1)[0])
    else:
        mu = float("nan")
    emit("fig1.scaling_exponent", 0.0, f"mu={mu:.2f};paper_range=0.4..1.0")


if __name__ == "__main__":
    run()

"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches run
on the single real CPU device; only launch/dryrun.py (a separate process)
forces 512 host devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

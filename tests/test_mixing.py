import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import mixing as M
from repro.core import topology as T


def _random_graph(seed: int, n: int):
    kind = seed % 3
    if kind == 0:
        return T.erdos_renyi_gnp(n, 4.0 / n + 0.05, seed=seed)
    if kind == 1:
        return T.random_k_regular(n, 4, seed=seed)
    return T.barabasi_albert(n, 3, seed=seed)


# ---------------------------------------------------------------- properties
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50), n=st.sampled_from([16, 24, 32, 64]))
def test_mixing_matrix_is_column_stochastic(seed, n):
    g = _random_graph(seed, n)
    ap = M.mixing_matrix(g)
    assert np.allclose(ap.sum(axis=0), 1.0, atol=1e-12)
    assert np.all(ap >= 0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50), n=st.sampled_from([16, 32, 64]))
def test_receive_matrix_row_stochastic_and_consensus_fixed_point(seed, n):
    g = _random_graph(seed, n)
    m = M.receive_matrix(g)
    assert np.allclose(m.sum(axis=1), 1.0, atol=1e-12)
    # consensus (equal params) is a fixed point of DecAvg
    w = np.ones((n, 5)) * 3.7
    assert np.allclose(m @ w, w)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 50), n=st.sampled_from([16, 32, 64]))
def test_v_steady_is_stationary_and_normalised(seed, n):
    g = _random_graph(seed, n)
    v = M.v_steady(g)
    ap = M.mixing_matrix(g)
    assert np.allclose(v.sum(), 1.0)
    assert np.allclose(ap @ v, v, atol=1e-10)
    # Cauchy–Schwarz floor (paper §4.3): ‖v‖² >= 1/n
    assert M.v_steady_norm(g) >= 1.0 / np.sqrt(n) - 1e-12


def test_v_steady_closed_form_vs_power_iteration():
    g = T.barabasi_albert(128, 4, seed=2)
    v_closed = M.v_steady(g)
    ap = M.mixing_matrix(g)
    # brute force: iterate the chain
    v = np.full(g.n, 1.0 / g.n)
    for _ in range(20000):
        v = ap @ v
        v /= v.sum()
    assert np.abs(v - v_closed).max() < 1e-10


def test_v_steady_norm_regular_graph_is_inverse_sqrt_n():
    for n in (16, 64, 256):
        g = T.random_k_regular(n, 8, seed=0)
        assert np.isclose(M.v_steady_norm(g), 1.0 / np.sqrt(n), rtol=1e-12)


def test_v_steady_scaling_exponents_match_paper_fig5():
    """Homogeneous families: α = 1/2; heavy-tail: α < 1/2 (paper Fig. 5a,b)."""
    ns = [128, 512, 2048]

    def alpha(build):
        vs = [M.v_steady_norm(build(n)) for n in ns]
        return -np.polyfit(np.log(ns), np.log(vs), 1)[0]

    a_kreg = alpha(lambda n: T.random_k_regular(n, 8, seed=0))
    a_er = alpha(lambda n: T.erdos_renyi_gnm(n, 4 * n, seed=0))
    a_ba = alpha(lambda n: T.barabasi_albert(n, 4, seed=0))
    assert abs(a_kreg - 0.5) < 0.01
    assert abs(a_er - 0.5) < 0.02
    assert a_ba < 0.48  # heterogeneous centralities compress less


def test_v_steady_norm_invariant_under_assortativity_rewiring():
    """Paper Fig. 5(c): degree-preserving rewiring leaves ‖v_steady‖ fixed."""
    g = T.erdos_renyi_gnp(128, 0.08, seed=5)
    before = M.v_steady_norm(g)
    for target in (-0.3, 0.3):
        g2 = M.rewire_to_assortativity(g, target, steps=20000, seed=1)
        assert abs(g2.degree_assortativity() - target) < 0.1
        assert np.isclose(M.v_steady_norm(g2), before, rtol=1e-12)
        assert np.array_equal(np.sort(g2.degrees), np.sort(g.degrees))


def test_degree_sample_estimator_close_to_truth():
    g = T.configuration_heavy_tail(512, 2.2, seed=7)
    est = M.v_steady_norm_from_degree_sample(g.degrees, g.n)
    assert np.isclose(est, M.v_steady_norm(g), rtol=1e-6)


def test_spectral_gap_orders_mixing_speed():
    """Expanders (k-regular) mix faster than rings (paper §4.5)."""
    n = 64
    gap_kreg = M.spectral_gap(T.random_k_regular(n, 8, seed=0))
    gap_ring = M.spectral_gap(T.ring(n))
    assert gap_kreg > 10 * gap_ring
    t_kreg = M.mixing_time_estimate(T.random_k_regular(n, 8, seed=0))
    t_ring = M.mixing_time_estimate(T.ring(n))
    assert t_kreg < t_ring


def test_directed_graph_power_iteration_path():
    # strongly-connected directed cycle with an extra chord
    n = 12
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, (i + 1) % n] = 1.0
    a[0, 6] = 1.0
    g = T.from_adjacency(a, directed=True)
    v = M.v_steady(g)
    assert np.isclose(v.sum(), 1.0)
    ap = M.mixing_matrix(g)
    assert np.allclose(ap @ v, v, atol=1e-9)

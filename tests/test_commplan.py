"""Backend parity for the CommPlan subsystem (DESIGN.md §3).

The contract: dense, sparse and ppermute are *interchangeable executions of
the same operator* — for any topology family, any data-size weighting and
any failure draw, mixing a node-stacked pytree must give identical results
(within fp32 accumulation tolerance) on every backend.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import topology as T
from repro.core.commplan import BACKENDS, FailureModel, compile_plan
from repro.core.mixing import receive_matrix

FAMILIES = {
    "complete": lambda n, seed: T.complete(n),
    "ring": lambda n, seed: T.ring(n),
    "circulant": lambda n, seed: T.circulant(n, (1, 2)),
    "kreg": lambda n, seed: T.random_k_regular(n, 4, seed=seed),
    "er_gnp": lambda n, seed: T.erdos_renyi_gnp(n, 4.5 / n + 0.05, seed=seed),
    "er_gnm": lambda n, seed: T.erdos_renyi_gnm(n, 3 * n, seed=seed),
    "ba": lambda n, seed: T.barabasi_albert(n, 3, seed=seed),
    "heavy_tail": lambda n, seed: T.configuration_heavy_tail(n, 2.2, seed=seed),
    "torus": lambda n, seed: T.torus_lattice((4, n // 4)),
    "star": lambda n, seed: T.star(n),
}


def _params(n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {
        "w": jax.random.normal(ks[0], (n, 6, 3)),
        "b": {"v": jax.random.normal(ks[1], (n, 5))},
        "h": jax.random.normal(ks[2], (n, 17)).astype(jnp.bfloat16),
    }


def _max_err(a, b):
    return max(
        float(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32)).max())
        for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b))
    )


# --------------------------------------------------------------- pure parity
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_backend_parity_all_families(family):
    g = FAMILIES[family](16, 0)
    params = _params(g.n)
    outs = {b: compile_plan(g, b).mix(params) for b in BACKENDS}
    assert _max_err(outs["dense"], outs["sparse"]) < 1e-2  # bf16 leaf dominates
    assert _max_err(outs["dense"], outs["ppermute"]) < 1e-2
    # fp32 leaves agree to fp32 accumulation tolerance
    for b in ("sparse", "ppermute"):
        assert float(jnp.abs(outs["dense"]["w"] - outs[b]["w"]).max()) < 1e-5


@settings(max_examples=12, deadline=None)
@given(
    family=st.sampled_from(sorted(FAMILIES)),
    n=st.sampled_from([8, 16, 24]),
    seed=st.integers(0, 10),
    weighted=st.booleans(),
)
def test_backend_parity_property(family, n, seed, weighted):
    g = FAMILIES[family](n, seed)
    params = _params(g.n, seed)
    sizes = np.linspace(1.0, 3.0, g.n) if weighted else None
    outs = {b: compile_plan(g, b, data_sizes=sizes).mix(params) for b in BACKENDS}
    for b in ("sparse", "ppermute"):
        assert float(jnp.abs(outs["dense"]["w"] - outs[b]["w"]).max()) < 1e-5, (family, b)


@settings(max_examples=12, deadline=None)
@given(
    family=st.sampled_from(sorted(FAMILIES)),
    seed=st.integers(0, 10),
    link_p=st.sampled_from([0.3, 0.7, 1.0]),
    node_p=st.sampled_from([0.6, 1.0]),
)
def test_backend_parity_under_failures(family, seed, link_p, node_p):
    """One Bernoulli per edge/node, keyed identically → identical effective
    operator on every backend, including the renormalisation."""
    if link_p == 1.0 and node_p == 1.0:
        link_p = 0.5  # ensure the failure path is exercised
    g = FAMILIES[family](16, seed)
    params = _params(g.n, seed)
    fm = FailureModel(link_p=link_p, node_p=node_p)
    key = jax.random.PRNGKey(seed * 31 + 7)
    outs = {b: compile_plan(g, b, failures=fm).mix(params, key) for b in BACKENDS}
    for b in ("sparse", "ppermute"):
        assert float(jnp.abs(outs["dense"]["w"] - outs[b]["w"]).max()) < 1e-5, (family, b)


def test_failed_isolation_keeps_own_params():
    """node_p → 0: every backend must collapse the receive row to identity."""
    g = T.random_k_regular(12, 4, seed=0)
    params = _params(g.n)
    key = jax.random.PRNGKey(0)
    for b in BACKENDS:
        plan = compile_plan(g, b, failures=FailureModel(node_p=1e-9))
        out = plan.mix(params, key)
        assert float(jnp.abs(out["w"] - params["w"]).max()) < 1e-6, b


@settings(max_examples=8, deadline=None)
@given(family=st.sampled_from(sorted(FAMILIES)), seed=st.integers(0, 5))
def test_sparse_segment_and_hyb_renderings_agree(family, seed):
    """The sparse backend's two executions — segment_sum gather-scatter and
    the HYB ELL+hub layout — are renderings of the same edge weights."""
    from repro.core.decavg import mix_pytree_hyb, mix_pytree_sparse

    g = FAMILIES[family](16, seed)
    plan = compile_plan(g, "sparse")
    params = _params(g.n, seed)
    seg = mix_pytree_sparse(
        params, plan.src, plan.dst, plan.edge_w, plan.self_w, n_nodes=plan.n
    )
    hyb = mix_pytree_hyb(
        params, plan.slot_idx, plan.slot_w, plan.hyb_self_w, plan.hub_rows, plan.hub_m
    )
    assert float(jnp.abs(seg["w"] - hyb["w"]).max()) < 1e-5


# ------------------------------------------------------------ graph exports
@settings(max_examples=10, deadline=None)
@given(family=st.sampled_from(sorted(FAMILIES)), seed=st.integers(0, 20))
def test_edge_coloring_is_proper_and_complete(family, seed):
    g = FAMILIES[family](16, seed)
    col = g.edge_coloring()
    n = g.n
    idx = np.arange(n)
    seen = set()
    for c in range(col.n_colors):
        p = col.partners[c]
        # involution: a colour class is a matching
        assert np.array_equal(p[p], idx)
        for i in range(n):
            if p[i] != i:
                assert g.adjacency[i, p[i]] != 0
                seen.add((min(i, int(p[i])), max(i, int(p[i]))))
    # every edge appears in exactly one colour class
    edges = {(int(u), int(v)) for u, v in g.edge_list()}
    assert seen == edges
    # greedy bound
    assert col.n_colors <= max(2 * int(g.degrees.max()) - 1, 1)


def test_directed_graph_dense_sparse_parity():
    """A[i, j] = 'i receives from j' must mean the same thing on both
    backends (regression: the directed CSR export once inverted it)."""
    rng = np.random.default_rng(3)
    a = (rng.random((10, 10)) < 0.3).astype(np.float32)
    np.fill_diagonal(a, 0.0)
    g = T.from_adjacency(a, directed=True)
    params = _params(g.n)
    dense = compile_plan(g, "dense").mix(params)
    sparse = compile_plan(g, "sparse").mix(params)
    assert float(jnp.abs(dense["w"] - sparse["w"]).max()) < 1e-5


def test_csr_matches_adjacency():
    g = T.barabasi_albert(20, 3, seed=4)
    indptr, indices, uid = g.csr()
    a = np.zeros_like(g.adjacency)
    for i in range(g.n):
        a[i, indices[indptr[i] : indptr[i + 1]]] = 1.0
    assert np.array_equal(a, (g.adjacency > 0).astype(a.dtype))
    # both directions of an undirected edge share one uid
    edges = g.edge_list()
    for i in range(g.n):
        for e in range(indptr[i], indptr[i + 1]):
            u, v = edges[uid[e]]
            assert {i, int(indices[e])} == {int(u), int(v)}


# ------------------------------------------------------- block-sparse kernel
def test_bsr_kernel_matches_dense_receive_matrix():
    from repro.kernels.mix.ops import decavg_mix

    g = T.configuration_heavy_tail(40, 2.2, seed=1)
    m = jnp.asarray(receive_matrix(g), jnp.float32)
    params = _params(g.n)
    want = compile_plan(g, "dense").mix(params)
    got = decavg_mix(m, params, backend="sparse", block_n=8, interpret=True)
    assert float(jnp.abs(want["w"] - got["w"]).max()) < 1e-5


# ----------------------------------------------- collective ppermute parity
@pytest.mark.skipif(jax.device_count() < 8, reason="needs 8 devices (CI sets XLA_FLAGS)")
def test_ppermute_collective_matches_dense_in_process():
    """True shard_map/ppermute rendering of the colour schedule (runs in CI
    where XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
    from jax.sharding import PartitionSpec as P

    try:
        shard_map = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    from repro.core.decavg import mix_pytree_colored

    n = 8
    mesh = jax.make_mesh((8,), ("data",))
    for family in ("kreg", "er_gnp", "ring", "star"):
        g = FAMILIES[family](n, 3)
        plan = compile_plan(g, "ppermute")
        params = {
            "w": jax.random.normal(jax.random.PRNGKey(0), (n, 16, 4)),
            "b": jax.random.normal(jax.random.PRNGKey(1), (n, 5)),
        }
        dense = compile_plan(g, "dense").mix(params)
        specs = {"w": P("data", None, None), "b": P("data", None)}
        f = shard_map(
            lambda p, cw, sw: mix_pytree_colored(p, plan.partners, cw, sw, axis_name="data"),
            mesh=mesh,
            in_specs=(specs, P(None, "data"), P("data")),
            out_specs=specs,
        )
        with mesh:
            out = jax.jit(f)(params, plan.color_w, plan.self_w)
        assert _max_err(dense, out) < 1e-5, family


# ----------------------------------------------------- trainer integration
def test_make_round_fn_accepts_plan_and_backends_agree():
    """One full communication round through make_round_fn must be
    backend-independent: same state, same batches → same mixed params."""
    from repro.fed import init_fl_state, make_round_fn
    from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
    from repro.core.initialisation import InitConfig
    from repro.optim import sgd

    g = T.barabasi_albert(8, 3, seed=0)
    opt = sgd(1e-2, 0.0)
    icfg = InitConfig("he_normal", 1.0)
    init_one = lambda k: init_mlp(icfg, k, in_dim=16, hidden=(8,), n_classes=3)
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 2, 4, 16))
    y = jax.random.randint(jax.random.PRNGKey(2), (8, 2, 4), 0, 3)

    results = []
    for backend in BACKENDS:
        state = init_fl_state(jax.random.PRNGKey(0), 8, init_one, opt)
        rf = jax.jit(make_round_fn(loss_fn, opt, compile_plan(g, backend)))
        state, metrics = rf(state, (x, y))
        results.append((backend, state.params, float(metrics["train_loss"])))
    for backend, params, loss in results[1:]:
        assert np.isclose(loss, results[0][2], rtol=1e-5)
        assert _max_err(results[0][1], params) < 1e-5, backend


def test_make_round_fn_data_sizes_override_keeps_plan_failures():
    """Overriding only data_sizes must not drop the plan's failure model
    (regression: the recompile once replaced it with the inactive default)."""
    from repro.fed import init_fl_state, make_round_fn
    from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
    from repro.core.initialisation import InitConfig
    from repro.optim import sgd

    g = T.random_k_regular(8, 4, seed=0)
    opt = sgd(1e-2, 0.0)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 1.0), k, in_dim=16, hidden=(8,), n_classes=3)
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, 4, 16))
    y = jax.random.randint(jax.random.PRNGKey(2), (8, 1, 4), 0, 3)
    sizes = np.linspace(1.0, 2.0, 8)

    # node_p -> 0 isolates every node; if the failure model survives the
    # data_sizes override, aggregation is the identity
    plan = compile_plan(g, "sparse", failures=FailureModel(node_p=1e-9))
    state0 = init_fl_state(jax.random.PRNGKey(0), 8, init_one, opt)
    rf = jax.jit(make_round_fn(loss_fn, opt, plan, data_sizes=sizes))
    state1, _ = rf(state0, (x, y))
    rf_local = jax.jit(make_round_fn(loss_fn, opt, g, aggregate=False))
    state2, _ = rf_local(state0, (x, y))
    assert _max_err(state1.params, state2.params) < 1e-6

"""Telemetry layer (repro.obs, DESIGN.md §17): channel specs, wire-cost
accounting against hand-counted edges/bytes, run-log schema, and the
no-perturbation contract — recording extra channels must not change the
trajectory or the legacy channels.

Executor↔train_loop bit-parity itself is pinned in tests/test_executor.py
(the executors now route through the Recorder, so those tests ARE the
Recorder parity suite); here we cover what telemetry *adds*.
"""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import topology as T
from repro.core.commplan import FailureModel, compile_plan, compile_schedule, cyclic_map
from repro.core.initialisation import InitConfig
from repro.core.shardplan import ShardedCommPlan, _build_hyb_tables, _build_layout
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, run_trajectory
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.obs import (
    BinChannel,
    BinSpec,
    Channel,
    MetricsSpec,
    Recorder,
    consensus_distance,
    history_rows,
    make_wire_fn,
    param_row_bytes,
    read_run_log,
    run_manifest,
    sharded_wire_per_round,
    staleness_histogram,
    static_wire_messages,
    validate_run_log,
    write_run_log,
)
from repro.optim import sgd

N, PER_NODE, BS, B_LOCAL, ROUNDS = 6, 48, 8, 2, 8


@pytest.fixture(scope="module")
def setup():
    ds = mnist_like(N * PER_NODE + 64, seed=0)
    parts = [np.arange(i * PER_NODE, (i + 1) * PER_NODE) for i in range(N)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-64:], ds.y[-64:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 2.0), k, hidden=(32,))
    return xs, ys, test, loss_fn, opt, init_one


def _sched(rounds=ROUNDS):
    return batch_index_schedule(PER_NODE, N, BS, rounds * B_LOCAL, seed=0)


# --------------------------------------------------------------- MetricsSpec


def test_legacy_spec_orders_channels_like_the_old_outs():
    spec = MetricsSpec.legacy(True, True, wire=True)
    assert spec.names == ("train_loss", "test_loss", "sigma_ap", "sigma_an", "wire_messages")
    assert [c.name for c in spec.gated] == ["test_loss", "sigma_ap", "sigma_an"]
    assert MetricsSpec.legacy(False, False).names == ("train_loss",)


def test_spec_rejects_duplicate_names():
    with pytest.raises(ValueError):
        MetricsSpec((Channel("a"), Channel("a")))


def test_recorder_step_gates_and_orders():
    rec = Recorder(MetricsSpec((Channel("x"), Channel("y", gated=True))))

    def one(gate):
        return rec.step(
            {"x": jnp.float32(2.0)},
            gate=jnp.asarray(gate),
            gated_fn=lambda op: {"y": op * 3.0},
            operand=jnp.float32(1.0),
        )

    on = [float(v) for v in jax.jit(one)(True)]
    off = [float(v) for v in jax.jit(one)(False)]
    assert on == [2.0, 3.0]
    assert off[0] == 2.0 and np.isnan(off[1])


def test_recorder_assemble_types_and_constants():
    rec = Recorder(MetricsSpec((Channel("loss"), Channel("count", ints=True))))
    mask = np.array([True, False, True])
    hist = rec.assemble(mask, [np.array([0.5, 1.0, 1.5]), np.array([2.0, 4.0, 6.0])],
                        constants={"wire_bytes": 128})
    assert hist["round"] == [0, 2]
    assert hist["loss"] == [0.5, 1.5] and hist["count"] == [2, 6]
    assert isinstance(hist["count"][0], int)
    assert hist["wire_bytes"] == [128, 128]
    assert hist["sigma_ap"] == []  # train_loop base keys always present


def test_binspec_shapes_and_fills():
    spec = BinSpec(5, (BinChannel("a"), BinChannel("nanbuf", fill=float("nan")),
                       BinChannel("wide", width=16)))
    acc = spec.init()
    assert acc["a"].shape == (5,) and float(acc["a"].sum()) == 0.0
    assert acc["wide"].shape == (16,)
    assert np.isnan(np.asarray(acc["nanbuf"])).all()


# ----------------------------------------------------------------- wire cost


def test_param_row_bytes_hand_counted():
    params = {"w": jnp.zeros((4, 3, 2), jnp.float32), "b": jnp.zeros((4, 5), jnp.float32)}
    assert param_row_bytes(params) == (3 * 2 + 5) * 4


def test_static_wire_ring_hand_counted():
    # ring(8): 8 undirected edges → 16 messages every clean round
    plan = compile_plan(T.ring(8), backend="sparse")
    msgs = static_wire_messages(plan, 5)
    np.testing.assert_array_equal(msgs, [16] * 5)


def test_static_wire_schedule_follows_round_map():
    # cyclic period-2 over ring(8) (8 edges) and complete(8) (28 edges)
    sch = compile_schedule([T.ring(8), T.complete(8)], "dense", round_map=cyclic_map(2))
    msgs = static_wire_messages(sch, 6)
    np.testing.assert_array_equal(msgs, [16, 16, 56, 56, 16, 16])


def test_static_wire_none_for_directed():
    g = T.ring(6)
    directed = T.Graph(adjacency=np.triu(g.adjacency), name="dir", directed=True)
    plan = compile_plan(directed, backend="dense")
    assert static_wire_messages(plan, 3) is None
    assert make_wire_fn(plan) is None


def test_wire_fn_clean_masks_hand_counted():
    # ring(8) with node 0 inactive: edges (0,1) and (7,0) die → 6 live edges
    plan = compile_plan(T.ring(8), backend="sparse")
    wire = make_wire_fn(plan)
    active = jnp.ones(8, bool).at[0].set(False)
    assert float(wire(None, 0, active=active)) == 12.0
    assert float(wire(None, 0)) == 16.0


def test_wire_fn_failure_draws_match_mask_replay():
    plan = compile_plan(
        T.random_k_regular(8, 3, seed=0), backend="sparse",
        failures=FailureModel(link_p=0.6, node_p=0.8),
    )
    wire = make_wire_fn(plan)
    for s in range(4):
        key = jax.random.PRNGKey(s)
        edge_keep, node_act = plan._round_masks_ext(key, None, None)
        ek, na = np.asarray(edge_keep), np.asarray(node_act)
        uv = np.asarray(plan.event_uv)
        expect = 2.0 * sum(ek[i] and na[u] and na[v] for i, (u, v) in enumerate(uv))
        assert float(wire(key, 0)) == expect


def _host_sharded(plan, shards):
    """Host-side ShardedCommPlan (layout tables only, no device mesh) — the
    tier-1 rendering of shard_plan's sparse path (test_sharded_plan pattern)."""
    n = plan.n
    src, dst = np.asarray(plan.src), np.asarray(plan.dst)
    uid, edge_w = np.asarray(plan.edge_uid), np.asarray(plan.edge_w)
    raw_e, self_w = np.asarray(plan.raw_edge_w), np.asarray(plan.self_w)
    raw_s = np.asarray(plan.raw_self_w)
    ident = np.arange(len(src), dtype=np.int32)
    recv = _build_layout(n, shards, dst, src, uid, edge_w, raw_e, ident, self_w, raw_s)
    order = np.lexsort((dst, src))
    send = _build_layout(
        n, shards, src[order], dst[order], uid[order], edge_w[order], raw_e[order],
        ident[order], self_w, raw_s,
    )
    return ShardedCommPlan(
        base=plan, mesh=None, axis="node", n_shards=shards, nps=n // shards,
        recv=recv, send=send, hyb=_build_hyb_tables(plan, recv, shards),
    )


def test_sharded_wire_two_shard_ring_hand_counted():
    # ring(8) over 2 contiguous shards, masked (failure-active) rendering:
    # cross edges (3,4) and (7,0) → each shard pulls 2 halo rows at
    # all_to_all width h_max=2 → 2 shards × 2 rows = 4 rows per round
    plan = compile_plan(T.ring(8), backend="sparse", failures=FailureModel(link_p=0.9))
    sp = _host_sharded(plan, 2)
    params = {"w": jnp.zeros((8, 10), jnp.float32)}
    w = sharded_wire_per_round(sp, params)
    assert w["wire_rows"] == 4
    assert w["wire_bytes"] == 4 * 10 * 4
    assert w["wire_collectives"] == 1  # one all_to_all, one param leaf


def test_sharded_wire_counts_hub_gather_of_clean_hyb_mix():
    # the clean mix of this plan renders all 8 rows through the HYB hub
    # contraction, which all-gathers the payload: + 2 shards × 4 remote rows
    sp = _host_sharded(compile_plan(T.ring(8), backend="sparse"), 2)
    params = {"w": jnp.zeros((8, 10), jnp.float32), "b": jnp.zeros((8,), jnp.float32)}
    w = sharded_wire_per_round(sp, params)
    assert w["wire_rows"] == 4 + 2 * 4
    assert w["wire_bytes"] == 12 * (10 + 1) * 4
    assert w["wire_collectives"] == 2 * 2  # (halo + hub gather) × two leaves


# ----------------------------------------------- executor wire integration


def test_trajectory_reports_static_wire(setup):
    xs, ys, test, loss_fn, opt, init_one = setup
    plan = compile_plan(T.ring(N), backend="dense")
    rf = make_round_fn(loss_fn, opt, plan)
    state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    state, hist = run_trajectory(
        state, rf, xs, ys, _sched(), n_rounds=ROUNDS, eval_every=3,
        eval_fn=make_eval_fn(loss_fn), eval_batch=test,
    )
    assert hist["wire_messages"] == [2 * N] * len(hist["round"])
    row_bytes = param_row_bytes(state.params)
    assert hist["wire_bytes"] == [2 * N * row_bytes] * len(hist["round"])


def test_trajectory_traced_wire_replays_key_stream(setup):
    """Under failures the in-scan count must replay exactly the k_mix
    stream the rounds consume — verified by re-deriving it from the
    initial state's rng on the host."""
    xs, ys, test, loss_fn, opt, init_one = setup
    plan = compile_plan(T.ring(N), backend="dense")
    rf = make_round_fn(loss_fn, opt, plan, link_p=0.5)
    state0 = init_fl_state(jax.random.PRNGKey(1), N, init_one, opt)
    _, hist = run_trajectory(
        state0, rf, xs, ys, _sched(), n_rounds=ROUNDS, eval_every=1,
    )
    eff = rf.plan  # make_round_fn recompiled the plan with the failure model
    rng = state0.rng
    uv = np.asarray(eff.event_uv)
    for r in range(ROUNDS):
        rng, k_mix = jax.random.split(rng)
        ek, na = (np.asarray(a) for a in eff._round_masks_ext(k_mix, None, None))
        expect = 2 * sum(bool(ek[i] and na[u] and na[v]) for i, (u, v) in enumerate(uv))
        assert hist["wire_messages"][r] == expect
    assert any(m < 2 * N for m in hist["wire_messages"])  # failures actually bit


def test_telemetry_does_not_perturb_trajectory(setup):
    """The wire channel rides the same scan: params, PRNG and the legacy
    channels must be bit-identical with and without it."""
    xs, ys, test, loss_fn, opt, init_one = setup
    plan = compile_plan(T.ring(N), backend="dense")
    rf = make_round_fn(loss_fn, opt, plan, link_p=0.5)
    bare = lambda state, batch: rf(state, batch)  # no .plan attr → no wire
    common = dict(n_rounds=ROUNDS, eval_every=3, eval_fn=make_eval_fn(loss_fn),
                  eval_batch=test, track_sigmas=True)
    s_wire = init_fl_state(jax.random.PRNGKey(2), N, init_one, opt)
    s_wire, h_wire = run_trajectory(s_wire, rf, xs, ys, _sched(), **common)
    s_bare = init_fl_state(jax.random.PRNGKey(2), N, init_one, opt)
    s_bare, h_bare = run_trajectory(s_bare, bare, xs, ys, _sched(), **common)
    assert "wire_messages" in h_wire and "wire_messages" not in h_bare
    for a, b in zip(jax.tree_util.tree_leaves(s_wire), jax.tree_util.tree_leaves(s_bare)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for k in ("round", "train_loss", "test_loss", "sigma_ap", "sigma_an"):
        assert h_wire[k] == h_bare[k]


def test_trajectory_on_chunk_streams_history(setup):
    xs, ys, test, loss_fn, opt, init_one = setup
    plan = compile_plan(T.ring(N), backend="dense")
    rf = make_round_fn(loss_fn, opt, plan)
    state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    seen = []
    state, hist = run_trajectory(
        state, rf, xs, ys, _sched(), n_rounds=ROUNDS, eval_every=2, chunk_size=3,
        on_chunk=lambda r0, r1, h: seen.append((r0, r1, h)),
    )
    assert [(r0, r1) for r0, r1, _ in seen] == [(0, 3), (3, 6), (6, 8)]
    streamed = [r for _, _, h in seen for r in h["round"]]
    assert streamed == hist["round"]
    streamed_loss = [v for _, _, h in seen for v in h["train_loss"]]
    assert streamed_loss == hist["train_loss"]
    assert all("wire_bytes" in h for _, _, h in seen)


# --------------------------------------------------------- health channels


def test_consensus_distance_hand_counted():
    params = {"w": jnp.asarray([[0.0], [2.0]], jnp.float32)}
    # mean over the two nodes of |w_i − 1| = 1
    assert float(consensus_distance(params)) == 1.0
    same = {"w": jnp.ones((4, 7), jnp.float32)}
    assert float(consensus_distance(same)) == 0.0


def test_staleness_histogram_edges():
    h = staleness_histogram(np.array([1.0, 0.0, 3.0, 0.0]), horizon=8.0)
    assert h["counts"] == [1.0, 0.0, 3.0, 0.0]
    assert h["edges"] == [0.0, 2.0, 4.0, 6.0, 8.0]


# ------------------------------------------------------------ run-log export


def test_run_log_round_trip(tmp_path):
    manifest = run_manifest({"fig": "smoke", "lr": 0.1}, seed=7, argv=["x", "--y"])
    hist = {"round": [0, 3], "train_loss": [1.0, float("nan")], "test_loss": [0.5, 0.4],
            "sigma_ap": [], "sigma_an": []}
    rows = history_rows(hist)
    path = tmp_path / "run.jsonl"
    n = write_run_log(path, [manifest, *rows, {"kind": "summary", "final": 0.4}])
    assert n == 4
    back = read_run_log(path)
    assert back[0]["kind"] == "manifest" and back[0]["seed"] == 7
    assert back[1] == {"kind": "round", "round": 0, "train_loss": 1.0, "test_loss": 0.5}
    assert back[2]["train_loss"] is None  # NaN sanitised to null (strict JSON)
    assert validate_run_log(path) == []
    # strict JSON end to end: stdlib parser with no NaN extension accepts it
    for line in path.read_text().splitlines():
        json.loads(line, parse_constant=lambda _: pytest.fail("non-strict JSON"))


def test_run_log_schema_gate_catches_breakage(tmp_path):
    manifest = run_manifest({}, seed=0)
    bad = dict(manifest)
    del bad["git_rev"]
    path = tmp_path / "bad.jsonl"
    write_run_log(path, [bad, {"kind": "round", "round": 0}])
    assert any("git_rev" in p for p in validate_run_log(path))
    write_run_log(path, [{"kind": "round", "round": 0}])
    assert any("manifest" in p for p in validate_run_log(path))
    write_run_log(path, [manifest])
    assert any("no data records" in p for p in validate_run_log(path))


def test_history_rows_uses_bin_axis_for_event_histories():
    hist = {"bin": [0, 1], "time": [2.0, 4.0], "messages": [4, 4], "round": []}
    rows = history_rows(hist, kind="bin")
    assert [r["kind"] for r in rows] == ["bin", "bin"]
    assert rows[1]["messages"] == 4 and rows[1]["time"] == 4.0


# ---------------------------------------------------------------------------
# dashboard + bench timing split
# ---------------------------------------------------------------------------


def test_dashboard_bench_report_matches_committed(tmp_path):
    # the CI gate's premise: the renderer is deterministic, so regenerating
    # from the committed artifacts reproduces the committed report exactly
    import pathlib
    import subprocess
    import sys as _sys

    root = pathlib.Path(__file__).resolve().parent.parent
    out_md = tmp_path / "BENCH_REPORT.md"
    out_html = tmp_path / "dash.html"
    subprocess.run(
        [_sys.executable, str(root / "tools" / "dashboard.py"), "--bench", str(root),
         "--out-md", str(out_md), "--out-html", str(out_html)],
        check=True, capture_output=True,
    )
    assert out_md.read_text() == (root / "BENCH_REPORT.md").read_text()
    html_text = out_html.read_text()
    assert "<table>" in html_text and "Headline timings" in html_text


def test_dashboard_run_mode_renders_telemetry(tmp_path):
    import pathlib
    import subprocess
    import sys as _sys

    root = pathlib.Path(__file__).resolve().parent.parent
    log = tmp_path / "run.jsonl"
    manifest = run_manifest({"model": "mlp", "rounds": 2}, seed=3)
    rows = history_rows({"round": [0, 1], "train_loss": [1.5, 1.25], "wire_bytes": [64, 64]})
    write_run_log(log, [manifest, *rows, {"kind": "summary", "final_train_loss": 1.25}])
    out = tmp_path / "run.md"
    subprocess.run(
        [_sys.executable, str(root / "tools" / "dashboard.py"), "--run", str(log),
         "--out-md", str(out)],
        check=True, capture_output=True,
    )
    text = out.read_text()
    assert "Manifest" in text and "History (2 round records)" in text
    assert "wire_bytes" in text and "summary" in text


def test_chunk_timer_splits_compile_from_steady():
    from benchmarks.common import ChunkTimer

    t = ChunkTimer()
    # first chunk carries ~8 s of compile on top of 4 rounds of steady work;
    # the trailing short chunk (recompiled) must not pollute the median
    t.walls = [10.0, 2.0, 2.2, 1.8, 5.0]
    t.sizes = [4, 4, 4, 4, 2]
    compile_s, steady = t.split()
    assert steady == pytest.approx(0.5)
    assert compile_s == pytest.approx(10.0 - 0.5 * 4)
    single = ChunkTimer()
    single.walls, single.sizes = [4.0], [8]
    assert single.split() == (0.0, pytest.approx(0.5))


def test_check_bench_prefers_steady_timing_keys():
    import importlib.util
    import pathlib

    root = pathlib.Path(__file__).resolve().parent.parent
    spec = importlib.util.spec_from_file_location("check_bench", root / "tools" / "check_bench.py")
    cb = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cb)
    conflated = {"us_per_event": 9.0, "sec_per_round_sync": 1.0, "final_loss": 2.0}
    assert sorted(cb._timing_keys(conflated)) == ["sec_per_round_sync", "us_per_event"]
    split = dict(conflated, us_per_event_steady=3.0, compile_seconds_event=5.0)
    assert cb._timing_keys(split) == ["us_per_event_steady"]

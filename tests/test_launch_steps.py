"""Integration tests for launch/steps.py: a REDUCED arch lowers, compiles
and RUNS on a small (2×2 data×model) mesh in a subprocess — exercising the
sharding rules, the DFL round step and the decode step end to end."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch.steps import SHAPES, ShapeSpec


def test_shape_registry():
    assert SHAPES["train_4k"] == ShapeSpec("train_4k", 4096, 256, "train")
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["decode_32k"].kind == "decode"


_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    import repro.launch.mesh as mesh_mod
    import repro.launch.steps as steps_mod

    # shrink the production mesh/node count to the test harness size
    mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh((2, 2), ("data", "model"))
    mesh_mod_n = mesh_mod.n_fl_nodes
    mesh_mod.n_fl_nodes = lambda multi_pod=False: 2
    steps_mod.n_fl_nodes = mesh_mod.n_fl_nodes
    sh = steps_mod.SHAPES
    sh["train_4k"] = dataclasses.replace(sh["train_4k"], seq_len=64, global_batch=4)
    sh["decode_32k"] = dataclasses.replace(sh["decode_32k"], seq_len=64, global_batch=4)

    from repro.configs import get_reduced_config
    cfg = dataclasses.replace(get_reduced_config("qwen2p5_3b"), d_model=128, n_heads=4, n_kv_heads=2, head_dim=32)
    mesh = mesh_mod.make_production_mesh()

    with mesh:
        # --- train round: lower, compile AND execute with real arrays ---
        step, args, in_sh, out_sh = steps_mod.build_train_step(cfg, mesh, mixing="dense")
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        def realize(sds_tree, sh_tree):
            leaves, treedef = jax.tree_util.tree_flatten(sds_tree)
            shs = jax.tree_util.tree_leaves(sh_tree, is_leaf=lambda x: hasattr(x, "spec"))
            out = []
            for i, l in enumerate(leaves):
                key = jax.random.PRNGKey(i)
                if jnp.issubdtype(l.dtype, jnp.integer):
                    v = jax.random.randint(key, l.shape, 0, 7).astype(l.dtype)
                else:
                    v = (0.02 * jax.random.normal(key, l.shape)).astype(l.dtype)
                out.append(v)
            return jax.tree_util.tree_unflatten(treedef, out)
        params, opt_state, batch = (realize(a, s) for a, s in zip(args, in_sh))
        p2, o2, loss = fn(params, opt_state, batch)
        assert np.isfinite(float(loss)), loss
        print("TRAIN_OK", float(loss))

        # --- sparse / ppermute CommPlan backends: run + parity vs dense ---
        for backend in ("sparse", "ppermute"):
            step_b, args_b, in_b, out_b = steps_mod.build_train_step(cfg, mesh, mixing=backend)
            fnb = jax.jit(step_b, in_shardings=in_b, out_shardings=out_b)
            p3, o3, loss_b = fnb(params, opt_state, batch)
            assert np.isfinite(float(loss_b)), (backend, loss_b)
            assert np.isclose(float(loss_b), float(loss), rtol=1e-4), (backend, loss_b, loss)
            err = max(
                float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
                for a, b in zip(jax.tree_util.tree_leaves(p2), jax.tree_util.tree_leaves(p3))
            )
            assert err < 5e-3, (backend, err)
            print(backend.upper() + "_OK", err)

        # --- decode step ---
        step_d, args_d, in_d, out_d = steps_mod.build_decode_step(cfg, mesh, shape_name="decode_32k")
        fnd = jax.jit(step_d, in_shardings=in_d, out_shardings=out_d)
        vals = [realize(a, s) for a, s in zip(args_d[:2], in_d[:2])]
        tokens = jnp.zeros(args_d[2].shape, jnp.int32)
        pos = jnp.asarray(5, jnp.int32)
        logits, cache = fnd(vals[0], vals[1], tokens, pos)
        assert logits.shape[0] == 4 and np.isfinite(np.asarray(logits, np.float32)).all()
        print("DECODE_OK", logits.shape)
    """
)


@pytest.mark.slow
def test_train_and_decode_steps_run_on_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env, timeout=540
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "TRAIN_OK" in out.stdout and "DECODE_OK" in out.stdout
    assert "SPARSE_OK" in out.stdout and "PPERMUTE_OK" in out.stdout

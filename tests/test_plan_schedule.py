"""PlanSchedule: time-varying topologies as a first-class axis (DESIGN.md §13).

Contracts under test:

* a size-1 ``PlanSchedule`` is **bit-identical** to the static ``CommPlan``
  executor — params, PRNG stream and train metrics — on every backend, with
  and without failures (the schedule machinery must cost nothing when the
  topology is static);
* a cyclic schedule run fused inside the executor's scan matches a legacy
  per-round loop that rebuilds each round's plan host-side;
* K > 1 folds the active plan id into the failure keying, so resampled
  plans draw independent failures (and the draws replay host-side);
* gossip estimation rides the schedule: push-sum over the dynamic graph
  matches the numpy reference integrated through the per-round active
  operators;
* leaderless exponential-random-minimum size sketches (``spread_min``
  transport) agree with the host reference and estimate n without a
  distinguished node;
* ``run_warmup_sweep`` vmaps (budget × seed) warmup grids with per-run
  parity against ``run_warmup_trajectory``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gossip as G
from repro.core import topology as T
from repro.core.commplan import (
    BACKENDS,
    FailureModel,
    compile_plan,
    compile_schedule,
    cyclic_map,
    sequence_map,
)
from repro.core.initialisation import InitConfig
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import (
    init_fl_state,
    make_eval_fn,
    make_round_fn,
    run_trajectory,
    run_warmup_sweep,
    run_warmup_trajectory,
)
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd
import repro.gossip as gsp

N, PER, BS, BL, ROUNDS = 6, 48, 8, 2, 8


def _graphs(k=3, seed=1):
    return T.churn_sequence(T.random_k_regular(N, 3, seed=0), k, 0.3, seed=seed)


@pytest.fixture(scope="module")
def setup():
    ds = mnist_like(N * PER + 64, seed=0)
    xs, ys = node_datasets(ds, [np.arange(i * PER, (i + 1) * PER) for i in range(N)])
    test = (ds.x[-64:], ds.y[-64:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 2.0), k, hidden=(32,))
    return xs, ys, test, loss_fn, opt, init_one


def _sched(rounds=ROUNDS, seed=0):
    return batch_index_schedule(PER, N, BS, rounds * BL, seed=seed)


def _run(setup, plan, link_p=1.0):
    xs, ys, test, loss_fn, opt, init_one = setup
    rf = make_round_fn(loss_fn, opt, plan, link_p=link_p)
    state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    return run_trajectory(
        state, rf, xs, ys, _sched(), n_rounds=ROUNDS, eval_every=3,
        eval_fn=make_eval_fn(loss_fn), eval_batch=test, track_sigmas=True,
    )


def _assert_bit_equal(s1, s2):
    for a, b in zip(jax.tree_util.tree_leaves(s1), jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------- size-1 schedule parity
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("link_p", [1.0, 0.6])
def test_size1_schedule_bit_identical(setup, backend, link_p):
    """Acceptance: K = 1 PlanSchedule ≡ static CommPlan, bit for bit —
    params, rng, train metrics — clean and under failures."""
    g = T.random_k_regular(N, 3, seed=0)
    s_pl, h_pl = _run(setup, compile_plan(g, backend), link_p=link_p)
    s_sc, h_sc = _run(setup, compile_schedule([g], backend), link_p=link_p)
    _assert_bit_equal(s_pl, s_sc)
    assert h_pl["train_loss"] == h_sc["train_loss"]
    assert h_pl["sigma_ap"] == h_sc["sigma_ap"]
    assert h_pl["test_loss"] == h_sc["test_loss"]


# ------------------------------------------- cyclic schedule vs legacy loop
def test_cyclic_schedule_matches_host_rebuilt_plans(setup):
    """Executor-fused schedule run ≡ a legacy per-round loop that recompiles
    the active round's plan host-side and dispatches one jitted round at a
    time (clean plans: the padded envelope must execute the exact unpadded
    operator)."""
    xs, ys, test, loss_fn, opt, init_one = setup
    graphs = _graphs()
    for backend in ("dense", "sparse"):
        sched_plan = compile_schedule(graphs, backend, round_map=cyclic_map(2))
        rf = make_round_fn(loss_fn, opt, sched_plan)
        state0 = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
        s_ex, h_ex = run_trajectory(
            state0, rf, xs, ys, _sched(), n_rounds=ROUNDS, eval_every=3
        )

        # legacy loop: per-round host rebuild of the active plan
        state = state0
        it_sched = _sched().reshape(ROUNDS, BL, N, BS).transpose(0, 2, 1, 3)
        node = np.arange(N)[:, None]
        losses = []
        for r in range(ROUNDS):
            idx_active = int(sched_plan.plan_index(r))
            plan_r = compile_plan(graphs[idx_active], backend)
            rf_r = jax.jit(make_round_fn(loss_fn, opt, plan_r))
            idx = it_sched[r].reshape(N, -1)
            bx = xs[node, idx].reshape(N, BL, BS, *xs.shape[2:])
            by = ys[node, idx].reshape(N, BL, BS)
            state, m = rf_r(state, (bx, by))
            losses.append(float(m["train_loss"]))
        for a, b in zip(
            jax.tree_util.tree_leaves(s_ex.params), jax.tree_util.tree_leaves(state.params)
        ):
            err = float(jnp.abs(jnp.asarray(a) - jnp.asarray(b)).max())
            assert err < 1e-6, (backend, err)
        np.testing.assert_allclose(
            h_ex["train_loss"], [losses[r] for r in h_ex["round"]], rtol=1e-6
        )


def test_hyb_envelope_mixed_hub_and_hub_free_plans():
    """The stacked HYB layout's fabricated-dense-row padding: a hub-free
    (regular) plan scheduled next to a hub-heavy (heavy-tail) plan must
    still execute the exact unpadded operator on clean sparse rounds —
    this is fig8's ba/kreg configuration."""
    graphs = [
        T.configuration_heavy_tail(64, 2.2, seed=0),  # hubs → dense rows
        T.random_k_regular(64, 6, seed=0),  # hub-free → fabricated padding
    ]
    sch = compile_schedule(graphs, "sparse", round_map=cyclic_map(1))
    assert int(sch.stacked["hub_rows"].shape[1]) > 0  # the branch is live
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 9, 2))}
    for r, g in enumerate(graphs):
        got = jax.jit(lambda p, r=r: sch.mix(p, r))(params)
        want = compile_plan(g, "sparse").mix(params)
        err = float(jnp.abs(got["w"] - want["w"]).max())
        assert err < 1e-6, (g.name, err)


def test_round_map_kinds():
    graphs = _graphs(3)
    cyc = compile_schedule(graphs, "dense", round_map=cyclic_map(2))
    assert [int(cyc.plan_index(r)) for r in range(8)] == [0, 0, 1, 1, 2, 2, 0, 0]
    seq = compile_schedule(graphs, "dense", round_map=sequence_map([2, 0, 1]))
    assert [int(seq.plan_index(r)) for r in range(5)] == [2, 0, 1, 2, 0]
    with pytest.raises(ValueError):
        compile_schedule(graphs, "dense", round_map=sequence_map([0, 3]))
    with pytest.raises(ValueError):
        compile_schedule([T.ring(4), T.ring(6)], "dense")


# --------------------------------------------------- failure keying contract
def test_schedule_folds_plan_id_into_failure_keys():
    """Satellite: K > 1 plans draw independent failures for the same base
    key (the plan id is folded in), and the draws replay host-side through
    ``round_key``/``round_masks``."""
    g = T.random_k_regular(16, 4, seed=0)
    fm = FailureModel(link_p=0.5)
    # the SAME graph twice: only the folded plan id can distinguish rounds
    sch = compile_schedule([g, g], "dense", failures=fm, round_map=cyclic_map(1))
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (16, 7))}
    key = jax.random.PRNGKey(3)
    out0 = sch.mix(params, 0, key)  # plan 0
    out1 = sch.mix(params, 1, key)  # plan 1, same key, same graph
    assert float(jnp.abs(out0["w"] - out1["w"]).max()) > 1e-6

    # host replay: masks drawn at the envelope width with the folded key
    for r in (0, 1):
        ek, na = sch.round_masks(sch.round_key(key, r))
        ref = G.effective_send_matrix(g, np.asarray(ek)[: g.n_edges], np.asarray(na)).T
        want = jnp.einsum("ij,jk->ik", jnp.asarray(ref, jnp.float32), params["w"])
        got = sch.mix(params, r, key)["w"]
        assert float(jnp.abs(got - want).max()) < 1e-5, r

    # size-1 schedule: key untouched → today's draws exactly
    sch1 = compile_schedule([g], "dense", failures=fm)
    plan = compile_plan(g, "dense", failures=fm)
    _assert_bit_equal(sch1.mix(params, 5, key), plan.mix(params, key))


# ----------------------------------------------------- gossip over schedules
@pytest.mark.parametrize("backend", BACKENDS)
def test_push_sum_over_schedule_matches_reference(backend):
    """Estimation rides the dynamic graph: engine push-sum over a cyclic
    schedule ≡ numpy push-sum integrated through the per-round active
    operators — clean and under (plan-id-folded) failure draws."""
    graphs = T.churn_sequence(T.random_k_regular(16, 4, seed=0), 3, 0.25, seed=2)
    vals = np.linspace(-2.0, 4.0, 16)
    rounds = 30
    sch = compile_schedule(graphs, backend, round_map=cyclic_map(2))
    out = np.asarray(gsp.push_sum(sch, vals, rounds))
    from repro.core.mixing import mixing_matrix

    mats = [mixing_matrix(graphs[int(sch.plan_index(r))]) for r in range(rounds)]
    ref = G.push_sum_failures(graphs[0], vals, mats)
    assert np.abs(out - ref).max() < 1e-3, backend

    fm = FailureModel(link_p=0.6, node_p=0.9)
    schf = compile_schedule(graphs, backend, failures=fm, round_map=cyclic_map(2))
    key = jax.random.PRNGKey(9)
    outf = np.asarray(gsp.push_sum(schf, vals, rounds, key))
    mats = []
    for r in range(rounds):
        kr = schf.round_key(jax.random.fold_in(key, r), r)
        ek, na = schf.round_masks(kr)
        g_act = graphs[int(schf.plan_index(r))]
        mats.append(
            G.effective_send_matrix(g_act, np.asarray(ek)[: g_act.n_edges], np.asarray(na))
        )
    reff = G.push_sum_failures(graphs[0], vals, mats)
    assert np.abs(outf - reff).max() < 1e-3, backend


def test_power_iteration_over_schedule_finite_and_consistent():
    """‖v̂‖ of the dynamic operator: the estimator must run fused over the
    schedule and for a rate-0 chain reduce to the static estimate."""
    base = T.random_k_regular(16, 4, seed=0)
    frozen = compile_schedule([base] * 3, "sparse", round_map=cyclic_map(1))
    est_sched = gsp.power_iteration_norm(frozen, 30, 50)
    est_static = gsp.power_iteration_norm(compile_plan(base, "sparse"), 30, 50)
    np.testing.assert_allclose(
        np.asarray(est_sched["vnorm"]), np.asarray(est_static["vnorm"]), rtol=1e-5
    )
    churned = compile_schedule(
        T.churn_sequence(base, 4, 0.2, seed=3), "sparse", round_map=cyclic_map(2)
    )
    est = gsp.power_iteration_norm(churned, 30, 50)
    v = np.asarray(est["vnorm"])
    assert np.isfinite(v).all() and (v > 0).all()
    # churn at fixed degree budget keeps ‖v‖ near the k-regular 1/√n regime
    assert abs(v.mean() - 1 / 4.0) < 0.15


# ------------------------------------------------- leaderless size sketches
@pytest.mark.parametrize("backend", BACKENDS)
def test_spread_min_parity_and_reference(backend):
    g = T.barabasi_albert(16, 3, seed=1)
    x = np.asarray(jax.random.exponential(jax.random.PRNGKey(0), (16, 5)))
    plan = compile_plan(g, backend)
    out = np.asarray(plan.spread_min(jnp.asarray(x)))
    np.testing.assert_allclose(out, G.min_spread_reference(g, x), rtol=1e-6)
    fm = FailureModel(link_p=0.5, node_p=0.8)
    planf = compile_plan(g, backend, failures=fm)
    key = jax.random.PRNGKey(4)
    outf = np.asarray(planf.spread_min(jnp.asarray(x), key))
    ek, na = planf.round_masks(key)
    reff = G.min_spread_reference(g, x, np.asarray(ek)[: g.n_edges], np.asarray(na))
    np.testing.assert_allclose(outf, reff, rtol=1e-6)


def test_leaderless_size_estimation():
    """No distinguished node: every node's sketch n̂ converges to consensus
    within the graph diameter and estimates n to the 1/√(m-2) noise floor;
    the engine matches the host reference draw for draw."""
    g = T.random_k_regular(32, 4, seed=0)
    plan = compile_plan(g, "sparse")
    key = jax.random.PRNGKey(0)
    n_hat = np.asarray(gsp.estimate_size_leaderless(plan, 20, key, n_sketches=512))
    assert np.allclose(n_hat, n_hat[0])  # consensus
    assert abs(n_hat[0] - 32) / 32 < 0.25
    # engine ≡ host reference given the same sketch draws
    k_draw, _ = jax.random.split(key)
    sk = np.asarray(jax.random.exponential(k_draw, (32, 512)))
    ref = G.estimate_size_sketch_reference(g, sk, 20)
    np.testing.assert_allclose(n_hat, ref, rtol=1e-4)
    # failures only delay flooding; estimates stay finite and in range
    planf = compile_plan(g, "sparse", failures=FailureModel(link_p=0.5))
    n_hat_f = np.asarray(
        gsp.estimate_size_leaderless(planf, 40, jax.random.PRNGKey(1), n_sketches=512)
    )
    assert np.isfinite(n_hat_f).all()
    assert abs(n_hat_f.mean() - 32) / 32 < 0.35


def test_leaderless_gain_estimator_no_special_node():
    """The leaderless estimator hands every node a finite, sane gain — and
    an isolated-by-budget node degrades to gain ≈ 1 (its own sketches)."""
    g = T.ring(64)
    plan = compile_plan(g, "dense")
    for mode in ("vnorm", "alpha"):
        est = gsp.make_gain_estimator(
            plan, pi_rounds=8, ps_rounds=8, mode=mode, leaderless=True
        )
        gains = np.asarray(jax.jit(est)(jax.random.PRNGKey(0)))
        assert np.isfinite(gains).all()
        assert gains.max() < 100.0, mode  # graceful: no 1/EPS blow-ups
    # good budget on a well-mixed graph → near the exact gain
    from repro.core.mixing import v_steady_norm

    g2 = T.random_k_regular(24, 4, seed=0)
    est = gsp.make_gain_estimator(
        compile_plan(g2, "sparse"), pi_rounds=60, ps_rounds=80,
        mode="vnorm", leaderless=True, n_sketches=512,
    )
    gains = np.asarray(jax.jit(est)(jax.random.PRNGKey(2)))
    exact = 1.0 / v_steady_norm(g2)
    assert np.abs(gains - exact).max() / exact < 0.2


# ----------------------------------------------------- swept fused warmups
def test_warmup_sweep_matches_independent_runs(setup):
    """Satellite: (budget × seed) warmup grids as one vmapped program, per
    run ≡ run_warmup_trajectory with the same key/budget."""
    xs, ys, test, loss_fn, opt, _ = setup
    g = T.random_k_regular(N, 3, seed=0)
    icfg = InitConfig("he_normal", 1.0)
    init_one_g = lambda k, gn: init_mlp(icfg.replace(gain=gn), k, hidden=(32,))
    rf = make_round_fn(loss_fn, opt, g)
    est = gsp.make_gain_estimator(compile_plan(g, "sparse"), pi_rounds=16, ps_rounds=16)
    common = dict(
        n_rounds=ROUNDS, eval_every=3, eval_fn=make_eval_fn(loss_fn),
        eval_batch=test, b_local=BL,
    )
    budgets, seeds = [4, 16], [0, 1]
    keys = [jax.random.PRNGKey(7 + s) for b in budgets for s in seeds]
    buds = [b for b in budgets for s in seeds]
    _, hists, gains = run_warmup_sweep(
        keys, rf, xs, ys, _sched(), n_nodes=N, init_one=init_one_g,
        optimizer=opt, estimate_gains=est, budgets=buds, **common,
    )
    # budget must matter: 4-round gains differ from 16-round gains (same key)
    assert not np.allclose(gains[0], gains[len(seeds)])
    for i, (k, b) in enumerate(zip(keys, buds)):
        _, h1, g1 = run_warmup_trajectory(
            k, rf, xs, ys, _sched(), n_nodes=N, init_one=init_one_g,
            optimizer=opt, estimate_gains=lambda kk, b=b: est(kk, b), **common,
        )
        np.testing.assert_allclose(gains[i], g1, rtol=1e-6)
        np.testing.assert_allclose(hists[i]["train_loss"], h1["train_loss"], rtol=1e-5)
        np.testing.assert_allclose(hists[i]["test_loss"], h1["test_loss"], rtol=1e-5)


def test_budget_masked_estimator_replays_standalone_budget():
    """A max-budget estimator masked to budget b must consume exactly the
    failure draws (and produce the gains) of an estimator built at b — the
    phase boundary follows the live budget, so sweep cells replay as
    standalone runs even with failures active."""
    g = T.random_k_regular(16, 4, seed=0)
    plan = compile_plan(g, "sparse", failures=FailureModel(link_p=0.7))
    key = jax.random.PRNGKey(3)
    for kw in (dict(), dict(leaderless=True), dict(mode="alpha", leaderless=True)):
        est_max = gsp.make_gain_estimator(plan, pi_rounds=24, ps_rounds=24, **kw)
        est_b = gsp.make_gain_estimator(plan, pi_rounds=8, ps_rounds=8, **kw)
        masked = np.asarray(jax.jit(lambda k, e=est_max: e(k, 8))(key))
        standalone = np.asarray(jax.jit(est_b)(key))
        np.testing.assert_allclose(masked, standalone, rtol=1e-6), kw


# ----------------------------------------------------------- churn generator
def test_churn_sequence_properties():
    base = T.random_k_regular(24, 4, seed=0)
    gs = T.churn_sequence(base, 5, 0.2, seed=1)
    assert len(gs) == 5 and gs[0] is base
    for g in gs:
        assert g.n == base.n and g.is_connected()
        assert np.all(np.diag(g.adjacency) == 0)
        # link budget conserved in expectation (exact here: add == drop)
        assert g.n_edges == base.n_edges
    # the chain actually moves
    assert any(not np.array_equal(g.adjacency, base.adjacency) for g in gs[1:])
    # rate 0 → static chain
    for g in T.churn_sequence(base, 3, 0.0, seed=1)[1:]:
        np.testing.assert_array_equal(g.adjacency, base.adjacency)
    with pytest.raises(ValueError):
        T.churn_sequence(base, 2, 1.0)


def test_walker_over_schedule():
    """Degree polls transition through the plan active at each step and
    read final degrees off the last active plan."""
    graphs = T.churn_sequence(T.configuration_heavy_tail(64, 2.2, seed=0), 3, 0.3, seed=1)
    sch = compile_schedule(graphs, "sparse", round_map=cyclic_map(2))
    ks = np.asarray(
        gsp.poll_degrees_device(
            sch.graph, 0, walk_length=12, n_walks=256,
            key=jax.random.PRNGKey(0), plan=sch,
        )
    )
    assert ks.shape == (256,) and np.isfinite(ks).all() and (ks > 0).all()
    mean_deg = np.mean([g.degrees.mean() for g in graphs])
    assert abs(ks.mean() - mean_deg) / mean_deg < 0.5
    # schedule walks under failures stay valid too
    schf = sch.with_options(failures=FailureModel(link_p=0.6))
    ksf = np.asarray(
        gsp.poll_degrees_device(
            schf.graph, 0, walk_length=12, n_walks=256,
            key=jax.random.PRNGKey(1), plan=schf,
        )
    )
    assert np.isfinite(ksf).all() and (ksf > 0).all()

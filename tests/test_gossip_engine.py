"""Parity + convergence tests for the device gossip engine (repro.gossip).

The contract (DESIGN.md §12): the engine is a jitted rendering of the numpy
reference protocols in ``core.gossip``, executed over the CommPlan backends
with failure draws keyed identically to training.  So for any topology
family, any backend and any failure draw, the engine's estimates must match
the reference integrated through the same per-round effective operators —
and, given enough rounds, the exact spectral quantities of ``core.mixing``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from test_commplan import FAMILIES

from repro.core import gossip as G
from repro.core import mixing as M
from repro.core import topology as T
from repro.core.commplan import BACKENDS, FailureModel, compile_plan
from repro.core.initialisation import gain_from_estimates
import repro.gossip as gsp


def _send_matrices(plan, key, rounds, offset=0):
    """Replay the engine's per-round failure draws (fold_in(key, r)) into the
    numpy reference's effective send operators."""
    mats = []
    for r in range(offset, offset + rounds):
        ek, na = plan.round_masks(jax.random.fold_in(key, r))
        mats.append(
            G.effective_send_matrix(
                plan.graph, np.asarray(ek)[: plan.n_edges], np.asarray(na)
            )
        )
    return mats


# ------------------------------------------------------------ push-sum parity
@settings(max_examples=10, deadline=None)
@given(
    family=st.sampled_from(sorted(FAMILIES)),
    backend=st.sampled_from(BACKENDS),
    link_p=st.sampled_from([1.0, 0.6]),
    seed=st.integers(0, 5),
)
def test_push_sum_parity_property(family, backend, link_p, seed):
    g = FAMILIES[family](16, seed)
    vals = np.linspace(-3.0, 5.0, g.n)
    rounds = 40
    fm = FailureModel(link_p=link_p)
    plan = compile_plan(g, backend, failures=fm)
    key = jax.random.PRNGKey(seed * 13 + 1) if fm.active else None
    out = np.asarray(gsp.push_sum(plan, vals, rounds, key))
    if fm.active:
        ref = G.push_sum_failures(g, vals, _send_matrices(plan, key, rounds))
    else:
        ref = G.push_sum(g, vals, rounds)
    assert np.abs(out - ref).max() < 1e-3, (family, backend)


@pytest.mark.slow
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_push_sum_parity_exhaustive(family):
    """Full backend × failure sweep per family: engine vs numpy reference vs
    the true average."""
    g = FAMILIES[family](16, 3)
    vals = np.arange(g.n, dtype=float)
    key = jax.random.PRNGKey(7)
    for backend in BACKENDS:
        plan = compile_plan(g, backend)
        out = np.asarray(gsp.push_sum(plan, vals, 300))
        assert np.abs(out - G.push_sum(g, vals, 300)).max() < 1e-3, backend
        assert np.abs(out - vals.mean()).max() < 1e-2, backend
        planf = compile_plan(g, backend, failures=FailureModel(link_p=0.7, node_p=0.9))
        outf = np.asarray(gsp.push_sum(planf, vals, 60, key))
        reff = G.push_sum_failures(g, vals, _send_matrices(planf, key, 60))
        assert np.abs(outf - reff).max() < 1e-3, backend


def test_spread_is_mass_conserving_under_failures():
    g = T.configuration_heavy_tail(48, 2.2, seed=0)
    vals = jnp.asarray(np.random.default_rng(0).normal(size=(48, 3)), jnp.float32)
    for backend in BACKENDS:
        plan = compile_plan(g, backend, failures=FailureModel(link_p=0.5, node_p=0.7))
        out = plan.spread(vals, jax.random.PRNGKey(3))
        np.testing.assert_allclose(
            np.asarray(out.sum(0)), np.asarray(vals.sum(0)), rtol=1e-5
        )


# ----------------------------------------------------- power-iteration parity
@settings(max_examples=8, deadline=None)
@given(
    family=st.sampled_from(sorted(FAMILIES)),
    backend=st.sampled_from(BACKENDS),
    failures=st.booleans(),
)
def test_power_iteration_matches_numpy_reference(family, backend, failures):
    g = FAMILIES[family](16, 1)
    pi_r, ps_r = 25, 35
    fm = FailureModel(link_p=0.7) if failures else FailureModel()
    plan = compile_plan(g, backend, failures=fm)
    key = jax.random.PRNGKey(11) if failures else None
    est = gsp.power_iteration_norm(plan, pi_r, ps_r, key)
    mats = _send_matrices(plan, key, pi_r + ps_r) if failures else None
    ref = G.power_iteration_norm_reference(g, pi_r, ps_r, send_matrices=mats)
    assert np.abs(np.asarray(est["vnorm"]) - ref["vnorm"]).max() < 1e-3, (family, backend)
    assert np.abs(np.asarray(est["n_hat"]) - ref["n_hat"]).max() / g.n < 1e-3


@pytest.mark.parametrize("family", ["kreg", "ba", "heavy_tail", "ring", "star"])
def test_power_iteration_converges_to_exact_vnorm(family):
    """Enough budget → every node's ‖v̂‖ within 5% of the spectral truth."""
    g = FAMILIES[family](16, 2)
    est = gsp.power_iteration_norm(compile_plan(g, "sparse"), 80, 160)
    exact = M.v_steady_norm(g)
    assert np.abs(np.asarray(est["vnorm"]) - exact).max() / exact < 5e-2, family
    # n̂ tolerance keyed to the slowest family's 160-round contraction
    assert np.abs(np.asarray(est["n_hat"]) - g.n).max() / g.n < 1e-2


# ------------------------------------------------------- gains: host ≡ device
def test_device_gains_match_host_gain_from_estimates():
    """Acceptance: per-node gains from the on-device engine reproduce the
    host ``gain_from_estimates`` to fp32 tolerance given identical estimates,
    on every knowledge pathway."""
    g = T.barabasi_albert(24, 3, seed=0)
    plan = compile_plan(g, "sparse")
    ests = gsp.estimate_all(plan, pi_rounds=40, ps_rounds=60)
    n_hat = np.asarray(ests.n_hat, np.float64)

    # α pathway (homogeneous default and explicit exponent)
    for alpha in (None, 0.3):
        host = gain_from_estimates(n_hat, family_exponent=alpha)
        dev = np.asarray(gsp.gains_from_estimates(ests.n_hat, family_exponent=alpha))
        assert np.abs(host - dev).max() / np.abs(host).max() < 1e-5

    # degree-sample pathway (per-node walker polls)
    sample = gsp.poll_degrees_device(
        g, np.arange(g.n), walk_length=10, n_walks=32, key=jax.random.PRNGKey(2)
    )
    host = gain_from_estimates(n_hat, degree_sample=np.asarray(sample, np.float64))
    dev = np.asarray(gsp.gain_from_degree_sample(ests.n_hat, sample))
    assert np.abs(host - dev).max() / np.abs(host).max() < 1e-5

    # direct ‖v̂‖ pathway vs the exact host gain
    dev = np.asarray(gsp.gains_from_estimates(ests.n_hat, vnorm=ests.vnorm))
    assert np.abs(dev - 1.0 / M.v_steady_norm(g)).max() < 5e-2 * dev.max()


def test_gains_from_estimates_rejects_both_sources():
    with pytest.raises(ValueError):
        gsp.gains_from_estimates(jnp.ones(4), vnorm=jnp.ones(4), family_exponent=0.5)
    with pytest.raises(ValueError):
        gsp.make_gain_estimator(
            T.ring(8), pi_rounds=2, ps_rounds=2, mode="vnorm", family_exponent=0.5
        )


def test_under_budget_nodes_fall_back_to_unit_gain():
    """A budget below a node's leader distance leaves it with no size
    estimate; the gain builders must hand it gain = 1.0 (unscaled He), not
    the astronomically wrong inverse of the underflow clamp."""
    g = T.ring(64)  # leader mass reaches ≤ budget hops per side
    plan = compile_plan(g, "dense")
    for mode in ("vnorm", "alpha"):
        gains = np.asarray(
            jax.jit(gsp.make_gain_estimator(plan, pi_rounds=8, ps_rounds=8, mode=mode))(
                jax.random.PRNGKey(0)
            )
        )
        assert np.isfinite(gains).all()
        assert gains.max() < 100.0, mode  # no 1/EPS blow-ups
        far = gains[24:40]  # nodes ≥ 9 hops from leader 0
        np.testing.assert_array_equal(far, 1.0)
    est = gsp.power_iteration_norm(plan, 8, 8)
    reached = np.asarray(est["reached"])
    assert reached[:8].all() and not reached[24:40].any()
    # numpy reference agrees on who was reached
    ref = G.power_iteration_norm_reference(g, 8, 8)
    np.testing.assert_array_equal(reached, ref["reached"])


# --------------------------------------------------------------- walker
def test_device_walker_bias_correction():
    g = T.configuration_heavy_tail(256, 2.2, seed=3)
    raw = gsp.poll_degrees_device(
        g, 0, walk_length=15, n_walks=600, key=jax.random.PRNGKey(0), correct_bias=False
    )
    fixed = gsp.poll_degrees_device(
        g, 0, walk_length=15, n_walks=600, key=jax.random.PRNGKey(0)
    )
    true_mean = g.degrees.mean()
    assert float(raw.mean()) > true_mean  # hub bias
    assert abs(float(fixed.mean()) - true_mean) < abs(float(raw.mean()) - true_mean)


def test_walker_degree_zero_guards():
    """Satellite regression: walkers on a neighbourless node must stay put,
    not read the next node's CSR segment; stuck *starts* raise."""
    a = np.zeros((4, 4), np.float32)
    a[0, 1] = a[1, 0] = 1.0  # node 2 receives from nobody; node 3 closes CSR
    a[0, 2] = 1.0  # 0 receives from 2 → walks from 0 can land on 2 and stick
    a[3, 0] = a[0, 3] = 0.0
    a[3, 1] = 1.0
    g = T.from_adjacency(a, directed=True)
    with pytest.raises(ValueError):
        G.poll_degrees(g, start=2, walk_length=3, n_walks=5)
    with pytest.raises(ValueError):
        gsp.poll_degrees_device(
            g, 2, walk_length=3, n_walks=5, key=jax.random.PRNGKey(0)
        )
    # walks from 0 traverse the sink without indexing out of its segment
    ks = G.poll_degrees(g, start=0, walk_length=6, n_walks=64, correct_bias=False)
    assert ks.shape == (64,)
    ks_d = gsp.poll_degrees_device(
        g, 0, walk_length=6, n_walks=64, key=jax.random.PRNGKey(1), correct_bias=False
    )
    assert ks_d.shape == (64,)
    # …and sink-trapped walkers are excluded from the 1/k resample instead
    # of poisoning it (host: NaN probabilities; device: all-zero samples)
    for sample in (
        G.poll_degrees(g, start=0, walk_length=6, n_walks=64),
        np.asarray(gsp.poll_degrees_device(
            g, 0, walk_length=6, n_walks=64, key=jax.random.PRNGKey(1)
        )),
    ):
        assert np.isfinite(sample).all() and (sample > 0).all()


def test_walker_rides_training_failure_draws():
    """Satellite contract: with a failure-model plan, the degree poll's
    transitions draw the same per-edge Bernoullis as training rounds — and
    still produce a valid, finite sample."""
    g = T.configuration_heavy_tail(128, 2.2, seed=1)
    plan = compile_plan(g, "sparse", failures=FailureModel(link_p=0.5, node_p=0.9))
    ks = np.asarray(gsp.poll_degrees_device(
        g, 0, walk_length=20, n_walks=400, key=jax.random.PRNGKey(4), plan=plan
    ))
    assert np.isfinite(ks).all() and (ks > 0).all()
    true_mean = g.degrees.mean()
    # failures slow exploration but the corrected sample stays in the right
    # ballpark (statistical, generous bound)
    assert abs(ks.mean() - true_mean) / true_mean < 0.5
    # inactive plan → bit-identical to the plain walk (no extra key splits)
    plan_ok = compile_plan(g, "sparse")
    a = gsp.poll_degrees_device(g, 0, walk_length=8, n_walks=32, key=jax.random.PRNGKey(5))
    b = gsp.poll_degrees_device(
        g, 0, walk_length=8, n_walks=32, key=jax.random.PRNGKey(5), plan=plan_ok
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- convergence vs spectral gap
def test_convergence_rate_tracks_spectral_gap():
    """The fitted per-round contraction of the size estimator must track
    |λ₂| = 1 − gap, and better-connected graphs must converge faster."""
    kreg = T.random_k_regular(32, 4, seed=0)
    rep = gsp.convergence_report(compile_plan(kreg, "dense"), 80)
    lam2 = rep["predicted_rate"]
    assert lam2**1.4 < rep["fitted_rate"] < lam2**0.6
    assert 0 < rep["rounds_to_1pct"] < 80

    ring = gsp.convergence_report(compile_plan(T.ring(32), "dense"), 80)
    comp = gsp.convergence_report(compile_plan(T.complete(32), "dense"), 80)
    # complete mixes in one round (λ₂ = 0: error lands on the fp32 noise
    # floor immediately, so compare budgets, not fitted rates)
    assert rep["fitted_rate"] < ring["fitted_rate"]
    assert comp["rounds_to_1pct"] < rep["rounds_to_1pct"]
    # per-node errors shrink monotonically-ish: late max error ≪ early
    assert rep["max_rel_err"][-1] < 1e-2 * rep["max_rel_err"][5]


# ------------------------------------------------------ fused warmup parity
@pytest.mark.slow
def test_fused_warmup_matches_manual_decomposition():
    """Acceptance: estimate→init→train as one program ≡ running the three
    phases by hand with the same key split (params to fp32 tolerance, gains
    bit-equal) — and the realised gains match the host gain computation."""
    from repro.core.initialisation import InitConfig
    from repro.data import batch_index_schedule, mnist_like, node_datasets
    from repro.fed import (
        init_fl_state,
        make_eval_fn,
        make_round_fn,
        run_trajectory,
        run_warmup_trajectory,
    )
    from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
    from repro.optim import sgd

    N, PER, BS, BL, R = 8, 48, 8, 2, 6
    g = T.random_k_regular(N, 4, seed=0)
    ds = mnist_like(N * PER + 64, seed=0)
    xs, ys = node_datasets(ds, [np.arange(i * PER, (i + 1) * PER) for i in range(N)])
    test = (ds.x[-64:], ds.y[-64:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    icfg = InitConfig("he_normal", 1.0)
    init_one_g = lambda k, gn: init_mlp(icfg.replace(gain=gn), k, hidden=(32,))
    rf = make_round_fn(loss_fn, opt, g, link_p=0.8)
    sched = batch_index_schedule(PER, N, BS, R * BL, seed=0)
    est_fn = gsp.make_gain_estimator(
        compile_plan(g, "sparse", failures=FailureModel(link_p=0.8)),
        pi_rounds=30, ps_rounds=50,
    )
    key = jax.random.PRNGKey(5)
    common = dict(n_rounds=R, eval_every=3, eval_fn=make_eval_fn(loss_fn),
                  eval_batch=test, b_local=BL)

    st, hist, gains = run_warmup_trajectory(
        key, rf, xs, ys, sched, n_nodes=N, init_one=init_one_g, optimizer=opt,
        estimate_gains=est_fn, **common,
    )
    k_est, k_init = jax.random.split(key)
    gains2 = jax.jit(est_fn)(k_est)
    st2 = init_fl_state(k_init, N, init_one_g, opt, gains=gains2)
    st2, hist2 = run_trajectory(st2, rf, xs, ys, sched, **common)

    np.testing.assert_array_equal(gains, np.asarray(gains2))
    for a, b in zip(jax.tree_util.tree_leaves(st.params), jax.tree_util.tree_leaves(st2.params)):
        assert float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()) < 1e-6
    np.testing.assert_allclose(hist["train_loss"], hist2["train_loss"], rtol=1e-6)
    # the estimates behind the gains reproduce the host gain path (fp32)
    host_gains = 1.0 / np.asarray(
        G.power_iteration_norm_reference(
            g, 30, 50,
            send_matrices=_send_matrices(
                compile_plan(g, "dense", failures=FailureModel(link_p=0.8)),
                jax.random.split(k_est)[0], 80,
            ),
        )["vnorm"]
    )
    np.testing.assert_allclose(gains, host_gains, rtol=1e-4)


def test_init_fl_state_per_node_gains_scale_draws():
    """gains=(n,) must reach each node's initialiser: std of node i's weights
    scales with gains[i]; gains=None keeps the legacy contract."""
    from repro.core.initialisation import InitConfig
    from repro.fed import init_fl_state
    from repro.models.paper_models import init_mlp
    from repro.optim import sgd

    icfg = InitConfig("he_normal", 1.0)
    init_g = lambda k, gn: init_mlp(icfg.replace(gain=gn), k, in_dim=64, hidden=(64,), n_classes=4)
    gains = jnp.asarray([1.0, 2.0, 4.0, 8.0])
    st = init_fl_state(jax.random.PRNGKey(0), 4, init_g, sgd(1e-2, 0.0), gains=gains)
    w = st.params["fc0"]["w"]  # (4, 64, 64)
    stds = np.asarray(jnp.std(w.reshape(4, -1), axis=1))
    np.testing.assert_allclose(stds / stds[0], [1.0, 2.0, 4.0, 8.0], rtol=0.05)
    st_legacy = init_fl_state(
        jax.random.PRNGKey(0), 4, lambda k: init_mlp(icfg, k, in_dim=64, hidden=(64,), n_classes=4),
        sgd(1e-2, 0.0),
    )
    np.testing.assert_array_equal(
        np.asarray(st_legacy.params["fc0"]["w"]),
        np.asarray(init_fl_state(jax.random.PRNGKey(0), 4, init_g, sgd(1e-2, 0.0),
                                 gains=jnp.ones(4)).params["fc0"]["w"]),
    )

"""Preemption-safe trajectories: SIGKILL mid-scan, resume bit-identically.

The contract (DESIGN.md §16): the executor snapshots its full mid-scan
carry (params, opt state, PRNG keys, data cursors, metric buffers) at chunk
boundaries, and ``resume_from=`` replays the remaining chunks so that
params AND recorded metrics are bit-identical to the uninterrupted run —
across a real process boundary, with the interruption a real ``SIGKILL``
(no atexit, no flush, no goodbye).  This is what makes ``FaultPlan``
preemption scenarios invisible in the trajectory.
"""
import os
import signal
import subprocess
import sys

import numpy as np
import jax
import pytest

from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.core.initialisation import InitConfig
from repro.core.membership import membership_schedule
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import CheckpointPolicy, init_fl_state, run_elastic_trajectory
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

CHILD = r"""
import sys
import numpy as np
import jax

from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.core.initialisation import InitConfig
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import (
    CheckpointPolicy,
    init_fl_state,
    make_eval_fn,
    make_round_fn,
    run_event_trajectory,
    run_trajectory,
)
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

kind, mode, ckpt_dir, out = sys.argv[1:5]
N, PER, BS, BL, R = 6, 32, 8, 2, 12
ds = mnist_like(N * PER + 64, seed=0)
parts = [np.arange(i * PER, (i + 1) * PER) for i in range(N)]
xs, ys = node_datasets(ds, parts)
test = (ds.x[-64:], ds.y[-64:])
loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
opt = sgd(1e-3, 0.5)
init_one = lambda k: init_mlp(InitConfig("he_normal", 2.0), k, hidden=(16,))
plan = compile_plan(T.ring(N))
eval_fn = make_eval_fn(loss_fn)
state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)

# kill: die (SIGKILL, no cleanup) right after chunk 0's checkpoint lands
policy = None if mode == "ref" else CheckpointPolicy(
    ckpt_dir, every=1, kill_after=0 if mode == "kill" else -1
)
resume = ckpt_dir if mode == "resume" else None

if kind == "traj":
    sched = batch_index_schedule(PER, N, BS, R * BL, seed=0)
    rf = make_round_fn(loss_fn, opt, plan)
    state, hist = run_trajectory(
        state, rf, xs, ys, sched, n_rounds=R, eval_every=3, eval_fn=eval_fn,
        eval_batch=test, track_sigmas=True, chunk_size=4,
        checkpoint=policy, resume_from=resume,
    )
    cols = {k: np.asarray(v) for k, v in hist.items()}
else:
    horizon = 6.0
    stream = T.poisson_event_stream(plan.graph, horizon=horizon, rate=1.0, seed=2)
    sched = batch_index_schedule(PER, N, BS, int(horizon) * BL, seed=0)
    state, hist, aux = run_event_trajectory(
        state, loss_fn, opt, plan, stream, xs, ys, sched, b_local=BL,
        n_bins=6, eval_fn=eval_fn, eval_batch=test, chunk_events=16,
        checkpoint=policy, resume_from=resume,
    )
    cols = {k: np.asarray(v) for k, v in hist.items()}
    cols["node_clock"] = aux["node_clock"]

leaves = {f"p{i}": np.asarray(l) for i, l in enumerate(jax.tree_util.tree_leaves(state))}
np.savez(out, **leaves, **{f"h_{k}": v for k, v in cols.items()})
"""


def _spawn(script, *argv):
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, script, *argv], env=env, capture_output=True, text=True,
        timeout=600,
    )


def _assert_npz_bit_equal(a_path, b_path):
    a, b = np.load(a_path), np.load(b_path)
    assert sorted(a.files) == sorted(b.files)
    for k in a.files:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)


@pytest.mark.parametrize("kind", ["traj", "event"])
def test_sigkill_and_resume_bit_parity(kind, tmp_path):
    """Reference run vs (run → SIGKILL after chunk 0 → resume from LATEST):
    params, metric history, and aux must be bit-identical."""
    script = str(tmp_path / "child.py")
    with open(script, "w") as f:
        f.write(CHILD)
    ckpt = str(tmp_path / "ckpts")

    ref = _spawn(script, kind, "ref", ckpt, str(tmp_path / "ref.npz"))
    assert ref.returncode == 0, ref.stderr

    killed = _spawn(script, kind, "kill", ckpt, str(tmp_path / "never.npz"))
    assert killed.returncode == -signal.SIGKILL, (killed.returncode, killed.stderr)
    assert not os.path.exists(tmp_path / "never.npz")  # it really died mid-run
    assert os.path.exists(os.path.join(ckpt, "LATEST"))

    res = _spawn(script, kind, "resume", ckpt, str(tmp_path / "res.npz"))
    assert res.returncode == 0, res.stderr
    _assert_npz_bit_equal(tmp_path / "ref.npz", tmp_path / "res.npz")


def test_elastic_resume_in_process_bit_parity(tmp_path):
    """The elastic carry (params, opt state, PRNG, n̂ sketches) checkpoints
    and resumes bit-identically too — here in-process, across two calls."""
    N, PER, BS, BL, R = 6, 32, 8, 2, 12
    ds = mnist_like(N * PER + 64, seed=0)
    parts = [np.arange(i * PER, (i + 1) * PER) for i in range(N)]
    xs, ys = node_datasets(ds, parts)
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    icfg = InitConfig("he_normal", 2.0)
    init_one = lambda k: init_mlp(icfg, k, hidden=(16,))
    init_one_g = lambda k, gn: init_mlp(icfg.replace(gain=gn), k, hidden=(16,))
    sched = batch_index_schedule(PER, N, BS, R * BL, seed=0)
    plan = compile_plan(T.ring(N))
    mem = membership_schedule(N, R, initial=N - 1, arrivals={1: [N - 1]}, join_warmup=3)
    kw = dict(n_rounds=R, eval_every=3, chunk_size=4, init_one=init_one_g)

    s0 = init_fl_state(jax.random.PRNGKey(3), N, init_one, opt)
    ref, h_ref, _ = run_elastic_trajectory(s0, loss_fn, opt, plan, mem, xs, ys, sched, **kw)

    d = str(tmp_path / "el")
    s1 = init_fl_state(jax.random.PRNGKey(3), N, init_one, opt)
    run_elastic_trajectory(s1, loss_fn, opt, plan, mem, xs, ys, sched,
                           checkpoint=CheckpointPolicy(d, every=1), **kw)
    s2 = init_fl_state(jax.random.PRNGKey(3), N, init_one, opt)
    # resume from the mid-run snapshot (chunk 1 of 3), not the final one
    got, h_got, _ = run_elastic_trajectory(
        s2, loss_fn, opt, plan, mem, xs, ys, sched,
        resume_from=os.path.join(d, "step_00000001.ckpt"), **kw,
    )
    for a, b in zip(jax.tree_util.tree_leaves(ref), jax.tree_util.tree_leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h_ref == h_got

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw, sgd


def test_sgd_momentum_recurrence():
    opt = sgd(learning_rate=0.1, momentum=0.5)
    p = {"w": jnp.ones(3)}
    s = opt.init(p)
    g = {"w": jnp.full(3, 2.0)}
    # v1 = 2 → Δ = -0.2; v2 = 0.5*2 + 2 = 3 → Δ = -0.3
    u1, s = opt.update(g, s, p)
    assert np.allclose(u1["w"], -0.2)
    u2, s = opt.update(g, s, p)
    assert np.allclose(u2["w"], -0.3)


def test_adamw_first_step_matches_closed_form():
    lr, wd, eps = 1e-3, 1e-2, 1e-8
    opt = adamw(learning_rate=lr, weight_decay=wd, eps=eps)
    p = {"w": jnp.full(4, 5.0)}
    s = opt.init(p)
    g = {"w": jnp.full(4, 0.3)}
    u, s = opt.update(g, s, p)
    # bias-corrected m̂ = g, v̂ = g² → step = -lr (g/(|g|+eps) + wd·p)
    want = -lr * (0.3 / (0.3 + eps) + wd * 5.0)
    assert np.allclose(u["w"], want, rtol=1e-5)
    assert int(s.step) == 1


def test_adamw_decoupled_decay_direction():
    """Weight decay must act on params, not via the gradient moments."""
    opt = adamw(learning_rate=1e-3, weight_decay=1.0)
    p = {"w": jnp.full(2, 10.0)}
    s = opt.init(p)
    g = {"w": jnp.zeros(2)}
    u, _ = opt.update(g, s, p)
    # zero grad → update is pure decay: -lr*wd*p
    assert np.allclose(u["w"], -1e-3 * 10.0)


def test_optimizers_converge_on_quadratic():
    for opt in (sgd(0.1, 0.5), adamw(0.05, weight_decay=0.0)):
        p = {"w": jnp.asarray(3.0)}
        s = opt.init(p)
        loss = lambda p: 0.5 * p["w"] ** 2
        for _ in range(200):
            gr = jax.grad(loss)(p)
            u, s = opt.update(gr, s, p)
            p = jax.tree_util.tree_map(lambda a, b: a + b, p, u)
        assert abs(float(p["w"])) < 1e-2, opt.name


def test_init_is_jit_friendly():
    """Algorithm 1 reinitialises optimizer state every round — init must jit."""
    opt = adamw()
    p = {"w": jnp.ones((4, 4))}
    s = jax.jit(opt.init)(p)
    assert int(s.step) == 0

"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the Pallas kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash.flash import flash_mha
from repro.kernels.flash.ref import attention_ref
from repro.kernels.mix.mix import mix_matmul
from repro.kernels.mix.ops import decavg_mix
from repro.kernels.mix.ref import decavg_mix_ref
from repro.kernels.rwkv.rwkv import rwkv6_chunked
from repro.kernels.rwkv.ref import rwkv6_ref


# ------------------------------------------------------------------ mix
@pytest.mark.parametrize("n,d", [(8, 64), (16, 1000), (64, 4096), (100, 257), (256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mix_matmul_sweep(n, d, dtype):
    m = jax.random.uniform(jax.random.PRNGKey(n), (n, n), jnp.float32)
    m = m / m.sum(1, keepdims=True)
    w = jax.random.normal(jax.random.PRNGKey(d), (n, d), jnp.float32).astype(dtype)
    got = mix_matmul(m, w, interpret=True)
    ref = decavg_mix_ref(m, w)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol)


@settings(max_examples=10, deadline=None)
@given(
    n=st.sampled_from([4, 12, 32]),
    d=st.integers(1, 300),
    bn=st.sampled_from([8, 32, 128]),
)
def test_mix_matmul_block_shapes_property(n, d, bn):
    """Any block shape must give the same answer (padding correctness)."""
    m = jax.random.uniform(jax.random.PRNGKey(0), (n, n))
    m = m / m.sum(1, keepdims=True)
    w = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    got = mix_matmul(m, w, block_n=bn, block_d=64, interpret=True)
    ref = decavg_mix_ref(m, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_decavg_mix_pytree_wrapper():
    n = 8
    m = jax.random.uniform(jax.random.PRNGKey(0), (n, n))
    m = m / m.sum(1, keepdims=True)
    tree = {
        "a": jax.random.normal(jax.random.PRNGKey(1), (n, 16, 4)),
        "b": {"w": jax.random.normal(jax.random.PRNGKey(2), (n, 33)).astype(jnp.bfloat16)},
    }
    got = decavg_mix(m, tree, interpret=True)
    want_a = jnp.einsum("ij,jkl->ikl", m, tree["a"])
    np.testing.assert_allclose(np.asarray(got["a"]), np.asarray(want_a), atol=1e-5)
    assert got["b"]["w"].dtype == jnp.bfloat16


def test_mix_row_stochastic_preserves_consensus():
    n = 16
    m = jnp.full((n, n), 1.0 / n)
    w = jnp.broadcast_to(jnp.arange(40.0), (n, 40))
    got = mix_matmul(m, w, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(w), atol=1e-5)


# ------------------------------------------------------------------ flash
@pytest.mark.parametrize(
    "b,h,kvh,s,hd,causal,window",
    [
        (2, 4, 2, 256, 64, True, 0),   # GQA causal
        (1, 4, 4, 200, 32, True, 64),  # MHA sliding window, padded seq
        (2, 2, 1, 128, 64, False, 0),  # bidirectional
        (1, 8, 2, 96, 128, True, 0),   # group 4
        (1, 2, 2, 512, 64, True, 128), # long + window
    ],
)
def test_flash_sweep(b, h, kvh, s, hd, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, kvh, s, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, kvh, s, hd), jnp.float32)
    got = flash_mha(q, k, v, causal=causal, window=window, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_bf16_io():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 128, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 128, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 2, 128, 64)).astype(jnp.bfloat16)
    got = flash_mha(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32), atol=3e-2, rtol=3e-2
    )


def test_flash_matches_model_attention_module():
    """The kernel and models/attention must implement the same math."""
    from repro.configs import get_reduced_config
    from repro.models.attention import _sdpa, _causal_mask

    cfg = get_reduced_config("qwen2p5_3b")
    b, s, h, kvh, hd = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kvh, hd))
    v = jax.random.normal(ks[2], (b, s, kvh, hd))
    model_out = _sdpa(q, k, v, _causal_mask(s), 1.0 / hd**0.5)
    from repro.kernels.flash.ops import flash_attention

    kern_out = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out), atol=2e-5)


# ------------------------------------------------------------------ rwkv
@pytest.mark.parametrize("bh,l,m", [(2, 64, 32), (6, 200, 64), (1, 33, 128), (4, 32, 64)])
def test_rwkv_sweep(bh, l, m):
    ks = jax.random.split(jax.random.PRNGKey(bh * l), 5)
    r = jax.random.normal(ks[0], (bh, l, m))
    k = jax.random.normal(ks[1], (bh, l, m)) * 0.5
    v = jax.random.normal(ks[2], (bh, l, m))
    z = jnp.clip(jax.random.normal(ks[3], (bh, l, m)) * 2.0, -8.0, 1.0)
    w = jnp.exp(-jnp.exp(z))
    u = jnp.abs(jax.random.normal(ks[4], (bh, m))) * 0.3
    got = rwkv6_chunked(r, k, v, w, u, interpret=True)
    ref = rwkv6_ref(r, k, v, w, u)
    scale = float(jnp.abs(ref).max())
    np.testing.assert_allclose(np.asarray(got) / scale, np.asarray(ref) / scale, atol=5e-5)


def test_rwkv_extreme_decay_stable():
    """Near-zero and near-one decays must not overflow (fp32 exponent span)."""
    bh, l, m = 2, 128, 32
    r = jnp.ones((bh, l, m))
    k = jnp.ones((bh, l, m))
    v = jnp.ones((bh, l, m))
    w = jnp.where(jnp.arange(l)[None, :, None] % 2 == 0, 0.066, 0.9997)  # clamp extremes
    u = jnp.zeros((bh, m))
    got = rwkv6_chunked(r, k, v, jnp.broadcast_to(w, (bh, l, m)), u, interpret=True)
    assert bool(jnp.isfinite(got).all())
    ref = rwkv6_ref(r, k, v, jnp.broadcast_to(w, (bh, l, m)), u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-4)


def test_rwkv_ops_layout_wrapper():
    from repro.kernels.rwkv.ops import rwkv6_attention

    b, l, h, m = 2, 50, 3, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (b, l, h, m))
    k = jax.random.normal(ks[1], (b, l, h, m)) * 0.3
    v = jax.random.normal(ks[2], (b, l, h, m))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, l, h, m)) + 2)
    u = jnp.abs(jax.random.normal(ks[4], (h, m)))
    got = rwkv6_attention(r, k, v, w, u, interpret=True)
    fold = lambda t: jnp.moveaxis(t, -2, -3).reshape(-1, l, m)
    ref = rwkv6_ref(fold(r), fold(k), fold(v), fold(w), jnp.tile(u, (b, 1)))
    ref = jnp.moveaxis(ref.reshape(b, h, l, m), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_rwkv_kernel_matches_model_module():
    """Kernel ↔ models/rwkv._wkv_chunked consistency (same clamped math)."""
    from repro.models.rwkv import _wkv_chunked

    b, l, h, m = 1, 96, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    shape = (b, l, h, m)
    r = jax.random.normal(ks[0], shape)
    k = jax.random.normal(ks[1], shape) * 0.5
    v = jax.random.normal(ks[2], shape)
    w = jnp.exp(-jnp.exp(jnp.clip(jax.random.normal(ks[3], shape), -8, 1)))
    u = jnp.abs(jax.random.normal(ks[4], (h, m))) * 0.2
    state0 = jnp.zeros((b, h, m, m), jnp.float32)
    model_out, _ = _wkv_chunked(r, k, v, w, u, state0)
    from repro.kernels.rwkv.ops import rwkv6_attention

    kern_out = rwkv6_attention(r, k, v, w, u, interpret=True)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out), atol=3e-5)

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import initialisation as I
from repro.core import topology as T


@pytest.mark.parametrize("dist,expected_std", [
    ("he_normal", np.sqrt(2.0 / 400)),
    ("glorot_normal", np.sqrt(2.0 / (400 + 300))),
])
def test_init_std_matches_formula(dist, expected_std):
    cfg = I.InitConfig(dist, gain=1.0)
    w = I.scaled_init(cfg, jax.random.PRNGKey(0), (400, 300))
    assert np.isclose(float(jnp.std(w)), expected_std, rtol=0.05)


def test_gain_scales_std_linearly():
    base = I.scaled_init(I.InitConfig("he_normal", 1.0), jax.random.PRNGKey(0), (512, 512))
    scaled = I.scaled_init(I.InitConfig("he_normal", 7.0), jax.random.PRNGKey(0), (512, 512))
    assert np.isclose(float(jnp.std(scaled)) / float(jnp.std(base)), 7.0, rtol=1e-5)


def test_uniform_variants_bounded():
    w = I.scaled_init(I.InitConfig("he_uniform", 2.0), jax.random.PRNGKey(1), (100, 100))
    limit = np.sqrt(6.0 / 100) * 2.0
    assert float(jnp.abs(w).max()) <= limit + 1e-6


def test_gain_from_graph_regular_is_sqrt_n():
    g = T.random_k_regular(64, 8, seed=0)
    assert np.isclose(I.gain_from_graph(g), 8.0, rtol=1e-10)  # √64


def test_gain_from_estimates_fallbacks():
    # homogeneous assumption: gain = √n̂
    assert np.isclose(I.gain_from_estimates(100.0), 10.0)
    # family exponent α: gain = n̂^α
    assert np.isclose(I.gain_from_estimates(256.0, family_exponent=0.25), 4.0)
    # degree sample (regular): matches closed form
    g = T.random_k_regular(64, 8, seed=0)
    est = I.gain_from_estimates(64, degree_sample=g.degrees)
    assert np.isclose(est, 8.0, rtol=1e-6)


def test_gain_from_estimates_rejects_contradictory_knowledge():
    """Satellite regression: family_exponent used to be silently ignored
    when a degree_sample was given — now the combination raises."""
    g = T.random_k_regular(64, 8, seed=0)
    with pytest.raises(ValueError, match="not both"):
        I.gain_from_estimates(64, degree_sample=g.degrees, family_exponent=0.25)


def test_gain_from_estimates_vectorises_per_node():
    """(n,) per-node estimates → (n,) gains, elementwise equal to scalar calls."""
    n_est = np.array([20.0, 64.0, 100.3])
    vec = I.gain_from_estimates(n_est)
    assert vec.shape == (3,)
    for i, ne in enumerate(n_est):
        assert np.isclose(vec[i], I.gain_from_estimates(float(ne)))
    vec_a = I.gain_from_estimates(n_est, family_exponent=0.25)
    np.testing.assert_allclose(vec_a, n_est**0.25)
    # per-node degree samples: (n, m) rows against (n,) size estimates
    g = T.random_k_regular(64, 8, seed=0)
    sample = np.stack([g.degrees, g.degrees])
    vec_d = I.gain_from_estimates(np.array([64.0, 64.2]), degree_sample=sample)
    assert vec_d.shape == (2,)
    for v in vec_d:
        assert np.isclose(v, I.gain_from_estimates(64, degree_sample=g.degrees), rtol=1e-6)


def test_per_node_gain_traces_through_scaled_init():
    """InitConfig.gain may be a traced scalar: vmapping over (key, gain)
    gives each lane its own σ scale."""
    import jax.numpy as jnp

    def one(k, g):
        return I.scaled_init(I.InitConfig("he_normal", g), k, (256, 256))

    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    gains = jnp.asarray([1.0, 3.0, 9.0])
    ws = jax.vmap(one)(keys, gains)
    stds = np.asarray(jnp.std(ws.reshape(3, -1), axis=1))
    np.testing.assert_allclose(stds / stds[0], [1.0, 3.0, 9.0], rtol=0.1)


def test_misestimated_n_degrades_gracefully():
    """Paper Fig. 4(a): 2x over/under-estimation changes gain only by √2."""
    g = T.random_k_regular(64, 8, seed=0)
    exact = I.gain_from_graph(g)
    over = I.gain_from_estimates(128)
    under = I.gain_from_estimates(32)
    assert exact / np.sqrt(2) - 1e-9 <= under <= over <= exact * np.sqrt(2) + 1e-9


def test_conv_fans():
    cfg = I.InitConfig("he_normal", 1.0)
    w = I.scaled_init(cfg, jax.random.PRNGKey(0), (3, 3, 16, 32))
    # fan_in = 3*3*16
    assert np.isclose(float(jnp.std(w)), np.sqrt(2.0 / 144), rtol=0.05)

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import initialisation as I
from repro.core import topology as T


@pytest.mark.parametrize("dist,expected_std", [
    ("he_normal", np.sqrt(2.0 / 400)),
    ("glorot_normal", np.sqrt(2.0 / (400 + 300))),
])
def test_init_std_matches_formula(dist, expected_std):
    cfg = I.InitConfig(dist, gain=1.0)
    w = I.scaled_init(cfg, jax.random.PRNGKey(0), (400, 300))
    assert np.isclose(float(jnp.std(w)), expected_std, rtol=0.05)


def test_gain_scales_std_linearly():
    base = I.scaled_init(I.InitConfig("he_normal", 1.0), jax.random.PRNGKey(0), (512, 512))
    scaled = I.scaled_init(I.InitConfig("he_normal", 7.0), jax.random.PRNGKey(0), (512, 512))
    assert np.isclose(float(jnp.std(scaled)) / float(jnp.std(base)), 7.0, rtol=1e-5)


def test_uniform_variants_bounded():
    w = I.scaled_init(I.InitConfig("he_uniform", 2.0), jax.random.PRNGKey(1), (100, 100))
    limit = np.sqrt(6.0 / 100) * 2.0
    assert float(jnp.abs(w).max()) <= limit + 1e-6


def test_gain_from_graph_regular_is_sqrt_n():
    g = T.random_k_regular(64, 8, seed=0)
    assert np.isclose(I.gain_from_graph(g), 8.0, rtol=1e-10)  # √64


def test_gain_from_estimates_fallbacks():
    # homogeneous assumption: gain = √n̂
    assert np.isclose(I.gain_from_estimates(100.0), 10.0)
    # family exponent α: gain = n̂^α
    assert np.isclose(I.gain_from_estimates(256.0, family_exponent=0.25), 4.0)
    # degree sample (regular): matches closed form
    g = T.random_k_regular(64, 8, seed=0)
    est = I.gain_from_estimates(64, degree_sample=g.degrees)
    assert np.isclose(est, 8.0, rtol=1e-6)


def test_misestimated_n_degrades_gracefully():
    """Paper Fig. 4(a): 2x over/under-estimation changes gain only by √2."""
    g = T.random_k_regular(64, 8, seed=0)
    exact = I.gain_from_graph(g)
    over = I.gain_from_estimates(128)
    under = I.gain_from_estimates(32)
    assert exact / np.sqrt(2) - 1e-9 <= under <= over <= exact * np.sqrt(2) + 1e-9


def test_conv_fans():
    cfg = I.InitConfig("he_normal", 1.0)
    w = I.scaled_init(cfg, jax.random.PRNGKey(0), (3, 3, 16, 32))
    # fan_in = 3*3*16
    assert np.isclose(float(jnp.std(w)), np.sqrt(2.0 / 144), rtol=0.05)

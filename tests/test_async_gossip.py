"""Event-driven (asynchronous) gossip: the barrier-free path (DESIGN.md §14).

Property tests for the Poisson event envelope, the pairwise event operators
(`CommPlan.event_mix` / `event_spread` / `event_spread_min`), the engine's
event protocols against the numpy event references in `core.gossip`, and
the event executor's virtual-clock / staleness bookkeeping.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import gossip as G
from repro.core import topology as T
from repro.core.commplan import FailureModel, compile_plan, compile_schedule
from repro.gossip import (
    estimate_size_leaderless_events,
    push_sum_events,
    spread_events,
)

BACKENDS = ("dense", "sparse", "ppermute")


def _graphs():
    return [
        T.ring(12),
        T.random_k_regular(12, 4, seed=0),
        T.barabasi_albert(16, 3, seed=1),
    ]


# ---------------------------------------------------------------- sampler
def test_poisson_stream_deterministic_under_seed_reuse():
    g = T.random_k_regular(16, 4, seed=0)
    a = T.poisson_event_stream(g, horizon=10.0, rate=1.0, seed=3)
    b = T.poisson_event_stream(g, horizon=10.0, rate=1.0, seed=3)
    assert a.n_events == b.n_events
    assert np.array_equal(a.edges, b.edges)
    assert np.array_equal(a.times, b.times)
    c = T.poisson_event_stream(g, horizon=10.0, rate=1.0, seed=4)
    assert not np.array_equal(a.edges, c.edges)


def test_poisson_stream_sorted_padded_and_scaled():
    g = T.ring(10)
    m = g.n_edges
    s = T.poisson_event_stream(g, horizon=50.0, rate=1.0, seed=0, envelope=1000)
    assert s.envelope == 1000
    live, pad = s.edges[: s.n_events], s.edges[s.n_events :]
    assert np.all(np.diff(s.times[: s.n_events]) >= 0)
    assert np.all(pad == -1) and np.all(s.times[s.n_events :] == s.horizon)
    assert np.all((live >= 0) & (live < m))
    # counts concentrate around rate·horizon per edge (5σ across the pool)
    lam = m * 50.0
    assert abs(s.n_events - lam) < 5 * np.sqrt(lam)
    # rate forms: per-edge vector and symmetric rate matrix
    sv = T.poisson_event_stream(g, horizon=5.0, rate=np.full(m, 2.0), seed=1)
    sm = T.poisson_event_stream(g, horizon=5.0, rate=2.0 * g.adjacency, seed=1)
    assert sv.n_events == sm.n_events and np.array_equal(sv.edges, sm.edges)


def test_poisson_stream_rejects_bad_input():
    g = T.ring(8)
    with pytest.raises(ValueError, match="envelope"):
        T.poisson_event_stream(g, horizon=50.0, rate=4.0, seed=0, envelope=3)
    with pytest.raises(ValueError, match="horizon"):
        T.poisson_event_stream(g, horizon=0.0)
    with pytest.raises(ValueError, match="non-negative"):
        T.poisson_event_stream(g, horizon=1.0, rate=np.full(g.n_edges, -1.0))
    directed = T.Graph(np.triu(np.ones((4, 4), np.float32), 1), name="dag", directed=True)
    with pytest.raises(ValueError, match="undirected"):
        T.poisson_event_stream(directed, horizon=1.0)


# ------------------------------------------------- operator parity properties
@pytest.mark.parametrize("backend", BACKENDS)
def test_event_generator_matches_sync_operator_in_expectation(backend):
    """One event per edge per unit time, linearised: the single-event
    generators sum to the synchronous operator EXACTLY (Σ_e B_e = M − I),
    i.e. Σ_e event_mix(x, e) − (m−1)·x == mix(x) — the rate-1 parity."""
    for g in _graphs():
        plan = compile_plan(g, backend=backend)
        x = jnp.asarray(np.random.default_rng(0).normal(size=(g.n, 3)), jnp.float32)
        acc = sum(plan.event_mix(x, e) for e in range(plan.n_edges))
        lhs = acc - (plan.n_edges - 1) * x
        np.testing.assert_allclose(lhs, plan.mix(x), atol=1e-4)
        accs = sum(plan.event_spread(x, e) for e in range(plan.n_edges))
        lhs_s = accs - (plan.n_edges - 1) * x
        np.testing.assert_allclose(lhs_s, plan.spread(x), atol=1e-4)


def test_event_sweep_approximates_one_round():
    """Composing one event per edge ≈ one synchronous round: the realised
    sweep operator is row-stochastic with the consensus fixed point exact,
    and contracts disagreement within a small factor of `mix`."""
    for g in _graphs():
        plan = compile_plan(g, backend="dense")
        ident = jnp.eye(g.n)
        sweep = ident
        for e in range(plan.n_edges):
            sweep = plan.event_mix(sweep, e)
        m_ev = np.asarray(sweep)
        np.testing.assert_allclose(m_ev.sum(axis=1), 1.0, atol=1e-5)
        x = np.random.default_rng(1).normal(size=g.n)
        dis = lambda v: np.linalg.norm(v - v.mean())
        r_event = dis(m_ev @ x) / dis(x)
        r_sync = dis(np.asarray(plan.receive) @ x) / dis(x)
        assert 0.3 < r_event / r_sync < 3.0, (g.name, r_event, r_sync)
        # consensus is a fixed point, exactly
        ones = jnp.ones(g.n)
        np.testing.assert_allclose(np.asarray(plan.event_mix(ones, 0)), 1.0, atol=1e-6)


def test_event_ops_identical_across_backends():
    g = T.barabasi_albert(14, 3, seed=2)
    plans = [compile_plan(g, backend=b) for b in BACKENDS]
    x = jnp.asarray(np.random.default_rng(2).normal(size=(g.n, 2)), jnp.float32)
    for e in [-1, 0, g.n_edges - 1]:
        outs = [np.asarray(p.event_mix(x, e)) for p in plans]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)
        outs = [np.asarray(p.event_spread(x, e)) for p in plans]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)


def test_event_padding_is_identity_and_mass_conserved():
    g = T.random_k_regular(12, 4, seed=1)
    plan = compile_plan(g)
    x = jnp.asarray(np.random.default_rng(3).normal(size=g.n), jnp.float32)
    np.testing.assert_array_equal(np.asarray(plan.event_mix(x, -1)), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(plan.event_spread(x, -1)), np.asarray(x))
    for e in range(plan.n_edges):
        x = plan.event_spread(x, e)
    assert abs(float(x.sum()) - float(jnp.asarray(np.random.default_rng(3).normal(size=g.n), jnp.float32).sum())) < 1e-4


def test_schedule_views_carry_event_tables():
    """Event tables stack into the schedule envelope: a selected view's
    pairwise event op matches the standalone plan's, and the schedule-level
    time-dispatched op resolves the same window plan."""
    graphs = T.churn_sequence(T.random_k_regular(12, 4, seed=0), 2, 0.2, seed=1)
    sched = compile_schedule(graphs, backend="dense")
    x = jnp.asarray(np.random.default_rng(7).normal(size=(12, 3)), jnp.float32)
    for w in (0, 1):
        plan = compile_plan(graphs[w], backend="dense")
        for e in (0, plan.n_edges - 1, -1):
            np.testing.assert_array_equal(
                np.asarray(sched.select(w).event_mix(x, e)),
                np.asarray(plan.event_mix(x, e)),
            )
            np.testing.assert_array_equal(
                np.asarray(sched.event_spread(x, e, w + 0.5)),
                np.asarray(plan.event_spread(x, e)),
            )


# --------------------------------------------------- engine vs numpy reference
@pytest.mark.parametrize("backend", ("dense", "sparse"))
def test_event_push_sum_matches_reference_and_converges(backend):
    g = T.barabasi_albert(20, 3, seed=4)
    stream = T.poisson_event_stream(g, horizon=14.0, rate=1.0, seed=5)
    vals = np.random.default_rng(4).normal(size=g.n)
    plan = compile_plan(g, backend=backend)
    dev = np.asarray(push_sum_events(plan, jnp.asarray(vals), stream))
    ref = G.push_sum_events_reference(g, vals, stream.edges)
    np.testing.assert_allclose(dev, ref, atol=1e-5)
    assert np.abs(dev - vals.mean()).max() < 0.05


def test_event_spread_failure_draws_replay_exactly():
    """Per-event failure draws (`fold_in(key, event_index)` through
    `CommPlan.event_keep`) are host-replayable: passing the realised keep
    flags to the numpy reference reproduces the device run exactly."""
    g = T.random_k_regular(16, 4, seed=2)
    stream = T.poisson_event_stream(g, horizon=6.0, rate=1.0, seed=6)
    vals = np.random.default_rng(5).normal(size=g.n)
    plan = compile_plan(g, backend="sparse", failures=FailureModel(link_p=0.6, node_p=0.9))
    key = jax.random.PRNGKey(8)
    dev = np.asarray(spread_events(plan, jnp.asarray(vals), stream, key))
    keep = np.array(
        [bool(plan.event_keep(jax.random.fold_in(key, i))) for i in range(stream.envelope)]
    )
    ref = G.event_spread_reference(g, vals, stream.edges, keep)
    np.testing.assert_allclose(dev, ref, atol=1e-5)
    assert abs(dev.sum() - vals.sum()) < 1e-4  # failures never destroy mass


def test_leaderless_sketches_over_events():
    """Barrier-free leaderless n̂: device min-exchange over the stream equals
    the numpy replay given the same sketches, and the estimate lands."""
    g = T.random_k_regular(24, 4, seed=3)
    stream = T.poisson_event_stream(g, horizon=10.0, rate=1.0, seed=7)
    key = jax.random.PRNGKey(11)
    n_hat, mins = estimate_size_leaderless_events(
        g, stream, key, n_sketches=64, return_sketches=True
    )
    # replicate the internal sketch draw, replay the min-exchange in numpy
    k_draw, _ = jax.random.split(key)
    sketches = np.asarray(jax.random.exponential(k_draw, (g.n, 64)))
    ref_mins = G.event_spread_min_reference(g, sketches, stream.edges)
    np.testing.assert_allclose(np.asarray(mins), ref_mins, atol=1e-5)
    ref_n = (64 - 1) / ref_mins.sum(axis=1)
    np.testing.assert_allclose(np.asarray(n_hat), ref_n, rtol=1e-4)
    assert abs(np.median(np.asarray(n_hat)) - g.n) / g.n < 0.3


# ------------------------------------------------------- executor bookkeeping
def _tiny_setup(n=4, per_node=8, dim=3):
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(n, per_node, dim)).astype(np.float32)
    ys = rng.integers(0, 2, size=(n, per_node)).astype(np.int32)

    def loss_fn(params, batch):
        x, y = batch
        pred = x @ params["w"]
        return jnp.mean((pred - y.astype(jnp.float32)) ** 2)

    init_one = lambda k: {"w": jax.random.normal(k, (dim,)) * 0.1}
    return xs, ys, loss_fn, init_one


def test_event_trajectory_clocks_staleness_and_counts():
    from repro.data import batch_index_schedule
    from repro.fed import init_fl_state, run_event_trajectory
    from repro.optim import sgd

    n = 4
    g = T.ring(n)
    xs, ys, loss_fn, init_one = _tiny_setup(n=n)
    opt = sgd(1e-2, 0.0)
    state = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)
    # hand-built stream on ring-4 (edges: 0:(0,1) 1:(0,3) 2:(1,2) 3:(2,3)),
    # horizon 4, two bins; one padding event exercises the identity path
    stream = T.EventStream(
        times=np.array([0.5, 1.0, 2.5, 3.0, 4.0], np.float32),
        edges=np.array([0, 2, 0, 1, -1], np.int32),
        n_events=4,
        horizon=4.0,
        rates=np.ones(g.n_edges),
    )
    sched = batch_index_schedule(8, n, 4, 6, seed=0)
    final, hist, aux = run_event_trajectory(
        state, loss_fn, opt, compile_plan(g, backend="dense"), stream, xs, ys, sched,
        b_local=2, n_bins=2,
    )
    # participation counts (ring-4 edges: 0:(0,1) 1:(0,3) 2:(1,2) 3:(2,3)):
    # node0 @0.5, 2.5, 3.0; node1 @0.5, 1.0, 2.5; node2 @1.0; node3 @3.0
    np.testing.assert_array_equal(aux["node_events"], [3, 3, 1, 1])
    np.testing.assert_allclose(aux["node_clock"], [3.0, 2.5, 1.0, 3.0], atol=1e-6)
    assert int(final.round) == 4  # live events only
    assert hist["events"] == [2, 2] and hist["messages"] == [4, 4]
    assert hist["time"] == [2.0, 4.0]
    # staleness: bin0 events (0.5: both fresh → 0.5 each; 1.0: node1 idle
    # 0.5, node2 idle 1.0) → mean (0.5 + 0.75)/2; bin1 (2.5: node0 idle 2.0,
    # node1... edge0=(0,1): idle 2.0 and 1.5 → 1.75; 3.0: edge1=(0,3): 0.5
    # and 3.0 → 1.75) → 1.75
    np.testing.assert_allclose(hist["staleness"], [0.625, 1.75], atol=1e-5)
    # train loss recorded in every bin, finite
    assert all(np.isfinite(hist["train_loss"]))


def test_event_trajectory_counts_only_delivered_messages():
    """A failure draw that kills the exchange spends no messages — but the
    endpoints still woke, trained and advanced their clocks."""
    from repro.data import batch_index_schedule
    from repro.fed import init_fl_state, run_event_trajectory
    from repro.optim import sgd

    n = 4
    g = T.ring(n)
    xs, ys, loss_fn, init_one = _tiny_setup(n=n)
    opt = sgd(1e-2, 0.0)
    state = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)
    stream = T.poisson_event_stream(g, horizon=3.0, rate=1.0, seed=3)
    sched = batch_index_schedule(8, n, 4, 6, seed=0)
    plan = compile_plan(g, backend="dense", failures=FailureModel(link_p=0.0))
    _, hist, aux = run_event_trajectory(
        state, loss_fn, opt, plan, stream, xs, ys, sched, b_local=2, n_bins=2
    )
    assert sum(hist["messages"]) == 0  # every exchange failed
    assert sum(hist["events"]) == stream.n_events  # ...but every clock fired
    assert aux["node_events"].sum() == 2 * stream.n_events


def test_event_trajectory_padding_invariant():
    """Extending the envelope with padding events changes nothing."""
    from repro.data import batch_index_schedule
    from repro.fed import init_fl_state, run_event_trajectory
    from repro.optim import sgd

    n = 4
    g = T.ring(n)
    xs, ys, loss_fn, init_one = _tiny_setup(n=n)
    opt = sgd(1e-2, 0.0)
    sched = batch_index_schedule(8, n, 4, 6, seed=0)
    stream = T.poisson_event_stream(g, horizon=3.0, rate=1.0, seed=2)
    padded = T.poisson_event_stream(g, horizon=3.0, rate=1.0, seed=2, envelope=stream.n_events + 7)

    def run(s):
        state = init_fl_state(jax.random.PRNGKey(1), n, init_one, opt)
        final, hist, aux = run_event_trajectory(
            state, loss_fn, opt, compile_plan(g, backend="dense"), s, xs, ys, sched,
            b_local=2, n_bins=3,
        )
        return final, hist, aux

    f1, h1, a1 = run(stream)
    f2, h2, a2 = run(padded)
    np.testing.assert_array_equal(a1["node_events"], a2["node_events"])
    assert h1["train_loss"] == h2["train_loss"]
    np.testing.assert_array_equal(
        np.asarray(f1.params["w"]), np.asarray(f2.params["w"])
    )


@pytest.mark.slow
def test_event_trajectory_rate1_tracks_synchronous_executor():
    """Budget-matched end-to-end band: rate-1 clocks over horizon R reach a
    final test loss in the same regime as R synchronous rounds (events
    trigger extra local compute, so they may only do better)."""
    from repro.core.initialisation import InitConfig, gain_from_graph
    from repro.data import batch_index_schedule, mnist_like, node_datasets
    from repro.fed import (
        init_fl_state,
        make_eval_fn,
        make_round_fn,
        run_event_trajectory,
        run_trajectory,
    )
    from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
    from repro.optim import sgd

    n, per_node, rounds = 16, 64, 20
    g = T.random_k_regular(n, 4, seed=0)
    ds = mnist_like(n * per_node + 256, seed=0)
    parts = [np.arange(i * per_node, (i + 1) * per_node) for i in range(n)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-256:], ds.y[-256:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    eval_fn = make_eval_fn(loss_fn)
    gain = gain_from_graph(g)
    init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k, hidden=(64, 32))
    sched = batch_index_schedule(per_node, n, 16, rounds * 2, seed=0)
    plan = compile_plan(g, backend="sparse")

    state = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)
    rf = make_round_fn(loss_fn, opt, plan)
    _, hist_sync = run_trajectory(
        state, rf, xs, ys, sched, n_rounds=rounds, eval_every=rounds,
        eval_fn=eval_fn, eval_batch=test, b_local=2,
    )
    stream = T.poisson_event_stream(g, horizon=float(rounds), rate=1.0, seed=1)
    state2 = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)
    _, hist_ev, _ = run_event_trajectory(
        state2, loss_fn, opt, plan, stream, xs, ys, sched,
        b_local=2, n_bins=5, eval_fn=eval_fn, eval_batch=test,
    )
    sync_final = hist_sync["test_loss"][-1]
    ev_final = hist_ev["test_loss"][-1]
    assert ev_final < sync_final + 0.3, (ev_final, sync_final)
    assert ev_final < hist_ev["test_loss"][0], "no descent over the stream"

"""Node-sharded CommPlan rendering: parity, determinism, batched events.

The sharded rendering's contract (DESIGN.md §15) is *bit*-parity: the same
plan run over a node-sharded mesh must produce bit-identical results to the
single-device operator — same per-row accumulation order through the
``[local | halo]`` gather space, same replicated failure draws.  Host-side
layout compilation is pure (tables must be deterministic), and the batched
event path must replay the sequential event stream exactly.

Multi-device cases run in a subprocess with 8 forced host devices (the
``tests/test_distributed.py`` pattern) and are marked slow; the host-side
layout and batched-event tests are tier-1.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.commplan import FailureModel, compile_plan
from repro.core.shardplan import _build_layout
from repro.core.topology import batch_events_by_color

_SCRIPT_OPERATORS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import topology as T
    from repro.core.commplan import FailureModel, compile_plan
    from repro.core.shardplan import shard_plan
    from repro.launch.mesh import make_production_mesh, n_fl_nodes, node_mesh

    # mesh satellite: explicit device counts scale the pod shape down
    assert n_fl_nodes(n_devices=8) == 8
    mesh = make_production_mesh(n_devices=8)
    assert int(np.prod(list(mesh.shape.values()))) == 8
    assert node_mesh(8).axis_names == ("node",)

    rng = np.random.default_rng(0)
    for graph in (T.random_k_regular(16, 4, seed=1), T.barabasi_albert(16, 3, seed=2)):
        n = graph.n
        x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
        params = {"w": jnp.asarray(rng.normal(size=(n, 3, 2)).astype(np.float32)), "b": x}
        for failures in (FailureModel(), FailureModel(link_p=0.7, node_p=0.9)):
            key = jax.random.PRNGKey(42) if failures.active else None
            for backend in ("sparse", "dense"):
                plan = compile_plan(graph, backend=backend, failures=failures)
                ref = plan.mix(params, key=key)
                ref_spread = plan.spread(x, key=key)
                ref_min = plan.spread_min(x, key=key)
                for s in (1, 2, 4):
                    sp = shard_plan(plan, n_shards=s)
                    got = sp.mix(params, key=key)
                    for k in params:
                        assert np.array_equal(np.asarray(ref[k]), np.asarray(got[k])), (
                            graph.name,
                            backend,
                            failures.active,
                            s,
                            k,
                        )
                    assert np.array_equal(
                        np.asarray(ref_spread), np.asarray(sp.spread(x, key=key))
                    ), (graph.name, backend, failures.active, s, "spread")
                    assert np.array_equal(
                        np.asarray(ref_min), np.asarray(sp.spread_min(x, key=key))
                    ), (graph.name, backend, failures.active, s, "spread_min")
    print("OPERATORS_OK")
    """
)

_SCRIPT_EXECUTOR = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core import topology as T
    from repro.core.commplan import FailureModel, compile_plan
    from repro.core.initialisation import InitConfig
    from repro.data import batch_index_schedule, mnist_like, node_datasets
    from repro.fed import (
        init_fl_state,
        make_eval_fn,
        make_round_fn,
        run_sharded_trajectory,
        run_trajectory,
    )
    from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
    from repro.optim import sgd

    N, PER_NODE, BS, B_LOCAL, ROUNDS = 8, 32, 8, 2, 6
    ds = mnist_like(N * PER_NODE + 32, seed=0)
    parts = [np.arange(i * PER_NODE, (i + 1) * PER_NODE) for i in range(N)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-32:], ds.y[-32:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 2.0), k, hidden=(16,))
    eval_fn = make_eval_fn(loss_fn)
    sched = batch_index_schedule(PER_NODE, N, BS, ROUNDS * B_LOCAL, seed=0)
    graph = T.random_k_regular(N, 4, seed=1)
    common = dict(eval_every=3, eval_fn=eval_fn, eval_batch=test, track_sigmas=True)
    for link_p in (1.0, 0.8):
        plan = compile_plan(graph, backend="sparse")
        rf = make_round_fn(loss_fn, opt, plan, link_p=link_p)
        s0 = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
        s_ref, h_ref = run_trajectory(
            s0, rf, xs, ys, sched, n_rounds=ROUNDS, b_local=B_LOCAL, **common
        )
        for S in (2, 4):
            p2 = plan if link_p == 1.0 else plan.with_options(failures=FailureModel(link_p=link_p))
            sp = p2.shard(n_shards=S)
            s0b = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
            s_sh, h_sh = run_sharded_trajectory(
                s0b, loss_fn, opt, sp, xs, ys, sched, n_rounds=ROUNDS, b_local=B_LOCAL, **common
            )
            for a, b in zip(
                jax.tree_util.tree_leaves(s_ref.params),
                jax.tree_util.tree_leaves(s_sh.params),
            ):
                assert np.array_equal(np.asarray(a), np.asarray(b)), (link_p, S)
            for col in ("train_loss", "test_loss", "sigma_ap", "sigma_an"):
                r, g = np.asarray(h_ref[col]), np.asarray(h_sh[col])
                assert np.isnan(r).tolist() == np.isnan(g).tolist(), (link_p, S, col)
                assert np.nanmax(np.abs(r - g), initial=0.0) < 5e-6, (link_p, S, col)
    print("EXECUTOR_OK")
    """
)

_SCRIPT_GOSSIP = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core import topology as T
    from repro.core.commplan import FailureModel, compile_plan
    from repro.gossip import estimate_all, estimate_size_leaderless

    graph = T.random_k_regular(16, 4, seed=3)
    key = jax.random.PRNGKey(7)
    for failures in (FailureModel(), FailureModel(link_p=0.85)):
        plan = compile_plan(
            graph,
            backend="sparse",
            failures=failures,
            data_sizes=np.arange(1, 17, dtype=np.float64),
        )
        ref = estimate_all(plan, pi_rounds=5, ps_rounds=8, key=key)
        ref_l = estimate_size_leaderless(plan, 8, key)
        for S in (2, 4):
            sp = plan.shard(n_shards=S)
            got = estimate_all(sp, pi_rounds=5, ps_rounds=8, key=key)
            got_l = estimate_size_leaderless(sp, 8, key)
            assert np.array_equal(np.asarray(ref.n_hat), np.asarray(got.n_hat)), S
            assert np.array_equal(np.asarray(ref.vnorm), np.asarray(got.vnorm)), S
            assert np.array_equal(np.asarray(ref_l), np.asarray(got_l)), S
    print("GOSSIP_OK")
    """
)


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=420
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_operators_bit_identical():
    """mix / spread / spread_min over {1, 2, 4} shards, dense and sparse,
    clean and failing, must be bit-identical to the single-device plan."""
    assert "OPERATORS_OK" in _run(_SCRIPT_OPERATORS)


@pytest.mark.slow
def test_sharded_executor_parity():
    """run_sharded_trajectory: final params bit-identical to run_trajectory,
    psum-reduced metrics within float tolerance, NaN eval mask preserved."""
    assert "EXECUTOR_OK" in _run(_SCRIPT_EXECUTOR)


@pytest.mark.slow
def test_sharded_gossip_estimation_parity():
    """The estimation engine over a sharded plan reproduces the unsharded
    estimates bit-exactly (spread / spread_min through the halo exchange)."""
    assert "GOSSIP_OK" in _run(_SCRIPT_GOSSIP)


def _layout_inputs(plan):
    src = np.asarray(plan.src)
    dst = np.asarray(plan.dst)
    return (
        plan.n,
        dst,
        src,
        np.asarray(plan.edge_uid),
        np.asarray(plan.edge_w),
        np.asarray(plan.raw_edge_w),
        np.arange(len(src), dtype=np.int32),
        np.asarray(plan.self_w),
        np.asarray(plan.raw_self_w),
    )


def test_halo_tables_deterministic():
    """Layout compilation is a pure function of the plan: two builds must
    produce identical tables and halo plans (the executor caches them as
    compile-time constants, so nondeterminism would break resume/replay)."""
    plan = compile_plan(T.barabasi_albert(24, 3, seed=5), backend="sparse")
    n, own, far, uid, ew, rew, perm, sw, rsw = _layout_inputs(plan)
    a = _build_layout(n, 4, own, far, uid, ew, rew, perm, sw, rsw)
    b = _build_layout(n, 4, own, far, uid, ew, rew, perm, sw, rsw)
    assert a.h_max == b.h_max
    assert a.pos == b.pos
    for f in ("seg", "gat", "uid", "edge_w", "gown", "gfar", "valid", "perm", "send"):
        np.testing.assert_array_equal(np.asarray(getattr(a, f)), np.asarray(getattr(b, f)))


def test_halo_layout_covers_all_edges():
    """Every CSR edge lands in exactly one shard slice, and every remote
    endpoint has a gather position in its owner's ``[local | halo]`` space."""
    plan = compile_plan(T.random_k_regular(24, 4, seed=6), backend="sparse")
    n, own, far, uid, ew, rew, perm, sw, rsw = _layout_inputs(plan)
    layout = _build_layout(n, 4, own, far, uid, ew, rew, perm, sw, rsw)
    nps = n // 4
    valid = np.asarray(layout.valid)
    assert int(valid.sum()) == len(far)
    gat = np.asarray(layout.gat)
    gfar = np.asarray(layout.gfar)
    for s in range(4):
        for g, fg in zip(gat[s][valid[s]], gfar[s][valid[s]]):
            if s * nps <= fg < (s + 1) * nps:
                assert g == fg - s * nps
            else:
                assert g == layout.pos[s][int(fg)]


def test_batched_events_match_sequential():
    """event_mix_batch over colour-batched events replays the sequential
    event stream bit-exactly — clean and with per-event failure draws."""
    graph = T.random_k_regular(12, 4, seed=1)
    stream = T.poisson_event_stream(graph, horizon=3.0, rate=1.0, seed=5)
    batches = batch_events_by_color(stream, graph)
    assert batches.n_events == stream.n_events
    el = graph.edge_list()
    for row in np.asarray(batches.edges):
        touched = [v for e in row if e >= 0 for v in (el[e, 0], el[e, 1])]
        assert len(touched) == len(set(touched)), row
    for failures in (FailureModel(), FailureModel(link_p=0.8, node_p=0.9)):
        plan = compile_plan(graph, backend="sparse", failures=failures)
        params = {
            "w": jnp.asarray(np.random.default_rng(0).normal(size=(12, 4)).astype(np.float32)),
        }
        base_key = jax.random.PRNGKey(3)
        seq = params
        for i in range(stream.n_events):
            k = jax.random.fold_in(base_key, i) if failures.active else None
            seq = plan.event_mix(seq, int(stream.edges[i]), k)
        bat = params
        for b in range(batches.n_batches):
            keys = None
            if failures.active:
                idx = jnp.asarray(np.maximum(batches.event_index[b], 0))
                keys = jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)
            bat = plan.event_mix_batch(bat, jnp.asarray(batches.edges[b]), keys)
        np.testing.assert_array_equal(np.asarray(seq["w"]), np.asarray(bat["w"]))


def test_mesh_exports():
    """Satellite regression: ``n_fl_nodes`` is exported and usable without
    touching device state (the 8-device shapes are covered in the slow
    operators subprocess, where the forced host devices exist)."""
    from repro.launch import mesh as M

    assert "n_fl_nodes" in M.__all__
    assert M.n_fl_nodes() == 16
    assert M.n_fl_nodes(multi_pod=True) == 32

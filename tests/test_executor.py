"""Fused round executor: parity with the legacy per-round train_loop.

The executor re-uses the exact ``round_fn`` that ``make_round_fn`` builds and
gathers its minibatches from ``batch_index_schedule`` — the same PRNG stream
and the same batch order as ``train_loop`` + ``node_batch_iterator``.  The
trajectory (params, opt state, rng, train/σ metrics) must therefore be
bit-identical.  The recorded test loss is a read-only observable computed in
a different XLA program; it is allowed the ~1-ulp slack XLA reserves when
lowering the same subgraph in different programs.
"""
import numpy as np
import jax
import pytest

from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.core.initialisation import InitConfig
from repro.data import batch_index_schedule, mnist_like, node_batch_iterator, node_datasets
from repro.fed import (
    init_fl_state,
    make_eval_fn,
    make_round_fn,
    run_sweep,
    run_trajectory,
    stack_states,
    train_loop,
    unstack_states,
)
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

N, PER_NODE, BS, B_LOCAL, ROUNDS = 6, 48, 8, 2, 10


@pytest.fixture(scope="module")
def setup():
    ds = mnist_like(N * PER_NODE + 64, seed=0)
    parts = [np.arange(i * PER_NODE, (i + 1) * PER_NODE) for i in range(N)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-64:], ds.y[-64:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 2.0), k, hidden=(32,))
    return xs, ys, test, loss_fn, opt, init_one


def _batches(xs, ys, seed=0):
    it = node_batch_iterator(xs, ys, BS, seed=seed)
    while True:
        b = [next(it) for _ in range(B_LOCAL)]
        yield (np.stack([q.x for q in b], 1), np.stack([q.y for q in b], 1))


def _schedule(seed=0, rounds=ROUNDS):
    return batch_index_schedule(PER_NODE, N, BS, rounds * B_LOCAL, seed=seed)


def _assert_states_bit_equal(s1, s2):
    for a, b in zip(jax.tree_util.tree_leaves(s1), jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _run_both(setup, plan, link_p=1.0, chunk_size=0, **round_kw):
    xs, ys, test, loss_fn, opt, init_one = setup
    eval_fn = make_eval_fn(loss_fn)
    rf = make_round_fn(loss_fn, opt, plan, link_p=link_p, **round_kw)
    common = dict(eval_every=3, eval_fn=eval_fn, eval_batch=test, track_sigmas=True)
    s_leg = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    s_leg, h_leg = train_loop(s_leg, rf, _batches(xs, ys), n_rounds=ROUNDS, **common)
    s_ex = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    s_ex, h_ex = run_trajectory(
        s_ex, rf, xs, ys, _schedule(), n_rounds=ROUNDS, chunk_size=chunk_size, **common
    )
    return (s_leg, h_leg), (s_ex, h_ex)


def _assert_parity(leg, ex):
    (s_leg, h_leg), (s_ex, h_ex) = leg, ex
    _assert_states_bit_equal(s_leg, s_ex)
    assert h_leg["round"] == h_ex["round"]
    # the trajectory's own metrics are computed by the same round_fn: exact
    assert h_leg["train_loss"] == h_ex["train_loss"]
    assert h_leg["sigma_ap"] == h_ex["sigma_ap"]
    assert h_leg["sigma_an"] == h_ex["sigma_an"]
    # test loss: separate XLA program → 1-ulp slack
    np.testing.assert_allclose(h_leg["test_loss"], h_ex["test_loss"], rtol=2e-6)


def test_parity_dense_backend(setup):
    plan = compile_plan(T.complete(N), backend="dense")
    _assert_parity(*_run_both(setup, plan))


def test_parity_sparse_backend(setup):
    plan = compile_plan(T.random_k_regular(N, 3, seed=0), backend="sparse")
    _assert_parity(*_run_both(setup, plan))


def test_parity_dense_with_failures(setup):
    """Failure draws come from the state's PRNG stream — the scanned stream
    must match the per-round one draw for draw."""
    plan = compile_plan(T.complete(N), backend="dense")
    _assert_parity(*_run_both(setup, plan, link_p=0.5))


def test_parity_sparse_with_failures(setup):
    plan = compile_plan(T.random_k_regular(N, 3, seed=0), backend="sparse")
    _assert_parity(*_run_both(setup, plan, link_p=0.6))


def test_parity_chunked(setup):
    """Chunk boundaries (incl. a ragged final chunk) don't change anything."""
    plan = compile_plan(T.complete(N), backend="dense")
    _assert_parity(*_run_both(setup, plan, chunk_size=4))


def test_host_iterator_matches_schedule(setup):
    """Satellite contract: the vectorised host iterator and the on-device
    gather schedule select the same samples in the same order."""
    xs, ys, *_ = setup
    sched = batch_index_schedule(PER_NODE, N, BS, 3 * (PER_NODE // BS) + 2, seed=7)
    it = node_batch_iterator(xs, ys, BS, seed=7)
    node = np.arange(N)[:, None]
    for k in range(sched.shape[0]):  # crosses epoch reshuffle boundaries
        b = next(it)
        np.testing.assert_array_equal(b.y, ys[node, sched[k]])
        np.testing.assert_array_equal(b.x, xs[node, sched[k]])


def test_schedule_indices_cover_epochs():
    sched = batch_index_schedule(32, 4, 8, 8, seed=0)  # exactly 2 epochs
    assert sched.shape == (8, 4, 8)
    for node in range(4):
        for epoch in range(2):
            idx = sched[epoch * 4 : (epoch + 1) * 4, node].ravel()
            assert sorted(idx.tolist()) == list(range(32))  # full pass, no repeats


def test_sweep_matches_stacked_independent_runs(setup):
    """vmapped sweep axis ≡ the same runs executed independently."""
    xs, ys, test, loss_fn, opt, _ = setup
    eval_fn = make_eval_fn(loss_fn)
    rf = make_round_fn(loss_fn, opt, T.complete(N))
    # sweep over (gain, seed): different init scales and different init keys
    variants = [(1.0, 0), (2.5, 1)]
    states = [
        init_fl_state(
            jax.random.PRNGKey(s), N,
            lambda k, g=g: init_mlp(InitConfig("he_normal", g), k, hidden=(32,)), opt,
        )
        for g, s in variants
    ]
    common = dict(n_rounds=ROUNDS, eval_every=3, eval_fn=eval_fn, eval_batch=test, track_sigmas=True)
    swept, hists = run_sweep(stack_states(states), rf, xs, ys, _schedule(), **common)
    finals = unstack_states(swept)
    assert len(hists) == len(variants)
    for state, hist in zip(states, hists):
        s_ind, h_ind = run_trajectory(state, rf, xs, ys, _schedule(), **common)
        for a, b in zip(jax.tree_util.tree_leaves(s_ind), jax.tree_util.tree_leaves(finals.pop(0))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-7)
        assert hist["round"] == h_ind["round"]
        np.testing.assert_allclose(hist["train_loss"], h_ind["train_loss"], rtol=1e-5)
        np.testing.assert_allclose(hist["test_loss"], h_ind["test_loss"], rtol=1e-5)
        np.testing.assert_allclose(hist["sigma_an"], h_ind["sigma_an"], rtol=1e-4, atol=1e-9)


def test_sweep_per_run_schedules(setup):
    """schedule_per_run routes run i through schedule i — probed with
    IDENTICAL init states so only the schedule axis can cause divergence."""
    xs, ys, test, loss_fn, opt, init_one = setup
    rf = make_round_fn(loss_fn, opt, T.complete(N))
    state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    kw = dict(n_rounds=ROUNDS, eval_every=3, schedule_per_run=True)
    # control: same schedule for both runs → identical trajectories
    same = np.stack([_schedule(seed=0)] * 2)
    _, h_same = run_sweep([state, state], rf, xs, ys, same, **kw)
    assert h_same[0]["train_loss"] == h_same[1]["train_loss"]
    # distinct schedules → run 1 must diverge from run 0
    diff = np.stack([_schedule(seed=0), _schedule(seed=1)])
    _, h_diff = run_sweep([state, state], rf, xs, ys, diff, **kw)
    assert h_diff[0]["train_loss"] == h_same[0]["train_loss"]  # run 0 kept schedule 0
    assert h_diff[1]["train_loss"] != h_diff[0]["train_loss"]


def test_no_eval_history_is_empty(setup):
    xs, ys, test, loss_fn, opt, init_one = setup
    rf = make_round_fn(loss_fn, opt, T.complete(N))
    state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    _, hist = run_trajectory(state, rf, xs, ys, _schedule(), n_rounds=ROUNDS)
    assert hist["round"] == [] and hist["train_loss"] == []

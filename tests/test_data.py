import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.data import (
    make_token_stream,
    mnist_like,
    node_batch_iterator,
    node_datasets,
    partition_iid,
    partition_zipf,
    token_batch_iterator,
)


def test_partitions_disjoint_and_equal():
    parts = partition_iid(1000, 8, seed=0)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(set(all_idx.tolist()))  # D_i ∩ D_j = ∅ (§3)
    assert all(len(p) == 125 for p in parts)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 20))
def test_zipf_partition_skews_labels(seed):
    ds = mnist_like(4000, seed=seed)
    parts = partition_zipf(ds.y, 8, alpha=1.8, seed=seed)
    all_idx = np.concatenate(parts)
    assert len(all_idx) == len(set(all_idx.tolist()))
    # each node's top class should dominate: paper's non-iid regime
    fracs = []
    for p in parts:
        hist = np.bincount(ds.y[p], minlength=10)
        fracs.append(hist.max() / hist.sum())
    # iid would give ≈0.12; depletion-fallback dilutes late nodes, so the
    # ensemble mean is the robust statistic
    assert np.mean(fracs) > 0.25
    assert max(fracs) > 0.4


def test_iid_partition_balanced_labels():
    ds = mnist_like(4000, seed=1)
    parts = partition_iid(len(ds.y), 8, seed=1)
    hist = np.bincount(ds.y[parts[0]], minlength=10) / len(parts[0])
    assert hist.max() < 0.25


def test_batch_iterator_shapes_and_determinism():
    ds = mnist_like(512, seed=0)
    parts = partition_iid(512, 4, seed=0)
    xs, ys = node_datasets(ds, parts)
    it1 = node_batch_iterator(xs, ys, 16, seed=3)
    it2 = node_batch_iterator(xs, ys, 16, seed=3)
    b1, b2 = next(it1), next(it2)
    assert b1.x.shape == (4, 16, 28, 28, 1)
    assert np.array_equal(b1.y, b2.y)


def test_batch_iterator_epoch_reshuffle():
    ds = mnist_like(64, seed=0)
    parts = partition_iid(64, 2, seed=0)
    xs, ys = node_datasets(ds, parts)
    it = node_batch_iterator(xs, ys, 16, seed=0)
    for _ in range(10):  # crosses epoch boundaries without error
        b = next(it)
        assert b.y.shape == (2, 16)


def test_token_stream_structure_learnable():
    toks = make_token_stream(50_000, 256, seed=0)
    assert toks.min() >= 0 and toks.max() < 256
    # bigram entropy far below unigram entropy (structure exists)
    from collections import Counter
    uni = Counter(toks.tolist())
    pu = np.array(list(uni.values())) / len(toks)
    hu = -(pu * np.log(pu)).sum()
    big = Counter(zip(toks[:-1].tolist(), toks[1:].tolist()))
    pb = np.array(list(big.values())) / (len(toks) - 1)
    hb = -(pb * np.log(pb)).sum() - hu  # H(next|prev)
    assert hb < 0.75 * hu


def test_token_batches_are_shifted_targets():
    toks = np.stack([make_token_stream(2000, 64, seed=i) for i in range(2)])
    it = token_batch_iterator(toks, batch_size=4, seq_len=32, seed=0)
    b = next(it)
    assert b.x.shape == (2, 4, 32)
    assert np.array_equal(b.x[0, 0, 1:], b.y[0, 0, :-1])

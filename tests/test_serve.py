"""Live serving (DESIGN.md §19): batched prefill parity, router policies,
and the interleaved train+serve executor.

The load-bearing properties: (1) the one-prefill ``generate`` path emits
exactly the tokens of the old token-by-token reference loop; (2) the router
is a pure function of (inputs, key) so fixed seeds replay routing verbatim;
(3) staleness/latency bookkeeping matches a hand-computed event stream; and
(4) interleaving serve events into the gossip scan leaves the training
trajectory **bitwise** untouched — at qps = 0 the serve executor IS the
event executor, and under load the training params must not move.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ArchConfig
from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import init_fl_state, make_eval_fn, run_event_trajectory
from repro.fed.router import QueryStream, hop_matrix, make_router, poisson_query_stream
from repro.fed.serve import generate, generate_tokenwise, run_serve_trajectory, serve_summary
from repro.models import transformer as TF
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

MICRO = ArchConfig(
    name="micro",
    family="paper",
    source="test",
    n_layers=2,
    d_model=32,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=97,
    tie_embeddings=True,
    dtype="float32",
    rwkv_head_dim=16,
)


def _mlp_dfl(n=6, per_node=32, horizon=8.0, seed=0, test_size=64):
    graph = T.ring(n)
    ds = mnist_like(n * per_node + test_size, seed=seed)
    parts = [np.arange(i * per_node, (i + 1) * per_node) for i in range(n)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-test_size:], ds.y[-test_size:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", gain_from_graph(graph)), k, hidden=(16,))
    state = init_fl_state(jax.random.PRNGKey(seed), n, init_one, opt)
    plan = compile_plan(graph)
    stream = T.poisson_event_stream(graph, horizon=horizon, rate=1.0, seed=seed + 1)
    sched = batch_index_schedule(per_node, n, 8, int(horizon) * 2, seed=seed)
    return graph, state, plan, stream, sched, xs, ys, test, loss_fn, opt


def _tree_equal(a, b) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    return len(la) == len(lb) and all(np.array_equal(x, y) for x, y in zip(la, lb))


# ------------------------------------------------------------- generate parity
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_generate_prefill_matches_tokenwise(temperature):
    """One batched prefill + scanned decode must emit exactly the tokens of
    the old token-by-token loop (same key-split chain, greedy and sampled)."""
    params = TF.init_params(jax.random.PRNGKey(1), MICRO, InitConfig(gain=2.0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 0, MICRO.vocab_size)
    rng = jax.random.PRNGKey(7)
    fast = generate(params, MICRO, prompt, 6, 16, temperature=temperature, rng=rng)
    slow = generate_tokenwise(params, MICRO, prompt, 6, 16, temperature=temperature, rng=rng)
    assert fast.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))


@pytest.mark.slow
@pytest.mark.parametrize("pattern", [("swa",), ("mamba",), ("rwkv",), ("attn", "mamba")])
def test_generate_parity_across_block_kinds(pattern):
    cfg = dataclasses.replace(MICRO, block_pattern=pattern, sliding_window=4)
    params = TF.init_params(jax.random.PRNGKey(1), cfg, InitConfig(gain=2.0))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 6), 0, cfg.vocab_size)
    fast = generate(params, cfg, prompt, 5, 16, rng=jax.random.PRNGKey(3))
    slow = generate_tokenwise(params, cfg, prompt, 5, 16, rng=jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))


# ------------------------------------------------------------------ the router
def test_hop_matrix_ring_and_complete():
    n = 8
    hops = hop_matrix(T.ring(n))
    for i in range(n):
        for j in range(n):
            assert hops[i, j] == min(abs(i - j), n - abs(i - j))
    hk = hop_matrix(T.complete(5))
    assert np.array_equal(hk, np.ones((5, 5), np.int32) - np.eye(5, dtype=np.int32))


def test_hop_matrix_disconnected_pairs_are_penalised():
    # two disjoint edges: 0-1 and 2-3; cross-component distance must be n
    adj = np.zeros((4, 4), np.float32)
    adj[0, 1] = adj[1, 0] = adj[2, 3] = adj[3, 2] = 1.0
    hops = hop_matrix(T.Graph(adj, name="pairs"))
    assert hops[0, 1] == 1 and hops[2, 3] == 1
    assert hops[0, 2] == 4 and hops[1, 3] == 4


def test_query_stream_deterministic_padded_and_skewed():
    a = poisson_query_stream(8, 20.0, 3.0, seed=5)
    b = poisson_query_stream(8, 20.0, 3.0, seed=5)
    assert a.n_queries == b.n_queries
    assert np.array_equal(a.times, b.times) and np.array_equal(a.homes, b.homes)
    assert np.all(np.diff(a.times[: a.n_queries]) >= 0)
    padded = poisson_query_stream(8, 20.0, 3.0, seed=5, envelope=a.n_queries + 10)
    assert padded.envelope == a.n_queries + 10
    assert np.all(padded.homes[padded.n_queries :] == -1)
    assert np.all(padded.times[padded.n_queries :] == 20.0)
    hot = poisson_query_stream(64, 50.0, 20.0, seed=5, skew=2.0)
    cold = poisson_query_stream(64, 50.0, 20.0, seed=5, skew=0.0)
    assert hot.homes[: hot.n_queries].mean() < cold.homes[: cold.n_queries].mean()
    with pytest.raises(ValueError, match="envelope"):
        poisson_query_stream(8, 20.0, 3.0, seed=5, envelope=1)


def test_router_policies_route_sensibly():
    graph = T.ring(6)
    n = graph.n
    stale = jnp.asarray([5.0, 0.1, 5.0, 5.0, 5.0, 5.0])
    wait = jnp.zeros(n)
    key = jax.random.PRNGKey(0)
    local = make_router(graph, "local")
    assert int(local.route(jnp.int32(3), stale, wait, key)) == 3
    # consensus with negligible locality weight tracks freshness
    cons = make_router(graph, "consensus", locality_weight=1e-4)
    assert int(cons.route(jnp.int32(3), stale, wait, key)) == 1
    # a binding staleness budget masks the fresh-but-remote node out only
    # when a within-budget candidate exists; all-over-budget falls back
    tight = make_router(graph, "consensus", staleness_budget=1.0, locality_weight=1e-4)
    assert int(tight.route(jnp.int32(3), stale, wait, key)) == 1
    none_ok = make_router(graph, "consensus", staleness_budget=0.01, locality_weight=1e-4)
    assert int(none_ok.route(jnp.int32(3), stale, wait, key)) == 1
    uni = make_router(graph, "uniform")
    picks = {int(uni.route(jnp.int32(0), stale, wait, jax.random.PRNGKey(s))) for s in range(32)}
    assert len(picks) > 1 and all(0 <= p < n for p in picks)


# ------------------------------------------- hand-built staleness bookkeeping
def test_triangle_staleness_and_latency_bookkeeping():
    """K3 with two gossip events and three queries, local routing: every
    query lands 0.5 after its home node's last mix, unqueued, zero hops."""
    _, state, _, _, sched, xs, ys, test, loss_fn, opt = _mlp_dfl(n=3, horizon=3.0)
    graph = T.complete(3)
    plan = compile_plan(graph)
    # edge ids (row-major, i<j): 0 = (0,1), 1 = (0,2), 2 = (1,2)
    stream = T.EventStream(
        times=np.array([1.0, 2.0], np.float32),
        edges=np.array([0, 2], np.int32),
        n_events=2,
        horizon=3.0,
        rates=np.ones(3),
    )
    queries = QueryStream(
        times=np.array([0.5, 1.5, 2.5], np.float32),
        homes=np.array([1, 0, 2], np.int32),
        qidx=np.zeros(3, np.int32),
        n_queries=3,
        horizon=3.0,
        qps=1.0,
    )
    _, _, serve, _ = run_serve_trajectory(
        state,
        loss_fn,
        opt,
        plan,
        stream,
        queries,
        make_router(graph, "local"),
        xs,
        ys,
        sched,
        b_local=2,
        n_bins=3,
        service_time=0.05,
        hop_latency=0.02,
    )
    # t=0.5 home 1: clock still 0 → stale 0.5; t=1.5 home 0: edge (0,1)
    # fired at 1.0 → 0.5; t=2.5 home 2: edge (1,2) fired at 2.0 → 0.5
    np.testing.assert_array_equal(serve["node"], [1, 0, 2])
    np.testing.assert_allclose(serve["staleness"], [0.5, 0.5, 0.5], atol=1e-6)
    np.testing.assert_allclose(serve["latency"], [0.05, 0.05, 0.05], atol=1e-6)
    np.testing.assert_allclose(serve["hops"], [0.0, 0.0, 0.0], atol=1e-6)
    summ = serve_summary(serve)
    assert summ["served"] == 3 and abs(summ["p50_latency"] - 0.05) < 1e-6


def test_queueing_serialises_back_to_back_queries():
    """Two queries hitting one node within its service window: the second
    waits for the first's slot, so its latency carries the queue delay."""
    _, state, _, _, sched, xs, ys, test, loss_fn, opt = _mlp_dfl(n=3, horizon=3.0)
    graph = T.complete(3)
    stream = T.EventStream(
        times=np.array([2.9], np.float32),
        edges=np.array([0], np.int32),
        n_events=1,
        horizon=3.0,
        rates=np.ones(3),
    )
    queries = QueryStream(
        times=np.array([1.0, 1.1], np.float32),
        homes=np.array([0, 0], np.int32),
        qidx=np.zeros(2, np.int32),
        n_queries=2,
        horizon=3.0,
        qps=1.0,
    )
    _, _, serve, _ = run_serve_trajectory(
        state,
        loss_fn,
        opt,
        compile_plan(graph),
        stream,
        queries,
        make_router(graph, "local"),
        xs,
        ys,
        sched,
        b_local=2,
        n_bins=3,
        service_time=0.5,
        hop_latency=0.0,
    )
    # first: starts at 1.0, done 1.5 → latency 0.5; second arrives 1.1,
    # waits until 1.5, done 2.0 → latency 0.9
    np.testing.assert_allclose(serve["latency"], [0.5, 0.9], atol=1e-6)


# ------------------------------------------------- determinism and bit-parity
def test_routing_deterministic_under_fixed_seed():
    graph, state, plan, stream, sched, xs, ys, test, loss_fn, opt = _mlp_dfl()
    queries = poisson_query_stream(graph.n, stream.horizon, 4.0, seed=3)
    router = make_router(graph, "consensus")
    outs = [
        run_serve_trajectory(
            state,
            loss_fn,
            opt,
            plan,
            stream,
            queries,
            router,
            xs,
            ys,
            sched,
            b_local=2,
            n_bins=4,
        )
        for _ in range(2)
    ]
    (_, _, s1, _), (_, _, s2, _) = outs
    for k in ("node", "latency", "staleness", "hops"):
        np.testing.assert_array_equal(s1[k], s2[k])


def test_qps_zero_is_bitwise_the_event_executor():
    """With no queries the merged envelope is the gossip envelope under an
    identity permutation: params AND history must match run_event_trajectory
    bit for bit."""
    graph, state, plan, stream, sched, xs, ys, test, loss_fn, opt = _mlp_dfl()
    eval_fn = make_eval_fn(loss_fn)
    kw = dict(b_local=2, n_bins=4, eval_fn=eval_fn, eval_batch=test)
    ref_state, ref_hist, _ = run_event_trajectory(
        state, loss_fn, opt, plan, stream, xs, ys, sched, **kw
    )
    queries = poisson_query_stream(graph.n, stream.horizon, 0.0, seed=3)
    router = make_router(graph, "consensus")
    srv_state, srv_hist, serve, _ = run_serve_trajectory(
        state, loss_fn, opt, plan, stream, queries, router, xs, ys, sched, **kw
    )
    assert serve_summary(serve)["served"] == 0
    assert _tree_equal(ref_state.params, srv_state.params)
    for k in ("train_loss", "test_loss", "staleness", "messages"):
        np.testing.assert_array_equal(np.asarray(ref_hist[k]), np.asarray(srv_hist[k]))


def test_training_params_invariant_under_serve_load():
    """Serve events read params but never write them, and failure keys fold
    on the gossip ordinal — so any qps leaves training bitwise unchanged."""
    graph, state, plan, stream, sched, xs, ys, test, loss_fn, opt = _mlp_dfl()
    router = make_router(graph, "consensus")
    q0 = poisson_query_stream(graph.n, stream.horizon, 0.0, seed=3)
    q5 = poisson_query_stream(graph.n, stream.horizon, 5.0, seed=3)
    s0, _, srv0, _ = run_serve_trajectory(
        state, loss_fn, opt, plan, stream, q0, router, xs, ys, sched, b_local=2, n_bins=4
    )
    s5, _, srv5, _ = run_serve_trajectory(
        state, loss_fn, opt, plan, stream, q5, router, xs, ys, sched, b_local=2, n_bins=4
    )
    assert serve_summary(srv5)["served"] == q5.n_queries > 0
    assert _tree_equal(s0.params, s5.params)
    assert _tree_equal(s0.opt_state, s5.opt_state)


def test_serve_summary_empty_is_zeroed():
    empty = {k: np.zeros(0) for k in ("latency", "staleness", "hops")}
    summ = serve_summary(empty)
    assert summ["served"] == 0 and summ["p50_latency"] == 0.0

"""End-to-end DFL training behaviour (the paper's system claims)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import mnist_like, node_batch_iterator, node_datasets
from repro.fed import consensus_params, init_fl_state, make_eval_fn, make_round_fn, sigma_metrics, train_loop
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd


def _setup(n_nodes=8, per_node=64, hidden=(64, 32)):
    ds = mnist_like(n_nodes * per_node + 256, seed=0)
    parts = [np.arange(i * per_node, (i + 1) * per_node) for i in range(n_nodes)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-256:], ds.y[-256:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    return xs, ys, test, loss_fn, hidden


def _batches(xs, ys, b_local=2, bs=16, seed=0):
    it = node_batch_iterator(xs, ys, bs, seed=seed)
    while True:
        batches = [next(it) for _ in range(b_local)]
        yield (
            np.stack([b.x for b in batches], axis=1),
            np.stack([b.y for b in batches], axis=1),
        )


@pytest.mark.slow
def test_corrected_init_escapes_plateau_uncorrected_stalls():
    """The paper's Fig. 1 phenomenon — needs n and model large enough that
    the √n compression actually stalls the He baseline (n = 16, the paper's
    MLP widths)."""
    xs, ys, test, loss_fn, _ = _setup(n_nodes=16, per_node=128)
    hidden = (512, 256, 128)  # the paper's MLP
    g = T.complete(16)
    opt = sgd(1e-3, 0.5)
    eval_fn = make_eval_fn(loss_fn)
    results = {}
    for name, gain in [("plain", 1.0), ("corrected", gain_from_graph(g))]:
        init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k, hidden=hidden)
        state = init_fl_state(jax.random.PRNGKey(0), 16, init_one, opt)
        rf = make_round_fn(loss_fn, opt, g)
        state, hist = train_loop(state, rf, _batches(xs, ys, b_local=4), n_rounds=40, eval_every=39,
                                 eval_fn=eval_fn, eval_batch=test)
        results[name] = hist["test_loss"][-1]
    # plain He sits on the log(10) ≈ 2.303 plateau; corrected escapes it
    assert results["plain"] > 2.25
    assert results["corrected"] < results["plain"] - 0.5


def test_sigma_dynamics_match_theory():
    """σ_an collapses fast; σ_ap → σ_init‖v_steady‖ (paper Fig. 3b)."""
    xs, ys, test, loss_fn, hidden = _setup()
    g = T.complete(8)
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 1.0), k, hidden=hidden)
    state = init_fl_state(jax.random.PRNGKey(1), 8, init_one, opt)
    s0 = sigma_metrics(state.params)
    rf = make_round_fn(loss_fn, opt, g)
    state, _ = train_loop(state, rf, _batches(xs, ys), n_rounds=10)
    s1 = sigma_metrics(state.params)
    # complete graph: one round is full consensus → σ_an collapses by >10x
    assert float(s1["sigma_an"]) < float(s0["sigma_an"]) / 10
    # σ_ap compressed toward ‖v_steady‖ = 1/√8 of its start
    ratio = float(s1["sigma_ap"]) / float(s0["sigma_ap"])
    assert 0.25 < ratio < 0.55  # 1/√8 ≈ 0.354 ± training drift


def test_failures_still_learn():
    """Fig. 2: p = 0.5 link failures slow but do not break training."""
    xs, ys, test, loss_fn, hidden = _setup()
    g = T.complete(8)
    opt = sgd(1e-3, 0.5)
    eval_fn = make_eval_fn(loss_fn)
    init_one = lambda k: init_mlp(InitConfig("he_normal", gain_from_graph(g)), k, hidden=hidden)
    state = init_fl_state(jax.random.PRNGKey(2), 8, init_one, opt)
    rf = make_round_fn(loss_fn, opt, g, link_p=0.5)
    state, hist = train_loop(state, rf, _batches(xs, ys), n_rounds=30, eval_every=29,
                             eval_fn=eval_fn, eval_batch=test)
    first, last = hist["test_loss"][0], hist["test_loss"][-1]
    assert last < first - 0.1


def test_isolated_nodes_when_node_p_zero():
    """node_p→0: no aggregation happens; models stay distinct."""
    xs, ys, test, loss_fn, hidden = _setup()
    g = T.complete(8)
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 1.0), k, hidden=hidden)
    state = init_fl_state(jax.random.PRNGKey(3), 8, init_one, opt)
    rf = make_round_fn(loss_fn, opt, g, node_p=1e-9)
    state2, _ = train_loop(state, rf, _batches(xs, ys), n_rounds=3)
    s = sigma_metrics(state2.params)
    assert float(s["sigma_an"]) > 0.01  # no consensus formed


def test_consensus_params_average():
    params = {"w": jnp.stack([jnp.full((3,), float(i)) for i in range(4)])}
    c = consensus_params(params)
    assert np.allclose(c["w"], 1.5)
    cw = consensus_params(params, weights=jnp.asarray([1.0, 0, 0, 1.0]))
    assert np.allclose(cw["w"], 1.5)


def test_decentralised_matches_fedavg_on_complete_graph():
    """§3: DecAvg on a complete graph ≡ centralised FedAvg."""
    xs, ys, test, loss_fn, hidden = _setup(n_nodes=4)
    g = T.complete(4)
    opt = sgd(1e-2, 0.0)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 2.0), k, hidden=hidden)
    state = init_fl_state(jax.random.PRNGKey(5), 4, init_one, opt)
    rf = make_round_fn(loss_fn, opt, g)
    batches = _batches(xs, ys, b_local=1)
    state, _ = train_loop(state, rf, batches, n_rounds=2)
    # after any round all nodes are identical (complete graph, equal data)
    w = state.params["fc0"]["w"]
    assert np.allclose(w[0], w[1], atol=1e-5)
    assert np.allclose(w[0], w[3], atol=1e-5)

import numpy as np
import pytest

from repro.core import topology as T


@pytest.mark.parametrize(
    "build,expected_degree",
    [
        (lambda: T.complete(16), 15),
        (lambda: T.ring(12), 2),
        (lambda: T.circulant(16, (1, 2)), 4),
        (lambda: T.random_k_regular(32, 6, seed=0), 6),
        (lambda: T.torus_lattice((4, 4)), 4),
        (lambda: T.torus_lattice((3, 3, 3)), 6),
    ],
)
def test_regular_families_have_exact_degree(build, expected_degree):
    g = build()
    assert g.is_connected()
    assert np.all(g.degrees == expected_degree)


def test_adjacency_is_symmetric_zero_diagonal():
    for g in [T.erdos_renyi_gnp(64, 0.1, seed=1), T.barabasi_albert(64, 3, seed=1)]:
        a = g.adjacency
        assert np.allclose(a, a.T)
        assert np.all(np.diag(a) == 0)


def test_erdos_renyi_gnm_edge_count():
    g = T.erdos_renyi_gnm(50, 120, seed=3)
    assert g.n_edges == 120


def test_barabasi_albert_mean_degree():
    # BA(m): mean degree → 2m for large n
    g = T.barabasi_albert(512, 4, seed=0)
    assert abs(g.mean_degree - 8) < 0.5
    # heavy tail: max degree far above mean
    assert g.degrees.max() > 4 * g.mean_degree


def test_configuration_heavy_tail_connected_and_powerlawish():
    g = T.configuration_heavy_tail(256, 2.3, seed=0)
    assert g.is_connected()
    # erased configuration model: multi-edge/self-loop removal can shave a
    # degree point off a few nodes — min k_min-1 is acceptable
    assert g.degrees.min() >= 1
    assert g.degrees.max() > 3 * g.mean_degree


def test_star_matches_centralised_topology():
    g = T.star(10)
    assert g.degrees[0] == 9
    assert np.all(g.degrees[1:] == 1)


def test_seeded_determinism():
    a1 = T.erdos_renyi_gnp(40, 0.15, seed=7).adjacency
    a2 = T.erdos_renyi_gnp(40, 0.15, seed=7).adjacency
    assert np.array_equal(a1, a2)


def test_disconnected_rejected_or_flagged():
    # p far below the connectivity threshold should raise after retries
    with pytest.raises(RuntimeError):
        T.erdos_renyi_gnp(200, 0.001, seed=0)

"""Use real hypothesis when installed; degrade to a deterministic sampler when not.

The dev environment (``pip install -e .[dev]``, see pyproject.toml) gets the
real library.  Hermetic containers without it still COLLECT and RUN every
property test: the fallback draws ``max_examples`` pseudo-random examples
from each strategy with a fixed seed — strictly weaker than hypothesis (no
shrinking, no example database) but the same assertions on the same
distributions, and deterministic across runs.

Test modules import from here instead of from ``hypothesis`` directly::

    from _hypothesis_compat import given, settings, strategies as st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 10)
                for i in range(n):
                    rng = np.random.default_rng(0xDF1 + i)
                    drawn = {k: s.example(rng) for k, s in strats.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except Exception as e:  # surface the failing example
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{n}): {drawn!r}"
                        ) from e

            # hide the strategy-bound params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(
                parameters=[p for k, p in sig.parameters.items() if k not in strats]
            )
            return wrapper

        return deco

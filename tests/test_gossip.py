import numpy as np

from repro.core import gossip as G
from repro.core import mixing as M
from repro.core import topology as T


def test_push_sum_reaches_average():
    g = T.random_k_regular(32, 4, seed=0)
    vals = np.arange(32, dtype=float)
    avg = G.push_sum(g, vals, rounds=300)
    assert np.allclose(avg, vals.mean(), rtol=1e-6)


def test_size_estimation_every_node():
    g = T.erdos_renyi_gnp(48, 0.15, seed=1)
    est = G.estimate_size(g, rounds=400)
    assert np.allclose(est, 48, rtol=1e-6)


def test_mean_degree_estimation():
    g = T.barabasi_albert(64, 3, seed=2)
    est = G.estimate_mean_degree(g, rounds=400)
    assert np.allclose(est, g.mean_degree, rtol=1e-6)


def test_degree_polling_bias_correction():
    """Uncorrected walks oversample hubs (q(k) bias); corrected ≈ p(k)."""
    g = T.configuration_heavy_tail(256, 2.2, seed=3)
    raw = G.poll_degrees(g, start=0, walk_length=15, n_walks=600, seed=0, correct_bias=False)
    fixed = G.poll_degrees(g, start=0, walk_length=15, n_walks=600, seed=0, correct_bias=True)
    true_mean = g.degrees.mean()
    assert raw.mean() > true_mean  # hub bias
    assert abs(fixed.mean() - true_mean) < abs(raw.mean() - true_mean)


def test_gossip_to_gain_pipeline():
    """§4.4 end-to-end: estimate n + poll degrees → ‖v_steady‖ within 20%."""
    g = T.barabasi_albert(128, 4, seed=4)
    n_est = float(G.estimate_size(g, rounds=300)[5])
    sample = G.poll_degrees(g, start=5, walk_length=20, n_walks=500, seed=5)
    est = M.v_steady_norm_from_degree_sample(sample, int(round(n_est)))
    assert abs(est - M.v_steady_norm(g)) / M.v_steady_norm(g) < 0.2

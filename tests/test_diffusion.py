import numpy as np

from repro.core import diffusion as D
from repro.core import mixing as M
from repro.core import topology as T


def test_sigma_ap_approaches_prediction_regular():
    """§4.3: lim σ_ap = σ_init‖v_steady‖ = σ_init/√n for k-regular."""
    g = T.random_k_regular(256, 32, seed=0)
    res = D.run_diffusion(g, d=512, sigma_init=1.0, sigma_noise=1e-5, rounds=120, seed=0)
    assert np.isclose(res.sigma_ap[-1], res.sigma_ap_prediction, rtol=0.05)
    assert np.isclose(res.sigma_ap_prediction, 1.0 / np.sqrt(256), rtol=1e-6)


def test_sigma_an_decays_to_noise_floor():
    g = T.random_k_regular(128, 16, seed=1)
    noise = 1e-3
    res = D.run_diffusion(g, d=256, sigma_noise=noise, rounds=150, seed=1)
    assert res.sigma_an[0] > 0.9  # starts at σ_init
    assert res.sigma_an[-1] < 10 * noise  # ends near the noise floor


def test_heterogeneous_graph_compresses_less():
    """BA keeps more within-node variance than k-regular (‖v‖ larger)."""
    ba = T.barabasi_albert(256, 4, seed=0)
    kreg = T.random_k_regular(256, 8, seed=0)
    r_ba = D.run_diffusion(ba, d=256, sigma_noise=1e-5, rounds=150)
    r_kreg = D.run_diffusion(kreg, d=256, sigma_noise=1e-5, rounds=150)
    assert r_ba.sigma_ap[-1] > r_kreg.sigma_ap[-1]


def test_stabilisation_faster_on_expander_than_ring():
    """§4.5: mixing-time ordering shows up in the σ_an trajectory."""
    n = 64
    def rounds_to_stabilise(g):
        res = D.run_diffusion(g, d=128, sigma_noise=1e-4, rounds=400, seed=0)
        target = res.sigma_an[-1] * 2
        return int(np.argmax(res.sigma_an < target))

    assert rounds_to_stabilise(T.random_k_regular(n, 8, seed=0)) < rounds_to_stabilise(T.ring(n))


def test_noise_free_diffusion_matches_markov_power():
    """W_t = W_0 A'^t exactly when σ_noise = 0 (§4.3).

    d must be large enough that the sample std over a node's d parameters
    concentrates: the prediction is an expectation, and at d=64 its sampling
    noise (~1/√(2d) ≈ 9%) exceeds the tolerance — the seed suite's failure.
    """
    g = T.random_k_regular(32, 4, seed=2)
    res = D.run_diffusion(g, d=1024, sigma_noise=0.0, rounds=50, seed=2)
    m = M.receive_matrix(g)
    # closed-form σ_ap after t rounds ≈ σ_init ‖rows of M^t‖ ... check the limit
    assert np.isclose(res.sigma_ap[-1], res.sigma_ap_prediction, rtol=0.08)

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, strategies as st

from repro.core import decavg as D
from repro.core import mixing as M
from repro.core import topology as T


def test_mix_pytree_matches_per_leaf_einsum():
    g = T.random_k_regular(8, 4, seed=0)
    m = jnp.asarray(M.receive_matrix(g), jnp.float32)
    params = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (8, 5, 3)),
        "b": {"w": jax.random.normal(jax.random.PRNGKey(1), (8, 7))},
    }
    mixed = D.mix_pytree(m, params)
    want_a = jnp.einsum("ij,jkl->ikl", m, params["a"])
    assert np.allclose(mixed["a"], want_a, atol=1e-6)


def test_consensus_is_fixed_point():
    g = T.complete(6)
    m = jnp.asarray(M.receive_matrix(g), jnp.float32)
    w = jnp.broadcast_to(jnp.arange(4.0), (6, 4))
    assert np.allclose(D.mix_array(m, w), w, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 30))
def test_mixing_contracts_cross_node_variance(seed):
    """One DecAvg round never increases σ_an (averaging is a contraction)."""
    g = T.random_k_regular(16, 4, seed=seed)
    m = jnp.asarray(M.receive_matrix(g), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(seed), (16, 64))
    w2 = D.mix_array(m, w)
    assert float(jnp.std(w2, axis=0).mean()) <= float(jnp.std(w, axis=0).mean()) + 1e-6


def test_complete_graph_single_round_consensus():
    """On a complete graph DecAvg averages everything in one round (= FedAvg)."""
    g = T.complete(10)
    m = jnp.asarray(M.receive_matrix(g), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(0), (10, 32))
    w2 = D.mix_array(m, w)
    assert np.allclose(w2, w.mean(axis=0, keepdims=True), atol=1e-5)


def test_failure_receive_matrix_isolated_node_keeps_params():
    g = T.ring(5)
    # all links down → every node keeps exactly its own params
    a = jnp.zeros((5, 5))
    m = D.failure_receive_matrix(a)
    assert np.allclose(m, np.eye(5))


def test_link_failure_mask_statistics():
    g = T.complete(32)
    key = jax.random.PRNGKey(0)
    kept = D.link_failure_mask(key, g, p=0.25)
    frac = float(kept.sum() / g.adjacency.sum())
    assert 0.18 < frac < 0.32
    assert np.allclose(np.asarray(kept), np.asarray(kept).T)


def test_node_failure_mask_removes_rows_and_cols():
    g = T.complete(16)
    a = D.node_failure_mask(jax.random.PRNGKey(1), g, p=0.5)
    a = np.asarray(a)
    inactive = np.nonzero(a.sum(1) == 0)[0]
    assert len(inactive) > 0
    assert np.all(a[:, inactive] == 0)


def test_data_weighted_receive_matrix_matches_eq2():
    """β_i = |D_i| / (|D_i| + Σ_j |D_j|) exactly (paper Eq. 2)."""
    g = T.ring(4)
    sizes = np.array([10.0, 20.0, 30.0, 40.0])
    m = np.asarray(D.failure_receive_matrix(jnp.asarray(g.adjacency), jnp.asarray(sizes)))
    # node 0's neighbours on the ring are 1 and 3
    denom = 10 + 20 + 40
    assert np.isclose(m[0, 0], 10 / denom)
    assert np.isclose(m[0, 1], 20 / denom)
    assert np.isclose(m[0, 3], 40 / denom)
    assert m[0, 2] == 0

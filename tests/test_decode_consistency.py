"""Prefill vs incremental decode must agree (KV caches, ring buffers,
recurrent states).  MoE archs use a raised capacity factor so no tokens are
dropped (capacity dropping is the one legitimate prefill/decode divergence)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced_config
from repro.core.initialisation import InitConfig
from repro.models import transformer as TF

CASES = ["gemma3_4b", "jamba_1p5_large_398b", "rwkv6_3b", "qwen2p5_3b", "granite_moe_1b_a400m", "musicgen_large"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", CASES)
def test_decode_matches_prefill(arch):
    cfg = dataclasses.replace(get_reduced_config(arch), capacity_factor=8.0)
    params = TF.init_params(jax.random.PRNGKey(1), cfg, InitConfig(gain=2.0))
    b, s = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    hidden, _ = TF.forward(params, cfg, toks, None, remat=False)
    logits_pre = TF.hidden_to_logits(params, cfg, hidden)

    cache = TF.init_cache(cfg, (b,), cache_len=64)
    outs = []
    for t in range(s):
        lg, cache = TF.decode_step(params, cfg, cache, toks[:, t : t + 1], jnp.asarray(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(logits_pre - logits_dec).max() / (jnp.abs(logits_pre).max() + 1e-9))
    assert err < 5e-4, err


@pytest.mark.slow
def test_swa_ring_buffer_beyond_window():
    """Decode past the sliding window: ring buffer must evict correctly."""
    cfg = get_reduced_config("gemma3_4b")  # window 16
    params = TF.init_params(jax.random.PRNGKey(0), cfg, InitConfig(gain=2.0))
    b, s = 1, 40  # > 2× window
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    hidden, _ = TF.forward(params, cfg, toks, None, remat=False)
    logits_pre = TF.hidden_to_logits(params, cfg, hidden)
    # cache_len larger than window: swa layers still clamp to window slots
    cache = TF.init_cache(cfg, (b,), cache_len=64)
    outs = []
    for t in range(s):
        lg, cache = TF.decode_step(params, cfg, cache, toks[:, t : t + 1], jnp.asarray(t))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(logits_pre - logits_dec).max() / jnp.abs(logits_pre).max())
    assert err < 5e-4, err


def test_decode_cache_smaller_than_context_for_swa():
    cfg = get_reduced_config("gemma3_4b")
    cache = TF.init_cache(cfg, (1,), cache_len=64)
    # layer 0 is swa → ring buffer of window size; layer 1 attn → full
    swa_cache, full_cache = cache["stack"][0], cache["stack"][1]
    assert swa_cache["k"].shape[-3] == cfg.sliding_window
    assert full_cache["k"].shape[-3] == 64

"""Elastic membership + fault injection (DESIGN.md §16).

The membership masks ride the ``active=`` / ``edge_live=`` channel of the
CommPlan operators: a masked-out node renormalises to the identity row, so
every backend must match the same numpy reference (``effective_send_matrix``
/ ``min_spread_reference``) that already anchors the Bernoulli failure
draws — masks and failures are one algebra.  The elastic executor's
zero-event path must be bit-identical to the static executor (the K = 1
contract applied to the node axis), and the join protocol must land a
usable n̂ at init time.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import gossip as G
from repro.core import topology as T
from repro.core.commplan import BACKENDS, FailureModel, compile_plan, compile_schedule, cyclic_map
from repro.core.faults import compose, crash_burst, hub_outage, no_faults, partition, scenario
from repro.core.initialisation import InitConfig
from repro.core.membership import MembershipSchedule, membership_schedule, poisson_membership
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, run_elastic_trajectory, run_trajectory
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

N = 12


def _masks(seed=0):
    rng = np.random.default_rng(seed)
    g = T.barabasi_albert(N, 3, seed=1)
    act = rng.random(N) < 0.7
    act[:2] = True  # keep at least two live nodes
    el = rng.random(g.n_edges) < 0.6
    return g, act, el


# ------------------------------------------------ operator mask parity
@pytest.mark.parametrize("backend", BACKENDS)
def test_mix_active_mask_matches_reference(backend):
    g, act, el = _masks()
    plan = compile_plan(g, backend)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (N, 5)))
    ref = G.effective_send_matrix(g, el, act).T @ x
    out = np.asarray(plan.mix({"w": jnp.asarray(x)}, active=jnp.asarray(act),
                              edge_live=jnp.asarray(el))["w"])
    np.testing.assert_allclose(out, ref, atol=1e-5)
    # inactive nodes are identity rows: they keep their own params exactly
    np.testing.assert_array_equal(out[~act], x[~act])


@pytest.mark.parametrize("backend", BACKENDS)
def test_spread_mask_conserves_mass(backend):
    g, act, el = _masks(3)
    plan = compile_plan(g, backend)
    x = np.abs(np.asarray(jax.random.normal(jax.random.PRNGKey(1), (N, 4)))) + 0.1
    ref = G.effective_send_matrix(g, el, act) @ x
    out = np.asarray(plan.spread(jnp.asarray(x), active=jnp.asarray(act),
                                 edge_live=jnp.asarray(el)))
    np.testing.assert_allclose(out, ref, atol=1e-5)
    # push-sum invariant: the masked send operator is column-stochastic
    np.testing.assert_allclose(out.sum(0), x.sum(0), rtol=1e-5)


@pytest.mark.parametrize("backend", BACKENDS)
def test_spread_min_mask_matches_reference(backend):
    g, act, el = _masks(7)
    plan = compile_plan(g, backend)
    x = np.asarray(jax.random.exponential(jax.random.PRNGKey(2), (N, 6)))
    ref = G.min_spread_reference(g, x, el, act)
    out = np.asarray(plan.spread_min(jnp.asarray(x), active=jnp.asarray(act),
                                     edge_live=jnp.asarray(el)))
    np.testing.assert_allclose(out, ref, rtol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_masks_compose_with_bernoulli_failures(backend):
    """active/edge_live AND into the same draw the failure model makes —
    host replay through round_masks composes identically."""
    g, act, el = _masks(11)
    plan = compile_plan(g, backend, failures=FailureModel(link_p=0.6, node_p=0.9))
    key = jax.random.PRNGKey(5)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (N, 5)))
    ek, na = plan.round_masks(key)
    ref = G.effective_send_matrix(
        g, np.asarray(ek)[: g.n_edges] & el, np.asarray(na) & act
    ).T @ x
    out = np.asarray(plan.mix({"w": jnp.asarray(x)}, key, active=jnp.asarray(act),
                              edge_live=jnp.asarray(el))["w"])
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_schedule_mask_passthrough():
    graphs = T.churn_sequence(T.barabasi_albert(N, 3, seed=1), 2, 0.3, seed=2)
    sch = compile_schedule(graphs, "dense", round_map=cyclic_map(1))
    rng = np.random.default_rng(0)
    act = rng.random(N) < 0.7
    act[:2] = True
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (N, 3)))
    for r, g in enumerate(graphs):
        el = rng.random(sch.n_edges_env) < 0.6
        ref = G.effective_send_matrix(g, el[: g.n_edges], act).T @ x
        out = np.asarray(sch.mix({"w": jnp.asarray(x)}, r, active=jnp.asarray(act),
                                 edge_live=jnp.asarray(el))["w"])
        np.testing.assert_allclose(out, ref, atol=1e-5)


# ------------------------------------------------ membership lowering
def test_membership_schedule_lowering():
    m = membership_schedule(8, 40, initial=6, arrivals={10: [6, 7]}, join_warmup=5)
    assert not m.trivial
    # arrival: gossip from round 10, one-shot join flag, init + train at 15
    assert not m.gossip[9, 6] and m.gossip[10:, 6].all()
    assert m.joins[10, 6] and m.joins.sum() == 2
    assert m.inits[15, 7] and m.inits.sum() == 2
    assert not m.active[14, 6] and m.active[15:, 6].all()
    np.testing.assert_array_equal(m.n_active(), [6] * 15 + [8] * 25)


def test_membership_departure_and_rearrival():
    m = membership_schedule(6, 30, departures={5: [2]}, arrivals={12: [2]}, join_warmup=4)
    assert m.active[:5, 2].all() and not m.active[5:16, 2].any()
    assert m.gossip[12:, 2].all() and m.joins[12, 2] and m.inits[16, 2]
    assert m.active[16:, 2].all()
    # arriving while already a member is a schedule bug
    with pytest.raises(ValueError, match="already a member"):
        membership_schedule(6, 30, arrivals={3: [1]})


def test_membership_invariants_and_late_arrival():
    # a too-late arrival gossips but never trains (clipped to the horizon)
    m = membership_schedule(4, 10, initial=3, arrivals={8: [3]}, join_warmup=8)
    assert m.gossip[8:, 3].all() and not m.active[:, 3].any() and not m.inits.any()
    with pytest.raises(ValueError, match="active nodes must gossip"):
        MembershipSchedule(
            n=2, n_rounds=2,
            active=np.ones((2, 2), bool), gossip=np.zeros((2, 2), bool),
            joins=np.zeros((2, 2), bool), inits=np.zeros((2, 2), bool),
        )


def test_poisson_membership_seeded_and_floored():
    a = poisson_membership(16, 80, initial=10, arrival_rate=0.3,
                           departure_rate=0.05, min_active=3, seed=4)
    b = poisson_membership(16, 80, initial=10, arrival_rate=0.3,
                           departure_rate=0.05, min_active=3, seed=4)
    np.testing.assert_array_equal(a.active, b.active)
    np.testing.assert_array_equal(a.joins, b.joins)
    assert (a.gossip.sum(axis=1) >= 3).all()
    assert a.joins.any()  # churn actually happened


# ------------------------------------------------ fault plans
def test_fault_plans_deterministic_and_composed():
    g = T.barabasi_albert(32, 3, seed=0)
    f1 = scenario("crash", g, 60, seed=9)
    f2 = scenario("crash", g, 60, seed=9)
    np.testing.assert_array_equal(f1.node_up, f2.node_up)
    assert not f1.trivial and no_faults(g, 60).trivial

    hub = hub_outage(g, 60, at=10, duration=5, k=2)
    hubs = np.argsort(-g.degrees, kind="stable")[:2]
    assert not hub.node_up[10:15, hubs].any() and hub.node_up[15:].all()

    part = partition(g, 60, at=20, duration=4, seed=1)
    edges = g.edge_list()
    cut = ~part.edge_up[20]
    assert cut.any() and part.node_up.all() and part.edge_up[24:].all()
    # only cross-edges of one balanced cut go down
    side = np.zeros(32, bool)
    side[np.random.default_rng(1).choice(32, size=16, replace=False)] = True
    np.testing.assert_array_equal(cut, side[edges[:, 0]] != side[edges[:, 1]])

    both = compose(hub, part)
    np.testing.assert_array_equal(both.node_up, hub.node_up)
    np.testing.assert_array_equal(both.edge_up, part.edge_up)


# ------------------------------------------------ elastic executor
NN, PER, BS, BL, R = 6, 32, 8, 2, 12


@pytest.fixture(scope="module")
def setup():
    ds = mnist_like(NN * PER + 64, seed=0)
    parts = [np.arange(i * PER, (i + 1) * PER) for i in range(NN)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-64:], ds.y[-64:])
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    icfg = InitConfig("he_normal", 2.0)
    init_one = lambda k: init_mlp(icfg, k, hidden=(16,))
    init_one_g = lambda k, gn: init_mlp(icfg.replace(gain=gn), k, hidden=(16,))
    sched = batch_index_schedule(PER, NN, BS, R * BL, seed=0)
    return xs, ys, test, loss_fn, opt, init_one, init_one_g, sched


def test_elastic_zero_event_bit_parity(setup):
    """A membership with no dynamics IS the static executor, bit for bit."""
    xs, ys, test, loss_fn, opt, init_one, _, sched = setup
    plan = compile_plan(T.ring(NN))
    common = dict(n_rounds=R, eval_every=4, eval_fn=make_eval_fn(loss_fn), eval_batch=test)
    rf = make_round_fn(loss_fn, opt, plan)
    s_ref = init_fl_state(jax.random.PRNGKey(0), NN, init_one, opt)
    s_ref, h_ref = run_trajectory(s_ref, rf, xs, ys, sched, **common)
    s_el = init_fl_state(jax.random.PRNGKey(0), NN, init_one, opt)
    s_el, h_el, aux = run_elastic_trajectory(
        s_el, loss_fn, opt, plan, membership_schedule(NN, R), xs, ys, sched, **common
    )
    for a, b in zip(jax.tree_util.tree_leaves(s_ref), jax.tree_util.tree_leaves(s_el)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h_el["train_loss"] == h_ref["train_loss"]
    assert h_el["test_loss"] == h_ref["test_loss"]
    assert h_el["n_active"] == [NN] * len(h_ref["round"])


def test_elastic_join_flow_initialises_with_online_n_hat(setup):
    """Two nodes arrive mid-run, sketch n̂ online, and enter training after
    warmup; their params change from the pre-join frozen state and the final
    sketches estimate n to sketch noise."""
    xs, ys, test, loss_fn, opt, init_one, init_one_g, sched = setup
    plan = compile_plan(T.ring(NN))
    mem = membership_schedule(NN, R, initial=NN - 2, arrivals={2: [NN - 2, NN - 1]},
                              join_warmup=4)
    state = init_fl_state(jax.random.PRNGKey(1), NN, init_one, opt)
    before = np.asarray(jax.tree_util.tree_leaves(state.params)[0][NN - 1]).copy()
    final, hist, aux = run_elastic_trajectory(
        state, loss_fn, opt, plan, mem, xs, ys, sched,
        n_rounds=R, eval_every=4, eval_fn=make_eval_fn(loss_fn), eval_batch=test,
        init_one=init_one_g, n_sketches=128,
    )
    after = np.asarray(jax.tree_util.tree_leaves(final.params)[0][NN - 1])
    assert np.abs(after - before).max() > 1e-6  # joiner re-initialised + trained
    assert hist["n_active"][0] == NN - 2 and hist["n_active"][-1] == NN
    # leaderless sketches see every gossiping node: n̂ ≈ n at m=128 noise
    assert abs(aux["n_hat"].mean() - NN) / NN < 0.5
    assert np.isfinite(hist["train_loss"]).all()


def test_elastic_fault_masks_freeze_victims(setup):
    """A crash burst freezes the victims' params for its window and drops
    them from the per-round active count."""
    xs, ys, test, loss_fn, opt, init_one, _, sched = setup
    g = T.ring(NN)
    plan = compile_plan(g)
    faults = crash_burst(g, R, at=1, size=2, duration=R, seed=0)
    victims = np.nonzero(~faults.node_up[1])[0]
    state = init_fl_state(jax.random.PRNGKey(2), NN, init_one, opt)
    final, hist, _ = run_elastic_trajectory(
        state, loss_fn, opt, plan, membership_schedule(NN, R), xs, ys, sched,
        n_rounds=R, eval_every=1, faults=faults,
    )
    assert hist["n_active"][0] == NN and set(hist["n_active"][1:]) == {NN - 2}
    # the victims took exactly one round of updates, then froze; compare
    # against a one-round run forced down the same inline masked path (a
    # join flag makes the membership non-trivial without touching params)
    ones = np.ones((1, NN), bool)
    joins = np.zeros((1, NN), bool)
    joins[0, 0] = True
    mem1 = MembershipSchedule(n=NN, n_rounds=1, active=ones, gossip=ones,
                              joins=joins, inits=np.zeros((1, NN), bool))
    one_round = run_elastic_trajectory(
        init_fl_state(jax.random.PRNGKey(2), NN, init_one, opt),
        loss_fn, opt, plan, mem1, xs, ys, sched[:BL],
        n_rounds=1, eval_every=1, b_local=BL,
    )[0]
    for a, b in zip(jax.tree_util.tree_leaves(final.params),
                    jax.tree_util.tree_leaves(one_round.params)):
        np.testing.assert_array_equal(np.asarray(a)[victims], np.asarray(b)[victims])

"""Sharding-rule unit tests (no big mesh needed: specs are pure data)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.core.initialisation import InitConfig
from repro.launch import shardings as SH
from repro.models import transformer as TF


class FakeMesh:
    """Only .shape / .axis_names are consulted by the rule code."""

    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


MESH = FakeMesh()


def _abstract_params(arch):
    cfg = get_config(arch)
    return cfg, jax.eval_shape(
        lambda k: TF.init_params(k, cfg, InitConfig(gain=1.0)), jax.random.PRNGKey(0)
    )


@pytest.mark.parametrize("arch", list_archs())
def test_specs_are_valid_for_every_leaf(arch):
    cfg, params = _abstract_params(arch)
    specs = SH.param_pspecs(params, cfg, MESH)
    leaves = jax.tree_util.tree_leaves_with_path(params)
    spec_leaves = {
        jax.tree_util.keystr(p): s
        for p, s in jax.tree_util.tree_leaves_with_path(specs, is_leaf=lambda x: isinstance(x, P))
    }
    for path, leaf in leaves:
        spec = spec_leaves[jax.tree_util.keystr(path)]
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                continue
            size = np.prod([MESH.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
            assert dim % size == 0, (jax.tree_util.keystr(path), spec, leaf.shape)


@pytest.mark.parametrize("arch", ["gemma3_4b", "granite_moe_1b_a400m", "qwen1p5_4b"])
def test_key_tensors_are_model_sharded(arch):
    cfg, params = _abstract_params(arch)
    specs = SH.param_pspecs(params, cfg, MESH)
    flat = {
        jax.tree_util.keystr(p): s
        for p, s in jax.tree_util.tree_leaves_with_path(specs, is_leaf=lambda x: isinstance(x, P))
    }
    # at least the FFN weights must be model-sharded for every arch
    # (MoE routers are intentionally replicated — exclude them)
    ffn_specs = [
        s for k, s in flat.items() if "ffn" in k and k.endswith("['w']") and "router" not in k
    ]
    assert ffn_specs and all("model" in str(s) for s in ffn_specs), ffn_specs


def test_granite_embedding_shards_on_dmodel():
    """vocab 49155 is indivisible by 16 → shard d_model instead."""
    cfg, params = _abstract_params("granite_moe_1b_a400m")
    specs = SH.param_pspecs(params, cfg, MESH)
    assert tuple(specs["embed"]["tok"]["w"]) == (None, "model")


def test_gemma_embedding_shards_on_vocab():
    cfg, params = _abstract_params("gemma3_4b")
    specs = SH.param_pspecs(params, cfg, MESH)
    assert tuple(specs["embed"]["tok"]["w"]) == ("model", None)


def test_moe_experts_shard_on_expert_axis():
    cfg, params = _abstract_params("granite_moe_1b_a400m")
    specs = SH.param_pspecs(params, cfg, MESH)
    moe_spec = specs["stack"][0]["ffn"]["w_in"]["w"]
    assert tuple(moe_spec) == (None, "model", None, None)  # (period, E, D, F)


def test_node_axis_prefix():
    cfg, params = _abstract_params("qwen2p5_3b")
    specs = SH.param_pspecs(params, cfg, MESH)
    with_node = SH.with_node_axis(specs, ("data",))
    assert tuple(with_node["embed"]["tok"]["w"])[0] == "data"
    with_pod = SH.with_node_axis(specs, ("pod", "data"))
    assert tuple(with_pod["embed"]["tok"]["w"])[0] == ("pod", "data")


def test_cache_specs_decode_vs_long():
    cfg = get_config("gemma3_4b")
    cache = jax.eval_shape(lambda: TF.init_cache(cfg, (128,), 32768))
    specs = SH.cache_pspecs(cache, cfg, MESH, batch_axis="data", seq_axis=None)
    kspec = tuple(specs["stack"][5]["k"])  # global-attn layer, stacked
    assert kspec[1] == "data"  # batch sharded
    cache1 = jax.eval_shape(lambda: TF.init_cache(cfg, (1,), 524288))
    specs1 = SH.cache_pspecs(cache1, cfg, MESH, batch_axis=None, seq_axis="data")
    k1 = tuple(specs1["stack"][5]["k"])
    assert k1[2] == "data"  # sequence sharded when batch = 1
    # swa ring buffers stay small; window 1024 still divisible → seq sharded
    ks = tuple(specs1["stack"][0]["k"])
    assert ks[2] == "data"


def test_musicgen_mha_kv_heads_shard():
    """kvh = 32 fills the model axis → cache kv heads shard over model."""
    cfg = get_config("musicgen_large")
    cache = jax.eval_shape(lambda: TF.init_cache(cfg, (128,), 1024))
    specs = SH.cache_pspecs(cache, cfg, MESH, batch_axis="data", seq_axis=None)
    assert tuple(specs["stack"][0]["k"])[3] == "model"

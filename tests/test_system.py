"""End-to-end behaviour of the whole system: DFL-train a reduced zoo
architecture on synthetic token data, form the consensus model, serve it."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow  # jit-heavy, excluded from tier-1

from repro.configs import get_reduced_config
from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import make_token_stream, token_batch_iterator
from repro.fed import consensus_params, generate, init_fl_state, make_round_fn, train_loop
from repro.models import transformer as TF
from repro.optim import adamw
from repro.fed.trainer import sigma_metrics


@pytest.fixture(scope="module")
def trained():
    n_nodes, seq, bs = 8, 32, 8
    cfg = get_reduced_config("qwen2p5_3b")
    graph = T.random_k_regular(n_nodes, 4, seed=0)
    gain = gain_from_graph(graph)
    icfg = InitConfig("trunc_normal", gain)
    opt = adamw(3e-3)

    def loss_fn(p, batch):
        x, y = batch
        hidden, aux = TF.forward(p, cfg, x)
        return TF.lm_loss(p, cfg, hidden, y) + 0.01 * aux

    toks = np.stack([make_token_stream(4000, cfg.vocab_size, seed=i) for i in range(n_nodes)])
    it = token_batch_iterator(toks, batch_size=bs, seq_len=seq, seed=0)

    def batches():
        while True:
            b = next(it)
            yield (b.x[:, None], b.y[:, None])  # 1 local minibatch per round

    init_one = lambda k: TF.init_params(k, cfg, icfg)
    state = init_fl_state(jax.random.PRNGKey(0), n_nodes, init_one, opt)
    rf = make_round_fn(loss_fn, opt, graph)
    state, hist = train_loop(state, rf, batches(), n_rounds=25, eval_every=6)
    return cfg, state, hist


def test_training_reduces_loss(trained):
    cfg, state, hist = trained
    losses = hist["train_loss"]
    assert losses[-1] < losses[0] - 0.3, losses


def test_sigma_an_contracts(trained):
    cfg, state, _ = trained
    s = sigma_metrics(state.params)
    assert float(s["sigma_an"]) < 0.05  # near-consensus after 25 rounds


def test_consensus_model_serves(trained):
    cfg, state, _ = trained
    cparams = consensus_params(state.params)
    prompt = jnp.asarray([[5, 9, 3, 7]], jnp.int32)
    out = generate(cparams, cfg, prompt, n_new=8, cache_len=64)
    assert out.shape == (1, 8)
    assert int(out.min()) >= 0 and int(out.max()) < cfg.vocab_size


def test_consensus_loss_not_worse_than_node_mean(trained):
    """The averaged model should be at least competitive with node models."""
    cfg, state, _ = trained
    toks = make_token_stream(2000, cfg.vocab_size, seed=99)
    x = jnp.asarray(toks[:256][None, :], jnp.int32)
    y = jnp.asarray(toks[1:257][None, :], jnp.int32)

    def eval_loss(p):
        hidden, _ = TF.forward(p, cfg, x, remat=False)
        return float(TF.lm_loss(p, cfg, hidden, y))

    cparams = consensus_params(state.params)
    node_losses = [eval_loss(jax.tree_util.tree_map(lambda l: l[i], state.params)) for i in range(4)]
    assert eval_loss(cparams) < np.mean(node_losses) + 0.2


def test_checkpoint_roundtrip_of_fl_state(trained, tmp_path):
    from repro.checkpoint import load_pytree, save_pytree

    cfg, state, _ = trained
    p = str(tmp_path / "fl.ckpt")
    save_pytree(p, state.params)
    back, _ = load_pytree(p, template=state.params)
    w0 = jax.tree_util.tree_leaves(state.params)[0]
    w1 = jax.tree_util.tree_leaves(back)[0]
    assert np.allclose(np.asarray(w0, np.float32), np.asarray(w1, np.float32))

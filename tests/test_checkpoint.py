import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, restore_train_state, save_pytree, save_train_state
from repro.optim import adamw


def test_roundtrip_dtypes_and_structure(tmp_path):
    tree = {
        "bf16": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "f32": jnp.ones((4,), jnp.float32) * 1.5,
        "i32": jnp.asarray([1, 2, 3], jnp.int32),
        "nested": [{"x": np.float64(2.5)}, (jnp.zeros(2),)],
    }
    p = str(tmp_path / "t.ckpt")
    save_pytree(p, tree, meta={"step": 7})
    back, meta = load_pytree(p)
    assert meta["step"] == 7
    assert np.asarray(back["bf16"]).dtype == jnp.bfloat16
    assert np.allclose(np.asarray(back["bf16"], np.float32), np.arange(6).reshape(2, 3))
    assert np.allclose(back["f32"], 1.5)
    assert back["nested"][1][0].shape == (2,)


def test_template_restores_namedtuples(tmp_path):
    opt = adamw()
    params = {"w": jnp.ones((3, 3))}
    state = opt.init(params)
    p = str(tmp_path / "opt.ckpt")
    save_pytree(p, state)
    back, _ = load_pytree(p, template=state)
    assert type(back).__name__ == "AdamWState"
    assert int(back.step) == 0


def test_latest_pointer_and_train_state(tmp_path):
    d = str(tmp_path / "ckpts")
    state = {"params": {"w": jnp.ones(3)}, "round": jnp.asarray(5)}
    save_train_state(d, 5, state)
    save_train_state(d, 10, {"params": {"w": jnp.ones(3) * 2}, "round": jnp.asarray(10)})
    got, meta = restore_train_state(d, template=state)
    assert meta["step"] == 10
    assert np.allclose(got["params"]["w"], 2.0)


def test_restore_missing_returns_none(tmp_path):
    assert restore_train_state(str(tmp_path / "nope")) is None


def test_atomic_write_leaves_no_tmp(tmp_path):
    p = str(tmp_path / "a.ckpt")
    save_pytree(p, {"x": jnp.zeros(2)})
    assert not os.path.exists(p + ".tmp")


def test_unsorted_dict_keys_roundtrip(tmp_path):
    """jax flattens dicts in sorted key order; the recorded structure must
    agree or leaves land in the wrong slots on a template-free load."""
    tree = {"z": jnp.ones(2) * 3, "a": jnp.ones(3) * 1, "m": jnp.ones(4) * 2}
    p = str(tmp_path / "d.ckpt")
    save_pytree(p, tree)
    back, _ = load_pytree(p)
    for k, v in tree.items():
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(v))


def test_opt_state_roundtrip_with_template(tmp_path):
    """The full optimizer pytree (nested namedtuples holding per-node
    moments) survives save → restore through the train-state path."""
    opt = adamw(1e-3)
    params = {"w": jnp.ones((4, 3)), "b": jnp.zeros((4,))}
    state = opt.init(params)
    # take one step so the moments are non-trivial
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    _, state = opt.update(g, state, params)
    d = str(tmp_path / "ck")
    save_train_state(d, 1, {"opt": state, "params": params})
    back, meta = restore_train_state(d, template={"opt": state, "params": params})
    assert meta["step"] == 1
    assert type(back["opt"]).__name__ == type(state).__name__
    for a, b in zip(jax.tree_util.tree_leaves(back), jax.tree_util.tree_leaves(
            {"opt": state, "params": params})):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_state_roundtrip(tmp_path):
    """Arrays committed to an explicit sharding save and restore by value
    (the checkpoint stores host buffers; placement is the loader's concern)."""
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("d",))
    sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("d"))
    x = jax.device_put(jnp.arange(8, dtype=jnp.float32), sh)
    p = str(tmp_path / "s.ckpt")
    save_pytree(p, {"x": x})
    back, _ = load_pytree(p)
    np.testing.assert_array_equal(np.asarray(back["x"]), np.arange(8, dtype=np.float32))


def test_keep_last_gc_and_latest_durability(tmp_path):
    """keep_last prunes old steps but never the one LATEST points to; the
    LATEST pointer itself is valid json naming an existing file."""
    d = str(tmp_path / "gc")
    for s in range(6):
        save_train_state(d, s, {"w": jnp.full((2,), float(s))}, keep_last=3)
    kept = sorted(f for f in os.listdir(d) if f.endswith(".ckpt"))
    assert kept == ["step_00000003.ckpt", "step_00000004.ckpt", "step_00000005.ckpt"]
    with open(os.path.join(d, "LATEST")) as f:
        latest = json.load(f)
    assert latest["step"] == 5
    assert os.path.exists(os.path.join(d, os.path.basename(latest["path"])))
    got, meta = restore_train_state(d)
    assert meta["step"] == 5
    np.testing.assert_array_equal(np.asarray(got["w"]), [5.0, 5.0])

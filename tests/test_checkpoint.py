import os

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, restore_train_state, save_pytree, save_train_state
from repro.optim import adamw


def test_roundtrip_dtypes_and_structure(tmp_path):
    tree = {
        "bf16": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
        "f32": jnp.ones((4,), jnp.float32) * 1.5,
        "i32": jnp.asarray([1, 2, 3], jnp.int32),
        "nested": [{"x": np.float64(2.5)}, (jnp.zeros(2),)],
    }
    p = str(tmp_path / "t.ckpt")
    save_pytree(p, tree, meta={"step": 7})
    back, meta = load_pytree(p)
    assert meta["step"] == 7
    assert np.asarray(back["bf16"]).dtype == jnp.bfloat16
    assert np.allclose(np.asarray(back["bf16"], np.float32), np.arange(6).reshape(2, 3))
    assert np.allclose(back["f32"], 1.5)
    assert back["nested"][1][0].shape == (2,)


def test_template_restores_namedtuples(tmp_path):
    opt = adamw()
    params = {"w": jnp.ones((3, 3))}
    state = opt.init(params)
    p = str(tmp_path / "opt.ckpt")
    save_pytree(p, state)
    back, _ = load_pytree(p, template=state)
    assert type(back).__name__ == "AdamWState"
    assert int(back.step) == 0


def test_latest_pointer_and_train_state(tmp_path):
    d = str(tmp_path / "ckpts")
    state = {"params": {"w": jnp.ones(3)}, "round": jnp.asarray(5)}
    save_train_state(d, 5, state)
    save_train_state(d, 10, {"params": {"w": jnp.ones(3) * 2}, "round": jnp.asarray(10)})
    got, meta = restore_train_state(d, template=state)
    assert meta["step"] == 10
    assert np.allclose(got["params"]["w"], 2.0)


def test_restore_missing_returns_none(tmp_path):
    assert restore_train_state(str(tmp_path / "nope")) is None


def test_atomic_write_leaves_no_tmp(tmp_path):
    p = str(tmp_path / "a.ckpt")
    save_pytree(p, {"x": jnp.zeros(2)})
    assert not os.path.exists(p + ".tmp")

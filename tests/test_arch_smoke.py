"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward AND one train step on CPU; output shapes
asserted, no NaNs anywhere."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_reduced_config, list_archs
from repro.core.initialisation import InitConfig
from repro.models import transformer as TF
from repro.optim import sgd

ARCHS = list_archs()


def _batch(cfg, key, b=2, s=32):
    text_len = s - cfg.n_frontend_tokens
    toks = jax.random.randint(key, (b, text_len), 0, cfg.vocab_size)
    fe = None
    if cfg.frontend and cfg.n_frontend_tokens:
        fe = 0.1 * jax.random.normal(key, (b, cfg.n_frontend_tokens, cfg.frontend_embed_dim), jnp.float32)
    targets = jax.random.randint(jax.random.fold_in(key, 1), (b, text_len), 0, cfg.vocab_size)
    return toks, fe, targets


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_variant_is_within_smoke_budget(arch):
    cfg = get_reduced_config(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced_config(arch)
    params = TF.init_params(jax.random.PRNGKey(0), cfg, InitConfig(gain=4.0))
    toks, fe, targets = _batch(cfg, jax.random.PRNGKey(1))
    hidden, aux = TF.forward(params, cfg, toks, fe)
    assert hidden.shape == (2, 32, cfg.d_model)
    assert bool(jnp.isfinite(hidden).all())
    logits = TF.hidden_to_logits(params, cfg, hidden[:, -1:, :])
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = get_reduced_config(arch)
    params = TF.init_params(jax.random.PRNGKey(0), cfg, InitConfig(gain=4.0))
    toks, fe, targets = _batch(cfg, jax.random.PRNGKey(2))

    def loss_fn(p):
        hidden, aux = TF.forward(p, cfg, toks, fe)
        nf = cfg.n_frontend_tokens if fe is not None else 0
        h = hidden[:, nf:, :] if nf else hidden
        return TF.lm_loss(p, cfg, h, targets) + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    opt = sgd(1e-3, 0.5)
    s = opt.init(params)
    upd, s = opt.update(grads, s, params)
    new_params = jax.tree_util.tree_map(lambda a, u: a + u.astype(a.dtype), params, upd)
    assert all(bool(jnp.isfinite(l).all()) for l in jax.tree_util.tree_leaves(new_params))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyper-parameters."""
    cfg = get_config(arch)
    spec = {
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "jamba_1p5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "qwen2p5_3b": (36, 2048, 16, 2, 11008, 151936),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "stablelm_12b": (40, 5120, 32, 8, 13824, 100352),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "qwen1p5_4b": (40, 2560, 20, 20, 6912, 151936),
        "rwkv6_3b": (32, 2560, 0, 0, 8960, 65536),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
    }[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size) == spec


def test_moe_configs():
    g = get_config("granite_moe_1b_a400m")
    assert (g.n_experts, g.experts_per_token) == (32, 8)
    j = get_config("jamba_1p5_large_398b")
    assert (j.n_experts, j.experts_per_token, j.moe_period) == (16, 2, 2)
    l4 = get_config("llama4_scout_17b_a16e")
    assert (l4.n_experts, l4.experts_per_token) == (16, 1)


def test_param_counts_near_nameplate():
    """Analytic parameter counts should land near the labels."""
    cases = {
        "gemma3_4b": (3.5e9, 4.5e9),
        "jamba_1p5_large_398b": (380e9, 410e9),
        "qwen2p5_3b": (2.8e9, 3.4e9),
        "stablelm_12b": (11.5e9, 12.8e9),
        "rwkv6_3b": (2.6e9, 3.2e9),
        "qwen1p5_4b": (3.6e9, 4.3e9),
    }
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, (arch, n)
    # active params: jamba ≈ 94B, granite ≈ 400M+embed
    assert 85e9 <= get_config("jamba_1p5_large_398b").n_active_params() <= 100e9


@pytest.mark.parametrize("arch", ["gemma3_4b", "jamba_1p5_large_398b"])
def test_tail_layers_handled(arch):
    """gemma3: 34 = 5 units of 6 + 4 tail; jamba: exact 9 units of 8."""
    cfg = get_config(arch)
    u = TF.unit_size(cfg)
    if arch == "gemma3_4b":
        assert (u, cfg.n_layers % u) == (6, 4)
    else:
        assert (u, cfg.n_layers % u) == (8, 0)

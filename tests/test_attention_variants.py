"""Beyond-paper attention implementations must be exact vs the baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.initialisation import InitConfig
from repro.models import transformer as TF
from repro.models.attention import _causal_mask, _sdpa, _sdpa_banded, _sdpa_chunked


def _qkv(key, b, s, h, kvh, hd):
    ks = jax.random.split(key, 3)
    return (
        jax.random.normal(ks[0], (b, s, h, hd)),
        jax.random.normal(ks[1], (b, s, kvh, hd)),
        jax.random.normal(ks[2], (b, s, kvh, hd)),
    )


@pytest.mark.parametrize("s,w", [(64, 16), (128, 32), (96, 32)])
def test_banded_equals_masked_full(s, w):
    q, k, v = _qkv(jax.random.PRNGKey(s), 2, s, 4, 2, 16)
    full = _sdpa(q, k, v, _causal_mask(s, w), 0.25)
    band = _sdpa_banded(q, k, v, w, 0.25)
    np.testing.assert_allclose(np.asarray(band), np.asarray(full), atol=2e-5)


@pytest.mark.parametrize("s,chunk,w", [(640, 512, 0), (600, 512, 0), (1024, 512, 256)])
def test_chunked_equals_full(s, chunk, w):
    q, k, v = _qkv(jax.random.PRNGKey(s), 1, s, 4, 4, 16)
    full = _sdpa(q, k, v, _causal_mask(s, w), 0.25)
    chunked = _sdpa_chunked(q, k, v, w, 0.25, chunk=chunk)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full), atol=2e-5)
    unrolled = _sdpa_chunked(q, k, v, w, 0.25, chunk=chunk, unroll=True)
    np.testing.assert_allclose(np.asarray(unrolled), np.asarray(full), atol=2e-5)


def test_model_level_equivalence_gemma():
    cfg = get_reduced_config("gemma3_4b")
    params = TF.init_params(jax.random.PRNGKey(0), cfg, InitConfig(gain=2.0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
    h_full, _ = TF.forward(params, cfg, toks, remat=False)
    h_blk, _ = TF.forward(params, dataclasses.replace(cfg, swa_impl="blocked"), toks, remat=False)
    err = float(jnp.abs(h_full - h_blk).max() / jnp.abs(h_full).max())
    assert err < 1e-4, err

"""Distribution tests that need multiple devices — run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single real CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # jit-heavy, excluded from tier-1

_SCRIPT_CIRCULANT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    try:
        shard_map = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    from repro.core import topology as T
    from repro.core.decavg import mix_pytree, mix_pytree_circulant
    from repro.core.mixing import receive_matrix

    n = 8
    mesh = jax.make_mesh((8,), ("data",))
    graph = T.circulant(n, (1, 2))
    m = jnp.asarray(receive_matrix(graph), jnp.float32)
    params = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (n, 16, 4)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (n, 5)),
    }
    dense = mix_pytree(m, params)
    specs = {"w": P("data", None, None), "b": P("data", None)}
    with mesh:
        circ = jax.jit(
            shard_map(
                lambda p: mix_pytree_circulant(p, offsets=(1, 2), axis_name="data"),
                mesh=mesh, in_specs=(specs,), out_specs=specs,
            )
        )(params)
    err = max(float(jnp.abs(dense[k] - circ[k]).max()) for k in params)
    assert err < 1e-5, err
    print("CIRCULANT_OK", err)
    """
)

_SCRIPT_SHARDED_TRAIN = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import topology as T
    from repro.core.initialisation import InitConfig, gain_from_graph
    from repro.core.mixing import receive_matrix
    from repro.core.decavg import mix_pytree
    from repro.models.paper_models import init_mlp, mlp_forward, classifier_loss
    from repro.optim import sgd

    n = 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    graph = T.random_k_regular(n, 4, seed=0)
    m = jnp.asarray(receive_matrix(graph), jnp.float32)
    opt = sgd(1e-3, 0.5)
    icfg = InitConfig("he_normal", gain_from_graph(graph))
    init_one = lambda k: init_mlp(icfg, k, in_dim=64, hidden=(32, 16), n_classes=4)
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    params = jax.vmap(init_one)(keys)
    opt_state = jax.vmap(opt.init)(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (n, 8, 64))
    y = jax.random.randint(jax.random.PRNGKey(2), (n, 8), 0, 4)

    def loss_fn(p, xx, yy):
        return classifier_loss(mlp_forward(p, xx), yy)

    def step(params, opt_state, x, y):
        loss, grads = jax.vmap(jax.value_and_grad(loss_fn))(params, x, y)
        upd, opt_state = jax.vmap(opt.update)(grads, opt_state, params)
        params = jax.tree_util.tree_map(lambda a, u: a + u, params, upd)
        params = mix_pytree(m, params)
        opt_state = jax.vmap(opt.init)(params)
        return params, opt_state, loss.mean()

    pspec = jax.tree_util.tree_map(lambda l: P("data", *([None] * (l.ndim - 1))), params)
    shard = lambda t, s: jax.tree_util.tree_map(
        lambda l, sp: jax.device_put(l, NamedSharding(mesh, sp)), t, s,
        is_leaf=lambda z: hasattr(z, "shape"))
    with mesh:
        params = shard(params, pspec)
        compiled = jax.jit(step)
        p2, o2, loss = compiled(params, opt_state, x, y)
        p3, o3, loss2 = compiled(p2, o2, x, y)
    assert np.isfinite(float(loss)) and np.isfinite(float(loss2))
    # loss decreases across two rounds on the same batch
    assert float(loss2) < float(loss)
    print("SHARDED_TRAIN_OK", float(loss), float(loss2))
    """
)


def _run(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True, env=env, timeout=420
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_circulant_schedule_equals_dense_mixing():
    """The ppermute schedule must equal the dense receive-matrix product on a
    circulant graph — the beyond-paper optimisation is semantics-preserving."""
    assert "CIRCULANT_OK" in _run(_SCRIPT_CIRCULANT)


def test_sharded_training_round_runs_and_learns():
    """A full DFL round jits and runs under a (data, model) mesh."""
    assert "SHARDED_TRAIN_OK" in _run(_SCRIPT_SHARDED_TRAIN)

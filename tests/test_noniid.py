"""Non-iid (Zipf) label distribution — the paper's cfg B regime — and
data-size-weighted DecAvg (Eq. 2 exact form)."""
import numpy as np
import jax
import pytest

from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import mnist_like, node_batch_iterator, node_datasets, partition_zipf
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, train_loop
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd


@pytest.mark.slow
def test_zipf_noniid_training_still_benefits_from_correction():
    """Paper cfg B uses Zipf α=1.8 non-iid data (on a BA graph): the
    gain-corrected init must still beat plain He under label skew."""
    n, per = 16, 128
    ds = mnist_like(n * per + 512, seed=0)
    parts = partition_zipf(ds.y[: n * per], n, alpha=1.8, items_per_node=per, seed=0)
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-512:], ds.y[-512:])
    graph = T.barabasi_albert(n, 4, seed=0)
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    eval_fn = make_eval_fn(loss_fn)

    def batches():
        it = node_batch_iterator(xs, ys, 16, seed=0)
        while True:
            bs = [next(it) for _ in range(4)]
            yield (np.stack([b.x for b in bs], 1), np.stack([b.y for b in bs], 1))

    finals = {}
    for label, gain in [("he", 1.0), ("corrected", gain_from_graph(graph))]:
        init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k)
        state = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)
        rf = make_round_fn(loss_fn, opt, graph)
        state, hist = train_loop(state, rf, batches(), n_rounds=40, eval_every=39,
                                 eval_fn=eval_fn, eval_batch=test)
        finals[label] = hist["test_loss"][-1]
    assert finals["corrected"] < finals["he"] - 0.3, finals


def test_data_weighted_aggregation_runs_and_learns():
    """Eq. 2 with unequal |D_i|: β_i weights follow the data sizes."""
    n = 8
    sizes = np.array([32, 32, 64, 64, 128, 128, 256, 256], dtype=np.float64)
    per = 32  # rectangular stack uses the min; sizes only affect the weights
    ds = mnist_like(n * per + 256, seed=1)
    parts = [np.arange(i * per, (i + 1) * per) for i in range(n)]
    xs, ys = node_datasets(ds, parts)
    graph = T.random_k_regular(n, 4, seed=1)
    loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", gain_from_graph(graph)), k, hidden=(64, 32))
    state = init_fl_state(jax.random.PRNGKey(1), n, init_one, opt)
    rf = make_round_fn(loss_fn, opt, graph, data_sizes=sizes)

    def batches():
        it = node_batch_iterator(xs, ys, 16, seed=1)
        while True:
            b = next(it)
            yield (b.x[:, None], b.y[:, None])

    state, hist = train_loop(state, rf, batches(), n_rounds=10, eval_every=9)
    assert np.isfinite(hist["train_loss"][-1])
    assert hist["train_loss"][-1] < hist["train_loss"][0]

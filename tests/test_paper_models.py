import jax
import jax.numpy as jnp

from repro.core.initialisation import InitConfig
from repro.models.paper_models import (
    accuracy,
    classifier_loss,
    cnn_forward,
    init_cnn,
    init_mlp,
    init_vgg16,
    mlp_forward,
    vgg16_forward,
)

ICFG = InitConfig("he_normal", 1.0)


def test_mlp_paper_architecture():
    """Appendix A: 784 → 512 → 256 → 128 → 10, ReLU."""
    p = init_mlp(ICFG, jax.random.PRNGKey(0))
    assert p["fc0"]["w"].shape == (784, 512)
    assert p["fc1"]["w"].shape == (512, 256)
    assert p["fc2"]["w"].shape == (256, 128)
    assert p["fc3"]["w"].shape == (128, 10)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 28, 28, 1))
    logits = mlp_forward(p, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.isfinite(logits).all())


def test_cnn_paper_architecture():
    """Appendix A: conv 32/64/64 (3×3, pad 1) + FC 128/64 + out (So2Sat 17)."""
    p = init_cnn(ICFG, jax.random.PRNGKey(0))
    assert p["conv0"]["w"].shape == (3, 3, 10, 32)
    assert p["conv2"]["w"].shape == (3, 3, 64, 64)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 10))
    logits = cnn_forward(p, x)
    assert logits.shape == (2, 17)
    assert bool(jnp.isfinite(logits).all())


def test_vgg16_reduced_width():
    p = init_vgg16(ICFG, jax.random.PRNGKey(0), width_mult=0.125)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    logits = vgg16_forward(p, x)
    assert logits.shape == (2, 10)
    assert bool(jnp.isfinite(logits).all())
    # 13 conv layers (VGG16 cfg D)
    assert sum(1 for k in p if k.startswith("conv")) == 13


def test_vgg16_full_width_shapes_only():
    """Full-width VGG16 params instantiate abstractly (no allocation)."""
    shapes = jax.eval_shape(lambda k: init_vgg16(ICFG, k), jax.random.PRNGKey(0))
    assert shapes["conv12"]["w"].shape == (3, 3, 512, 512)
    assert shapes["fc0"]["w"].shape == (512, 4096)  # 32×32 → 1×1 after 5 pools


def test_loss_and_accuracy():
    logits = jnp.asarray([[10.0, 0, 0], [0, 10.0, 0]])
    labels = jnp.asarray([0, 1])
    assert float(classifier_loss(logits, labels)) < 1e-3
    assert float(accuracy(logits, labels)) == 1.0
    labels_bad = jnp.asarray([1, 0])
    assert float(classifier_loss(logits, labels_bad)) > 5.0


def test_mlp_trains_on_synthetic():
    from repro.data import mnist_like
    from repro.optim import sgd

    ds = mnist_like(512, seed=0)
    p = init_mlp(ICFG, jax.random.PRNGKey(0), hidden=(64,))
    opt = sgd(1e-2, 0.5)
    s = opt.init(p)
    x, y = jnp.asarray(ds.x[:256]), jnp.asarray(ds.y[:256])
    loss_fn = lambda p: classifier_loss(mlp_forward(p, x), y)
    l0 = float(loss_fn(p))
    for _ in range(60):
        g = jax.grad(loss_fn)(p)
        u, s = opt.update(g, s, p)
        p = jax.tree_util.tree_map(lambda a, b: a + b, p, u)
    assert float(loss_fn(p)) < l0 - 0.3

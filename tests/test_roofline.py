"""Unit tests for the roofline derivation layer (HLO parsing + extrapolation)."""
import numpy as np

from repro.launch import roofline as rl

SYNTH_HLO = """
HloModule jit_step

%fused (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128]{1,0} parameter(0)
  ROOT %r = f32[8,128]{1,0} add(%p0, %p0)
}

ENTRY %main (a: f32[8,128], b: bf16[4,256]) -> f32[8,128] {
  %a = f32[8,128]{1,0} parameter(0)
  %b = bf16[4,256]{1,0} parameter(1)
  %ag = bf16[64,256]{1,0} all-gather(%b), channel_id=1, dimensions={0}
  %ar = f32[8,128]{1,0} all-reduce(%a), channel_id=2, to_apply=%sum
  %ars = f32[8,128]{1,0} all-reduce-start(%a), channel_id=5
  %ard = f32[8,128]{1,0} all-reduce-done(%ars)
  %cp = f32[8,128]{1,0} collective-permute(%ar), channel_id=3, source_target_pairs={{0,1}}
  %a2a = (f32[2,128]{1,0}, f32[2,128]{1,0}) all-to-all(%a, %a), channel_id=4
  ROOT %out = f32[8,128]{1,0} add(%cp, %cp)
}
"""


def test_collective_bytes_parses_operands():
    cb = rl.collective_bytes(SYNTH_HLO)
    f32_a = 8 * 128 * 4
    bf16_b = 4 * 256 * 2
    assert cb["all-gather"] == bf16_b  # operand (not output) bytes
    # all-reduce + all-reduce-start counted, -done skipped
    assert cb["all-reduce"] == 2 * f32_a
    assert cb["collective-permute"] == f32_a
    assert cb["all-to-all"] == 2 * f32_a  # two operands


def test_shape_bytes_tuple_and_dtypes():
    assert rl._shape_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert rl._shape_bytes("(bf16[2,2], s32[3])") == 2 * 2 * 2 + 3 * 4
    assert rl._shape_bytes("pred[7]") == 7


def test_terms_and_dominant():
    t = rl.RooflineTerms(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9 * 0.5)
    assert np.isclose(t.compute_s, 1.0)
    assert np.isclose(t.memory_s, 2.0)
    assert np.isclose(t.collective_s, 0.5)
    assert t.dominant == "memory"


def test_depth_extrapolation_linear():
    a = rl.RooflineTerms(10.0, 100.0, 5.0, {"all-reduce": 5, "all-gather": 0, "reduce-scatter": 0, "all-to-all": 0, "collective-permute": 0})
    b = rl.RooflineTerms(16.0, 160.0, 8.0, {"all-reduce": 8, "all-gather": 0, "reduce-scatter": 0, "all-to-all": 0, "collective-permute": 0})
    t = rl.extrapolate_depth(a, b, n_periods=10)
    # total(P) = A + (P-1)(B-A): 10 + 9*6 = 64
    assert np.isclose(t.flops, 64.0)
    assert np.isclose(t.coll_bytes, 32.0)


def test_seq_extrapolation_recovers_polynomial():
    """cost(P,S) = (3 + 2S) + P·(7 + S + 0.001·S²) recovered exactly."""
    cb0 = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")}
    def cost(p, s):
        alpha = 3 + 2 * s
        beta = 7 + s + 0.001 * s * s
        return rl.RooflineTerms(alpha + p * beta, 2 * (alpha + p * beta), 0.0, dict(cb0))

    points = {(p, s): cost(p, s) for p in (1, 2) for s in (256, 512, 1024, 2048)}
    t = rl.extrapolate_depth_and_seq(points, n_periods=12, seq_target=32768)
    want = (3 + 2 * 32768) + 12 * (7 + 32768 + 0.001 * 32768**2)
    assert np.isclose(t.flops, want, rtol=1e-6)


def test_nonneg_fit_suppresses_spurious_curvature():
    """A linear metric with padding wiggles must not explode at 32× range."""
    rng = np.random.default_rng(0)
    seqs = [256, 512, 1024, 2048]
    true = lambda s: 1000.0 * s
    vals = [true(s) * (1 + rng.uniform(-0.02, 0.02)) for s in seqs]
    got = rl._nonneg_poly_extrapolate(seqs, vals, 32768)
    assert 0.5 * true(32768) < got < 2.0 * true(32768)


def test_model_flops():
    assert rl.model_flops(1_000_000, 100, "train") == 6e8
    assert rl.model_flops(1_000_000, 100, "prefill") == 2e8

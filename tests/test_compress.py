"""Compressed gossip (core.compress, DESIGN.md §18).

Four contracts:

1. **Codec round-trip bounds** — int8 dequantisation error per entry is at
   most half a quantisation step of its chunk; topk keeps its entries exact
   and zeroes the rest.
2. **Error-feedback contraction** — compressed DecAvg with the mirror carry
   drives consensus distance toward 0 on ring / k-regular graphs (γ = 1 for
   the quantisers, γ = 0.3 for topk — the sparsifier needs damping on
   poorly-connected graphs).
3. **Bit-parity of the uncompressed path** — codec "none" routes straight
   to the raw operators, bitwise, across dense / sparse / ppermute and
   {clean, failure} rounds, and ``Compression`` threads through
   ``make_round_fn`` / the executors without perturbing anything.
4. **Fused Pallas kernel parity** — ``quantised_mix_bsr`` matches the jnp
   oracle with the same chunk grid on every sparse-plan family.

Plus the wire-format arithmetic of ``leaf_row_bytes`` against hand-computed
values and the mixed-dtype ``param_row_bytes`` fix.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topology as T
from repro.core.commplan import FailureModel, compile_plan
from repro.core.compress import (
    Compression,
    compressed_mix,
    compressed_spread,
    encode_decode,
    init_residuals,
)
from repro.core.mixing import receive_matrix
from repro.kernels.mix import bsr_from_dense, quantised_decavg_mix_ref, quantised_mix_bsr
from repro.obs.wirecost import param_row_bytes


def _consensus_distance(x):
    return float(jnp.linalg.norm(x - x.mean(axis=0, keepdims=True)))


# ------------------------------------------------------------- config guards
def test_compression_validation():
    with pytest.raises(ValueError):
        Compression(codec="lz4")
    with pytest.raises(ValueError):
        Compression(codec="int8", chunk=0)
    with pytest.raises(ValueError):
        Compression(codec="int8", chunk=1 << 17)  # uint16 in-chunk indices
    with pytest.raises(ValueError):
        Compression(codec="topk", topk_frac=0.0)
    with pytest.raises(ValueError):
        Compression(codec="int8", gamma=0.0)
    assert not Compression().active
    assert Compression(codec="fp8").active


# --------------------------------------------------------- codec round trips
def test_int8_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 500)) * 7.0
    comp = Compression(codec="int8", chunk=128)
    q = encode_decode(x, comp)
    # error <= scale/2 per entry, scale = chunk absmax / 127, per 128-chunk
    pad = np.pad(np.asarray(x), ((0, 0), (0, -500 % 128)))
    chunks = pad.reshape(6, -1, 128)
    scale = np.abs(chunks).max(axis=-1, keepdims=True) / 127.0
    bound = np.broadcast_to(scale / 2 + 1e-7, chunks.shape).reshape(6, -1)[:, :500]
    assert (np.abs(np.asarray(q) - np.asarray(x)) <= bound).all()


def test_fp8_roundtrip_relative_bound():
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 300)) * 0.3
    q = encode_decode(x, Compression(codec="fp8", chunk=64))
    # e4m3 keeps ~3 mantissa bits -> <=2^-4 relative error at full scale,
    # plus the absmax normalisation; 10% of chunk absmax is a safe envelope
    pad = np.pad(np.asarray(x), ((0, 0), (0, -300 % 64)))
    amax = np.abs(pad.reshape(4, -1, 64)).max(axis=-1, keepdims=True)
    bound = np.broadcast_to(0.1 * amax, pad.reshape(4, -1, 64).shape).reshape(4, -1)[:, :300]
    assert (np.abs(np.asarray(q) - np.asarray(x)) <= bound).all()


def test_topk_keeps_exact_and_zeroes_rest():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 96))
    comp = Compression(codec="topk", chunk=32, topk_frac=0.25)
    q = np.asarray(encode_decode(x, comp))
    xn = np.asarray(x)
    kept = q != 0
    # kept entries are transmitted verbatim; count per 32-chunk is exactly k
    assert np.array_equal(q[kept], xn[kept])
    assert (kept.reshape(3, 3, 32).sum(axis=-1) == comp.topk_count(32)).all()
    # each chunk keeps its largest-|.| entries: min kept |x| >= max dropped
    a = np.abs(xn).reshape(3, 3, 32)
    k3 = kept.reshape(3, 3, 32)
    assert (
        np.where(k3, a, np.inf).min(axis=-1) >= np.where(k3, -np.inf, a).max(axis=-1)
    ).all()


def test_qtopk_sparsity_pattern_and_value_bound():
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 96))
    comp = Compression(codec="qtopk", chunk=32, topk_frac=0.25)
    q = np.asarray(encode_decode(x, comp))
    xn = np.asarray(x)
    kept = q != 0
    assert (kept.reshape(3, 3, 32).sum(axis=-1) == comp.topk_count(32)).all()
    # same selection as topk, but kept values carry the int8 error bound:
    # scale = chunk absmax / 127 (absmax IS the top-1 kept magnitude)
    scale = np.abs(xn).reshape(3, 3, 32).max(axis=-1, keepdims=True) / 127.0
    bound = np.broadcast_to(scale / 2 + 1e-7, (3, 3, 32)).reshape(3, 96)
    assert (np.abs(q[kept] - xn[kept]) <= bound[kept]).all()
    sel = np.asarray(
        encode_decode(x, Compression(codec="topk", chunk=32, topk_frac=0.25))
    ) != 0
    assert np.array_equal(kept, sel)


def test_encode_decode_pytree_and_none():
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(3), (4, 33)),
        "b": jax.random.normal(jax.random.PRNGKey(4), (4,)),
    }
    assert encode_decode(tree, Compression()) is tree  # codec none: no touch
    q = encode_decode(tree, Compression(codec="int8", chunk=16))
    assert q["w"].shape == (4, 33) and q["b"].shape == (4,)


# ------------------------------------------------- error-feedback contraction
@pytest.mark.parametrize(
    "codec,gamma,target",
    [
        ("int8", 1.0, 1e-3),
        ("fp8", 1.0, 1e-3),
        ("topk", 0.3, 0.35),
        ("qtopk", 0.3, 0.35),
    ],
)
def test_compressed_consensus_contracts(codec, gamma, target):
    """Mirror-form compressed DecAvg reaches (near-)consensus where memory-
    less compression would floor out: the quantisers get all the way down,
    the damped sparsifier contracts by >10x over the horizon."""
    for graph in (T.ring(16), T.random_k_regular(16, 4, seed=0)):
        plan = compile_plan(graph)
        x = jax.random.normal(jax.random.PRNGKey(7), (16, 400))
        comp = Compression(codec=codec, chunk=128, gamma=gamma)
        h = init_residuals(x)
        d0 = _consensus_distance(x)

        @jax.jit
        def rounds(x, h):
            def step(carry, _):
                x, h = carry
                x, h = compressed_mix(plan, x, h, compression=comp)
                return (x, h), None

            (x, h), _ = jax.lax.scan(step, (x, h), None, length=300)
            return x, h

        x_end, _ = rounds(x, h)
        assert _consensus_distance(x_end) < target * d0, graph.name
        # the mean is conserved through every compressed round (M doubly
        # stochastic on these families, delta form adds mix(h')-h')
        np.testing.assert_allclose(
            np.asarray(x_end.mean(axis=0)), np.asarray(x.mean(axis=0)), atol=1e-3
        )


def test_error_feedback_off_floors_out():
    """Ablation: memory-less int8 stalls at the codec noise floor while the
    mirror form keeps contracting — the reason the carry exists."""
    plan = compile_plan(T.ring(12))
    x = jax.random.normal(jax.random.PRNGKey(8), (12, 256))
    on = Compression(codec="int8", chunk=64)
    off = dataclasses.replace(on, error_feedback=False)

    def run(comp):
        def step(carry, _):
            return compressed_mix(plan, *carry, compression=comp), None

        (xe, _), _ = jax.lax.scan(step, (x, init_residuals(x)), None, length=200)
        return _consensus_distance(xe)

    assert run(on) < 0.05 * run(off)


def test_stream_matches_unstreamed():
    plan = compile_plan(T.random_k_regular(12, 4, seed=1))
    x = jax.random.normal(jax.random.PRNGKey(9), (12, 300))
    h = init_residuals(x) + 0.1
    comp = Compression(codec="int8", chunk=64)
    a, ha = compressed_mix(plan, x, h, compression=comp)
    b, hb = compressed_mix(
        plan, x, h, compression=dataclasses.replace(comp, stream=True)
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-6)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hb), atol=2e-6)


def test_compressed_spread_conserves_mass():
    plan = compile_plan(T.barabasi_albert(14, 3, seed=2))
    v = jax.random.uniform(jax.random.PRNGKey(10), (14, 8)) + 0.5
    h = jnp.zeros_like(v)
    comp = Compression(codec="topk", chunk=8, topk_frac=0.25, gamma=0.5)
    total = v.sum(axis=0)
    for _ in range(5):
        v, h = compressed_spread(plan, v, h, compression=comp)
    np.testing.assert_allclose(np.asarray(v.sum(axis=0)), np.asarray(total), rtol=1e-5)


# ------------------------------------------------------ uncompressed parity
@pytest.mark.parametrize("backend", ["dense", "sparse", "ppermute"])
@pytest.mark.parametrize("link_p", [1.0, 0.7])
def test_codec_none_bitwise_parity(backend, link_p):
    plan = compile_plan(
        T.random_k_regular(8, 4, seed=3),
        backend=backend,
        failures=FailureModel(link_p=link_p),
    )
    tree = {
        "w": jax.random.normal(jax.random.PRNGKey(11), (8, 40)),
        "b": jax.random.normal(jax.random.PRNGKey(12), (8, 5)),
    }
    key = jax.random.PRNGKey(13) if link_p < 1.0 else None
    h = init_residuals(tree)
    out, h2 = compressed_mix(plan, tree, h, key, compression=Compression())
    ref = plan.mix(tree, key=key)
    for a, b in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h2 is h  # the carry is passed through untouched


def test_commplan_mix_compression_kwarg():
    """CommPlan.mix(compression=) is the same operator as compressed_mix."""
    plan = compile_plan(T.ring(10))
    x = jax.random.normal(jax.random.PRNGKey(14), (10, 64))
    comp = Compression(codec="int8", chunk=32)
    a, ha = plan.mix(x, compression=comp, residual=init_residuals(x))
    b, hb = compressed_mix(plan, x, init_residuals(x), compression=comp)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(ha), np.asarray(hb))


# ------------------------------------------------------- fused Pallas kernel
@pytest.mark.parametrize("codec", ["int8", "fp8"])
@pytest.mark.parametrize(
    "family",
    ["ring", "kregular", "ba", "complete"],
)
def test_quantised_mix_bsr_parity(codec, family):
    g = {
        "ring": lambda: T.ring(40),
        "kregular": lambda: T.random_k_regular(40, 4, seed=0),
        "ba": lambda: T.barabasi_albert(40, 3, seed=0),
        "complete": lambda: T.complete(40),
    }[family]()
    m = np.asarray(receive_matrix(g), np.float32)
    rng = np.random.default_rng(5)
    w = (rng.normal(size=(40, 190)) * rng.uniform(0.01, 8, size=(40, 1))).astype(
        np.float32
    )
    bc, tiles = bsr_from_dense(m, 8)
    got = quantised_mix_bsr(
        jnp.asarray(bc),
        jnp.asarray(tiles),
        jnp.asarray(w),
        codec=codec,
        block_d=64,
        interpret=True,
    )
    ref = quantised_decavg_mix_ref(
        jnp.asarray(m), jnp.asarray(w), codec=codec, block_d=64
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)


def test_quantised_mix_bsr_rejects_unknown_codec():
    bc, tiles = bsr_from_dense(np.eye(8, dtype=np.float32), 8)
    w = jnp.ones((8, 16))
    with pytest.raises(ValueError):
        quantised_mix_bsr(jnp.asarray(bc), jnp.asarray(tiles), w, codec="zstd")


def test_quantised_kernel_exact_at_uniform_rows():
    """Rows with a single magnitude level quantise exactly (x = scale*q with
    integer q), so the fused kernel must equal the uncompressed product."""
    g = T.ring(16)
    m = np.asarray(receive_matrix(g), np.float32)
    w = np.tile(
        np.asarray([1.0, -1.0, 1.0, 1.0], np.float32), (16, 32)
    )  # |w| = 1 everywhere
    bc, tiles = bsr_from_dense(m, 8)
    got = quantised_mix_bsr(
        jnp.asarray(bc), jnp.asarray(tiles), jnp.asarray(w), block_d=64, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), m @ w, atol=1e-6)


# ------------------------------------------------------------- wire formats
def test_leaf_row_bytes_hand_values():
    c = Compression(codec="int8", chunk=100)
    assert c.leaf_row_bytes(250, np.float32) == 250 + 3 * 4  # 3 chunks' scales
    assert c.leaf_row_bytes(0, np.float32) == 0.0
    f = Compression(codec="fp8", chunk=64)
    assert f.leaf_row_bytes(64, np.float32) == 64 + 4
    t = Compression(codec="topk", chunk=100, topk_frac=0.1)
    # 2 full chunks keep 10 each, the 50-tail keeps 5; 6 bytes per entry
    assert t.leaf_row_bytes(250, np.float32) == (10 + 10 + 5) * 6
    # a 3-element tail still transmits at least one entry
    assert t.leaf_row_bytes(103, np.float32) == (10 + 1) * 6
    qt = Compression(codec="qtopk", chunk=100, topk_frac=0.1)
    # same selection, 3 bytes per entry + one fp32 scale per chunk
    assert qt.leaf_row_bytes(250, np.float32) == (10 + 10 + 5) * 3 + 3 * 4
    n = Compression()
    assert n.leaf_row_bytes(250, np.float32) == 1000.0


def test_executor_compression_integration():
    """make_round_fn + run_trajectory: codec "none"/None are bit-identical,
    an active codec threads the mirror through the scan carry and the wire
    channel prices bytes at the codec's encoding."""
    from repro.data import batch_index_schedule, mnist_like, node_datasets
    from repro.fed import init_fl_state, make_round_fn, run_trajectory
    from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
    from repro.core.initialisation import InitConfig
    from repro.optim import sgd

    n, per, rounds, b = 6, 32, 4, 2
    ds = mnist_like(n * per, seed=0)
    xs, ys = node_datasets(ds, [np.arange(i * per, (i + 1) * per) for i in range(n)])
    loss_fn = lambda p, bt: classifier_loss(mlp_forward(p, bt[0]), bt[1])
    opt = sgd(1e-3, 0.5)
    init_one = lambda k: init_mlp(InitConfig("he_normal", 2.0), k, hidden=(16,))
    sched = batch_index_schedule(per, n, 8, rounds * b, seed=0)
    plan = compile_plan(T.ring(n))
    state = init_fl_state(jax.random.PRNGKey(0), n, init_one, opt)

    def run(compression):
        rf = make_round_fn(loss_fn, opt, plan, compression=compression)
        return run_trajectory(
            state, rf, xs, ys, sched, n_rounds=rounds, eval_every=2, b_local=b
        )

    s_raw, h_raw = run(None)
    s_none, h_none = run(Compression())
    for a, bb in zip(
        jax.tree_util.tree_leaves(s_none.params), jax.tree_util.tree_leaves(s_raw.params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))
    assert s_raw.residual is None and s_none.residual is None

    comp = Compression(codec="int8", chunk=256)
    s_c, h_c = run(comp)
    assert s_c.residual is not None
    # codec pricing: same message counts, codec-rate bytes
    assert h_c["wire_messages"] == h_raw["wire_messages"]
    want = param_row_bytes(state.params, codec_bytes=comp.leaf_row_bytes)
    assert h_c["wire_bytes"][0] == h_c["wire_messages"][0] * want
    assert h_raw["wire_bytes"][0] > 3.7 * h_c["wire_bytes"][0]
    # compression perturbs the trajectory but not catastrophically
    diff = max(
        float(jnp.abs(a - bb).max())
        for a, bb in zip(
            jax.tree_util.tree_leaves(s_c.params),
            jax.tree_util.tree_leaves(s_raw.params),
        )
    )
    assert 0 < diff < 1.0


def test_param_row_bytes_mixed_dtype_and_codec():
    params = {
        "w": jnp.zeros((4, 100), jnp.float32),
        "h": jnp.zeros((4, 50), jnp.bfloat16),
        "s": jnp.zeros((4,), jnp.float32),
    }
    # mixed dtypes price at their own itemsize (the satellite fix): the old
    # single-itemsize accounting would have charged bf16 rows 4 bytes/elem
    assert param_row_bytes(params) == 100 * 4 + 50 * 2 + 4
    comp = Compression(codec="int8", chunk=64)
    want = (100 + 2 * 4) + (50 + 4) + (1 + 4)
    assert param_row_bytes(params, codec_bytes=comp.leaf_row_bytes) == want
    # >=4x headline: a topk row at frac 0.1 versus its fp32 encoding
    t = Compression(codec="topk", chunk=1000, topk_frac=0.1)
    big = {"w": jnp.zeros((2, 10_000), jnp.float32)}
    assert param_row_bytes(big) / param_row_bytes(big, codec_bytes=t.leaf_row_bytes) > 4

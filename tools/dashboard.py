"""Render the committed BENCH_*.json artifacts — or one telemetry run log —
into a static markdown / HTML dashboard.

Two modes, both stdlib-only and **deterministic** (no timestamps, no
environment probes): the output is a pure function of the input files, so
CI can regenerate ``BENCH_REPORT.md`` from the committed artifacts and fail
on any diff — the committed report can never drift from the committed
numbers.

* **bench** (``--bench DIR``): one section per artifact, every record as a
  table row (identity columns first, then measurements), plus a headline
  summary table with each artifact's primary timing per record identity —
  the cross-PR trend view: diffing this report between commits shows every
  timing/loss movement the bench suite measured.
* **run** (``--run out.jsonl``): a single run's telemetry
  (``launch/train.py --telemetry``) — manifest, per-round/bin history
  table, summary and gossip-health records.

Usage:
    python tools/dashboard.py --bench . --out-md BENCH_REPORT.md
    python tools/dashboard.py --bench . --out-html dashboard.html
    python tools/dashboard.py --run /tmp/run.jsonl --out-html run.html
"""
from __future__ import annotations

import argparse
import html
import json
import pathlib
import sys

# committed artifact set, rendered in this order (missing ones are noted)
BENCH_ORDER = (
    "BENCH_mixing.json",
    "BENCH_rounds.json",
    "BENCH_estimates.json",
    "BENCH_churn.json",
    "BENCH_async.json",
    "BENCH_scaling.json",
    "BENCH_elastic.json",
    "BENCH_compress.json",
    "BENCH_serve.json",
)

# per-artifact headline timing field for the summary trend table, tried in
# order (steady fields first — the compile/steady split's honest number)
HEADLINE = (
    "us_per_round_steady",
    "us_per_event_steady",
    "us_per_round_steady_schedule",
    "us_per_round_steady_sync",
    "us_per_round",
    "us_per_event",
    "us_dense",
    "us_sparse",
    "sec_executor",
    "sec_per_round_schedule",
    "sec_per_round",
)


def _fmt(v) -> str:
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.4g}"
    if isinstance(v, (list, tuple)):
        return f"[{len(v)} values]"
    if isinstance(v, dict):
        return f"{{{len(v)} keys}}"
    return str(v)


def _identity_label(rec: dict) -> str:
    parts = [
        f"{k}={v}"
        for k, v in rec.items()
        if isinstance(v, (str, bool))
        or (isinstance(v, int) and k in ("n", "n_nodes", "n_shards", "rounds", "k_plans"))
    ]
    return " ".join(parts) if parts else "-"


def _columns(records: list[dict]) -> list[str]:
    """Stable column order: first record's key order, then later extras."""
    cols: list[str] = []
    for rec in records:
        for k in rec:
            if k not in cols:
                cols.append(k)
    # identity-ish columns (strings/bools) lead, measurements follow
    ident = [c for c in cols if any(isinstance(r.get(c), (str, bool)) for r in records)]
    return ident + [c for c in cols if c not in ident]


def _md_table(headers: list[str], rows: list[list[str]]) -> list[str]:
    out = ["| " + " | ".join(headers) + " |", "|" + "---|" * len(headers)]
    out += ["| " + " | ".join(r) + " |" for r in rows]
    return out


def _pareto_lines(doc: dict) -> list[str]:
    """Bytes-vs-loss Pareto table from BENCH_compress.json: per (kind,
    family, n) group, rows sorted by wire bytes; a row is Pareto-optimal
    when no sibling costs fewer bytes AND lands a lower final loss."""
    groups: dict[tuple, list[dict]] = {}
    for rec in doc.get("records", []):
        groups.setdefault(
            (rec.get("kind", "?"), rec.get("family", "?"), rec.get("n", "?")), []
        ).append(rec)
    lines = [
        "Per setup, sorted cheapest-wire first; `pareto` marks codecs no",
        "sibling beats on both bytes and final loss simultaneously.",
        "",
    ]
    for (kind, family, n), recs in groups.items():
        recs = sorted(recs, key=lambda r: r.get("wire_bytes_per_round", 0))
        rows = []
        for rec in recs:
            b, l = rec.get("wire_bytes_per_round", 0), rec.get("final_test_loss", 0.0)
            dominated = any(
                o is not rec
                and o.get("wire_bytes_per_round", 0) <= b
                and o.get("final_test_loss", 0.0) < l
                for o in recs
            )
            rows.append(
                [
                    rec.get("codec", "?"),
                    _fmt(b),
                    f"{rec.get('bytes_reduction_vs_fp32', 1.0):.2f}x",
                    _fmt(l),
                    f"{rec.get('loss_delta_vs_fp32_pct', 0.0):+.2f}%",
                    _fmt(rec.get("us_per_round_steady", "")),
                    "" if dominated else "yes",
                ]
            )
        lines += [f"**{kind} / {family} / n={n}**", ""]
        lines += _md_table(
            ["codec", "wire B/round", "reduction", "final loss", "Δloss",
             "us/round steady", "pareto"],
            rows,
        )
        lines.append("")
    return lines


def _serve_lines(doc: dict) -> list[str]:
    """Latency-vs-staleness table from BENCH_serve.json: per (family, n, qps)
    cell, one row per router policy so the trade each router makes — hops
    and queueing against the staleness of the answering parameters — reads
    off a single table."""
    groups: dict[tuple, list[dict]] = {}
    for rec in doc.get("records", []):
        groups.setdefault(
            (rec.get("family", "?"), rec.get("n", "?"), rec.get("qps", "?")), []
        ).append(rec)
    wins = doc.get("consensus_wins", [])
    lines = [
        "Per traffic cell, one row per router; latencies and staleness are",
        "virtual-time (open-loop queueing model over the merged train+serve",
        "envelope).  Consensus beats uniform on staleness at ≤1.05x p50",
        "latency on: " + (", ".join(map(str, wins)) if wins else "none") + ".",
        "",
    ]
    for (family, n, qps), recs in groups.items():
        rows = [
            [
                rec.get("router", "?"),
                _fmt(rec.get("p50_latency", "")),
                _fmt(rec.get("p95_latency", "")),
                _fmt(rec.get("mean_staleness_served", "")),
                _fmt(rec.get("mean_hops", "")),
                _fmt(rec.get("served", "")),
                _fmt(rec.get("final_test_loss", "")),
            ]
            for rec in sorted(recs, key=lambda r: r.get("router", ""))
        ]
        lines += [f"**{family} / n={n} / qps={qps}**", ""]
        lines += _md_table(
            ["router", "p50 lat", "p95 lat", "staleness", "hops", "served", "final test loss"],
            rows,
        )
        lines.append("")
    return lines


def bench_sections(root: pathlib.Path) -> list[tuple[str, list[str]]]:
    """(title, markdown lines) per section, from the artifacts under root."""
    docs: dict[str, dict] = {}
    for name in BENCH_ORDER:
        path = root / name
        if path.exists():
            docs[name] = json.loads(path.read_text())

    sections: list[tuple[str, list[str]]] = []
    summary_rows: list[list[str]] = []
    for name, doc in docs.items():
        records = doc.get("records", [])
        for rec in records:
            field = next((f for f in HEADLINE if f in rec), None)
            if field is not None:
                summary_rows.append(
                    [name.removeprefix("BENCH_").removesuffix(".json"),
                     _identity_label(rec), field, _fmt(rec[field])]
                )
    lines = [
        "Regenerate with `python tools/dashboard.py --bench . --out-md BENCH_REPORT.md`",
        "— the output is deterministic, so CI diffs it against this committed copy.",
        "",
    ]
    missing = [n for n in BENCH_ORDER if n not in docs]
    if missing:
        lines += ["Missing artifacts: " + ", ".join(missing), ""]
    lines += _md_table(["suite", "identity", "field", "value"], summary_rows)
    sections.append(("Headline timings", lines))

    if "BENCH_compress.json" in docs:
        sections.append(
            ("Compressed gossip: bytes-vs-loss Pareto", _pareto_lines(docs["BENCH_compress.json"]))
        )

    if "BENCH_serve.json" in docs:
        sections.append(
            ("Serving: latency vs staleness by router", _serve_lines(docs["BENCH_serve.json"]))
        )

    for name, doc in docs.items():
        records = doc.get("records", [])
        cols = _columns(records)
        rows = [[_fmt(rec.get(c, "")) for c in cols] for rec in records]
        meta = ", ".join(
            f"{k}={_fmt(v)}" for k, v in doc.items() if not isinstance(v, (list, dict))
        )
        lines = [meta, ""] if meta else []
        lines += _md_table(cols, rows)
        sections.append((name.removeprefix("BENCH_").removesuffix(".json"), lines))
    return sections


def run_sections(path: pathlib.Path) -> list[tuple[str, list[str]]]:
    """Sections for one telemetry run log (JSONL)."""
    with path.open() as fh:
        records = [json.loads(line) for line in fh if line.strip()]
    by_kind: dict[str, list[dict]] = {}
    for rec in records:
        by_kind.setdefault(rec.get("kind", "?"), []).append(rec)

    sections: list[tuple[str, list[str]]] = []
    for man in by_kind.pop("manifest", []):
        lines = _md_table(
            ["key", "value"],
            [[k, _fmt(v)] for k, v in man.items() if k not in ("kind", "config")],
        )
        cfg = man.get("config") or {}
        interesting = {k: v for k, v in cfg.items() if v not in (None, False)}
        if interesting:
            lines += ["", "Config (non-default):", ""]
            lines += _md_table(["option", "value"], [[k, _fmt(v)] for k, v in interesting.items()])
        sections.append(("Manifest", lines))
    for kind in ("round", "bin"):
        rows = by_kind.pop(kind, [])
        if not rows:
            continue
        cols = [c for c in _columns(rows) if c != "kind"]
        table = [[_fmt(rec.get(c, "")) for c in cols] for rec in rows]
        sections.append((f"History ({len(rows)} {kind} records)", _md_table(cols, table)))
    for kind, rows in by_kind.items():
        lines: list[str] = []
        for rec in rows:
            lines += _md_table(
                ["key", "value"], [[k, _fmt(v)] for k, v in rec.items() if k != "kind"]
            )
            lines.append("")
        sections.append((kind, lines))
    return sections


def to_markdown(title: str, sections: list[tuple[str, list[str]]]) -> str:
    out = [f"# {title}", ""]
    for heading, lines in sections:
        out += [f"## {heading}", ""]
        out += lines
        out.append("")
    return "\n".join(out).rstrip() + "\n"


def to_html(title: str, sections: list[tuple[str, list[str]]]) -> str:
    """Markdown-ish sections → a self-contained HTML page (tables only —
    the report is tables and short paragraphs, no full markdown needed)."""
    parts = [
        "<!doctype html><html><head><meta charset='utf-8'>",
        f"<title>{html.escape(title)}</title>",
        "<style>body{font-family:sans-serif;margin:2em;max-width:72em}"
        "table{border-collapse:collapse;margin:1em 0}"
        "td,th{border:1px solid #bbb;padding:0.25em 0.6em;text-align:left;"
        "font-size:0.85em}th{background:#eee}h2{margin-top:1.6em}</style>",
        f"</head><body><h1>{html.escape(title)}</h1>",
    ]
    for heading, lines in sections:
        parts.append(f"<h2>{html.escape(heading)}</h2>")
        in_table = False
        for line in lines:
            bar = line.startswith("|") and line.endswith("|")
            if bar and set(line) <= {"|", "-"}:
                continue  # separator row
            if bar:
                cells = [c.strip() for c in line.strip("|").split("|")]
                tag = "th" if not in_table else "td"
                if not in_table:
                    parts.append("<table>")
                    in_table = True
                parts.append(
                    "<tr>" + "".join(f"<{tag}>{html.escape(c)}</{tag}>" for c in cells) + "</tr>"
                )
            else:
                if in_table:
                    parts.append("</table>")
                    in_table = False
                if line:
                    parts.append(f"<p>{html.escape(line)}</p>")
        if in_table:
            parts.append("</table>")
    parts.append("</body></html>")
    return "\n".join(parts) + "\n"


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument("--bench", metavar="DIR", help="render the BENCH_*.json artifacts under DIR")
    mode.add_argument("--run", metavar="JSONL", help="render one telemetry run log")
    ap.add_argument("--out-md", metavar="PATH", default=None)
    ap.add_argument("--out-html", metavar="PATH", default=None)
    args = ap.parse_args()
    if not args.out_md and not args.out_html:
        ap.error("give --out-md and/or --out-html")

    if args.bench:
        title = "Bench dashboard"
        sections = bench_sections(pathlib.Path(args.bench))
        if len(sections) <= 1 and not sections[0][1]:
            print(f"no BENCH_*.json under {args.bench}", file=sys.stderr)
            return 1
    else:
        title = f"Run log: {pathlib.Path(args.run).name}"
        sections = run_sections(pathlib.Path(args.run))

    for out, render in ((args.out_md, to_markdown), (args.out_html, to_html)):
        if out:
            pathlib.Path(out).write_text(render(title, sections))
            print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

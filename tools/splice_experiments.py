"""Re-splice the rendered dry-run/roofline tables into EXPERIMENTS.md.

Replaces everything between the '### §Dry-run summary' marker (or the
'<!-- DRYRUN_TABLE -->' placeholder) and the '## §Perf' heading with the
fresh render from results/dryrun.  Idempotent.
"""
import subprocess
import sys

EXP = "/root/repo/EXPERIMENTS.md"

render = subprocess.run(
    [sys.executable, "tools/render_tables.py", "results/dryrun"],
    capture_output=True, text=True, cwd="/root/repo",
    env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
)
tables = render.stdout
assert "§Roofline table" in tables, render.stderr[-500:]

exp = open(EXP).read()
start_markers = ["### §Dry-run summary", "<!-- DRYRUN_TABLE -->"]
start = -1
for m in start_markers:
    start = exp.find(m)
    if start != -1:
        break
end = exp.find("## §Perf")
assert start != -1 and end != -1 and start < end
# keep the roofline §-preamble? The render includes its own headings; insert
# the §Roofline prose header before its table.
roof_preamble = """
---

## §Roofline (deliverable g)

Terms per (arch × shape), single-pod mesh, per-chip: compute_s =
HLO_FLOPs/197e12, memory_s = bytes_accessed/819e9, collective_s =
Σ collective-operand-bytes/50e9; scan-corrected via unrolled small
lowerings + constrained polynomial extrapolation (launch/roofline.py —
see the caveats there and in DESIGN.md §10.1: memory terms are upper
bounds; decode cache writes counted as full rewrites).
MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (serve);
useful = MODEL_FLOPS / (HLO_FLOPs × chips) — NOTE it does not count
attention FLOPs, so long-context small-d_model combos read low by
construction.

"""
sections = tables.split("### §Roofline table")
dry_part = sections[0].strip()
roof_part = "### §Roofline table" + sections[1]
new = exp[:start] + dry_part + "\n" + roof_preamble + roof_part.strip() + "\n\n---\n\n" + exp[end:]
open(EXP, "w").write(new)
print("ok", len(new))

"""Render EXPERIMENTS.md tables from results/dryrun/*.json.

Usage: PYTHONPATH=src python tools/render_tables.py [results/dryrun]
Prints the §Dry-run and §Roofline markdown tables + memory notes.
"""
from __future__ import annotations

import glob
import json
import os
import sys

HBM_PER_CHIP = 16e9  # v5e


def fmt_bytes(b: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if b < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    recs = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            recs.append((os.path.basename(f), json.load(fh)))

    base = [
        (n, r)
        for n, r in recs
        if not r.get("variant") and (r.get("mixing") in (None, "dense")) and "__rebase" not in n
    ]
    sp = [(n, r) for n, r in base if r["mesh"] == "pod16x16"]
    mp = [(n, r) for n, r in base if r["mesh"] == "pod2x16x16"]

    print("### §Dry-run summary\n")
    print(f"single-pod combos: {len(sp)} ({sum(1 for _, r in sp if r['status']=='ok')} ok); "
          f"multi-pod combos: {len(mp)} ({sum(1 for _, r in mp if r['status']=='ok')} ok)\n")
    print("| arch | shape | mesh | compile | status | args/chip | temps/chip | fits v5e? |")
    print("|---|---|---|---|---|---|---|---|")
    for name, r in base:
        mem = r.get("memory_analysis", {})
        args_b = mem.get("argument_size_in_bytes", 0)
        temp_b = mem.get("temp_size_in_bytes", 0)
        tot = args_b + temp_b
        fits = "yes" if tot and tot < HBM_PER_CHIP else ("NO" if tot else "?")
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('lower_compile_s','-')}s "
            f"| {r['status']} | {fmt_bytes(args_b)} | {fmt_bytes(temp_b)} | {fits} |"
        )

    print("\n### §Roofline table (single-pod, per-chip, scan-corrected)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant | MODEL_FLOPS | useful |")
    print("|---|---|---|---|---|---|---|---|")
    for name, r in sp:
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — | ERROR | — | — |")
            continue
        t = r["terms"]
        print(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2e} | {t['memory_s']:.2e} "
            f"| {t['collective_s']:.2e} | **{t['dominant']}** | {r['model_flops']:.2e} "
            f"| {r['useful_flops_ratio']:.2f} |"
        )

    print("\n### Variant / optimised runs (§Perf)\n")
    var = [(n, r) for n, r in recs if r.get("variant") or (r.get("mixing") not in (None, "dense"))]
    if var:
        print("| arch | shape | variant | compute_s | memory_s | collective_s | dominant |")
        print("|---|---|---|---|---|---|---|")
        for name, r in var:
            tag = ";".join(f"{k}={v}" for k, v in (r.get("variant") or {}).items())
            if r.get("mixing") not in (None, "dense"):
                tag = (tag + ";" if tag else "") + f"mixing={r['mixing']}"
            if r["status"] != "ok":
                print(f"| {r['arch']} | {r['shape']} | {tag} | — | — | — | ERROR |")
                continue
            t = r["terms"]
            print(
                f"| {r['arch']} | {r['shape']} | {tag} | {t['compute_s']:.2e} | {t['memory_s']:.2e} "
                f"| {t['collective_s']:.2e} | {t['dominant']} |"
            )

    # memory notes
    print("\n### Memory-fit notes\n")
    for name, r in sp:
        mem = r.get("memory_analysis", {})
        tot = mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
        if tot > HBM_PER_CHIP:
            print(f"* {r['arch']} × {r['shape']}: {fmt_bytes(tot)}/chip exceeds v5e 16 GB — "
                  f"needs ≥{-(-tot // HBM_PER_CHIP):.0f}× more chips or sharper sharding/quantisation.")


if __name__ == "__main__":
    main()

"""Offline lint fallback — the container-runnable subset of the CI ruff gate.

CI's ``lint`` job runs ``ruff check`` (rule set pinned in pyproject.toml)
plus ``ruff format --check``.  The dev container has no ruff and no network,
so this script re-implements the mechanical subset of the enforced rules on
the stdlib ``ast``/``tokenize`` — enough to keep the tree clean between CI
runs:

  F401  module-level import never used (``__init__.py`` re-export files and
        names listed in ``__all__`` are exempt)
  F541  f-string without any placeholder
  E711  ``== None`` / ``!= None`` comparison
  E712  ``== True`` / ``== False`` comparison
  E722  bare ``except:``
  E401  multiple imports on one line (``import a, b``)

Usage: python tools/lint.py [paths...]   (default: src tests benchmarks
tools examples).  Exit 1 on any finding, printing ruff-style locations.
"""
from __future__ import annotations

import ast
import pathlib
import sys

DEFAULT_PATHS = ["src", "tests", "benchmarks", "tools", "examples"]


def _module_all(tree: ast.Module) -> set[str]:
    names: set[str] = set()
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    if isinstance(node.value, (ast.List, ast.Tuple)):
                        names |= {
                            e.value
                            for e in node.value.elts
                            if isinstance(e, ast.Constant) and isinstance(e.value, str)
                        }
    return names


def _used_names(tree: ast.Module) -> set[str]:
    """Every ``Name`` load/store in the module (``a.b.c`` marks ``a`` used
    via the Name node at its root)."""
    return {node.id for node in ast.walk(tree) if isinstance(node, ast.Name)}


def check_file(path: pathlib.Path) -> list[str]:
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:  # E9: syntax errors always fail
        return [f"{path}:{e.lineno}: E999 syntax error: {e.msg}"]
    lines = src.splitlines()
    noqa = {i + 1 for i, line in enumerate(lines) if "# noqa" in line}
    findings: list[str] = []
    exported = _module_all(tree)
    reexport_file = path.name == "__init__.py"
    used = _used_names(tree)

    # format specs (the ":.2f" in f"{x:.2f}") parse as nested JoinedStrs with
    # no placeholders of their own — they are not F541 candidates
    spec_ids = {
        id(node.format_spec)
        for node in ast.walk(tree)
        if isinstance(node, ast.FormattedValue) and node.format_spec is not None
    }

    imports: list[tuple[str, str, int]] = []  # (bound name, display, lineno)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if len(node.names) > 1:
                findings.append(f"{path}:{node.lineno}: E401 multiple imports on one line")
            for a in node.names:
                bound = (a.asname or a.name).split(".")[0]
                imports.append((bound, a.name, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                bound = a.asname or a.name
                imports.append((bound, f"{node.module}.{a.name}", node.lineno))
        elif isinstance(node, ast.ExceptHandler) and node.type is None:
            findings.append(f"{path}:{node.lineno}: E722 bare except")
        elif isinstance(node, ast.Compare):
            for op, right in zip(node.ops, node.comparators):
                if isinstance(op, (ast.Eq, ast.NotEq)) and isinstance(right, ast.Constant):
                    if right.value is None:
                        findings.append(f"{path}:{node.lineno}: E711 comparison to None")
                    elif right.value is True or right.value is False:
                        findings.append(f"{path}:{node.lineno}: E712 comparison to {right.value}")
        elif isinstance(node, ast.JoinedStr):
            if id(node) not in spec_ids and not any(
                isinstance(v, ast.FormattedValue) for v in node.values
            ):
                findings.append(f"{path}:{node.lineno}: F541 f-string without placeholders")
    if not reexport_file:
        for bound, display, lineno in imports:
            if bound not in used and bound not in exported:
                findings.append(f"{path}:{lineno}: F401 {display!r} imported but unused")
    return [f for f in findings if int(f.split(":")[1]) not in noqa]


def main() -> int:
    roots = [pathlib.Path(p) for p in (sys.argv[1:] or DEFAULT_PATHS)]
    files: list[pathlib.Path] = []
    for r in roots:
        if r.is_file():
            files.append(r)
        elif r.is_dir():
            files.extend(sorted(r.rglob("*.py")))
    findings: list[str] = []
    for f in files:
        findings.extend(check_file(f))
    for line in findings:
        print(line)
    print(f"{len(findings)} finding(s) across {len(files)} files")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

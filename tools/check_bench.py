"""Validate that the checked-in BENCH_*.json artifacts stay parseable.

CI runs this so a benchmark writer that drifts from the schema (or a bad
hand-edit) fails the build instead of silently breaking the roofline /
rendering tooling that consumes these files.

Usage: python tools/check_bench.py [repo_root]
"""
from __future__ import annotations

import json
import pathlib
import sys

# per-file required keys: top level and per record
SCHEMAS = {
    "BENCH_mixing.json": (["records"], ["family", "n", "d", "us_dense"]),
    "BENCH_rounds.json": (["records"], ["config", "n_nodes", "rounds", "sec_executor"]),
    "BENCH_estimates.json": (
        ["records", "rounds_block"],
        ["family", "n", "us_dense", "us_sparse", "sparse_speedup_vs_dense"],
    ),
    "BENCH_churn.json": (
        ["records"],
        ["family", "n", "k_plans", "churn_rate", "sec_per_round_schedule",
         "overhead_vs_static"],
    ),
}
DEFAULT_SCHEMA = (["records"], [])


def check_file(path: pathlib.Path) -> list[str]:
    errors: list[str] = []
    try:
        doc = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path.name}: unreadable ({e})"]
    if not isinstance(doc, dict):
        return [f"{path.name}: top level must be an object, got {type(doc).__name__}"]
    top_keys, rec_keys = SCHEMAS.get(path.name, DEFAULT_SCHEMA)
    for k in top_keys:
        if k not in doc:
            errors.append(f"{path.name}: missing top-level key {k!r}")
    records = doc.get("records", [])
    if not isinstance(records, list) or not records:
        errors.append(f"{path.name}: 'records' must be a non-empty list")
        return errors
    for i, rec in enumerate(records):
        if not isinstance(rec, dict):
            errors.append(f"{path.name}: records[{i}] is not an object")
            continue
        for k in rec_keys:
            if k not in rec:
                errors.append(f"{path.name}: records[{i}] missing {k!r}")
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1]) if len(sys.argv) > 1 else pathlib.Path(__file__).resolve().parent.parent
    paths = sorted(root.glob("BENCH_*.json"))
    if not paths:
        print(f"no BENCH_*.json found under {root}", file=sys.stderr)
        return 1
    errors: list[str] = []
    for p in paths:
        errs = check_file(p)
        errors.extend(errs)
        n_rec = "-" if errs else len(json.loads(p.read_text())["records"])
        print(f"{p.name}: {'FAIL' if errs else 'ok'} ({n_rec} records)")
    for e in errors:
        print(f"  {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

"""Elastic membership (DESIGN.md §16): a 16-node k-regular swarm where 4
nodes arrive at round 50, re-derive the network size online via leaderless
sketches, and initialise uncoordinated mid-run — and the whole trajectory
survives a mid-run restart bit-identically (checkpoint → resume).

Run:  PYTHONPATH=src python examples/elastic_membership.py
"""
import os
import tempfile

import numpy as np
import jax

from repro.core import topology as T
from repro.core.commplan import compile_plan
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.core.membership import membership_schedule
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import (
    CheckpointPolicy,
    init_fl_state,
    make_eval_fn,
    run_elastic_trajectory,
)
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

N, JOIN, PER, ROUNDS, JOIN_ROUND, WARMUP = 16, 4, 128, 100, 50, 8
graph = T.random_k_regular(N, 6, seed=0)
plan = compile_plan(graph)
ds = mnist_like(N * PER + 512, seed=0)
parts = [np.arange(i * PER, (i + 1) * PER) for i in range(N)]
xs, ys = node_datasets(ds, parts)
test = (ds.x[-512:], ds.y[-512:])
loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
opt = sgd(1e-3, 0.5)
sched = batch_index_schedule(PER, N, 16, ROUNDS * 2, seed=0)

# initial members use the perfect-knowledge gain; the late cohort gets NO
# coordination — each joiner sketches n̂ over the live gossip population
# during warmup and initialises from its own estimate (√n̂, §4.4 size-only)
gain = gain_from_graph(graph)
init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k)
init_one_g = lambda k, gn: init_mlp(InitConfig("he_normal", gn), k)
mem = membership_schedule(
    N, ROUNDS, initial=N - JOIN,
    arrivals={JOIN_ROUND: list(range(N - JOIN, N))}, join_warmup=WARMUP,
)
kw = dict(
    n_rounds=ROUNDS, eval_every=10, eval_fn=make_eval_fn(loss_fn),
    eval_batch=test, b_local=2, chunk_size=25, init_one=init_one_g,
)

print(f"{N - JOIN} nodes train from round 0; {JOIN} arrive at round "
      f"{JOIN_ROUND}, init at round {JOIN_ROUND + WARMUP}")
state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
final, hist, aux = run_elastic_trajectory(
    state, loss_fn, opt, plan, mem, xs, ys, sched, **kw
)
for i, r in enumerate(hist["round"]):
    print(f"round {r:3d}  train {hist['train_loss'][i]:.3f}  "
          f"test {hist['test_loss'][i]:.3f}  active {hist['n_active'][i]:2d}")
print(f"final online n̂ (true n = {N}): "
      f"mean {aux['n_hat'].mean():.1f}, spread "
      f"[{aux['n_hat'].min():.1f}, {aux['n_hat'].max():.1f}]")

# ---- the same trajectory, interrupted: checkpoint every chunk, restart
# from the round-50 snapshot, and land on bit-identical params
with tempfile.TemporaryDirectory() as d:
    s1 = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    run_elastic_trajectory(s1, loss_fn, opt, plan, mem, xs, ys, sched,
                           checkpoint=CheckpointPolicy(d, every=1), **kw)
    s2 = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    resumed, h2, _ = run_elastic_trajectory(
        s2, loss_fn, opt, plan, mem, xs, ys, sched,
        resume_from=os.path.join(d, "step_00000001.ckpt"), **kw,
    )
bit = all(
    np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(final.params),
                    jax.tree_util.tree_leaves(resumed.params))
) and h2 == hist
print(f"restart at round 50 → resume: bit-identical = {bit}")

"""Failure resilience (paper Fig. 2): every link/node is only active with
probability p each round — inactive nodes keep training locally.

Run:  PYTHONPATH=src python examples/failure_resilience.py
"""
import numpy as np
import jax

from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import mnist_like, node_batch_iterator, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, train_loop
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

N, PER, ROUNDS = 16, 128, 30
graph = T.complete(N)
ds = mnist_like(N * PER + 512, seed=0)
parts = [np.arange(i * PER, (i + 1) * PER) for i in range(N)]
xs, ys = node_datasets(ds, parts)
test = (ds.x[-512:], ds.y[-512:])
loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
opt = sgd(1e-3, 0.5)
eval_fn = make_eval_fn(loss_fn)


def batches():
    it = node_batch_iterator(xs, ys, 16, seed=0)
    while True:
        bs = [next(it) for _ in range(4)]
        yield (np.stack([b.x for b in bs], 1), np.stack([b.y for b in bs], 1))


print(f"{'failure mode':16s} {'p':>5s} {'He final':>9s} {'proposed final':>15s}")
for mode in ("link", "node"):
    for p in (0.2, 0.5, 1.0):
        finals = {}
        for label, gain in (("he", 1.0), ("proposed", gain_from_graph(graph))):
            kw = {"link_p": p} if mode == "link" else {"node_p": p}
            init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k)
            state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
            state, hist = train_loop(
                state, make_round_fn(loss_fn, opt, graph, **kw), batches(),
                n_rounds=ROUNDS, eval_every=ROUNDS - 1, eval_fn=eval_fn, eval_batch=test,
            )
            finals[label] = hist["test_loss"][-1]
        print(f"{mode:16s} {p:5.2f} {finals['he']:9.3f} {finals['proposed']:15.3f}")

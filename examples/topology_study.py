"""Topology study: how the communication network shapes DFL.

For several network families at n = 16 this example reports
    · ‖v_steady‖ (the compression factor → the init gain),
    · spectral gap and the mixing-time estimate (stabilisation rounds, §4.5),
    · the resulting test-loss trajectory with the corrected init.

Run:  PYTHONPATH=src python examples/topology_study.py
"""
import numpy as np
import jax

from repro.core import mixing as M
from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import mnist_like, node_batch_iterator, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, train_loop
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

N, PER, ROUNDS = 16, 128, 30

GRAPHS = {
    "complete": T.complete(N),
    "4-regular": T.random_k_regular(N, 4, seed=0),
    "barabasi-albert m=4": T.barabasi_albert(N, 4, seed=0),
    "ring": T.ring(N),
    "torus 4x4": T.torus_lattice((4, 4)),
}

ds = mnist_like(N * PER + 512, seed=0)
parts = [np.arange(i * PER, (i + 1) * PER) for i in range(N)]
xs, ys = node_datasets(ds, parts)
test = (ds.x[-512:], ds.y[-512:])
loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
opt = sgd(1e-3, 0.5)
eval_fn = make_eval_fn(loss_fn)

print(f"{'topology':22s} {'‖v_steady‖':>11s} {'gain':>6s} {'gap':>7s} {'t_mix':>6s}  final test loss")
for name, graph in GRAPHS.items():
    vnorm = M.v_steady_norm(graph)
    gain = gain_from_graph(graph)
    gap = M.spectral_gap(graph)
    tmix = M.mixing_time_estimate(graph)

    def batches():
        it = node_batch_iterator(xs, ys, 16, seed=0)
        while True:
            bs = [next(it) for _ in range(4)]
            yield (np.stack([b.x for b in bs], 1), np.stack([b.y for b in bs], 1))

    init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k)
    state = init_fl_state(jax.random.PRNGKey(0), N, init_one, opt)
    state, hist = train_loop(
        state, make_round_fn(loss_fn, opt, graph), batches(), n_rounds=ROUNDS,
        eval_every=ROUNDS - 1, eval_fn=eval_fn, eval_batch=test,
    )
    print(f"{name:22s} {vnorm:11.4f} {gain:6.2f} {gap:7.4f} {tmix:6.1f}  {hist['test_loss'][-1]:.4f}")

"""End-to-end train → route → serve (DESIGN.md §19).

DFL-trains a reduced qwen2.5-family decoder on synthetic token streams
(8 nodes, random 4-regular graph, gain-corrected init), then serves a
batch of generation requests two ways:

1. **consensus serving** — average the node ensemble into one artifact
   (``consensus_params``) and answer everything from it through the
   batched prefill→KV-insert→decode ``ServeEngine``;
2. **ensemble serving** — keep the per-node parameter stacks and let a
   ``Router`` assign each query a serving node (here: the consensus
   policy with equal clocks, which degrades gracefully to
   nearest-by-hops), answered via ``ServeEngine.serve``.

The two answer sets differ only by consensus noise — exactly the gap the
paper's σ-floor characterises.  For serving *interleaved with training*
(queries riding the gossip event scan against live, drifting node
parameters), see ``python -m repro.launch.serve``.

Run:  PYTHONPATH=src python examples/serve_consensus.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import make_token_stream, token_batch_iterator
from repro.fed import (
    ServeEngine,
    consensus_params,
    init_fl_state,
    make_round_fn,
    make_router,
    train_loop,
)
from repro.models import transformer as TF
from repro.optim import adamw

N_NODES, ROUNDS, SEQ = 8, 30, 48

cfg = get_reduced_config("qwen2.5-3b")
graph = T.random_k_regular(N_NODES, 4, seed=0)
icfg = InitConfig("trunc_normal", gain_from_graph(graph))
opt = adamw(3e-3)
print(f"arch={cfg.name} (reduced) graph={graph.name} gain={icfg.gain:.2f}")


def loss_fn(p, batch):
    x, y = batch
    hidden, aux = TF.forward(p, cfg, x)
    return TF.lm_loss(p, cfg, hidden, y) + 0.01 * aux


toks = np.stack([make_token_stream(20_000, cfg.vocab_size, seed=i) for i in range(N_NODES)])
it = token_batch_iterator(toks, batch_size=8, seq_len=SEQ, seed=0)


def batches():
    while True:
        b = next(it)
        yield (b.x[:, None], b.y[:, None])


state = init_fl_state(jax.random.PRNGKey(0), N_NODES, lambda k: TF.init_params(k, cfg, icfg), opt)
state, hist = train_loop(
    state, make_round_fn(loss_fn, opt, graph), batches(), n_rounds=ROUNDS, eval_every=5, progress=True
)

prompts = jnp.asarray(
    [make_token_stream(16, cfg.vocab_size, seed=100 + i)[:8] for i in range(4)], jnp.int32
)
engine = ServeEngine(cfg, cache_len=128)

print("\n[1] consensus serving (DecAvg average of the node ensemble)...")
params = consensus_params(state.params)
out = engine.generate(params, prompts, n_new=16)
for i in range(prompts.shape[0]):
    print(f"  req{i}: prompt={prompts[i].tolist()} -> {out[i].tolist()}")

print("\n[2] ensemble serving (router assigns each query a node)...")
router = make_router(graph, "consensus")
homes = jnp.arange(prompts.shape[0], dtype=jnp.int32) % N_NODES
clocks = jnp.zeros(N_NODES)  # post-training: every node equally fresh
assignments = jnp.stack(
    [
        router.route(homes[i], clocks, jnp.zeros(N_NODES), jax.random.PRNGKey(i))
        for i in range(prompts.shape[0])
    ]
)
out_nodes = engine.serve(state.params, assignments, prompts, n_new=16)
for i in range(prompts.shape[0]):
    agree = "==" if bool(jnp.all(out_nodes[i] == out[i])) else "!="
    print(f"  req{i}: node {int(assignments[i])} {agree} consensus -> {out_nodes[i].tolist()}")

"""End-to-end train → consensus → serve.

DFL-trains a reduced qwen2.5-family decoder on synthetic token streams
(8 nodes, random 4-regular graph, gain-corrected init), averages the node
ensemble into the consensus model, and serves a batch of generation
requests through the KV-cache decode path.

Run:  PYTHONPATH=src python examples/serve_consensus.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced_config
from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import make_token_stream, token_batch_iterator
from repro.fed import consensus_params, generate, init_fl_state, make_round_fn, train_loop
from repro.models import transformer as TF
from repro.optim import adamw

N_NODES, ROUNDS, SEQ = 8, 30, 48

cfg = get_reduced_config("qwen2.5-3b")
graph = T.random_k_regular(N_NODES, 4, seed=0)
icfg = InitConfig("trunc_normal", gain_from_graph(graph))
opt = adamw(3e-3)
print(f"arch={cfg.name} (reduced) graph={graph.name} gain={icfg.gain:.2f}")


def loss_fn(p, batch):
    x, y = batch
    hidden, aux = TF.forward(p, cfg, x)
    return TF.lm_loss(p, cfg, hidden, y) + 0.01 * aux


toks = np.stack([make_token_stream(20_000, cfg.vocab_size, seed=i) for i in range(N_NODES)])
it = token_batch_iterator(toks, batch_size=8, seq_len=SEQ, seed=0)


def batches():
    while True:
        b = next(it)
        yield (b.x[:, None], b.y[:, None])


state = init_fl_state(jax.random.PRNGKey(0), N_NODES, lambda k: TF.init_params(k, cfg, icfg), opt)
state, hist = train_loop(
    state, make_round_fn(loss_fn, opt, graph), batches(), n_rounds=ROUNDS, eval_every=5, progress=True
)

print("\nforming consensus model (DecAvg average of the node ensemble)...")
params = consensus_params(state.params)

prompts = jnp.asarray(
    [make_token_stream(16, cfg.vocab_size, seed=100 + i)[:8] for i in range(4)], jnp.int32
)
print(f"serving a batch of {prompts.shape[0]} requests (greedy, KV cache)...")
out = generate(params, cfg, prompts, n_new=16, cache_len=128)
for i in range(prompts.shape[0]):
    print(f"  req{i}: prompt={prompts[i].tolist()} -> {out[i].tolist()}")

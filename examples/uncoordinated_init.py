"""Truly uncoordinated initialisation: estimate → init → train, one program.

The paper's headline claim (§4.4) is that no node needs to *know* the
network: each derives its own gain ``‖v̂_steady‖⁻¹`` from gossip with its
neighbours.  This example makes that literal.  On a random 4-regular graph
with unreliable links (20% of edges drop per round), every node

  1. runs the on-device gossip engine (``repro.gossip``) for a small budget
     of power-iteration + push-sum rounds — over the same failure-prone
     links the training rounds will use,
  2. turns its own noisy estimates into its own init gain,
  3. draws its parameters with that gain and starts training —

with all three phases fused into a single jitted program by
``run_warmup_trajectory`` (no host round-trip between estimation and
training).  Compare against the perfect-knowledge gain and the unscaled He
baseline: even a tiny estimation budget recovers almost all of the benefit.

Run:  PYTHONPATH=src python examples/uncoordinated_init.py
"""
import jax
import numpy as np

from repro.core import topology as T
from repro.core.commplan import FailureModel, compile_plan
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.core.mixing import spectral_gap
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, run_trajectory, run_warmup_trajectory
from repro.gossip import convergence_report, make_gain_estimator
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

N_NODES, PER_NODE, ROUNDS, B_LOCAL, LINK_P = 16, 128, 40, 4, 0.8

graph = T.random_k_regular(N_NODES, 4, seed=0)
exact_gain = gain_from_graph(graph)
print(f"network: {graph.name}  spectral gap={spectral_gap(graph):.3f}  "
      f"exact ‖v_steady‖⁻¹ = {exact_gain:.2f}  link_p={LINK_P}\n")

ds = mnist_like(N_NODES * PER_NODE + 512, seed=0)
parts = [np.arange(i * PER_NODE, (i + 1) * PER_NODE) for i in range(N_NODES)]
xs, ys = node_datasets(ds, parts)
test = (ds.x[-512:], ds.y[-512:])
loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
opt = sgd(1e-3, momentum=0.5)
eval_fn = make_eval_fn(loss_fn)
icfg = InitConfig("he_normal", 1.0)
init_one_g = lambda k, gn: init_mlp(icfg.replace(gain=gn), k)
rf = make_round_fn(loss_fn, opt, graph, link_p=LINK_P)
sched = batch_index_schedule(PER_NODE, N_NODES, 16, ROUNDS * B_LOCAL, seed=0)
common = dict(n_rounds=ROUNDS, eval_every=10, eval_fn=eval_fn, eval_batch=test, b_local=B_LOCAL)

# how many gossip rounds does this topology need? ask the diagnostics
est_plan = compile_plan(graph, failures=FailureModel(link_p=LINK_P))
report = convergence_report(est_plan, 64, jax.random.PRNGKey(99))
print(f"gossip convergence: fitted rate {report['fitted_rate']:.3f} "
      f"(predicted |λ₂| = {report['predicted_rate']:.3f}), "
      f"1% error at round {report['rounds_to_1pct']}\n")

for label, budget in [("tiny budget (4 rounds)", 4), ("converged budget (32 rounds)", 32)]:
    estimate_fn = make_gain_estimator(est_plan, pi_rounds=budget, ps_rounds=budget)
    _, hist, gains = run_warmup_trajectory(
        jax.random.PRNGKey(0), rf, xs, ys, sched, n_nodes=N_NODES,
        init_one=init_one_g, optimizer=opt, estimate_gains=estimate_fn, **common,
    )
    print(f"{label:28s} per-node gains ∈ [{gains.min():.2f}, {gains.max():.2f}]  "
          f"final test loss {hist['test_loss'][-1]:.3f}")

for label, gain in [("perfect knowledge", exact_gain), ("He baseline (no correction)", 1.0)]:
    state = init_fl_state(jax.random.PRNGKey(0), N_NODES, init_one_g, opt,
                          gains=np.full(N_NODES, gain, np.float32))
    _, hist = run_trajectory(state, rf, xs, ys, sched, **common)
    print(f"{label:28s} gain {gain:.2f}  final test loss {hist['test_loss'][-1]:.3f}")

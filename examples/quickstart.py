"""Quickstart: the paper's effect in one minute.

Trains a 16-node decentralised federated MLP on synthetic MNIST-like data
with plain He initialisation (the paper's Fig. 1 dashed baseline, which
plateaus) and with the proposed ‖v_steady‖⁻¹ gain-corrected initialisation,
and prints both test-loss trajectories.  Both runs execute as ONE fused,
vmapped program via the round executor (`repro.fed.run_sweep`): the whole
trajectory pair is a single scan-over-rounds with on-device data sampling
and on-device eval.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_round_fn, run_sweep, stack_states
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.optim import sgd

N_NODES, PER_NODE, ROUNDS, B_LOCAL = 16, 128, 40, 4

graph = T.complete(N_NODES)  # paper cfg. A: fully-connected communication
gain = gain_from_graph(graph)
print(f"communication network: {graph.name};  ‖v_steady‖⁻¹ gain = {gain:.2f}\n")

ds = mnist_like(N_NODES * PER_NODE + 512, seed=0)
parts = [np.arange(i * PER_NODE, (i + 1) * PER_NODE) for i in range(N_NODES)]
xs, ys = node_datasets(ds, parts)
test = (ds.x[-512:], ds.y[-512:])

loss_fn = lambda p, b: classifier_loss(mlp_forward(p, b[0]), b[1])
opt = sgd(1e-3, momentum=0.5)
eval_fn = make_eval_fn(loss_fn)

variants = [("He et al. (uncorrected)", 1.0), ("proposed (gain-corrected)", gain)]
states = stack_states([
    init_fl_state(
        jax.random.PRNGKey(0), N_NODES,
        lambda k, g=g: init_mlp(InitConfig("he_normal", g), k), opt,
    )
    for _, g in variants
])
schedule = batch_index_schedule(PER_NODE, N_NODES, 16, ROUNDS * B_LOCAL, seed=0)
_, hists = run_sweep(
    states, make_round_fn(loss_fn, opt, graph), xs, ys, schedule,
    n_rounds=ROUNDS, eval_every=5, eval_fn=eval_fn, eval_batch=test,
    b_local=B_LOCAL,
)

for (label, _), hist in zip(variants, hists):
    traj = "  ".join(f"{v:.3f}" for v in hist["test_loss"])
    print(f"{label:28s} test loss @ rounds {hist['round']}:\n    {traj}\n")

print("note the plateau at log(10) ≈ 2.303 without the correction (paper Fig. 1).")

"""Decentralised federated training loop (paper Algorithm 1).

The node ensemble is *vectorised*: every parameter leaf carries a leading
node axis and all nodes step in one SPMD program (DESIGN.md §2).  One
communication round =

    1. ``b`` local minibatch steps per node        (Algorithm 1 lines 8–10)
    2. DecAvg aggregation over the graph           (line 14, Eq. 2)
    3. optimizer-state re-initialisation           (line 15)

The round function is model-agnostic: it takes any per-node
``loss_fn(params, batch) -> scalar`` and vmaps it over the node axis.  Under
``jax.jit`` with the node axis sharded over the mesh "data" axis this is the
production training step the dry-run lowers.

Failures (Fig. 2): pass ``link_p``/``node_p`` < 1 and a PRNG key; the
round rebuilds the effective receive matrix on-device each round.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commplan import CommPlan, FailureModel, PlanSchedule, compile_plan
from repro.core.compress import Compression, compressed_mix, init_residuals
from repro.core.topology import Graph
from repro.optim import Optimizer

PyTree = Any
LossFn = Callable[[PyTree, Any], jax.Array]

__all__ = ["DFLState", "init_fl_state", "make_round_fn", "make_eval_fn", "sigma_metrics", "train_loop"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DFLState:
    params: PyTree  # node-stacked: every leaf (n_nodes, ...)
    opt_state: PyTree
    round: jax.Array  # scalar int32
    rng: jax.Array
    # compressed-gossip carry (core.compress, DESIGN.md §18): each node's
    # transmitted mirror, params-shaped fp32.  None (the default) is an
    # *empty* pytree child — zero leaves, so uncompressed states flatten
    # exactly as before and existing checkpoints/scans are untouched.
    residual: PyTree | None = None

    def tree_flatten(self):
        return (self.params, self.opt_state, self.round, self.rng, self.residual), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def init_fl_state(
    key: jax.Array,
    n_nodes: int,
    init_one: Callable[..., PyTree],
    optimizer: Optimizer,
    gains: jax.Array | np.ndarray | None = None,
) -> DFLState:
    """Uncoordinated init: every node draws independently (distinct keys) —
    the paper's premise w_i ≠ w_j at t=0 (§3).

    ``gains``: optional (n,) per-node init gain vector (or scalar,
    broadcast) — each node's own ``‖v̂_steady‖⁻¹`` from its gossip estimates
    (§4.4, ``repro.gossip``).  When given, ``init_one`` must accept
    ``(key, gain)`` and apply the gain to its random draws (e.g.
    ``lambda k, g: init_mlp(icfg.replace(gain=g), k)``).  Without it the
    single-gain ``init_one(key)`` contract is unchanged.  Fully traceable,
    so the fused warmup can inline estimation → init → training in one
    program (``fed.executor.run_warmup_trajectory``).
    """
    keys = jax.random.split(key, n_nodes + 1)
    if gains is None:
        params = jax.vmap(init_one)(keys[:n_nodes])
    else:
        g = jnp.broadcast_to(jnp.asarray(gains, jnp.float32), (n_nodes,))
        params = jax.vmap(init_one)(keys[:n_nodes], g)
    opt_state = jax.vmap(optimizer.init)(params)
    return DFLState(params=params, opt_state=opt_state, round=jnp.zeros((), jnp.int32), rng=keys[-1])


def _local_steps(
    loss_fn: LossFn, optimizer: Optimizer, params: PyTree, opt_state: PyTree, batches: Any
) -> tuple[PyTree, PyTree, jax.Array]:
    """b sequential minibatch steps for ONE node. batches: leaves (b, ...)."""

    def step(carry, batch):
        p, s = carry
        loss, grads = jax.value_and_grad(loss_fn)(p, batch)
        updates, s = optimizer.update(grads, s, p)
        p = jax.tree_util.tree_map(lambda a, u: (a + u.astype(a.dtype)), p, updates)
        return (p, s), loss

    (params, opt_state), losses = jax.lax.scan(step, (params, opt_state), batches)
    return params, opt_state, losses.mean()


def make_round_fn(
    loss_fn: LossFn,
    optimizer: Optimizer,
    plan: CommPlan | PlanSchedule | Graph,
    data_sizes: np.ndarray | None = None,
    link_p: float = 1.0,
    node_p: float = 1.0,
    reinit_opt: bool = True,
    aggregate: bool = True,
    compression: Compression | None = None,
):
    """Build the jittable communication-round function.

    ``plan`` is a compiled ``CommPlan`` (``core.commplan.compile_plan``) or a
    time-varying ``PlanSchedule`` (``compile_schedule``) — the round body
    then mixes with the plan active at ``state.round``, switching operators
    by round index *inside* any enclosing scan (DESIGN.md §13); a raw
    ``Graph`` is accepted for convenience and compiled with the "auto"
    backend.  ``data_sizes``/``link_p``/``node_p`` override the plan's own
    settings when given (the plan is recompiled, cheap and host-side).

    Returns ``round_fn(state, node_batches) -> (state, metrics)`` where
    ``node_batches`` leaves are (n_nodes, b, batch, ...): b local minibatches
    per node per round (Appendix A: b = 8).

    ``compression`` (an active ``core.compress.Compression``) switches the
    aggregation to the error-feedback delta form over the same plan
    operator; the per-node mirror rides ``state.residual`` (seeded lazily
    with zeros when absent — the fused executors seed it before their scan
    so the carry structure is static).  ``compression=None`` or codec
    ``"none"`` leaves the round body *bit-identical* to before.
    """
    failures = FailureModel(link_p=link_p, node_p=node_p)
    if isinstance(plan, Graph):
        plan = compile_plan(plan, backend="auto", data_sizes=data_sizes, failures=failures)
    elif failures.active or data_sizes is not None:
        # override only the knobs actually given: data_sizes alone must not
        # silently replace the plan's own failure model with the inactive one
        plan = plan.with_options(
            data_sizes=data_sizes, failures=failures if failures.active else None
        )
    scheduled = isinstance(plan, PlanSchedule)
    comp = compression if (compression is not None and compression.active) else None

    def round_fn(state: DFLState, node_batches: Any) -> tuple[DFLState, dict]:
        rng, k_mix = jax.random.split(state.rng)

        with jax.named_scope("dfl_local"):
            params, opt_state, losses = jax.vmap(
                partial(_local_steps, loss_fn, optimizer)
            )(state.params, state.opt_state, node_batches)

        residual = state.residual
        if aggregate:
            key = k_mix if plan.failures.active else None
            with jax.named_scope("dfl_mix"):
                if comp is not None:
                    if residual is None:  # legacy train_loop path (no seeding)
                        residual = init_residuals(params)
                    params, residual = compressed_mix(
                        plan, params, residual, key, compression=comp,
                        round_index=state.round if scheduled else None,
                    )
                elif scheduled:
                    params = plan.mix(params, state.round, key)
                else:
                    params = plan.mix(params, key=key)
            if reinit_opt:  # Algorithm 1 line 15
                opt_state = jax.vmap(optimizer.init)(params)

        new_state = DFLState(
            params=params, opt_state=opt_state, round=state.round + 1, rng=rng,
            residual=residual,
        )
        return new_state, {"train_loss": losses.mean(), "train_loss_per_node": losses}

    # the *effective* plan (overrides applied) — the executor's wire-cost
    # accountant reads it to count exactly the edges this round_fn mixes over;
    # the compression config rides along for codec-aware byte accounting
    round_fn.plan = plan if aggregate else None
    round_fn.compression = comp if aggregate else None
    return round_fn


def make_eval_fn(loss_fn: LossFn, batch_eval: bool = True):
    """Mean test loss of every node's model on the (global) test set —
    the paper's headline observable ("mean test cross-entropy loss")."""

    @jax.jit
    def eval_fn(params: PyTree, test_batch: Any) -> jax.Array:
        per_node = jax.vmap(lambda p: loss_fn(p, test_batch))(params)
        return per_node

    return eval_fn


def sigma_metrics(params: PyTree) -> dict[str, jax.Array]:
    """σ_an / σ_ap over the full node-stacked parameter matrix W (§3).

    σ_ap: mean over nodes of the std across that node's parameters;
    σ_an: mean over parameters of the std across nodes.

    Streaming per-leaf moment accumulation: equivalent to std over the
    concatenated (n, d_total) matrix but never materialises it, so the
    fused executor can run this every eval round on device for free.
    """
    leaves = [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in jax.tree_util.tree_leaves(params)]
    d_total = sum(l.shape[1] for l in leaves)
    # σ_ap: two-pass per-node moments accumulated across leaves
    mean_n = sum(l.sum(axis=1) for l in leaves) / d_total  # (n,)
    var_n = sum(((l - mean_n[:, None]) ** 2).sum(axis=1) for l in leaves) / d_total
    # σ_an: per-parameter std across nodes, reduced leaf by leaf
    an_sum = sum(jnp.std(l, axis=0).sum() for l in leaves)
    return {
        "sigma_ap": jnp.sqrt(var_n).mean(),
        "sigma_an": an_sum / d_total,
    }


def train_loop(
    state: DFLState,
    round_fn,
    batches: Iterable[Any],
    n_rounds: int,
    eval_every: int = 0,
    eval_fn=None,
    eval_batch=None,
    track_sigmas: bool = False,
    progress: bool = False,
) -> tuple[DFLState, dict[str, list]]:
    """Python-level driver (checkpoint hooks etc. live in launch/train.py).

    Legacy per-round-dispatch path; ``repro.fed.executor.run_trajectory`` is
    the fused equivalent (same round_fn, bit-identical results).  Metrics are
    collected as device scalars and converted to floats once at the end, so
    eval rounds no longer block the dispatch pipeline (unless ``progress``
    forces a readback to print).
    """
    jit_round = jax.jit(round_fn)
    jit_sigmas = jax.jit(sigma_metrics)
    history: dict[str, list] = {"round": [], "train_loss": [], "test_loss": [], "sigma_ap": [], "sigma_an": []}
    for r in range(n_rounds):
        state, metrics = jit_round(state, next(batches))
        if eval_every and (r % eval_every == 0 or r == n_rounds - 1):
            history["round"].append(r)
            history["train_loss"].append(metrics["train_loss"])
            if eval_fn is not None:
                tl = eval_fn(state.params, eval_batch)
                history["test_loss"].append(jnp.mean(tl))
            if track_sigmas:
                s = jit_sigmas(state.params)
                history["sigma_ap"].append(s["sigma_ap"])
                history["sigma_an"].append(s["sigma_an"])
            if progress:
                msg = f"round {r:4d} train {float(history['train_loss'][-1]):.4f}"
                if history["test_loss"]:
                    msg += f" test {float(history['test_loss'][-1]):.4f}"
                print(msg, flush=True)
    return state, {
        k: [float(v) if isinstance(v, jax.Array) else v for v in vs]
        for k, vs in history.items()
    }

"""Serving the consensus model (post-DFL deployment artifact).

After decentralised training converges, every node's parameters agree up to
the noise floor (σ_an → σ_noise, §4.2); the deployable model is the DecAvg
consensus — ``consensus_params`` below — served with standard
prefill + batched autoregressive decode.  These are the functions the
``prefill_32k`` / ``decode_32k`` / ``long_500k`` input shapes lower.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf

PyTree = Any

__all__ = ["consensus_params", "prefill", "decode_one", "generate"]


def consensus_params(node_params: PyTree, weights: jax.Array | None = None) -> PyTree:
    """Average the node ensemble into one deployable parameter set."""

    def avg(leaf):
        lf = leaf.astype(jnp.float32)
        if weights is None:
            out = lf.mean(axis=0)
        else:
            w = weights / weights.sum()
            out = jnp.tensordot(w, lf, axes=1)
        return out.astype(leaf.dtype)

    return jax.tree_util.tree_map(avg, node_params)


def prefill(
    params: PyTree, cfg: ArchConfig, tokens: jax.Array, frontend_embeds: jax.Array | None = None
) -> jax.Array:
    """Full-sequence forward → next-token logits for the LAST position only
    ((B, V)); full logits never materialise (vocab can be 262k)."""
    hidden, _ = tf.forward(params, cfg, tokens, frontend_embeds, remat=False)
    return tf.hidden_to_logits(params, cfg, hidden[..., -1:, :])[..., 0, :]


def decode_one(
    params: PyTree, cfg: ArchConfig, cache: PyTree, tokens: jax.Array, pos: jax.Array
) -> tuple[jax.Array, PyTree]:
    """ONE new token against a cache of ``cache_len`` — the decode_32k /
    long_500k step. tokens (B, 1), pos scalar absolute position."""
    return tf.decode_step(params, cfg, cache, tokens, pos)


def generate(
    params: PyTree,
    cfg: ArchConfig,
    prompt: jax.Array,
    n_new: int,
    cache_len: int,
    temperature: float = 0.0,
    rng: jax.Array | None = None,
) -> jax.Array:
    """Greedy/temperature sampling driver (example + integration tests).

    Prompt is consumed token-by-token through the decode path (simple and
    exact); production prefill would batch it — see ``prefill``.
    """
    b = prompt.shape[0]
    cache = tf.init_cache(cfg, (b,), cache_len)
    out = []
    step = jax.jit(tf.decode_step, static_argnums=(1,))
    pos = 0
    for t in range(prompt.shape[1] - 1):
        _, cache = step(params, cfg, cache, prompt[:, t : t + 1], jnp.asarray(pos))
        pos += 1
    tok = prompt[:, -1:]
    key = rng if rng is not None else jax.random.PRNGKey(0)
    for _ in range(n_new):
        logits, cache = step(params, cfg, cache, tok, jnp.asarray(pos))
        pos += 1
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        else:
            tok = logits[:, -1].argmax(-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)

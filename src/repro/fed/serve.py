"""Live serving of decentralised models: prefill→insert→decode engine plus
an interleaved train+serve event executor (DESIGN.md §19).

Decentralised training's end product is an *ensemble*: every node holds its
own parameters, equal only up to the consensus noise floor (§4.2).  This
module serves that ensemble two ways:

* **offline** — ``consensus_params`` collapses the ensemble into one
  deployable artifact; ``generate`` runs batched prefill (one full-sequence
  pass that also fills the decode cache — ``models.transformer.
  prefill_cache``) followed by a scanned decode loop, the whole thing one
  jitted program per (cfg, n_new, cache_len, temperature) signature;
* **live** — ``run_serve_trajectory`` merges an open-loop Poisson
  ``QueryStream`` into the gossip ``EventStream``'s sorted envelope and
  advances both through one ``lax.scan``: gossip events replay the *exact*
  training step of ``run_event_trajectory`` (shared ``_make_event_step``,
  failure keys folded on the gossip ordinal — so training is bit-identical
  to a serve-free run), and query events route to a node (``fed.router``),
  read its current parameters, and settle a queueing latency model on the
  same virtual clocks, with per-bin ``serve_latency`` / ``serve_staleness``
  channels riding the scan carry.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.commplan import CommPlan, compile_plan
from repro.core.topology import EventStream, Graph
from repro.models import transformer as tf
from repro.obs.health import staleness_histogram
from repro.obs.spec import BinChannel, BinSpec
from repro.obs.wirecost import param_row_bytes

from .executor import _STALE_BUCKETS, _as_round_schedule, _make_event_step
from .router import QueryStream, Router
from .trainer import DFLState

PyTree = Any

__all__ = [
    "consensus_params",
    "prefill",
    "decode_one",
    "generate",
    "generate_tokenwise",
    "ServeEngine",
    "run_serve_trajectory",
    "serve_summary",
]


def consensus_params(node_params: PyTree, weights: jax.Array | None = None) -> PyTree:
    """Average the node ensemble into one deployable parameter set."""

    def avg(leaf):
        lf = leaf.astype(jnp.float32)
        if weights is None:
            out = lf.mean(axis=0)
        else:
            w = weights / weights.sum()
            out = jnp.tensordot(w, lf, axes=1)
        return out.astype(leaf.dtype)

    return jax.tree_util.tree_map(avg, node_params)


def prefill(
    params: PyTree, cfg: ArchConfig, tokens: jax.Array, frontend_embeds: jax.Array | None = None
) -> jax.Array:
    """Full-sequence forward → next-token logits for the LAST position only
    ((B, V)); full logits never materialise (vocab can be 262k)."""
    hidden, _ = tf.forward(params, cfg, tokens, frontend_embeds, remat=False)
    return tf.hidden_to_logits(params, cfg, hidden[..., -1:, :])[..., 0, :]


def decode_one(
    params: PyTree, cfg: ArchConfig, cache: PyTree, tokens: jax.Array, pos: jax.Array
) -> tuple[jax.Array, PyTree]:
    """ONE new token against a cache of ``cache_len`` — the decode_32k /
    long_500k step. tokens (B, 1), pos scalar absolute position."""
    return tf.decode_step(params, cfg, cache, tokens, pos)


# ----------------------------------------------------------------- generate
@partial(jax.jit, static_argnames=("cfg", "n_new", "cache_len", "temperature"))
def _generate_impl(
    params: PyTree,
    cfg: ArchConfig,
    prompt: jax.Array,
    n_new: int,
    cache_len: int,
    temperature: float,
    rng: jax.Array,
) -> jax.Array:
    """Batched prefill → cache insert → scanned decode, one jitted program.

    The prompt is consumed by ONE full-sequence pass whose last-position
    logits are exactly what the old token-by-token loop saw after feeding
    ``prompt[:, -1:]`` at position S-1, and whose cache insert leaves the
    slots token-wise decode would have written — so sampling continues the
    identical key chain (split once per sampled token, temperature > 0).
    """
    s = prompt.shape[-1]

    def sample(logits, key):
        if temperature > 0:
            return jax.random.categorical(key, logits / temperature, axis=-1)
        return jnp.argmax(logits, axis=-1)

    logits0, cache = tf.prefill_cache(params, cfg, prompt, cache_len)
    rng, k0 = jax.random.split(rng)
    tok0 = sample(logits0, k0).astype(prompt.dtype)

    def step(carry, i):
        cache, tok, rng = carry
        logits, cache = tf.decode_step(params, cfg, cache, tok[..., None], s + i)
        rng, k = jax.random.split(rng)
        nxt = sample(logits[..., -1, :], k).astype(tok.dtype)
        return (cache, nxt, rng), nxt

    _, toks = jax.lax.scan(step, (cache, tok0, rng), jnp.arange(n_new - 1, dtype=jnp.int32))
    return jnp.concatenate([tok0[..., None], jnp.moveaxis(toks, 0, -1)], axis=-1)


def generate(
    params: PyTree,
    cfg: ArchConfig,
    prompt: jax.Array,
    n_new: int,
    cache_len: int,
    temperature: float = 0.0,
    rng: jax.Array | None = None,
) -> jax.Array:
    """Greedy/temperature sampling driver: one batched prefill + scanned
    decode, jitted once per (cfg, n_new, cache_len, temperature).

    ``generate_tokenwise`` is the old per-token reference path; the two are
    parity-tested (``tests/test_serve.py``)."""
    key = rng if rng is not None else jax.random.PRNGKey(0)
    return _generate_impl(params, cfg, prompt, int(n_new), int(cache_len), float(temperature), key)


def generate_tokenwise(
    params: PyTree,
    cfg: ArchConfig,
    prompt: jax.Array,
    n_new: int,
    cache_len: int,
    temperature: float = 0.0,
    rng: jax.Array | None = None,
) -> jax.Array:
    """Reference decode loop: prompt consumed token-by-token (the seed-era
    ``generate``), kept as the parity baseline for the prefill path."""
    b = prompt.shape[0]
    cache = tf.init_cache(cfg, (b,), cache_len)
    out = []
    step = jax.jit(tf.decode_step, static_argnums=(1,))
    pos = 0
    for t in range(prompt.shape[1] - 1):
        _, cache = step(params, cfg, cache, prompt[:, t : t + 1], jnp.asarray(pos))
        pos += 1
    tok = prompt[:, -1:]
    key = rng if rng is not None else jax.random.PRNGKey(0)
    for _ in range(n_new):
        logits, cache = step(params, cfg, cache, tok, jnp.asarray(pos))
        pos += 1
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None]
        else:
            tok = logits[:, -1].argmax(-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


class ServeEngine:
    """Batched prefill→insert→decode engine over per-node parameter stacks.

    One jitted program per (cfg, n_new, cache_len, temperature): ``generate``
    serves a batch against ONE parameter set (e.g. the consensus), ``serve``
    answers per-query assignments against a node-stacked ensemble by
    gathering each query's node parameters and vmapping the same program.
    """

    def __init__(self, cfg: ArchConfig, cache_len: int, temperature: float = 0.0):
        self.cfg = cfg
        self.cache_len = int(cache_len)
        self.temperature = float(temperature)

    def generate(self, params: PyTree, prompt: jax.Array, n_new: int, rng=None) -> jax.Array:
        key = rng if rng is not None else jax.random.PRNGKey(0)
        return _generate_impl(
            params, self.cfg, prompt, int(n_new), self.cache_len, self.temperature, key
        )

    def serve(
        self,
        node_params: PyTree,
        assignments: jax.Array,
        prompts: jax.Array,
        n_new: int,
        rng=None,
    ) -> jax.Array:
        """prompts (B, S) answered by the nodes in ``assignments`` (B,)."""
        key = rng if rng is not None else jax.random.PRNGKey(0)
        a = jnp.asarray(assignments, jnp.int32)
        per_q = jax.tree_util.tree_map(lambda l: l[a], node_params)
        keys = jax.random.split(key, prompts.shape[0])

        def one(p, t, k):
            return _generate_impl(
                p, self.cfg, t[None], int(n_new), self.cache_len, self.temperature, k
            )[0]

        return jax.vmap(one)(per_q, prompts, keys)


# ------------------------------------------------------- interleaved serving
def run_serve_trajectory(
    state: DFLState,
    loss_fn,
    optimizer,
    plan: CommPlan | Graph,
    stream: EventStream,
    queries: QueryStream,
    router: Router,
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    b_local: int,
    n_bins: int = 20,
    eval_fn=None,
    eval_batch=None,
    reinit_opt: bool = True,
    service_time: float = 0.05,
    hop_latency: float = 0.02,
    serve_fn: Callable[[PyTree, jax.Array], jax.Array] | None = None,
    query_xs: np.ndarray | None = None,
    chunk_events: int = 0,
    on_chunk=None,
) -> tuple[DFLState, dict[str, list], dict[str, np.ndarray], dict[str, np.ndarray]]:
    """Interleaved train+serve: one scan over the merged gossip+query envelope.

    Gossip events replay ``run_event_trajectory``'s step exactly (shared
    ``_make_event_step``; failure keys fold on the *gossip ordinal*, routing
    keys on the *query ordinal* of a split-off key) — so the training
    trajectory is invariant to the query load, and at qps = 0 bit-identical
    to a serve-free run.  Each query event, under ``lax.cond``:

    1. routes to a node ``v = router.route(home, t - clocks, wait, key)``
       — staleness read straight off the training carry's virtual clocks
       (the flight-recorder channel), queue wait off per-node busy-until
       times;
    2. settles the open-loop latency model
       ``latency = (start - t) + service_time + hop_latency · hops(home, v)``
       with ``start = max(t, busy[v])`` and ``busy[v] ← start + service_time``
       (single serving slot per node — serving competes with itself, not
       with training, which rides virtual time);
    3. optionally answers it: ``serve_fn(params_v, query_xs[qidx])`` runs
       the query payload through the routed node's *current* parameters
       inside the scan (scalar answer, recorded per query).

    Returns ``(final_state, hist, serve, aux)``: ``hist`` is the event
    executor's per-bin history plus ``queries`` / ``serve_latency`` /
    ``serve_staleness`` channels; ``serve`` holds per-query arrays (time,
    home, node, latency, staleness, hops, answer) in arrival order; ``aux``
    the per-node clocks / event counts / staleness histogram / busy times.
    """
    plan = compile_plan(plan) if isinstance(plan, Graph) else plan
    if plan.event_uv is None:
        raise ValueError("run_serve_trajectory needs an undirected, statically compiled plan")
    n_nodes = xs.shape[0]
    if plan.n != n_nodes:
        raise ValueError(f"plan has {plan.n} nodes but xs carries {n_nodes}")
    if abs(queries.horizon - stream.horizon) > 1e-6:
        raise ValueError("query stream and event stream must share one horizon")
    s = np.asarray(schedule)
    n_sched_rounds = (s.shape[0] // b_local) if s.ndim == 3 else s.shape[0]
    sched_d = jnp.asarray(_as_round_schedule(s, n_sched_rounds, b_local))
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)
    qx_d = None if query_xs is None else jnp.asarray(query_xs)

    # ---- host-side merge of the two sorted envelopes ---------------------
    env_g, env_q = stream.envelope, queries.envelope
    times = np.concatenate([np.asarray(stream.times), np.asarray(queries.times)])
    edges = np.concatenate([np.asarray(stream.edges, np.int32), np.full(env_q, -1, np.int32)])
    homes = np.concatenate([np.full(env_g, -1, np.int32), np.asarray(queries.homes, np.int32)])
    gidx = np.concatenate([np.arange(env_g), np.zeros(env_q)]).astype(np.int32)
    qord = np.concatenate([np.zeros(env_g), np.arange(env_q)]).astype(np.int32)
    qidx = np.concatenate([np.zeros(env_g, np.int32), np.asarray(queries.qidx, np.int32)])
    # stable: gossip precedes queries at equal times, and at qps = 0 the
    # merged arrays are exactly the gossip arrays (identity permutation)
    order = np.argsort(times, kind="stable")
    times, edges, homes = times[order], edges[order], homes[order]
    gidx, qord, qidx = gidx[order], qord[order], qidx[order]
    env = env_g + env_q
    has_serve = env_q > 0

    live_g = edges >= 0
    bins_np = np.clip((times / stream.horizon * n_bins).astype(np.int64), 0, n_bins - 1)
    do_eval_np = np.zeros(env, dtype=bool)
    if eval_fn is not None:
        for b in range(n_bins):
            hits = np.nonzero(live_g & (bins_np == b))[0]
            if len(hits):
                do_eval_np[hits[-1]] = True

    rng, base_key = jax.random.split(state.rng)
    event_step = _make_event_step(
        loss_fn,
        optimizer,
        plan,
        sched_d,
        n_sched_rounds,
        xs_d,
        ys_d,
        reinit_opt=reinit_opt,
        comp=None,
        base_key=base_key,
    )
    # routing keys live on a split-off key so query draws can never collide
    # with the failure-key folds off base_key itself
    k_route = jax.random.split(base_key)[1]

    bin_spec = BinSpec(
        n_bins,
        (
            BinChannel("loss_sum"),
            BinChannel("cnt"),
            BinChannel("stale_sum"),
            BinChannel("msg_cnt"),
            BinChannel("test_bin", fill=float("nan")),
            BinChannel("stale_hist", width=_STALE_BUCKETS),
            BinChannel("serve_lat_sum"),
            BinChannel("serve_stale_sum"),
            BinChannel("serve_cnt"),
        ),
    )
    horizon = float(stream.horizon)
    hops_f = router.hops
    null_out = (
        jnp.int32(-1),
        jnp.float32(0.0),
        jnp.float32(0.0),
        jnp.float32(0.0),
        jnp.float32(jnp.nan),
    )

    def gossip_case(operand):
        carry, inp = operand
        params, opt_state, counts, clocks, busy, acc = carry
        g, qn, qi, e, u, t, b, do_ev = inp
        params, opt_state, counts, clocks, _, (liv, loss_mean, stale, delivered) = (
            event_step(params, opt_state, counts, clocks, None, g, e, t)
        )
        livf = liv.astype(jnp.float32)
        acc = dict(acc)
        acc["loss_sum"] = acc["loss_sum"].at[b].add(loss_mean * livf)
        acc["stale_sum"] = acc["stale_sum"].at[b].add(stale * livf)
        acc["cnt"] = acc["cnt"].at[b].add(livf)
        acc["msg_cnt"] = acc["msg_cnt"].at[b].add(2.0 * delivered.astype(jnp.float32))
        sb = jnp.clip((stale / horizon * _STALE_BUCKETS).astype(jnp.int32), 0, _STALE_BUCKETS - 1)
        acc["stale_hist"] = acc["stale_hist"].at[sb].add(livf)
        if eval_fn is not None:
            acc["test_bin"] = jax.lax.cond(
                do_ev,
                lambda tb: tb.at[b].set(jnp.mean(eval_fn(params, eval_d)).astype(jnp.float32)),
                lambda tb: tb,
                acc["test_bin"],
            )
        return (params, opt_state, counts, clocks, busy, acc), null_out

    def serve_case(operand):
        carry, inp = operand
        params, opt_state, counts, clocks, busy, acc = carry
        g, qn, qi, e, u, t, b, do_ev = inp
        live = u >= 0
        livf = live.astype(jnp.float32)
        uu = jnp.maximum(u, 0)
        stale_all = t - clocks
        wait_all = jnp.maximum(busy - t, 0.0)
        v = router.route(uu, stale_all, wait_all, jax.random.fold_in(k_route, qn))
        start = jnp.maximum(t, busy[v])
        hops = hops_f[uu, v]
        latency = (start - t) + service_time + hop_latency * hops
        stale_v = t - clocks[v]
        busy = busy.at[v].set(jnp.where(live, start + service_time, busy[v]))
        if serve_fn is not None and qx_d is not None:
            node_p = jax.tree_util.tree_map(lambda l: l[v], params)
            ans = jnp.asarray(serve_fn(node_p, qx_d[qi]), jnp.float32)
        else:
            ans = jnp.float32(jnp.nan)
        acc = dict(acc)
        acc["serve_lat_sum"] = acc["serve_lat_sum"].at[b].add(latency * livf)
        acc["serve_stale_sum"] = acc["serve_stale_sum"].at[b].add(stale_v * livf)
        acc["serve_cnt"] = acc["serve_cnt"].at[b].add(livf)
        out = (
            jnp.where(live, v, -1).astype(jnp.int32),
            latency * livf,
            stale_v * livf,
            hops * livf,
            jnp.where(live, ans, jnp.nan),
        )
        return (params, opt_state, counts, clocks, busy, acc), out

    def body(carry, inp):
        if has_serve:
            u = inp[4]
            return jax.lax.cond(u >= 0, serve_case, gossip_case, (carry, inp))
        return gossip_case((carry, inp))

    @jax.jit
    def drive_chunk(carry, inp):
        return jax.lax.scan(body, carry, inp)

    carry = (
        state.params,
        state.opt_state,
        jnp.zeros(n_nodes, jnp.int32),
        jnp.zeros(n_nodes, jnp.float32),
        jnp.zeros(n_nodes, jnp.float32),
        bin_spec.init(),
    )
    inp_all = (
        jnp.asarray(gidx),
        jnp.asarray(qord),
        jnp.asarray(qidx),
        jnp.asarray(edges),
        jnp.asarray(homes),
        jnp.asarray(times, jnp.float32),
        jnp.asarray(bins_np, jnp.int32),
        jnp.asarray(do_eval_np),
    )
    size = env if chunk_events <= 0 else int(chunk_events)
    bounds = [(i0, min(i0 + size, env)) for i0 in range(0, env, size)]
    ys_chunks = []
    for ci, (i0, i1) in enumerate(bounds):
        carry, ys_c = drive_chunk(carry, tuple(a[i0:i1] for a in inp_all))
        ys_chunks.append(ys_c)
        if on_chunk is not None:
            on_chunk(ci, i0, i1, carry[5])
    params, opt_state, counts, clocks, busy, acc = carry
    ys_all = [np.concatenate([np.asarray(c[j]) for c in ys_chunks]) for j in range(5)]

    cnt_np = np.asarray(acc["cnt"])
    safe = np.maximum(cnt_np, 1.0)
    qcnt_np = np.asarray(acc["serve_cnt"])
    qsafe = np.maximum(qcnt_np, 1.0)
    width = stream.horizon / n_bins
    row_bytes = param_row_bytes(state.params)
    messages = [int(v) for v in np.asarray(acc["msg_cnt"])]
    hist = {
        "bin": list(range(n_bins)),
        "time": [float((b + 1) * width) for b in range(n_bins)],
        "train_loss": [float(v) for v in np.asarray(acc["loss_sum"]) / safe],
        "test_loss": [float(v) for v in np.asarray(acc["test_bin"])],
        "staleness": [float(v) for v in np.asarray(acc["stale_sum"]) / safe],
        "events": [int(v) for v in cnt_np],
        "messages": messages,
        "wire_bytes": [m * row_bytes for m in messages],
        "queries": [int(v) for v in qcnt_np],
        "serve_latency": [float(v) for v in np.asarray(acc["serve_lat_sum"]) / qsafe],
        "serve_staleness": [float(v) for v in np.asarray(acc["serve_stale_sum"]) / qsafe],
    }
    qpos = np.nonzero(homes >= 0)[0]
    serve = {
        "time": times[qpos].astype(np.float64),
        "home": homes[qpos].astype(np.int64),
        "node": ys_all[0][qpos].astype(np.int64),
        "latency": ys_all[1][qpos].astype(np.float64),
        "staleness": ys_all[2][qpos].astype(np.float64),
        "hops": ys_all[3][qpos].astype(np.float64),
        "answer": ys_all[4][qpos].astype(np.float64),
    }
    final = DFLState(
        params=params,
        opt_state=opt_state,
        round=state.round + jnp.int32(stream.n_events),
        rng=rng,
        residual=None,
    )
    aux = {
        "node_clock": np.asarray(clocks),
        "node_events": np.asarray(counts),
        "node_busy": np.asarray(busy),
        "staleness_hist": staleness_histogram(acc["stale_hist"], horizon),
    }
    return final, hist, serve, aux


def serve_summary(serve: dict[str, np.ndarray]) -> dict[str, float]:
    """Headline latency/staleness stats of one ``run_serve_trajectory`` run."""
    lat = np.asarray(serve["latency"], np.float64)
    if lat.size == 0:
        return {
            "served": 0,
            "p50_latency": 0.0,
            "p95_latency": 0.0,
            "mean_latency": 0.0,
            "mean_staleness": 0.0,
            "mean_hops": 0.0,
        }
    return {
        "served": int(lat.size),
        "p50_latency": float(np.percentile(lat, 50)),
        "p95_latency": float(np.percentile(lat, 95)),
        "mean_latency": float(lat.mean()),
        "mean_staleness": float(np.asarray(serve["staleness"]).mean()),
        "mean_hops": float(np.asarray(serve["hops"]).mean()),
    }

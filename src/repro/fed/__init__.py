"""Decentralised federated runtime: vectorised node-ensemble trainer + serving."""
from .executor import (
    CheckpointPolicy,
    TrajectoryConfig,
    run_elastic_trajectory,
    run_event_trajectory,
    run_sharded_trajectory,
    run_sweep,
    run_trajectory,
    run_warmup_sweep,
    run_warmup_trajectory,
    stack_states,
    unstack_states,
)
from .router import QueryStream, Router, hop_matrix, make_router, poisson_query_stream
from .serve import (
    ServeEngine,
    consensus_params,
    decode_one,
    generate,
    generate_tokenwise,
    prefill,
    run_serve_trajectory,
    serve_summary,
)
from .trainer import DFLState, init_fl_state, make_eval_fn, make_round_fn, sigma_metrics, train_loop

"""Fused multi-round executor: a whole DFL trajectory as one scanned program.

``fed.trainer.train_loop`` dispatches one jitted round per Python iteration,
re-assembles every node's minibatch on the host, and blocks on device→host
syncs at every eval — at the paper's scales dispatch and host overhead
dominate everything the benchmarks measure.  This module fuses the entire
trajectory (DESIGN.md §11):

* **scan over rounds** — ``n_rounds`` of local-steps → CommPlan mixing →
  opt reinit run as chunked ``lax.scan`` inside a single jitted,
  buffer-donated call; Python re-enters once per *chunk*, not per round.
* **on-device data sampling** — the per-node datasets live on device and
  each round's minibatches are taken by gather from the precomputed
  ``data.pipeline.batch_index_schedule`` (bit-identical order to the host
  iterator for the same seed).
* **on-device metrics** — periodic eval / σ_an/σ_ap are computed inside the
  scan under ``lax.cond`` and written to fixed-size per-round output
  buffers; the host touches them once, after the last chunk.  The channels
  route through ``repro.obs`` (``MetricsSpec``/``Recorder``, DESIGN.md §17)
  — bit-identical to the hand-rolled outs they replaced — and every
  executor reports per-round wire cost (messages / bytes) alongside loss.
* **sweep axis** — ``run_sweep`` vmaps the whole scanned trajectory over a
  leading run axis (seeds × gains × ...), so a figure's grid of trajectories
  compiles to a handful of programs.
* **warmup phase** — ``run_warmup_trajectory`` prepends the uncoordinated-
  init estimation phase (``repro.gossip``): gossip estimates → per-node
  gains → vmapped init → first training chunk, fused as one program
  (DESIGN.md §12).

``round_fn`` is exactly the function ``make_round_fn`` builds — the executor
re-uses it unchanged, which is what makes executor-vs-legacy parity
bit-exact (same PRNG stream, same batch order, same round body).
"""
from __future__ import annotations

import dataclasses
import os
import signal
from functools import partial
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.checkpoint.io import restore_train_state, save_train_state
from repro.core.commplan import CommPlan, PlanSchedule, compile_plan
from repro.core.compress import (
    Compression,
    compressed_mix,
    compressed_mix_with,
    init_residuals,
    seed_residual,
)
from repro.core.shardplan import ShardedCommPlan, _shard_map
from repro.core.topology import EventStream, Graph
from repro.obs.health import staleness_histogram
from repro.obs.spec import BinChannel, BinSpec, Channel, MetricsSpec, Recorder
from repro.obs.wirecost import (
    make_wire_fn,
    param_row_bytes,
    sharded_wire_per_round,
    static_wire_messages,
)

from .trainer import DFLState, _local_steps, init_fl_state, make_round_fn, sigma_metrics

PyTree = Any

# staleness-histogram buckets of the event executor (linear over [0, horizon])
_STALE_BUCKETS = 16

__all__ = [
    "CheckpointPolicy",
    "TrajectoryConfig",
    "run_trajectory",
    "run_sharded_trajectory",
    "run_event_trajectory",
    "run_elastic_trajectory",
    "run_warmup_trajectory",
    "run_warmup_sweep",
    "run_sweep",
    "stack_states",
    "unstack_states",
]


@dataclasses.dataclass(frozen=True)
class TrajectoryConfig:
    """Static knobs of a fused trajectory.

    ``eval_every`` matches ``train_loop``: metrics are recorded at rounds
    ``r % eval_every == 0`` plus the final round; 0 disables recording.
    ``chunk_size`` bounds rounds per jitted call (0 = auto): smaller chunks
    surface metrics earlier, larger ones amortise dispatch further.
    """

    n_rounds: int
    eval_every: int = 0
    track_sigmas: bool = False
    chunk_size: int = 0

    def eval_mask(self) -> np.ndarray:
        mask = np.zeros(self.n_rounds, dtype=bool)
        if self.eval_every:
            mask[:: self.eval_every] = True
            mask[-1] = True
        return mask

    def chunks(self) -> list[tuple[int, int]]:
        size = self.chunk_size
        if size <= 0:
            size = self.n_rounds if self.n_rounds <= 1024 else 256
        return [(r0, min(r0 + size, self.n_rounds)) for r0 in range(0, self.n_rounds, size)]


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    """Chunk-boundary checkpointing of a fused trajectory (DESIGN.md §16).

    After every ``every``-th chunk the executor snapshots the **full scan
    carry** (params, optimizer state, PRNG stream, data cursors, virtual
    clocks, metric accumulators) plus the realised per-chunk metric buffers
    into ``dir`` via the durable ``checkpoint.io`` layout, repointing LATEST
    and keeping the newest ``keep_last`` steps.  A later call with
    ``resume_from=dir`` replays the remaining chunks **bit-identically** —
    the chunk programs are pure functions of the restored carry.

    ``kill_after`` is the fault-injection hook (``core.faults.preemption``):
    chunk index after whose checkpoint the process SIGKILLs itself —
    uncatchable, mid-run, exactly the preemption the resume contract must
    survive.  -1 disables.
    """

    dir: str
    every: int = 1
    keep_last: int = 3
    kill_after: int = -1


def _save_chunk_ckpt(
    policy: CheckpointPolicy, chunk_idx: int, is_last: bool, carry, outs, meta: dict
) -> None:
    due = policy.every <= 1 or (chunk_idx + 1) % policy.every == 0
    if due or is_last or policy.kill_after == chunk_idx:
        payload = {
            "carry": [np.asarray(jax.device_get(l)) for l in jax.tree_util.tree_leaves(carry)],
            "outs": [[np.asarray(c) for c in o] for o in outs],
        }
        save_train_state(
            policy.dir, chunk_idx, payload,
            meta={**meta, "chunk": chunk_idx}, keep_last=policy.keep_last,
        )
    if policy.kill_after == chunk_idx:
        os.kill(os.getpid(), signal.SIGKILL)


def _load_resume(resume_from: str, meta_id: dict):
    """(payload, start_chunk) from a checkpoint dir, or None to start fresh.
    Every identity field recorded at save time must match the caller's —
    resuming under different trajectory knobs would not be a replay."""
    restored = restore_train_state(resume_from)
    if restored is None:
        return None
    payload, meta = restored
    for k, v in meta_id.items():
        if meta.get(k) != v:
            raise ValueError(
                f"checkpoint at {resume_from!r} was written with {k}={meta.get(k)!r}, "
                f"but this run has {k}={v!r} — resume must replay the same trajectory"
            )
    return payload, int(meta["chunk"]) + 1


def _restore_carry(template, payload) -> PyTree:
    """Rebuild the scan carry from checkpointed leaves, using the live
    template's treedef (NamedTuples and custom nodes round-trip exactly)."""
    treedef = jax.tree_util.tree_structure(template)
    leaves = payload["carry"]
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint carries {len(leaves)} leaves, live state wants {treedef.num_leaves}"
        )
    return jax.tree_util.tree_unflatten(treedef, [jnp.asarray(l) for l in leaves])


def stack_states(states: Sequence[DFLState]) -> DFLState:
    """Stack independent DFLStates into one with a leading sweep axis."""
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *states)


def unstack_states(states: DFLState) -> list[DFLState]:
    """Split a swept DFLState back into its independent runs."""
    n = int(jax.tree_util.tree_leaves(states)[0].shape[0])
    return [jax.tree_util.tree_map(lambda l: l[i], states) for i in range(n)]


def _as_round_schedule(
    schedule: np.ndarray, n_rounds: int, b_local: int | None = None
) -> np.ndarray:
    """(n_rounds·b, n, bs) or (n_rounds, n, b, bs) → (n_rounds, n, b, bs).

    Pass ``b_local`` to pin the local-steps-per-round split: an oversized
    flat schedule that happens to divide n_rounds would otherwise be
    silently reinterpreted as more local steps per round.
    """
    s = np.asarray(schedule)
    if s.ndim == 4:
        if s.shape[0] != n_rounds:
            raise ValueError(f"schedule rounds {s.shape[0]} != n_rounds {n_rounds}")
        if b_local is not None and s.shape[2] != b_local:
            raise ValueError(f"schedule b_local {s.shape[2]} != b_local {b_local}")
        return s
    if s.ndim != 3 or s.shape[0] % n_rounds:
        raise ValueError(
            f"schedule shape {s.shape} incompatible with n_rounds={n_rounds}"
        )
    b = s.shape[0] // n_rounds
    if b_local is not None and b != b_local:
        raise ValueError(
            f"schedule holds {s.shape[0]} batches = {b}/round over {n_rounds} "
            f"rounds, but b_local={b_local} was requested"
        )
    return s.reshape(n_rounds, b, s.shape[1], s.shape[2]).transpose(0, 2, 1, 3)


def _build_chunk_fn(
    round_fn,
    xs: jax.Array,
    ys: jax.Array,
    eval_fn,
    eval_batch,
    track_sigmas: bool,
    *,
    sweep: bool = False,
    schedule_mapped: bool = False,
    wire_fn=None,
):
    """Compile-once chunk executor: (state, sched_chunk, mask_chunk) →
    (state, per-round metric buffers).

    The buffers are the :class:`repro.obs.Recorder`'s channels — the legacy
    train/eval/σ set (bit-identical to the hand-rolled outs this replaced)
    plus, when ``wire_fn`` is given, the round's delivered-message count
    traced from the same ``k_mix`` the round consumes.  Returns
    ``(jitted chunk, donate, raw chunk, recorder)``.
    """
    n_nodes = xs.shape[0]
    node_idx = jnp.arange(n_nodes)[:, None]
    rec = Recorder(
        MetricsSpec.legacy(eval_fn is not None, track_sigmas, wire=wire_fn is not None)
    )

    def gather_batch(idx: jax.Array):
        # idx (n, b, bs) → ((n, b, bs, *feat), (n, b, bs))
        flat = idx.reshape(n_nodes, -1)
        bx = xs[node_idx, flat].reshape(idx.shape + xs.shape[2:])
        by = ys[node_idx, flat].reshape(idx.shape + ys.shape[2:])
        return bx, by

    def gated_metrics(params):
        vals = {}
        if eval_fn is not None:
            # Barriers keep the eval subgraph isolated from the round body so
            # it compiles like train_loop's standalone eval_fn.  XLA still
            # doesn't guarantee bit-identical lowering across programs: the
            # recorded test loss can differ from the legacy path by ~1 ulp
            # (the trajectory itself — params/PRNG/train metrics — is exact).
            # optimization_barrier has no vmap batching rule, so the swept
            # path goes without.
            barrier = (lambda x: x) if sweep else jax.lax.optimization_barrier
            with jax.named_scope("dfl_eval"):
                per_node = barrier(eval_fn(barrier(params), eval_batch))
            vals["test_loss"] = jnp.mean(per_node).astype(jnp.float32)
        if track_sigmas:
            s = sigma_metrics(params)
            vals["sigma_ap"] = s["sigma_ap"].astype(jnp.float32)
            vals["sigma_an"] = s["sigma_an"].astype(jnp.float32)
        return vals

    def body(state, per_round):
        idx, do_eval = per_round
        values = {}
        if wire_fn is not None:
            # replay the round's k_mix split before round_fn re-derives and
            # consumes it — pure bookkeeping, no PRNG stream is advanced
            _, k_mix = jax.random.split(state.rng)
            values["wire_messages"] = wire_fn(k_mix, state.round)
        state, metrics = round_fn(state, gather_batch(idx))
        values["train_loss"] = metrics["train_loss"].astype(jnp.float32)
        out = rec.step(values, gate=do_eval, gated_fn=gated_metrics, operand=state.params)
        return state, out

    def chunk_inner(state, sched_chunk, mask_chunk):
        return jax.lax.scan(body, state, (sched_chunk, mask_chunk))

    chunk = chunk_inner
    if sweep:
        chunk = jax.vmap(chunk_inner, in_axes=(0, 0 if schedule_mapped else None, None))
    # Donating the carried state lets XLA reuse the ensemble's buffers across
    # chunk calls (a no-op warning-free pass-through on CPU).  _drive_chunks
    # copies the caller's state before the first call so donation never
    # invalidates it (train_loop drop-in contract).  The raw *unvmapped*
    # chunk is returned too so the fused warmups (``run_warmup_trajectory``,
    # ``run_warmup_sweep``) can inline it after their estimation/init
    # prologues — the sweep re-vmaps the whole prologue+chunk composite.
    donate = jax.default_backend() != "cpu"
    return jax.jit(chunk, donate_argnums=(0,) if donate else ()), donate, chunk_inner, rec


def _finish_wire(hist: dict, wire_static, row_bytes: int) -> dict:
    """Attach the clean-path static message counts (no device buffer ever
    existed for them) and derive bytes-on-the-wire = messages × row bytes."""
    if wire_static is not None:
        hist["wire_messages"] = [int(wire_static[r]) for r in hist["round"]]
    if "wire_messages" in hist:
        hist["wire_bytes"] = [int(m) * row_bytes for m in hist["wire_messages"]]
    return hist


def _drive_chunks(
    chunk_fn, state, sched_d, mask_np, cfg, *,
    round_axis: int = 0, donate: bool = False, skip: int = 0, head_outs=(),
    checkpoint: CheckpointPolicy | None = None, ckpt_meta: dict | None = None,
    on_chunk=None,
):
    """Run the chunk schedule; one host sync, after the last chunk.

    ``skip``/``head_outs`` let a caller that already executed the first
    ``skip`` chunks through a different program (the fused warmup) — or a
    resumed run that restored them from a checkpoint — hand over their
    metric buffers and continue here.  ``sched_d`` may be any pytree of
    round-axis arrays (the elastic executor threads membership masks
    alongside the batch schedule).  With a ``checkpoint`` policy the carry
    and accumulated metric buffers snapshot at chunk boundaries — syncing
    the carry to host is the checkpoint's cost, paid only on saving chunks.

    ``on_chunk(ci, r0, r1, out)`` fires after every chunk call with the
    chunk's device metric buffers — the streaming/telemetry hook.  Reading
    them costs only that chunk's host transfer (the same one the final
    assembly would pay); without the hook nothing syncs until the end.
    """
    if donate:
        # first chunk call would otherwise donate (delete) the caller's state
        state = jax.tree_util.tree_map(jnp.copy, state)
    mask_d = jnp.asarray(mask_np)
    outs = list(head_outs)
    chunks = cfg.chunks()
    for ci in range(skip, len(chunks)):
        r0, r1 = chunks[ci]
        sched_c = jax.tree_util.tree_map(
            lambda a: jax.lax.slice_in_dim(a, r0, r1, axis=round_axis), sched_d
        )
        state, out = chunk_fn(state, sched_c, mask_d[r0:r1])
        outs.append(out)
        if on_chunk is not None:
            on_chunk(ci, r0, r1, out)
        if checkpoint is not None:
            _save_chunk_ckpt(
                checkpoint, ci, ci == len(chunks) - 1, state, outs, ckpt_meta or {}
            )
    n_cols = len(outs[0])
    cols = [
        np.concatenate([np.asarray(o[i]) for o in outs], axis=-1) for i in range(n_cols)
    ]
    return state, cols


def run_trajectory(
    state: DFLState,
    round_fn: Callable[[DFLState, Any], tuple[DFLState, dict]],
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    n_rounds: int,
    eval_every: int = 0,
    eval_fn=None,
    eval_batch=None,
    track_sigmas: bool = False,
    chunk_size: int = 0,
    b_local: int | None = None,
    checkpoint: CheckpointPolicy | None = None,
    resume_from: str | None = None,
    plan: CommPlan | PlanSchedule | None = None,
    on_chunk=None,
) -> tuple[DFLState, dict[str, list]]:
    """Run a full trajectory fused on device.  Drop-in for ``train_loop``:
    same ``round_fn``, same history dict, bit-identical results — minus the
    per-round dispatch, host batch assembly and per-eval blocking syncs.

    ``schedule`` is ``batch_index_schedule(...)`` output covering
    ``n_rounds × b_local`` minibatches (or already round-shaped
    ``(n_rounds, n, b, bs)``); give ``b_local`` to validate the split.

    ``checkpoint`` snapshots the carry at chunk boundaries; ``resume_from``
    restores the newest snapshot in that directory and replays the remaining
    chunks — the resumed run's final params and metric history are
    **bit-identical** to the uninterrupted run's (the preemption-safety
    contract, subprocess-kill-tested), because each chunk is a pure function
    of the restored carry.  Pass the *same* initial ``state``/arguments as
    the original run; with no checkpoint on disk the run starts fresh.

    Wire cost (DESIGN.md §17): the plan the round mixes over — read from
    ``round_fn.plan`` (``make_round_fn`` attaches it) or passed as ``plan=``
    — adds ``wire_messages`` / ``wire_bytes`` history channels.  Clean plans
    cost nothing (static host-side counts); under an active failure model
    the count is traced in-scan from the same ``k_mix`` the mix consumes.
    Hand-rolled round_fns without a plan simply record no wire channels.

    ``on_chunk(r0, r1, chunk_hist)`` streams each chunk's assembled history
    slice as it lands (the ``--log-every`` hook) — the only added sync is
    the chunk's own host transfer, paid early instead of at the end.
    """
    cfg = TrajectoryConfig(n_rounds, eval_every, track_sigmas, chunk_size)
    sched_d = jnp.asarray(_as_round_schedule(schedule, n_rounds, b_local))
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)
    eff_plan = plan if plan is not None else getattr(round_fn, "plan", None)
    wire_fn, wire_static = None, None
    if eff_plan is not None:
        if eff_plan.failures.active:
            wire_fn = make_wire_fn(eff_plan)
        else:
            wire_static = static_wire_messages(eff_plan, n_rounds)
    # compressed round_fns (make_round_fn(compression=...)) carry their codec:
    # the mirror seeds into the carry before the scan (static structure) and
    # wire bytes price at the codec's encoding, not the raw itemsize
    comp: Compression | None = getattr(round_fn, "compression", None)
    state = seed_residual(state, comp)
    row_bytes = param_row_bytes(
        state.params, codec_bytes=comp.leaf_row_bytes if comp is not None else None
    )
    chunk_fn, donate, _, rec = _build_chunk_fn(
        round_fn, xs_d, ys_d, eval_fn, eval_d, track_sigmas, wire_fn=wire_fn
    )
    meta_id = {
        "kind": "trajectory", "n_rounds": n_rounds, "eval_every": eval_every,
        "track_sigmas": track_sigmas, "chunk_size": cfg.chunk_size,
        "compressed": comp is not None,
    }
    mask_np = cfg.eval_mask()
    hook = None
    if on_chunk is not None:
        def hook(ci, r0, r1, out):
            del ci
            h = rec.assemble(mask_np[r0:r1], [np.asarray(c) for c in out])
            h["round"] = [r + r0 for r in h["round"]]
            on_chunk(r0, r1, _finish_wire(h, wire_static, row_bytes))
    skip, head_outs = 0, ()
    if resume_from is not None:
        resumed = _load_resume(resume_from, meta_id)
        if resumed is not None:
            payload, skip = resumed
            state = _restore_carry(state, payload)
            head_outs = [tuple(np.asarray(c) for c in o) for o in payload["outs"]]
    state, cols = _drive_chunks(
        chunk_fn, state, sched_d, mask_np, cfg, donate=donate,
        skip=skip, head_outs=head_outs, checkpoint=checkpoint, ckpt_meta=meta_id,
        on_chunk=hook,
    )
    hist = _finish_wire(rec.assemble(mask_np, cols), wire_static, row_bytes)
    return state, hist


def run_sharded_trajectory(
    state: DFLState,
    loss_fn,
    optimizer,
    plan: ShardedCommPlan,
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    n_rounds: int,
    eval_every: int = 0,
    eval_fn=None,
    eval_batch=None,
    track_sigmas: bool = False,
    reinit_opt: bool = True,
    b_local: int | None = None,
    compression: Compression | None = None,
) -> tuple[DFLState, dict[str, list]]:
    """Node-sharded fused trajectory: the whole round loop inside ONE
    ``shard_map`` over the plan's node mesh axis (DESIGN.md §15).

    The sharded sibling of ``run_trajectory``: parameter / optimizer stacks,
    the per-node dataset and the batch schedule enter as node-axis-sharded
    operands, each shard scans its ``nps`` nodes' local steps, mixing runs
    through the plan's halo-exchange collectives (``local_mix``), and every
    per-round metric reduces with ``psum`` — no (n, d) array is ever
    materialised on one device.  The round discipline (PRNG split, local
    steps, mix, optimizer reinit) replicates ``make_round_fn`` exactly, so
    final parameters are bit-identical to the single-device executor for
    the same inputs (the property ``tests/test_sharded_plan.py`` pins).

    Differences from ``run_trajectory``, both metric-only: scalar metrics
    reduce as ``psum(local sum)/n`` (a different summation order than one
    global ``mean``, ~1 ulp), and with ``track_sigmas`` the σ moments are
    computed every round (collectives cannot sit under ``lax.cond``) with
    non-eval rounds masked to NaN afterwards.

    ``plan`` must be a static ``ShardedCommPlan`` (``CommPlan.shard()``);
    schedules are not supported here.  ``eval_fn``/``eval_batch`` follow
    ``run_trajectory`` (the eval batch is replicated to every shard).

    ``compression`` runs the error-feedback delta form around the halo-
    exchange ``local_mix`` — mirrors are node-sharded exactly like params
    (compression is a per-node-row transform, so it needs no collective of
    its own), and the halo payload prices at the codec's encoding.
    """
    n_nodes = xs.shape[0]
    if plan.n != n_nodes:
        raise ValueError(f"plan has {plan.n} nodes but xs carries {n_nodes}")
    cfg = TrajectoryConfig(n_rounds, eval_every, track_sigmas, 0)
    sched_d = jnp.asarray(_as_round_schedule(schedule, n_rounds, b_local))
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)
    mesh, ax, nps, n = plan.mesh, plan.axis, plan.nps, plan.n
    tables, tab_specs = plan.mix_operands()
    has_eval = eval_fn is not None
    failures_active = plan.failures.active
    mask_np = cfg.eval_mask()
    node_idx = jnp.arange(nps)[:, None]
    comp = compression if (compression is not None and compression.active) else None

    def sharded_sigmas(params):
        # σ_ap: per-node moments are shard-local; σ_an needs cross-shard
        # per-parameter moments — two psum phases (sum, then centred sum)
        leaves = [
            l.reshape(l.shape[0], -1).astype(jnp.float32)
            for l in jax.tree_util.tree_leaves(params)
        ]
        d_total = sum(l.shape[1] for l in leaves)
        mean_n = sum(l.sum(axis=1) for l in leaves) / d_total
        var_n = sum(((l - mean_n[:, None]) ** 2).sum(axis=1) for l in leaves) / d_total
        ap = jax.lax.psum(jnp.sqrt(var_n).sum(), ax) / n
        an_sum = jnp.float32(0.0)
        for l in leaves:
            m = jax.lax.psum(l.sum(axis=0), ax) / n
            v = jax.lax.psum(((l - m[None, :]) ** 2).sum(axis=0), ax) / n
            an_sum = an_sum + jnp.sqrt(v).sum()
        return ap.astype(jnp.float32), (an_sum / d_total).astype(jnp.float32)

    def body(carry, per_round, xs_l, ys_l, t):
        if comp is not None:
            params, opt_state, rng, mirror = carry
        else:
            (params, opt_state, rng), mirror = carry, None
        idx, do_eval = per_round  # idx: (nps, b, bs) local slice of the schedule
        rng, k_mix = jax.random.split(rng)
        flat = idx.reshape(nps, -1)
        bx = xs_l[node_idx, flat].reshape(idx.shape + xs_l.shape[2:])
        by = ys_l[node_idx, flat].reshape(idx.shape + ys_l.shape[2:])
        params, opt_state, losses = jax.vmap(partial(_local_steps, loss_fn, optimizer))(
            params, opt_state, (bx, by)
        )
        key = k_mix if failures_active else None
        if comp is not None:
            # delta-form compressed halo mix: the mirror is shard-local (a
            # per-node-row transform), only h' rides the halo exchange
            params, mirror = compressed_mix_with(
                lambda q: plan.local_mix_any(q, key, t), params, mirror, comp
            )
        else:
            params = plan.local_mix_any(params, key, t)
        if reinit_opt:  # Algorithm 1 line 15
            opt_state = jax.vmap(optimizer.init)(params)
        metrics = [jax.lax.psum(losses.sum(), ax).astype(jnp.float32) / n]
        if has_eval:
            # local eval sum under cond (no collective inside the branch),
            # psum unconditionally: psum(NaN) = NaN keeps skip semantics
            local = jax.lax.cond(
                do_eval,
                lambda p: jnp.sum(eval_fn(p, eval_d)).astype(jnp.float32),
                lambda p: jnp.float32(jnp.nan),
                params,
            )
            metrics.append(jax.lax.psum(local, ax) / n)
        if track_sigmas:
            nan = jnp.float32(jnp.nan)
            ap, an = sharded_sigmas(params)
            metrics += [jnp.where(do_eval, ap, nan), jnp.where(do_eval, an, nan)]
        new_carry = (
            (params, opt_state, rng, mirror)
            if comp is not None
            else (params, opt_state, rng)
        )
        return new_carry, tuple(metrics)

    def traj(carry, sched, mask, xs_l, ys_l, t):
        def step(c, pr):
            return body(c, pr, xs_l, ys_l, t)

        return jax.lax.scan(step, carry, (sched, mask))

    pspecs = jax.tree_util.tree_map(
        lambda l: P(ax, *([None] * (l.ndim - 1))), state.params
    )
    ospecs = jax.tree_util.tree_map(
        lambda l: P(ax, *([None] * (l.ndim - 1))), state.opt_state
    )
    data_spec = lambda a: P(ax, *([None] * (a.ndim - 1)))  # noqa: E731
    n_metrics = 1 + int(has_eval) + 2 * int(track_sigmas)
    if comp is not None:
        carry0 = (
            state.params, state.opt_state, state.rng,
            state.residual if state.residual is not None
            else init_residuals(state.params),
        )
        cspecs = (pspecs, ospecs, P(), pspecs)
    else:
        carry0 = (state.params, state.opt_state, state.rng)
        cspecs = (pspecs, ospecs, P())
    f = _shard_map(
        traj,
        mesh=mesh,
        in_specs=(
            cspecs,
            P(None, ax, None, None),
            P(),
            data_spec(xs_d),
            data_spec(ys_d),
            tab_specs,
        ),
        out_specs=(cspecs, tuple(P() for _ in range(n_metrics))),
        check_rep=False,  # scalar outs are psum-replicated; the static checker
        # can't always prove it through scan+cond on older jax
    )
    carry, metrics = jax.jit(f)(
        carry0, sched_d, jnp.asarray(mask_np), xs_d, ys_d, tables
    )
    if comp is not None:
        params, opt_state, rng, mirror = carry
    else:
        (params, opt_state, rng), mirror = carry, None
    cols = [np.asarray(m) for m in metrics]
    # halo wire cost is a plan static (the cross-shard row set never changes
    # round to round), so the channels are host-side constants — no buffer
    rec = Recorder(MetricsSpec.legacy(has_eval, track_sigmas))
    hist = rec.assemble(
        mask_np, cols,
        constants=sharded_wire_per_round(
            plan, state.params,
            codec_bytes=comp.leaf_row_bytes if comp is not None else None,
        ),
    )
    final = DFLState(
        params=params, opt_state=opt_state,
        round=state.round + jnp.int32(n_rounds), rng=rng, residual=mirror,
    )
    return final, hist


def _make_event_step(
    loss_fn,
    optimizer,
    plan: CommPlan,
    sched_d: jax.Array,
    n_sched_rounds: int,
    xs_d: jax.Array,
    ys_d: jax.Array,
    *,
    reinit_opt: bool,
    comp: Compression | None,
    base_key: jax.Array,
):
    """One gossip event (local phase → pairwise mix → opt reinit → clocks)
    as a reusable traced step, shared by ``run_event_trajectory`` and the
    serving executor (``fed.serve.run_serve_trajectory``) so interleaving
    queries cannot change the training math.

    Returns ``step(params, opt_state, counts, clocks, mirror, i, e, t) ->
    (params, opt_state, counts, clocks, mirror, (liv, loss_mean, stale,
    delivered))``.  ``i`` is the event's ordinal in the *gossip* stream (the
    failure-key fold index), not its position in whatever envelope the
    caller scans — so the failure draws are invariant to interleaved
    non-gossip events.  ``mirror`` is the compression residual tree (pass
    ``None`` when ``comp`` is ``None``).
    """
    ep = plan.event_uv
    failures_active = plan.failures.active
    n_nodes = xs_d.shape[0]

    def step(params, opt_state, counts, clocks, mirror, i, e, t):
        liv = e >= 0
        uv = ep[jnp.maximum(e, 0)]  # (2,) endpoints (padding reads edge 0, masked below)

        # 1. local phase: both endpoints catch up by b_local minibatch steps
        cur = counts[uv] % n_sched_rounds
        idx = sched_d[cur, uv]  # (2, b, bs)
        batch = (xs_d[uv[:, None, None], idx], ys_d[uv[:, None, None], idx])
        pair_p = jax.tree_util.tree_map(lambda l: l[uv], params)
        pair_o = jax.tree_util.tree_map(lambda l: l[uv], opt_state)
        new_p, new_o, loss_pair = jax.vmap(partial(_local_steps, loss_fn, optimizer))(
            pair_p, pair_o, batch
        )
        new_p = jax.tree_util.tree_map(lambda a, old: jnp.where(liv, a, old), new_p, pair_p)
        new_o = jax.tree_util.tree_map(lambda a, old: jnp.where(liv, a, old), new_o, pair_o)
        params = jax.tree_util.tree_map(lambda l, nl: l.at[uv].set(nl), params, new_p)
        opt_state = jax.tree_util.tree_map(lambda l, nl: l.at[uv].set(nl), opt_state, new_o)

        # 2. pairwise exchange (failure draws keyed per event).  event_keep
        # here consumes the same key event_mix folds internally, so the
        # executor's bookkeeping sees exactly the draw that masked the
        # exchange: a failed exchange moves no model (and counts no
        # messages below), but the endpoints did wake and train.
        k = jax.random.fold_in(base_key, i) if failures_active else None
        delivered = (liv & plan.event_keep(k)) if failures_active else liv
        if comp is not None:
            upd = jnp.zeros(n_nodes, bool).at[uv].set(delivered)
            params, mirror = compressed_mix_with(
                lambda q: plan.event_mix(q, e, k), params, mirror, comp,
                update_mask=upd,
            )
        else:
            params = plan.event_mix(params, e, k)

        # 3. pairwise optimizer-state reinit (Algorithm 1 line 15)
        if reinit_opt:
            pair_after = jax.tree_util.tree_map(lambda l: l[uv], params)
            fresh = jax.vmap(optimizer.init)(pair_after)
            kept = jax.tree_util.tree_map(lambda l: l[uv], opt_state)
            fresh = jax.tree_util.tree_map(
                lambda a, old: jnp.where(liv, a, old), fresh, kept
            )
            opt_state = jax.tree_util.tree_map(
                lambda l, nl: l.at[uv].set(nl), opt_state, fresh
            )

        # 4. virtual clocks (staleness measured before the clocks move)
        stale = (t - clocks[uv]).mean()
        clocks = clocks.at[uv].set(jnp.where(liv, t, clocks[uv]))
        counts = counts.at[uv].add(jnp.where(liv, 1, 0))
        return params, opt_state, counts, clocks, mirror, (liv, loss_pair.mean(), stale, delivered)

    return step


def run_event_trajectory(
    state: DFLState,
    loss_fn,
    optimizer,
    plan: CommPlan | Graph,
    stream: EventStream,
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    b_local: int,
    n_bins: int = 20,
    eval_fn=None,
    eval_batch=None,
    reinit_opt: bool = True,
    chunk_events: int = 0,
    checkpoint: CheckpointPolicy | None = None,
    resume_from: str | None = None,
    on_chunk=None,
    compression: Compression | None = None,
) -> tuple[DFLState, dict[str, list], dict[str, np.ndarray]]:
    """Event-driven (asynchronous) DFL trajectory: no global round barrier.

    The coordination-free rendering of the round loop (DESIGN.md §14): the
    ``EventStream``'s per-edge Poisson clocks replace the synchronous
    barrier, and one ``lax.scan`` over the (time, edge) envelope runs, per
    event,

      1. a **local phase** — each endpoint takes ``b_local`` minibatch
         steps from its own cursor into the shared gather ``schedule``
         (wrapped modulo its length, so nodes never exhaust it);
      2. the **pairwise DecAvg exchange** ``CommPlan.event_mix`` (per-event
         failure draws keyed ``fold_in(rng, event_index)``; a failed draw
         moves no model and spends no messages, but the endpoints still
         trained — synchronous failed-link semantics);
      3. the pairwise analogue of Algorithm 1 line 15 — the two
         participants' optimizer states re-initialise.

    Per-node **virtual clocks** track each node's last participation time;
    an event's *staleness* is ``t − clock`` at its endpoints — how long the
    pair's models idled since they last moved.  Padding events (edge = -1)
    are the exact identity, so streams of different realised lengths share
    one compiled program.

    Metrics are bucketed into ``n_bins`` equal **wall-time bins** over
    ``stream.horizon`` (per-bin mean train loss / staleness / event and
    message counts; ``eval_fn`` runs once at each bin's last live event), so
    the history plots on the same axes as the synchronous fig1-style curves
    — bin b of a rate-1 stream is the budget-matched peer of synchronous
    round ``b · horizon / n_bins`` in transmitted messages.  Note the local
    phase is event-*triggered*: per unit time a node takes ``degree × b``
    local steps (vs ``b`` per synchronous round), which is why fig9 compares
    convergence per transmitted message, not per local step.

    Semantics knobs mirror ``make_round_fn``; ``plan`` may be a ``Graph``
    (compiled with the auto backend).  Returns ``(final_state, history,
    aux)`` with ``aux`` the per-node clocks/event counts.

    ``chunk_events`` bounds events per jitted call (0 = the whole envelope,
    the fully-fused default); the metric accumulators ride the scan carry,
    so chunking changes nothing numerically.  ``checkpoint``/``resume_from``
    follow ``run_trajectory``: the full carry (params, opt state, event
    counts = data cursors, virtual clocks, per-bin accumulators) snapshots
    at chunk boundaries and a resumed run — fed the *same* initial
    ``state`` — replays the remaining events bit-identically (the per-event
    failure key stream re-derives from ``state.rng``, not from the carry).

    ``compression`` compresses the *pairwise* exchange: the event's two
    endpoints transmit ``C(x − h)``, update their carried mirrors, and
    blend the mirrors — everyone else's rows (and an exchange the failure
    draw killed) stay untouched, mirrors included, because a node that
    transmitted nothing updated nobody's copy.
    """
    plan = compile_plan(plan) if isinstance(plan, Graph) else plan
    if plan.event_uv is None:
        raise ValueError("run_event_trajectory needs an undirected, statically compiled plan")
    n_nodes = xs.shape[0]
    if plan.n != n_nodes:
        raise ValueError(f"plan has {plan.n} nodes but xs carries {n_nodes}")
    s = np.asarray(schedule)
    n_sched_rounds = (s.shape[0] // b_local) if s.ndim == 3 else s.shape[0]
    sched_d = jnp.asarray(_as_round_schedule(s, n_sched_rounds, b_local))
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)

    # ---- static host realisation of the stream's metric structure --------
    env = stream.envelope
    live_np = stream.edges >= 0
    bins_np = np.clip(
        (stream.times / stream.horizon * n_bins).astype(np.int64), 0, n_bins - 1
    )
    do_eval_np = np.zeros(env, dtype=bool)
    if eval_fn is not None:
        for b in range(n_bins):
            hits = np.nonzero(live_np & (bins_np == b))[0]
            if len(hits):
                do_eval_np[hits[-1]] = True

    comp = compression if (compression is not None and compression.active) else None
    rng, base_key = jax.random.split(state.rng)
    event_step = _make_event_step(
        loss_fn, optimizer, plan, sched_d, n_sched_rounds, xs_d, ys_d,
        reinit_opt=reinit_opt, comp=comp, base_key=base_key,
    )

    # per-bin accumulators riding the scan carry (repro.obs.BinSpec): sums /
    # counts per wall-time bin, the set-style eval slot, and a fixed-width
    # staleness histogram over [0, horizon] (last bucket catches the tail)
    bin_spec = BinSpec(
        n_bins,
        (
            BinChannel("loss_sum"),
            BinChannel("cnt"),
            BinChannel("stale_sum"),
            BinChannel("msg_cnt"),
            BinChannel("test_bin", fill=float("nan")),
            BinChannel("stale_hist", width=_STALE_BUCKETS),
        ),
    )
    horizon = float(stream.horizon)

    def body(carry, inp):
        if comp is not None:
            params, opt_state, counts, clocks, acc, mirror = carry
        else:
            (params, opt_state, counts, clocks, acc), mirror = carry, None
        i, e, t, b, do_ev = inp
        params, opt_state, counts, clocks, mirror, (liv, loss_mean, stale, delivered) = (
            event_step(params, opt_state, counts, clocks, mirror, i, e, t)
        )
        livf = liv.astype(jnp.float32)

        # per-bin metric accumulation
        acc = dict(acc)
        acc["loss_sum"] = acc["loss_sum"].at[b].add(loss_mean * livf)
        acc["stale_sum"] = acc["stale_sum"].at[b].add(stale * livf)
        acc["cnt"] = acc["cnt"].at[b].add(livf)
        acc["msg_cnt"] = acc["msg_cnt"].at[b].add(2.0 * delivered.astype(jnp.float32))
        sb = jnp.clip(
            (stale / horizon * _STALE_BUCKETS).astype(jnp.int32), 0, _STALE_BUCKETS - 1
        )
        acc["stale_hist"] = acc["stale_hist"].at[sb].add(livf)
        if eval_fn is not None:
            acc["test_bin"] = jax.lax.cond(
                do_ev,
                lambda tb: tb.at[b].set(jnp.mean(eval_fn(params, eval_d)).astype(jnp.float32)),
                lambda tb: tb,
                acc["test_bin"],
            )
        out = (params, opt_state, counts, clocks, acc)
        return (out + (mirror,) if comp is not None else out), None

    @jax.jit
    def drive_chunk(carry, inp):
        carry, _ = jax.lax.scan(body, carry, inp)
        return carry

    state = seed_residual(state, comp)
    carry = (
        state.params,
        state.opt_state,
        jnp.zeros(n_nodes, jnp.int32),
        jnp.zeros(n_nodes, jnp.float32),
        bin_spec.init(),
    )
    if comp is not None:
        carry = carry + (state.residual,)
    inp_all = (
        jnp.arange(env, dtype=jnp.int32),
        jnp.asarray(stream.edges),
        jnp.asarray(stream.times),
        jnp.asarray(bins_np, jnp.int32),
        jnp.asarray(do_eval_np),
    )
    size = env if chunk_events <= 0 else int(chunk_events)
    bounds = [(i0, min(i0 + size, env)) for i0 in range(0, env, size)]
    meta_id = {
        "kind": "event", "env": env, "n_bins": n_bins,
        "chunk_events": size, "reinit_opt": bool(reinit_opt),
        "compressed": comp is not None,
    }
    skip = 0
    if resume_from is not None:
        resumed = _load_resume(resume_from, meta_id)
        if resumed is not None:
            payload, skip = resumed
            carry = _restore_carry(carry, payload)
    for ci in range(skip, len(bounds)):
        i0, i1 = bounds[ci]
        carry = drive_chunk(carry, tuple(a[i0:i1] for a in inp_all))
        if on_chunk is not None:
            on_chunk(ci, i0, i1, carry[4])
        if checkpoint is not None:
            _save_chunk_ckpt(checkpoint, ci, ci == len(bounds) - 1, carry, [], meta_id)
    if comp is not None:
        params, opt_state, counts, clocks, acc, mirror = carry
    else:
        (params, opt_state, counts, clocks, acc), mirror = carry, None
    cnt_np = np.asarray(acc["cnt"])
    safe = np.maximum(cnt_np, 1.0)
    width = stream.horizon / n_bins
    row_bytes = param_row_bytes(
        state.params, codec_bytes=comp.leaf_row_bytes if comp is not None else None
    )
    messages = [int(v) for v in np.asarray(acc["msg_cnt"])]
    hist = {
        "bin": list(range(n_bins)),
        "time": [float((b + 1) * width) for b in range(n_bins)],
        "train_loss": [float(v) for v in np.asarray(acc["loss_sum"]) / safe],
        "test_loss": [float(v) for v in np.asarray(acc["test_bin"])],
        "staleness": [float(v) for v in np.asarray(acc["stale_sum"]) / safe],
        "events": [int(v) for v in cnt_np],
        # delivered messages only: an exchange the failure draw killed moved
        # no model, so it spends none of the budget fig9 normalises by
        "messages": messages,
        "wire_bytes": [m * row_bytes for m in messages],
    }
    final = DFLState(
        params=params,
        opt_state=opt_state,
        round=state.round + jnp.int32(stream.n_events),
        rng=rng,
        residual=mirror,
    )
    aux = {
        "node_clock": np.asarray(clocks),
        "node_events": np.asarray(counts),
        "staleness_hist": staleness_histogram(acc["stale_hist"], horizon),
    }
    return final, hist, aux


def run_elastic_trajectory(
    state: DFLState,
    loss_fn,
    optimizer,
    plan: CommPlan | PlanSchedule | Graph,
    membership,
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    n_rounds: int,
    eval_every: int = 0,
    eval_fn=None,
    eval_batch=None,
    reinit_opt: bool = True,
    b_local: int | None = None,
    chunk_size: int = 0,
    init_one: Callable[[jax.Array, jax.Array], PyTree] | None = None,
    n_sketches: int = 32,
    faults=None,
    checkpoint: CheckpointPolicy | None = None,
    resume_from: str | None = None,
    on_chunk=None,
    compression: Compression | None = None,
) -> tuple[DFLState, dict[str, list], dict[str, np.ndarray]]:
    """Elastic-membership fused trajectory: nodes join, leave, crash — the
    static-envelope rendering of DESIGN.md §16.

    The scanned round body runs at the full n-node envelope every round;
    a ``core.membership.MembershipSchedule`` lowers to per-round masks that
    (a) freeze non-members' params/optimizer (their local phase computes
    and is discarded — static shapes, no recompilation), and (b) thread
    ``active=`` / ``edge_live=`` into the ``CommPlan`` operators, where the
    masked receive matrix renormalises members' rows over the live
    neighbourhood and turns non-members into identity rows.  A
    ``core.faults.FaultPlan`` ANDs its correlated outage masks into the
    same channel.

    Join protocol (§4.4 applied mid-run): an arriving node redraws Exp(1)
    sketches; every gossip-active node min-exchanges them each round
    (``spread_min`` riding the *same* per-round failure key as the training
    mix, so estimation shares training's links); after the membership's
    ``join_warmup`` rounds the joiner initialises **uncoordinated** via
    ``init_one(key, gain)`` with the size-only gain ``√n̂`` from its own
    online sketches — no leader, no barrier, nobody else pauses.

    A membership with no dynamics (``membership.trivial``) and no faults
    delegates to ``make_round_fn`` + ``run_trajectory`` — the zero-event
    path IS the static executor, bit for bit (the K = 1 contract applied to
    membership).  ``checkpoint``/``resume_from`` snapshot the full carry
    (params, opt state, PRNG, sketches) exactly like ``run_trajectory``.

    Returns ``(final_state, history, aux)``: history rows at the eval mask
    with ``n_active`` alongside the losses; ``aux`` carries the final
    per-node n̂ from the carried sketches.

    ``compression`` compresses the training mix exactly as in
    ``make_round_fn``; only the *live training* population updates its
    mirror each round (frozen / crashed nodes transmitted nothing, so
    their peers' copies — and their own — stay put until they return).
    Sketch min-exchanges stay uncompressed: they are O(n_sketches) floats,
    not model payloads.
    """
    plan = compile_plan(plan) if isinstance(plan, Graph) else plan
    n_nodes = xs.shape[0]
    if plan.n != n_nodes:
        raise ValueError(f"plan has {plan.n} nodes but xs carries {n_nodes}")
    if membership.n != n_nodes or membership.n_rounds != n_rounds:
        raise ValueError(
            f"membership is ({membership.n_rounds}, {membership.n}) but the run "
            f"wants ({n_rounds}, {n_nodes})"
        )
    trivial_faults = faults is None or faults.trivial
    if faults is not None and (faults.n != n_nodes or faults.n_rounds != n_rounds):
        raise ValueError(
            f"fault plan is ({faults.n_rounds}, {faults.n}) but the run wants "
            f"({n_rounds}, {n_nodes})"
        )
    if membership.trivial and trivial_faults:
        round_fn = make_round_fn(
            loss_fn, optimizer, plan, reinit_opt=reinit_opt, compression=compression
        )
        state, hist = run_trajectory(
            state, round_fn, xs, ys, schedule,
            n_rounds=n_rounds, eval_every=eval_every, eval_fn=eval_fn,
            eval_batch=eval_batch, chunk_size=chunk_size, b_local=b_local,
            checkpoint=checkpoint, resume_from=resume_from, on_chunk=on_chunk,
        )
        hist["n_active"] = [n_nodes] * len(hist["round"])
        return state, hist, {"n_hat": np.full(n_nodes, float(n_nodes))}
    if membership.inits.any() and init_one is None:
        raise ValueError("membership has joining nodes: init_one(key, gain) is required")

    scheduled = isinstance(plan, PlanSchedule)
    failures_active = plan.failures.active
    comp = compression if (compression is not None and compression.active) else None
    has_inits = bool(membership.inits.any())
    cfg = TrajectoryConfig(n_rounds, eval_every, False, chunk_size)
    mask_np = cfg.eval_mask()
    sched_np = _as_round_schedule(schedule, n_rounds, b_local)
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)
    node_idx = jnp.arange(n_nodes)[:, None]
    n_edges = plan.n_edges_env if scheduled else plan.n_edges
    if trivial_faults:
        node_up = np.ones((n_rounds, n_nodes), bool)
        edge_up = np.ones((n_rounds, max(n_edges, 1)), bool)
    else:
        node_up, edge_up = faults.node_up, faults.edge_up

    # aux PRNG streams fork off state.rng without consuming from it: the
    # training stream (per-round k_mix splits) stays the static executors'
    k_fresh, k_init = jax.random.split(jax.random.fold_in(state.rng, 0x5EED))
    sketches0 = jax.random.exponential(
        jax.random.fold_in(k_fresh, n_rounds), (n_nodes, n_sketches)
    )

    # wire accountant: same per-round key, membership and fault masks the
    # mix consumes, so the count is exactly the delivered-edge set (§17)
    wire_fn = make_wire_fn(plan)
    channels = [Channel("train_loss")]
    if eval_fn is not None:
        channels.append(Channel("test_loss", gated=True))
    channels.append(Channel("n_active", ints=True))
    if wire_fn is not None:
        channels.append(Channel("wire_messages", ints=True))
    rec = Recorder(MetricsSpec(tuple(channels)))

    def per_node_where(cond, new, old):
        return jax.tree_util.tree_map(
            lambda a, b: jnp.where(cond.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
            new, old,
        )

    def gather_batch(idx):
        flat = idx.reshape(n_nodes, -1)
        bx = xs_d[node_idx, flat].reshape(idx.shape + xs_d.shape[2:])
        by = ys_d[node_idx, flat].reshape(idx.shape + ys_d.shape[2:])
        return bx, by

    def body(carry, per_round):
        if comp is not None:
            params, opt_state, rng, sketches, mirror = carry
        else:
            (params, opt_state, rng, sketches), mirror = carry, None
        idx, tr_m, gs_m, jn, ini, nup, eup, r, do_eval = per_round
        tr_eff = tr_m & nup
        gs_eff = gs_m & nup
        rng, k_mix = jax.random.split(rng)
        key = k_mix if failures_active else None

        # 1. joiners whose warmup just completed initialise uncoordinated,
        # with the size-only gain √n̂ from their own carried sketches
        # (traced only when the schedule has inits at all — host knowledge)
        def do_init(po):
            p, o = po
            gains = jnp.sqrt(jnp.maximum((n_sketches - 1) / jnp.maximum(
                sketches.sum(axis=1), jnp.float32(1e-30)), 1.0))
            kr = jax.random.fold_in(k_init, r)
            keys = jax.vmap(lambda i: jax.random.fold_in(kr, i))(jnp.arange(n_nodes))
            p = per_node_where(ini, jax.vmap(init_one)(keys, gains), p)
            o = per_node_where(ini, jax.vmap(optimizer.init)(p), o)
            return p, o

        if has_inits:
            params, opt_state = jax.lax.cond(
                ini.any(), do_init, lambda po: po, (params, opt_state)
            )

        # 2. local phase at the full envelope; non-members are frozen
        bx, by = gather_batch(idx)
        new_p, new_o, losses = jax.vmap(partial(_local_steps, loss_fn, optimizer))(
            params, opt_state, (bx, by)
        )
        params = per_node_where(tr_eff, new_p, params)
        opt_state = per_node_where(tr_eff, new_o, opt_state)

        # 3. sketch transport: arrivals redraw, the gossip-active population
        # min-exchanges over the same per-round failure draws as the mix
        fresh = jax.random.exponential(
            jax.random.fold_in(k_fresh, r), (n_nodes, n_sketches)
        )
        sketches = jnp.where(jn[:, None], fresh, sketches)
        if scheduled:
            sketches = plan.spread_min(sketches, r, key, active=gs_eff, edge_live=eup)
        else:
            sketches = plan.spread_min(sketches, key, active=gs_eff, edge_live=eup)
        if comp is not None:
            # only live trainers transmitted → only their mirrors advance
            params, mirror = compressed_mix(
                plan, params, mirror, key, compression=comp,
                round_index=r if scheduled else None,
                active=tr_eff, edge_live=eup, update_mask=tr_eff,
            )
        elif scheduled:
            params = plan.mix(params, r, key, active=tr_eff, edge_live=eup)
        else:
            params = plan.mix(params, key, active=tr_eff, edge_live=eup)
        if reinit_opt:  # Algorithm 1 line 15, members only
            opt_state = per_node_where(
                tr_eff, jax.vmap(optimizer.init)(params), opt_state
            )

        # 4. metrics over the live training population
        n_act = tr_eff.sum().astype(jnp.float32)
        safe = jnp.maximum(n_act, 1.0)
        values = {
            "train_loss": ((losses * tr_eff).sum() / safe).astype(jnp.float32),
            "n_active": n_act,
        }
        if wire_fn is not None:
            values["wire_messages"] = wire_fn(key, r, active=tr_eff, edge_live=eup)

        def gated_metrics(p):
            return {
                "test_loss": ((eval_fn(p, eval_d) * tr_eff).sum() / safe).astype(jnp.float32)
            }

        out = rec.step(values, gate=do_eval, gated_fn=gated_metrics, operand=params)
        new_carry = (params, opt_state, rng, sketches)
        return (new_carry + (mirror,) if comp is not None else new_carry), out

    def chunk_inner(carry, sched_chunk, mask_chunk):
        def step(c, inp):
            sc, do_eval = inp
            return body(c, (*sc, do_eval))

        return jax.lax.scan(step, carry, (sched_chunk, mask_chunk))

    chunk_fn = jax.jit(chunk_inner)
    sched_tuple = (
        jnp.asarray(sched_np),
        jnp.asarray(membership.active),
        jnp.asarray(membership.gossip),
        jnp.asarray(membership.joins),
        jnp.asarray(membership.inits),
        jnp.asarray(node_up),
        jnp.asarray(edge_up),
        jnp.arange(n_rounds, dtype=jnp.int32),
    )
    state = seed_residual(state, comp)
    carry = (state.params, state.opt_state, state.rng, sketches0)
    if comp is not None:
        carry = carry + (state.residual,)
    meta_id = {
        "kind": "elastic", "n_rounds": n_rounds, "eval_every": eval_every,
        "chunk_size": cfg.chunk_size, "n_sketches": n_sketches,
        "compressed": comp is not None,
    }
    row_bytes = param_row_bytes(
        state.params, codec_bytes=comp.leaf_row_bytes if comp is not None else None
    )
    hook = None
    if on_chunk is not None:
        def hook(ci, r0, r1, out):
            del ci
            h = rec.assemble(mask_np[r0:r1], [np.asarray(c) for c in out])
            h["round"] = [r + r0 for r in h["round"]]
            on_chunk(r0, r1, _finish_wire(h, None, row_bytes))
    skip, head_outs = 0, ()
    if resume_from is not None:
        resumed = _load_resume(resume_from, meta_id)
        if resumed is not None:
            payload, skip = resumed
            carry = _restore_carry(carry, payload)
            head_outs = [tuple(np.asarray(c) for c in o) for o in payload["outs"]]
    carry, cols = _drive_chunks(
        chunk_fn, carry, sched_tuple, mask_np, cfg,
        skip=skip, head_outs=head_outs, checkpoint=checkpoint, ckpt_meta=meta_id,
        on_chunk=hook,
    )
    if comp is not None:
        params, opt_state, rng, sketches, mirror = carry
    else:
        (params, opt_state, rng, sketches), mirror = carry, None
    hist = _finish_wire(rec.assemble(mask_np, cols), None, row_bytes)
    final = DFLState(
        params=params, opt_state=opt_state,
        round=state.round + jnp.int32(n_rounds), rng=rng,
        residual=mirror,
    )
    n_hat = (n_sketches - 1) / np.maximum(np.asarray(sketches).sum(axis=1), 1e-30)
    return final, hist, {"n_hat": n_hat}


def run_warmup_trajectory(
    key: jax.Array,
    round_fn: Callable[[DFLState, Any], tuple[DFLState, dict]],
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    n_nodes: int,
    init_one: Callable[[jax.Array, jax.Array], PyTree],
    optimizer,
    estimate_gains: Callable[[jax.Array], jax.Array],
    n_rounds: int,
    eval_every: int = 0,
    eval_fn=None,
    eval_batch=None,
    track_sigmas: bool = False,
    chunk_size: int = 0,
    b_local: int | None = None,
) -> tuple[DFLState, dict[str, list], np.ndarray]:
    """Fused **estimate → per-node gain → init → train** trajectory (§4.4).

    The uncoordinated-init warmup phase: ``estimate_gains`` (a pure-jax
    ``key → (n,) gains`` function, e.g. ``repro.gossip.make_gain_estimator``)
    runs the gossip protocols over the CommPlan backends, ``init_fl_state``
    draws every node's parameters with its own gain, and the first training
    chunk scans on — all inside ONE jitted program, so there is no host
    round-trip between the estimation and training phases and the
    estimation traffic shares the device residency of the round loop.
    Remaining chunks run through the same chunk program ``run_trajectory``
    uses.

    Key discipline: ``key`` splits once into (estimation key, init key);
    running ``estimate_gains`` + ``init_fl_state(gains=...)`` +
    ``run_trajectory`` by hand with the same split reproduces this function
    (property-tested in tests/test_gossip_engine.py).

    Returns ``(final_state, history, gains)`` with ``gains`` the realised
    (n,) per-node vector, for inspection/logging.
    """
    cfg = TrajectoryConfig(n_rounds, eval_every, track_sigmas, chunk_size)
    sched_d = jnp.asarray(_as_round_schedule(schedule, n_rounds, b_local))
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)
    chunk_fn, _, chunk_raw, rec = _build_chunk_fn(
        round_fn, xs_d, ys_d, eval_fn, eval_d, track_sigmas
    )

    comp = getattr(round_fn, "compression", None)

    @jax.jit
    def warmup_chunk(k, sched_c, mask_c):
        k_est, k_init = jax.random.split(k)
        gains = estimate_gains(k_est)
        state = init_fl_state(k_init, n_nodes, init_one, optimizer, gains=gains)
        state = seed_residual(state, comp)  # static scan-carry structure
        state, out = chunk_raw(state, sched_c, mask_c)
        return state, out, gains

    mask_np = cfg.eval_mask()
    r0, r1 = cfg.chunks()[0]
    state, out, gains = warmup_chunk(
        key, jax.lax.slice_in_dim(sched_d, r0, r1, axis=0), jnp.asarray(mask_np[r0:r1])
    )
    # later chunks may donate `state` — it was created inside warmup_chunk,
    # so no caller-owned buffer is ever invalidated (donate=False: no copy)
    state, cols = _drive_chunks(
        chunk_fn, state, sched_d, mask_np, cfg, skip=1, head_outs=[out]
    )
    hist = rec.assemble(mask_np, cols)
    return state, hist, np.asarray(gains)


def run_warmup_sweep(
    keys: Sequence[jax.Array] | jax.Array,
    round_fn: Callable[[DFLState, Any], tuple[DFLState, dict]],
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    n_nodes: int,
    init_one: Callable[[jax.Array, jax.Array], PyTree],
    optimizer,
    estimate_gains: Callable[..., jax.Array],
    budgets: Sequence[int] | np.ndarray | None = None,
    n_rounds: int,
    eval_every: int = 0,
    eval_fn=None,
    eval_batch=None,
    track_sigmas: bool = False,
    chunk_size: int = 0,
    schedule_per_run: bool = False,
    b_local: int | None = None,
) -> tuple[DFLState, list[dict[str, list]], np.ndarray]:
    """Vmapped fused warmups: a (budget × seed) grid of **estimate → per-node
    gain → init → train** trajectories as one program (ROADMAP item).

    ``keys`` is one PRNG key per run (the per-run analogue of
    ``run_warmup_trajectory``'s ``key``); ``budgets``, when given, is one
    gossip budget per run, forwarded as ``estimate_gains(key, budget)`` —
    build the estimator at the grid's *max* budget and let it mask the tail
    rounds (``make_gain_estimator``'s ``budget`` argument), so every run
    shares one static program shape.  The masking keys its phase boundary
    off the *live* budget, so a budget-b cell consumes exactly the failure
    draws a standalone budget-b estimator would — failures included.
    Without ``budgets`` the estimator is called as ``estimate_gains(key)``.

    Per-run semantics match ``run_warmup_trajectory`` run for run (same key
    split, same phases) up to vmap's usual fp-reassociation slack; dataset,
    topology and — unless ``schedule_per_run`` — batch order are shared
    across the sweep like ``run_sweep``.

    Returns ``(stacked_states, histories, gains)`` with ``gains`` the
    realised (n_runs, n_nodes) per-node vectors.
    """
    keys = jnp.stack([jnp.asarray(k) for k in keys]) if isinstance(keys, (list, tuple)) else jnp.asarray(keys)
    n_runs = int(keys.shape[0])
    cfg = TrajectoryConfig(n_rounds, eval_every, track_sigmas, chunk_size)
    if schedule_per_run:
        sched = np.stack(
            [_as_round_schedule(s, n_rounds, b_local) for s in np.asarray(schedule)]
        )
    else:
        sched = _as_round_schedule(schedule, n_rounds, b_local)
    sched_d = jnp.asarray(sched)
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)
    chunk_fn, _, chunk_inner, rec = _build_chunk_fn(
        round_fn, xs_d, ys_d, eval_fn, eval_d, track_sigmas,
        sweep=True, schedule_mapped=schedule_per_run,
    )
    has_budget = budgets is not None
    if has_budget and len(np.asarray(budgets)) != n_runs:
        raise ValueError(
            f"budgets has {len(np.asarray(budgets))} entries for {n_runs} keys"
        )
    b_arr = jnp.asarray(np.asarray(budgets if has_budget else np.zeros(n_runs)), jnp.int32)

    comp = getattr(round_fn, "compression", None)

    def one(k, b, sched_c, mask_c):
        k_est, k_init = jax.random.split(k)
        gains = estimate_gains(k_est, b) if has_budget else estimate_gains(k_est)
        state = init_fl_state(k_init, n_nodes, init_one, optimizer, gains=gains)
        state = seed_residual(state, comp)  # static scan-carry structure
        state, out = chunk_inner(state, sched_c, mask_c)
        return state, out, gains

    warmup_chunk = jax.jit(
        jax.vmap(one, in_axes=(0, 0, 0 if schedule_per_run else None, None))
    )
    mask_np = cfg.eval_mask()
    axis = 1 if schedule_per_run else 0
    r0, r1 = cfg.chunks()[0]
    states, out, gains = warmup_chunk(
        keys,
        b_arr,
        jax.lax.slice_in_dim(sched_d, r0, r1, axis=axis),
        jnp.asarray(mask_np[r0:r1]),
    )
    states, cols = _drive_chunks(
        chunk_fn, states, sched_d, mask_np, cfg,
        round_axis=axis, skip=1, head_outs=[out],
    )
    hists = [rec.assemble(mask_np, [c[i] for c in cols]) for i in range(n_runs)]
    return states, hists, np.asarray(gains)


def run_sweep(
    states: DFLState | Sequence[DFLState],
    round_fn: Callable[[DFLState, Any], tuple[DFLState, dict]],
    xs: np.ndarray,
    ys: np.ndarray,
    schedule: np.ndarray,
    *,
    n_rounds: int,
    eval_every: int = 0,
    eval_fn=None,
    eval_batch=None,
    track_sigmas: bool = False,
    chunk_size: int = 0,
    schedule_per_run: bool = False,
    b_local: int | None = None,
) -> tuple[DFLState, list[dict[str, list]]]:
    """Vmapped sweep: many trajectories (seeds, gains, ...) in one program.

    ``states`` is a list of per-run DFLStates (or an already-stacked one with
    a leading sweep axis).  Dataset and topology are shared across the sweep;
    pass ``schedule_per_run=True`` with a leading run axis on ``schedule`` to
    give each run its own batch order.  Returns the stacked final state and
    one history dict per run.
    """
    if isinstance(states, (list, tuple)):
        states = stack_states(states)
    states = seed_residual(states, getattr(round_fn, "compression", None))
    n_runs = int(jax.tree_util.tree_leaves(states)[0].shape[0])
    cfg = TrajectoryConfig(n_rounds, eval_every, track_sigmas, chunk_size)
    if schedule_per_run:
        sched = np.stack(
            [_as_round_schedule(s, n_rounds, b_local) for s in np.asarray(schedule)]
        )
    else:
        sched = _as_round_schedule(schedule, n_rounds, b_local)
    sched_d = jnp.asarray(sched)
    xs_d, ys_d = jnp.asarray(xs), jnp.asarray(ys)
    eval_d = None if eval_batch is None else jax.tree_util.tree_map(jnp.asarray, eval_batch)
    chunk_fn, donate, _, rec = _build_chunk_fn(
        round_fn, xs_d, ys_d, eval_fn, eval_d, track_sigmas,
        sweep=True, schedule_mapped=schedule_per_run,
    )
    state, cols = _drive_chunks(
        chunk_fn, states, sched_d, cfg.eval_mask(), cfg,
        round_axis=1 if schedule_per_run else 0, donate=donate,
    )
    mask = cfg.eval_mask()
    hists = [rec.assemble(mask, [c[i] for c in cols]) for i in range(n_runs)]
    return state, hists

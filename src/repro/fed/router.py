"""Consensus-aware query routing for live DFL serving (DESIGN.md §19).

Decentralised training never produces one converged artifact: each node
holds its own parameters, equal only up to the consensus noise floor
(§4.2).  Serving therefore means queries hit *nodes*, and the router
decides which node's parameters answer each query by trading

* **staleness** — time since the candidate last mixed (its virtual clock,
  the same per-node quantity the flight recorder's staleness channels bin),
* **locality** — hop distance from the query's home node to the candidate,
* **queueing** — how far in the future the candidate's serve slot is under
  the open-loop latency model.

``QueryStream`` realises an open-loop Poisson arrival process host-side
into the padded, sorted, static-envelope discipline of
``core.topology.EventStream``, so gossip and serve events merge into one
scanned envelope (``fed.serve.run_serve_trajectory``) with no barrier
between training and inference.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.topology import Graph

PyTree = Any

__all__ = [
    "QueryStream",
    "poisson_query_stream",
    "hop_matrix",
    "Router",
    "make_router",
    "ROUTER_POLICIES",
]

ROUTER_POLICIES = ("uniform", "local", "consensus")


@dataclasses.dataclass(frozen=True)
class QueryStream:
    """A realised open-loop query arrival schedule: sorted (time, home) events.

    Mirrors ``EventStream``'s static-envelope discipline so different
    seeds / rates share one compiled scan:

    ``times``  (Q,) float32 non-decreasing; padding entries hold ``horizon``.
    ``homes``  (Q,) int32 arrival node per query; padding is -1 (identity).
    ``qidx``   (Q,) int32 index into the caller's query payload pool.
    """

    times: np.ndarray
    homes: np.ndarray
    qidx: np.ndarray
    n_queries: int
    horizon: float
    qps: float

    def __post_init__(self):
        if self.times.shape != self.homes.shape or self.times.ndim != 1:
            raise ValueError(
                f"times/homes must be matching 1-D arrays, got "
                f"{self.times.shape} vs {self.homes.shape}"
            )
        if self.qidx.shape != self.times.shape:
            raise ValueError("qidx must match the envelope")
        if self.n_queries > len(self.times):
            raise ValueError("n_queries exceeds the padded envelope")

    @property
    def envelope(self) -> int:
        return len(self.times)


def poisson_query_stream(
    n_nodes: int,
    horizon: float,
    qps: float,
    seed: int = 0,
    pool: int = 1,
    envelope: int | None = None,
    skew: float = 0.0,
) -> QueryStream:
    """Sample a Poisson(qps · horizon) open-loop arrival process.

    Arrival instants are iid Uniform(0, horizon) (equivalent to exponential
    inter-arrivals), sorted; each query lands on a home node drawn uniformly
    — or, with ``skew`` > 0, rank-weighted ∝ (rank+1)^-skew so traffic
    concentrates on low-index nodes (hot-spot scenarios).  ``qidx`` indexes
    a payload pool of size ``pool``.  Pure function of ``seed``.
    """
    if horizon <= 0:
        raise ValueError(f"horizon must be positive, got {horizon}")
    if qps < 0:
        raise ValueError(f"qps must be non-negative, got {qps}")
    rs = np.random.RandomState(seed)
    q = int(rs.poisson(qps * horizon)) if qps > 0 else 0
    times = np.sort(rs.uniform(0.0, horizon, size=q)).astype(np.float32)
    if skew > 0:
        w = (np.arange(n_nodes) + 1.0) ** (-float(skew))
        homes = rs.choice(n_nodes, size=q, p=w / w.sum()).astype(np.int32)
    else:
        homes = rs.randint(0, n_nodes, size=q).astype(np.int32)
    qidx = rs.randint(0, max(pool, 1), size=q).astype(np.int32)
    env = q if envelope is None else int(envelope)
    if env < q:
        raise ValueError(f"envelope {env} cannot hold {q} realised queries")
    pad = env - q
    if pad:
        times = np.concatenate([times, np.full(pad, horizon, np.float32)])
        homes = np.concatenate([homes, np.full(pad, -1, np.int32)])
        qidx = np.concatenate([qidx, np.zeros(pad, np.int32)])
    return QueryStream(
        times=times,
        homes=homes,
        qidx=qidx,
        n_queries=q,
        horizon=float(horizon),
        qps=float(qps),
    )


def hop_matrix(graph: Graph) -> np.ndarray:
    """All-pairs hop distances (n, n) int32 via BFS frontier expansion.

    Unreachable pairs get ``n`` (an impossible distance — strictly worse
    than any real path, so routers naturally avoid them).
    """
    a = graph.adjacency > 0
    if graph.directed:
        a = a | a.T
    n = graph.n
    hops = np.full((n, n), n, np.int32)
    np.fill_diagonal(hops, 0)
    reach = np.eye(n, dtype=bool)
    for d in range(1, n):
        nxt = (reach @ a) & ~reach
        if not nxt.any():
            break
        hops[nxt] = d
        reach |= nxt
    return hops


@dataclasses.dataclass(frozen=True)
class Router:
    """Routing policy over a fixed topology; ``route`` is traced in-scan.

    ``policy``: "uniform" (any node, key-driven), "local" (always the home
    node), or "consensus" (argmin of a freshness/locality/queue score with
    a hard staleness budget — candidates over budget are masked out unless
    *every* node is over budget, in which case the unmasked score decides).
    """

    policy: str
    hops: jax.Array  # (n, n) float32 hop distances
    staleness_budget: float = float("inf")
    locality_weight: float = 0.1
    queue_weight: float = 1.0

    @property
    def n(self) -> int:
        return self.hops.shape[0]

    def route(
        self, home: jax.Array, staleness: jax.Array, wait: jax.Array, key: jax.Array
    ) -> jax.Array:
        """Pick the serving node for one query.

        home (), staleness (n,) = t - clocks, wait (n,) = max(busy - t, 0);
        returns a scalar int32 node id.  Pure and deterministic in (inputs,
        key), so a fixed seed replays the exact routing sequence.
        """
        if self.policy == "local":
            return home.astype(jnp.int32)
        if self.policy == "uniform":
            return jax.random.randint(key, (), 0, self.n, dtype=jnp.int32)
        if self.policy != "consensus":
            raise ValueError(f"unknown router policy {self.policy!r}")
        score = self.locality_weight * self.hops[home] + staleness + self.queue_weight * wait
        ok = staleness <= self.staleness_budget
        masked = jnp.where(ok, score, jnp.inf)
        return jnp.where(jnp.any(ok), jnp.argmin(masked), jnp.argmin(score)).astype(jnp.int32)


def make_router(
    graph: Graph,
    policy: str = "consensus",
    *,
    staleness_budget: float = float("inf"),
    locality_weight: float = 0.1,
    queue_weight: float = 1.0,
) -> Router:
    """Build a ``Router`` for ``graph`` (hop table computed host-side once)."""
    if policy not in ROUTER_POLICIES:
        raise ValueError(f"policy must be one of {ROUTER_POLICIES}, got {policy!r}")
    return Router(
        policy=policy,
        hops=jnp.asarray(hop_matrix(graph), jnp.float32),
        staleness_budget=float(staleness_budget),
        locality_weight=float(locality_weight),
        queue_weight=float(queue_weight),
    )

"""Gossip-health channels (DESIGN.md §17) riding ``gossip.diagnostics``.

Four measurements, all JSON-able:

* :func:`consensus_distance` — mean per-node L2 distance to the fleet
  average, the quantity whose contraction the spectral gap predicts.
* :func:`mass_drift_trace` — per-round |Σs − Σs₀|/Σs₀ of a spread payload;
  ``spread`` is column-stochastic so any drift is pure fp32 error, and this
  curve is the canary for a broken mask/renormalisation path.
* :func:`staleness_histogram` — fixed-width linear bucketing of event-driven
  parameter staleness (the executor accumulates the buckets in-scan).
* :func:`gossip_health` — one dict bundling the convergence report's
  fitted-vs-predicted contraction with the measured mass drift.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.gossip.diagnostics import convergence_report

__all__ = [
    "consensus_distance",
    "gossip_health",
    "mass_drift_trace",
    "staleness_histogram",
]


def consensus_distance(params) -> jax.Array:
    """Mean over nodes of ‖wᵢ − w̄‖₂ across the whole flattened model.

    ``params`` is any pytree whose leaves carry a leading node axis.
    Traceable — usable inside a scanned round body as a gated channel.
    """
    leaves = jax.tree_util.tree_leaves(params)
    n = leaves[0].shape[0]
    sq = jnp.zeros((n,), jnp.float32)
    for leaf in leaves:
        flat = leaf.reshape(n, -1).astype(jnp.float32)
        dev = flat - flat.mean(axis=0, keepdims=True)
        sq = sq + jnp.sum(dev * dev, axis=1)
    return jnp.sqrt(sq).mean()


def mass_drift_trace(plan, rounds: int, key=None) -> np.ndarray:
    """(rounds + 1,) relative total-mass drift of a unit payload under
    ``plan.spread`` — exactly zero in exact arithmetic (column-stochastic),
    so the curve measures fp32 conservation through the masked backends.

    ``key`` seeds per-round failure draws when the plan's failure model is
    active (round r uses ``fold_in(key, r)``, the executors' convention).
    """
    spread = jax.jit(plan.spread)
    x = jnp.ones((plan.n,), jnp.float32)
    total0 = float(plan.n)
    drift = [0.0]
    for r in range(rounds):
        k = jax.random.fold_in(key, r) if key is not None else None
        x = spread(x, k)
        drift.append(abs(float(jnp.sum(x)) - total0) / total0)
    return np.asarray(drift, dtype=np.float64)


def staleness_histogram(counts, horizon: float) -> dict:
    """In-scan staleness buckets → ``{counts, edges}`` (JSON-able lists).

    ``counts`` is the executor's fixed-width accumulator (linear buckets
    over [0, horizon], last bucket catching everything beyond); ``edges``
    are the n+1 bucket boundaries in the staleness unit (wall time).
    """
    c = np.asarray(counts, dtype=np.float64)
    edges = np.linspace(0.0, float(horizon), len(c) + 1)
    return {
        "counts": [float(v) for v in c],
        "edges": [float(e) for e in edges],
    }


def gossip_health(plan, rounds: int, key=None, *, leader: int = 0) -> dict:
    """Measured gossip health of one plan: fitted vs predicted contraction,
    rounds-to-1%, and push-sum mass conservation.  All scalars/lists."""
    rep = convergence_report(plan, rounds, key, leader=leader)
    drift = mass_drift_trace(plan, rounds, key)
    return {
        "fitted_rate": float(rep["fitted_rate"]),
        "predicted_rate": float(rep["predicted_rate"]),
        "rounds_to_1pct": int(rep["rounds_to_1pct"]),
        "max_rel_err": [float(v) for v in rep["max_rel_err"]],
        "mass_drift_max": float(drift.max()),
        "mass_drift": [float(v) for v in drift],
    }

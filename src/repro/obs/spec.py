"""Typed metric channels for the fused executors (DESIGN.md §17).

A :class:`MetricsSpec` names the per-round channels one executor records;
the :class:`Recorder` turns them into the executor's scan-out tuple (one
fixed float32 buffer per channel, donation-safe — the buffers are plain
scan ``ys``) and assembles the host history after the final chunk's single
sync.  ``gated`` channels are computed under one ``lax.cond`` on the round's
eval mask with a NaN skip branch — exactly the structure the hand-rolled
executor outs used, so the legacy channels stay **bit-identical**.

:class:`BinSpec` is the event-driven sibling: named fixed-width accumulator
buffers that ride the event scan's *carry* (per-wall-time-bin sums/counts
and set-style slots) instead of per-step outs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BinChannel", "BinSpec", "Channel", "MetricsSpec", "Recorder"]

# every history dict carries these keys (empty when unrecorded) — the
# train_loop drop-in contract the executors inherit
BASE_KEYS = ("round", "train_loss", "test_loss", "sigma_ap", "sigma_an")


@dataclasses.dataclass(frozen=True)
class Channel:
    """One named per-round scalar channel.

    ``gated`` channels follow the eval cadence (``lax.cond``-gated, NaN on
    gated-off rounds); ungated channels record every round.  On device every
    channel is a float32 scalar; ``ints`` only controls the host-side
    rendering in the assembled history.
    """

    name: str
    gated: bool = False
    ints: bool = False


@dataclasses.dataclass(frozen=True)
class MetricsSpec:
    """Ordered channel registry of one executor's per-round outs."""

    channels: tuple[Channel, ...]

    def __post_init__(self):
        names = [c.name for c in self.channels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate channel names: {names}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.channels)

    @property
    def gated(self) -> tuple[Channel, ...]:
        return tuple(c for c in self.channels if c.gated)

    @classmethod
    def legacy(
        cls,
        has_eval: bool,
        track_sigmas: bool,
        *,
        wire: bool = False,
    ) -> "MetricsSpec":
        """The executors' historical channel set in the historical order —
        the Recorder emits bit-identical buffers for these channels; the
        ``wire`` channel (delivered message count) appends after them."""
        ch = [Channel("train_loss")]
        if has_eval:
            ch.append(Channel("test_loss", gated=True))
        if track_sigmas:
            ch += [Channel("sigma_ap", gated=True), Channel("sigma_an", gated=True)]
        if wire:
            ch.append(Channel("wire_messages", ints=True))
        return cls(tuple(ch))


class Recorder:
    """Spec-ordered channel recording inside a scanned round body.

    ``step`` builds one round's out tuple; ``assemble`` converts the
    concatenated per-round buffers back into the train_loop-compatible
    history dict.  The per-round buffers are ordinary scan outputs, so
    buffer donation of the carry is untouched and the host syncs exactly
    once, after the last chunk.
    """

    def __init__(self, spec: MetricsSpec):
        self.spec = spec

    def step(self, values: dict, gate=None, gated_fn=None, operand=None) -> tuple:
        """One round's out tuple in spec order (float32 scalars).

        ``values`` holds the ungated channel values; the gated channels are
        computed as ``gated_fn(operand) -> dict`` under ONE ``lax.cond`` on
        ``gate`` with a NaN skip branch — the legacy executors' exact
        structure, which is what keeps the refactor bit-identical.
        """
        out = dict(values)
        gated = self.spec.gated
        if gated:

            def on_eval(op):
                d = gated_fn(op)
                return tuple(jnp.asarray(d[c.name]).astype(jnp.float32) for c in gated)

            def skip(op):
                del op
                return tuple(jnp.float32(jnp.nan) for _ in gated)

            vals = jax.lax.cond(gate, on_eval, skip, operand)
            out.update({c.name: v for c, v in zip(gated, vals)})
        missing = [c.name for c in self.spec.channels if c.name not in out]
        if missing:
            raise ValueError(f"round body did not provide channels {missing}")
        return tuple(jnp.asarray(out[c.name]).astype(jnp.float32) for c in self.spec.channels)

    def assemble(
        self,
        mask: np.ndarray,
        cols,
        constants: dict | None = None,
    ) -> dict[str, list]:
        """(n_rounds,) per-channel buffers → history dict at the recorded
        rounds.  ``constants`` adds host-side per-round-constant channels
        (e.g. the clean-path wire cost) without a device buffer."""
        if len(cols) != len(self.spec.channels):
            raise ValueError(
                f"{len(cols)} metric buffers for {len(self.spec.channels)} channels"
            )
        rounds = np.nonzero(np.asarray(mask))[0]
        hist: dict[str, list] = {k: [] for k in BASE_KEYS}
        hist["round"] = [int(r) for r in rounds]
        for c, col in zip(self.spec.channels, cols):
            vals = np.asarray(col)[rounds]
            hist[c.name] = [int(v) if c.ints else float(v) for v in vals]
        for name, value in (constants or {}).items():
            hist[name] = [value] * len(rounds)
        return hist


@dataclasses.dataclass(frozen=True)
class BinChannel:
    """One named accumulator buffer of an event scan's carry.

    ``width`` 0 means the spec's ``n_bins``; ``fill`` is the initial buffer
    value (0 for sum-style channels, NaN for set-style slots).
    """

    name: str
    width: int = 0
    fill: float = 0.0


@dataclasses.dataclass(frozen=True)
class BinSpec:
    """Named fixed-width accumulators for the event-driven executor."""

    n_bins: int
    channels: tuple[BinChannel, ...]

    def __post_init__(self):
        names = [c.name for c in self.channels]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate bin channel names: {names}")

    def init(self) -> dict[str, jax.Array]:
        """Fresh accumulator pytree (a dict, stable under tree flattening)."""
        return {
            c.name: jnp.full((c.width or self.n_bins,), c.fill, jnp.float32)
            for c in self.channels
        }

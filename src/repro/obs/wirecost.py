"""Communication-cost accounting: messages and bytes on the wire (§17).

Every trajectory reports what its mixing actually *transmitted*.  The
counts derive from the plans' static structure composed with the same
per-round masks the operators themselves apply:

* **synchronous plans** — one DecAvg round exchanges two full models per
  live undirected edge.  Clean rounds are a static count; under failures /
  membership / fault masks :func:`make_wire_fn` replays the round's failure
  draws from the *same* ``k_mix`` the mix consumes (the repo-wide
  host-replayable key discipline) and counts the surviving edges on device.
* **event-driven plans** — the executor already tracks delivered exchanges
  per wall-time bin; bytes follow as ``messages × row bytes``.
* **sharded plans** — ``ShardedCommPlan`` exposes static per-round halo
  rows / collective counts; :func:`sharded_wire_per_round` scales them by
  the payload's per-node row bytes.

Directed plans carry no event tables (a pairwise exchange has no
orientation), so they get no wire channels — the accountant returns None
and the executors skip the channel rather than guess.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commplan import CommPlan, PlanSchedule

__all__ = [
    "make_wire_fn",
    "param_row_bytes",
    "sharded_wire_per_round",
    "static_wire_messages",
]


def param_row_bytes(
    params: Any,
    codec_bytes: Callable[[int, Any], float] | None = None,
) -> int:
    """Bytes of ONE node's model — every leaf carries a leading node axis,
    so a node's row is ``leaf.size / n`` elements per leaf.

    The per-leaf node axis is read off each leaf's own leading dim (a
    mixed-dtype pytree prices every leaf at its *own* itemsize, and a
    scalar/unstacked leaf counts in full rather than crashing the
    accountant).  ``codec_bytes(row_elems, dtype) -> float`` overrides the
    itemsize pricing with a compressed encoding's wire cost — pass
    ``Compression.leaf_row_bytes`` (``repro.core.compress``) so quantised /
    sparsified exchanges stop over-reporting at the raw dtype width.
    Fractional per-leaf costs accumulate exactly; only the total rounds.
    """
    leaves = jax.tree_util.tree_leaves(params)
    if not leaves:
        return 0
    total = 0.0
    for leaf in leaves:
        row_elems = leaf.size // leaf.shape[0] if leaf.ndim else leaf.size
        if codec_bytes is not None:
            total += float(codec_bytes(int(row_elems), leaf.dtype))
        else:
            total += row_elems * np.dtype(leaf.dtype).itemsize
    return int(round(total))


def _event_plan(plan: CommPlan | PlanSchedule) -> CommPlan | None:
    probe = plan.plans[0] if isinstance(plan, PlanSchedule) else plan
    return probe if probe.event_uv is not None else None


def static_wire_messages(plan: CommPlan | PlanSchedule, n_rounds: int) -> np.ndarray | None:
    """(n_rounds,) clean-path delivered messages per round, host-side.

    Two messages per undirected edge of the round's active plan; a
    ``PlanSchedule`` resolves its round map so churned rounds report the
    snapshot they actually mixed over.  None for directed plans.
    """
    if _event_plan(plan) is None:
        return None
    if isinstance(plan, PlanSchedule):
        idx = np.asarray(plan.plan_index(np.arange(n_rounds)))
        per_plan = np.array([2 * p.n_edges for p in plan.plans], dtype=np.int64)
        return per_plan[idx]
    return np.full(n_rounds, 2 * plan.n_edges, dtype=np.int64)


def make_wire_fn(
    plan: CommPlan | PlanSchedule,
) -> Callable[..., jax.Array] | None:
    """Traced per-round delivered-message accountant, or None.

    ``wire(k_mix, round_index, active=None, edge_live=None)`` returns the
    float32 count of messages this round's *effective* operator delivers:
    the static edge set masked by the Bernoulli failure draws — replayed
    through ``_round_masks_ext`` with exactly the key the mix consumes, so
    the count matches the operator bit for bit — AND the deterministic
    membership / fault masks.  An edge delivers iff its draw survives and
    both endpoints are active (the masked-mix semantics); each delivery is
    two messages.  None for directed plans (no event tables to count over).
    """
    if _event_plan(plan) is None:
        return None
    scheduled = isinstance(plan, PlanSchedule)

    def wire(key, round_index, active=None, edge_live=None) -> jax.Array:
        view = plan.select(round_index) if scheduled else plan
        k = plan.round_key(key, round_index) if scheduled else key
        edge_keep, node_act = view._round_masks_ext(k, active, edge_live)
        uv = view.event_uv
        # schedule envelopes pad event rows with exactly-zero weights (and a
        # 1-row pad on edgeless graphs) — real edges always weigh > 0
        valid = view.event_w.max(axis=1) > 0
        live = valid & edge_keep[: uv.shape[0]] & node_act[uv[:, 0]] & node_act[uv[:, 1]]
        return 2.0 * live.sum().astype(jnp.float32)

    return wire


def sharded_wire_per_round(
    plan, params: Any, codec_bytes: Callable[[int, Any], float] | None = None
) -> dict[str, int]:
    """Static per-round wire stats of a ``ShardedCommPlan`` mix.

    ``wire_bytes`` is the cross-shard halo traffic for the full parameter
    payload (the plan's static row count × the model's per-node row bytes,
    every leaf's halo exchange included); ``wire_collectives`` counts
    collective launches per round (the plan's per-leaf count × leaves).
    ``codec_bytes`` follows :func:`param_row_bytes` — compressed halo rows
    price at the codec's encoding.
    """
    row_bytes = param_row_bytes(params, codec_bytes=codec_bytes)
    n_leaves = len(jax.tree_util.tree_leaves(params))
    return {
        "wire_bytes": int(plan.cross_shard_bytes_per_round(row_bytes, "mix")),
        "wire_rows": int(plan.cross_shard_rows_per_round("mix")),
        "wire_collectives": int(plan.collectives_per_round("mix") * n_leaves),
    }

"""Host-side run-log export: JSONL records + run manifest (DESIGN.md §17).

A run log is newline-delimited JSON: the first record is the **manifest**
(``kind: "manifest"`` — config, seed, git rev, backend, schema version),
followed by one record per recorded round/bin (:func:`history_rows`) and any
trailing summary records the driver appends (final metrics, gossip health).
Everything is sanitised to strict JSON — NaN/Inf become null, numpy scalars
become Python numbers — so any downstream reader parses it.

:func:`profile_trace` is the opt-in ``jax.profiler`` capture used by
``launch/train.py --profile-trace DIR``; the executors' ``named_scope``
phases (local step / mix / eval / halo) show up inside the trace.
"""

from __future__ import annotations

import contextlib
import json
import math
import subprocess
from pathlib import Path
from typing import Any, Iterable, Iterator

import jax

__all__ = [
    "SCHEMA_VERSION",
    "git_rev",
    "history_rows",
    "profile_trace",
    "read_run_log",
    "run_manifest",
    "validate_run_log",
    "write_run_log",
]

SCHEMA_VERSION = 1

# keys every manifest must carry — the check_bench --run-log gate enforces this
MANIFEST_KEYS = ("kind", "schema", "config", "seed", "git_rev", "backend", "jax_version")


def _sanitize(obj: Any) -> Any:
    """Strict-JSON form: NaN/Inf → None, numpy/jax scalars → Python."""
    if obj is None or isinstance(obj, (bool, int, str)):
        return obj
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {str(k): _sanitize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_sanitize(v) for v in obj]
    if hasattr(obj, "item") and not hasattr(obj, "__len__"):
        return _sanitize(obj.item())
    if hasattr(obj, "tolist"):
        return _sanitize(obj.tolist())
    return str(obj)


def git_rev(cwd: str | Path | None = None) -> str:
    """Short git revision of the working tree, or "unknown" outside a repo."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=str(cwd) if cwd else None,
            capture_output=True,
            text=True,
            timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return "unknown"
    return out.stdout.strip() if out.returncode == 0 and out.stdout.strip() else "unknown"


def run_manifest(config: dict, *, seed: int, argv: list[str] | None = None) -> dict:
    """The run log's head record: everything needed to re-run or diff it."""
    return _sanitize(
        {
            "kind": "manifest",
            "schema": SCHEMA_VERSION,
            "config": config,
            "seed": int(seed),
            "argv": list(argv) if argv is not None else None,
            "git_rev": git_rev(),
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "n_devices": jax.device_count(),
        }
    )


def history_rows(hist: dict, kind: str = "round") -> list[dict]:
    """History dict → one record per recorded index.

    The index channel is ``round`` (synchronous executors) or ``bin``
    (event-driven); only keys whose list length matches the index ride
    along — scalars and mismatched extras are the driver's job to append
    as summary records.
    """
    index_key = "bin" if "bin" in hist and hist.get("bin") else "round"
    index = hist.get(index_key) or []
    n = len(index)
    if n == 0:
        return []
    keys = [k for k, v in hist.items() if isinstance(v, (list, tuple)) and len(v) == n]
    return [
        _sanitize({"kind": kind, **{k: hist[k][i] for k in keys}}) for i in range(n)
    ]


def write_run_log(path: str | Path, records: Iterable[dict]) -> int:
    """Write records as JSONL (strict JSON, one object per line); returns
    the record count.  Callers compose ``[manifest, *rows, *summaries]``."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    n = 0
    with path.open("w") as fh:
        for rec in records:
            fh.write(json.dumps(_sanitize(rec), allow_nan=False) + "\n")
            n += 1
    return n


def read_run_log(path: str | Path) -> list[dict]:
    """Parse a JSONL run log back into its records."""
    with Path(path).open() as fh:
        return [json.loads(line) for line in fh if line.strip()]


def validate_run_log(records: list[dict] | str | Path) -> list[str]:
    """Schema-gate a run log; returns human-readable problems (empty = ok).

    Checks: non-empty, manifest-first with :data:`MANIFEST_KEYS` and a
    matching schema version, every record a dict with a ``kind``, and at
    least one data (non-manifest) record.
    """
    if isinstance(records, (str, Path)):
        try:
            records = read_run_log(records)
        except (OSError, json.JSONDecodeError) as exc:
            return [f"unreadable run log: {exc}"]
    problems: list[str] = []
    if not records:
        return ["empty run log"]
    head = records[0]
    if not isinstance(head, dict) or head.get("kind") != "manifest":
        problems.append("first record is not a manifest")
    else:
        missing = [k for k in MANIFEST_KEYS if k not in head]
        if missing:
            problems.append(f"manifest missing keys: {missing}")
        if head.get("schema") != SCHEMA_VERSION:
            problems.append(
                f"manifest schema {head.get('schema')!r} != {SCHEMA_VERSION}"
            )
    for i, rec in enumerate(records[1:], start=2):
        if not isinstance(rec, dict) or "kind" not in rec:
            problems.append(f"record {i} has no 'kind'")
            break
    if sum(1 for r in records if isinstance(r, dict) and r.get("kind") != "manifest") == 0:
        problems.append("no data records after the manifest")
    return problems


@contextlib.contextmanager
def profile_trace(trace_dir: str | Path | None) -> Iterator[None]:
    """Capture a ``jax.profiler`` trace into ``trace_dir`` (no-op if falsy).

    The executors' ``named_scope`` phases — ``dfl_local``, ``dfl_mix``,
    ``dfl_eval``, ``halo_exchange`` — annotate the captured timeline.
    """
    if not trace_dir:
        yield
        return
    Path(trace_dir).mkdir(parents=True, exist_ok=True)
    jax.profiler.start_trace(str(trace_dir))
    try:
        yield
    finally:
        jax.profiler.stop_trace()

"""``repro.obs`` — typed in-scan telemetry for the fused executors (DESIGN.md §17).

One observability layer, four writers: ``run_trajectory``,
``run_event_trajectory``, ``run_elastic_trajectory`` and
``run_sharded_trajectory`` all route their per-round metric buffers through
the :class:`MetricsSpec`/:class:`Recorder` abstraction (bit-identical to the
hand-rolled outs they replace), report bytes-on-the-wire via the
:mod:`~repro.obs.wirecost` accountant, and export host-side JSONL run logs
through :mod:`~repro.obs.export`.
"""

from .export import (
    SCHEMA_VERSION,
    git_rev,
    history_rows,
    profile_trace,
    read_run_log,
    run_manifest,
    validate_run_log,
    write_run_log,
)
from .health import consensus_distance, gossip_health, mass_drift_trace, staleness_histogram
from .spec import BinChannel, BinSpec, Channel, MetricsSpec, Recorder
from .wirecost import (
    make_wire_fn,
    param_row_bytes,
    sharded_wire_per_round,
    static_wire_messages,
)

__all__ = [
    "SCHEMA_VERSION",
    "BinChannel",
    "BinSpec",
    "Channel",
    "MetricsSpec",
    "Recorder",
    "consensus_distance",
    "git_rev",
    "gossip_health",
    "history_rows",
    "make_wire_fn",
    "mass_drift_trace",
    "param_row_bytes",
    "profile_trace",
    "read_run_log",
    "run_manifest",
    "sharded_wire_per_round",
    "staleness_histogram",
    "static_wire_messages",
    "validate_run_log",
    "write_run_log",
]

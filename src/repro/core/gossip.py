"""Gossip protocols for uncoordinated estimation (paper §4.4, ref [35]).

The init gain needs ``‖v_steady‖``, which a node can estimate from (a) the
system size n and a known network-formation family, or (b) a polled sample of
the degree distribution.  Both are obtainable without coordination:

* ``push_sum``          — Kempe-style push-sum average consensus; averaging a
                          one-hot vector yields 1/n at every node (size
                          estimation), averaging local degrees yields ⟨k⟩.
* ``estimate_size``     — n̂ from push-sum of a leader one-hot.
* ``poll_degrees``      — random-walk degree polling with the excess-degree
                          (q(k)) bias corrected by importance re-weighting.

These run on the same ``Graph``/receive-matrix machinery as DecAvg itself, so
the estimation traffic is the same kind of neighbour exchange the training
loop already performs.
"""
from __future__ import annotations

import numpy as np

from .mixing import receive_matrix
from .topology import Graph

__all__ = ["push_sum", "estimate_size", "estimate_mean_degree", "poll_degrees"]


def push_sum(graph: Graph, values: np.ndarray, rounds: int) -> np.ndarray:
    """Push-sum (ratio) gossip: every node tracks (s, w); both mix with the
    column-stochastic send weights; s/w converges to the true average at every
    node regardless of the non-doubly-stochastic mixing (mass conservation).
    """
    n = graph.n
    # column-stochastic send operator: node j sends 1/(k_j+1) to each of
    # itself and its neighbours — mass-conserving, as push-sum requires.
    from .mixing import mixing_matrix

    ap = mixing_matrix(graph)  # columns sum to 1
    s = np.asarray(values, dtype=np.float64).copy()
    w = np.ones(n, dtype=np.float64)
    for _ in range(rounds):
        s = ap @ s
        w = ap @ w
    return s / w


def estimate_size(graph: Graph, rounds: int, leader: int = 0) -> np.ndarray:
    """Every node's estimate of n after ``rounds`` of push-sum (§4.4)."""
    one_hot = np.zeros(graph.n)
    one_hot[leader] = 1.0
    avg = push_sum(graph, one_hot, rounds)
    return 1.0 / np.maximum(avg, 1e-300)


def estimate_mean_degree(graph: Graph, rounds: int) -> np.ndarray:
    return push_sum(graph, graph.degrees.astype(np.float64), rounds)


def poll_degrees(graph: Graph, start: int, walk_length: int, n_walks: int, seed: int = 0,
                 correct_bias: bool = True) -> np.ndarray:
    """Sample degrees by random walks from ``start``.

    A simple random walk visits nodes ∝ degree (the excess-degree bias q(k),
    §3); with ``correct_bias`` we resample ∝ 1/k to recover p(k), which is the
    distribution ``v_steady_norm_from_degree_sample`` expects.
    """
    rng = np.random.default_rng(seed)
    # vectorised transition sampling: all walks advance one step per
    # iteration through the CSR neighbour lists — O(walk_length) numpy ops
    # instead of the O(n_walks · walk_length) Python loop.
    indptr, indices, _ = graph.csr()
    deg = (indptr[1:] - indptr[:-1]).astype(np.int64)
    v = np.full(n_walks, start, dtype=np.int64)
    for _ in range(walk_length):
        u = rng.random(n_walks)
        v = indices[indptr[v] + (u * deg[v]).astype(np.int64)]
    ks = graph.degrees[v].astype(np.float64)
    if not correct_bias:
        return ks
    # importance resample ∝ 1/k to undo the stationary ∝ k visit bias
    p = (1.0 / ks) / (1.0 / ks).sum()
    idx = rng.choice(len(ks), size=len(ks), p=p)
    return ks[idx]

"""Gossip protocols for uncoordinated estimation (paper §4.4, ref [35]).

The init gain needs ``‖v_steady‖``, which a node can estimate from (a) the
system size n and a known network-formation family, or (b) a polled sample of
the degree distribution.  Both are obtainable without coordination:

* ``push_sum``          — Kempe-style push-sum average consensus; averaging a
                          one-hot vector yields 1/n at every node (size
                          estimation), averaging local degrees yields ⟨k⟩.
* ``estimate_size``     — n̂ from push-sum of a leader one-hot.
* ``poll_degrees``      — random-walk degree polling with the excess-degree
                          (q(k)) bias corrected by importance re-weighting.

This module is the **host-side numpy reference**: it materialises dense
O(n²) operators and exists to pin down semantics.  The production engine is
``repro.gossip`` — jitted, ``lax.scan``-chunked programs over the CommPlan
backends (dense / sparse / ppermute) with the same per-edge failure draws as
training; its parity tests compare against the functions here.
``effective_send_matrix`` / ``push_sum_failures`` /
``power_iteration_norm_reference`` extend the reference to the failure and
power-iteration semantics the engine implements.
"""
from __future__ import annotations

import numpy as np

from .mixing import mixing_matrix, receive_matrix
from .topology import Graph

__all__ = [
    "push_sum",
    "estimate_size",
    "estimate_mean_degree",
    "poll_degrees",
    "effective_send_matrix",
    "push_sum_failures",
    "power_iteration_norm_reference",
    "min_spread_reference",
    "estimate_size_sketch_reference",
    "event_mix_reference",
    "event_spread_reference",
    "event_spread_min_reference",
    "push_sum_events_reference",
]


def push_sum(graph: Graph, values: np.ndarray, rounds: int) -> np.ndarray:
    """Push-sum (ratio) gossip: every node tracks (s, w); both mix with the
    column-stochastic send weights; s/w converges to the true average at every
    node regardless of the non-doubly-stochastic mixing (mass conservation).
    """
    n = graph.n
    # column-stochastic send operator: node j sends 1/(k_j+1) to each of
    # itself and its neighbours — mass-conserving, as push-sum requires.
    ap = mixing_matrix(graph)  # columns sum to 1
    s = np.asarray(values, dtype=np.float64).copy()
    w = np.ones(n, dtype=np.float64)
    for _ in range(rounds):
        s = ap @ s
        w = ap @ w
    return s / w


def effective_send_matrix(
    graph: Graph, edge_keep: np.ndarray | None = None, node_active: np.ndarray | None = None
) -> np.ndarray:
    """Column-stochastic send operator of one round under a failure draw.

    ``edge_keep`` is indexed by ``Graph.edge_list()`` row (one Bernoulli per
    *undirected* edge, both endpoints agreeing — the same keying as
    ``CommPlan``'s training failures); ``node_active`` is per node.  An edge
    is usable iff it survived and both endpoints are active; every node
    always keeps its self-weight, so columns renormalise over the surviving
    neighbourhood and the matrix stays mass-conserving.  With no failures
    this is exactly ``mixing_matrix(graph)`` (Eq. 3); it also equals the
    transpose of the unit-data-size effective *receive* operator, which is
    what lets ``CommPlan.spread`` reuse the training backends.
    """
    n = graph.n
    a = graph.adjacency.astype(np.float64).copy()
    if edge_keep is not None:
        edges = graph.edge_list()
        dead = np.asarray(edge_keep) == 0
        if dead.any():
            u, v = edges[dead, 0], edges[dead, 1]
            a[u, v] = 0.0
            a[v, u] = 0.0
    if node_active is not None:
        act = np.asarray(node_active).astype(bool)
        a = a * act[:, None] * act[None, :]
    b = a + np.eye(n)
    return b / b.sum(axis=0, keepdims=True)


def push_sum_failures(
    graph: Graph, values: np.ndarray, send_matrices: list[np.ndarray]
) -> np.ndarray:
    """Push-sum through an explicit per-round sequence of send operators.

    Mass conservation makes the (s, w) ratio converge to the uniform average
    even though each round's operator (a failure draw) differs — this is the
    reference the engine's failure-parity tests integrate against.
    """
    s = np.asarray(values, dtype=np.float64).copy()
    w = np.ones(graph.n, dtype=np.float64)
    for ap in send_matrices:
        s = ap @ s
        w = ap @ w
    return s / (w if s.ndim == 1 else w[:, None])


def power_iteration_norm_reference(
    graph: Graph,
    pi_rounds: int,
    ps_rounds: int,
    leader: int = 0,
    send_matrices: list[np.ndarray] | None = None,
) -> dict[str, np.ndarray]:
    """Numpy reference of the gossip ``‖v_steady‖`` estimator (`repro.gossip`).

    Phase 1 (rounds ``0..pi_rounds``): power-iterate ``x ← A' x`` from
    ``x₀ = 1``.  Mass conservation keeps ``Σx = n`` while ``A'^t → v·1ᵀ``,
    so ``x → n·v`` without any explicit normalisation.

    Phase 2 (rounds ``pi_rounds..pi_rounds+ps_rounds``): push-sum average of
    the payload ``[x², 1_leader]`` → every node holds ``m2 ≈ n‖v‖²`` and
    ``z ≈ 1/n``, hence the *per-round push-sum normalisation*
    ``‖v̂‖ = √(m2·z)`` and ``n̂ = 1/z`` — all without coordination.

    ``send_matrices``, when given, supplies the per-round effective
    operators (length ``pi_rounds + ps_rounds``) of a failure draw.
    """
    n = graph.n
    if send_matrices is None:
        send_matrices = [mixing_matrix(graph)] * (pi_rounds + ps_rounds)
    if len(send_matrices) != pi_rounds + ps_rounds:
        raise ValueError(
            f"need {pi_rounds + ps_rounds} per-round operators, got {len(send_matrices)}"
        )
    x = np.ones(n, dtype=np.float64)
    for ap in send_matrices[:pi_rounds]:
        x = ap @ x
    one_hot = np.zeros(n, dtype=np.float64)
    one_hot[leader] = 1.0
    payload = np.stack([x**2, one_hot], axis=1)
    avg = push_sum_failures(graph, payload, send_matrices[pi_rounds:])
    m2, z = avg[:, 0], np.maximum(avg[:, 1], 1e-300)
    return {
        "vnorm": np.sqrt(np.maximum(m2 * z, 0.0)),
        "n_hat": 1.0 / z,
        "x": x,
        # nodes the leader's mass never visited within the budget: their
        # estimates are meaningless (the engine's gain builders fall back
        # to gain = 1 there — see repro.gossip.make_gain_estimator)
        "reached": avg[:, 1] > 1e-20,
    }


def min_spread_reference(
    graph: Graph,
    values: np.ndarray,
    edge_keep: np.ndarray | None = None,
    node_active: np.ndarray | None = None,
) -> np.ndarray:
    """One round of neighbourhood min-exchange under a failure draw.

    ``out[i] = min(values[i], min over i's surviving neighbourhood)`` — the
    transport of the leaderless exponential-random-minimum size sketches
    (``repro.gossip.estimate_size_leaderless`` is the device rendering;
    ``CommPlan.spread_min`` executes the same masks).  Failure indexing
    matches ``effective_send_matrix``: one Bernoulli per *undirected* edge
    (``Graph.edge_list()`` order) and one per node; a node always keeps its
    own values.
    """
    a = graph.adjacency.astype(bool).copy()
    if edge_keep is not None:
        edges = graph.edge_list()
        dead = np.asarray(edge_keep) == 0
        if dead.any():
            u, v = edges[dead, 0], edges[dead, 1]
            a[u, v] = False
            a[v, u] = False
    if node_active is not None:
        act = np.asarray(node_active).astype(bool)
        a = a & act[:, None] & act[None, :]
    x = np.asarray(values, dtype=np.float64)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    nbr = np.where(a[:, :, None], x[None, :, :], np.inf).min(axis=1)
    out = np.minimum(x, nbr)  # self-inclusion: a node always keeps its own
    return out[:, 0] if squeeze else out


def estimate_size_sketch_reference(
    graph: Graph,
    sketches: np.ndarray,
    rounds: int,
    masks: list[tuple[np.ndarray | None, np.ndarray | None]] | None = None,
) -> np.ndarray:
    """Leaderless n̂ reference: ``rounds`` of min-exchange of the given
    (n, m) Exp(1) sketches, then the unbiased inverse-mean estimator
    ``n̂ = (m - 1) / Σ_sketches min``.  ``masks``, when given, supplies one
    (edge_keep, node_active) failure draw per round (same indexing as
    ``effective_send_matrix``)."""
    x = np.asarray(sketches, dtype=np.float64)
    if masks is None:
        masks = [(None, None)] * rounds
    if len(masks) != rounds:
        raise ValueError(f"need {rounds} per-round masks, got {len(masks)}")
    for ek, na in masks:
        x = min_spread_reference(graph, x, ek, na)
    m = x.shape[1]
    return (m - 1) / np.maximum(x.sum(axis=1), 1e-300)


def _event_weights(
    graph: Graph,
    edges_fired: np.ndarray,
    keep: np.ndarray | None,
    data_sizes: np.ndarray | None = None,
):
    """Shared prep of the event references: per-event (u, v, w_uv, w_vu).

    Weights are the synchronous receive operator's entries ``M[u, v]`` /
    ``M[v, u]`` — exactly the ``event_w`` table ``commplan.compile_plan``
    bakes for ``CommPlan.event_mix``/``event_spread``, so device-vs-
    reference parity is draw-exact given the same edge sequence (pass the
    plan's ``data_sizes`` to replay a |D_j|-weighted plan).  ``keep`` (one
    bool per event, or None = all live) replays the device's per-event
    failure draws; a padding event (edge < 0) is skipped like the device's
    zero-weight identity.
    """
    m = receive_matrix(graph, data_sizes)
    edge_list = graph.edge_list()
    fired = np.asarray(edges_fired, dtype=np.int64)
    if keep is None:
        keep = np.ones(len(fired), dtype=bool)
    keep = np.asarray(keep, dtype=bool)
    if len(keep) != len(fired):
        raise ValueError(f"need one keep flag per event, got {len(keep)} for {len(fired)}")
    for e, k in zip(fired, keep):
        if e < 0 or not k:
            continue
        u, v = int(edge_list[e, 0]), int(edge_list[e, 1])
        yield u, v, m[u, v], m[v, u]


def event_mix_reference(
    graph: Graph,
    values: np.ndarray,
    edges_fired: np.ndarray,
    keep: np.ndarray | None = None,
    data_sizes: np.ndarray | None = None,
) -> np.ndarray:
    """Replay a (time-ordered) event sequence of pairwise DecAvg exchanges:
    ``w_u ← w_u + M[u,v]·(w_v − w_u)`` and symmetrically per event — the
    numpy reference of ``CommPlan.event_mix`` scanned over an
    ``EventStream`` (``values``: (n,) or (n, k))."""
    x = np.asarray(values, dtype=np.float64).copy()
    for u, v, w_uv, w_vu in _event_weights(graph, edges_fired, keep, data_sizes):
        xu, xv = x[u].copy(), x[v].copy()
        x[u] = xu + w_uv * (xv - xu)
        x[v] = xv + w_vu * (xu - xv)
    return x


def event_spread_reference(
    graph: Graph,
    values: np.ndarray,
    edges_fired: np.ndarray,
    keep: np.ndarray | None = None,
    data_sizes: np.ndarray | None = None,
) -> np.ndarray:
    """Replay pairwise **push** events: ``s_u ← s_u − M[u,v]·s_u + M[v,u]·s_v``
    and symmetrically — mass-conserving event by event for any weights (the
    reference of ``CommPlan.event_spread``)."""
    x = np.asarray(values, dtype=np.float64).copy()
    for u, v, w_uv, w_vu in _event_weights(graph, edges_fired, keep, data_sizes):
        give_u, give_v = w_uv * x[u].copy(), w_vu * x[v].copy()
        x[u] = x[u] - give_u + give_v
        x[v] = x[v] - give_v + give_u
    return x


def event_spread_min_reference(
    graph: Graph,
    values: np.ndarray,
    edges_fired: np.ndarray,
    keep: np.ndarray | None = None,
    data_sizes: np.ndarray | None = None,
) -> np.ndarray:
    """Replay pairwise **min** events: both endpoints take the coordinate-wise
    minimum (reference of ``CommPlan.event_spread_min`` — the leaderless
    sketch transport without barriers)."""
    x = np.asarray(values, dtype=np.float64).copy()
    for u, v, _, _ in _event_weights(graph, edges_fired, keep, data_sizes):
        lo = np.minimum(x[u], x[v])
        x[u] = lo
        x[v] = lo.copy()
    return x


def push_sum_events_reference(
    graph: Graph, values: np.ndarray, edges_fired: np.ndarray, keep: np.ndarray | None = None
) -> np.ndarray:
    """Event-driven push-sum reference: spread the (s, w) pair through the
    same pairwise exchanges and return s/w — mass conservation per event
    makes the ratio converge to the uniform average with no round barrier
    (reference of ``repro.gossip.push_sum_events``)."""
    s = np.asarray(values, dtype=np.float64)
    squeeze = s.ndim == 1
    if squeeze:
        s = s[:, None]
    payload = np.concatenate([s, np.ones((graph.n, 1))], axis=1)
    out = event_spread_reference(graph, payload, edges_fired, keep)
    ratio = out[:, :-1] / np.maximum(out[:, -1:], 1e-300)
    return ratio[:, 0] if squeeze else ratio


def estimate_size(graph: Graph, rounds: int, leader: int = 0) -> np.ndarray:
    """Every node's estimate of n after ``rounds`` of push-sum (§4.4)."""
    one_hot = np.zeros(graph.n)
    one_hot[leader] = 1.0
    avg = push_sum(graph, one_hot, rounds)
    return 1.0 / np.maximum(avg, 1e-300)


def estimate_mean_degree(graph: Graph, rounds: int) -> np.ndarray:
    return push_sum(graph, graph.degrees.astype(np.float64), rounds)


def poll_degrees(graph: Graph, start: int, walk_length: int, n_walks: int, seed: int = 0,
                 correct_bias: bool = True) -> np.ndarray:
    """Sample degrees by random walks from ``start``.

    A simple random walk visits nodes ∝ degree (the excess-degree bias q(k),
    §3); with ``correct_bias`` we resample ∝ 1/k to recover p(k), which is the
    distribution ``v_steady_norm_from_degree_sample`` expects.

    Degree-0 guard: a walker on a neighbourless node has nowhere to go —
    ``indices[indptr[v] + 0]`` would silently read the *next* node's
    adjacency (or fall off the array for the last node).  Starting on an
    isolated node raises; walkers that reach one (possible only on directed
    graphs with out-degree-0 sinks) stay put, mirroring the on-device
    walker in ``repro.gossip.walker``.
    """
    rng = np.random.default_rng(seed)
    # vectorised transition sampling: all walks advance one step per
    # iteration through the CSR neighbour lists — O(walk_length) numpy ops
    # instead of the O(n_walks · walk_length) Python loop.
    indptr, indices, _ = graph.csr()
    deg = (indptr[1:] - indptr[:-1]).astype(np.int64)
    if deg[start] == 0:
        raise ValueError(
            f"poll_degrees: start node {start} has no neighbours — every walk "
            "would be stuck and the 1/k bias correction would divide by zero"
        )
    v = np.full(n_walks, start, dtype=np.int64)
    for _ in range(walk_length):
        u = rng.random(n_walks)
        alive = deg[v] > 0
        step = indptr[v] + (u * deg[v]).astype(np.int64)
        v = np.where(alive, indices[np.where(alive, step, 0)], v)
    ks = graph.degrees[v].astype(np.float64)
    if not correct_bias:
        return ks
    # importance resample ∝ 1/k to undo the stationary ∝ k visit bias.
    # Walkers trapped on a degree-0 sink carry no degree information and
    # would inject 1/0 into the weights — exclude them from the resample.
    ok = np.nonzero(ks > 0)[0]
    if len(ok) == 0:
        raise ValueError(
            "poll_degrees: every walk ended on a degree-0 sink — no degree "
            "information to resample (is the graph mostly absorbing?)"
        )
    kk = ks[ok]
    p = (1.0 / kk) / (1.0 / kk).sum()
    idx = rng.choice(len(kk), size=len(ks), p=p)
    return kk[idx]

"""Deterministic fault injection beyond i.i.d. dropout (ROADMAP direction 5).

``FailureModel`` covers the paper's §4.2 regime — independent per-round
Bernoulli link/node survival.  Real outages are *correlated*: a rack loses
power (crash burst), a switch partitions the network, the best-connected
nodes are exactly the ones overloaded first (2402.18606's topology-impact
result: robustness depends on **which** nodes fail), and the whole training
process gets preempted mid-scan.  ``FaultPlan`` realises those scenarios
host-side — seeded, replayable, a pure function of its arguments — into
per-round boolean masks that ride the same ``active=`` / ``edge_live=``
channel as membership (``CommPlan`` renormalises the masked operator, mass
conserved), plus a preemption schedule the executor's checkpoint layer turns
into SIGKILL-style kills.

Composition: masks AND together (``compose``), and the whole stack ANDs
with the membership schedule and the Bernoulli draws inside the operator —
deterministic outages, stochastic dropout, and elastic membership are one
orthogonal mask algebra.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .topology import Graph

__all__ = [
    "FaultPlan",
    "no_faults",
    "crash_burst",
    "partition",
    "hub_outage",
    "preemption",
    "compose",
    "scenario",
    "SCENARIOS",
]


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Realised per-round outage masks (True = up) over a fixed graph.

    ``node_up``  (n_rounds, n) bool;
    ``edge_up``  (n_rounds, n_edges) bool in ``Graph.edge_list()`` order —
                 the failure-mask index order every backend shares;
    ``preempt_chunks``  chunk indices after whose checkpoint the executor
                 kills the process (``fed.executor.CheckpointPolicy``).
    """

    name: str
    n: int
    n_rounds: int
    node_up: np.ndarray
    edge_up: np.ndarray
    preempt_chunks: tuple[int, ...] = ()

    def __post_init__(self):
        if self.node_up.shape != (self.n_rounds, self.n) or self.node_up.dtype != np.bool_:
            raise ValueError(
                f"node_up must be bool ({self.n_rounds}, {self.n}), "
                f"got {self.node_up.dtype} {self.node_up.shape}"
            )
        if self.edge_up.ndim != 2 or self.edge_up.shape[0] != self.n_rounds:
            raise ValueError(f"edge_up must be (n_rounds, n_edges), got {self.edge_up.shape}")

    @property
    def trivial(self) -> bool:
        return bool(self.node_up.all() and self.edge_up.all() and not self.preempt_chunks)


def _blank(name: str, n: int, n_edges: int, n_rounds: int) -> tuple[np.ndarray, np.ndarray]:
    return np.ones((n_rounds, n), bool), np.ones((n_rounds, n_edges), bool)


def no_faults(graph: Graph, n_rounds: int) -> FaultPlan:
    node_up, edge_up = _blank("none", graph.n, len(graph.edge_list()), n_rounds)
    return FaultPlan("none", graph.n, n_rounds, node_up, edge_up)


def _window(at: int, duration: int, n_rounds: int) -> slice:
    if not 0 <= at < n_rounds:
        raise ValueError(f"fault onset round {at} outside [0, {n_rounds})")
    if duration < 1:
        raise ValueError(f"fault duration must be >= 1, got {duration}")
    return slice(at, min(at + duration, n_rounds))


def crash_burst(
    graph: Graph,
    n_rounds: int,
    *,
    at: int,
    size: int,
    duration: int,
    seed: int = 0,
    targeted: bool = False,
) -> FaultPlan:
    """``size`` nodes go down together for ``duration`` rounds — the
    correlated burst i.i.d. dropout cannot express.  ``targeted=True`` takes
    the ``size`` highest-degree nodes (the hubs whose loss 2402.18606 shows
    hurts most); otherwise a seeded uniform draw."""
    n = graph.n
    if not 0 < size <= n:
        raise ValueError(f"burst size must be in (0, {n}], got {size}")
    w = _window(at, duration, n_rounds)
    if targeted:
        victims = np.argsort(-graph.degrees, kind="stable")[:size]
    else:
        victims = np.random.default_rng(seed).choice(n, size=size, replace=False)
    node_up, edge_up = _blank("crash", n, len(graph.edge_list()), n_rounds)
    node_up[w.start : w.stop, victims] = False
    tag = "hub-crash" if targeted else "crash"
    return FaultPlan(f"{tag}@{at}x{size}", n, n_rounds, node_up, edge_up)


def partition(
    graph: Graph,
    n_rounds: int,
    *,
    at: int,
    duration: int,
    seed: int = 0,
) -> FaultPlan:
    """A temporary network split: a seeded balanced node cut, every edge
    crossing it down for ``duration`` rounds.  Nodes stay up — both halves
    keep training and mixing internally, then re-merge; the transient the
    recovery curves in ``benchmarks/fig11_elastic.py`` measure."""
    n = graph.n
    w = _window(at, duration, n_rounds)
    side = np.zeros(n, bool)
    half = np.random.default_rng(seed).choice(n, size=n // 2, replace=False)
    side[half] = True
    edges = graph.edge_list()
    cross = side[edges[:, 0]] != side[edges[:, 1]]
    node_up, edge_up = _blank("partition", n, len(edges), n_rounds)
    edge_up[w.start : w.stop, :] = np.broadcast_to(~cross, (w.stop - w.start, len(edges)))
    return FaultPlan(f"partition@{at}", n, n_rounds, node_up, edge_up)


def hub_outage(
    graph: Graph,
    n_rounds: int,
    *,
    at: int,
    duration: int,
    k: int = 1,
) -> FaultPlan:
    """The ``k`` highest-degree nodes go dark for ``duration`` rounds —
    degree-targeted outage, deterministic (no seed: the hubs are a property
    of the topology)."""
    return crash_burst(
        graph, n_rounds, at=at, size=k, duration=duration, targeted=True
    )


def preemption(graph: Graph, n_rounds: int, chunks: tuple[int, ...] | list[int]) -> FaultPlan:
    """No network faults — the *process* dies: after each listed chunk's
    checkpoint lands, the executor SIGKILLs itself, and the driver resumes
    from LATEST.  The resume-parity contract makes this invisible in the
    trajectory (bit-identical params/metrics)."""
    node_up, edge_up = _blank("preempt", graph.n, len(graph.edge_list()), n_rounds)
    return FaultPlan(
        f"preempt@{','.join(map(str, chunks))}", graph.n, n_rounds,
        node_up, edge_up, preempt_chunks=tuple(int(c) for c in chunks),
    )


def compose(*plans: FaultPlan) -> FaultPlan:
    """AND the masks, union the preemption schedule."""
    if not plans:
        raise ValueError("compose needs at least one FaultPlan")
    first = plans[0]
    for p in plans[1:]:
        if (p.n, p.n_rounds, p.edge_up.shape[1]) != (
            first.n, first.n_rounds, first.edge_up.shape[1]
        ):
            raise ValueError("composed FaultPlans must share the (n, n_rounds, n_edges) envelope")
    node_up = np.logical_and.reduce([p.node_up for p in plans])
    edge_up = np.logical_and.reduce([p.edge_up for p in plans])
    chunks = tuple(sorted({c for p in plans for c in p.preempt_chunks}))
    name = "+".join(p.name for p in plans)
    return FaultPlan(name, first.n, first.n_rounds, node_up, edge_up, preempt_chunks=chunks)


# named scenarios for the CLI / benchmarks: graph, n_rounds, seed → FaultPlan
SCENARIOS = {
    "none": lambda g, R, s: no_faults(g, R),
    "crash": lambda g, R, s: crash_burst(
        g, R, at=R // 3, size=max(g.n // 8, 1), duration=max(R // 10, 1), seed=s
    ),
    "hub": lambda g, R, s: hub_outage(
        g, R, at=R // 3, duration=max(R // 10, 1), k=max(g.n // 16, 1)
    ),
    "partition": lambda g, R, s: partition(
        g, R, at=R // 3, duration=max(R // 10, 1), seed=s
    ),
    "crash+partition": lambda g, R, s: compose(
        crash_burst(g, R, at=R // 4, size=max(g.n // 8, 1), duration=max(R // 10, 1), seed=s),
        partition(g, R, at=R // 2, duration=max(R // 10, 1), seed=s + 1),
    ),
}


def scenario(name: str, graph: Graph, n_rounds: int, seed: int = 0) -> FaultPlan:
    """Instantiate a named fault scenario (``--fault-scenario`` on the CLI)."""
    if name not in SCENARIOS:
        raise ValueError(f"unknown fault scenario {name!r}; have {sorted(SCENARIOS)}")
    return SCENARIOS[name](graph, n_rounds, seed)

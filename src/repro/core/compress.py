"""Payload compression for DecAvg gossip (DESIGN.md §18).

At transformer-scale parameter counts the mixing step is wire-bound, not
compute-bound: one DecAvg round moves ``degree × d_total × itemsize`` bytes
per node.  This module makes bytes-on-the-wire an *optimisable* axis for
every CommPlan backend without touching their operator algebra:

* **chunked per-leaf gossip** — every leaf is processed as fixed-size
  chunks of its per-node row (``chunk`` elements).  Chunks are the codec's
  scale granularity, and with ``stream=True`` the mix itself runs chunk by
  chunk under ``lax.map`` so no temporary larger than (n, chunk) exists per
  leaf — an n-node mix never materialises a second (n, d_total) stack.
* **int8 / fp8 quantised exchanges** — per-chunk absmax scales; what a
  node transmits is the *dequantised* value its peers would decode, so the
  operators stay linear and backend-agnostic.
* **top-k sparsification** — per chunk, only the k = ``ceil(topk_frac·c)``
  largest-|·| entries are transmitted.  ``"qtopk"`` additionally int8-
  quantises the kept values against the chunk absmax (3 bytes/entry
  instead of 6): at the same kept fraction it halves the sparse wire cost,
  which is what lets a quality-preserving fraction still clear a 4×
  reduction (the fig12 acceptance configuration is qtopk at frac 0.3).
* **error feedback** — each node carries a *mirror* ``h`` (a params-shaped
  fp32 pytree in the scan state): the copy of itself its peers hold, built
  from everything it ever transmitted.  The residual ``x − h`` is the
  accumulated untransmitted mass.  One compressed round is

      q  = C(x − h)            # the wire payload
      h' = h + q               # peers decode the same update
      x' = x + γ (M h' − h')   # delta-form gossip on the shared mirrors

  — the difference-compression scheme of CHOCO-style compressed gossip
  (PAPERS.md heterogeneity line): quantisation error scales with the
  *residual*, not the weights, so it vanishes as consensus approaches, and
  every dropped top-k coordinate is retransmitted once its residual grows.
  With an exact codec and ``gamma=1`` the update collapses to ``x' = M x``.
  ``gamma`` (the consensus step size) trades contraction speed for
  stability: quantisers run at 1.0; aggressive sparsifiers (small
  ``topk_frac``) need γ < 1 on poorly-connected graphs — the classic
  compressed-gossip trade-off, measured in tests/test_compress.py.

The **uncompressed path is bit-identical to the raw operators**: codec
``"none"`` routes straight to ``plan.mix`` / ``plan.spread`` with no delta
arithmetic, so a ``Compression()`` default changes nothing (the PR 8
parity contract).  Wire accounting lives in ``Compression.leaf_row_bytes``
— ``repro.obs.wirecost.param_row_bytes`` takes it as its ``codec_bytes=``
hook, replacing the dtype itemsize with the codec's encoding:

======  =========================================================
codec   bytes per row of a d-element leaf (C = ceil(d/chunk))
======  =========================================================
none    d · itemsize
int8    d · 1 + C · 4                  (fp32 scale per chunk)
fp8     d · 1 + C · 4                  (e4m3 payload, fp32 scale)
topk    Σ_chunks k_c · (4 + 2)         (fp32 value + uint16 idx)
qtopk   Σ_chunks k_c · (1 + 2) + C · 4 (int8 value + uint16 idx)
======  =========================================================
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

__all__ = [
    "Compression",
    "compressed_mix",
    "compressed_mix_with",
    "compressed_spread",
    "encode_decode",
    "init_residuals",
    "seed_residual",
]

CODECS = ("none", "int8", "fp8", "topk", "qtopk")
_FP8_MAX = 448.0  # float8_e4m3fn finite max
_SCALE_BYTES = 4  # fp32 scale per chunk on the wire
_TOPK_IDX_BYTES = 2  # uint16 in-chunk index (chunk <= 65536)


@dataclasses.dataclass(frozen=True)
class Compression:
    """Static codec configuration threaded through ``CommPlan.mix/spread``.

    ``chunk`` is the per-node-row chunk size in elements — the codec's
    scale granularity and, with ``stream=True``, the mix's streaming unit.
    ``topk_frac`` is the kept fraction per chunk (codec ``"topk"``).
    ``gamma`` is the consensus step size of the delta-form update.
    ``error_feedback=False`` drops the mirror update (every round
    compresses the raw weights with no memory) — for ablations only;
    memory-less compressed DecAvg stalls at the codec's noise floor.
    """

    codec: str = "none"
    chunk: int = 2048
    topk_frac: float = 0.1
    gamma: float = 1.0
    error_feedback: bool = True
    stream: bool = False

    def __post_init__(self):
        if self.codec not in CODECS:
            raise ValueError(f"unknown codec {self.codec!r}, want one of {CODECS}")
        if self.chunk < 1:
            raise ValueError(f"chunk must be >= 1, got {self.chunk}")
        if self.chunk > 65536:
            # the documented wire format carries uint16 in-chunk indices
            raise ValueError(f"chunk must be <= 65536, got {self.chunk}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got {self.topk_frac}")
        if not 0.0 < self.gamma <= 1.0:
            raise ValueError(f"gamma must be in (0, 1], got {self.gamma}")

    @property
    def active(self) -> bool:
        return self.codec != "none"

    # ------------------------------------------------------------ wire cost
    def topk_count(self, chunk_elems: int) -> int:
        """Entries kept in one chunk of ``chunk_elems`` elements."""
        return max(1, min(chunk_elems, math.ceil(self.topk_frac * chunk_elems)))

    def leaf_row_bytes(self, n_elems: int, dtype) -> float:
        """Wire bytes for ONE node's row of one leaf (``codec_bytes=`` hook
        of ``obs.wirecost.param_row_bytes``).  Uncompressed leaves cost
        their dtype itemsize; compressed ones cost the codec encoding plus
        per-chunk scale overhead (see the module table)."""
        if n_elems == 0:
            return 0.0
        if not self.active:
            return float(n_elems * np.dtype(dtype).itemsize)
        full, rem = divmod(n_elems, self.chunk)
        n_chunks = full + (1 if rem else 0)
        if self.codec in ("int8", "fp8"):
            return float(n_elems + n_chunks * _SCALE_BYTES)
        entries = full * self.topk_count(self.chunk)
        if rem:
            entries += self.topk_count(rem)
        if self.codec == "qtopk":
            return float(entries * (1 + _TOPK_IDX_BYTES) + n_chunks * _SCALE_BYTES)
        return float(entries * (4 + _TOPK_IDX_BYTES))


# --------------------------------------------------------------- chunk codecs
def _to_chunks(x2: jax.Array, chunk: int) -> tuple[jax.Array, int]:
    """(n, d) → (n, C, c) zero-padded; returns the padded array and d."""
    n, d = x2.shape
    c = min(chunk, d)
    pad = -d % c
    if pad:
        x2 = jnp.pad(x2, ((0, 0), (0, pad)))
    return x2.reshape(n, (d + pad) // c, c), d


def _from_chunks(q3: jax.Array, d: int) -> jax.Array:
    return q3.reshape(q3.shape[0], -1)[:, :d]


def _absmax_scale(t3: jax.Array, qmax: float) -> jax.Array:
    amax = jnp.max(jnp.abs(t3), axis=-1, keepdims=True)
    return jnp.maximum(amax, jnp.float32(1e-30)) / jnp.float32(qmax)


def _codec_int8(t3: jax.Array) -> jax.Array:
    scale = _absmax_scale(t3, 127.0)
    q = jnp.clip(jnp.round(t3 / scale), -127.0, 127.0)
    return q * scale


def _codec_fp8(t3: jax.Array) -> jax.Array:
    # normalise the chunk absmax to the e4m3 finite range, cast through the
    # real fp8 dtype (round-to-nearest-even in hardware), scale back
    scale = _absmax_scale(t3, _FP8_MAX)
    return (t3 / scale).astype(jnp.float8_e4m3fn).astype(jnp.float32) * scale


def _codec_topk(t3: jax.Array, k: int, quantise: bool = False) -> jax.Array:
    vals, idx = jax.lax.top_k(jnp.abs(t3), k)  # (n, C, k)
    del vals
    kept = jnp.take_along_axis(t3, idx, axis=-1)
    if quantise:
        # "qtopk": int8-quantise the kept values against the chunk absmax
        # (the top-1 |value| of the full chunk), 3 wire bytes per entry
        scale = _absmax_scale(t3, 127.0)
        kept = jnp.clip(jnp.round(kept / scale), -127.0, 127.0) * scale
    n, n_chunks, _ = t3.shape
    i0 = jnp.arange(n)[:, None, None]
    i1 = jnp.arange(n_chunks)[None, :, None]
    return jnp.zeros_like(t3).at[i0, i1, idx].set(kept)


def _encode_decode_2d(x2: jax.Array, comp: Compression) -> jax.Array:
    """decode(encode(x)) of one (n, d) leaf — what the peers receive."""
    t3, d = _to_chunks(x2.astype(jnp.float32), comp.chunk)
    if comp.codec == "int8":
        q3 = _codec_int8(t3)
    elif comp.codec == "fp8":
        q3 = _codec_fp8(t3)
    elif comp.codec in ("topk", "qtopk"):
        q3 = _codec_topk(
            t3, comp.topk_count(t3.shape[-1]), quantise=comp.codec == "qtopk"
        )
    else:
        q3 = t3
    return _from_chunks(q3, d)


def encode_decode(params: PyTree, comp: Compression) -> PyTree:
    """Per-leaf decode(encode(·)) of a node-stacked pytree (fp32 out)."""
    if not comp.active:
        return params

    def one(leaf):
        q = _encode_decode_2d(leaf.reshape(leaf.shape[0], -1), comp)
        return q.reshape(leaf.shape)

    return jax.tree_util.tree_map(one, params)


# ----------------------------------------------------------- residual carry
def init_residuals(params: PyTree) -> PyTree:
    """Zero compression carry: params-shaped, fp32.  The carry holds each
    node's transmitted *mirror* h; starting from h = 0 the first round
    transmits C(x) in full (modulo the codec)."""
    return jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape, jnp.float32), params)


def seed_residual(state, compression: Compression | None):
    """Attach a zero compression carry to a ``DFLState`` when the codec
    needs one (executors call this before the scan so the carry structure
    is static)."""
    if compression is None or not compression.active or state.residual is not None:
        return state
    return dataclasses.replace(state, residual=init_residuals(state.params))


def _mask_rows(mask: jax.Array, new: PyTree, old: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(mask.reshape((-1,) + (1,) * (a.ndim - 1)), a, b),
        new,
        old,
    )


# ------------------------------------------------------------- mixing forms
def compressed_mix_with(
    mix_fn: Callable[[PyTree], PyTree],
    params: PyTree,
    residual: PyTree,
    comp: Compression,
    *,
    update_mask: jax.Array | None = None,
) -> tuple[PyTree, PyTree]:
    """Error-feedback delta-form gossip around ANY linear node-mixing
    operator ``mix_fn`` (CommPlan.mix, a sharded local_mix, an event_mix):

        q = C(x − h);  h' = h + q;  x' = x + γ (mix(h') − h');

    returning ``(x', h')`` — ``residual`` is the carried mirror ``h``.
    Rows where ``mix_fn`` is the identity (masked-out members, event
    non-participants) satisfy ``mix(h')_i = h'_i`` and therefore come back
    unchanged; pass ``update_mask`` ((n,) bool) to also freeze their
    mirrors — a node that transmitted nothing updated nobody's copy.

    Codec ``"none"`` returns ``(mix_fn(params), residual)`` verbatim — the
    bit-identity contract of the uncompressed path.
    """
    if not comp.active:
        return mix_fn(params), residual
    if comp.error_feedback:
        delta = jax.tree_util.tree_map(
            lambda x, h: x.astype(jnp.float32) - h, params, residual
        )
        h_new = jax.tree_util.tree_map(
            lambda h, qq: h + qq, residual, encode_decode(delta, comp)
        )
    else:
        # memory-less ablation: every round transmits C(x) from scratch —
        # the quantisation error never leaves, so consensus floors out
        h_new = encode_decode(
            jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), params), comp
        )
    if update_mask is not None:
        h_new = _mask_rows(update_mask, h_new, residual)
    mixed = mix_fn(h_new)
    g = jnp.float32(comp.gamma)
    out = jax.tree_util.tree_map(
        lambda x, mh, hh: (x.astype(jnp.float32) + g * (mh - hh)).astype(x.dtype),
        params,
        mixed,
        h_new,
    )
    return out, h_new


def _plan_mix_fn(plan, key, round_index, active, edge_live):
    if round_index is not None:  # PlanSchedule
        return lambda p: plan.mix(
            p, round_index, key, active=active, edge_live=edge_live
        )
    return lambda p: plan.mix(p, key, active=active, edge_live=edge_live)


def compressed_mix(
    plan,
    params: PyTree,
    residual: PyTree,
    key: jax.Array | None = None,
    *,
    compression: Compression,
    round_index=None,
    active: jax.Array | None = None,
    edge_live: jax.Array | None = None,
    update_mask: jax.Array | None = None,
) -> tuple[PyTree, PyTree]:
    """One compressed DecAvg round over a CommPlan / PlanSchedule.

    The plan-aware form of :func:`compressed_mix_with`: with
    ``compression.stream`` the whole pipeline runs per chunk under
    ``lax.map`` — compress chunk, mix chunk, delta, mirror update — so the
    largest per-leaf temporary is (n, chunk).  Failure draws re-derive from
    the same ``key`` for every chunk, so all chunks of a round ride one
    effective operator, identical to the unstreamed path.
    """
    mix_fn = _plan_mix_fn(plan, key, round_index, active, edge_live)
    if not compression.active or not compression.stream:
        return compressed_mix_with(
            mix_fn, params, residual, compression, update_mask=update_mask
        )

    comp = compression

    def one_leaf(x, h):
        shape = x.shape
        x3, d = _to_chunks(x.reshape(shape[0], -1).astype(jnp.float32), comp.chunk)
        h3, _ = _to_chunks(h.reshape(shape[0], -1), comp.chunk)
        flat = dataclasses.replace(comp, chunk=x3.shape[-1], stream=False)

        def step(xh):
            xc, hc = xh  # (n, c) one chunk of every node's row
            return compressed_mix_with(mix_fn, xc, hc, flat, update_mask=update_mask)

        out3, nh3 = jax.lax.map(step, (x3.transpose(1, 0, 2), h3.transpose(1, 0, 2)))
        out = _from_chunks(out3.transpose(1, 0, 2), d).astype(x.dtype)
        new_h = _from_chunks(nh3.transpose(1, 0, 2), d)
        return out.reshape(shape), new_h.reshape(shape)

    pairs = jax.tree_util.tree_map(one_leaf, params, residual)
    is_pair = lambda t: isinstance(t, tuple)  # noqa: E731
    out = jax.tree_util.tree_map(lambda p: p[0], pairs, is_leaf=is_pair)
    new_h = jax.tree_util.tree_map(lambda p: p[1], pairs, is_leaf=is_pair)
    return out, new_h


def compressed_spread(
    plan,
    values: jax.Array,
    residual: jax.Array,
    key: jax.Array | None = None,
    *,
    compression: Compression,
    round_index=None,
    active: jax.Array | None = None,
    edge_live: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """One compressed send-form (push) round: ``v' = v + γ (Mᵀ h' − h')``.

    Because the masked ``Mᵀ`` is column-stochastic, ``sum(Mᵀ h') =
    sum(h')`` and the delta form conserves total mass *exactly* for any
    codec — the invariant push-sum estimation needs survives compression
    untouched.
    """
    if round_index is not None:
        spread = lambda v: plan.spread(  # noqa: E731
            v, round_index, key, active=active, edge_live=edge_live
        )
    else:
        spread = lambda v: plan.spread(  # noqa: E731
            v, key, active=active, edge_live=edge_live
        )
    if not compression.active:
        return spread(values), residual
    v = jnp.asarray(values, jnp.float32)
    delta = v - residual if compression.error_feedback else v
    q = _encode_decode_2d(delta.reshape(delta.shape[0], -1), compression).reshape(
        delta.shape
    )
    h_new = (residual + q) if compression.error_feedback else q
    out = v + jnp.float32(compression.gamma) * (spread(h_new) - h_new)
    return out, h_new

"""CommPlan: compile a ``Graph`` into an executable mixing backend.

The paper's dynamics depend only on the communication network's *structure*
(eigenvector centralities, degrees, spectral gap), but how a round of DecAvg
*executes* on hardware is a separate engineering choice.  ``compile_plan``
makes that choice a config knob: it lowers a ``Graph`` (+ optional per-node
data sizes + a failure model) into one of three interchangeable backends, all
implementing Eq. 2 exactly (DESIGN.md §3):

``dense``     the (n, n) receive-matrix einsum — reference semantics, any
              topology, O(n²·d); the paper-faithful baseline.
``sparse``    CSR/edge-list gather + ``segment_sum`` scatter — O(E·d), makes
              n in the thousands tractable; ``repro.kernels.mix.sparse``
              supplies the blocked block-sparse Pallas kernel for the TPU
              rendering of the same contraction.
``ppermute``  greedy edge colouring → each colour class is a matching = one
              ``ppermute`` round inside ``shard_map``; moves degree·|w| bytes
              per node instead of n·|w|.  Generalises the circulant-only
              schedule to arbitrary static undirected graphs.

Failure semantics are uniform across backends: one Bernoulli(link_p) draw per
*undirected edge* (both endpoints agree by construction — the draw is keyed
on the edge's index in ``Graph.edge_list()``) and one Bernoulli(node_p) per
node; the effective receive operator renormalises over the surviving
neighbourhood.  Identical keys therefore give identical effective operators
on every backend, which is what the parity property tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .decavg import mix_pytree, mix_pytree_colored, mix_pytree_hyb, mix_pytree_sparse
from .mixing import receive_matrix
from .topology import Graph

PyTree = Any

__all__ = ["BACKENDS", "CommPlan", "FailureModel", "compile_plan"]

BACKENDS = ("dense", "sparse", "ppermute")


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Per-round Bernoulli link/node survival probabilities (paper §4.1)."""

    link_p: float = 1.0
    node_p: float = 1.0

    @property
    def active(self) -> bool:
        return self.link_p < 1.0 or self.node_p < 1.0


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """A compiled, backend-specific execution plan for one DecAvg round.

    Produced by ``compile_plan``; all array fields are device arrays ready to
    be closed over by a jitted round function.  ``mix(params, key)`` is the
    single entry point every consumer dispatches through; ``key`` is required
    iff ``failures.active``.
    """

    graph: Graph
    backend: str
    failures: FailureModel
    data_sizes: np.ndarray | None
    # ---- dense ----
    receive: jax.Array | None = None  # (n, n) static row-stochastic operator
    adjacency: jax.Array | None = None  # (n, n) original adjacency
    edge_uid_matrix: jax.Array | None = None  # (n, n) int32 undirected edge ids
    # ---- sparse (CSR receive order, dst-sorted) ----
    src: jax.Array | None = None  # (nnz,) int32
    dst: jax.Array | None = None  # (nnz,) int32
    edge_uid: jax.Array | None = None  # (nnz,) int32 → undirected edge index
    edge_w: jax.Array | None = None  # (nnz,) statically normalised weights
    self_w: jax.Array | None = None  # (n,) statically normalised self weights
    raw_edge_w: jax.Array | None = None  # (nnz,) unnormalised A[dst,src]·s[src]
    raw_self_w: jax.Array | None = None  # (n,) unnormalised s
    # ---- sparse HYB layout (static-topology fast path) ----
    slot_idx: jax.Array | None = None  # (S, n) int32, self-padded
    slot_w: jax.Array | None = None  # (S, n) statically normalised
    hyb_self_w: jax.Array | None = None  # (n,), 0 at hub rows
    hub_rows: jax.Array | None = None  # (H,) int32
    hub_m: jax.Array | None = None  # (H, n) dense receive rows incl. self
    # ---- ppermute / colored ----
    partners: np.ndarray | None = None  # (n_colors, n) static int32
    color_edge_uid: jax.Array | None = None  # (n_colors, n) int32, -1 unmatched
    color_w: jax.Array | None = None  # (n_colors, n) statically normalised
    color_raw_w: jax.Array | None = None  # (n_colors, n) unnormalised
    n_edges: int = 0  # undirected edge count (failure draw width)

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def n_colors(self) -> int:
        return 0 if self.partners is None else self.partners.shape[0]

    # ------------------------------------------------------------- execution
    def mix(self, params: PyTree, key: jax.Array | None = None) -> PyTree:
        """One DecAvg aggregation of a node-stacked pytree.

        Jit-friendly: ``self`` is closed over as compile-time constants, only
        ``params``/``key`` are traced.  The ``ppermute`` backend here executes
        its colour schedule as node-axis gathers (single-process semantics);
        use ``color_round_weights`` + ``decavg.mix_pytree_colored`` inside
        ``shard_map`` for the true collective rendering (see launch/steps.py).
        """
        if self.failures.active and key is None:
            raise ValueError("failure model active: mix() needs a PRNG key")
        if self.backend == "dense":
            return mix_pytree(self._dense_round_matrix(key), params)
        if self.backend == "sparse":
            if not self.failures.active and self.slot_idx is not None:
                # static topology: HYB layout (ELL slot chain + dense hub
                # rows) — the fused-gather rendering that beats the dense
                # einsum on CPU.  Failure rounds renormalise per-edge, so
                # they take the segment_sum formulation below.
                return mix_pytree_hyb(
                    params, self.slot_idx, self.slot_w, self.hyb_self_w,
                    self.hub_rows, self.hub_m,
                )
            edge_w, self_w = self._sparse_round_weights(key)
            return mix_pytree_sparse(
                params, self.src, self.dst, edge_w, self_w, n_nodes=self.n
            )
        color_w, self_w = self.color_round_weights(key)
        return mix_pytree_colored(params, self.partners, color_w, self_w)

    def spread(self, values: jax.Array, key: jax.Array | None = None) -> jax.Array:
        """One *send-form* (column-stochastic) round: ``values ← Mᵀ values``.

        ``mix`` applies the row-stochastic receive operator ``M`` (Eq. 2);
        ``spread`` applies its transpose — column-stochastic, hence
        mass-conserving (``values.sum(0)`` is invariant), which is the
        property push-sum gossip needs (``repro.gossip``, paper §4.4).  For
        undirected graphs with unit data sizes ``Mᵀ`` *is* the paper's
        mixing matrix ``A'`` of Eq. 3: node j keeps ``1/(k_j+1)`` of its
        mass and pushes ``1/(k_j+1)`` along each live edge.

        Same backends, same sharding rules and — crucially — the same
        per-edge/per-node failure draws as ``mix`` for the same ``key``:
        estimation traffic rides exactly the links training rides.

        ``values``: (n,) or (n, k) float payload.  Returns the same shape.
        """
        if self.failures.active and key is None:
            raise ValueError("failure model active: spread() needs a PRNG key")
        x = jnp.asarray(values, jnp.float32)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        if self.backend == "dense":
            m = self._dense_round_matrix(key)
            out = jnp.einsum("ji,jk->ik", m, x)
        elif self.backend == "sparse":
            edge_w, self_w = self._sparse_round_weights(key)
            contrib = edge_w[:, None] * x[self.dst]
            out = self_w[:, None] * x + jax.ops.segment_sum(
                contrib, self.src, num_segments=self.n
            )
        else:
            color_w, self_w = self.color_round_weights(key)
            partners = jnp.asarray(self.partners)
            sends = color_w[:, :, None] * x[None, :, :]  # (n_colors, n, k)
            # node j receives what its colour-c partner sent: partners is an
            # involution per colour, so gathering sends at partners[c] lands
            # each edge's mass on the opposite endpoint.
            recv = sends[jnp.arange(self.n_colors)[:, None], partners]
            out = self_w[:, None] * x + recv.sum(axis=0)
        return out[:, 0] if squeeze else out

    # ----------------------------------------------------- per-round weights
    def round_masks(self, key: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Public alias of the per-round failure draws, for host-side
        references that must key their Bernoullis identically (parity tests,
        ``core.gossip.effective_send_matrix``)."""
        return self._edge_node_masks(key)

    def _edge_node_masks(self, key: jax.Array) -> tuple[jax.Array, jax.Array]:
        """(edge_keep (n_edges,), node_active (n,)) — shared across backends."""
        k_link, k_node = jax.random.split(key)
        if self.failures.link_p < 1.0:
            edge_keep = (
                jax.random.uniform(k_link, (max(self.n_edges, 1),))
                < self.failures.link_p
            )
        else:
            edge_keep = jnp.ones((max(self.n_edges, 1),), dtype=bool)
        if self.failures.node_p < 1.0:
            active = jax.random.bernoulli(k_node, self.failures.node_p, (self.n,))
        else:
            active = jnp.ones((self.n,), dtype=bool)
        return edge_keep, active

    def _dense_round_matrix(self, key: jax.Array | None) -> jax.Array:
        if not self.failures.active:
            return self.receive
        edge_keep, active = self._edge_node_masks(key)
        keep = edge_keep[self.edge_uid_matrix] & (self.adjacency > 0)
        keep = keep & active[:, None] & active[None, :]
        a = self.adjacency * keep
        sizes = None if self.data_sizes is None else jnp.asarray(self.data_sizes, jnp.float32)
        b = a.astype(jnp.float32) + jnp.eye(self.n, dtype=jnp.float32)
        if sizes is not None:
            b = b * sizes[None, :]
        return b / b.sum(axis=1, keepdims=True)

    def _sparse_round_weights(self, key: jax.Array | None) -> tuple[jax.Array, jax.Array]:
        if not self.failures.active:
            return self.edge_w, self.self_w
        edge_keep, active = self._edge_node_masks(key)
        keep = edge_keep[self.edge_uid] & active[self.src] & active[self.dst]
        num = self.raw_edge_w * keep
        den = self.raw_self_w + jax.ops.segment_sum(
            num, self.dst, num_segments=self.n, indices_are_sorted=True
        )
        return num / den[self.dst], self.raw_self_w / den

    def color_round_weights(self, key: jax.Array | None) -> tuple[jax.Array, jax.Array]:
        """((n_colors, n), (n,)) normalised weights for this round's schedule."""
        if not self.failures.active:
            return self.color_w, self.self_w
        edge_keep, active = self._edge_node_masks(key)
        matched = self.color_edge_uid >= 0
        keep = matched & edge_keep[jnp.clip(self.color_edge_uid, 0, None)]
        partners = jnp.asarray(self.partners)
        keep = keep & active[None, :] & jnp.take(active, partners)
        num = self.color_raw_w * keep
        den = self.raw_self_w + num.sum(axis=0)
        return num / den[None, :], self.raw_self_w / den

    def color_perms(self) -> list[list[tuple[int, int]]]:
        """Static ppermute (src, dst) pair lists, one per colour class."""
        perms = []
        for c in range(self.n_colors):
            p = self.partners[c]
            perms.append([(i, int(p[i])) for i in range(self.n) if p[i] != i])
        return perms

    # ------------------------------------------------------------- plumbing
    def with_options(
        self,
        *,
        backend: str | None = None,
        data_sizes: np.ndarray | None = None,
        failures: FailureModel | None = None,
    ) -> "CommPlan":
        """Recompile this plan with some knobs replaced."""
        return compile_plan(
            self.graph,
            backend=backend or self.backend,
            data_sizes=self.data_sizes if data_sizes is None else data_sizes,
            failures=failures or self.failures,
        )


def _hyb_layout(
    graph: Graph,
    indptr: np.ndarray,
    src: np.ndarray,
    raw_edge: np.ndarray,
    s: np.ndarray,
    den: np.ndarray,
) -> dict:
    """Compile the sparse backend's HYB layout (ELL slots + dense hub rows).

    Degree-threshold heuristic: each ELL slot costs one fused full-length
    gather pass over the (n, d) ensemble, each hub row one (1, n)·(n, d)
    matmul row; measured on CPU a hub row costs about a sixth of a slot
    pass, so minimise ``n_slots(t) + n_hub(t)/6`` over thresholds t.
    Heavy-tail hubs land in the dense part (a complete graph compiles to
    "all hub" = the dense einsum, which is indeed optimal there).
    """
    n = graph.n
    deg = np.diff(indptr)
    candidates = sorted(set(deg.tolist()) | {0})
    cost = lambda t: min(t, int(deg[deg <= t].max()) if (deg <= t).any() else 0) + (deg > t).sum() / 6.0
    t = min(candidates, key=cost)
    hub = np.nonzero(deg > t)[0].astype(np.int32)
    n_slots = int(deg[deg <= t].max()) if (deg <= t).any() else 0
    slot_idx = np.tile(np.arange(n, dtype=np.int32)[None, :], (n_slots, 1))
    slot_w = np.zeros((n_slots, n), np.float64)
    is_hub = np.zeros(n, dtype=bool)
    is_hub[hub] = True
    for i in range(n):
        if is_hub[i]:
            continue
        lo, hi = indptr[i], indptr[i + 1]
        slot_idx[: hi - lo, i] = src[lo:hi]
        slot_w[: hi - lo, i] = raw_edge[lo:hi] / den[i]
    hub_m = np.zeros((len(hub), n), np.float64)
    for r, i in enumerate(hub):
        lo, hi = indptr[i], indptr[i + 1]
        hub_m[r, src[lo:hi]] = raw_edge[lo:hi] / den[i]
        hub_m[r, i] = s[i] / den[i]
    return dict(
        slot_idx=jnp.asarray(slot_idx),
        slot_w=jnp.asarray(slot_w, jnp.float32),
        hyb_self_w=jnp.asarray(np.where(is_hub, 0.0, s / den), jnp.float32),
        hub_rows=jnp.asarray(hub),
        hub_m=jnp.asarray(hub_m, jnp.float32),
    )


def compile_plan(
    graph: Graph,
    backend: str = "auto",
    data_sizes: np.ndarray | Sequence[float] | None = None,
    failures: FailureModel | None = None,
) -> CommPlan:
    """Lower a ``Graph`` into an executable ``CommPlan``.

    backend="auto" picks dense for small ensembles (n ≤ 64, where the (n, n)
    einsum is cheapest and GSPMD-friendliest) and sparse beyond — the
    crossover the mixing benchmark sweep measures.
    """
    failures = failures or FailureModel()
    if backend == "auto":
        backend = "dense" if graph.n <= 64 else "sparse"
    if backend not in BACKENDS:
        raise ValueError(f"unknown mixing backend {backend!r}; expected one of {BACKENDS}")

    sizes = None if data_sizes is None else np.asarray(data_sizes, dtype=np.float64)
    n = graph.n
    n_edges = len(graph.edge_list())
    common = dict(
        graph=graph,
        backend=backend,
        failures=failures,
        data_sizes=None if sizes is None else sizes.copy(),
        n_edges=n_edges,
    )

    if backend == "dense":
        uid_matrix = np.zeros((n, n), dtype=np.int32)
        edges = graph.edge_list()
        if graph.directed:
            uid_matrix[edges[:, 0], edges[:, 1]] = np.arange(len(edges))
        else:
            uid_matrix[edges[:, 0], edges[:, 1]] = np.arange(len(edges))
            uid_matrix[edges[:, 1], edges[:, 0]] = np.arange(len(edges))
        return CommPlan(
            **common,
            receive=jnp.asarray(receive_matrix(graph, sizes), jnp.float32),
            adjacency=jnp.asarray(graph.adjacency),
            edge_uid_matrix=jnp.asarray(uid_matrix),
        )

    s = np.ones(n, dtype=np.float64) if sizes is None else sizes
    if backend == "sparse":
        indptr, src, uid = graph.csr()
        dst = np.repeat(np.arange(n, dtype=np.int32), np.diff(indptr))
        raw_edge = graph.adjacency[dst, src].astype(np.float64) * s[src]
        den = s + np.bincount(dst, weights=raw_edge, minlength=n)
        return CommPlan(
            **common,
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            edge_uid=jnp.asarray(uid),
            edge_w=jnp.asarray(raw_edge / den[dst], jnp.float32),
            self_w=jnp.asarray(s / den, jnp.float32),
            raw_edge_w=jnp.asarray(raw_edge, jnp.float32),
            raw_self_w=jnp.asarray(s, jnp.float32),
            **_hyb_layout(graph, indptr, src, raw_edge, s, den),
        )

    # ppermute: greedy edge colouring → per-colour matchings
    coloring = graph.edge_coloring()
    partners = coloring.partners
    idx = np.arange(n)
    matched = partners != idx[None, :]
    # receive weight of edge (i, partner) at node i: A[i, partner] * s[partner]
    raw = np.where(
        matched,
        graph.adjacency[idx[None, :], partners] * s[partners],
        0.0,
    )
    den = s + raw.sum(axis=0)
    return CommPlan(
        **common,
        partners=partners,
        color_edge_uid=jnp.asarray(coloring.edge_index),
        color_w=jnp.asarray(raw / den[None, :], jnp.float32),
        color_raw_w=jnp.asarray(raw, jnp.float32),
        self_w=jnp.asarray(s / den, jnp.float32),
        raw_self_w=jnp.asarray(s, jnp.float32),
    )

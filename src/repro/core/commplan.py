"""CommPlan: compile a ``Graph`` into an executable mixing backend.

The paper's dynamics depend only on the communication network's *structure*
(eigenvector centralities, degrees, spectral gap), but how a round of DecAvg
*executes* on hardware is a separate engineering choice.  ``compile_plan``
makes that choice a config knob: it lowers a ``Graph`` (+ optional per-node
data sizes + a failure model) into one of three interchangeable backends, all
implementing Eq. 2 exactly (DESIGN.md §3):

``dense``     the (n, n) receive-matrix einsum — reference semantics, any
              topology, O(n²·d); the paper-faithful baseline.
``sparse``    CSR/edge-list gather + ``segment_sum`` scatter — O(E·d), makes
              n in the thousands tractable; ``repro.kernels.mix.sparse``
              supplies the blocked block-sparse Pallas kernel for the TPU
              rendering of the same contraction.
``ppermute``  greedy edge colouring → each colour class is a matching = one
              ``ppermute`` round inside ``shard_map``; moves degree·|w| bytes
              per node instead of n·|w|.  Generalises the circulant-only
              schedule to arbitrary static undirected graphs.

Failure semantics are uniform across backends: one Bernoulli(link_p) draw per
*undirected edge* (both endpoints agree by construction — the draw is keyed
on the edge's index in ``Graph.edge_list()``) and one Bernoulli(node_p) per
node; the effective receive operator renormalises over the surviving
neighbourhood.  Identical keys therefore give identical effective operators
on every backend, which is what the parity property tests assert.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .compress import Compression, compressed_mix, compressed_spread, init_residuals
from .decavg import (
    mix_pytree,
    mix_pytree_colored,
    mix_pytree_hyb,
    mix_pytree_pairwise,
    mix_pytree_pairwise_batch,
    mix_pytree_sparse,
    spread_min_pairwise,
    spread_pairwise,
)
from .mixing import receive_matrix
from .topology import Graph

PyTree = Any

__all__ = [
    "BACKENDS",
    "CommPlan",
    "FailureModel",
    "PlanSchedule",
    "RoundMap",
    "compile_plan",
    "compile_schedule",
    "cyclic_map",
    "sequence_map",
]

BACKENDS = ("dense", "sparse", "ppermute")


def _draw_failure_masks(
    failures: "FailureModel", n_edges: int, n: int, key: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(edge_keep (n_edges,), node_active (n,)) — the uniform failure draw.

    Shared by ``CommPlan`` (width = the plan's own edge count) and
    ``PlanSchedule`` (width = the schedule's shared edge *envelope*, so the
    draw shape is static while the active plan varies by round)."""
    k_link, k_node = jax.random.split(key)
    if failures.link_p < 1.0:
        edge_keep = jax.random.uniform(k_link, (max(n_edges, 1),)) < failures.link_p
    else:
        edge_keep = jnp.ones((max(n_edges, 1),), dtype=bool)
    if failures.node_p < 1.0:
        active = jax.random.bernoulli(k_node, failures.node_p, (n,))
    else:
        active = jnp.ones((n,), dtype=bool)
    return edge_keep, active


@dataclasses.dataclass(frozen=True)
class FailureModel:
    """Per-round Bernoulli link/node survival probabilities (paper §4.1)."""

    link_p: float = 1.0
    node_p: float = 1.0

    @property
    def active(self) -> bool:
        return self.link_p < 1.0 or self.node_p < 1.0


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """A compiled, backend-specific execution plan for one DecAvg round.

    Produced by ``compile_plan``; all array fields are device arrays ready to
    be closed over by a jitted round function.  ``mix(params, key)`` is the
    single entry point every consumer dispatches through; ``key`` is required
    iff ``failures.active``.
    """

    graph: Graph
    backend: str
    failures: FailureModel
    data_sizes: np.ndarray | None
    # ---- dense ----
    receive: jax.Array | None = None  # (n, n) static row-stochastic operator
    adjacency: jax.Array | None = None  # (n, n) original adjacency
    edge_uid_matrix: jax.Array | None = None  # (n, n) int32 undirected edge ids
    # ---- sparse (CSR receive order, dst-sorted) ----
    src: jax.Array | None = None  # (nnz,) int32
    dst: jax.Array | None = None  # (nnz,) int32
    edge_uid: jax.Array | None = None  # (nnz,) int32 → undirected edge index
    edge_w: jax.Array | None = None  # (nnz,) statically normalised weights
    self_w: jax.Array | None = None  # (n,) statically normalised self weights
    raw_edge_w: jax.Array | None = None  # (nnz,) unnormalised A[dst,src]·s[src]
    raw_self_w: jax.Array | None = None  # (n,) unnormalised s
    # ---- sparse HYB layout (static-topology fast path) ----
    slot_idx: jax.Array | None = None  # (S, n) int32, self-padded
    slot_w: jax.Array | None = None  # (S, n) statically normalised
    hyb_self_w: jax.Array | None = None  # (n,), 0 at hub rows
    hub_rows: jax.Array | None = None  # (H,) int32
    hub_m: jax.Array | None = None  # (H, n) dense receive rows incl. self
    # ---- ppermute / colored ----
    partners: np.ndarray | None = None  # (n_colors, n) static int32
    color_edge_uid: jax.Array | None = None  # (n_colors, n) int32, -1 unmatched
    color_w: jax.Array | None = None  # (n_colors, n) statically normalised
    color_raw_w: jax.Array | None = None  # (n_colors, n) unnormalised
    # ---- event-driven (asynchronous) rendering, undirected plans only ----
    event_uv: jax.Array | None = None  # (max(n_edges,1), 2) int32 endpoints
    event_w: jax.Array | None = None  # (max(n_edges,1), 2) [M[u,v], M[v,u]]
    n_edges: int = 0  # undirected edge count (failure draw width)

    @property
    def n(self) -> int:
        return self.graph.n

    @property
    def n_colors(self) -> int:
        return 0 if self.partners is None else self.partners.shape[0]

    # ------------------------------------------------------------- execution
    def _masked(self, active, edge_live) -> bool:
        """Does this round need the renormalising masked path?  True when the
        failure model is active OR a deterministic membership/fault mask was
        supplied — the static fast paths (precomputed weights, HYB) encode
        the all-alive operator and must not serve masked rounds."""
        return self.failures.active or active is not None or edge_live is not None

    def mix(
        self,
        params: PyTree,
        key: jax.Array | None = None,
        *,
        active: jax.Array | None = None,
        edge_live: jax.Array | None = None,
        compression: Compression | None = None,
        residual: PyTree | None = None,
    ) -> PyTree:
        """One DecAvg aggregation of a node-stacked pytree.

        With ``compression`` (an active :class:`repro.core.compress
        .Compression` codec) the round runs the error-feedback delta form
        over this same operator and returns ``(mixed, new_residual)``
        instead — thread ``residual`` from the previous round (omitted:
        zeros).  Codec ``"none"``/``compression=None`` is the raw operator,
        bit-identical to the uncompressed path.

        Jit-friendly: ``self`` is closed over as compile-time constants, only
        ``params``/``key``/masks are traced.  ``active`` ((n,) bool) and
        ``edge_live`` ((n_edges,) bool, ``Graph.edge_list()`` order) are
        deterministic membership / fault-injection masks AND-composed with
        the Bernoulli failure draws: a masked-out node's row renormalises to
        the identity (it keeps its own model and nobody receives from it),
        exactly like a node the failure draw dropped.  The ``ppermute``
        backend here executes its colour schedule as node-axis gathers
        (single-process semantics); use ``color_round_weights`` +
        ``decavg.mix_pytree_colored`` inside ``shard_map`` for the true
        collective rendering (see launch/steps.py).
        """
        if self.failures.active and key is None:
            raise ValueError("failure model active: mix() needs a PRNG key")
        if compression is not None and compression.active:
            return compressed_mix(
                self,
                params,
                residual if residual is not None else init_residuals(params),
                key,
                compression=compression,
                active=active,
                edge_live=edge_live,
            )
        if self.backend == "dense":
            return mix_pytree(self._dense_round_matrix(key, active, edge_live), params)
        if self.backend == "sparse":
            if not self._masked(active, edge_live) and self.slot_idx is not None:
                # static topology: HYB layout (ELL slot chain + dense hub
                # rows) — the fused-gather rendering that beats the dense
                # einsum on CPU.  Failure/masked rounds renormalise per-edge,
                # so they take the segment_sum formulation below.
                return mix_pytree_hyb(
                    params, self.slot_idx, self.slot_w, self.hyb_self_w,
                    self.hub_rows, self.hub_m,
                )
            edge_w, self_w = self._sparse_round_weights(key, active, edge_live)
            return mix_pytree_sparse(
                params, self.src, self.dst, edge_w, self_w, n_nodes=self.n
            )
        color_w, self_w = self.color_round_weights(key, active, edge_live)
        return mix_pytree_colored(params, self.partners, color_w, self_w)

    def spread(
        self,
        values: jax.Array,
        key: jax.Array | None = None,
        *,
        active: jax.Array | None = None,
        edge_live: jax.Array | None = None,
        compression: Compression | None = None,
        residual: jax.Array | None = None,
    ) -> jax.Array:
        """One *send-form* (column-stochastic) round: ``values ← Mᵀ values``.

        With an active ``compression`` codec the round runs the delta form
        ``v + Mᵀ C(v + r) − C(v + r)`` and returns ``(values, residual)`` —
        mass-conserving for ANY codec because ``Mᵀ`` is column-stochastic
        (see ``core.compress.compressed_spread``).

        ``mix`` applies the row-stochastic receive operator ``M`` (Eq. 2);
        ``spread`` applies its transpose — column-stochastic, hence
        mass-conserving (``values.sum(0)`` is invariant), which is the
        property push-sum gossip needs (``repro.gossip``, paper §4.4).  For
        undirected graphs with unit data sizes ``Mᵀ`` *is* the paper's
        mixing matrix ``A'`` of Eq. 3: node j keeps ``1/(k_j+1)`` of its
        mass and pushes ``1/(k_j+1)`` along each live edge.

        Same backends, same sharding rules and — crucially — the same
        per-edge/per-node failure draws *and* membership masks as ``mix``
        for the same arguments: estimation traffic rides exactly the links
        training rides.  Because the masked ``M`` keeps every row summing
        to 1 (masked-out rows renormalise to the identity), ``Mᵀ`` stays
        column-stochastic: total mass is conserved under any mask.

        ``values``: (n,) or (n, k) float payload.  Returns the same shape.
        """
        if self.failures.active and key is None:
            raise ValueError("failure model active: spread() needs a PRNG key")
        if compression is not None and compression.active:
            return compressed_spread(
                self,
                values,
                residual if residual is not None else jnp.zeros(
                    jnp.shape(values), jnp.float32
                ),
                key,
                compression=compression,
                active=active,
                edge_live=edge_live,
            )
        x = jnp.asarray(values, jnp.float32)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        if self.backend == "dense":
            m = self._dense_round_matrix(key, active, edge_live)
            out = jnp.einsum("ji,jk->ik", m, x)
        elif self.backend == "sparse":
            edge_w, self_w = self._sparse_round_weights(key, active, edge_live)
            contrib = edge_w[:, None] * x[self.dst]
            out = self_w[:, None] * x + jax.ops.segment_sum(
                contrib, self.src, num_segments=self.n
            )
        else:
            color_w, self_w = self.color_round_weights(key, active, edge_live)
            partners = jnp.asarray(self.partners)
            sends = color_w[:, :, None] * x[None, :, :]  # (n_colors, n, k)
            # node j receives what its colour-c partner sent: partners is an
            # involution per colour, so gathering sends at partners[c] lands
            # each edge's mass on the opposite endpoint.
            recv = sends[jnp.arange(self.n_colors)[:, None], partners]
            out = self_w[:, None] * x + recv.sum(axis=0)
        return out[:, 0] if squeeze else out

    def spread_min(
        self,
        values: jax.Array,
        key: jax.Array | None = None,
        *,
        active: jax.Array | None = None,
        edge_live: jax.Array | None = None,
    ) -> jax.Array:
        """One round of neighbourhood **min**-exchange over the live links.

        ``out[i] = min(values[i], min over i's surviving neighbourhood)`` —
        the transport the leaderless exponential-random-minimum size sketches
        ride (``repro.gossip.estimate_size_leaderless``): extrema propagate
        through exactly the per-edge/per-node failure draws and membership
        masks that ``mix`` / ``spread`` consume for the same arguments, so
        sketch traffic shares training's links round for round.  Receive
        orientation (row i's neighbours); for the undirected graphs the init
        math assumes this is symmetric.

        ``values``: (n,) or (n, k) float payload.  Returns the same shape.
        """
        if self.failures.active and key is None:
            raise ValueError("failure model active: spread_min() needs a PRNG key")
        x = jnp.asarray(values, jnp.float32)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        inf = jnp.float32(jnp.inf)
        masked = self._masked(active, edge_live)
        if masked:
            edge_keep, node_act = self._round_masks_ext(key, active, edge_live)
        if self.backend == "dense":
            keep = self.adjacency > 0
            if masked:
                keep = keep & edge_keep[self.edge_uid_matrix]
                keep = keep & node_act[:, None] & node_act[None, :]
            nbr = jnp.where(keep[:, :, None], x[None, :, :], inf).min(axis=1)
        elif self.backend == "sparse":
            if masked:
                keep = edge_keep[self.edge_uid] & node_act[self.src] & node_act[self.dst]
                gathered = jnp.where(keep[:, None], x[self.src], inf)
            else:
                gathered = x[self.src]
            nbr = jax.ops.segment_min(
                gathered, self.dst, num_segments=self.n, indices_are_sorted=True
            )
        else:
            partners = jnp.asarray(self.partners)
            keep = self.color_edge_uid >= 0
            if masked:
                keep = keep & edge_keep[jnp.clip(self.color_edge_uid, 0, None)]
                keep = keep & node_act[None, :] & jnp.take(node_act, partners)
            cand = x[partners]  # (n_colors, n, k)
            nbr = jnp.where(keep[:, :, None], cand, inf).min(axis=0)
        out = jnp.minimum(x, nbr)
        return out[:, 0] if squeeze else out

    # ------------------------------------------------- event-driven execution
    def event_keep(self, key: jax.Array) -> jax.Array:
        """Bool scalar: did this event's exchange survive the failure model?

        The asynchronous analogue of ``round_masks``: one Bernoulli(link_p)
        for the firing edge plus one Bernoulli(node_p) per endpoint, drawn
        from the per-event key (callers fold the event index in, mirroring
        the per-round ``fold_in`` discipline).  A failed draw makes the
        *exchange* a no-op — no model moves, no message counts; the event
        executor's endpoints still wake for their local phase, exactly like
        failed-link nodes keep training in a synchronous round."""
        k_link, k_node = jax.random.split(key)
        keep = jnp.bool_(True)
        if self.failures.link_p < 1.0:
            keep = keep & (jax.random.uniform(k_link) < self.failures.link_p)
        if self.failures.node_p < 1.0:
            act = jax.random.bernoulli(k_node, self.failures.node_p, (2,))
            keep = keep & act[0] & act[1]
        return keep

    def _event_edge(self, edge, key: jax.Array | None):
        """(u, v, w_uv, w_vu) of one event; padding (edge = -1) and failed
        draws carry exactly-zero weights, i.e. the identity update."""
        if self.event_uv is None:
            raise ValueError(
                "event rendering needs an undirected CommPlan "
                "(directed plans have no event tables)"
            )
        if self.failures.active and key is None:
            raise ValueError("failure model active: event ops need a PRNG key")
        e = jnp.asarray(edge, jnp.int32)
        live = e >= 0
        if self.failures.active:
            live = live & self.event_keep(key)
        e0 = jnp.maximum(e, 0)
        w = self.event_w[e0] * live
        return self.event_uv[e0, 0], self.event_uv[e0, 1], w[0], w[1], live

    def event_mix(self, params: PyTree, edge, key: jax.Array | None = None) -> PyTree:
        """One asynchronous DecAvg event: edge ``edge``'s endpoints blend with
        the plan's receive weights (``w_u ← w_u + M[u,v]·(w_v − w_u)`` and
        symmetrically), everyone else untouched.  ``edge`` is a traced int32
        index into ``Graph.edge_list()``; -1 (the event-stream padding) is
        the identity.  Composing one event per edge reproduces ``mix`` to
        first order in the weights — the rate-1 parity property the event
        tests pin down."""
        u, v, w_uv, w_vu, _ = self._event_edge(edge, key)
        return mix_pytree_pairwise(params, u, v, w_uv, w_vu)

    def event_mix_batch(
        self, params: PyTree, edges, keys: jax.Array | None = None
    ) -> PyTree:
        """One **colour step**: a batch of simultaneous asynchronous events
        on endpoint-disjoint edges (``topology.batch_events_by_color``),
        applied as a single vectorised gather + scatter-add instead of W
        sequential pairwise updates — the ROADMAP §14 batching that recovers
        matmul-shaped work on the event path.

        ``edges``: (W,) traced int32 edge ids, -1 padding = identity.
        ``keys``: (W,) batch of *per-event* keys (``fold_in(base, i)`` with
        each event's original stream index), required iff failures are
        active — the failure draws are then bit-identical to replaying the
        same events through sequential ``event_mix``.
        """
        if self.event_uv is None:
            raise ValueError(
                "event rendering needs an undirected CommPlan "
                "(directed plans have no event tables)"
            )
        if self.failures.active and keys is None:
            raise ValueError("failure model active: event_mix_batch needs per-event keys")
        e = jnp.asarray(edges, jnp.int32)
        live = e >= 0
        if self.failures.active:
            live = live & jax.vmap(self.event_keep)(keys)
        e0 = jnp.maximum(e, 0)
        w = self.event_w[e0] * live[:, None]
        u, v = self.event_uv[e0, 0], self.event_uv[e0, 1]
        return mix_pytree_pairwise_batch(params, u, v, w[:, 0], w[:, 1])

    def event_spread(self, values: jax.Array, edge, key: jax.Array | None = None) -> jax.Array:
        """One asynchronous **push** event — the pairwise, mass-conserving
        rendering of ``spread`` (``s_u ← s_u − M[u,v]·s_u + M[v,u]·s_v``, and
        symmetrically): ``values.sum(0)`` is invariant event by event, which
        is what barrier-free push-sum estimation rides."""
        u, v, w_uv, w_vu, _ = self._event_edge(edge, key)
        x = jnp.asarray(values, jnp.float32)
        return spread_pairwise(x, u, v, w_uv, w_vu)

    def event_spread_min(self, values: jax.Array, edge, key: jax.Array | None = None) -> jax.Array:
        """One asynchronous **min** event: both endpoints take the
        coordinate-wise minimum over the live exchange — the event transport
        of the leaderless size sketches."""
        u, v, _, _, live = self._event_edge(edge, key)
        x = jnp.asarray(values, jnp.float32)
        return spread_min_pairwise(x, u, v, live)

    # ----------------------------------------------------- per-round weights
    def round_masks(self, key: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Public alias of the per-round failure draws, for host-side
        references that must key their Bernoullis identically (parity tests,
        ``core.gossip.effective_send_matrix``)."""
        return self._edge_node_masks(key)

    def _edge_node_masks(self, key: jax.Array) -> tuple[jax.Array, jax.Array]:
        """(edge_keep (n_edges,), node_active (n,)) — shared across backends."""
        return _draw_failure_masks(self.failures, self.n_edges, self.n, key)

    def _round_masks_ext(
        self, key: jax.Array | None, active, edge_live
    ) -> tuple[jax.Array, jax.Array]:
        """Bernoulli failure draws AND-composed with the deterministic
        membership / fault-injection masks.  ``edge_live`` shorter than the
        draw width (e.g. a plan's own edge count under a schedule envelope)
        pads with True — padding edges carry zero weight anyway."""
        if self.failures.active:
            edge_keep, node_act = self._edge_node_masks(key)
        else:
            edge_keep = jnp.ones((max(self.n_edges, 1),), dtype=bool)
            node_act = jnp.ones((self.n,), dtype=bool)
        if edge_live is not None:
            el = jnp.asarray(edge_live, dtype=bool)
            if el.shape[0] < edge_keep.shape[0]:
                el = jnp.pad(el, (0, edge_keep.shape[0] - el.shape[0]), constant_values=True)
            edge_keep = edge_keep & el[: edge_keep.shape[0]]
        if active is not None:
            node_act = node_act & jnp.asarray(active, dtype=bool)
        return edge_keep, node_act

    def _dense_round_matrix(
        self, key: jax.Array | None, active=None, edge_live=None
    ) -> jax.Array:
        if not self._masked(active, edge_live):
            return self.receive
        edge_keep, node_act = self._round_masks_ext(key, active, edge_live)
        keep = edge_keep[self.edge_uid_matrix] & (self.adjacency > 0)
        keep = keep & node_act[:, None] & node_act[None, :]
        a = self.adjacency * keep
        sizes = None if self.data_sizes is None else jnp.asarray(self.data_sizes, jnp.float32)
        b = a.astype(jnp.float32) + jnp.eye(self.n, dtype=jnp.float32)
        if sizes is not None:
            b = b * sizes[None, :]
        return b / b.sum(axis=1, keepdims=True)

    def _sparse_round_weights(
        self, key: jax.Array | None, active=None, edge_live=None
    ) -> tuple[jax.Array, jax.Array]:
        if not self._masked(active, edge_live):
            return self.edge_w, self.self_w
        edge_keep, node_act = self._round_masks_ext(key, active, edge_live)
        keep = edge_keep[self.edge_uid] & node_act[self.src] & node_act[self.dst]
        num = self.raw_edge_w * keep
        den = self.raw_self_w + jax.ops.segment_sum(
            num, self.dst, num_segments=self.n, indices_are_sorted=True
        )
        return num / den[self.dst], self.raw_self_w / den

    def color_round_weights(
        self, key: jax.Array | None, active=None, edge_live=None
    ) -> tuple[jax.Array, jax.Array]:
        """((n_colors, n), (n,)) normalised weights for this round's schedule."""
        if not self._masked(active, edge_live):
            return self.color_w, self.self_w
        edge_keep, node_act = self._round_masks_ext(key, active, edge_live)
        matched = self.color_edge_uid >= 0
        keep = matched & edge_keep[jnp.clip(self.color_edge_uid, 0, None)]
        partners = jnp.asarray(self.partners)
        keep = keep & node_act[None, :] & jnp.take(node_act, partners)
        num = self.color_raw_w * keep
        den = self.raw_self_w + num.sum(axis=0)
        return num / den[None, :], self.raw_self_w / den

    def color_perms(self) -> list[list[tuple[int, int]]]:
        """Static ppermute (src, dst) pair lists, one per colour class."""
        perms = []
        for c in range(self.n_colors):
            p = self.partners[c]
            perms.append([(i, int(p[i])) for i in range(self.n) if p[i] != i])
        return perms

    # ------------------------------------------------------------- plumbing
    def with_options(
        self,
        *,
        backend: str | None = None,
        data_sizes: np.ndarray | None = None,
        failures: FailureModel | None = None,
    ) -> "CommPlan":
        """Recompile this plan with some knobs replaced."""
        return compile_plan(
            self.graph,
            backend=backend or self.backend,
            data_sizes=self.data_sizes if data_sizes is None else data_sizes,
            failures=failures or self.failures,
        )

    def shard(self, *, mesh=None, axis: str | None = None, n_shards: int | None = None):
        """Render this plan over a node-sharded mesh axis (DESIGN.md §15) —
        see ``core.shardplan.shard_plan`` for the partition contract."""
        from .shardplan import shard_plan  # local import: shardplan builds on CommPlan

        return shard_plan(self, mesh=mesh, axis=axis, n_shards=n_shards)


def _event_tables(graph: Graph, sizes: np.ndarray | None) -> dict:
    """Per-edge endpoint/weight tables of the event-driven rendering.

    ``event_uv[e] = (u, v)`` in ``Graph.edge_list()`` order and
    ``event_w[e] = (M[u, v], M[v, u])`` — the synchronous receive operator's
    entries, so one event per edge composes to one synchronous round to
    first order.  Padded to at least one row so a traced clamp-to-0 gather
    stays in bounds on edgeless graphs.  Directed graphs get no tables
    (a pairwise exchange has no orientation to respect).
    """
    if graph.directed:
        return {}
    edges = graph.edge_list()
    if len(edges) == 0:
        return dict(
            event_uv=jnp.zeros((1, 2), jnp.int32),
            event_w=jnp.zeros((1, 2), jnp.float32),
        )
    m = receive_matrix(graph, sizes)
    u, v = edges[:, 0], edges[:, 1]
    return dict(
        event_uv=jnp.asarray(edges),
        event_w=jnp.asarray(np.stack([m[u, v], m[v, u]], axis=1), jnp.float32),
    )


def _hyb_layout(
    graph: Graph,
    indptr: np.ndarray,
    src: np.ndarray,
    raw_edge: np.ndarray,
    s: np.ndarray,
    den: np.ndarray,
) -> dict:
    """Compile the sparse backend's HYB layout (ELL slots + dense hub rows).

    Degree-threshold heuristic: each ELL slot costs one fused full-length
    gather pass over the (n, d) ensemble, each hub row one (1, n)·(n, d)
    matmul row; measured on CPU a hub row costs about a sixth of a slot
    pass, so minimise ``n_slots(t) + n_hub(t)/6`` over thresholds t.
    Heavy-tail hubs land in the dense part (a complete graph compiles to
    "all hub" = the dense einsum, which is indeed optimal there).
    """
    n = graph.n
    deg = np.diff(indptr)
    candidates = sorted(set(deg.tolist()) | {0})
    cost = lambda t: min(t, int(deg[deg <= t].max()) if (deg <= t).any() else 0) + (deg > t).sum() / 6.0
    t = min(candidates, key=cost)
    hub = np.nonzero(deg > t)[0].astype(np.int32)
    n_slots = int(deg[deg <= t].max()) if (deg <= t).any() else 0
    slot_idx = np.tile(np.arange(n, dtype=np.int32)[None, :], (n_slots, 1))
    slot_w = np.zeros((n_slots, n), np.float64)
    is_hub = np.zeros(n, dtype=bool)
    is_hub[hub] = True
    for i in range(n):
        if is_hub[i]:
            continue
        lo, hi = indptr[i], indptr[i + 1]
        slot_idx[: hi - lo, i] = src[lo:hi]
        slot_w[: hi - lo, i] = raw_edge[lo:hi] / den[i]
    hub_m = np.zeros((len(hub), n), np.float64)
    for r, i in enumerate(hub):
        lo, hi = indptr[i], indptr[i + 1]
        hub_m[r, src[lo:hi]] = raw_edge[lo:hi] / den[i]
        hub_m[r, i] = s[i] / den[i]
    return dict(
        slot_idx=jnp.asarray(slot_idx),
        slot_w=jnp.asarray(slot_w, jnp.float32),
        hyb_self_w=jnp.asarray(np.where(is_hub, 0.0, s / den), jnp.float32),
        hub_rows=jnp.asarray(hub),
        hub_m=jnp.asarray(hub_m, jnp.float32),
    )


def compile_plan(
    graph: Graph,
    backend: str = "auto",
    data_sizes: np.ndarray | Sequence[float] | None = None,
    failures: FailureModel | None = None,
) -> CommPlan:
    """Lower a ``Graph`` into an executable ``CommPlan``.

    backend="auto" picks dense for small ensembles (n ≤ 64, where the (n, n)
    einsum is cheapest and GSPMD-friendliest) and sparse beyond — the
    crossover the mixing benchmark sweep measures.
    """
    failures = failures or FailureModel()
    if backend == "auto":
        backend = "dense" if graph.n <= 64 else "sparse"
    if backend not in BACKENDS:
        raise ValueError(f"unknown mixing backend {backend!r}; expected one of {BACKENDS}")

    sizes = None if data_sizes is None else np.asarray(data_sizes, dtype=np.float64)
    n = graph.n
    n_edges = len(graph.edge_list())
    common = dict(
        graph=graph,
        backend=backend,
        failures=failures,
        data_sizes=None if sizes is None else sizes.copy(),
        n_edges=n_edges,
        **_event_tables(graph, sizes),
    )

    if backend == "dense":
        uid_matrix = np.zeros((n, n), dtype=np.int32)
        edges = graph.edge_list()
        if graph.directed:
            uid_matrix[edges[:, 0], edges[:, 1]] = np.arange(len(edges))
        else:
            uid_matrix[edges[:, 0], edges[:, 1]] = np.arange(len(edges))
            uid_matrix[edges[:, 1], edges[:, 0]] = np.arange(len(edges))
        return CommPlan(
            **common,
            receive=jnp.asarray(receive_matrix(graph, sizes), jnp.float32),
            adjacency=jnp.asarray(graph.adjacency),
            edge_uid_matrix=jnp.asarray(uid_matrix),
        )

    s = np.ones(n, dtype=np.float64) if sizes is None else sizes
    if backend == "sparse":
        indptr, src, uid = graph.csr()
        dst = np.repeat(np.arange(n, dtype=np.int32), np.diff(indptr))
        raw_edge = graph.adjacency[dst, src].astype(np.float64) * s[src]
        den = s + np.bincount(dst, weights=raw_edge, minlength=n)
        return CommPlan(
            **common,
            src=jnp.asarray(src),
            dst=jnp.asarray(dst),
            edge_uid=jnp.asarray(uid),
            edge_w=jnp.asarray(raw_edge / den[dst], jnp.float32),
            self_w=jnp.asarray(s / den, jnp.float32),
            raw_edge_w=jnp.asarray(raw_edge, jnp.float32),
            raw_self_w=jnp.asarray(s, jnp.float32),
            **_hyb_layout(graph, indptr, src, raw_edge, s, den),
        )

    # ppermute: greedy edge colouring → per-colour matchings
    coloring = graph.edge_coloring()
    partners = coloring.partners
    idx = np.arange(n)
    matched = partners != idx[None, :]
    # receive weight of edge (i, partner) at node i: A[i, partner] * s[partner]
    raw = np.where(
        matched,
        graph.adjacency[idx[None, :], partners] * s[partners],
        0.0,
    )
    den = s + raw.sum(axis=0)
    return CommPlan(
        **common,
        partners=partners,
        color_edge_uid=jnp.asarray(coloring.edge_index),
        color_w=jnp.asarray(raw / den[None, :], jnp.float32),
        color_raw_w=jnp.asarray(raw, jnp.float32),
        self_w=jnp.asarray(s / den, jnp.float32),
        raw_self_w=jnp.asarray(s, jnp.float32),
    )


# =========================================================================
# PlanSchedule: time-varying topologies as a first-class axis (DESIGN.md §13)
# =========================================================================


@dataclasses.dataclass(frozen=True)
class RoundMap:
    """round index → plan index assignment for a ``PlanSchedule``.

    ``cyclic``:   plan ``(r // period) % K`` — plans take turns, ``period``
                  rounds each.
    ``sequence``: plan ``sequence[r % len(sequence)]`` — an explicit
                  (piecewise or seeded-random/Markov-realised) assignment,
                  tiled past its horizon.
    Both forms are jit-traceable in ``r`` (integer arithmetic / one gather),
    which is what lets the executor switch operators *inside* its scan.
    """

    kind: str  # "cyclic" | "sequence"
    period: int = 1
    sequence: np.ndarray | None = None

    def __post_init__(self):
        if self.kind not in ("cyclic", "sequence"):
            raise ValueError(f"unknown round-map kind {self.kind!r}")
        if self.kind == "cyclic" and self.period < 1:
            raise ValueError("cyclic round map needs period >= 1")
        if self.kind == "sequence" and (self.sequence is None or len(self.sequence) == 0):
            raise ValueError("sequence round map needs a non-empty index sequence")


def cyclic_map(period: int = 1) -> RoundMap:
    """Plans take turns, ``period`` consecutive rounds each."""
    return RoundMap("cyclic", period=int(period))


def sequence_map(sequence) -> RoundMap:
    """Explicit per-round plan indices, tiled cyclically past the horizon."""
    return RoundMap("sequence", sequence=np.asarray(sequence, np.int32))


def _pad1(a: jax.Array, width: int, fill) -> jax.Array:
    return jnp.pad(a, (0, width - a.shape[0]), constant_values=fill)


def _stack_hyb(plans: Sequence[CommPlan], n: int) -> dict[str, jax.Array]:
    """Pad the sparse plans' HYB (ELL slots + dense hub rows) layouts to one
    envelope so the clean-path fast rendering survives scheduling.

    Slot padding is identity-index / zero-weight.  Hub-row padding repeats a
    plan's first hub (duplicate ``.set`` of the same value — harmless); a
    hub-free plan fabricates node 0's dense receive row, so the overwritten
    row carries exactly the operator value the ELL slots would produce.
    """
    s_env = max(p.slot_idx.shape[0] for p in plans)
    h_env = max(p.hub_rows.shape[0] for p in plans)
    idrow = jnp.arange(n, dtype=jnp.int32)[None, :]
    slot_idx, slot_w, hub_rows, hub_m = [], [], [], []
    for p in plans:
        s = p.slot_idx.shape[0]
        slot_idx.append(
            jnp.concatenate([p.slot_idx, jnp.tile(idrow, (s_env - s, 1))])
            if s_env > s
            else p.slot_idx
        )
        slot_w.append(jnp.pad(p.slot_w, ((0, s_env - s), (0, 0))))
        h = p.hub_rows.shape[0]
        if h_env == 0:
            hub_rows.append(p.hub_rows)
            hub_m.append(p.hub_m)
        elif h > 0:
            hub_rows.append(jnp.concatenate([p.hub_rows, jnp.repeat(p.hub_rows[:1], h_env - h)]))
            hub_m.append(jnp.concatenate([p.hub_m, jnp.repeat(p.hub_m[:1], h_env - h, axis=0)]))
        else:
            src, dst = np.asarray(p.src), np.asarray(p.dst)
            row = np.zeros(n, np.float32)
            sel = dst == 0
            row[src[sel]] = np.asarray(p.edge_w)[sel]
            row[0] = float(np.asarray(p.self_w)[0])
            hub_rows.append(jnp.zeros((h_env,), jnp.int32))
            hub_m.append(jnp.tile(jnp.asarray(row)[None, :], (h_env, 1)))
    return dict(
        slot_idx=jnp.stack(slot_idx),
        slot_w=jnp.stack(slot_w),
        hyb_self_w=jnp.stack([p.hyb_self_w for p in plans]),
        hub_rows=jnp.stack(hub_rows),
        hub_m=jnp.stack(hub_m),
    )


def _stack_plans(plans: Sequence[CommPlan]) -> dict[str, jax.Array]:
    """Stack K same-backend plans into shared-shape device buffers.

    The shared sparsity envelope: CSR edge arrays pad to the max nnz with
    zero-weight (src = dst = n-1) entries — appended, so per-plan ``dst``
    stays sorted and ``segment_sum(indices_are_sorted=True)`` stays valid —
    and colour layouts pad to the max colour count with unmatched
    (identity-partner, zero-weight, uid = -1) classes.  Padding carries
    exactly-zero weights, so gathered plans execute the unpadded operator.
    """
    backend = plans[0].backend
    st: dict[str, jax.Array] = {}
    if backend == "dense":
        for f in ("receive", "adjacency", "edge_uid_matrix"):
            st[f] = jnp.stack([getattr(p, f) for p in plans])
    elif backend == "sparse":
        n = plans[0].n
        nnz = max(p.src.shape[0] for p in plans)
        st["src"] = jnp.stack([_pad1(p.src, nnz, n - 1) for p in plans])
        st["dst"] = jnp.stack([_pad1(p.dst, nnz, n - 1) for p in plans])
        st["edge_uid"] = jnp.stack([_pad1(p.edge_uid, nnz, 0) for p in plans])
        st["edge_w"] = jnp.stack([_pad1(p.edge_w, nnz, 0.0) for p in plans])
        st["raw_edge_w"] = jnp.stack([_pad1(p.raw_edge_w, nnz, 0.0) for p in plans])
        st["self_w"] = jnp.stack([p.self_w for p in plans])
        st["raw_self_w"] = jnp.stack([p.raw_self_w for p in plans])
        st.update(_stack_hyb(plans, n))
    else:  # ppermute
        n = plans[0].n
        nc = max(p.n_colors for p in plans)
        idrow = np.arange(n, dtype=np.int32)

        def pad_colors(a, fill, k):
            a = jnp.asarray(a)
            return jnp.pad(a, ((0, nc - k), (0, 0)), constant_values=fill)

        st["partners"] = jnp.stack(
            [
                jnp.asarray(
                    np.concatenate(
                        [p.partners, np.tile(idrow[None, :], (nc - p.n_colors, 1))]
                    )
                    if nc > p.n_colors
                    else p.partners
                )
                for p in plans
            ]
        )
        st["color_edge_uid"] = jnp.stack(
            [pad_colors(p.color_edge_uid, -1, p.n_colors) for p in plans]
        )
        st["color_w"] = jnp.stack([pad_colors(p.color_w, 0.0, p.n_colors) for p in plans])
        st["color_raw_w"] = jnp.stack(
            [pad_colors(p.color_raw_w, 0.0, p.n_colors) for p in plans]
        )
        st["self_w"] = jnp.stack([p.self_w for p in plans])
        st["raw_self_w"] = jnp.stack([p.raw_self_w for p in plans])
    if all(p.event_uv is not None for p in plans):
        # event tables pad to the edge envelope with (0, 0) endpoints and
        # exactly-zero weights — a padded event id is the identity update
        ev = max(p.event_uv.shape[0] for p in plans)
        st["event_uv"] = jnp.stack(
            [jnp.pad(p.event_uv, ((0, ev - p.event_uv.shape[0]), (0, 0))) for p in plans]
        )
        st["event_w"] = jnp.stack(
            [jnp.pad(p.event_w, ((0, ev - p.event_w.shape[0]), (0, 0))) for p in plans]
        )
    return st


@dataclasses.dataclass(frozen=True)
class PlanSchedule:
    """A time-varying mixing operator: K compiled ``CommPlan``s + a round map.

    The K plans share one backend, one failure model and one shape envelope
    (``_stack_plans``), so ``select(round)`` — a handful of gathers at a
    traced plan index — yields a ``CommPlan`` view *inside* jit/scan/vmap:
    the executor's scanned round body switches operators by round index with
    no host round-trip, and the gossip engine estimates on the dynamic graph
    nodes actually see.

    Contracts:
    * K = 1 is the static case and stays **bit-identical** to the plain
      ``CommPlan`` path: ``select`` returns the underlying plan itself (no
      gather, no padding) and ``round_key`` leaves failure keys untouched.
    * K > 1 folds the active plan index into every failure key
      (``round_key``), so resampled plans draw independent failures.
    * All plans must share the node count; data sizes are per-node and
      shared across plans.
    """

    plans: tuple[CommPlan, ...]
    round_map: RoundMap
    stacked: dict[str, jax.Array] = dataclasses.field(default_factory=dict, repr=False)
    n_edges_env: int = 0

    # ------------------------------------------------------------- metadata
    @property
    def k(self) -> int:
        return len(self.plans)

    @property
    def n(self) -> int:
        return self.plans[0].n

    @property
    def backend(self) -> str:
        return self.plans[0].backend

    @property
    def failures(self) -> FailureModel:
        return self.plans[0].failures

    @property
    def data_sizes(self) -> np.ndarray | None:
        return self.plans[0].data_sizes

    @property
    def graph(self) -> Graph:
        """The round-0 plan's graph — size metadata and the "what a node sees
        at estimation start" anchor (degrees payloads, walker start checks)."""
        return self.plans[0].graph

    # ------------------------------------------------------------ selection
    def plan_index(self, round_index) -> jax.Array:
        """Traceable round → plan index (int32 scalar)."""
        r = jnp.asarray(round_index, jnp.int32)
        if self.k == 1:
            return jnp.zeros_like(r)
        m = self.round_map
        if m.kind == "cyclic":
            return (r // m.period) % self.k
        seq = jnp.asarray(m.sequence)
        return seq[r % seq.shape[0]]

    def round_key(self, key: jax.Array | None, round_index) -> jax.Array | None:
        """Fold the active plan id into a per-round failure key (satellite
        contract): K > 1 resampled plans draw independent failures; K = 1
        leaves the key untouched, reproducing the static plan's draws
        exactly."""
        if key is None or self.k == 1:
            return key
        return jax.random.fold_in(key, self.plan_index(round_index))

    def select(self, round_index) -> CommPlan:
        """The round's ``CommPlan``: K = 1 → the plan itself (bit-identical
        static path); K > 1 → a gathered view over the stacked envelope,
        traceable in ``round_index``.  The view's ``graph`` field is the
        round-0 graph (size metadata only) and its ``n_edges`` is the shared
        envelope, so failure draws have one static shape for every round."""
        if self.k == 1:
            return self.plans[0]
        i = self.plan_index(round_index)
        t = lambda name: (
            jnp.take(self.stacked[name], i, axis=0) if name in self.stacked else None
        )
        return CommPlan(
            graph=self.plans[0].graph,
            backend=self.backend,
            failures=self.failures,
            data_sizes=self.plans[0].data_sizes,
            receive=t("receive"),
            adjacency=t("adjacency"),
            edge_uid_matrix=t("edge_uid_matrix"),
            src=t("src"),
            dst=t("dst"),
            edge_uid=t("edge_uid"),
            edge_w=t("edge_w"),
            self_w=t("self_w"),
            raw_edge_w=t("raw_edge_w"),
            raw_self_w=t("raw_self_w"),
            slot_idx=t("slot_idx"),
            slot_w=t("slot_w"),
            hyb_self_w=t("hyb_self_w"),
            hub_rows=t("hub_rows"),
            hub_m=t("hub_m"),
            partners=t("partners"),
            color_edge_uid=t("color_edge_uid"),
            color_w=t("color_w"),
            color_raw_w=t("color_raw_w"),
            event_uv=t("event_uv"),
            event_w=t("event_w"),
            n_edges=self.n_edges_env,
        )

    # ------------------------------------------------------------ execution
    def mix(
        self,
        params: PyTree,
        round_index,
        key: jax.Array | None = None,
        *,
        active: jax.Array | None = None,
        edge_live: jax.Array | None = None,
        compression: Compression | None = None,
        residual: PyTree | None = None,
    ) -> PyTree:
        """One DecAvg round under the plan active at ``round_index``.
        ``edge_live`` is read at the schedule's shared edge *envelope* width
        (``n_edges_env``), indexed by the active plan's own edge uids.
        ``compression``/``residual`` follow ``CommPlan.mix``: an active
        codec returns ``(mixed, new_residual)``."""
        return self.select(round_index).mix(
            params, self.round_key(key, round_index), active=active,
            edge_live=edge_live, compression=compression, residual=residual,
        )

    def spread(
        self,
        values: jax.Array,
        round_index,
        key: jax.Array | None = None,
        *,
        active: jax.Array | None = None,
        edge_live: jax.Array | None = None,
        compression: Compression | None = None,
        residual: jax.Array | None = None,
    ) -> jax.Array:
        """One send-form (push) round under the active plan."""
        return self.select(round_index).spread(
            values, self.round_key(key, round_index), active=active,
            edge_live=edge_live, compression=compression, residual=residual,
        )

    def spread_min(
        self,
        values: jax.Array,
        round_index,
        key: jax.Array | None = None,
        *,
        active: jax.Array | None = None,
        edge_live: jax.Array | None = None,
    ) -> jax.Array:
        """One min-exchange round under the active plan (leaderless sketches)."""
        return self.select(round_index).spread_min(
            values, self.round_key(key, round_index), active=active, edge_live=edge_live
        )

    # ------------------------------------------------- event-driven execution
    def _window(self, time) -> jax.Array:
        """Unit-time window index of an event timestamp (1 window = 1 round
        of the round map), traceable in ``time``."""
        return jnp.floor(jnp.asarray(time, jnp.float32)).astype(jnp.int32)

    def event_key(self, key: jax.Array | None, time) -> jax.Array | None:
        """Fold the plan id active at ``time``'s window into a per-event
        failure key — the event-path mirror of ``round_key`` (satellite
        contract): K > 1 plans draw independent per-event node/link outages;
        K = 1 leaves the key untouched, bit-identical to the static plan."""
        if key is None or self.k == 1:
            return key
        return jax.random.fold_in(key, self.plan_index(self._window(time)))

    def event_mix(self, params: PyTree, edge, time, key: jax.Array | None = None) -> PyTree:
        """One asynchronous DecAvg event under the plan active at ``time``.
        ``edge`` indexes the active plan's own ``Graph.edge_list()`` (use
        ``event_stream`` to sample streams with per-window edge ids)."""
        w = self._window(time)
        return self.select(w).event_mix(params, edge, self.event_key(key, time))

    def event_spread(self, values: jax.Array, edge, time, key: jax.Array | None = None) -> jax.Array:
        """One asynchronous push event under the plan active at ``time``."""
        w = self._window(time)
        return self.select(w).event_spread(values, edge, self.event_key(key, time))

    def event_spread_min(
        self, values: jax.Array, edge, time, key: jax.Array | None = None
    ) -> jax.Array:
        """One asynchronous min event under the plan active at ``time``."""
        w = self._window(time)
        return self.select(w).event_spread_min(values, edge, self.event_key(key, time))

    def _host_plan_index(self, round_index: int) -> int:
        """Host (numpy) replica of ``plan_index`` — event-stream sampling and
        parity references resolve the active plan without tracing."""
        if self.k == 1:
            return 0
        m = self.round_map
        if m.kind == "cyclic":
            return (int(round_index) // m.period) % self.k
        seq = np.asarray(m.sequence)
        return int(seq[int(round_index) % len(seq)])

    def event_stream(self, horizon: float, rate: float = 1.0, seed: int = 0):
        """Sample a Poisson edge-clock stream over the *schedule*: each
        unit-time window draws its events from the plan active in that
        window (edge ids in that plan's own edge order), windows concatenate
        into one time-sorted stream.  K = 1 delegates to the static sampler
        bit-identically."""
        from .topology import EventStream, poisson_event_stream

        if self.k == 1:
            return poisson_event_stream(self.plans[0].graph, horizon, rate=rate, seed=seed)
        n_windows = int(np.ceil(horizon))
        times, edges = [], []
        for w in range(n_windows):
            g = self.plans[self._host_plan_index(w)].graph
            span = min(1.0, horizon - w)
            win = poisson_event_stream(g, span, rate=rate, seed=seed + w)
            k = win.n_events
            times.append(np.asarray(win.times[:k]) + w)
            edges.append(np.asarray(win.edges[:k]))
        t = np.concatenate(times) if times else np.zeros(0, np.float64)
        e = np.concatenate(edges) if edges else np.zeros(0, np.int32)
        return EventStream(
            times=np.asarray(t, np.float32),
            edges=np.asarray(e, np.int32),
            n_events=len(t),
            horizon=float(horizon),
            rates=np.full(len(self.plans[0].graph.edge_list()), float(rate)),
        )

    def round_masks(self, key: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Envelope-width failure draws — what every selected plan consumes.
        Host references replaying a schedule must draw at this width (then
        index masks by the active plan's own edge uids)."""
        return _draw_failure_masks(self.failures, self.n_edges_env, self.n, key)

    def stacked_csr(self) -> dict[str, jax.Array]:
        """Stacked CSR views of every plan's graph, padded to one envelope:
        ``indptr`` (K, n+1), ``indices``/``uid`` (K, nnz_env), ``deg`` (K, n)
        int32 and ``degrees`` (K, n) float32 — the random-walk degree
        pollers' per-round transition tables (``repro.gossip.walker``)."""
        graphs = [p.graph for p in self.plans]
        csrs = [g.csr() for g in graphs]
        nnz = max(len(c[1]) for c in csrs)
        pad = lambda a: np.pad(a, (0, nnz - len(a)))
        return dict(
            indptr=jnp.asarray(np.stack([c[0] for c in csrs])),
            indices=jnp.asarray(np.stack([pad(c[1]) for c in csrs])),
            uid=jnp.asarray(np.stack([pad(c[2]) for c in csrs])),
            deg=jnp.asarray(np.stack([np.diff(c[0]).astype(np.int32) for c in csrs])),
            degrees=jnp.asarray(
                np.stack([g.degrees for g in graphs]), jnp.float32
            ),
        )

    # ------------------------------------------------------------- plumbing
    def with_options(
        self,
        *,
        backend: str | None = None,
        data_sizes: np.ndarray | None = None,
        failures: FailureModel | None = None,
    ) -> "PlanSchedule":
        """Recompile the whole schedule with some knobs replaced."""
        return compile_schedule(
            [p.graph for p in self.plans],
            backend=backend or self.backend,
            data_sizes=self.data_sizes if data_sizes is None else data_sizes,
            failures=failures or self.failures,
            round_map=self.round_map,
        )


def compile_schedule(
    graphs: Sequence[Graph],
    backend: str = "auto",
    data_sizes: np.ndarray | Sequence[float] | None = None,
    failures: FailureModel | None = None,
    round_map: RoundMap | None = None,
) -> PlanSchedule:
    """Lower K graphs (+ a round→plan map) into a ``PlanSchedule``.

    Every graph compiles through ``compile_plan`` with the same backend /
    data sizes / failure model; the per-plan buffers are then stacked into
    the shared shape envelope.  ``round_map`` defaults to ``cyclic_map(1)``
    (round-robin); ``topology.churn_sequence`` builds Markov-churned graph
    sequences to feed here.
    """
    graphs = list(graphs)
    if not graphs:
        raise ValueError("compile_schedule needs at least one graph")
    if len({g.n for g in graphs}) != 1:
        raise ValueError(
            f"all plans in a schedule must share the node count, got "
            f"{[g.n for g in graphs]}"
        )
    if backend == "auto":
        backend = "dense" if graphs[0].n <= 64 else "sparse"
    plans = tuple(
        compile_plan(g, backend=backend, data_sizes=data_sizes, failures=failures)
        for g in graphs
    )
    round_map = round_map or cyclic_map(1)
    if round_map.kind == "sequence" and int(np.max(round_map.sequence)) >= len(plans):
        raise ValueError(
            f"round map references plan {int(np.max(round_map.sequence))} but the "
            f"schedule holds only {len(plans)} plans"
        )
    stacked = _stack_plans(plans) if len(plans) > 1 else {}
    return PlanSchedule(
        plans=plans,
        round_map=round_map,
        stacked=stacked,
        n_edges_env=max(p.n_edges for p in plans),
    )

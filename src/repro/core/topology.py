"""Communication-network topologies for decentralised federated learning.

The paper (§3, §4.4) studies complete graphs, random k-regular graphs,
Erdős–Rényi G(n,p)/G(n,m), Barabási–Albert preferential attachment,
heavy-tail configuration models and lattices on d-dimensional tori.

Graphs are built with numpy (seeded, deterministic) and exposed as a small
``Graph`` value type carrying the dense adjacency matrix.  The dense (n, n)
float32 matrix stays the canonical *description* of the network (trivially
small up to a few thousand nodes), but execution no longer has to consume it
densely: ``Graph`` also exports cached CSR / edge-list / edge-colouring views
that ``repro.core.commplan`` compiles into sparse gather-scatter and
``ppermute`` mixing schedules (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = [
    "Graph",
    "EdgeColoring",
    "EventStream",
    "complete",
    "ring",
    "circulant",
    "random_k_regular",
    "erdos_renyi_gnp",
    "erdos_renyi_gnm",
    "barabasi_albert",
    "configuration_heavy_tail",
    "torus_lattice",
    "star",
    "from_adjacency",
    "churn_sequence",
    "poisson_event_stream",
    "EventBatches",
    "batch_events_by_color",
]


@dataclasses.dataclass(frozen=True)
class EdgeColoring:
    """A proper edge colouring as per-colour perfect partial matchings.

    Each colour class is a set of vertex-disjoint edges, i.e. an involution on
    the node set: ``partners[c, i]`` is i's partner under colour c (or i itself
    when i is unmatched in that colour).  ``edge_index[c, i]`` is the index of
    edge (i, partners[c, i]) in ``Graph.edge_list()`` (-1 when unmatched) —
    the hook failure models use to draw one Bernoulli per *edge* and have both
    endpoints agree on it.  Because each colour is a matching, one colour =
    one ``ppermute`` round on a node-sharded mesh (DESIGN.md §3.3).
    """

    partners: np.ndarray  # (n_colors, n) int32
    edge_index: np.ndarray  # (n_colors, n) int32, -1 where unmatched

    @property
    def n_colors(self) -> int:
        return self.partners.shape[0]


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected (or directed, if ``directed``) communication network."""

    adjacency: np.ndarray  # (n, n) float32, zero diagonal
    name: str
    directed: bool = False

    def __post_init__(self):
        a = self.adjacency
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"adjacency must be square, got {a.shape}")
        if np.any(np.diag(a) != 0):
            raise ValueError("adjacency must have a zero diagonal (self-loops are added by the mixing matrix)")
        if not self.directed and not np.allclose(a, a.T):
            raise ValueError("undirected graph must have a symmetric adjacency matrix")
        object.__setattr__(self, "_export_cache", {})

    @property
    def n(self) -> int:
        return self.adjacency.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """Weighted out-degree of each node (row sums for directed graphs)."""
        return self.adjacency.sum(axis=1)

    @property
    def n_edges(self) -> int:
        m = int(np.count_nonzero(self.adjacency))
        return m if self.directed else m // 2

    @property
    def mean_degree(self) -> float:
        return float(self.degrees.mean())

    def neighbours(self, i: int) -> np.ndarray:
        return np.nonzero(self.adjacency[i])[0]

    def is_connected(self) -> bool:
        """BFS connectivity check (weak connectivity for directed graphs)."""
        a = self.adjacency
        if self.directed:
            a = a + a.T
        n = self.n
        seen = np.zeros(n, dtype=bool)
        frontier = np.zeros(n, dtype=bool)
        frontier[0] = seen[0] = True
        while frontier.any():
            nxt = (a[frontier].sum(axis=0) > 0) & ~seen
            seen |= nxt
            frontier = nxt
        return bool(seen.all())

    # ---- execution-backend exports (cached; consumed by core.commplan) ----
    def edge_list(self) -> np.ndarray:
        """(m, 2) int32 array of edges.

        Undirected graphs list each edge once with i < j; directed graphs
        list every (src, dst) arc.  Order is deterministic (row-major scan of
        the adjacency), so edge indices are stable identifiers — the failure
        model keys its per-edge Bernoulli draws on them.
        """
        return self._cached("edge_list", self._build_edge_list)

    def csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """CSR view of the *receive* pattern: (indptr, indices, edge_uid).

        Row i lists the in-neighbours j with A[i, j] != 0 (for undirected
        graphs that is simply the neighbourhood).  ``edge_uid[e]`` maps the
        e-th CSR entry back to its row in ``edge_list()`` so both directions
        of an undirected edge share one failure draw.
        """
        return self._cached("csr", self._build_csr)

    def edge_coloring(self) -> EdgeColoring:
        """Greedy proper edge colouring (≤ 2Δ-1 colours; Δ or Δ+1 typical).

        Edges are coloured in descending order of endpoint-degree sum — the
        classical greedy order that keeps the colour count near Vizing's Δ+1
        bound on the heavy-tail graphs where naive order is worst.
        Undirected graphs only: a colour class must be a matching to be a
        valid ``ppermute`` round.
        """
        if self.directed:
            raise ValueError("edge colouring (ppermute scheduling) requires an undirected graph")
        return self._cached("edge_coloring", self._build_edge_coloring)

    def _cached(self, key: str, build):
        cache = self._export_cache
        if key not in cache:
            cache[key] = build()
        return cache[key]

    def _build_edge_list(self) -> np.ndarray:
        a = self.adjacency
        if self.directed:
            i, j = np.nonzero(a)
        else:
            i, j = np.nonzero(np.triu(a, k=1))
        return np.stack([i, j], axis=1).astype(np.int32)

    def _build_csr(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        edges = self.edge_list()
        n = self.n
        if self.directed:
            # A[i, j] != 0 means "i receives from j" (receive_matrix, Eq. 2):
            # row i's CSR entries are exactly row i's adjacency nonzeros
            dst, src = edges[:, 0], edges[:, 1]
            uid = np.arange(len(edges), dtype=np.int32)
        else:
            dst = np.concatenate([edges[:, 0], edges[:, 1]])
            src = np.concatenate([edges[:, 1], edges[:, 0]])
            uid = np.concatenate([np.arange(len(edges), dtype=np.int32)] * 2)
        order = np.lexsort((src, dst))
        dst, src, uid = dst[order], src[order], uid[order]
        indptr = np.zeros(n + 1, dtype=np.int32)
        np.add.at(indptr, dst + 1, 1)
        indptr = np.cumsum(indptr).astype(np.int32)
        return indptr, src.astype(np.int32), uid.astype(np.int32)

    def _build_edge_coloring(self) -> EdgeColoring:
        edges = self.edge_list()
        k = self.adjacency.astype(bool).sum(axis=1)
        order = np.argsort(-(k[edges[:, 0]] + k[edges[:, 1]]), kind="stable")
        node_colors: list[set[int]] = [set() for _ in range(self.n)]
        colors: list[list[tuple[int, int, int]]] = []
        for e in order:
            u, v = int(edges[e, 0]), int(edges[e, 1])
            c = 0
            used = node_colors[u] | node_colors[v]
            while c in used:
                c += 1
            if c == len(colors):
                colors.append([])
            colors[c].append((u, v, int(e)))
            node_colors[u].add(c)
            node_colors[v].add(c)
        partners = np.tile(np.arange(self.n, dtype=np.int32), (len(colors), 1))
        edge_index = np.full((len(colors), self.n), -1, dtype=np.int32)
        for c, cls in enumerate(colors):
            for u, v, e in cls:
                partners[c, u], partners[c, v] = v, u
                edge_index[c, u] = edge_index[c, v] = e
        return EdgeColoring(partners=partners, edge_index=edge_index)

    def degree_assortativity(self) -> float:
        """Pearson correlation of degrees at either end of an edge."""
        i, j = np.nonzero(np.triu(self.adjacency))
        k = self.degrees
        x = np.concatenate([k[i], k[j]])
        y = np.concatenate([k[j], k[i]])
        if x.std() == 0:
            return 0.0
        return float(np.corrcoef(x, y)[0, 1])


def from_adjacency(a: np.ndarray, name: str = "custom", directed: bool = False) -> Graph:
    return Graph(np.asarray(a, dtype=np.float32), name=name, directed=directed)


def complete(n: int) -> Graph:
    a = np.ones((n, n), dtype=np.float32) - np.eye(n, dtype=np.float32)
    return Graph(a, name=f"complete-{n}")


def ring(n: int) -> Graph:
    return circulant(n, offsets=(1,), name=f"ring-{n}")


def circulant(n: int, offsets: Sequence[int], name: str | None = None) -> Graph:
    """Circulant graph: node i is connected to i +- s (mod n) for each offset s.

    Circulant graphs are k-regular with k = 2 * len(offsets) (assuming distinct
    offsets with s != n/2) and map onto TPU meshes as ``collective_permute``
    chains -- the beyond-paper optimisation of the DecAvg schedule.
    """
    a = np.zeros((n, n), dtype=np.float32)
    for s in offsets:
        s = int(s) % n
        if s == 0:
            raise ValueError("offset 0 would be a self-loop")
        idx = np.arange(n)
        a[idx, (idx + s) % n] = 1.0
        a[(idx + s) % n, idx] = 1.0
    return Graph(a, name=name or f"circulant-{n}-{tuple(offsets)}")


def random_k_regular(n: int, k: int, seed: int = 0) -> Graph:
    """Random k-regular graph (Steger–Wormald style, via networkx), connected.

    Plain pairing-model rejection has acceptance ~exp(-(k²-1)/4) and is
    hopeless beyond k≈6; networkx implements the suitable-edge algorithm.
    Connectivity is w.h.p. for k >= 3 and retried across seeds otherwise.
    """
    import networkx as nx

    if (n * k) % 2 != 0:
        raise ValueError("n*k must be even")
    if k >= n:
        raise ValueError("k must be < n")
    for attempt in range(100):
        gnx = nx.random_regular_graph(k, n, seed=seed + 7919 * attempt)
        a = nx.to_numpy_array(gnx, dtype=np.float32)
        g = Graph(a, name=f"kreg-{n}-{k}")
        if g.is_connected():
            return g
    raise RuntimeError(f"failed to build a connected simple {k}-regular graph on {n} nodes")


def erdos_renyi_gnp(n: int, p: float, seed: int = 0, require_connected: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    for _attempt in range(2000):
        u = rng.random((n, n))
        upper = np.triu(u < p, k=1)
        a = (upper | upper.T).astype(np.float32)
        g = Graph(a, name=f"er-gnp-{n}-{p:g}")
        if not require_connected or g.is_connected():
            return g
    raise RuntimeError(f"failed to sample a connected G({n},{p}) graph")


def erdos_renyi_gnm(n: int, m: int, seed: int = 0, require_connected: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    iu, ju = np.triu_indices(n, k=1)
    for _attempt in range(2000):
        pick = rng.choice(len(iu), size=m, replace=False)
        a = np.zeros((n, n), dtype=np.float32)
        a[iu[pick], ju[pick]] = 1.0
        a += a.T
        g = Graph(a, name=f"er-gnm-{n}-{m}")
        if not require_connected or g.is_connected():
            return g
    raise RuntimeError(f"failed to sample a connected G({n},{m}) graph")


def barabasi_albert(n: int, m: int, seed: int = 0) -> Graph:
    """Barabási–Albert preferential attachment: each new node attaches m edges."""
    if m < 1 or m >= n:
        raise ValueError("need 1 <= m < n")
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), dtype=np.float32)
    # seed clique of m+1 nodes so early attachment targets exist
    for i in range(m + 1):
        for j in range(i + 1, m + 1):
            a[i, j] = a[j, i] = 1.0
    # repeated-nodes list implements linear preferential attachment
    targets_pool = list(np.nonzero(a)[0])
    for v in range(m + 1, n):
        chosen: set[int] = set()
        while len(chosen) < m:
            t = int(targets_pool[rng.integers(len(targets_pool))])
            if t != v:
                chosen.add(t)
        for t in chosen:
            a[v, t] = a[t, v] = 1.0
            targets_pool.extend([v, t])
    return Graph(a, name=f"ba-{n}-{m}")


def configuration_heavy_tail(
    n: int, gamma: float, k_min: int = 2, mean_degree: float | None = None, seed: int = 0
) -> Graph:
    """Configuration-model graph with p(k) ~ k^-gamma, simple-graph rejection.

    If ``mean_degree`` is given, k_min is kept and the power-law is truncated /
    resampled so the expected mean degree matches approximately (the paper
    compares families at equal link counts).
    """
    import networkx as nx

    rng = np.random.default_rng(seed)
    k_max = max(int(np.sqrt(n)), k_min + 1)  # structural cutoff keeps the graph simple-able
    ks = np.arange(k_min, k_max + 1)
    pk = ks.astype(np.float64) ** (-gamma)
    pk /= pk.sum()
    deg = rng.choice(ks, size=n, p=pk)
    if mean_degree is not None:
        # resample individual nodes to nudge the mean toward the target
        for _ in range(20 * n):
            err = deg.mean() - mean_degree
            if abs(err) < 0.05:
                break
            i = rng.integers(n)
            deg[i] = max(k_min, min(k_max, deg[i] - int(np.sign(err))))
    if deg.sum() % 2 == 1:
        deg[int(rng.integers(n))] += 1
    # erased configuration model: pair stubs, then drop self-loops/multi-edges.
    # Degree distortion is O(⟨k²⟩/n), negligible under the structural cutoff.
    gnx = nx.configuration_model(deg.tolist(), seed=int(rng.integers(2**31)))
    gnx = nx.Graph(gnx)  # collapse multi-edges
    gnx.remove_edges_from(nx.selfloop_edges(gnx))
    a = nx.to_numpy_array(gnx, nodelist=range(n), dtype=np.float32)
    # stitch smaller components onto the giant one (one edge each) so the
    # graph is connected, as the paper's simulations require
    comps = sorted(nx.connected_components(gnx), key=len, reverse=True)
    giant = list(comps[0])
    for comp in comps[1:]:
        u = int(next(iter(comp)))
        v = int(giant[int(rng.integers(len(giant)))])
        a[u, v] = a[v, u] = 1.0
    g = Graph(a, name=f"conf-{n}-g{gamma:g}")
    if not g.is_connected():
        raise RuntimeError(f"failed to build connected heavy-tail configuration graph (n={n}, gamma={gamma})")
    return g


def torus_lattice(dims: Sequence[int]) -> Graph:
    """Lattice on a d-dimensional torus (each node has degree 2d)."""
    dims = tuple(int(d) for d in dims)
    n = int(np.prod(dims))
    coords = np.stack(np.unravel_index(np.arange(n), dims), axis=1)  # (n, d)
    a = np.zeros((n, n), dtype=np.float32)
    for axis, size in enumerate(dims):
        nxt = coords.copy()
        nxt[:, axis] = (nxt[:, axis] + 1) % size
        j = np.ravel_multi_index(tuple(nxt.T), dims)
        i = np.arange(n)
        a[i, j] = 1.0
        a[j, i] = 1.0
    return Graph(a, name=f"torus-{'x'.join(map(str, dims))}")


def star(n: int) -> Graph:
    """Star graph: the topology of *centralised* federated learning (§1)."""
    a = np.zeros((n, n), dtype=np.float32)
    a[0, 1:] = 1.0
    a[1:, 0] = 1.0
    return Graph(a, name=f"star-{n}")


@dataclasses.dataclass(frozen=True)
class EventStream:
    """A realised asynchronous gossip schedule: sorted (time, edge) events.

    The coordination-free setting has no global round barrier — each edge
    carries an independent Poisson clock and the pair it joins exchanges
    whenever the clock fires (Boyd-style randomised gossip; Valerio et al.'s
    uncoordinated DFL).  Like ``churn_sequence``, the stochastic process is
    realised **host-side** (seeded, deterministic) into static device-shaped
    tensors so the executor can ``lax.scan`` over events without host
    round-trips:

    ``times``  (E,) float32, non-decreasing; padding entries hold ``horizon``.
    ``edges``  (E,) int32 indices into ``Graph.edge_list()``; padding is -1,
               which every event operator treats as the identity — the
               static *envelope* that lets streams of different realised
               lengths share one compiled program (sweeps, budget masking).
    ``n_events``  live events (≤ E).
    ``rates``  (m,) per-edge clock rates the stream was drawn from.
    """

    times: np.ndarray  # (E,) float32 sorted, padded with `horizon`
    edges: np.ndarray  # (E,) int32 edge ids, padded with -1
    n_events: int
    horizon: float
    rates: np.ndarray  # (m,) float64

    def __post_init__(self):
        if self.times.shape != self.edges.shape or self.times.ndim != 1:
            raise ValueError(
                f"times/edges must be matching 1-D arrays, got "
                f"{self.times.shape} vs {self.edges.shape}"
            )
        if self.n_events > len(self.times):
            raise ValueError("n_events exceeds the padded envelope")

    @property
    def envelope(self) -> int:
        return len(self.times)

    @property
    def messages_per_event(self) -> int:
        """A pairwise exchange moves one model in each direction."""
        return 2


def poisson_event_stream(
    graph: Graph,
    horizon: float,
    rate: float | np.ndarray = 1.0,
    seed: int = 0,
    envelope: int | None = None,
) -> EventStream:
    """Sample per-edge Poisson clocks into a sorted, padded event stream.

    ``rate`` is the clock intensity: a scalar (every edge fires at that
    rate), an (m,) per-edge vector in ``Graph.edge_list()`` order, or an
    (n, n) symmetric rate matrix read off at the edge positions.  Each edge
    fires ``Poisson(rate_e · horizon)`` times at iid Uniform(0, horizon)
    instants (equivalent to exponential inter-arrivals, but vectorises);
    the merged stream is time-sorted with ties broken by edge id, so the
    realisation is a pure function of ``seed``.

    ``rate = 1`` with ``horizon = R`` matches R synchronous rounds in
    expected per-edge traffic: one exchange per edge per unit time — the
    budget-matched comparison ``benchmarks/fig9_async.py`` draws.

    ``envelope``, when given, pads (or rejects: the realised count must fit)
    to a static length so different seeds/rates share one compiled scan.
    """
    if graph.directed:
        raise ValueError("poisson_event_stream needs an undirected graph (pairwise exchanges)")
    if horizon <= 0:
        raise ValueError(f"horizon must be positive, got {horizon}")
    edges = graph.edge_list()
    m = len(edges)
    r = np.asarray(rate, dtype=np.float64)
    if r.ndim == 0:
        rates = np.full(m, float(r))
    elif r.ndim == 1:
        if r.shape[0] != m:
            raise ValueError(f"per-edge rates need shape ({m},), got {r.shape}")
        rates = r.copy()
    elif r.shape == (graph.n, graph.n):
        if not np.allclose(r, r.T):
            raise ValueError("rate matrix must be symmetric (one clock per undirected edge)")
        rates = r[edges[:, 0], edges[:, 1]].astype(np.float64)
    else:
        raise ValueError(f"rate must be scalar, ({m},) or ({graph.n}, {graph.n}), got {r.shape}")
    if np.any(rates < 0):
        raise ValueError("edge clock rates must be non-negative")
    rng = np.random.default_rng(seed)
    counts = rng.poisson(rates * horizon)
    edge_ids = np.repeat(np.arange(m, dtype=np.int32), counts)
    times = rng.uniform(0.0, horizon, size=int(counts.sum()))
    order = np.lexsort((edge_ids, times))
    times, edge_ids = times[order], edge_ids[order]
    n_events = len(times)
    width = n_events if envelope is None else int(envelope)
    if width < n_events:
        raise ValueError(
            f"envelope {width} too small for the realised stream ({n_events} events) — "
            f"size it like a Poisson tail, e.g. ceil(Σrate·T + 4·sqrt(Σrate·T))"
        )
    pad = width - n_events
    return EventStream(
        times=np.concatenate([times, np.full(pad, horizon)]).astype(np.float32),
        edges=np.concatenate([edge_ids, np.full(pad, -1, np.int32)]).astype(np.int32),
        n_events=n_events,
        horizon=float(horizon),
        rates=rates,
    )


@dataclasses.dataclass(frozen=True)
class EventBatches:
    """An ``EventStream`` regrouped into endpoint-disjoint batches.

    Simultaneous (or near-simultaneous) asynchronous events on *disjoint*
    edges commute exactly — each pairwise exchange touches only its two
    endpoints — so a run of consecutive events whose edges form a matching
    is one parallel "colour step" (ROADMAP §14): a single vectorised
    scatter instead of ``W`` sequential pairwise updates, which recovers
    matmul-shaped work on the event path (``CommPlan.event_mix_batch``).

    ``edges``        (B, W) int32 edge ids, padded -1 (the identity);
    ``event_index``  (B, W) int32 position of each event in the *original*
                     stream, padded -1 — per-event failure keys stay
                     ``fold_in(key, event_index)``, so a batched replay
                     draws bit-identical Bernoullis to the sequential scan.
    """

    edges: np.ndarray  # (B, W) int32, padded -1
    event_index: np.ndarray  # (B, W) int32, padded -1
    n_events: int

    @property
    def n_batches(self) -> int:
        return self.edges.shape[0]

    @property
    def width(self) -> int:
        return self.edges.shape[1]


def batch_events_by_color(
    stream: EventStream, graph: Graph, max_width: int | None = None
) -> EventBatches:
    """Greedily batch a time-ordered ``EventStream`` into colour steps.

    Walks the live events in time order, growing the current batch until the
    next event's edge shares an endpoint with one already in it (or the
    optional ``max_width`` is hit), then starts a new batch — so batches
    respect event order (only provably-commuting exchanges are merged) and
    the batching is a pure function of the stream.  Padding events (-1) are
    dropped; an empty stream yields one all-padding batch so downstream
    scans keep a static shape.
    """
    edge_list = graph.edge_list()
    ids = stream.edges[: stream.n_events]
    batches: list[list[int]] = []
    indices: list[list[int]] = []
    used: set[int] = set()
    cur_e: list[int] = []
    cur_i: list[int] = []
    for pos, e in enumerate(ids):
        if e < 0:
            continue
        u, v = int(edge_list[e, 0]), int(edge_list[e, 1])
        full = max_width is not None and len(cur_e) >= max_width
        if full or u in used or v in used:
            batches.append(cur_e)
            indices.append(cur_i)
            cur_e, cur_i, used = [], [], set()
        cur_e.append(int(e))
        cur_i.append(pos)
        used.update((u, v))
    if cur_e or not batches:
        batches.append(cur_e)
        indices.append(cur_i)
    width = max(max(len(b) for b in batches), 1)
    out_e = np.full((len(batches), width), -1, np.int32)
    out_i = np.full((len(batches), width), -1, np.int32)
    for b, (es, ix) in enumerate(zip(batches, indices)):
        out_e[b, : len(es)] = es
        out_i[b, : len(ix)] = ix
    return EventBatches(edges=out_e, event_index=out_i, n_events=int((ids >= 0).sum()))


def churn_sequence(
    graph: Graph,
    k_plans: int,
    churn_rate: float,
    seed: int = 0,
    require_connected: bool = True,
) -> list[Graph]:
    """Seeded Markov chain of churned topology snapshots (edge up/down).

    Snapshot t+1 perturbs snapshot t (not the base graph — churn compounds,
    like real mobility/link churn): every live edge drops independently with
    probability ``churn_rate`` and the same number of fresh edges appears
    uniformly among the currently absent pairs, so the link budget is
    conserved in expectation while the wiring drifts.  Snapshot 0 is the
    base graph itself, so ``churn_rate = 0`` (or ``k_plans = 1``) reproduces
    the static topology exactly.

    The snapshots feed ``commplan.compile_schedule``; per-round *node*
    dropout composes orthogonally through ``FailureModel.node_p`` (a node
    vanishing for one round is a failure draw, not a topology change).
    Unweighted graphs only: churned edges appear with weight 1.
    """
    if k_plans < 1:
        raise ValueError("churn_sequence needs k_plans >= 1")
    if not 0.0 <= churn_rate < 1.0:
        raise ValueError(f"churn_rate must be in [0, 1), got {churn_rate}")
    if graph.directed:
        raise ValueError("churn_sequence supports undirected graphs only")
    rng = np.random.default_rng(seed)
    a = graph.adjacency.copy()
    out = [graph]
    for t in range(1, k_plans):
        for _attempt in range(100):
            b = a.copy()
            iu, ju = np.nonzero(np.triu(b, k=1))
            drop = rng.random(len(iu)) < churn_rate
            b[iu[drop], ju[drop]] = 0.0
            b[ju[drop], iu[drop]] = 0.0
            cu, cv = np.nonzero(np.triu(b == 0, k=1))
            n_add = min(int(drop.sum()), len(cu))
            if n_add:
                pick = rng.choice(len(cu), size=n_add, replace=False)
                b[cu[pick], cv[pick]] = 1.0
                b[cv[pick], cu[pick]] = 1.0
            g = Graph(b.astype(np.float32), name=f"{graph.name}-churn{t}")
            if not require_connected or g.is_connected():
                break
        else:
            raise RuntimeError(
                f"churn_sequence: no connected churned snapshot found after 100 "
                f"attempts (n={graph.n}, churn_rate={churn_rate}) — lower the "
                "rate or pass require_connected=False"
            )
        out.append(g)
        a = b
    return out

"""Mixing matrices, steady-state vectors and mixing-time estimates (paper §4.3–4.5).

Conventions
-----------
The paper arranges node parameters as a ``d × n`` matrix ``W`` whose *columns*
are nodes, and evolves ``W_t = W_init A'^t`` with the *column-stochastic*
matrix (Eq. 3)::

    A'_ij = (A_ij + I_ij) / sum_k (A_kj + I_kj)

i.e. column j holds the weights node j *sends*: node j keeps 1/(k_j+1) of its
own parameters and gives 1/(k_j+1) to each neighbour.  Our code stores node
parameters with a *leading* node axis (``(n, ...)`` pytrees), so the DecAvg
update reads ``w_new[i] = sum_j M[i, j] w[j]`` with the *row-stochastic*
matrix ``M = A'`` read row-wise... careful: with uniform data-set sizes
(``beta_i ~ 1/(k_i+1)``, §3) the receive-side weights are ``M[i, j] =
(A_ij + I_ij) / (k_i + 1)`` — row-stochastic, and equal to ``A'`` transposed
only for regular graphs.  Both operators are exposed below; ``M`` ("receive
form") drives the aggregation, ``A'`` ("send form", Eq. 3) drives the
Markov-chain analysis.  For undirected graphs they are transposes of each
other up to the degree normalisation and share the same spectrum.

``v_steady`` is the stationary vector of ``A'`` (``A' v = v``, sum-normalised).
For undirected graphs it has the closed form ``v_i = (k_i + 1) / sum_j (k_j + 1)``
(detailed balance of the lazy-ish walk); the general directed/weighted case
falls back to power iteration.
"""
from __future__ import annotations

import numpy as np

from .topology import Graph

__all__ = [
    "mixing_matrix",
    "receive_matrix",
    "v_steady",
    "v_steady_norm",
    "v_steady_norm_closed_form",
    "v_steady_norm_from_degree_sample",
    "spectral_gap",
    "mixing_time_estimate",
    "rewire_to_assortativity",
]


def _augmented(adjacency: np.ndarray, self_weights: np.ndarray | None = None) -> np.ndarray:
    """A + diag(self-weights); identity self-weights per Eq. 3 unless overridden.

    The paper (§4.3, last paragraph) notes weighted networks replace I with a
    diagonal matrix of self-weights.
    """
    n = adjacency.shape[0]
    if self_weights is None:
        s = np.eye(n, dtype=np.float64)
    else:
        s = np.diag(np.asarray(self_weights, dtype=np.float64))
    return adjacency.astype(np.float64) + s


def mixing_matrix(graph: Graph, self_weights: np.ndarray | None = None) -> np.ndarray:
    """Column-stochastic ``A'`` of Eq. 3 (columns sum to 1)."""
    b = _augmented(graph.adjacency, self_weights)
    col = b.sum(axis=0, keepdims=True)
    if np.any(col == 0):
        raise ValueError("graph has an isolated node with zero self-weight")
    return (b / col).astype(np.float64)


def receive_matrix(graph: Graph, data_sizes: np.ndarray | None = None) -> np.ndarray:
    """Row-stochastic DecAvg receive operator ``M`` (Eq. 2).

    ``w_new[i] = sum_j M[i, j] w[j]`` with
    ``M[i, j] = |D_j| (A_ij + I_ij) / (|D_i| + sum_{l in N_i} |D_l|)``.
    With equal data sizes this is ``(A + I)`` row-normalised, i.e.
    ``beta ~ 1/(k_i + 1)`` exactly as §3 assumes.
    """
    n = graph.n
    b = graph.adjacency.astype(np.float64) + np.eye(n)
    if data_sizes is None:
        w = b
    else:
        d = np.asarray(data_sizes, dtype=np.float64)
        w = b * d[None, :]
    row = w.sum(axis=1, keepdims=True)
    return w / row


def v_steady(graph: Graph, self_weights: np.ndarray | None = None, tol: float = 1e-12, max_iter: int = 100_000) -> np.ndarray:
    """Stationary vector of ``A'``: ``A' v = v``, normalised to sum to 1.

    Closed form for undirected graphs with identity self-weights; power
    iteration otherwise (guaranteed to converge: self-loops make A' aperiodic,
    §4.3).
    """
    if not graph.directed and self_weights is None:
        k = graph.degrees.astype(np.float64)
        v = k + 1.0
        return v / v.sum()
    ap = mixing_matrix(graph, self_weights)
    n = ap.shape[0]
    v = np.full(n, 1.0 / n)
    for _ in range(max_iter):
        v_next = ap @ v
        v_next /= v_next.sum()
        if np.abs(v_next - v).max() < tol:
            return v_next
        v = v_next
    raise RuntimeError("power iteration for v_steady did not converge (is the graph strongly connected?)")


def v_steady_norm(graph: Graph, self_weights: np.ndarray | None = None) -> float:
    """``‖v_steady‖_2`` — the parameter-compression factor of §4.3.

    ``lim_{t→∞} σ_ap ≈ σ_init · ‖v_steady‖``; the paper's init multiplies the
    He/Glorot σ by ``‖v_steady‖⁻¹``.
    """
    return float(np.linalg.norm(v_steady(graph, self_weights)))


def v_steady_norm_closed_form(degrees: np.ndarray) -> float:
    """``‖v_steady‖`` from a *full* degree sequence (undirected closed form)."""
    k1 = np.asarray(degrees, dtype=np.float64) + 1.0
    return float(np.sqrt((k1**2).sum()) / k1.sum())


def v_steady_norm_from_degree_sample(
    degree_sample: np.ndarray, n: int | float | np.ndarray
) -> float | np.ndarray:
    """Estimate ``‖v_steady‖`` from a degree *sample* plus an estimate of n (§4.4).

    ``‖v‖² = Σ(k+1)² / (Σ(k+1))² ≈ ⟨(k+1)²⟩ / (n ⟨k+1⟩²)`` — this is what a
    node can compute after polling degrees through a gossip protocol.

    Vectorised over per-node estimates: ``degree_sample`` may be (m,) shared
    or (..., m) per node, ``n`` a scalar or matching array; scalar inputs
    return a float (device mirror: ``repro.gossip.gain_from_degree_sample``).
    """
    k1 = np.asarray(degree_sample, dtype=np.float64) + 1.0
    out = np.sqrt(
        (k1**2).mean(axis=-1) / (np.asarray(n, np.float64) * k1.mean(axis=-1) ** 2)
    )
    return float(out) if out.ndim == 0 else out


def spectral_gap(graph: Graph, self_weights: np.ndarray | None = None) -> float:
    """1 - |λ₂| of ``A'`` — controls the convergence rate (§4.5, [46])."""
    ap = mixing_matrix(graph, self_weights)
    eig = np.linalg.eigvals(ap)
    eig = np.sort(np.abs(eig))[::-1]
    return float(1.0 - eig[1])


def mixing_time_estimate(graph: Graph, eps: float = 0.25) -> float:
    """Relaxation-time upper-bound estimate of the ε-mixing time (§4.5).

    ``t_mix(ε) <= log(1/(ε·min_i v_i)) / gap`` for reversible chains
    [Levin & Peres, Thm 12.4].  The dry-run/benchmarks use this to predict the
    σ_an stabilisation round counts; the paper's asymptotics (O(log n) for
    k-regular expanders, O(log² n) for supercritical ER, O(d²·n^{2/d}) for
    d-dim tori) emerge from the gap scaling of those families.
    """
    gap = spectral_gap(graph)
    v = v_steady(graph)
    return float(np.log(1.0 / (eps * v.min())) / max(gap, 1e-12))


def rewire_to_assortativity(
    graph: Graph,
    target: float,
    seed: int = 0,
    steps: int = 200_000,
    t0: float = 0.05,
    cooling: float = 0.9995,
) -> Graph:
    """Degree-preserving edge-swap annealing toward a target assortativity (§4.4, Fig. 5c).

    Select two edges (a,b),(c,d), propose the swap (a,d),(c,b); accept based on
    |assortativity - target| improvement with a slowly-cooled temperature.
    Degrees (hence ``v_steady``) are invariant under the swap — that is the
    point of Fig. 5(c).
    """
    rng = np.random.default_rng(seed)
    a = graph.adjacency.copy()
    k = a.sum(axis=1)
    sum_k = k.sum()

    # incremental assortativity bookkeeping: r is a function of S1 = Σ_e k_i k_j,
    # with the degree-dependent terms constant under degree-preserving swaps.
    ii, jj = np.nonzero(np.triu(a))
    edges = list(zip(ii.tolist(), jj.tolist()))
    m = len(edges)

    # moments over edge ends (each edge counted in both directions)
    ksum = sum(k[i] + k[j] for i, j in edges)
    k2sum = sum(k[i] ** 2 + k[j] ** 2 for i, j in edges)
    mean = ksum / (2 * m)
    var = k2sum / (2 * m) - mean**2
    if var <= 0:
        return graph

    def r_of(s1: float) -> float:
        return (s1 / m - mean**2) / var

    s1 = float(sum(k[i] * k[j] for i, j in edges))
    temp = t0
    for _ in range(steps):
        e1, e2 = rng.integers(m), rng.integers(m)
        if e1 == e2:
            continue
        a1, b1 = edges[e1]
        c1, d1 = edges[e2]
        if rng.random() < 0.5:
            c1, d1 = d1, c1
        # proposed new edges (a1,d1), (c1,b1)
        if len({a1, b1, c1, d1}) < 4:
            continue
        if a[a1, d1] or a[c1, b1]:
            continue
        s1_new = s1 - k[a1] * k[b1] - k[c1] * k[d1] + k[a1] * k[d1] + k[c1] * k[b1]
        delta = abs(r_of(s1_new) - target) - abs(r_of(s1) - target)
        if delta < 0 or rng.random() < np.exp(-delta / max(temp, 1e-9)):
            a[a1, b1] = a[b1, a1] = 0.0
            a[c1, d1] = a[d1, c1] = 0.0
            a[a1, d1] = a[d1, a1] = 1.0
            a[c1, b1] = a[b1, c1] = 1.0
            edges[e1] = (min(a1, d1), max(a1, d1))
            edges[e2] = (min(c1, b1), max(c1, b1))
            s1 = s1_new
        temp *= cooling
        if abs(r_of(s1) - target) < 5e-3 and temp < t0 / 10:
            break
    g = Graph(a.astype(np.float32), name=f"{graph.name}-rho{target:g}")
    return g

"""DecAvg / "Decay" aggregation (paper Eq. 2) and its TPU renderings.

Execution backends of the same operator, all consuming parameter pytrees
with a leading node axis ``(n, ...)`` (compiled and dispatched by
``repro.core.commplan``, DESIGN.md §3):

1. ``mix_pytree``            — dense ``w_new[i] = Σ_j M[i,j] w[j]`` einsum with
                               the receive matrix.  Reference semantics, works
                               for any topology, any failure pattern.  Under
                               pjit with the node axis sharded over ``data``,
                               XLA lowers the contraction to an all-gather of
                               the full parameter ensemble — the *paper-faithful
                               baseline* of the §Perf story.
2. ``mix_pytree_sparse``     — edge-list gather-scatter: gather each receive
                               edge's source row, weight, ``segment_sum`` into
                               the destination.  O(E·d) compute / bytes instead
                               of O(n²·d) — the backend that makes n in the
                               thousands tractable.  ``mix_pytree_hyb`` is the
                               CPU-fast rendering of the same operator (ELL
                               slot chain + dense hub rows); ``repro.kernels
                               .mix`` additionally provides the blocked
                               block-sparse Pallas kernel for the TPU hot-spot.
3. ``mix_pytree_colored``    — edge-coloured collective schedule for *any*
                               static undirected graph: each colour class is a
                               matching, i.e. one ``ppermute`` round inside
                               ``shard_map`` (generalises the circulant-only
                               schedule).  Falls back to gather semantics when
                               no mesh axis is given — same math, same
                               schedule, single-process.
4. ``mix_pytree_circulant``  — the original circulant-only ``ppermute`` shift
                               schedule, kept for regular rings/tori where the
                               offset structure is known a priori.

Failure modelling (paper §4.1, Fig. 2): each *link* or *node* is active per
round with probability p; inactive nodes still train locally but are
momentarily isolated.  ``failure_receive_matrix`` rebuilds the round's
effective row-stochastic operator for the dense backend; the sparse/colored
backends apply per-edge keep masks and renormalise via segment sums (see
``commplan``).
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .topology import Graph

__all__ = [
    "mix_pytree",
    "mix_array",
    "mix_pytree_sparse",
    "mix_pytree_hyb",
    "mix_pytree_colored",
    "mix_pytree_circulant",
    "mix_pytree_pairwise",
    "mix_pytree_pairwise_batch",
    "spread_pairwise",
    "spread_min_pairwise",
    "failure_receive_matrix",
    "link_failure_mask",
    "node_failure_mask",
]

PyTree = Any


def _bcast(w: jax.Array, ndim: int) -> jax.Array:
    """Reshape a 1-D weight vector to broadcast over ``ndim - 1`` trailing dims."""
    return w.reshape(w.shape + (1,) * (ndim - 1))


def mix_array(m: jax.Array, x: jax.Array) -> jax.Array:
    """``x_new[i] = Σ_j m[i, j] x[j]`` over the leading node axis.

    fp32 accumulation regardless of parameter dtype: the mixing weights are
    O(1/k) and parameter magnitudes shrink by ‖v_steady‖ during diffusion, so
    bf16 accumulation would lose exactly the signal the paper studies.

    Implemented as a tensordot over the node axis WITHOUT flattening: under
    pjit the trailing dims keep their model-axis sharding, so the only
    communication is the node-axis gather inherent to dense mixing (a
    reshape-to-(n, -1) here would force a full model-axis all-gather).
    """
    out = jnp.tensordot(m, x, axes=[[1], [0]], preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def mix_pytree(m: jax.Array, params: PyTree) -> PyTree:
    """DecAvg over every leaf of a node-stacked parameter pytree."""
    return jax.tree_util.tree_map(lambda w: mix_array(m, w), params)


def mix_pytree_sparse(
    params: PyTree,
    src: jax.Array,
    dst: jax.Array,
    edge_w: jax.Array,
    self_w: jax.Array,
    *,
    n_nodes: int,
) -> PyTree:
    """DecAvg via edge-list gather-scatter (CSR order, dst-sorted).

    ``out[i] = self_w[i] * x[i] + Σ_{e: dst[e]=i} edge_w[e] * x[src[e]]``

    Weights must already be normalised (rows of the effective receive matrix
    sum to 1) — ``commplan`` precomputes them statically or renormalises per
    round under failures.  fp32 accumulation for the same reason as
    ``mix_array``.
    """

    def mix_leaf(x: jax.Array) -> jax.Array:
        gathered = jnp.take(x, src, axis=0).astype(jnp.float32)
        contrib = _bcast(edge_w, x.ndim) * gathered
        agg = jax.ops.segment_sum(
            contrib, dst, num_segments=n_nodes, indices_are_sorted=True
        )
        out = _bcast(self_w, x.ndim) * x.astype(jnp.float32) + agg
        return out.astype(x.dtype)

    return jax.tree_util.tree_map(mix_leaf, params)


def mix_pytree_hyb(
    params: PyTree,
    slot_idx: jax.Array,
    slot_w: jax.Array,
    self_w: jax.Array,
    hub_rows: jax.Array | None,
    hub_m: jax.Array | None,
) -> PyTree:
    """DecAvg via the HYB (ELL + dense hub rows) sparse layout.

    The CPU-fast rendering of the sparse backend: low-degree rows execute as
    a chain of weighted full-length gathers (one per ELL slot — XLA fuses the
    chain into a single pass, so S slots cost far less than one materialised
    (nnz, d) gather), and the few heavy-tail hub rows as one small dense
    (H, n) matmul.  ``slot_idx``/``slot_w`` are (S, n) — slot s holds node
    i's s-th neighbour (self-index with weight 0 when exhausted or when i is
    a hub row); ``hub_m`` holds the hubs' full receive rows including their
    self weight.  Weights must be normalised.
    """

    def mix_leaf(x: jax.Array) -> jax.Array:
        xf = x.astype(jnp.float32)
        acc = _bcast(self_w, x.ndim) * xf
        for s in range(slot_idx.shape[0]):
            acc = acc + _bcast(slot_w[s], x.ndim) * jnp.take(xf, slot_idx[s], axis=0)
        if hub_rows is not None and hub_rows.shape[0]:
            hub_out = jnp.tensordot(
                hub_m, xf, axes=[[1], [0]], preferred_element_type=jnp.float32
            )
            acc = acc.at[hub_rows].set(hub_out)
        return acc.astype(x.dtype)

    return jax.tree_util.tree_map(mix_leaf, params)


def mix_pytree_colored(
    params: PyTree,
    partners: np.ndarray,
    color_w: jax.Array,
    self_w: jax.Array,
    axis_name: str | Sequence[str] | None = None,
) -> PyTree:
    """DecAvg over an edge-coloured schedule (arbitrary undirected graphs).

    partners: static (n_colors, n) int array — colour c's matching as an
    involution (partners[c, i] == i when unmatched).  color_w: (n_colors, n)
    receive weight of the edge (i, partners[c, i]) at node i (0 when
    unmatched); self_w: (n,).  Weights must be normalised.

    With ``axis_name`` set this must run inside ``shard_map`` with the node
    axis sharded one node per device group: each colour class becomes one
    ``ppermute`` (matchings are involutions, hence valid permutations), and
    ``color_w`` / ``self_w`` must be passed as node-sharded operands (their
    local shards).  Without ``axis_name`` the same schedule executes as
    node-axis gathers — identical math, single process — and ``partners``
    may be a *traced* array (a ``PlanSchedule``-selected colour table); the
    collective rendering needs static host perms and keeps requiring numpy.
    """
    if axis_name is None:
        partners = jnp.asarray(partners)
        n_colors = partners.shape[0]

        def mix_leaf(x: jax.Array) -> jax.Array:
            acc = _bcast(self_w, x.ndim) * x.astype(jnp.float32)
            for c in range(n_colors):
                shifted = jnp.take(x, partners[c], axis=0)
                acc = acc + _bcast(color_w[c], x.ndim) * shifted.astype(jnp.float32)
            return acc.astype(x.dtype)

        return jax.tree_util.tree_map(mix_leaf, params)

    partners = np.asarray(partners)
    n_colors, n = partners.shape
    axis_size = jax.lax.psum(1, axis_name)
    if axis_size != n:
        raise ValueError(
            f"colored ppermute schedule needs one node per device group: axis size {axis_size} != n {n}"
        )
    perms = [
        [(i, int(partners[c, i])) for i in range(n) if partners[c, i] != i]
        for c in range(n_colors)
    ]

    def mix_leaf_collective(x: jax.Array) -> jax.Array:
        acc = _bcast(self_w, x.ndim) * x.astype(jnp.float32)
        for c in range(n_colors):
            if not perms[c]:
                continue
            shifted = jax.lax.ppermute(x, axis_name, perms[c])
            acc = acc + _bcast(color_w[c], x.ndim) * shifted.astype(jnp.float32)
        return acc.astype(x.dtype)

    return jax.tree_util.tree_map(mix_leaf_collective, params)


def mix_pytree_pairwise(
    params: PyTree,
    u: jax.Array,
    v: jax.Array,
    w_uv: jax.Array,
    w_vu: jax.Array,
) -> PyTree:
    """One event-driven pairwise DecAvg exchange on edge (u, v).

    The asynchronous rendering of Eq. 2 (DESIGN.md §14): when edge (u, v)'s
    Poisson clock fires, only its two endpoints move —

        ``w_u ← w_u + w_uv·(w_v − w_u)``   and symmetrically for v.

    ``u``/``v`` are traced int32 scalars; ``w_uv``/``w_vu`` traced float32
    weights, normally the synchronous plan's receive entries ``M[u, v]`` /
    ``M[v, u]`` so composing one event per edge reproduces the synchronous
    round to first order in the weights (the rate-1 parity property).  A
    masked event (dead edge, padding) passes ``w = 0`` and is the exact
    identity.  fp32 blend for the same reason as ``mix_array``.
    """

    def mix_leaf(x: jax.Array) -> jax.Array:
        xu, xv = x[u].astype(jnp.float32), x[v].astype(jnp.float32)
        new_u = xu + w_uv * (xv - xu)
        new_v = xv + w_vu * (xu - xv)
        return x.at[u].set(new_u.astype(x.dtype)).at[v].set(new_v.astype(x.dtype))

    return jax.tree_util.tree_map(mix_leaf, params)


def mix_pytree_pairwise_batch(
    params: PyTree,
    u: jax.Array,
    v: jax.Array,
    w_uv: jax.Array,
    w_vu: jax.Array,
) -> PyTree:
    """One **colour step**: simultaneous pairwise exchanges on a batch of
    endpoint-disjoint edges (ROADMAP §14's batched event rendering).

    ``u``/``v``: (W,) int32 endpoint vectors; ``w_uv``/``w_vu``: (W,) f32
    receive weights.  The edges must be pairwise vertex-disjoint (a matching
    — ``topology.batch_events_by_color`` produces such batches), so the W
    sequential ``mix_pytree_pairwise`` updates commute and collapse into one
    vectorised gather + scatter-*add* of the per-endpoint deltas.  The add
    form keeps padding safe: a masked event passes ``w = 0``, contributes an
    exactly-zero delta, and may alias any row (including a live endpoint)
    without an ordering hazard — unlike scatter-set, whose result under
    duplicate indices is implementation-defined.  Each live endpoint
    receives ``x_u + w_uv·(x_v − x_u)`` — the same expression the pairwise
    form computes, so a batched replay matches the sequential scan.
    """

    def mix_leaf(x: jax.Array) -> jax.Array:
        xu, xv = x[u].astype(jnp.float32), x[v].astype(jnp.float32)
        du = _bcast(w_uv, x.ndim) * (xv - xu)
        dv = _bcast(w_vu, x.ndim) * (xu - xv)
        return x.at[u].add(du.astype(x.dtype)).at[v].add(dv.astype(x.dtype))

    return jax.tree_util.tree_map(mix_leaf, params)


def spread_pairwise(
    values: jax.Array,
    u: jax.Array,
    v: jax.Array,
    w_uv: jax.Array,
    w_vu: jax.Array,
) -> jax.Array:
    """One event-driven **push** exchange on edge (u, v) — mass-conserving.

    The asynchronous rendering of the send-form operator Mᵀ: u hands the
    fraction ``w_uv = M[u, v]`` of its mass to v and receives ``w_vu·s_v``
    back —

        ``s_u ← s_u − w_uv·s_u + w_vu·s_v``   and symmetrically for v,

    so ``s_u + s_v`` (hence the global sum) is invariant for *any* weights —
    the property event-driven push-sum rides (``repro.gossip``).  Composing
    one event per edge matches the synchronous ``CommPlan.spread`` to first
    order, same as the mix form.  ``values``: (n,) or (n, k) float32.
    """
    xu, xv = values[u], values[v]
    give_u, give_v = w_uv * xu, w_vu * xv
    return values.at[u].set(xu - give_u + give_v).at[v].set(xv - give_v + give_u)


def spread_min_pairwise(values: jax.Array, u: jax.Array, v: jax.Array, keep: jax.Array) -> jax.Array:
    """One event-driven **min** exchange on edge (u, v): both endpoints take
    the elementwise minimum (identity when ``keep`` is False) — the event
    transport of the leaderless size sketches."""
    xu, xv = values[u], values[v]
    lo = jnp.minimum(xu, xv)
    return values.at[u].set(jnp.where(keep, lo, xu)).at[v].set(jnp.where(keep, lo, xv))


def mix_pytree_circulant(
    params: PyTree,
    offsets: Sequence[int],
    axis_name: str | Sequence[str],
    weights: jax.Array | None = None,
) -> PyTree:
    """Circulant DecAvg on a sharded node axis via ``jax.lax.ppermute``.

    Must be called inside ``shard_map`` where ``axis_name`` indexes the node
    shards (one node per device group along the FL axis).  For a circulant
    graph with offset set S (degree k = 2|S|), the DecAvg receive weights with
    uniform data are 1/(k+1) for self and each of the 2|S| neighbours.

    weights: optional (2|S|+1,) receive weights ordered [self, +s1, -s1, ...],
    for non-uniform data sizes.
    """
    n_terms = 2 * len(offsets) + 1
    if weights is None:
        w = jnp.full((n_terms,), 1.0 / n_terms, dtype=jnp.float32)
    else:
        w = weights.astype(jnp.float32)

    axis_size = jax.lax.psum(1, axis_name)

    def mix_leaf(x: jax.Array) -> jax.Array:
        acc = w[0] * x.astype(jnp.float32)
        t = 1
        for s in offsets:
            for sign in (1, -1):
                perm = [(i, (i + sign * s) % axis_size) for i in range(axis_size)]
                shifted = jax.lax.ppermute(x, axis_name, perm)
                acc = acc + w[t] * shifted.astype(jnp.float32)
                t += 1
        return acc.astype(x.dtype)

    return jax.tree_util.tree_map(mix_leaf, params)


def link_failure_mask(key: jax.Array, graph: Graph, p: float) -> jax.Array:
    """Symmetric Bernoulli(p) mask over the graph's edges (Fig. 2a)."""
    a = jnp.asarray(graph.adjacency)
    u = jax.random.uniform(key, a.shape)
    upper = jnp.triu(u, k=1)
    keep = (upper < p) & (jnp.triu(a, k=1) > 0)
    keep = keep | keep.T
    return keep.astype(a.dtype)


def node_failure_mask(key: jax.Array, graph: Graph, p: float) -> jax.Array:
    """Adjacency with all edges of inactive nodes removed (Fig. 2b).

    An inactive node neither sends nor receives this round, but keeps training
    locally (its receive row collapses to identity below).
    """
    a = jnp.asarray(graph.adjacency)
    active = jax.random.bernoulli(key, p, (graph.n,))
    m = active[:, None] & active[None, :]
    return (a * m).astype(a.dtype)


def failure_receive_matrix(adjacency: jax.Array, data_sizes: jax.Array | None = None) -> jax.Array:
    """Row-stochastic DecAvg receive operator for a (possibly masked) adjacency.

    Jax-traceable version of ``core.mixing.receive_matrix`` so per-round
    failure masks can stay on-device inside the jitted round function.
    """
    n = adjacency.shape[0]
    b = adjacency.astype(jnp.float32) + jnp.eye(n, dtype=jnp.float32)
    if data_sizes is not None:
        b = b * data_sizes[None, :].astype(jnp.float32)
    return b / b.sum(axis=1, keepdims=True)

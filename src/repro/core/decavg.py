"""DecAvg / "Decay" aggregation (paper Eq. 2) and its TPU renderings.

Three implementations of the same operator, all consuming parameter pytrees
with a leading node axis ``(n, ...)``:

1. ``mix_pytree``            — dense ``w_new[i] = Σ_j M[i,j] w[j]`` einsum with
                               the receive matrix.  Reference semantics, works
                               for any topology, any failure pattern.  Under
                               pjit with the node axis sharded over ``data``,
                               XLA lowers the contraction to an all-gather of
                               the full parameter ensemble — the *paper-faithful
                               baseline* of the §Perf story.
2. ``mix_pytree_circulant``  — for circulant topologies: k ``ppermute`` shifts
                               + local weighted sum inside ``shard_map``.  Moves
                               only degree·|w| bytes instead of n·|w| — the
                               beyond-paper optimised collective schedule.
3. Pallas kernel             — ``repro.kernels.mix`` provides the blocked
                               (d × n)·(n × n) product for the dense form's
                               on-chip hot-spot (see kernels/mix).

Failure modelling (paper §4.1, Fig. 2): each *link* or *node* is active per
round with probability p; inactive nodes still train locally but are
momentarily isolated.  ``failure_receive_matrix`` rebuilds the round's
effective row-stochastic operator.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .topology import Graph

__all__ = [
    "mix_pytree",
    "mix_array",
    "mix_pytree_circulant",
    "failure_receive_matrix",
    "link_failure_mask",
    "node_failure_mask",
]

PyTree = Any


def mix_array(m: jax.Array, x: jax.Array) -> jax.Array:
    """``x_new[i] = Σ_j m[i, j] x[j]`` over the leading node axis.

    fp32 accumulation regardless of parameter dtype: the mixing weights are
    O(1/k) and parameter magnitudes shrink by ‖v_steady‖ during diffusion, so
    bf16 accumulation would lose exactly the signal the paper studies.

    Implemented as a tensordot over the node axis WITHOUT flattening: under
    pjit the trailing dims keep their model-axis sharding, so the only
    communication is the node-axis gather inherent to dense mixing (a
    reshape-to-(n, -1) here would force a full model-axis all-gather).
    """
    out = jnp.tensordot(m, x, axes=[[1], [0]], preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def mix_pytree(m: jax.Array, params: PyTree) -> PyTree:
    """DecAvg over every leaf of a node-stacked parameter pytree."""
    return jax.tree_util.tree_map(lambda w: mix_array(m, w), params)


def mix_pytree_circulant(
    params: PyTree,
    offsets: Sequence[int],
    axis_name: str | Sequence[str],
    weights: jax.Array | None = None,
) -> PyTree:
    """Circulant DecAvg on a sharded node axis via ``jax.lax.ppermute``.

    Must be called inside ``shard_map`` where ``axis_name`` indexes the node
    shards (one node per device group along the FL axis).  For a circulant
    graph with offset set S (degree k = 2|S|), the DecAvg receive weights with
    uniform data are 1/(k+1) for self and each of the 2|S| neighbours.

    weights: optional (2|S|+1,) receive weights ordered [self, +s1, -s1, ...],
    for non-uniform data sizes.
    """
    n_terms = 2 * len(offsets) + 1
    if weights is None:
        w = jnp.full((n_terms,), 1.0 / n_terms, dtype=jnp.float32)
    else:
        w = weights.astype(jnp.float32)

    axis_size = jax.lax.psum(1, axis_name)

    def mix_leaf(x: jax.Array) -> jax.Array:
        acc = w[0] * x.astype(jnp.float32)
        t = 1
        for s in offsets:
            for sign in (1, -1):
                perm = [(i, (i + sign * s) % axis_size) for i in range(axis_size)]
                shifted = jax.lax.ppermute(x, axis_name, perm)
                acc = acc + w[t] * shifted.astype(jnp.float32)
                t += 1
        return acc.astype(x.dtype)

    return jax.tree_util.tree_map(mix_leaf, params)


def link_failure_mask(key: jax.Array, graph: Graph, p: float) -> jax.Array:
    """Symmetric Bernoulli(p) mask over the graph's edges (Fig. 2a)."""
    a = jnp.asarray(graph.adjacency)
    u = jax.random.uniform(key, a.shape)
    upper = jnp.triu(u, k=1)
    keep = (upper < p) & (jnp.triu(a, k=1) > 0)
    keep = keep | keep.T
    return keep.astype(a.dtype)


def node_failure_mask(key: jax.Array, graph: Graph, p: float) -> jax.Array:
    """Adjacency with all edges of inactive nodes removed (Fig. 2b).

    An inactive node neither sends nor receives this round, but keeps training
    locally (its receive row collapses to identity below).
    """
    a = jnp.asarray(graph.adjacency)
    active = jax.random.bernoulli(key, p, (graph.n,))
    m = active[:, None] & active[None, :]
    return (a * m).astype(a.dtype)


def failure_receive_matrix(adjacency: jax.Array, data_sizes: jax.Array | None = None) -> jax.Array:
    """Row-stochastic DecAvg receive operator for a (possibly masked) adjacency.

    Jax-traceable version of ``core.mixing.receive_matrix`` so per-round
    failure masks can stay on-device inside the jitted round function.
    """
    n = adjacency.shape[0]
    b = adjacency.astype(jnp.float32) + jnp.eye(n, dtype=jnp.float32)
    if data_sizes is not None:
        b = b * data_sizes[None, :].astype(jnp.float32)
    return b / b.sum(axis=1, keepdims=True)

"""Uncoordinated, network-aware parameter initialisation (paper §4, Algorithm 1).

The technique: each node draws its parameters *independently* with a standard
architecture-appropriate initialiser (He et al. [33] for ReLU nets, Glorot for
tanh/linear, truncated-normal for transformers), then **rescales every
randomly-drawn weight distribution by ``gain = ‖v_steady‖⁻¹``** so that after
the early diffusion phase compresses per-node parameter variance by
``‖v_steady‖`` (§4.3), the surviving distribution is exactly the one the
initialiser intended.

Structured parameters (zeros, ones, RoPE-free, SSM decay spectra) are *not*
rescaled — the σ_init analysis only covers zero-mean random draws; under
DecAvg, deterministic equal values are a fixed point of the mixing operator
(see DESIGN.md §4 caveat).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .mixing import v_steady_norm, v_steady_norm_from_degree_sample
from .topology import Graph

__all__ = [
    "InitConfig",
    "gain_from_graph",
    "gain_from_estimates",
    "he_normal",
    "he_uniform",
    "glorot_normal",
    "glorot_uniform",
    "trunc_normal",
    "scaled_init",
]

Distribution = Literal["he_normal", "he_uniform", "glorot_normal", "glorot_uniform", "trunc_normal"]


@dataclasses.dataclass(frozen=True)
class InitConfig:
    """How to initialise one node's parameters.

    gain: the paper's correction factor, ``‖v_steady‖⁻¹`` (1.0 reproduces the
    *uncorrected* He-et-al. baseline of Fig. 1, dashed lines).
    """

    distribution: Distribution = "he_normal"
    gain: float = 1.0

    def replace(self, **kw) -> "InitConfig":
        return dataclasses.replace(self, **kw)


def gain_from_graph(graph: Graph) -> float:
    """Perfect-knowledge gain: ``‖v_steady‖⁻¹`` from the full topology (§4.3).

    For random k-regular / ER / torus graphs this is ≈ √n, the factor the
    paper multiplies into the He standard deviation.
    """
    return 1.0 / v_steady_norm(graph)


def gain_from_estimates(
    n_estimate: float,
    degree_sample: np.ndarray | None = None,
    family_exponent: float | None = None,
) -> float:
    """Imperfect-knowledge gain (§4.4).

    Priority: a sampled degree distribution (gossip poll) → closed-form ‖v‖
    estimate; else a known family exponent α with ``‖v‖ = n^-α`` (α = 1/2 for
    homogeneous graphs, Fig. 5); else assume homogeneous (α = 1/2 ⇒ gain = √n).
    Fig. 4 shows the method is robust to substantial mis-estimation of n.
    """
    if degree_sample is not None:
        return 1.0 / v_steady_norm_from_degree_sample(np.asarray(degree_sample), int(round(n_estimate)))
    alpha = 0.5 if family_exponent is None else family_exponent
    return float(n_estimate**alpha)


def _fans(shape: tuple[int, ...]) -> tuple[float, float]:
    """fan_in/fan_out for dense (in, out), conv (kh, kw, cin, cout) and stacked shapes."""
    if len(shape) == 1:
        return float(shape[0]), float(shape[0])
    if len(shape) == 2:
        return float(shape[0]), float(shape[1])
    receptive = math.prod(shape[:-2])
    return float(shape[-2] * receptive), float(shape[-1] * receptive)


def he_normal(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    """He et al. [33] fan-in normal init × the paper's gain correction."""
    fan_in, _ = _fans(shape)
    std = math.sqrt(2.0 / fan_in) * gain
    return std * jax.random.normal(key, shape, dtype)


def he_uniform(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    fan_in, _ = _fans(shape)
    limit = math.sqrt(6.0 / fan_in) * gain
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def glorot_normal(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    fan_in, fan_out = _fans(shape)
    std = math.sqrt(2.0 / (fan_in + fan_out)) * gain
    return std * jax.random.normal(key, shape, dtype)


def glorot_uniform(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    fan_in, fan_out = _fans(shape)
    limit = math.sqrt(6.0 / (fan_in + fan_out)) * gain
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def trunc_normal(
    key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0, std: float | None = None
) -> jax.Array:
    """Truncated-normal (±2σ) fan-in init — the transformer-zoo default."""
    fan_in, _ = _fans(shape)
    s = (std if std is not None else math.sqrt(1.0 / fan_in)) * gain
    return s * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


_DISTS = {
    "he_normal": he_normal,
    "he_uniform": he_uniform,
    "glorot_normal": glorot_normal,
    "glorot_uniform": glorot_uniform,
    "trunc_normal": trunc_normal,
}


def scaled_init(cfg: InitConfig, key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    """Draw one weight tensor per ``cfg`` (Algorithm 1, lines 3–6)."""
    return _DISTS[cfg.distribution](key, shape, dtype, gain=cfg.gain)

"""Uncoordinated, network-aware parameter initialisation (paper §4, Algorithm 1).

The technique: each node draws its parameters *independently* with a standard
architecture-appropriate initialiser (He et al. [33] for ReLU nets, Glorot for
tanh/linear, truncated-normal for transformers), then **rescales every
randomly-drawn weight distribution by ``gain = ‖v_steady‖⁻¹``** so that after
the early diffusion phase compresses per-node parameter variance by
``‖v_steady‖`` (§4.3), the surviving distribution is exactly the one the
initialiser intended.

Structured parameters (zeros, ones, RoPE-free, SSM decay spectra) are *not*
rescaled — the σ_init analysis only covers zero-mean random draws; under
DecAvg, deterministic equal values are a fixed point of the mixing operator
(see DESIGN.md §4 caveat).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from .mixing import v_steady_norm, v_steady_norm_from_degree_sample
from .topology import Graph

__all__ = [
    "InitConfig",
    "gain_from_graph",
    "gain_from_estimates",
    "he_normal",
    "he_uniform",
    "glorot_normal",
    "glorot_uniform",
    "trunc_normal",
    "scaled_init",
]

Distribution = Literal["he_normal", "he_uniform", "glorot_normal", "glorot_uniform", "trunc_normal"]


@dataclasses.dataclass(frozen=True)
class InitConfig:
    """How to initialise one node's parameters.

    gain: the paper's correction factor, ``‖v_steady‖⁻¹`` (1.0 reproduces the
    *uncorrected* He-et-al. baseline of Fig. 1, dashed lines).  May be a
    traced 0-d jax scalar: per-node gains are applied by vmapping the init
    over ``(key, gain)`` pairs (``fed.trainer.init_fl_state(gains=...)``),
    each lane seeing ``cfg.replace(gain=g_i)`` — the initialisers below are
    linear in ``gain``, so tracing it costs nothing.
    """

    distribution: Distribution = "he_normal"
    gain: float | jax.Array = 1.0

    def replace(self, **kw) -> "InitConfig":
        return dataclasses.replace(self, **kw)


def gain_from_graph(graph: Graph) -> float:
    """Perfect-knowledge gain: ``‖v_steady‖⁻¹`` from the full topology (§4.3).

    For random k-regular / ER / torus graphs this is ≈ √n, the factor the
    paper multiplies into the He standard deviation.
    """
    return 1.0 / v_steady_norm(graph)


def gain_from_estimates(
    n_estimate: float | np.ndarray,
    degree_sample: np.ndarray | None = None,
    family_exponent: float | None = None,
) -> float | np.ndarray:
    """Imperfect-knowledge gain (§4.4), vectorised over per-node estimates.

    Exactly one knowledge source may be given, and the priority order is:

    1. ``degree_sample`` — a polled degree distribution (gossip random walk)
       → closed-form ‖v̂‖ via ``v_steady_norm_from_degree_sample``;
    2. ``family_exponent`` — a known network-formation exponent α with
       ``‖v‖ = n^-α`` (α = 1/2 for homogeneous graphs, Fig. 5);
    3. neither — assume homogeneous (α = 1/2 ⇒ gain = √n̂).

    Passing both ``degree_sample`` and ``family_exponent`` raises: the two
    encode contradictory knowledge and the old behaviour of silently
    ignoring the exponent hid caller bugs.

    Vectorised: ``n_estimate`` may be an (n,) vector of per-node estimates
    (the truly uncoordinated setting — every node trusts only its own
    gossip), and ``degree_sample`` may be (m,) shared or (n, m) per node.
    Scalar inputs return a float, array inputs an (n,) array.  Device
    mirror: ``repro.gossip.gains_from_estimates`` (fp32-parity tested).
    Fig. 4 shows the method is robust to substantial mis-estimation of n.
    """
    if degree_sample is not None and family_exponent is not None:
        raise ValueError(
            "gain_from_estimates: give either degree_sample or "
            "family_exponent, not both — a polled degree distribution "
            "already determines the ‖v‖ estimate (priority 1), so an "
            "exponent alongside it would be silently ignored"
        )
    n_est = np.asarray(n_estimate, dtype=np.float64)
    if degree_sample is not None:
        out = 1.0 / v_steady_norm_from_degree_sample(degree_sample, np.round(n_est))
    else:
        alpha = 0.5 if family_exponent is None else family_exponent
        out = n_est**alpha
    out = np.asarray(out)
    return float(out) if out.ndim == 0 else out


def _fans(shape: tuple[int, ...]) -> tuple[float, float]:
    """fan_in/fan_out for dense (in, out), conv (kh, kw, cin, cout) and stacked shapes."""
    if len(shape) == 1:
        return float(shape[0]), float(shape[0])
    if len(shape) == 2:
        return float(shape[0]), float(shape[1])
    receptive = math.prod(shape[:-2])
    return float(shape[-2] * receptive), float(shape[-1] * receptive)


def he_normal(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    """He et al. [33] fan-in normal init × the paper's gain correction."""
    fan_in, _ = _fans(shape)
    std = math.sqrt(2.0 / fan_in) * gain
    return std * jax.random.normal(key, shape, dtype)


def he_uniform(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    fan_in, _ = _fans(shape)
    limit = math.sqrt(6.0 / fan_in) * gain
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def glorot_normal(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    fan_in, fan_out = _fans(shape)
    std = math.sqrt(2.0 / (fan_in + fan_out)) * gain
    return std * jax.random.normal(key, shape, dtype)


def glorot_uniform(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0) -> jax.Array:
    fan_in, fan_out = _fans(shape)
    limit = math.sqrt(6.0 / (fan_in + fan_out)) * gain
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def trunc_normal(
    key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32, gain: float = 1.0, std: float | None = None
) -> jax.Array:
    """Truncated-normal (±2σ) fan-in init — the transformer-zoo default."""
    fan_in, _ = _fans(shape)
    s = (std if std is not None else math.sqrt(1.0 / fan_in)) * gain
    return s * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


_DISTS = {
    "he_normal": he_normal,
    "he_uniform": he_uniform,
    "glorot_normal": glorot_normal,
    "glorot_uniform": glorot_uniform,
    "trunc_normal": trunc_normal,
}


def scaled_init(cfg: InitConfig, key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
    """Draw one weight tensor per ``cfg`` (Algorithm 1, lines 3–6)."""
    return _DISTS[cfg.distribution](key, shape, dtype, gain=cfg.gain)

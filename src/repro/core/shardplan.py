"""Node-sharded rendering of a ``CommPlan``: DecAvg over a device mesh axis.

Every other rendering in ``core.commplan`` materialises the full node axis on
one device; this module partitions the FL node dimension **contiguously**
across a mesh axis (DESIGN.md §15) and executes the same effective operator
with per-shard work plus static halo collectives:

* **intra-shard edges** — the global receive CSR is dst-sorted, so each
  shard's in-edges are one contiguous slice of it; the slice runs as the
  usual gather + ``segment_sum`` (padded with dummy-segment entries, so the
  per-row accumulation order — hence the floating-point result — is
  bit-identical to the single-device segment-sum rendering).
* **cross-shard edges** — a static halo-exchange plan: for every shard
  offset δ with traffic, each shard gathers the rows its offset-δ neighbour
  needs (a per-shard send-index table) and one ``jax.lax.ppermute`` moves
  the buffers; received rows are appended to the local block in a fixed
  deterministic order, and edge gather indices point into that
  ``[local | halo]`` buffer.

Failure draws stay **globally keyed**: every shard redraws the full
(n_edges,) / (n,) Bernoulli masks from the same (replicated) per-round key,
so a sharded round keeps the exact per-edge draws of the single-device plan
— the bit-parity property ``tests/test_sharded_plan.py`` pins down.

``spread`` (the send-form operator gossip rides) uses a second, src-sorted
layout of the same edges with its own halo plan; ``spread_min`` reuses the
receive layout with ``segment_min``.  The dense backend shards the receive
matrix by rows (one ``all_gather`` of the payload — the paper-faithful
baseline's communication pattern made explicit); the ppermute backend keeps
its one-node-per-device contract and runs the colour matchings as true
per-colour ``ppermute`` rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .commplan import CommPlan, _draw_failure_masks
from .decavg import _bcast, mix_pytree_colored

try:  # jax >= 0.6 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version-dependent import
    from jax.experimental.shard_map import shard_map as _shard_map

PyTree = Any

__all__ = ["ShardedCommPlan", "shard_plan"]

_F32 = jnp.float32


# ---------------------------------------------------------------------------
# host-side layout compilation
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Layout:
    """One sharded edge layout (receive- or send-sorted) + its halo plan.

    All per-shard tables carry a leading ``(n_shards, ...)`` axis and enter
    ``shard_map`` as node-axis-sharded operands; ``h_max`` is a static int
    baked into the (single) ``all_to_all`` halo exchange.

    ``seg``    (S, E) local segment index of the *owning* endpoint
               (padding rows point at the dummy segment ``nps``);
    ``gat``    (S, E) gather index into the ``[local | halo]`` buffer;
    ``uid``    (S, E) global undirected edge id (failure-draw key);
    ``gown``/``gfar`` (S, E) global ids of the owning / gathered endpoint;
    ``perm``   (S, E) position of the edge in the global receive-CSR arrays;
    ``send``   (S, S, H) local rows shard q ships to every other shard,
               padded per pair to the uniform width ``h_max`` so the whole
               halo moves as ONE ``all_to_all`` per round (collective
               rendezvous dominates small payloads, so k per-offset
               ``ppermute`` rounds lose to one padded exchange).
    """

    nps: int
    n_shards: int
    h_max: int
    seg: jax.Array
    gat: jax.Array
    uid: jax.Array
    edge_w: jax.Array
    raw_edge_w: jax.Array
    gown: jax.Array
    gfar: jax.Array
    valid: jax.Array
    perm: jax.Array
    self_w: jax.Array  # (S, nps) statically normalised self weights
    raw_self_w: jax.Array  # (S, nps)
    send: jax.Array  # (S, S, h_max) all_to_all send tables
    # host-side gather-position maps: pos[s][global node] → row in shard s's
    # ``[local | halo]`` buffer, for compiling further per-shard index tables
    # (the HYB slot chain) against this layout's halo plan
    pos: tuple[dict, ...] = ()

    def tables(self) -> dict[str, jax.Array]:
        """The shard_map operand dict (all leading-axis node-sharded)."""
        return {
            "seg": self.seg,
            "gat": self.gat,
            "uid": self.uid,
            "edge_w": self.edge_w,
            "raw_edge_w": self.raw_edge_w,
            "gown": self.gown,
            "gfar": self.gfar,
            "valid": self.valid,
            "perm": self.perm,
            "self_w": self.self_w,
            "raw_self_w": self.raw_self_w,
            "send": self.send,
        }

    @property
    def halo_rows(self) -> int:
        """Rows each shard ships cross-device per round — the padded
        ``all_to_all`` width times the S-1 remote destinations (the q→q
        block of the exchange never leaves the device)."""
        return (self.n_shards - 1) * self.h_max


def _build_layout(
    n: int,
    n_shards: int,
    own: np.ndarray,
    far: np.ndarray,
    uid: np.ndarray,
    edge_w: np.ndarray,
    raw_edge_w: np.ndarray,
    perm: np.ndarray,
    self_w: np.ndarray,
    raw_self_w: np.ndarray,
) -> _Layout:
    """Compile one (own-sorted) edge layout into per-shard tables + halo plan.

    ``own`` must be sorted ascending (dst for the receive layout, src for the
    send layout); edges of shard s are then the contiguous slice whose owner
    falls in ``[s*nps, (s+1)*nps)``.  Fully deterministic: halo rows are the
    sorted unique remote endpoints, laid out per source shard in ascending
    shard order at the uniform ``all_to_all`` width ``h_max``.
    """
    nps = n // n_shards
    bounds = np.searchsorted(own, np.arange(1, n_shards + 1) * nps)
    starts = np.concatenate([[0], bounds[:-1]])
    env = max(int((bounds - starts).max()), 1)

    # remote needs: needs[s][q] = sorted global nodes shard s must pull from q
    needs: list[dict[int, np.ndarray]] = [{} for _ in range(n_shards)]
    for s in range(n_shards):
        f = far[starts[s] : bounds[s]]
        remote = f[(f < s * nps) | (f >= (s + 1) * nps)]
        for q in np.unique(remote // nps):
            needs[s][int(q)] = np.unique(remote[remote // nps == q])

    h_max = max((len(nd) for ns in needs for nd in ns.values()), default=0)
    pos: list[dict[int, int]] = [{} for _ in range(n_shards)]
    send = np.zeros((n_shards, n_shards, max(h_max, 1)), np.int32)
    for s in range(n_shards):
        for q, nd in needs[s].items():
            send[q, s, : len(nd)] = (nd - q * nps).astype(np.int32)
            for j, g in enumerate(nd):
                # gather space is [local | recv block of shard 0 | shard 1 |…]
                pos[s][int(g)] = nps + q * h_max + j

    seg = np.full((n_shards, env), nps, np.int32)
    gat = np.zeros((n_shards, env), np.int32)
    uid_t = np.zeros((n_shards, env), np.int32)
    ew_t = np.zeros((n_shards, env), np.float32)
    rew_t = np.zeros((n_shards, env), np.float32)
    gown_t = np.zeros((n_shards, env), np.int32)
    gfar_t = np.zeros((n_shards, env), np.int32)
    valid_t = np.zeros((n_shards, env), bool)
    perm_t = np.zeros((n_shards, env), np.int32)
    for s in range(n_shards):
        sl = slice(starts[s], bounds[s])
        m = bounds[s] - starts[s]
        lo = s * nps
        f = far[sl]
        seg[s, :m] = (own[sl] - lo).astype(np.int32)
        gat[s, :m] = [
            int(g) - lo if lo <= g < lo + nps else pos[s][int(g)] for g in f
        ]
        uid_t[s, :m] = uid[sl]
        ew_t[s, :m] = edge_w[sl]
        rew_t[s, :m] = raw_edge_w[sl]
        gown_t[s, :m] = own[sl]
        gfar_t[s, :m] = f
        valid_t[s, :m] = True
        perm_t[s, :m] = perm[sl]

    return _Layout(
        nps=nps,
        n_shards=n_shards,
        h_max=h_max,
        seg=jnp.asarray(seg),
        gat=jnp.asarray(gat),
        uid=jnp.asarray(uid_t),
        edge_w=jnp.asarray(ew_t),
        raw_edge_w=jnp.asarray(rew_t),
        gown=jnp.asarray(gown_t),
        gfar=jnp.asarray(gfar_t),
        valid=jnp.asarray(valid_t),
        perm=jnp.asarray(perm_t),
        self_w=jnp.asarray(self_w.reshape(n_shards, nps), jnp.float32),
        raw_self_w=jnp.asarray(raw_self_w.reshape(n_shards, nps), jnp.float32),
        send=jnp.asarray(send),
        pos=tuple(pos),
    )


def _build_hyb_tables(plan: CommPlan, recv: _Layout, n_shards: int) -> dict | None:
    """Shard the sparse backend's HYB layout against the receive halo plan.

    The ELL slot chain is row-parallel (per owned row: self term then one
    fused gather per slot, in slot order), so re-pointing each slot index at
    the ``[local | halo]`` buffer preserves the exact accumulation order of
    ``mix_pytree_hyb`` — the clean-topology sharded mix stays bit-identical
    to the single-device ``CommPlan.mix``.  Heavy-tail hub rows keep their
    full-length dense receive rows (their halo would approach n anyway) and
    contract against an all-gathered payload; padding hub slots scatter to
    the out-of-range row ``nps``, which JAX's scatter drops.
    """
    if plan.slot_idx is None:
        return None
    slot_idx = np.asarray(plan.slot_idx)  # (n_slots, n)
    slot_w = np.asarray(plan.slot_w)
    hyb_self = np.asarray(plan.hyb_self_w)
    hub_rows = np.asarray(plan.hub_rows)
    hub_m = np.asarray(plan.hub_m)
    n = plan.n
    nps = n // n_shards
    n_slots = slot_idx.shape[0]
    slot_pos = np.zeros((n_shards, n_slots, nps), np.int32)
    for q in range(n_shards):
        lo = q * nps
        for s in range(n_slots):
            for r in range(nps):
                g = int(slot_idx[s, lo + r])
                slot_pos[q, s, r] = g - lo if lo <= g < lo + nps else recv.pos[q][g]
    owner = hub_rows // nps if len(hub_rows) else np.zeros(0, np.int64)
    h_max = int(max((np.sum(owner == q) for q in range(n_shards)), default=0)) if len(hub_rows) else 0
    hub_loc = np.full((n_shards, h_max), nps, np.int32)  # pad → dropped scatter
    hub_m_t = np.zeros((n_shards, h_max, n), np.float32)
    for q in range(n_shards):
        rows = np.nonzero(owner == q)[0]
        for j, ri in enumerate(rows):
            hub_loc[q, j] = int(hub_rows[ri]) - q * nps
            hub_m_t[q, j] = hub_m[ri]
    return {
        "slot_pos": jnp.asarray(slot_pos),
        "slot_w": jnp.asarray(
            slot_w.reshape(n_slots, n_shards, nps).transpose(1, 0, 2), jnp.float32
        ),
        "hyb_self": jnp.asarray(hyb_self.reshape(n_shards, nps), jnp.float32),
        "hub_loc": jnp.asarray(hub_loc),
        "hub_m": jnp.asarray(hub_m_t),
    }


# ---------------------------------------------------------------------------
# the sharded plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardedCommPlan:
    """A ``CommPlan`` rendered over a node-sharded mesh axis.

    Drop-in for the gossip engine's operator protocol: ``mix`` / ``spread``
    / ``spread_min`` take globally shaped payloads, run one ``shard_map``
    internally (jit/scan-traceable) and return globally shaped results that
    are bit-identical to the single-device segment-sum rendering of the same
    plan.  ``local_*`` variants run *inside* an enclosing ``shard_map`` (the
    sharded executor) on per-shard blocks.
    """

    base: CommPlan
    mesh: Mesh
    axis: str
    n_shards: int
    nps: int
    recv: _Layout | None = None  # sparse backends
    send: _Layout | None = None
    hyb: dict | None = None  # sharded HYB tables (clean sparse mix)

    # ------------------------------------------------------------- metadata
    @property
    def n(self) -> int:
        return self.base.n

    @property
    def graph(self):
        return self.base.graph

    @property
    def backend(self) -> str:
        return self.base.backend

    @property
    def failures(self):
        return self.base.failures

    @property
    def data_sizes(self):
        return self.base.data_sizes

    @property
    def n_edges(self) -> int:
        return self.base.n_edges

    def cross_shard_rows_per_round(self, op: str = "mix") -> int:
        """Total rows moved across devices per round, all collectives of the
        op included (static — the weak-scaling benchmark's traffic axis)."""
        if self.backend == "dense":
            return self.n_shards * (self.n - self.nps)
        layout = self.send if op == "spread" else self.recv
        if layout is None:
            return 0
        rows = self.n_shards * layout.halo_rows
        if op == "mix" and not self.failures.active and self.hyb is not None:
            if self.hyb["hub_loc"].shape[-1]:
                # heavy-tail hub rows contract against an all-gathered payload
                rows += self.n_shards * (self.n - self.nps)
        return rows

    def collectives_per_round(self, op: str = "mix") -> int:
        """Collective launches per round per payload leaf (static)."""
        if self.n_shards == 1:
            return 0
        if self.backend == "dense":
            return 1
        if self.backend == "ppermute":
            return sum(1 for p in self.base.color_perms() if p)
        layout = self.send if op == "spread" else self.recv
        k = 1 if layout is not None and layout.h_max else 0
        if op == "mix" and not self.failures.active and self.hyb is not None:
            if self.hyb["hub_loc"].shape[-1]:
                k += 1
        return k

    def cross_shard_bytes_per_round(self, row_bytes: int, op: str = "mix") -> int:
        """Cross-shard traffic per round for a payload of ``row_bytes`` per
        node row — the weak-scaling benchmark's bytes axis."""
        return self.cross_shard_rows_per_round(op) * row_bytes

    # ----------------------------------------------------------- primitives
    def _halo_gather(self, x: jax.Array, layout: _Layout, t: dict) -> jax.Array:
        """(nps, ...) local block → (nps + S·h_max, ...) ``[local | halo]``.

        One ``all_to_all`` moves every shard's padded send blocks at once —
        the recv block of source shard q lands at rows ``nps + q*h_max``."""
        if layout.h_max == 0 or self.n_shards == 1:
            return x
        with jax.named_scope("halo_exchange"):
            buf = jnp.take(x, t["send"][0], axis=0)  # (S, h_max, ...)
            recv = jax.lax.all_to_all(buf, self.axis, split_axis=0, concat_axis=0)
            halo = recv.reshape((self.n_shards * layout.h_max,) + x.shape[1:])
            return jnp.concatenate([x, halo], axis=0)

    def _masks(self, key: jax.Array) -> tuple[jax.Array, jax.Array]:
        """The plan's global failure draw, replicated on every shard: same
        key → bit-identical masks to the single-device ``round_masks``."""
        return _draw_failure_masks(self.failures, self.n_edges, self.n, key)

    def _recv_round_weights(
        self, key: jax.Array | None, t: dict
    ) -> tuple[jax.Array, jax.Array]:
        """Per-shard (edge_w, self_w) of this round's effective operator —
        the sharded ``_sparse_round_weights`` (same values, same per-row
        accumulation order for the renormalising denominator)."""
        if not self.failures.active:
            return t["edge_w"][0], t["self_w"][0]
        edge_keep, active = self._masks(key)
        keep = t["valid"][0] & edge_keep[t["uid"][0]]
        keep = keep & active[t["gfar"][0]] & active[t["gown"][0]]
        num = t["raw_edge_w"][0] * keep
        den = t["raw_self_w"][0] + jax.ops.segment_sum(
            num, t["seg"][0], num_segments=self.nps + 1, indices_are_sorted=True
        )[: self.nps]
        den_pad = jnp.concatenate([den, jnp.ones((1,), _F32)])
        return num / den_pad[t["seg"][0]], t["raw_self_w"][0] / den

    # -------------------------------------------------------- local bodies
    def local_mix(self, params: PyTree, key: jax.Array | None, t: dict) -> PyTree:
        """One DecAvg round on per-shard blocks — call inside ``shard_map``
        with ``t = recv.tables()`` passed as node-sharded operands."""
        if self.backend == "dense":
            return self._local_dense("mix", params, key)
        layout = self.recv
        if not self.failures.active and self.hyb is not None:
            return self._local_mix_hyb(params, t)
        edge_w, self_w = self._recv_round_weights(key, t)
        seg, gat = t["seg"][0], t["gat"][0]

        def mix_leaf(x: jax.Array) -> jax.Array:
            x_all = self._halo_gather(x, layout, t)
            gathered = jnp.take(x_all, gat, axis=0).astype(_F32)
            contrib = _bcast(edge_w, x.ndim) * gathered
            agg = jax.ops.segment_sum(
                contrib, seg, num_segments=self.nps + 1, indices_are_sorted=True
            )[: self.nps]
            out = _bcast(self_w, x.ndim) * x.astype(_F32) + agg
            return out.astype(x.dtype)

        return jax.tree_util.tree_map(mix_leaf, params)

    def _local_mix_hyb(self, params: PyTree, t: dict) -> PyTree:
        """Sharded rendering of the clean-topology HYB mix: the per-row slot
        chain gathers from the ``[local | halo]`` buffer in the same slot
        order as ``mix_pytree_hyb`` (bit-identical accumulation), hub rows
        contract their full dense receive rows against an all-gathered
        payload."""
        slot_pos, slot_w = t["slot_pos"][0], t["slot_w"][0]
        self_w = t["hyb_self"][0]
        n_hub = t["hub_loc"].shape[-1]

        def mix_leaf(x: jax.Array) -> jax.Array:
            xf = x.astype(_F32)
            x_all = self._halo_gather(x, self.recv, t).astype(_F32)
            acc = _bcast(self_w, x.ndim) * xf
            for s in range(slot_pos.shape[0]):
                acc = acc + _bcast(slot_w[s], x.ndim) * jnp.take(
                    x_all, slot_pos[s], axis=0
                )
            if n_hub:
                x_full = jax.lax.all_gather(xf, self.axis, axis=0, tiled=True)
                hub_out = jnp.tensordot(
                    t["hub_m"][0], x_full, axes=[[1], [0]],
                    preferred_element_type=_F32,
                )
                acc = acc.at[t["hub_loc"][0]].set(hub_out)
            return acc.astype(x.dtype)

        return jax.tree_util.tree_map(mix_leaf, params)

    def local_spread(self, x: jax.Array, key: jax.Array | None, t: dict) -> jax.Array:
        """Send-form round on the (nps, k) local block (src-sorted layout)."""
        if self.backend == "dense":
            return self._local_dense("spread", x, key)
        layout = self.send
        if not self.failures.active:
            edge_w, self_w = t["edge_w"][0], t["self_w"][0]
        else:
            # the renormalising denominator is indexed by the *remote* dst
            # endpoint, so each shard replays the global replicated reduction
            # (masks are replicated anyway; O(nnz) elementwise work)
            edge_keep, active = self._masks(key)
            g = self.base
            keep = edge_keep[g.edge_uid] & active[g.src] & active[g.dst]
            num_g = g.raw_edge_w * keep
            den_g = g.raw_self_w + jax.ops.segment_sum(
                num_g, g.dst, num_segments=self.n, indices_are_sorted=True
            )
            p = t["perm"][0]
            edge_w = jnp.where(
                t["valid"][0], num_g[p] / den_g[t["gfar"][0]], jnp.float32(0.0)
            )
            i = jax.lax.axis_index(self.axis)
            den_l = jax.lax.dynamic_slice_in_dim(den_g, i * self.nps, self.nps)
            self_w = t["raw_self_w"][0] / den_l
        x_all = self._halo_gather(x, layout, t)
        contrib = edge_w[:, None] * x_all[t["gat"][0]]
        agg = jax.ops.segment_sum(
            contrib, t["seg"][0], num_segments=self.nps + 1, indices_are_sorted=True
        )[: self.nps]
        return self_w[:, None] * x + agg

    def local_spread_min(
        self, x: jax.Array, key: jax.Array | None, t: dict
    ) -> jax.Array:
        """Min-exchange round on the (nps, k) local block (receive layout)."""
        if self.backend == "dense":
            return self._local_dense("spread_min", x, key)
        layout = self.recv
        keep = t["valid"][0]
        if self.failures.active:
            edge_keep, active = self._masks(key)
            keep = keep & edge_keep[t["uid"][0]]
            keep = keep & active[t["gfar"][0]] & active[t["gown"][0]]
        x_all = self._halo_gather(x, layout, t)
        gathered = jnp.where(keep[:, None], x_all[t["gat"][0]], jnp.float32(jnp.inf))
        nbr = jax.ops.segment_min(
            gathered, t["seg"][0], num_segments=self.nps + 1, indices_are_sorted=True
        )[: self.nps]
        return jnp.minimum(x, nbr)

    def _local_dense(self, op: str, payload, key: jax.Array | None):
        """Row-block rendering of the dense backend: the (replicated) round
        matrix is sliced at ``axis_index`` and the payload all-gathered —
        dense mixing's inherent node-axis gather, made explicit."""
        m = self.base._dense_round_matrix(key)
        i = jax.lax.axis_index(self.axis)
        if op == "mix":
            block = jax.lax.dynamic_slice_in_dim(m, i * self.nps, self.nps, axis=0)

            def mix_leaf(x: jax.Array) -> jax.Array:
                x_full = jax.lax.all_gather(x, self.axis, axis=0, tiled=True)
                out = jnp.tensordot(
                    block, x_full, axes=[[1], [0]], preferred_element_type=_F32
                )
                return out.astype(x.dtype)

            return jax.tree_util.tree_map(mix_leaf, payload)
        x_full = jax.lax.all_gather(payload, self.axis, axis=0, tiled=True)
        if op == "spread":
            cols = jax.lax.dynamic_slice_in_dim(m, i * self.nps, self.nps, axis=1)
            return jnp.einsum("ji,jk->ik", cols, x_full)
        # spread_min: surviving-neighbourhood mask rows
        keep = self.base.adjacency > 0
        if self.failures.active:
            edge_keep, active = self._masks(key)
            keep = keep & edge_keep[self.base.edge_uid_matrix]
            keep = keep & active[:, None] & active[None, :]
        rows = jax.lax.dynamic_slice_in_dim(keep, i * self.nps, self.nps, axis=0)
        nbr = jnp.where(rows[:, :, None], x_full[None, :, :], jnp.float32(jnp.inf))
        return jnp.minimum(payload, nbr.min(axis=1))

    # ------------------------------------------------------ public operator
    def _specs_for(self, tree: PyTree) -> PyTree:
        ax = self.axis
        return jax.tree_util.tree_map(
            lambda l: P(ax, *([None] * (l.ndim - 1))), tree
        )

    def _run(self, op: str, payload: PyTree, key: jax.Array | None) -> PyTree:
        if self.failures.active and key is None:
            raise ValueError("failure model active: sharded ops need a PRNG key")
        if self.backend == "ppermute":
            return self._run_colored(op, payload, key)
        local_fn = getattr(self, f"local_{op}")
        if self.backend == "dense":
            tables: dict[str, jax.Array] = {}
        elif op == "mix":
            tables = self._mix_tables()
        else:
            layout = self.send if op == "spread" else self.recv
            tables = layout.tables()
        pay_specs = self._specs_for(payload)
        tab_specs = self._specs_for(tables)
        if key is None:
            f = _shard_map(
                lambda pay, t: local_fn(pay, None, t),
                mesh=self.mesh,
                in_specs=(pay_specs, tab_specs),
                out_specs=pay_specs,
            )
            return f(payload, tables)
        f = _shard_map(
            lambda pay, k, t: local_fn(pay, k, t),
            mesh=self.mesh,
            in_specs=(pay_specs, P(), tab_specs),
            out_specs=pay_specs,
        )
        return f(payload, key, tables)

    def mix(self, params: PyTree, key: jax.Array | None = None) -> PyTree:
        """One DecAvg aggregation of a globally shaped node-stacked pytree."""
        return self._run("mix", params, key)

    def spread(self, values: jax.Array, key: jax.Array | None = None) -> jax.Array:
        """One send-form (column-stochastic) round — ``CommPlan.spread``."""
        x = jnp.asarray(values, _F32)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        out = self._run("spread", x, key)
        return out[:, 0] if squeeze else out

    def spread_min(self, values: jax.Array, key: jax.Array | None = None) -> jax.Array:
        """One neighbourhood min-exchange round — ``CommPlan.spread_min``."""
        x = jnp.asarray(values, _F32)
        squeeze = x.ndim == 1
        if squeeze:
            x = x[:, None]
        out = self._run("spread_min", x, key)
        return out[:, 0] if squeeze else out

    # ------------------------------------------------- ppermute (nps == 1)
    def _color_round_weights_local(
        self, key: jax.Array | None, t: dict
    ) -> tuple[jax.Array, jax.Array]:
        """Local column of ``color_round_weights`` — (n_colors, 1), (1,)."""
        if not self.failures.active:
            return t["color_w"], t["self_w"]
        edge_keep, active = self._masks(key)
        matched = t["color_uid"] >= 0
        keep = matched & edge_keep[jnp.clip(t["color_uid"], 0, None)]
        i = jax.lax.axis_index(self.axis)
        keep = keep & active[i] & jnp.take(active, t["partner"])
        num = t["color_raw_w"] * keep
        den = t["raw_self_w"] + num.sum(axis=0)
        return num / den[None, :], t["raw_self_w"] / den

    def local_colored(
        self, op: str, pay: PyTree, key: jax.Array | None, t: dict
    ) -> PyTree:
        """Colour-matching backend body: one node per device group, each
        colour class one true ``ppermute`` round (the collective rendering
        DESIGN.md §12 flagged as emulated)."""
        ax = self.axis
        base = self.base
        if op == "mix":
            cw, sw = self._color_round_weights_local(key, t)
            return mix_pytree_colored(pay, base.partners, cw, sw, axis_name=ax)
        perms = base.color_perms()
        if op == "spread":
            cw, sw = self._color_round_weights_local(key, t)
            x = pay
            acc = sw[:, None] * x
            for c in range(base.n_colors):
                if not perms[c]:
                    continue
                # the mass each node pushes along its colour-c edge lands on
                # the opposite endpoint — weights travel with the payload
                acc = acc + jax.lax.ppermute(cw[c][:, None] * x, ax, perms[c])
            return acc
        # spread_min
        keep = t["color_uid"] >= 0
        if self.failures.active:
            edge_keep, active = self._masks(key)
            keep = keep & edge_keep[jnp.clip(t["color_uid"], 0, None)]
            i = jax.lax.axis_index(ax)
            keep = keep & active[i] & jnp.take(active, t["partner"])
        x = pay
        inf = jnp.float32(jnp.inf)
        nbr = jnp.full_like(x, inf)
        for c in range(base.n_colors):
            if not perms[c]:
                continue
            cand = jax.lax.ppermute(x, ax, perms[c])
            nbr = jnp.minimum(nbr, jnp.where(keep[c][:, None], cand, inf))
        return jnp.minimum(x, nbr)

    def _colored_tables(self) -> tuple[dict, dict]:
        base = self.base
        tables = {
            "color_w": base.color_w,
            "color_raw_w": base.color_raw_w,
            "color_uid": base.color_edge_uid,
            "partner": jnp.asarray(base.partners),
            "self_w": base.self_w,
            "raw_self_w": base.raw_self_w,
        }
        ax = self.axis
        specs = {k: P(ax) if v.ndim == 1 else P(None, ax) for k, v in tables.items()}
        return tables, specs

    def mix_operands(self) -> tuple[dict, dict]:
        """(tables, in_specs) an enclosing ``shard_map`` (e.g. the sharded
        executor) passes through to ``local_mix_any`` — the per-shard mixing
        tables of this plan's backend."""
        if self.backend == "dense":
            return {}, {}
        if self.backend == "ppermute":
            return self._colored_tables()
        t = self._mix_tables()
        return t, self._specs_for(t)

    def _mix_tables(self) -> dict[str, jax.Array]:
        """Receive-layout tables, plus the sharded HYB tables on the clean
        static-topology path (where ``local_mix`` takes the slot chain)."""
        t = self.recv.tables()
        if not self.failures.active and self.hyb is not None:
            t = {**t, **self.hyb}
        return t

    def local_mix_any(self, params: PyTree, key: jax.Array | None, t: dict) -> PyTree:
        """Backend-dispatching ``local_mix`` for use inside an enclosing
        ``shard_map`` with ``mix_operands()``'s tables."""
        if self.backend == "ppermute":
            return self.local_colored("mix", params, key, t)
        return self.local_mix(params, key, t)

    def _run_colored(self, op: str, payload: PyTree, key: jax.Array | None) -> PyTree:
        tables, tab_specs = self._colored_tables()
        pay_specs = self._specs_for(payload)
        if key is None:
            f = _shard_map(
                lambda pay, t: self.local_colored(op, pay, None, t),
                mesh=self.mesh,
                in_specs=(pay_specs, tab_specs),
                out_specs=pay_specs,
            )
            return f(payload, tables)
        f = _shard_map(
            lambda pay, k, t: self.local_colored(op, pay, k, t),
            mesh=self.mesh,
            in_specs=(pay_specs, P(), tab_specs),
            out_specs=pay_specs,
        )
        return f(payload, key, tables)

    # ------------------------------------------------------------- plumbing
    def with_options(self, **kw) -> "ShardedCommPlan":
        """Recompile the base plan with some knobs replaced, re-sharded over
        the same mesh/axis."""
        return shard_plan(self.base.with_options(**kw), mesh=self.mesh, axis=self.axis)


def shard_plan(
    plan: CommPlan,
    *,
    mesh: Mesh | None = None,
    axis: str | None = None,
    n_shards: int | None = None,
) -> ShardedCommPlan:
    """Render a compiled ``CommPlan`` over a node-sharded mesh axis.

    ``mesh``/``axis`` name the node axis (e.g. ``launch.mesh.node_mesh(4)``
    with axis ``"node"``); alternatively give just ``n_shards`` and a 1-D
    mesh over the first ``n_shards`` local devices is built here.  Nodes are
    partitioned contiguously — shard s owns rows ``[s·nps, (s+1)·nps)`` —
    and ``n`` must divide evenly.  The ppermute backend additionally
    requires one node per device (``nps == 1``), where the colour matchings
    run as true per-colour collective rounds.
    """
    if mesh is None:
        if n_shards is None:
            raise ValueError("shard_plan needs a mesh or an explicit n_shards")
        devs = jax.devices()
        if n_shards > len(devs):
            raise ValueError(f"n_shards={n_shards} exceeds {len(devs)} devices")
        mesh = Mesh(np.asarray(devs[:n_shards]), (axis or "node",))
        axis = axis or "node"
    if axis is None:
        if len(mesh.axis_names) != 1:
            raise ValueError(f"mesh has axes {mesh.axis_names}; pass axis=...")
        axis = mesh.axis_names[0]
    if isinstance(axis, (tuple, list)):
        if len(axis) != 1:
            raise ValueError(f"sharded plans need a single node axis, got {axis}")
        axis = axis[0]
    shards = int(mesh.shape[axis])
    if n_shards is not None and n_shards != shards:
        raise ValueError(f"n_shards={n_shards} but mesh axis {axis!r} has {shards}")
    n = plan.n
    if n % shards:
        raise ValueError(f"n={n} nodes not divisible into {shards} shards")
    nps = n // shards

    if plan.backend == "ppermute":
        if nps != 1:
            raise ValueError(
                "the ppermute backend shards one node per device group; use the "
                f"sparse backend for nodes-per-shard {nps} > 1"
            )
        return ShardedCommPlan(base=plan, mesh=mesh, axis=axis, n_shards=shards, nps=nps)
    if plan.backend == "dense":
        return ShardedCommPlan(base=plan, mesh=mesh, axis=axis, n_shards=shards, nps=nps)

    src = np.asarray(plan.src)
    dst = np.asarray(plan.dst)
    uid = np.asarray(plan.edge_uid)
    edge_w = np.asarray(plan.edge_w)
    raw_edge_w = np.asarray(plan.raw_edge_w)
    self_w = np.asarray(plan.self_w)
    raw_self_w = np.asarray(plan.raw_self_w)
    ident = np.arange(len(src), dtype=np.int32)
    recv = _build_layout(
        n, shards, dst, src, uid, edge_w, raw_edge_w, ident, self_w, raw_self_w
    )
    order = np.lexsort((dst, src))  # src-major, dst-minor: the send layout
    send = _build_layout(
        n,
        shards,
        src[order],
        dst[order],
        uid[order],
        edge_w[order],
        raw_edge_w[order],
        ident[order],
        self_w,
        raw_self_w,
    )
    return ShardedCommPlan(
        base=plan,
        mesh=mesh,
        axis=axis,
        n_shards=shards,
        nps=nps,
        recv=recv,
        send=send,
        hyb=_build_hyb_tables(plan, recv, shards),
    )

"""Core contribution of the paper: network-aware uncoordinated initialisation
and DecAvg aggregation for decentralised federated learning."""
from . import (
    commplan,
    compress,
    decavg,
    diffusion,
    faults,
    gossip,
    initialisation,
    membership,
    mixing,
    shardplan,
    topology,
)
from .commplan import (
    BACKENDS,
    CommPlan,
    FailureModel,
    PlanSchedule,
    RoundMap,
    compile_plan,
    compile_schedule,
    cyclic_map,
    sequence_map,
)
from .compress import (
    Compression,
    compressed_mix,
    compressed_mix_with,
    compressed_spread,
    init_residuals,
    seed_residual,
)
from .decavg import (
    failure_receive_matrix,
    link_failure_mask,
    mix_array,
    mix_pytree,
    mix_pytree_circulant,
    mix_pytree_colored,
    mix_pytree_sparse,
    node_failure_mask,
)
from .diffusion import DiffusionResult, run_diffusion, sigma_ap_prediction
from .faults import (
    FaultPlan,
    compose,
    crash_burst,
    hub_outage,
    no_faults,
    partition,
    preemption,
    scenario,
)
from .membership import MembershipSchedule, membership_schedule, poisson_membership
from .shardplan import ShardedCommPlan, shard_plan
from .initialisation import (
    InitConfig,
    gain_from_estimates,
    gain_from_graph,
    scaled_init,
)
from .mixing import (
    mixing_matrix,
    mixing_time_estimate,
    receive_matrix,
    rewire_to_assortativity,
    spectral_gap,
    v_steady,
    v_steady_norm,
    v_steady_norm_closed_form,
    v_steady_norm_from_degree_sample,
)
from .topology import EventBatches, Graph, batch_events_by_color, churn_sequence

"""Simplified numerical model of early-stage DFL dynamics (paper §4.2–4.3).

Each of n nodes holds a d-vector drawn from N(0, σ_init²).  Per iteration:
aggregate with the DecAvg receive operator, then add N(0, σ_noise²) noise
(standing in for the local-training update).  The observables are

    σ_an — mean over parameters of the std *across nodes* (columns of Wᵀ),
    σ_ap — mean over nodes of the std *across parameters* (within a node),

with the §4.3 predictions::

    σ_ap  →  σ_init · ‖v_steady‖      (up to the accumulated-noise floor)
    σ_an  →  O(σ_noise)               after ~ the lazy-walk mixing time.

This model is the mechanism carrier of the paper: it is what justifies the
‖v_steady‖⁻¹ init gain, and it scales to n = thousands on CPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .decavg import mix_array
from .mixing import receive_matrix, v_steady_norm
from .topology import Graph

__all__ = ["DiffusionResult", "run_diffusion", "sigma_ap_prediction"]


@dataclasses.dataclass(frozen=True)
class DiffusionResult:
    sigma_an: np.ndarray  # (rounds+1,)
    sigma_ap: np.ndarray  # (rounds+1,)
    sigma_ap_prediction: float
    v_steady_norm: float


def _sigmas(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """w: (n, d) node-major parameter matrix → (σ_an, σ_ap)."""
    sigma_an = jnp.std(w, axis=0).mean()  # per-parameter spread across nodes
    sigma_ap = jnp.std(w, axis=1).mean()  # per-node spread across parameters
    return sigma_an, sigma_ap


@partial(jax.jit, static_argnames=("rounds",))
def _simulate(m: jax.Array, w0: jax.Array, key: jax.Array, sigma_noise: float, rounds: int):
    def step(carry, k):
        w = carry
        w = mix_array(m, w)
        w = w + sigma_noise * jax.random.normal(k, w.shape)
        return w, _sigmas(w)

    keys = jax.random.split(key, rounds)
    _, (an, ap) = jax.lax.scan(step, w0, keys)
    an0, ap0 = _sigmas(w0)
    return jnp.concatenate([an0[None], an]), jnp.concatenate([ap0[None], ap])


def run_diffusion(
    graph: Graph,
    d: int = 1024,
    sigma_init: float = 1.0,
    sigma_noise: float = 1e-3,
    rounds: int = 200,
    seed: int = 0,
) -> DiffusionResult:
    """Run the §4.2 numerical model and return the σ trajectories."""
    key = jax.random.PRNGKey(seed)
    k0, k1 = jax.random.split(key)
    w0 = sigma_init * jax.random.normal(k0, (graph.n, d))
    m = jnp.asarray(receive_matrix(graph), dtype=jnp.float32)
    an, ap = _simulate(m, w0, k1, sigma_noise, rounds)
    vnorm = v_steady_norm(graph)
    return DiffusionResult(
        sigma_an=np.asarray(an),
        sigma_ap=np.asarray(ap),
        sigma_ap_prediction=sigma_init * vnorm,
        v_steady_norm=vnorm,
    )


def sigma_ap_prediction(graph: Graph, sigma_init: float) -> float:
    """§4.3 closed form: lim σ_ap ≈ σ_init‖v_steady‖ (noise floor excluded)."""
    return sigma_init * v_steady_norm(graph)

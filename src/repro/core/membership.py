"""Elastic membership: nodes that join and leave inside a static envelope.

The paper's population is fixed (§2); real decentralised deployments are not
— nodes arrive, depart, crash, and come back (ROADMAP direction 5; the
coordination-free regime of PAPERS.md 2312.04504).  This module applies the
``PlanSchedule`` padding trick to the **node axis**: the compiled plans and
the executor's scanned round body keep one static shape (the n-node
*envelope*), and membership lowers to per-round boolean masks the
``CommPlan`` operators AND into their failure draws (``active=`` /
``edge_live=``).  A node outside the membership renormalises to the identity
row — it keeps its own model and nobody receives from it — exactly like a
node the Bernoulli failure draw dropped, so all the mass-conservation and
parity machinery carries over unchanged.

Join protocol (uncoordinated, §4.4 applied mid-run):

1. At its **arrival round** a node starts gossiping (``gossip`` mask on):
   it draws fresh Exp(1) sketches and rides ``spread_min`` with the live
   population, re-deriving n̂ online via the leaderless extrema sketches —
   no leader, no barrier, no global round counter shared with anyone.
2. After ``join_warmup`` rounds of estimation the node **initialises**
   (``inits[r]`` one-shot flag): it draws fresh uncoordinated-init params
   with the gain its own n̂ implies and joins training (``active`` mask on).

Departures simply clear both masks from the departure round; a later
re-arrival of the same slot re-runs the join protocol (crash + resume with
amnesia).  Everything is realised host-side into (n_rounds, n) numpy masks:
seeded, deterministic, replayable — the executor scans over device copies.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "MembershipSchedule",
    "membership_schedule",
    "poisson_membership",
]


@dataclasses.dataclass(frozen=True)
class MembershipSchedule:
    """Realised per-round membership masks over the n-node envelope.

    ``active``  (n_rounds, n) bool — node trains and mixes this round.
    ``gossip``  (n_rounds, n) bool — node carries estimation traffic
                (superset of ``active``: joiners gossip during warmup
                before they train).
    ``joins``   (n_rounds, n) bool — one-shot: node (re)drew its sketches
                this round (arrival instant).
    ``inits``   (n_rounds, n) bool — one-shot: node initialises params from
                its online n̂ this round and enters training.
    """

    n: int
    n_rounds: int
    active: np.ndarray
    gossip: np.ndarray
    joins: np.ndarray
    inits: np.ndarray
    join_warmup: int = 8

    def __post_init__(self):
        shape = (self.n_rounds, self.n)
        for f in ("active", "gossip", "joins", "inits"):
            a = getattr(self, f)
            if a.shape != shape or a.dtype != np.bool_:
                raise ValueError(f"{f} must be bool {shape}, got {a.dtype} {a.shape}")
        if np.any(self.active & ~self.gossip):
            raise ValueError("active nodes must gossip (active ⊆ gossip)")

    @property
    def trivial(self) -> bool:
        """No membership dynamics at all — the static executors' regime."""
        return bool(self.active.all() and self.gossip.all()
                    and not self.joins.any() and not self.inits.any())

    def n_active(self) -> np.ndarray:
        """(n_rounds,) live training population per round."""
        return self.active.sum(axis=1).astype(np.int32)


def _check_round(r: int, n_rounds: int, what: str) -> int:
    r = int(r)
    if not 0 <= r < n_rounds:
        raise ValueError(f"{what} round {r} outside [0, {n_rounds})")
    return r


def membership_schedule(
    n: int,
    n_rounds: int,
    *,
    initial: np.ndarray | int | None = None,
    arrivals: dict[int, list[int]] | tuple = (),
    departures: dict[int, list[int]] | tuple = (),
    join_warmup: int = 8,
) -> MembershipSchedule:
    """Lower explicit arrival/departure events into per-round masks.

    ``initial``: the round-0 training membership — a bool/int mask, an int
    (the first ``initial`` node slots), or None (everyone).  ``arrivals`` /
    ``departures`` map round → node ids (dict) or are (round, node) pair
    iterables.  An arriving node gossips from its arrival round and starts
    *training* ``join_warmup`` rounds later (clipped to the horizon: a
    too-late arrival gossips but never trains).  A departure clears both
    masks; the same slot may arrive again later (crash + rejoin, with
    amnesia — it re-runs the join protocol from scratch).
    """
    if n < 1 or n_rounds < 1:
        raise ValueError(f"need n >= 1 and n_rounds >= 1, got {n}, {n_rounds}")
    if isinstance(initial, (int, np.integer)):
        base = np.zeros(n, bool)
        base[: int(initial)] = True
    elif initial is None:
        base = np.ones(n, bool)
    else:
        base = np.asarray(initial, bool)
        if base.shape != (n,):
            raise ValueError(f"initial mask must have shape ({n},), got {base.shape}")

    def _pairs(spec) -> list[tuple[int, int]]:
        if isinstance(spec, dict):
            return [(int(r), int(i)) for r, nodes in spec.items() for i in np.atleast_1d(nodes)]
        return [(int(r), int(i)) for r, i in spec]

    arr = sorted(_pairs(arrivals))
    dep = sorted(_pairs(departures))
    for r, i in arr + dep:
        _check_round(r, n_rounds, "membership event")
        if not 0 <= i < n:
            raise ValueError(f"node {i} outside the {n}-node envelope")
    for r, i in arr:
        if base[i]:
            pre = [(rd, j) for rd, j in dep if j == i and rd <= r]
            if not pre:
                raise ValueError(f"node {i} arrives at round {r} but is already a member")

    active = np.tile(base, (n_rounds, 1))
    gossip = active.copy()
    joins = np.zeros((n_rounds, n), bool)
    inits = np.zeros((n_rounds, n), bool)
    # merge-sort events by round: a departure and a later re-arrival of the
    # same slot compose left to right
    events = sorted([(r, "dep", i) for r, i in dep] + [(r, "arr", i) for r, i in arr])
    for r, kind, i in events:
        if kind == "dep":
            active[r:, i] = False
            gossip[r:, i] = False
        else:
            gossip[r:, i] = True
            joins[r, i] = True
            r_train = r + int(join_warmup)
            if r_train < n_rounds:
                inits[r_train, i] = True
                active[r_train:, i] = True
    return MembershipSchedule(
        n=n, n_rounds=n_rounds, active=active, gossip=gossip,
        joins=joins, inits=inits, join_warmup=int(join_warmup),
    )


def poisson_membership(
    n: int,
    n_rounds: int,
    *,
    initial: int,
    arrival_rate: float = 0.0,
    departure_rate: float = 0.0,
    min_active: int = 2,
    join_warmup: int = 8,
    seed: int = 0,
) -> MembershipSchedule:
    """Seeded stochastic churn: per-round Poisson arrivals fill empty slots,
    per-member Bernoulli departures drain them, floored at ``min_active``
    training members.  A pure function of ``seed`` — host-replayable, like
    ``churn_sequence`` and ``poisson_event_stream``."""
    if not 0 < initial <= n:
        raise ValueError(f"initial membership must be in (0, {n}], got {initial}")
    rng = np.random.default_rng(seed)
    member = np.zeros(n, bool)
    member[:initial] = True
    arrivals: list[tuple[int, int]] = []
    departures: list[tuple[int, int]] = []
    for r in range(n_rounds):
        if departure_rate > 0.0:
            leave = np.nonzero(member & (rng.random(n) < departure_rate))[0]
            for i in leave:
                if member.sum() <= min_active:
                    break
                member[i] = False
                departures.append((r, int(i)))
        if arrival_rate > 0.0:
            k = min(int(rng.poisson(arrival_rate)), int((~member).sum()))
            if k:
                slots = rng.choice(np.nonzero(~member)[0], size=k, replace=False)
                for i in slots:
                    member[i] = True
                    arrivals.append((r, int(i)))
    return membership_schedule(
        n, n_rounds, initial=initial, arrivals=arrivals,
        departures=departures, join_warmup=join_warmup,
    )

"""Production and host-CI mesh construction.

Target hardware: TPU v5e pods — 256 chips/pod as a (data=16, model=16) mesh,
two pods as (pod=2, data=16, model=16).  FL nodes map to the ``data`` axis
(one 16-chip model-parallel slice per node; 32 nodes multi-pod), tensor
parallelism to ``model`` (DESIGN.md §2).

The same functions also serve the 8-host-device CI configuration
(``xla_force_host_platform_device_count=8``): pass an explicit
``n_devices`` and the pod shape scales down instead of pretending to be a
TPU pod, and ``node_mesh`` builds the 1-D node-sharding mesh the sharded
``CommPlan`` rendering (``core.shardplan``, DESIGN.md §15) runs over.

NOTE: functions, not module constants — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS *before* jax init).
"""

from __future__ import annotations

import jax
import numpy as np

__all__ = [
    "NODE_AXIS",
    "N_CHIPS",
    "make_production_mesh",
    "n_fl_nodes",
    "node_axis",
    "node_mesh",
]

N_CHIPS = {"single": 256, "multi": 512}
NODE_AXIS = "node"  # the 1-D node-sharding axis name (host / CI meshes)


def make_production_mesh(*, multi_pod: bool = False, n_devices: int | None = None):
    """The (pod,) data × model mesh.

    Default shapes assume pod hardware (256 / 512 chips).  ``n_devices``
    overrides the total: the model axis shrinks first (data keeps one slice
    per FL node), so e.g. the 8-host-device CI config yields (data=8,
    model=1) without pretending to be a TPU pod.
    """
    if n_devices is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    else:
        pods = 2 if multi_pod else 1
        per_pod = n_devices // pods
        if per_pod < 1 or n_devices % pods:
            raise ValueError(f"n_devices={n_devices} cannot fill {pods} pod(s)")
        data = min(16, per_pod)
        if per_pod % data:
            raise ValueError(
                f"n_devices={n_devices}: per-pod {per_pod} not divisible by data={data}"
            )
        shape = (pods, data, per_pod // data) if multi_pod else (data, per_pod // data)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def node_axis(*, multi_pod: bool = False):
    """The mesh axis (or axes) the FL node dimension shards over."""
    return ("pod", "data") if multi_pod else ("data",)


def n_fl_nodes(*, multi_pod: bool = False, n_devices: int | None = None) -> int:
    """FL node slots on the production mesh (= size of the node axis)."""
    if n_devices is None:
        return 32 if multi_pod else 16
    mesh = make_production_mesh(multi_pod=multi_pod, n_devices=n_devices)
    return int(np.prod([mesh.shape[a] for a in node_axis(multi_pod=multi_pod)]))


def node_mesh(n_shards: int, *, axis: str = NODE_AXIS):
    """A 1-D mesh over the first ``n_shards`` local devices, axis ``"node"``.

    The mesh ``core.shardplan.shard_plan`` / the sharded executor run over on
    hosts and in CI (where ``xla_force_host_platform_device_count`` provides
    the devices); on pods, pass ``make_production_mesh`` + ``node_axis``
    instead.
    """
    devices = jax.devices()
    if n_shards < 1 or n_shards > len(devices):
        raise ValueError(f"n_shards={n_shards} needs 1..{len(devices)} devices")
    return jax.sharding.Mesh(np.asarray(devices[:n_shards]), (axis,))

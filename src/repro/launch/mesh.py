"""Production mesh construction.

Target hardware: TPU v5e pods — 256 chips/pod as a (data=16, model=16) mesh,
two pods as (pod=2, data=16, model=16).  FL nodes map to the ``data`` axis
(one 16-chip model-parallel slice per node; 32 nodes multi-pod), tensor
parallelism to ``model`` (DESIGN.md §2).

NOTE: functions, not module constants — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS *before* jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "node_axis", "N_CHIPS"]

N_CHIPS = {"single": 256, "multi": 512}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def node_axis(*, multi_pod: bool = False):
    """The mesh axis (or axes) the FL node dimension shards over."""
    return ("pod", "data") if multi_pod else ("data",)


def n_fl_nodes(*, multi_pod: bool = False) -> int:
    return 32 if multi_pod else 16

"""Launcher layer: production mesh, sharding rules, step builders, dry-run,
roofline derivation, training/serving CLIs.

NOTE: ``dryrun`` is intentionally NOT imported here — it sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` at import time and
must only be loaded as the entry point of a dedicated process.
"""
from . import mesh, roofline, shardings, steps

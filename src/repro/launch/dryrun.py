import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination:
    lower → compile → memory_analysis (fits?) → cost_analysis + HLO parse
    (roofline terms, §Roofline), with the scan-depth correction of
    launch/roofline.py.

The XLA_FLAGS line above MUST precede any jax import — jax locks the device
count at first init; 512 host devices back both the 256-chip single-pod
mesh and the 2×256 multi-pod mesh.  Smoke tests / benches must NOT import
this module (they want 1 device).

Usage:
    python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--mixing circulant]
    python -m repro.launch.dryrun --all --both-meshes --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.base import get_config, list_archs
from repro.launch import roofline as rl
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import unit_size

# long_500k requires sub-quadratic state (DESIGN.md §4): native runners only
LONG_CONTEXT_ARCHS = {"gemma3_4b", "jamba_1p5_large_398b", "rwkv6_3b"}


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return _norm(arch) in LONG_CONTEXT_ARCHS
    return True


def _norm(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "p")


def run_one(
    arch: str,
    shape: str,
    *,
    multi_pod: bool = False,
    mixing: str = "dense",
    skip_cost_extrapolation: bool = False,
    cfg_override=None,
    variant: dict | None = None,
) -> dict:
    """Lower + compile one combination; return the §Dry-run/§Roofline record.

    ``variant``: §Perf config overrides, e.g. {"attn_impl": "chunked",
    "swa_impl": "blocked", "attn_weight_sharding": "replicate"}.
    """
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if variant:
        cfg = dataclasses.replace(cfg, **variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {
        "arch": cfg.name,
        "shape": shape,
        "mesh": mesh_name,
        "mixing": mixing if shape == "train_4k" else None,
        "variant": variant or {},
        "status": "unknown",
    }
    t0 = time.time()
    try:
        with mesh:
            step, args, in_sh, out_sh = steps_mod.build(
                cfg, shape, mesh, multi_pod=multi_pod, mixing=mixing
            )
            lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            rec["lower_compile_s"] = round(time.time() - t0, 1)
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
            full_terms = rl.terms_from_costs(cost, hlo)
            rec["raw_terms_scan_body_once"] = full_terms.as_dict()

            # ---- scan-depth-corrected roofline terms ------------------
            # XLA cost analysis counts while bodies once; the corrected
            # terms come from small UNROLLED lowerings + exact polynomial
            # extrapolation (launch/roofline.py).
            u = unit_size(cfg)
            tail = cfg.n_layers % u
            n_full = cfg.n_layers // u
            kind = steps_mod.SHAPES[shape].kind
            if skip_cost_extrapolation or n_full <= 2:
                terms = full_terms
            elif kind == "decode":
                # no inner sequence scans on the decode path → depth-only
                sub = []
                for periods in (1, 2):
                    cfg_t = dataclasses.replace(cfg, n_layers=periods * u + tail)
                    step_t, args_t, in_t, out_t = steps_mod.build(
                        cfg_t, shape, mesh, multi_pod=multi_pod, mixing=mixing
                    )
                    comp_t = (
                        jax.jit(step_t, in_shardings=in_t, out_shardings=out_t).lower(*args_t).compile()
                    )
                    sub.append(rl.terms_from_costs(comp_t.cost_analysis(), comp_t.as_text()))
                terms = rl.extrapolate_depth(sub[0], sub[1], n_full)
            else:
                # train/prefill: 6-point (period × seq) fit with unrolled
                # inner chunk scans; costs are exact polynomials in S
                seq_target = steps_mod.SHAPES[shape].seq_len
                points = {}
                # blocked-SWA only activates for S > window: fit above it
                if cfg.swa_impl == "blocked" and cfg.sliding_window >= 256:
                    w = cfg.sliding_window
                    s_points = (2 * w, 4 * w, 8 * w) if 8 * w <= seq_target else (2 * w, 3 * w, 4 * w)
                else:
                    s_points = tuple(s for s in (256, 512, 1024, 2048) if s <= seq_target)
                for periods in (1, 2):
                    for s in s_points:
                        nf_scaled = 0
                        if cfg.n_frontend_tokens:
                            nf_scaled = max(8, (cfg.n_frontend_tokens * s // seq_target) // 8 * 8)
                        cfg_t = dataclasses.replace(
                            cfg,
                            n_layers=periods * u + tail,
                            unroll_scans=True,
                            n_frontend_tokens=nf_scaled,
                        )
                        step_t, args_t, in_t, out_t = steps_mod.build(
                            cfg_t, shape, mesh, multi_pod=multi_pod, mixing=mixing, seq_len=s
                        )
                        comp_t = (
                            jax.jit(step_t, in_shardings=in_t, out_shardings=out_t)
                            .lower(*args_t)
                            .compile()
                        )
                        points[(periods, s)] = rl.terms_from_costs(comp_t.cost_analysis(), comp_t.as_text())
                # frontend tokens scale with S in the fit; correct the target
                # text length implicitly via seq_target evaluation
                terms = rl.extrapolate_depth_and_seq(points, n_full, seq_target)
            rec["terms"] = terms.as_dict()

            # ---- MODEL_FLOPS ratio ------------------------------------
            sh = steps_mod.SHAPES[shape]
            if sh.kind == "train":
                tokens = sh.global_batch * sh.seq_len
            elif sh.kind == "prefill":
                tokens = sh.global_batch * sh.seq_len
            else:
                tokens = sh.global_batch  # ONE new token per sequence
            chips = 512 if multi_pod else 256
            mf = rl.model_flops(cfg.n_active_params(), tokens, sh.kind)
            rec["model_flops"] = mf
            rec["hlo_flops_total"] = terms.flops * chips
            rec["useful_flops_ratio"] = mf / max(terms.flops * chips, 1.0)
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", type=str, default=None)
    p.add_argument("--shape", type=str, default=None, choices=[*steps_mod.SHAPES, None])
    p.add_argument("--all", action="store_true", help="sweep all (arch × applicable shape)")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--mixing", type=str, default="dense", choices=["dense", "circulant"])
    p.add_argument("--out", type=str, default="results/dryrun")
    p.add_argument("--skip-extrapolation", action="store_true")
    p.add_argument("--attn-impl", type=str, default=None, choices=["full", "chunked"])
    p.add_argument("--swa-impl", type=str, default=None, choices=["full", "blocked"])
    p.add_argument("--attn-sharding", type=str, default=None, choices=["auto", "replicate", "qkv_split"])
    p.add_argument("--tag", type=str, default=None, help="suffix for result filenames")
    p.add_argument(
        "--sliding-window", type=int, default=None,
        help="beyond-paper demo: force all layers to sliding-window attention "
        "of this size (enables long_500k for dense archs; DESIGN.md §4)",
    )
    args = p.parse_args()

    variant = {}
    if args.attn_impl:
        variant["attn_impl"] = args.attn_impl
    if args.swa_impl:
        variant["swa_impl"] = args.swa_impl
    if args.attn_sharding:
        variant["attn_weight_sharding"] = args.attn_sharding
    if args.sliding_window:
        variant["block_pattern"] = ("swa",)
        variant["sliding_window"] = args.sliding_window
        variant["max_seq_len"] = 524288

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(steps_mod.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            if not shape_applicable(arch, shape) and "sliding_window" not in variant:
                print(f"SKIP  {arch:28s} {shape:12s} (long-context inapplicable, see DESIGN.md)")
                continue
            for mp in meshes:
                rec = run_one(arch, shape, multi_pod=mp, mixing=args.mixing,
                              skip_cost_extrapolation=args.skip_extrapolation,
                              variant=variant or None)
                mesh_name = rec["mesh"]
                tag = f"{_norm(arch)}__{shape}__{mesh_name}" + (
                    f"__{args.mixing}" if shape == "train_4k" and args.mixing != "dense" else ""
                ) + (f"__{args.tag}" if args.tag else "")
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    t = rec["terms"]
                    extra = (
                        f"dom={t['dominant']:10s} comp={t['compute_s']:.2e}s "
                        f"mem={t['memory_s']:.2e}s coll={t['collective_s']:.2e}s "
                        f"useful={rec['useful_flops_ratio']:.2f}"
                    )
                else:
                    extra = rec["error"][:120]
                print(f"{status.upper():5s} {arch:28s} {shape:12s} {mesh_name:10s} "
                      f"{rec['wall_s']:6.1f}s {extra}", flush=True)


if __name__ == "__main__":
    main()

"""Step functions + input specs for every (architecture × input shape).

This is the deployable SPMD layer: given an arch config, an input shape name
and a mesh, build

    * the jittable step function (fl_train_step or serve prefill/decode),
    * ShapeDtypeStruct stand-ins for every input (no allocation — the same
      abstract-lowering pattern the dry-run mandates),
    * in/out shardings.

Training = one DFL communication round on the production mesh: every FL node
(= one ``data``-axis slice) takes ``local_batches`` gradient steps, then the
ensemble aggregates through a compiled ``CommPlan`` (DESIGN.md §3):

    mixing="dense"      paper-faithful general-graph DecAvg — einsum with
                        the (n, n) receive matrix; GSPMD renders the node-axis
                        contraction as all-gather + local reduce.
    mixing="sparse"     edge-list gather + segment_sum — O(E·d) compute,
                        the large-n backend.
    mixing="ppermute"   edge-coloured collective schedule — one ppermute per
                        colour class inside shard_map, moving degree·|w|
                        instead of n·|w| bytes.  Works for ANY static
                        undirected graph; "circulant" is kept as an alias
                        (the production graph is circulant, for which the
                        colouring recovers the offset schedule).

Serving = consensus model; decode is ONE token against a cache of seq_len.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import topology
from repro.core.commplan import compile_plan
from repro.core.decavg import mix_pytree_colored
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.models import transformer as tfm
from repro.optim import Optimizer, sgd
from . import shardings as shard_rules
from .mesh import n_fl_nodes, node_axis

try:  # jax >= 0.5 exposes shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

PyTree = Any

__all__ = ["SHAPES", "ShapeSpec", "build_train_step", "build_prefill_step", "build_decode_step", "build"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# circulant communication graph used for the production training rounds:
# offsets (1, 2) → random-4-regular-like degree-4 ring, paper §5's default k
# regime, and the collective_permute-friendly topology (DESIGN.md §2)
CIRCULANT_OFFSETS = (1, 2)


def _abstract_params(cfg: ArchConfig, gain: float) -> PyTree:
    icfg = InitConfig("trunc_normal", gain)
    return jax.eval_shape(lambda k: tfm.init_params(k, cfg, icfg), jax.random.PRNGKey(0))


def _token_spec(cfg: ArchConfig, batch: int, seq: int):
    """tokens (+ frontend embeds) for one sequence batch."""
    text_len = seq - cfg.n_frontend_tokens
    out = {"tokens": jax.ShapeDtypeStruct((batch, text_len), jnp.int32)}
    if cfg.frontend and cfg.n_frontend_tokens:
        out["frontend"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_frontend_tokens, cfg.frontend_embed_dim), jnp.bfloat16
        )
    return out


# ===================================================================== train
def build_train_step(
    cfg: ArchConfig,
    mesh,
    *,
    multi_pod: bool = False,
    mixing: str = "dense",
    local_batches: int = 1,
    optimizer: Optimizer | None = None,
    remat: bool = True,
    seq_len: int | None = None,
):
    """Returns (step_fn, example_args, in_shardings, out_shardings)."""
    n = n_fl_nodes(multi_pod=multi_pod)
    node_ax = node_axis(multi_pod=multi_pod)
    # degree-4 circulant at production sizes; complete graph for the tiny
    # meshes used by the integration tests (offsets would degenerate)
    graph = topology.circulant(n, CIRCULANT_OFFSETS) if n >= 5 else topology.complete(n)
    gain = gain_from_graph(graph)
    opt = optimizer or sgd(1e-3, 0.5)
    if mixing == "circulant":  # back-compat alias: colouring ≡ offset schedule
        mixing = "ppermute"
    plan = compile_plan(graph, backend=mixing)

    def loss_fn(params: PyTree, batch: dict) -> jax.Array:
        fe = batch.get("frontend")
        hidden, aux = tfm.forward(params, cfg, batch["tokens"], fe, remat=remat)
        nf = cfg.n_frontend_tokens if (cfg.frontend and fe is not None) else 0
        hidden_text = hidden[..., nf:, :] if nf else hidden
        loss = tfm.lm_loss(params, cfg, hidden_text, batch["targets"])
        return loss + 0.01 * aux

    def local_steps(params, opt_state, batches):
        def one(carry, batch):
            p, s = carry
            loss, grads = jax.value_and_grad(loss_fn)(p, batch)
            upd, s = opt.update(grads, s, p)
            p = jax.tree_util.tree_map(lambda a, u: a + u.astype(a.dtype), p, upd)
            return (p, s), loss

        (params, opt_state), losses = jax.lax.scan(one, (params, opt_state), batches)
        return params, opt_state, losses.mean()

    # ---- abstract inputs ---------------------------------------------
    params = _abstract_params(cfg, gain)
    params = jax.eval_shape(lambda p: jax.tree_util.tree_map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), p), params)
    opt_state = jax.eval_shape(jax.vmap(opt.init), params)
    node_pspecs = shard_rules.with_node_axis(
        shard_rules.param_pspecs(params_strip_node(params), cfg, mesh), node_ax
    )

    def step(params, opt_state, batch):
        params, opt_state, loss = jax.vmap(local_steps)(params, opt_state, batch)
        if plan.backend in ("dense", "sparse"):
            # GSPMD handles both: dense = node-axis all-gather + local
            # contraction, sparse = gather/segment_sum over the node axis
            params = plan.mix(params)
        elif plan.backend == "ppermute":
            ax = node_ax if len(node_ax) > 1 else node_ax[0]
            mix_specs = shard_rules.commplan_in_specs(plan.backend, node_ax)
            mix = _shard_map(
                lambda p, cw, sw: mix_pytree_colored(p, plan.partners, cw, sw, axis_name=ax),
                mesh=mesh,
                in_specs=(node_pspecs, *mix_specs),
                out_specs=node_pspecs,
            )
            params = mix(params, plan.color_w, plan.self_w)
        else:
            raise ValueError(plan.backend)
        opt_state = jax.vmap(opt.init)(params)  # Algorithm 1 line 15
        return params, opt_state, loss.mean()
    per_node = SHAPES["train_4k"].global_batch // n
    seq = seq_len or SHAPES["train_4k"].seq_len
    batch = _token_spec(cfg, per_node, seq)
    batch = {
        k: jax.ShapeDtypeStruct((n, local_batches) + v.shape, v.dtype) for k, v in batch.items()
    }
    text_len = seq - cfg.n_frontend_tokens
    batch["targets"] = jax.ShapeDtypeStruct((n, local_batches, per_node, text_len), jnp.int32)

    # ---- shardings -----------------------------------------------------
    pspecs = node_pspecs
    ospecs = jax.eval_shape(opt.init, params_strip_node(params))
    ospecs = shard_rules.with_node_axis(shard_rules.param_pspecs(ospecs, cfg, mesh), node_ax)
    nax = tuple(node_ax) if len(node_ax) > 1 else node_ax[0]
    bspecs = {k: P(nax, *([None] * (len(v.shape) - 1))) for k, v in batch.items()}
    in_shardings = (
        shard_rules.shardings_for(pspecs, mesh),
        shard_rules.shardings_for(ospecs, mesh),
        shard_rules.shardings_for(bspecs, mesh),
    )
    out_shardings = (in_shardings[0], in_shardings[1], NamedSharding(mesh, P()))
    return step, (params, opt_state, batch), in_shardings, out_shardings


def params_strip_node(params: PyTree) -> PyTree:
    """Drop the leading node dim from abstract param shapes (spec helper)."""
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), params
    )


# ===================================================================== serve
def build_prefill_step(cfg: ArchConfig, mesh, *, multi_pod: bool = False, seq_len: int | None = None):
    shape = SHAPES["prefill_32k"]
    nax = ("pod", "data") if multi_pod else "data"

    def step(params, batch):
        fe = batch.get("frontend")
        hidden, _ = tfm.forward(params, cfg, batch["tokens"], fe, remat=False)
        return tfm.hidden_to_logits(params, cfg, hidden[..., -1:, :])[..., 0, :]

    params = _abstract_params(cfg, 1.0)
    batch = _token_spec(cfg, shape.global_batch, seq_len or shape.seq_len)
    pspecs = shard_rules.param_pspecs(params, cfg, mesh)
    bsize = shape.global_batch
    bdiv = bsize % _ax_size(mesh, nax) == 0
    bspecs = {k: P(nax if bdiv else None, *([None] * (len(v.shape) - 1))) for k, v in batch.items()}
    in_shardings = (shard_rules.shardings_for(pspecs, mesh), shard_rules.shardings_for(bspecs, mesh))
    vdiv = cfg.vocab_size % mesh.shape["model"] == 0
    out_shardings = NamedSharding(mesh, P(nax if bdiv else None, "model" if vdiv else None))
    return step, (params, batch), in_shardings, out_shardings


def build_decode_step(cfg: ArchConfig, mesh, *, shape_name: str = "decode_32k", multi_pod: bool = False):
    shape = SHAPES[shape_name]
    nax = ("pod", "data") if multi_pod else "data"
    b = shape.global_batch
    bdiv = b % _ax_size(mesh, nax) == 0

    def step(params, cache, tokens, pos):
        return tfm.decode_step(params, cfg, cache, tokens, pos)

    params = _abstract_params(cfg, 1.0)
    cache = jax.eval_shape(lambda: tfm.init_cache(cfg, (b,), shape.seq_len))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    pspecs = shard_rules.param_pspecs(params, cfg, mesh)
    batch_axis = ("+".join(nax) if isinstance(nax, tuple) else nax) if bdiv else None
    seq_axis = None if bdiv else ("+".join(nax) if isinstance(nax, tuple) else nax)
    cspecs = shard_rules.cache_pspecs(cache, cfg, mesh, batch_axis=batch_axis, seq_axis=seq_axis)
    tok_spec = P(nax if bdiv else None, None)
    in_shardings = (
        shard_rules.shardings_for(pspecs, mesh),
        shard_rules.shardings_for(cspecs, mesh),
        NamedSharding(mesh, tok_spec),
        NamedSharding(mesh, P()),
    )
    vdiv = cfg.vocab_size % mesh.shape["model"] == 0
    out_shardings = (
        NamedSharding(mesh, P(nax if bdiv else None, None, "model" if vdiv else None)),
        shard_rules.shardings_for(cspecs, mesh),
    )
    return step, (params, cache, tokens, pos), in_shardings, out_shardings


def _ax_size(mesh, nax) -> int:
    if isinstance(nax, tuple):
        return int(np.prod([mesh.shape[a] for a in nax]))
    return mesh.shape[nax]


def build(
    cfg: ArchConfig,
    shape_name: str,
    mesh,
    *,
    multi_pod: bool = False,
    mixing: str = "dense",
    seq_len: int | None = None,
):
    """Dispatch: (arch, shape) → (step_fn, args, in_shardings, out_shardings)."""
    kind = SHAPES[shape_name].kind
    if kind == "train":
        return build_train_step(cfg, mesh, multi_pod=multi_pod, mixing=mixing, seq_len=seq_len)
    if kind == "prefill":
        return build_prefill_step(cfg, mesh, multi_pod=multi_pod, seq_len=seq_len)
    return build_decode_step(cfg, mesh, shape_name=shape_name, multi_pod=multi_pod)

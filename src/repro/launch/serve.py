"""Live-serving launcher: train and serve concurrently under Poisson traffic.

An open-loop Poisson load generator (``--qps``) fires synthetic queries at
the nodes of an event-driven DFL run; gossip and query events ride one
merged envelope through ``fed.serve.run_serve_trajectory``, so one jitted
scan advances training and answers queries with no barrier.  The router
policy (``--router``) decides which node's *current* parameters answer each
query, trading staleness against locality and queueing
(``fed.router.make_router``).

Examples:
    python -m repro.launch.serve --nodes 16 --topology ring --horizon 30 \\
        --qps 8 --router consensus --staleness-budget 2.0
    python -m repro.launch.serve --qps 4 --router uniform \\
        --telemetry /tmp/serve.jsonl
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import topology as T
from repro.core.commplan import FailureModel, compile_plan
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import batch_index_schedule, mnist_like, node_datasets
from repro.fed import init_fl_state, make_eval_fn, make_router, run_serve_trajectory, serve_summary
from repro.fed.router import ROUTER_POLICIES, poisson_query_stream
from repro.models.paper_models import classifier_loss, init_mlp, mlp_forward
from repro.obs.export import history_rows, run_manifest, write_run_log
from repro.optim import sgd

TOPOLOGIES = ("ring", "kreg", "ba", "complete")


def build_graph(name: str, n: int, seed: int) -> T.Graph:
    if name == "ring":
        return T.ring(n)
    if name == "kreg":
        return T.random_k_regular(n, min(8, n - 1), seed=seed)
    if name == "ba":
        return T.barabasi_albert(n, 4, seed=seed)
    if name == "complete":
        return T.complete(n)
    raise ValueError(f"unknown topology {name!r} (choose from {TOPOLOGIES})")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--nodes", type=int, default=16)
    p.add_argument("--topology", type=str, default="ring", choices=TOPOLOGIES)
    p.add_argument("--horizon", type=float, default=30.0, help="virtual-time span (≈ rounds)")
    p.add_argument("--rate", type=float, default=1.0, help="per-edge gossip clock rate")
    p.add_argument("--qps", type=float, default=4.0, help="open-loop query arrival rate")
    p.add_argument("--router", type=str, default="consensus", choices=ROUTER_POLICIES)
    p.add_argument("--staleness-budget", type=float, default=float("inf"))
    p.add_argument("--locality-weight", type=float, default=0.1)
    p.add_argument("--queue-weight", type=float, default=1.0)
    p.add_argument("--service-time", type=float, default=0.2, help="virtual seconds per answer")
    p.add_argument("--hop-latency", type=float, default=0.05, help="virtual seconds per hop")
    p.add_argument("--skew", type=float, default=0.0, help="home-node rank skew (0 = uniform)")
    p.add_argument("--per-node", type=int, default=64)
    p.add_argument("--batch-size", type=int, default=16)
    p.add_argument("--local-batches", type=int, default=2)
    p.add_argument("--bins", type=int, default=10)
    p.add_argument("--link-p", type=float, default=1.0)
    p.add_argument("--test-size", type=int, default=256)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--telemetry", type=str, default=None, help="write a JSONL run log here")
    p.add_argument(
        "--log-queries",
        type=int,
        default=200,
        help="max per-query records in the run log (0 = none)",
    )
    args = p.parse_args()

    n = args.nodes
    graph = build_graph(args.topology, n, args.seed)
    ds = mnist_like(n * args.per_node + args.test_size, seed=args.seed)
    parts = [np.arange(i * args.per_node, (i + 1) * args.per_node) for i in range(n)]
    xs, ys = node_datasets(ds, parts)
    test = (ds.x[-args.test_size :], ds.y[-args.test_size :])
    loss_fn = lambda p_, b: classifier_loss(mlp_forward(p_, b[0]), b[1])  # noqa: E731
    opt = sgd(1e-3, 0.5)
    eval_fn = make_eval_fn(loss_fn)
    gain = gain_from_graph(graph)
    init_one = lambda k: init_mlp(InitConfig("he_normal", gain), k)  # noqa: E731
    state = init_fl_state(jax.random.PRNGKey(args.seed), n, init_one, opt)

    plan = compile_plan(graph, failures=FailureModel(link_p=args.link_p))
    stream = T.poisson_event_stream(graph, horizon=args.horizon, rate=args.rate, seed=args.seed + 1)
    queries = poisson_query_stream(
        n, args.horizon, args.qps, seed=args.seed + 2, pool=args.test_size, skew=args.skew
    )
    router = make_router(
        graph,
        args.router,
        staleness_budget=args.staleness_budget,
        locality_weight=args.locality_weight,
        queue_weight=args.queue_weight,
    )
    sched = batch_index_schedule(
        args.per_node,
        n,
        args.batch_size,
        max(int(args.horizon), 1) * args.local_batches,
        seed=args.seed,
    )
    # answers: the routed node's predicted class for the query image
    serve_fn = lambda p_, x: jnp.argmax(mlp_forward(p_, x[None]), axis=-1)[0]  # noqa: E731

    print(
        f"serving {queries.n_queries} queries (qps={args.qps}) over "
        f"{stream.n_events} gossip events ({args.topology}, n={n}, "
        f"horizon={args.horizon}, router={args.router})"
    )
    t0 = time.time()
    final, hist, serve, aux = run_serve_trajectory(
        state,
        loss_fn,
        opt,
        plan,
        stream,
        queries,
        router,
        xs,
        ys,
        sched,
        b_local=args.local_batches,
        n_bins=args.bins,
        eval_fn=eval_fn,
        eval_batch=test,
        service_time=args.service_time,
        hop_latency=args.hop_latency,
        serve_fn=serve_fn,
        query_xs=test[0],
    )
    wall = time.time() - t0
    summ = serve_summary(serve)
    summ["train_loss_final"] = float(hist["train_loss"][-1])
    summ["test_loss_final"] = float(hist["test_loss"][-1])
    summ["queries_per_sec_wall"] = summ["served"] / max(wall, 1e-9)
    for k, v in summ.items():
        print(f"  {k}: {v:.4g}" if isinstance(v, float) else f"  {k}: {v}")

    if args.telemetry:
        records = [run_manifest(vars(args), seed=args.seed, argv=sys.argv[1:])]
        records += history_rows(hist, kind="bin")
        for i in range(min(len(serve["time"]), max(args.log_queries, 0))):
            records.append(
                {
                    "kind": "query",
                    "time": float(serve["time"][i]),
                    "home": int(serve["home"][i]),
                    "node": int(serve["node"][i]),
                    "latency": float(serve["latency"][i]),
                    "staleness": float(serve["staleness"][i]),
                    "hops": float(serve["hops"][i]),
                    "answer": float(serve["answer"][i]),
                }
            )
        records.append({"kind": "summary", "wall_seconds": wall, **summ})
        n_rec = write_run_log(args.telemetry, records)
        print(f"wrote {n_rec} records to {args.telemetry}")


if __name__ == "__main__":
    main()

"""Serving launcher (CPU-runnable): restore (or train briefly) a consensus
model and serve batched generation requests through the decode path.

Examples:
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --requests 4 --new-tokens 16
    python -m repro.launch.serve --arch rwkv6-3b --reduced --ckpt results/ckpts
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import restore_train_state
from repro.configs import get_reduced_config
from repro.core import topology as T
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import make_token_stream, token_batch_iterator
from repro.fed import consensus_params, generate, init_fl_state, make_round_fn, train_loop
from repro.models import transformer as TF
from repro.optim import adamw


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", type=str, default="qwen2.5-3b")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--requests", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=8)
    p.add_argument("--new-tokens", type=int, default=16)
    p.add_argument("--cache-len", type=int, default=128)
    p.add_argument("--warmup-rounds", type=int, default=15, help="DFL rounds if no checkpoint")
    p.add_argument("--ckpt", type=str, default=None)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    cfg = get_reduced_config(args.arch)
    n_nodes = 8
    graph = T.random_k_regular(n_nodes, 4, seed=args.seed)
    icfg = InitConfig("trunc_normal", gain_from_graph(graph))
    init_one = lambda k: TF.init_params(k, cfg, icfg)

    restored = restore_train_state(args.ckpt) if args.ckpt else None
    if restored is not None:
        node_params, meta = restored
        print(f"restored checkpoint (step {meta.get('step')})")
    else:
        print(f"no checkpoint — warm-starting with {args.warmup_rounds} DFL rounds on synthetic data")
        opt = adamw(3e-3)

        def loss_fn(p_, batch):
            x, y = batch
            hidden, aux = TF.forward(p_, cfg, x)
            return TF.lm_loss(p_, cfg, hidden, y) + 0.01 * aux

        toks = np.stack([make_token_stream(16_000, cfg.vocab_size, seed=i) for i in range(n_nodes)])
        it = token_batch_iterator(toks, batch_size=8, seq_len=48, seed=args.seed)

        def batches():
            while True:
                b = next(it)
                yield (b.x[:, None], b.y[:, None])

        state = init_fl_state(jax.random.PRNGKey(args.seed), n_nodes, init_one, opt)
        state, _ = train_loop(state, make_round_fn(loss_fn, opt, graph), batches(),
                              n_rounds=args.warmup_rounds, eval_every=5, progress=True)
        node_params = state.params

    params = consensus_params(node_params)
    prompts = jnp.asarray(
        [make_token_stream(args.prompt_len * 2, cfg.vocab_size, seed=100 + i)[: args.prompt_len]
         for i in range(args.requests)],
        jnp.int32,
    )
    t0 = time.time()
    out = generate(params, cfg, prompts, n_new=args.new_tokens,
                   cache_len=args.cache_len, temperature=args.temperature,
                   rng=jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    for i in range(args.requests):
        print(f"req{i}: {prompts[i].tolist()} -> {out[i].tolist()}")
    total_new = args.requests * args.new_tokens
    print(f"{total_new} tokens in {dt:.2f}s ({total_new / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()

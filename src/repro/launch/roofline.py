"""Roofline-term derivation from compiled dry-run artifacts (deliverable g).

Hardware constants (TPU v5e target, per brief):
    197 TFLOP/s bf16 / chip,  819 GB/s HBM / chip,  ~50 GB/s / ICI link.

Sources: ``compiled.cost_analysis()`` (per-device FLOPs / bytes — the SPMD
module is one device's program) and the partitioned HLO text for collective
operand bytes (not in cost_analysis).

Scan correction: XLA cost analysis counts a while-loop body ONCE regardless
of trip count, and the stack scans over layer periods.  We therefore lower
two *unrolled* truncations (1 and 2 periods — the model unrolls when
n_full <= 2) and extrapolate:  total(P) = A + (P - 1)·(B - A), where A/B are
the 1-/2-period costs.  The full-depth compile still provides
memory_analysis and proves the real program lowers.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([\d,]*)\]")

__all__ = [
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
    "collective_bytes",
    "RooflineTerms",
    "terms_from_costs",
    "extrapolate_depth",
]


def _shape_bytes(text: str) -> int:
    """Sum the byte sizes of every dtype[shape] literal in ``text``."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%[\w.\-]+")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind operand bytes, parsed from (partitioned) HLO text.

    HLO text elides operand shapes, so we first index every instruction's
    output shape, then sum the referenced operands' bytes for each
    collective.  ``-start`` variants are counted, ``-done`` skipped (same
    transfer).  Collectives inside while bodies appear once — consistent
    with the scan-depth extrapolation applied to all terms.
    """
    shapes: dict[str, int] = {}
    collectives: list[tuple[str, list[str]]] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_text, opcode = m.groups()
        shapes[name] = _shape_bytes(shape_text)
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            # operand list: inside the call parens, before attribute kwargs
            args = line[m.end() - 1 :]
            depth, end = 0, len(args)
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            collectives.append((base, _OPERAND_RE.findall(args[:end])))
    out = {k: 0 for k in _COLLECTIVES}
    for kind, operands in collectives:
        out[kind] += sum(shapes.get(o, 0) for o in operands)
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float  # per-chip
    hbm_bytes: float  # per-chip
    coll_bytes: float  # per-chip
    coll_breakdown: dict[str, int] | None = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s, "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops_per_chip": self.flops,
            "hbm_bytes_per_chip": self.hbm_bytes,
            "collective_bytes_per_chip": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "collective_breakdown": self.coll_breakdown,
        }


def terms_from_costs(cost: dict, hlo_text: str) -> RooflineTerms:
    cb = collective_bytes(hlo_text)
    return RooflineTerms(
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(sum(cb.values())),
        coll_breakdown=cb,
    )


def extrapolate_depth(a: RooflineTerms, b: RooflineTerms, n_periods: int) -> RooflineTerms:
    """total(P) = A + (P-1)·(B-A) from 1-period (A) and 2-period (B) costs."""
    lin = lambda x, y: x + (n_periods - 1) * (y - x)
    cb = None
    if a.coll_breakdown is not None and b.coll_breakdown is not None:
        cb = {k: int(lin(a.coll_breakdown[k], b.coll_breakdown[k])) for k in a.coll_breakdown}
    return RooflineTerms(
        flops=lin(a.flops, b.flops),
        hbm_bytes=lin(a.hbm_bytes, b.hbm_bytes),
        coll_bytes=lin(a.coll_bytes, b.coll_bytes),
        coll_breakdown=cb,
    )


def _nonneg_poly_extrapolate(seqs, vals, seq_target: int) -> float:
    """Evaluate a non-negative-coefficient quadratic fit at seq_target.

    Costs are non-negative combinations of {1, S, S²}; an unconstrained
    interpolation can acquire spurious curvature from alignment/padding
    wiggles that explodes when extrapolating 32× (observed: a linear
    collective term inflated 4×).  Projected least squares: fit deg-2; if
    the S² (then S) coefficient is negative, refit without it.
    """
    import numpy as np

    seqs = np.asarray(seqs, dtype=np.float64)
    vals = np.asarray(vals, dtype=np.float64)
    for cols in ([seqs**2, seqs, seqs * 0 + 1], [seqs, seqs * 0 + 1], [seqs * 0 + 1]):
        a = np.stack(cols, axis=1)
        coef, *_ = np.linalg.lstsq(a, vals, rcond=None)
        if np.all(coef[:-1] >= 0) or len(cols) == 1:
            basis = {3: [seq_target**2, seq_target, 1.0], 2: [seq_target, 1.0], 1: [1.0]}[len(cols)]
            return float(max(0.0, np.dot(coef, basis)))
    raise AssertionError


def extrapolate_depth_and_seq(
    points: dict[tuple[int, int], RooflineTerms], n_periods: int, seq_target: int
) -> RooflineTerms:
    """Fit cost(P, S) = α(S) + P·β(S) with α, β (constrained) quadratic in S.

    ``points`` maps (periods ∈ {1,2}, seq ∈ {s₁..s_k}) → measured terms from
    small *unrolled* lowerings.  Costs are polynomials of S (attention S²,
    everything else linear); k ≥ 3 points + the non-negative-coefficient fit
    keep the 8–32× extrapolation stable against padding wiggles.
    """
    import numpy as np

    seqs = sorted({s for (_, s) in points})
    assert len(seqs) >= 3, seqs

    def fit_metric(get) -> float:
        beta_pts = [get(points[(2, s)]) - get(points[(1, s)]) for s in seqs]
        alpha_pts = [get(points[(1, s)]) - b for s, b in zip(seqs, beta_pts)]
        beta = _nonneg_poly_extrapolate(seqs, beta_pts, seq_target)
        alpha = _nonneg_poly_extrapolate(seqs, alpha_pts, seq_target)
        return max(0.0, alpha + n_periods * beta)

    keys = next(iter(points.values())).coll_breakdown.keys()
    cb = {k: int(fit_metric(lambda t, k=k: t.coll_breakdown[k])) for k in keys}
    return RooflineTerms(
        flops=fit_metric(lambda t: t.flops),
        hbm_bytes=fit_metric(lambda t: t.hbm_bytes),
        coll_bytes=float(sum(cb.values())),
        coll_breakdown=cb,
    )


def model_flops(n_active_params: int, tokens: int, kind: str) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for training, 2·N·D forward-only."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active_params * tokens

"""PartitionSpec assignment for every parameter / state / input tensor.

Rules are path + shape driven (GSPMD-style sharding config, DESIGN.md §8):

* FL node axis            → ``data`` (train shapes) or ``("pod","data")``
* tensor parallelism      → ``model``: attention heads (fallback: head_dim
                            when the head count doesn't divide the axis —
                            qwen1.5's 20H, llama4's 40H), FFN hidden dim,
                            MoE expert dim, vocab (fallback: d_model when
                            vocab doesn't divide — granite's 49155)
* period-stacked layers   → extra leading None (the ``stack`` lists)
* structured scalars      → replicated

Divisibility is checked per tensor: any dim not divisible by the axis size
falls back to replication rather than failing to lower.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

PyTree = Any

__all__ = [
    "param_pspecs",
    "with_node_axis",
    "node_stack_specs",
    "cache_pspecs",
    "commplan_in_specs",
    "shardings_for",
]

_MODEL = "model"


def _div(n: int, size: int) -> bool:
    return n % size == 0


def _leaf_spec(path: tuple, shape: tuple[int, ...], msize: int, replicate_attn: str = "auto") -> P:
    """Logical trailing-dims spec (no node/period prefixes yet)."""
    names = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
    names = [str(n) for n in names]
    leaf = names[-1] if names else ""
    parent = names[-2] if len(names) >= 2 else ""
    gparent = names[-3] if len(names) >= 3 else ""
    rank = len(shape)

    # §Perf variants (cfg.attn_weight_sharding):
    #   "replicate": all attention weights replicated
    #   "qkv_split": K/V projections replicated (they're small for GQA and
    #       their hd-sharding forces (S, S)-score all-reduces), Q/O sharded
    if replicate_attn == "replicate" and "attn" in names:
        return P(*([None] * rank))
    if replicate_attn == "qkv_split" and "attn" in names and parent in ("wk", "wv"):
        return P(*([None] * rank))

    def last2(d0, d1):
        """Spec for the last two dims, padded with Nones on the left."""
        return P(*([None] * (rank - 2)), d0, d1)

    def last1(d0):
        return P(*([None] * (rank - 1)), d0)

    # ---- embeddings / head -------------------------------------------
    if parent == "tok":  # (V, D)
        v, d = shape[-2], shape[-1]
        if _div(v, msize):
            return last2(_MODEL, None)
        return last2(None, _MODEL) if _div(d, msize) else last2(None, None)
    if gparent == "lm_head" or parent == "lm_head":  # (D, V)
        d, v = shape[-2], shape[-1]
        if _div(v, msize):
            return last2(None, _MODEL)
        return last2(_MODEL, None) if _div(d, msize) else last2(None, None)

    # ---- biases / vectors --------------------------------------------
    if leaf == "b" or rank - _n_prefix_dims(names) <= 1:
        d = shape[-1]
        # bias of an output-sharded projection shards with it
        if parent in ("wq", "wk", "wv", "wg", "wr", "w_in", "w_gate", "in_proj", "dt_proj", "wk_c") and _div(d, msize):
            return last1(_MODEL)
        if leaf in ("conv_b", "dt_bias", "d_skip") and _div(d, msize):
            return last1(_MODEL)
        return P(*([None] * rank))

    # ---- MoE expert stacks (E, D, F) / (E, F, D) ----------------------
    if gparent == "ffn" and rank >= 3 and parent in ("w_in", "w_gate", "w_out"):
        e = shape[-3]
        if _div(e, msize):
            return P(*([None] * (rank - 3)), _MODEL, None, None)
        f_dim = -1 if parent in ("w_in", "w_gate") else -2
        if _div(shape[f_dim], msize):
            spec = [None, None, None]
            spec[3 + f_dim] = _MODEL
            return P(*([None] * (rank - 3)), *spec)
        return P(*([None] * rank))
    if parent == "router":
        return P(*([None] * rank))

    # ---- dense 2-D weights -------------------------------------------
    out_sharded = {"wq", "wk", "wv", "wg", "w_in", "w_gate", "in_proj", "dt_proj", "decay_lora_a"}
    in_sharded = {"wo", "w_out", "x_proj", "out_proj", "decay_lora_b"}
    if gparent == "cmix" and parent == "wv":  # rwkv channel-mix wv is (F, D)
        return last2(_MODEL, None) if _div(shape[-2], msize) else last2(None, None)
    if parent in out_sharded or leaf in ("conv_w",):
        return last2(None, _MODEL) if _div(shape[-1], msize) else last2(None, None)
    if parent in in_sharded:
        return last2(_MODEL, None) if _div(shape[-2], msize) else last2(None, None)
    if parent == "wr":  # rwkv receptance: output-sharded
        return last2(None, _MODEL) if _div(shape[-1], msize) else last2(None, None)
    if leaf == "a_log":  # (di, N)
        return last2(_MODEL, None) if _div(shape[-2], msize) else last2(None, None)
    if parent == "frontend_proj" or gparent == "frontend_proj":
        if rank >= 2 and _div(shape[-1], msize):
            return last2(None, _MODEL)
        return P(*([None] * rank))

    # ---- everything else (norm scales, mixes, decay bases, bonus) ----
    return P(*([None] * rank))


def _n_prefix_dims(names: list[str]) -> int:
    """Number of structural leading dims: 1 if under a period-stacked list."""
    return 1 if "stack" in names else 0


def param_pspecs(params: PyTree, cfg: ArchConfig, mesh) -> PyTree:
    """PartitionSpec tree matching ``params`` (consensus / per-node layout)."""
    msize = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a == _MODEL]))
    replicate_attn = getattr(cfg, "attn_weight_sharding", "auto")

    def spec_of(path, leaf):
        s = _leaf_spec(path, leaf.shape, msize, replicate_attn=replicate_attn)
        pad = leaf.ndim - len(s)
        if pad:
            s = P(*([None] * pad), *s)
        return s

    return jax.tree_util.tree_map_with_path(spec_of, params)


def with_node_axis(specs: PyTree, node_ax) -> PyTree:
    """Prepend the FL node axis to every spec (training layout)."""
    ax = tuple(node_ax) if isinstance(node_ax, (tuple, list)) else (node_ax,)
    ax = ax if len(ax) > 1 else ax[0]

    def add(s: P) -> P:
        return P(ax, *tuple(s))

    return jax.tree_util.tree_map(add, specs, is_leaf=lambda x: isinstance(x, P))


def node_stack_specs(tree: PyTree, node_ax) -> PyTree:
    """``P(node_ax, None, ...)`` per leaf of a node-stacked pytree.

    The operand/result specs of the node-sharded renderings (``core
    .shardplan``, the sharded executor): every leaf carries the FL node
    dimension first and only that dimension shards.  Unlike
    ``with_node_axis`` this derives each spec from the leaf's own rank, so
    it applies to arbitrary stacks (params, opt state, metric buffers)
    without a per-tensor rule pass.
    """
    ax = tuple(node_ax) if isinstance(node_ax, (tuple, list)) else (node_ax,)
    ax = ax if len(ax) > 1 else ax[0]
    return jax.tree_util.tree_map(
        lambda l: P(ax, *([None] * (l.ndim - 1))), tree
    )


def commplan_in_specs(backend: str, node_ax) -> tuple[P, ...]:
    """PartitionSpecs for a ``CommPlan``'s explicit operands (DESIGN.md §8).

    Only the ppermute backend passes operands into ``shard_map`` — the
    (n_colors, n) colour weights and (n,) self weights shard along the node
    axis so each node group reads just its own column of the schedule.  The
    dense receive matrix and the sparse edge arrays are closed over as jit
    constants instead: they index the *global* node axis, and GSPMD
    replicates them (inserting the node-axis all-gather the dense baseline
    is defined by), so they have no explicit operand specs.
    """
    if backend != "ppermute":
        return ()
    ax = tuple(node_ax) if isinstance(node_ax, (tuple, list)) else (node_ax,)
    ax = ax if len(ax) > 1 else ax[0]
    return (P(None, ax), P(ax))


def cache_pspecs(cache: PyTree, cfg: ArchConfig, mesh, *, batch_axis: str | None, seq_axis: str | None) -> PyTree:
    """KV/state cache specs.

    decode_32k: batch over ``data``; long_500k (batch=1): the *sequence* dim
    of attention caches shards over ``data`` instead; SSM/conv states shard
    their feature dim over ``model`` when divisible.
    """
    msize = mesh.shape[_MODEL]

    def spec_of(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", ""))) for k in path]
        leafname = names[-1]
        rank = leaf.ndim
        stacked = 1 if "stack" in names else 0
        body = [None] * (rank - stacked)
        # body dims by cache kind:
        if leafname in ("k", "v"):  # (B, T, KVH, hd)
            if batch_axis and leaf.shape[stacked + 0] % _axsize(mesh, batch_axis) == 0:
                body[0] = batch_axis if "+" not in batch_axis else tuple(batch_axis.split("+"))
            elif seq_axis and leaf.shape[stacked + 1] % _axsize(mesh, seq_axis) == 0:
                body[1] = seq_axis if "+" not in seq_axis else tuple(seq_axis.split("+"))
            # KV heads shard over model ONLY when they fill the axis (MHA);
            # GQA kv-heads < axis size stay replicated (Megatron-style) —
            # anything else fights the q-aligned (kvh ⊗ group) einsum
            # sharding and triggers involuntary full rematerialisation.
            if leaf.shape[stacked + 2] % msize == 0:
                body[2] = _MODEL
        elif leafname == "conv":  # (B, dc-1, di)
            if batch_axis and leaf.shape[stacked + 0] % _axsize(mesh, batch_axis) == 0:
                body[0] = batch_axis if "+" not in batch_axis else tuple(batch_axis.split("+"))
            if leaf.shape[stacked + 2] % msize == 0:
                body[2] = _MODEL
        elif leafname == "ssm":  # (B, di, N)
            if batch_axis and leaf.shape[stacked + 0] % _axsize(mesh, batch_axis) == 0:
                body[0] = batch_axis if "+" not in batch_axis else tuple(batch_axis.split("+"))
            if leaf.shape[stacked + 1] % msize == 0:
                body[1] = _MODEL
        elif leafname in ("tshift", "cshift"):  # (B, 1, D)
            if batch_axis and leaf.shape[stacked + 0] % _axsize(mesh, batch_axis) == 0:
                body[0] = batch_axis if "+" not in batch_axis else tuple(batch_axis.split("+"))
            if leaf.shape[stacked + 2] % msize == 0:
                body[2] = _MODEL
        elif leafname == "state":  # (B, H, M, M)
            if batch_axis and leaf.shape[stacked + 0] % _axsize(mesh, batch_axis) == 0:
                body[0] = batch_axis if "+" not in batch_axis else tuple(batch_axis.split("+"))
            elif leaf.shape[stacked + 1] % msize == 0:
                body[1] = _MODEL
        return P(*([None] * stacked), *body)

    return jax.tree_util.tree_map_with_path(spec_of, cache)


def _axsize(mesh, axis: str) -> int:
    if "+" in axis:
        return int(np.prod([mesh.shape[a] for a in axis.split("+")]))
    return mesh.shape[axis]


def shardings_for(specs: PyTree, mesh) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )

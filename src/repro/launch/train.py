"""Training launcher (CPU-runnable end-to-end driver).

Trains the paper's MLP / CNN / reduced-VGG16 — or a reduced zoo arch on
synthetic token data — with the full DFL stack: topology, gain-corrected
uncoordinated init, DecAvg rounds, optimizer-state reinit, checkpointing.

Examples:
    python -m repro.launch.train --model mlp --nodes 16 --rounds 100
    python -m repro.launch.train --model cnn --topology ba --rounds 50
    python -m repro.launch.train --arch qwen2.5-3b --reduced --rounds 30
    # transformer-scale gossip through the fused executor, int8-compressed
    # exchanges (error-feedback mirrors ride the scan carry, DESIGN.md §18)
    python -m repro.launch.train --model transformer --nodes 8 --rounds 20 --compress int8
    python -m repro.launch.train --model mlp --compress topk --topk-frac 0.05
    python -m repro.launch.train --model mlp --no-gain-correction   # Fig.1 baseline
    # truly uncoordinated: per-node gains from on-device gossip estimation,
    # fused estimate→init→train (no host round-trip between phases)
    python -m repro.launch.train --model mlp --topology ba --uncoordinated-init --estimate-rounds 24
    # time-varying topology: train AND estimate over a Markov-churned
    # PlanSchedule (operators switch by round index inside the fused scan)
    python -m repro.launch.train --model mlp --topology kregular --topology-schedule churn \
        --plans 8 --churn-rate 0.2 --uncoordinated-init --leaderless
    # event-driven (no round barrier): per-edge Poisson clocks, pairwise
    # DecAvg exchanges scanned over the realised event stream
    python -m repro.launch.train --model mlp --topology ba --async --event-rate 1.0 \
        --event-horizon 100
    # elastic membership: 4 nodes arrive at round 50, estimate n online, and
    # initialise uncoordinated mid-run; correlated crash burst injected
    python -m repro.launch.train --model mlp --topology kregular --elastic \
        --join-nodes 4 --join-round 50 --fault-scenario crash
    # preemption-safe: checkpoint every chunk, then resume bit-identically
    python -m repro.launch.train --model mlp --rounds 100 --ckpt-dir /tmp/ck --checkpoint-every 1
    python -m repro.launch.train --model mlp --rounds 100 --ckpt-dir /tmp/ck --resume /tmp/ck
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys

import jax
import numpy as np

from repro.checkpoint import save_train_state
from repro.configs import get_reduced_config
from repro.core import topology as T
from repro.core.commplan import CommPlan, FailureModel, compile_plan, compile_schedule, cyclic_map
from repro.core.compress import Compression
from repro.core.faults import SCENARIOS, scenario
from repro.core.membership import membership_schedule
from repro.core.initialisation import InitConfig, gain_from_graph
from repro.data import (
    batch_index_schedule,
    cifar10_like,
    make_token_stream,
    mnist_like,
    node_batch_iterator,
    node_datasets,
    partition_iid,
    partition_zipf,
    so2sat_like,
    token_batch_iterator,
)
from repro.fed import (
    CheckpointPolicy,
    init_fl_state,
    make_eval_fn,
    make_round_fn,
    run_elastic_trajectory,
    run_event_trajectory,
    run_trajectory,
    run_warmup_trajectory,
    train_loop,
)
from repro.gossip import (
    estimate_size_leaderless_events,
    gains_from_estimates,
    make_gain_estimator,
)
from repro.models import transformer as TF
from repro.obs import gossip_health, history_rows, profile_trace, run_manifest, write_run_log
from repro.models.paper_models import classifier_loss, cnn_forward, init_cnn, init_mlp, init_vgg16, mlp_forward, vgg16_forward
from repro.optim import adamw, sgd


def build_graph(kind: str, n: int, seed: int) -> T.Graph:
    return {
        "full": lambda: T.complete(n),
        "kregular": lambda: T.random_k_regular(n, min(4, n - 1 - (n % 2 == 0)), seed=seed)
        if n > 5
        else T.complete(n),
        "ba": lambda: T.barabasi_albert(n, min(8, n // 2), seed=seed),
        "er": lambda: T.erdos_renyi_gnp(n, min(1.0, 6.0 / n), seed=seed),
        "ring": lambda: T.ring(n),
        "circulant": lambda: T.circulant(n, (1, 2)),
    }[kind]()


# --model token archs: reduced zoo configs gossiped through the fused
# executor on windowed synthetic token data (the transformer-scale payloads
# the compressed-gossip codecs exist for)
TOKEN_MODELS = {
    "transformer": "qwen2.5-3b",
    "moe": "granite-moe-1b-a400m",
    "rwkv": "rwkv6-3b",
}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument(
        "--model",
        choices=["mlp", "cnn", "vgg16", *sorted(TOKEN_MODELS)],
        default=None,
    )
    p.add_argument("--arch", type=str, default=None, help="zoo arch id (with --reduced)")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--nodes", type=int, default=16)
    p.add_argument("--rounds", type=int, default=100)
    p.add_argument("--topology", choices=["full", "kregular", "ba", "er", "ring", "circulant"], default="full")
    p.add_argument("--optimizer", choices=["sgd", "adamw"], default="sgd")
    p.add_argument("--items-per-node", type=int, default=256)
    p.add_argument("--batch-size", type=int, default=16)
    p.add_argument("--local-batches", type=int, default=8)
    p.add_argument("--zipf", type=float, default=0.0, help="non-iid Zipf alpha (0 = iid)")
    p.add_argument("--seq-len", type=int, default=64,
                   help="window length for the token --model archs")
    p.add_argument(
        "--compress", choices=["none", "int8", "fp8", "topk", "qtopk"],
        default="none",
        help="compressed gossip (core.compress): quantised / top-k sparsified "
        "exchanges with per-node error-feedback mirrors in the scan carry; "
        "wire-byte telemetry prices the codec's actual encoding "
        "(qtopk = top-k with int8 values, 3 bytes/entry)",
    )
    p.add_argument("--compress-chunk", type=int, default=2048,
                   help="codec chunk: elements per fp32 scale (≤ 65536)")
    p.add_argument("--topk-frac", type=float, default=0.1,
                   help="fraction of each chunk the topk/qtopk codecs transmit")
    p.add_argument("--gamma", type=float, default=None,
                   help="consensus step size of the compressed mix "
                   "(default 1.0; 0.3 for topk/qtopk, which need the damping "
                   "on sparse graphs)")
    p.add_argument("--link-p", type=float, default=1.0)
    p.add_argument("--node-p", type=float, default=1.0)
    p.add_argument(
        "--topology-schedule", choices=["static", "cyclic", "churn"], default="static",
        help="time-varying topology (PlanSchedule): 'cyclic' cycles --plans "
        "independently re-sampled graphs of the chosen family, 'churn' walks "
        "a seeded Markov chain of edge up/down rewirings of the base graph "
        "(--churn-rate); both switch operators by round index inside the "
        "fused scan",
    )
    p.add_argument("--plans", type=int, default=4, help="K: plans in the schedule")
    p.add_argument("--plan-period", type=int, default=1,
                   help="rounds each plan stays active before the schedule advances")
    p.add_argument("--churn-rate", type=float, default=0.1,
                   help="per-snapshot edge resampling probability (churn schedule)")
    p.add_argument("--no-gain-correction", action="store_true")
    p.add_argument(
        "--uncoordinated-init", action="store_true",
        help="per-node gains from on-device gossip estimation (repro.gossip) "
        "instead of the perfect-knowledge gain_from_graph; estimation rides "
        "the same failure-prone links as training",
    )
    p.add_argument("--estimate-rounds", type=int, default=32,
                   help="gossip budget: power-iteration and push-sum rounds each")
    p.add_argument("--estimate-mode", choices=["vnorm", "alpha", "degree"], default="vnorm",
                   help="§4.4 knowledge regime: gossip ‖v̂‖ / size-only n̂^α / degree polling")
    p.add_argument(
        "--leaderless", action="store_true",
        help="size estimation by exponential-random-minimum sketches instead "
        "of the leader one-hot — no distinguished node",
    )
    p.add_argument(
        "--async", action="store_true", dest="async_gossip",
        help="event-driven gossip: no global round barrier — per-edge Poisson "
        "clocks realise an event stream and training/mixing happen pairwise "
        "as edges fire (fed.executor.run_event_trajectory, DESIGN.md §14)",
    )
    p.add_argument("--event-rate", type=float, default=1.0,
                   help="per-edge Poisson clock rate; 1.0 message-budget-matches "
                   "one synchronous round per unit time")
    p.add_argument("--event-horizon", type=float, default=None,
                   help="virtual-time horizon of the event stream (default: --rounds)")
    p.add_argument(
        "--legacy-loop", action="store_true",
        help="per-round dispatch via train_loop instead of the fused executor",
    )
    p.add_argument(
        "--elastic", action="store_true",
        help="elastic membership executor (fed.run_elastic_trajectory, "
        "DESIGN.md §16): nodes join/leave inside the static envelope; implied "
        "by --join-nodes / --fault-scenario",
    )
    p.add_argument("--join-nodes", type=int, default=0,
                   help="hold this many envelope slots out of the initial "
                   "membership; they arrive at --join-round, re-derive n̂ via "
                   "leaderless sketches, and initialise uncoordinated mid-run")
    p.add_argument("--join-round", type=int, default=None,
                   help="arrival round of the joining nodes (default: rounds // 2)")
    p.add_argument("--join-warmup", type=int, default=8,
                   help="estimation rounds between a node's arrival and its init")
    p.add_argument("--fault-scenario", choices=sorted(SCENARIOS), default="none",
                   help="deterministic fault injection (core.faults): correlated "
                   "crash bursts, partitions, hub outages — seeded and replayable")
    p.add_argument("--checkpoint-every", type=int, default=0,
                   help="with --ckpt-dir: snapshot full mid-scan state every N "
                   "chunks (preemption-safe; resume is bit-identical)")
    p.add_argument("--resume", type=str, default=None,
                   help="checkpoint dir or step file to resume the trajectory "
                   "from (replays bit-identical params/metrics)")
    p.add_argument("--chunk-rounds", type=int, default=0, help="executor scan chunk size (0 = auto)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--ckpt-dir", type=str, default=None)
    p.add_argument("--history-out", type=str, default=None)
    p.add_argument("--telemetry", type=str, default=None,
                   help="write a JSONL run log — manifest, one record per "
                   "recorded round/bin, summary, gossip health (repro.obs, "
                   "DESIGN.md §17)")
    p.add_argument("--profile-trace", type=str, default=None,
                   help="capture a jax.profiler trace of the run into this "
                   "directory (named_scope phases: dfl_local / dfl_mix / "
                   "dfl_eval / halo_exchange)")
    p.add_argument("--log-every", type=int, default=0,
                   help="stream recorded metrics every N rounds at chunk "
                   "boundaries instead of printing after the run (fused "
                   "executors; sets the chunk size unless --chunk-rounds is "
                   "given — no extra device syncs beyond the chunk transfer)")
    args = p.parse_args()
    if args.join_nodes > 0 or args.fault_scenario != "none":
        args.elastic = True
    if args.uncoordinated_init and args.no_gain_correction:
        p.error("--uncoordinated-init estimates (and applies) per-node gains; "
                "it contradicts --no-gain-correction — pick one")
    if args.async_gossip:
        if args.arch or args.legacy_loop:
            p.error("--async runs through the event executor — it excludes --arch and --legacy-loop")
        if args.topology_schedule != "static":
            p.error("--async needs a static topology: realise dynamics as per-edge "
                    "clock rates (poisson_event_stream) rather than a PlanSchedule")
        if args.uncoordinated_init and args.estimate_mode == "degree":
            p.error("--async estimation is barrier-free leaderless sketching; "
                    "degree polling needs the round-based walker — drop "
                    "--estimate-mode degree or drop --async")
    if args.elastic:
        if args.async_gossip or args.arch or args.legacy_loop:
            p.error("--elastic runs through the fused elastic executor — it "
                    "excludes --async, --arch, and --legacy-loop")
        if args.uncoordinated_init:
            p.error("--elastic joiners already initialise uncoordinated from "
                    "online n̂ sketches; initial members use the graph gain — "
                    "drop --uncoordinated-init")
        if not 0 <= args.join_nodes < args.nodes:
            p.error(f"--join-nodes must leave at least one initial member "
                    f"(got {args.join_nodes} of {args.nodes})")
        if args.topology_schedule != "static" and "partition" in args.fault_scenario:
            p.error("edge-cut fault scenarios index the base graph's edge list "
                    "— they need --topology-schedule static")
    if args.resume and args.uncoordinated_init and not args.async_gossip:
        p.error("--resume is not supported through the fused warmup phase; "
                "drop --uncoordinated-init (or resume an --elastic run)")
    token_model = args.model in TOKEN_MODELS
    if token_model and args.legacy_loop:
        p.error("token --model archs gather from the precomputed schedule — "
                "they run through the fused executors, not --legacy-loop "
                "(use --arch for the host-driven token path)")
    compress_cfg = None
    if args.compress != "none":
        sparse = args.compress in ("topk", "qtopk")
        gamma = args.gamma if args.gamma is not None else (0.3 if sparse else 1.0)
        compress_cfg = Compression(
            codec=args.compress, chunk=args.compress_chunk,
            topk_frac=args.topk_frac, gamma=gamma,
        )
        print(
            f"compress: {args.compress} chunk={args.compress_chunk} "
            + (f"topk_frac={args.topk_frac} " if sparse else "")
            + f"gamma={gamma:g} "
            f"(~{4.0 / compress_cfg.leaf_row_bytes(args.compress_chunk, np.float32) * args.compress_chunk:.1f}x bytes)"
        )

    n = args.nodes
    graph = build_graph(args.topology, n, args.seed)
    sched_graphs = None
    mix_plan = graph
    if args.topology_schedule != "static":
        if args.topology_schedule == "churn":
            sched_graphs = T.churn_sequence(
                graph, args.plans, args.churn_rate, seed=args.seed + 1
            )
        else:  # cyclic: independently re-sampled graphs of the same family
            sched_graphs = [graph] + [
                build_graph(args.topology, n, args.seed + 101 * t)
                for t in range(1, args.plans)
            ]
        # failures ride in via make_round_fn's link_p/node_p override
        mix_plan = compile_schedule(sched_graphs, round_map=cyclic_map(args.plan_period))
        print(
            f"schedule: {args.topology_schedule} K={mix_plan.k} "
            f"period={args.plan_period}"
            + (f" churn_rate={args.churn_rate}" if args.topology_schedule == "churn" else "")
        )
    gain = 1.0 if args.no_gain_correction else gain_from_graph(graph)
    print(f"graph={graph.name} ‖v_steady‖⁻¹ gain={gain:.2f}" + (" (DISABLED)" if args.no_gain_correction else ""))
    opt = sgd(1e-3, 0.5) if args.optimizer == "sgd" else adamw(1e-3)

    if args.arch:
        cfg = get_reduced_config(args.arch)
        icfg = InitConfig("trunc_normal", gain)
        toks = np.stack([make_token_stream(20_000, cfg.vocab_size, seed=args.seed + i) for i in range(n)])
        it = token_batch_iterator(toks, batch_size=args.batch_size, seq_len=64, seed=args.seed)

        def loss_fn(params, batch):
            x, y = batch
            hidden, aux = TF.forward(params, cfg, x)
            return TF.lm_loss(params, cfg, hidden, y) + 0.01 * aux

        def batches():
            while True:
                bs = [next(it) for _ in range(args.local_batches)]
                yield (np.stack([b.x for b in bs], 1), np.stack([b.y for b in bs], 1))

        init_with = lambda c: (lambda k: TF.init_params(k, cfg, c))
        eval_batch = None
        eval_fn = None
    elif token_model:
        # reduced zoo arch on windowed token data: xs/ys are (n, items, seq)
        # next-token windows, so the fused executors' schedule gather (and
        # the compressed mix riding them) drive a transformer-scale payload
        cfg = get_reduced_config(TOKEN_MODELS[args.model])
        seq, items = args.seq_len, args.items_per_node
        win = (np.arange(items) * seq)[:, None] + np.arange(seq + 1)

        def windows(seed):
            t = make_token_stream(items * seq + 1, cfg.vocab_size, seed=seed)[win]
            return t[:, :-1].astype(np.int32), t[:, 1:].astype(np.int32)

        per_node = [windows(args.seed + i) for i in range(n)]
        xs = np.stack([x for x, _ in per_node])
        ys = np.stack([y for _, y in per_node])
        ex, ey = windows(args.seed + n)  # held-out stream, same window grid
        eval_batch = (ex[:64], ey[:64])
        icfg = InitConfig("trunc_normal", gain)
        init_with = lambda c: (lambda k: TF.init_params(k, cfg, c))

        def loss_fn(params, batch):
            x, y = batch
            hidden, aux = TF.forward(params, cfg, x)
            return TF.lm_loss(params, cfg, hidden, y) + 0.01 * aux

        eval_fn = make_eval_fn(loss_fn)
        d_model = sum(
            int(np.prod(l.shape))
            for l in jax.tree_util.tree_leaves(TF.init_params(jax.random.PRNGKey(0), cfg, icfg))
        )
        print(f"token model {cfg.name}: {d_model / 1e6:.2f}M params/node, seq {seq}")
    else:
        model = args.model or "mlp"
        ds = {"mlp": mnist_like, "cnn": so2sat_like, "vgg16": cifar10_like}[model](
            n * args.items_per_node + 1024, seed=args.seed
        )
        if args.zipf > 0:
            parts = partition_zipf(ds.y[: n * args.items_per_node], n, alpha=args.zipf, seed=args.seed)
        else:
            parts = partition_iid(n * args.items_per_node, n, seed=args.seed)
        xs, ys = node_datasets(ds, parts)
        eval_batch = (ds.x[-1024:], ds.y[-1024:])
        icfg = InitConfig("he_normal", gain)
        if model == "mlp":
            init_with = lambda c: (lambda k: init_mlp(c, k))
            fwd = mlp_forward
        elif model == "cnn":
            init_with = lambda c: (lambda k: init_cnn(c, k, image_shape=ds.x.shape[1:], n_classes=ds.n_classes))
            fwd = cnn_forward
        else:
            init_with = lambda c: (
                lambda k: init_vgg16(c, k, image_shape=ds.x.shape[1:], n_classes=ds.n_classes, width_mult=0.25)
            )
            fwd = vgg16_forward
        loss_fn = lambda p, b: classifier_loss(fwd(p, b[0]), b[1])
        eval_fn = make_eval_fn(loss_fn)

        def batches():
            it = node_batch_iterator(xs, ys, args.batch_size, seed=args.seed)
            while True:
                bs = [next(it) for _ in range(args.local_batches)]
                yield (np.stack([b.x for b in bs], 1), np.stack([b.y for b in bs], 1))

    init_one = init_with(icfg)
    init_one_g = lambda k, gn: init_with(icfg.replace(gain=gn))(k)
    key = jax.random.PRNGKey(args.seed)
    ckpt_policy = None
    if args.ckpt_dir and args.checkpoint_every > 0:
        ckpt_policy = CheckpointPolicy(args.ckpt_dir, every=args.checkpoint_every)
    # the async branch mixes pairwise through its own plan — don't compile a
    # round function (and its O(n²) dense operator) it would never call
    round_fn = (
        None
        if args.async_gossip
        else make_round_fn(
            loss_fn, opt, mix_plan, link_p=args.link_p, node_p=args.node_p,
            compression=compress_cfg,
        )
    )
    eval_every = max(1, args.rounds // 20)
    if args.log_every > 0 and not args.chunk_rounds:
        args.chunk_rounds = args.log_every

    def stream_rows(r0, r1, h):
        # fires at chunk boundaries with the chunk's assembled history slice
        del r0, r1
        for i, r in enumerate(h["round"]):
            line = f"round {r:4d} train {h['train_loss'][i]:.4f}"
            if h.get("test_loss"):
                line += f" test {h['test_loss'][i]:.4f}"
            if h.get("n_active"):
                line += f" active {h['n_active'][i]:3d}"
            if h.get("wire_bytes"):
                line += f" wire {h['wire_bytes'][i]}B"
            print(line, flush=True)

    stream_hook = stream_rows if args.log_every > 0 else None
    profile_ctx = contextlib.ExitStack()
    profile_ctx.enter_context(profile_trace(args.profile_trace))
    estimate_fn = None
    if args.uncoordinated_init and not args.async_gossip:
        # the async branch estimates with barrier-free leaderless sketches
        # over its own event stream instead (below) — don't build (and
        # compile) a round-based estimator it would never call
        # estimation rides the same links — and the same failure model — as
        # the training rounds (unit-weight plan: Eq. 3 send operator); over a
        # topology schedule the gossip itself follows the dynamic graph
        fm = FailureModel(link_p=args.link_p, node_p=args.node_p)
        if sched_graphs is not None:
            est_plan = compile_schedule(
                sched_graphs, failures=fm, round_map=cyclic_map(args.plan_period)
            )
        else:
            est_plan = compile_plan(graph, failures=fm)
        estimate_fn = make_gain_estimator(
            est_plan, pi_rounds=args.estimate_rounds, ps_rounds=args.estimate_rounds,
            mode=args.estimate_mode, leaderless=args.leaderless,
        )
    if args.async_gossip:
        # ---- event-driven path: no round barrier, no estimation barrier ----
        horizon = args.event_horizon if args.event_horizon is not None else float(args.rounds)
        fm = FailureModel(link_p=args.link_p, node_p=args.node_p)
        plan = compile_plan(graph, failures=fm)
        stream = T.poisson_event_stream(
            graph, horizon=horizon, rate=args.event_rate, seed=args.seed + 2
        )
        print(
            f"event stream: {stream.n_events} events over horizon {horizon:g} "
            f"(rate {args.event_rate:g}, {2 * stream.n_events} messages)"
        )
        sched = batch_index_schedule(
            ys.shape[1], n, args.batch_size,
            max(int(horizon), 1) * args.local_batches, seed=args.seed,
        )
        if args.uncoordinated_init:
            # estimation is barrier-free too: leaderless sketches over their
            # own Poisson stream (--estimate-rounds units of virtual time).
            # --estimate-mode vnorm/alpha and --leaderless don't apply here:
            # the event path always sketches (no leader, no phase counter)
            # and gains are n̂^0.5 — the §4.4 size-only knowledge regime
            est_stream = T.poisson_event_stream(
                graph, horizon=float(args.estimate_rounds), rate=args.event_rate,
                seed=args.seed + 3,
            )
            k_est, key = jax.random.split(key)
            n_hat = estimate_size_leaderless_events(plan, est_stream, k_est)
            gains = np.asarray(jax.jit(gains_from_estimates)(n_hat))
            print(
                f"barrier-free leaderless gains (n̂^0.5): mean={gains.mean():.2f} "
                f"min={gains.min():.2f} max={gains.max():.2f}"
            )
            state = init_fl_state(key, n, init_one_g, opt, gains=gains)
        else:
            state = init_fl_state(key, n, init_one, opt)
        state, hist, _aux = run_event_trajectory(
            state, loss_fn, opt, plan, stream, xs, ys, sched,
            b_local=args.local_batches, n_bins=20, eval_fn=eval_fn,
            eval_batch=eval_batch, compression=compress_cfg,
        )
        for i, t in enumerate(hist["time"]):
            print(
                f"t={t:8.1f} train {hist['train_loss'][i]:.4f} "
                f"test {hist['test_loss'][i]:.4f} stale {hist['staleness'][i]:.2f} "
                f"msgs {hist['messages'][i]}", flush=True,
            )
    elif args.arch or args.legacy_loop:
        # token streams sample per-batch windows (no gather schedule yet), so
        # the arch path stays on the host-driven loop
        if estimate_fn is None:
            state = init_fl_state(key, n, init_one, opt)
        else:
            k_est, k_init = jax.random.split(key)
            gains = np.asarray(jax.jit(estimate_fn)(k_est))
            print(f"gossip gains: mean={gains.mean():.2f} min={gains.min():.2f} max={gains.max():.2f}")
            state = init_fl_state(k_init, n, init_one_g, opt, gains=gains)
        state, hist = train_loop(
            state, round_fn, batches(), n_rounds=args.rounds, eval_every=eval_every,
            eval_fn=eval_fn, eval_batch=eval_batch, track_sigmas=True, progress=True,
        )
    else:
        sched = batch_index_schedule(
            ys.shape[1], n, args.batch_size, args.rounds * args.local_batches, seed=args.seed
        )
        common = dict(
            n_rounds=args.rounds, eval_every=eval_every, eval_fn=eval_fn,
            eval_batch=eval_batch, track_sigmas=True, chunk_size=args.chunk_rounds,
            b_local=args.local_batches,
        )
        if args.elastic:
            join_round = args.join_round if args.join_round is not None else args.rounds // 2
            if args.join_nodes:
                mem = membership_schedule(
                    n, args.rounds, initial=n - args.join_nodes,
                    arrivals={join_round: list(range(n - args.join_nodes, n))},
                    join_warmup=args.join_warmup,
                )
                print(
                    f"membership: {n - args.join_nodes} initial, "
                    f"{args.join_nodes} arrive at round {join_round} "
                    f"(warmup {args.join_warmup})"
                )
            else:
                mem = membership_schedule(n, args.rounds)
            faults = (
                None if args.fault_scenario == "none"
                else scenario(args.fault_scenario, graph, args.rounds, seed=args.seed)
            )
            if faults is not None:
                print(f"fault plan: {faults.name} "
                      f"({(~faults.node_up).sum()} node-round outages, "
                      f"{(~faults.edge_up).sum()} edge-round cuts)")
            state = init_fl_state(key, n, init_one, opt)
            state, hist, aux = run_elastic_trajectory(
                state, loss_fn, opt, mix_plan, mem, xs, ys, sched,
                n_rounds=args.rounds, eval_every=eval_every, eval_fn=eval_fn,
                eval_batch=eval_batch, chunk_size=args.chunk_rounds,
                b_local=args.local_batches, init_one=init_one_g, faults=faults,
                checkpoint=ckpt_policy, resume_from=args.resume,
                on_chunk=stream_hook, compression=compress_cfg,
            )
            if stream_hook is None:
                for i, r in enumerate(hist["round"]):
                    print(
                        f"round {r:4d} train {hist['train_loss'][i]:.4f} "
                        f"test {hist['test_loss'][i]:.4f} "
                        f"active {hist['n_active'][i]:3d}", flush=True,
                    )
        elif estimate_fn is None:
            state = init_fl_state(key, n, init_one, opt)
            state, hist = run_trajectory(
                state, round_fn, xs, ys, sched,
                checkpoint=ckpt_policy, resume_from=args.resume,
                on_chunk=stream_hook, **common,
            )
        else:
            # fused warmup: estimate → per-node gain → init → train is one program
            state, hist, gains = run_warmup_trajectory(
                key, round_fn, xs, ys, sched, n_nodes=n, init_one=init_one_g,
                optimizer=opt, estimate_gains=estimate_fn, **common,
            )
            print(f"gossip gains: mean={gains.mean():.2f} min={gains.min():.2f} max={gains.max():.2f}")
        if not args.elastic and (stream_hook is None or estimate_fn is not None):
            # the fused-warmup path has no chunk hook — it prints at the end
            for i, r in enumerate(hist["round"]):
                print(f"round {r:4d} train {hist['train_loss'][i]:.4f} test {hist['test_loss'][i]:.4f}", flush=True)
    profile_ctx.close()
    if args.ckpt_dir and ckpt_policy is None:
        # legacy params-only snapshot; with --checkpoint-every the trajectory
        # checkpoints own the directory (LATEST must stay resume-compatible)
        path = save_train_state(args.ckpt_dir, int(state.round), state.params, meta={"graph": graph.name})
        print(f"checkpoint: {path}")
    if args.history_out:
        os.makedirs(os.path.dirname(args.history_out) or ".", exist_ok=True)
        with open(args.history_out, "w") as f:
            json.dump(hist, f, indent=1)
        print(f"history: {args.history_out}")
    if args.telemetry:
        records = [run_manifest(vars(args), seed=args.seed, argv=sys.argv[1:])]
        records += history_rows(hist, kind="bin" if args.async_gossip else "round")
        summary = {"kind": "summary", "rounds_run": int(state.round)}
        if hist.get("train_loss"):
            summary["final_train_loss"] = hist["train_loss"][-1]
        if hist.get("test_loss"):
            summary["final_test_loss"] = hist["test_loss"][-1]
        if hist.get("wire_messages"):
            summary["recorded_wire_messages"] = int(sum(hist["wire_messages"]))
        elif hist.get("messages"):
            summary["recorded_wire_messages"] = int(sum(hist["messages"]))
        if hist.get("wire_bytes"):
            summary["recorded_wire_bytes"] = int(sum(hist["wire_bytes"]))
        records.append(summary)
        # gossip-health fingerprint of the mixing operator actually used
        if args.async_gossip:
            health_plan = plan
        elif round_fn is not None:
            health_plan = getattr(round_fn, "plan", None)
        else:
            health_plan = None
        if isinstance(health_plan, CommPlan):
            hk = (
                jax.random.PRNGKey(args.seed + 17)
                if health_plan.failures.active else None
            )
            records.append({
                "kind": "gossip_health",
                **gossip_health(health_plan, rounds=min(64, max(16, 2 * n)), key=hk),
            })
        n_rec = write_run_log(args.telemetry, records)
        print(f"telemetry: {args.telemetry} ({n_rec} records)")


if __name__ == "__main__":
    main()

"""Pure-jnp oracle: naive per-token RWKV-6 recurrence via lax.scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rwkv6_ref"]


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array) -> jax.Array:
    """r/k/v/w (BH, L, M), u (BH, M) → out (BH, L, M); fp32 state."""
    bh, l, m = r.shape
    r32, k32, v32, w32 = (jnp.moveaxis(t, 1, 0).astype(jnp.float32) for t in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]  # (BH, M, M)
        out = jnp.einsum("bm,bmn->bn", rt, state + u32[..., :, None] * kv)
        state = state * wt[..., :, None] + kv
        return state, out

    state0 = jnp.zeros((bh, m, m), jnp.float32)
    _, outs = jax.lax.scan(step, state0, (r32, k32, v32, w32))
    return jnp.moveaxis(outs, 0, 1).astype(r.dtype)

from .ops import rwkv6_attention
from .ref import rwkv6_ref

"""Pallas TPU kernel for the RWKV-6 chunked time-mix recurrence.

    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t
    o_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)

TPU-native rendering (DESIGN.md §9): grid (B·H, n_chunks) — chunks innermost
and *sequential*, so the (M, M) fp32 state lives in a VMEM scratch that
carries across chunk steps.  Intra-chunk work is three (chunk × chunk|M)
matmuls on the MXU with cumulative-decay weighting; the mid-chunk-referenced
factorisation (see ``repro.models.rwkv``) keeps exponents inside fp32 range
given the clamped per-step log-decay.

Chunk = 32, M = head_dim (64): score tile 32×32, state 64×64 fp32 = 16 KB —
tiny VMEM footprint; the win over the naive scan is batching the per-token
recurrence into MXU matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rwkv6_chunked"]

DEFAULT_CHUNK = 32


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_scr, *, chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)  # (chunk, M)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (1, M)
    state = state_scr[...]  # (M, M)

    logw = jnp.log(jnp.clip(w, 1e-20, 1.0))
    cum = jnp.cumsum(logw, axis=0)  # (chunk, M) inclusive

    # state-in contribution: r_t W_{t-1} S  (exponent <= 0 — safe)
    rq = r * jnp.exp(cum - logw)
    out = jax.lax.dot_general(rq, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # intra-chunk pairs, mid-referenced factorisation (see models/rwkv.py)
    mid = cum[chunk // 2, :][None, :]
    rq2 = r * jnp.exp(cum - logw - mid)
    kd2 = k * jnp.exp(mid - cum)
    scores = jax.lax.dot_general(rq2, kd2, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(jj < ii, scores, 0.0)
    out = out + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # bonus (current-token) term: (r ⊙ u ⊙ k)·1 per token → scale v
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)  # (chunk, 1)
    out = out + diag * v

    o_ref[0] = out.astype(o_ref.dtype)

    # state update: S' = diag(W_c) S + Σ_s (W_c/W_s ⊙ k_s)ᵀ v_s  (exponents <= 0)
    wc = jnp.exp(cum[chunk - 1, :])  # (M,)
    kfac = k * jnp.exp(cum[chunk - 1, :][None, :] - cum)
    state_scr[...] = state * wc[:, None] + jax.lax.dot_general(
        kfac, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = False,
) -> jax.Array:
    """r/k/v/w (BH, L, M) with w ∈ (0,1); u (BH, M) bonus → out (BH, L, M).

    L is padded to a chunk multiple with w-padding = 1 (no decay from padding).
    """
    bh, l, m = r.shape
    pad = -l % chunk
    if pad:
        pz = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0)))
        r, k, v = pz(r), pz(k), pz(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
    lp = l + pad

    out = pl.pallas_call(
        functools.partial(_rwkv_kernel, chunk=chunk),
        grid=(bh, lp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, m), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, m), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, m), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lp, m), r.dtype),
        scratch_shapes=[pltpu.VMEM((m, m), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return out[:, :l, :]

"""Jit'd public wrapper for the RWKV-6 time-mix kernel.

Accepts the model-zoo layout (..., L, H, M) and flattens (leading, H) into
the kernel's BH grid axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .rwkv import rwkv6_chunked

__all__ = ["rwkv6_attention"]


def rwkv6_attention(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    u: jax.Array,
    *,
    interpret: bool = False,
) -> jax.Array:
    """r/k/v/w (..., L, H, M); u (H, M) → (..., L, H, M)."""
    *lead, l, h, m = r.shape
    fold = lambda t: jnp.moveaxis(t, -2, -3).reshape(-1, l, m)
    rr, kk, vv, ww = fold(r), fold(k), fold(v), fold(w)
    bh = rr.shape[0]
    b = bh // h
    uu = jnp.tile(u, (b, 1))
    out = rwkv6_chunked(rr, kk, vv, ww, uu, interpret=interpret)
    out = out.reshape(tuple(lead) + (h, l, m))
    return jnp.moveaxis(out, -3, -2)

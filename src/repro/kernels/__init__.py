"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage: <name>.py (pl.pallas_call + BlockSpec tiling), ops.py
(jit'd public wrapper), ref.py (pure-jnp oracle).  All validated with
interpret=True on CPU; TPU is the target (DESIGN.md §9).
"""
from .flash import attention_ref, flash_attention
from .mix import decavg_mix, decavg_mix_ref
from .rwkv import rwkv6_attention, rwkv6_ref

"""Pallas TPU kernel for blocked *sparse* DecAvg mixing  Y = M · W, M sparse.

The sparse backend's XLA rendering (gather + ``segment_sum``) moves degree·d
bytes but scatters row-by-row on the VPU.  On TPU the same contraction wants
the MXU, so we lower M to *block*-sparse form (BSR): partition the (n, n)
receive operator into (block_n × block_n) tiles, keep only tiles with any
nonzero, and walk each row-block's tile list with a scalar-prefetched index
map — the W row-block to load is data-dependent, which is exactly what
``PrefetchScalarGridSpec`` exists for (DESIGN.md §9).

Grid: (n_row_blocks, d_blocks, max_tiles_per_row_block); the K loop is
innermost so the fp32 VMEM accumulator lives across it.  Row blocks with
fewer tiles than the max are padded with all-zero tiles pointing at column
block 0 — harmless extra MXU work, no branching.  For the paper's sparse
families (E = O(n)) the tile count per row block is O(1) at production block
sizes, so compute drops from O(n²·d) to O(n·d) like the gather path but at
MXU rates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["bsr_from_dense", "mix_bsr"]

DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_D = 512


def bsr_from_dense(m: np.ndarray, block_n: int) -> tuple[np.ndarray, np.ndarray]:
    """Lower a dense (n, n) operator to padded BSR tiles.

    Returns (block_cols (nrb, max_nnz) int32, tiles (nrb, max_nnz, bn, bn)
    float32).  Rows are padded to the densest row-block with zero tiles at
    column-block 0.  Pure numpy — runs once at plan-compile time, not per
    round.
    """
    m = np.asarray(m, dtype=np.float32)
    n = m.shape[0]
    bn = block_n
    n_pad = -n % bn
    if n_pad:
        m = np.pad(m, ((0, n_pad), (0, n_pad)))
    nb = m.shape[0] // bn
    tiles4 = m.reshape(nb, bn, nb, bn).transpose(0, 2, 1, 3)  # (nrb, ncb, bn, bn)
    nonzero = np.abs(tiles4).sum(axis=(2, 3)) > 0
    max_nnz = max(int(nonzero.sum(axis=1).max()), 1)
    block_cols = np.zeros((nb, max_nnz), dtype=np.int32)
    tiles = np.zeros((nb, max_nnz, bn, bn), dtype=np.float32)
    for i in range(nb):
        cols = np.nonzero(nonzero[i])[0]
        block_cols[i, : len(cols)] = cols
        tiles[i, : len(cols)] = tiles4[i, cols]
    return block_cols, tiles


def _mix_bsr_kernel(bc_ref, m_ref, w_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc[i, j] += tiles[i, k] @ W[bc[i, k], j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        m_ref[0, 0].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def mix_bsr(
    block_cols: jax.Array,
    tiles: jax.Array,
    w: jax.Array,
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Y = M @ W from the BSR form of M; W is (n, d) node-major params.

    ``block_cols``/``tiles`` come from ``bsr_from_dense``; block_n is read off
    the tile shape.  Output rows beyond n (BSR row padding) are sliced away
    by the caller — the padded tiles are zero so they contribute nothing.
    """
    nrb, max_nnz, bn, _ = tiles.shape
    n, d = w.shape
    bd = min(block_d, pl.next_power_of_2(d))
    n_pad = nrb * bn - n
    d_pad = -d % bd
    wp = jnp.pad(w, ((0, n_pad), (0, d_pad)))
    dp_ = d + d_pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nrb, dp_ // bd, max_nnz),
        in_specs=[
            pl.BlockSpec((1, 1, bn, bn), lambda i, j, k, bc: (i, k, 0, 0)),
            pl.BlockSpec((bn, bd), lambda i, j, k, bc: (bc[i, k], j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, k, bc: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
    )
    out = pl.pallas_call(
        _mix_bsr_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrb * bn, dp_), w.dtype),
        interpret=interpret,
    )(block_cols, tiles, wp)
    return out[:n, :d]

"""Jit'd public wrappers for the DecAvg mixing kernels.

``decavg_mix(m, tree)`` mixes a whole node-stacked parameter pytree: leaves
are flattened per node, concatenated, pushed through the blocked kernel and
split back — one big MXU-friendly (n, d_total) product instead of hundreds
of skinny ones.  ``backend="sparse"`` routes the same product through the
block-sparse kernel (BSR lowering of M happens once per distinct operator,
cached on its numpy bytes).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from .mix import mix_matmul
from .sparse import bsr_from_dense, mix_bsr

PyTree = Any

__all__ = ["decavg_mix"]

_BSR_CACHE: dict[tuple[bytes, int], tuple[jax.Array, jax.Array]] = {}


def _bsr_of(m: np.ndarray, block_n: int) -> tuple[jax.Array, jax.Array]:
    key = (m.tobytes(), block_n)
    if key not in _BSR_CACHE:
        bc, tiles = bsr_from_dense(m, block_n)
        _BSR_CACHE[key] = (jnp.asarray(bc), jnp.asarray(tiles))
        if len(_BSR_CACHE) > 64:  # bound the static-operator cache
            _BSR_CACHE.pop(next(iter(_BSR_CACHE)))
    return _BSR_CACHE[key]


def decavg_mix(
    m: jax.Array,
    params: PyTree,
    *,
    backend: str = "dense",
    block_n: int = 128,
    interpret: bool = False,
) -> PyTree:
    """Apply ``w_new[i] = Σ_j M[i,j] w[j]`` to every leaf of a node-stacked
    pytree via the Pallas kernels.  Leaves must share the leading node dim.

    backend="dense" runs the blocked dense kernel; "sparse" lowers M to BSR
    once (requires a concrete, non-traced M — the static-topology case) and
    runs the block-sparse kernel.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params)
    import math

    n = leaves[0].shape[0]
    shapes = [l.shape for l in leaves]
    sizes = [math.prod(s[1:]) for s in shapes]
    if backend == "sparse":
        bc, tiles = _bsr_of(np.asarray(m, np.float32), block_n)
        run = lambda flat: mix_bsr(bc, tiles, flat, interpret=interpret)
    elif backend == "dense":
        run = lambda flat: mix_matmul(m.astype(jnp.float32), flat, interpret=interpret)
    else:
        raise ValueError(f"unknown kernel backend {backend!r}")
    # group by dtype so concatenation is valid; mix each group
    out_leaves: list = [None] * len(leaves)
    by_dtype: dict = {}
    for idx, l in enumerate(leaves):
        by_dtype.setdefault(l.dtype, []).append(idx)
    for dt, idxs in by_dtype.items():
        flat = jnp.concatenate([leaves[i].reshape(n, -1) for i in idxs], axis=1)
        mixed = run(flat)
        off = 0
        for i in idxs:
            sz = sizes[i]
            out_leaves[i] = mixed[:, off : off + sz].reshape(shapes[i])
            off += sz
    return jax.tree_util.tree_unflatten(treedef, out_leaves)

"""Jit'd public wrapper for the DecAvg mixing kernel.

``decavg_mix(m, tree)`` mixes a whole node-stacked parameter pytree: leaves
are flattened per node, concatenated, pushed through the blocked kernel and
split back — one big MXU-friendly (n, d_total) product instead of hundreds
of skinny ones.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .mix import mix_matmul

PyTree = Any

__all__ = ["decavg_mix"]


def decavg_mix(m: jax.Array, params: PyTree, *, interpret: bool = False) -> PyTree:
    """Apply ``w_new[i] = Σ_j M[i,j] w[j]`` to every leaf of a node-stacked
    pytree via the Pallas kernel.  Leaves must share the leading node dim."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    import math

    n = leaves[0].shape[0]
    shapes = [l.shape for l in leaves]
    sizes = [math.prod(s[1:]) for s in shapes]
    # group by dtype so concatenation is valid; mix each group
    out_leaves: list = [None] * len(leaves)
    by_dtype: dict = {}
    for idx, l in enumerate(leaves):
        by_dtype.setdefault(l.dtype, []).append(idx)
    for dt, idxs in by_dtype.items():
        flat = jnp.concatenate([leaves[i].reshape(n, -1) for i in idxs], axis=1)
        mixed = mix_matmul(m.astype(jnp.float32), flat, interpret=interpret)
        off = 0
        for i in idxs:
            sz = sizes[i]
            out_leaves[i] = mixed[:, off : off + sz].reshape(shapes[i])
            off += sz
    return jax.tree_util.tree_unflatten(treedef, out_leaves)

"""Pallas TPU kernel for the DecAvg mixing product  Y = M · W.

W is the node-stacked flattened parameter matrix (n, d) — d is the per-shard
parameter count, typically 10⁶–10⁹/16 — and M is the (n, n) row-stochastic
receive operator (Eq. 2).  On the production mesh the node axis is sharded
over ``data``; after the all-gather (or the circulant ppermute schedule)
each chip runs this kernel over its d-shard.

TPU tiling (DESIGN.md §9): n is small (16–4096) and d huge, so the grid
walks (n-row tiles × d tiles) with a K-loop over n-column tiles innermost.
M tiles live in VMEM (block_n² fp32 ≤ 256 KB), W tiles are (block_n,
block_d) = (128, 512) → 256 KB bf16, and the accumulator is an fp32 VMEM
scratch — everything MXU-aligned at multiples of 128 (lane) / 8 (sublane).
fp32 accumulation is mandatory here: the mixing weights are O(1/k) and the
post-diffusion parameter scale is σ_init·‖v_steady‖ (§4.3) — exactly the
signal bf16 accumulation would truncate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mix_matmul"]

DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_D = 512


def _mix_kernel(m_ref, w_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc[i, j] += M[i, k] @ W[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        m_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def mix_matmul(
    m: jax.Array,
    w: jax.Array,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Y = M @ W with M (n, n) mixing weights, W (n, d) node-major params.

    Pads n up to the row-tile and d up to the lane tile; the padding rows of
    M are zero so padded outputs are zero and sliced away.
    """
    n, d = w.shape
    assert m.shape == (n, n), (m.shape, w.shape)
    bn = min(block_n, pl.next_power_of_2(n))
    bd = min(block_d, pl.next_power_of_2(d))
    n_pad = -n % bn
    d_pad = -d % bd
    mp = jnp.pad(m, ((0, n_pad), (0, n_pad)))
    wp = jnp.pad(w, ((0, n_pad), (0, d_pad)))
    np_, dp_ = n + n_pad, d + d_pad

    out = pl.pallas_call(
        _mix_kernel,
        grid=(np_ // bn, dp_ // bd, np_ // bn),
        in_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bd), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((np_, dp_), w.dtype),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
        interpret=interpret,
    )(mp, wp)
    return out[:n, :d]

"""Pure-jnp oracle for the DecAvg mixing kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["decavg_mix_ref"]


def decavg_mix_ref(m: jax.Array, w: jax.Array) -> jax.Array:
    """Y = M @ W with fp32 accumulation, cast back to w.dtype."""
    out = jnp.einsum(
        "ij,jd->id",
        m.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(w.dtype)

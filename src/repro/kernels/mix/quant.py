"""Fused quantise → block-sparse mix → dequantise Pallas TPU kernel.

The compressed-gossip codecs (``repro.core.compress``) quantise each node's
transmitted row in fixed-size chunks with one fp32 scale per chunk.  Lowered
naively that is three passes over the payload — quantise, mix, dequantise —
each of which streams (n, d) through HBM.  On TPU the whole pipeline fits in
the sparse mixing kernel's inner loop: the W row-block a grid step loads is
exactly one (block_n, block_d) tile, i.e. ``block_d``-element chunks of
``block_n`` source rows, so the kernel quantises the tile *in VMEM* (per-row
absmax over the chunk → scale → round/clip → dequantise) and feeds the MXU
the dequantised fp32 tile directly.  One HBM pass, zero extra buffers; the
quantisation cost rides the same data movement the mix already pays.

Semantics: each *source* node transmits its row quantised per ``block_d``
chunk; every receiver dequantises identically, so the mixed output is
``M @ Q(W)`` with ``Q`` the per-(row, chunk) codec.  A column block referenced
by several row blocks is re-quantised per reference — redundant FLOPs, not
redundant semantics (Q is deterministic).  ``quantised_decavg_mix_ref`` is
the jnp oracle with the same chunk boundaries (d padded to a ``block_d``
multiple; zero padding never raises an absmax, so padded and unpadded chunks
agree on the scale).

Grid and BSR layout are ``sparse.mix_bsr``'s — see that module and
DESIGN.md §9 for the scalar-prefetch walk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["quantised_decavg_mix_ref", "quantised_mix_bsr"]

DEFAULT_BLOCK_D = 512
_INT8_MAX = 127.0
_FP8_MAX = 448.0  # float8_e4m3fn finite max, matches core.compress


def _dequantised(w: jax.Array, codec: str) -> jax.Array:
    """Per-row codec over one (rows, chunk) fp32 tile: Q(w) = deq(quant(w))."""
    amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True)
    if codec == "int8":
        scale = jnp.maximum(amax / _INT8_MAX, 1e-30)
        return jnp.clip(jnp.round(w / scale), -_INT8_MAX, _INT8_MAX) * scale
    if codec == "fp8":
        scale = jnp.maximum(amax / _FP8_MAX, 1e-30)
        q = (w / scale).astype(jnp.float8_e4m3fn)
        return q.astype(jnp.float32) * scale
    raise ValueError(f"unknown kernel codec {codec!r} (int8 | fp8)")


def _quant_mix_kernel(codec, bc_ref, m_ref, w_ref, o_ref, acc_ref):
    """acc[i, j] += tiles[i, k] @ Q(W[bc[i, k], j]) — quantise in VMEM."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    deq = _dequantised(w_ref[...].astype(jnp.float32), codec)
    acc_ref[...] += jnp.dot(
        m_ref[0, 0].astype(jnp.float32), deq, preferred_element_type=jnp.float32
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("codec", "block_d", "interpret"))
def quantised_mix_bsr(
    block_cols: jax.Array,
    tiles: jax.Array,
    w: jax.Array,
    *,
    codec: str = "int8",
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Y = M @ Q(W) from the BSR form of M; W is (n, d) node-major params.

    ``block_cols``/``tiles`` come from ``sparse.bsr_from_dense``; the codec
    chunk IS the kernel's d-block (``block_d`` elements per scale).  Output
    rows beyond n (BSR row padding) are sliced away like ``mix_bsr``.
    """
    if codec not in ("int8", "fp8"):
        raise ValueError(f"unknown kernel codec {codec!r} (int8 | fp8)")
    nrb, max_nnz, bn, _ = tiles.shape
    n, d = w.shape
    bd = min(block_d, pl.next_power_of_2(d))
    n_pad = nrb * bn - n
    d_pad = -d % bd
    wp = jnp.pad(w, ((0, n_pad), (0, d_pad)))
    dp_ = d + d_pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nrb, dp_ // bd, max_nnz),
        in_specs=[
            pl.BlockSpec((1, 1, bn, bn), lambda i, j, k, bc: (i, k, 0, 0)),
            pl.BlockSpec((bn, bd), lambda i, j, k, bc: (bc[i, k], j)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j, k, bc: (i, j)),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_quant_mix_kernel, codec),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nrb * bn, dp_), w.dtype),
        interpret=interpret,
    )(block_cols, tiles, wp)
    return out[:n, :d]


def quantised_decavg_mix_ref(
    m: jax.Array,
    w: jax.Array,
    *,
    codec: str = "int8",
    block_d: int = DEFAULT_BLOCK_D,
) -> jax.Array:
    """jnp oracle: M @ Q(W) with the kernel's exact chunking.

    d is padded to a ``block_d`` multiple before chunking so the scale of the
    last chunk matches what the kernel's padded tile computes (zero padding
    never changes an absmax).
    """
    n, d = w.shape
    bd = min(block_d, pl.next_power_of_2(d))
    d_pad = -d % bd
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, d_pad)))
    chunks = wp.reshape(n, (d + d_pad) // bd, bd)
    deq = _dequantised(chunks, codec).reshape(n, d + d_pad)[:, :d]
    out = jnp.einsum(
        "ij,jd->id", m.astype(jnp.float32), deq, preferred_element_type=jnp.float32
    )
    return out.astype(w.dtype)

from .ops import decavg_mix
from .ref import decavg_mix_ref

from .ops import decavg_mix
from .ref import decavg_mix_ref
from .sparse import bsr_from_dense, mix_bsr

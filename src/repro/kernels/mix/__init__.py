from .ops import decavg_mix
from .quant import quantised_decavg_mix_ref, quantised_mix_bsr
from .ref import decavg_mix_ref
from .sparse import bsr_from_dense, mix_bsr

"""Pure-jnp oracle for flash attention (mirrors models/attention math)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, window: int = 0
) -> jax.Array:
    """q (B, H, S, hd); k/v (B, KVH, S, hd) → (B, H, S, hd). fp32 softmax."""
    b, h, s, hd = q.shape
    kvh = k.shape[1]
    group = h // kvh
    qg = q.reshape(b, kvh, group, s, hd)
    scores = jnp.einsum("bngsd,bntd->bngst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / (hd**0.5)
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = mask & (j <= i)
    if window > 0:
        mask = mask & (j > i - window)
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,bntd->bngsd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, s, hd).astype(q.dtype)

"""Jit'd public wrapper: layout adaptation for the flash attention kernel.

The model zoo keeps activations (B, S, H, hd); the kernel wants (B, H, S, hd)
(sequence minor-most-but-one so q/kv tiles are contiguous VMEM loads).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash import flash_mha

__all__ = ["flash_attention"]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    interpret: bool = False,
) -> jax.Array:
    """q (B, S, H, hd); k/v (B, S, KVH, hd) → (B, S, H, hd)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_mha(qt, kt, vt, causal=causal, window=window, interpret=interpret)
    return jnp.swapaxes(out, 1, 2)

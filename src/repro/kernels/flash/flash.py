"""Pallas TPU flash attention (online softmax), GQA + sliding-window aware.

Grid: (batch, q_heads, q_blocks, kv_blocks) — kv innermost so the running
max/denominator/accumulator scratch carries across kv steps of one q tile.
Blocks: q 128 × kv 128 × head_dim — MXU-aligned; K/V tiles are indexed to
the GQA group's kv head (q head h reads kv head h // group).

Sliding-window layers (gemma3 locals, SWA variants) mask per element AND
skip fully-out-of-range kv blocks with ``pl.when`` — the early-exit that
makes local attention O(S·window) instead of O(S²).

fp32 softmax state; output flushed once at the last kv block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_mha"]

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, window, block_q, block_k, seq_len
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (block_q, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (block_k, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (block_q, block_k)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask = mask & (kpos <= qpos)
        if window > 0:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, _NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    # block-level early exit: skip kv tiles entirely above the causal
    # diagonal or entirely left of the sliding window
    if causal or window > 0:
        needed = k_start <= q_start + block_q - 1 if causal else k_start == k_start
        if window > 0:
            needed = needed & (k_start + block_k - 1 > q_start - window)
        pl.when(needed)(_compute)
    else:
        _compute()

    @pl.when(ki == nk - 1)
    def _flush():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    """q (B, H, S, hd); k/v (B, KVH, S, hd); H % KVH == 0 → (B, H, S, hd)."""
    b, h, s, hd = q.shape
    kvh = k.shape[1]
    assert h % kvh == 0, (h, kvh)
    group = h // kvh
    scale = 1.0 / (hd**0.5)

    bq = min(block_q, pl.next_power_of_2(s))
    bk = min(block_k, pl.next_power_of_2(s))
    pad = -s % max(bq, bk)
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    else:
        qp, kp, vp = q, k, v
    sp = s + pad

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=bq,
        block_k=bk,
        seq_len=s,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, sp // bq, sp // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :s, :]

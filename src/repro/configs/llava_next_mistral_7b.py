"""llava-next-mistral-7b [vlm]: Mistral-7B text backbone — 32L, d_model
4096, 32H GQA(kv=8), d_ff 14336, vocab 32000 — consuming anyres-tiled
vision patch embeddings through a learned projector.
Source: [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Frontend stub (DESIGN.md §5): the CLIP-ViT-L/14-336 encoder is NOT
implemented; ``input_specs`` supplies (batch, n_patches, 1024) precomputed
patch embeddings (anyres: base 576 + 4 tiles × 576 = 2880 tokens).
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    norm="rmsnorm",
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    max_seq_len=32768,
    frontend="vision",
    n_frontend_tokens=2880,  # anyres: 576 base + 4×576 tiles
    frontend_embed_dim=1024,  # CLIP-ViT-L/14 hidden size
    notes="text tokens per shape = seq_len - 2880; long_500k skipped "
    "(full attention).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=256,
        n_frontend_tokens=8,
        frontend_embed_dim=32,
        dtype="float32",
    )

"""qwen1.5-4b [dense]: 40L, d_model 2560, 20H MHA(kv=20), d_ff 6912,
vocab 151936, QKV bias.  Source: [hf:Qwen/Qwen1.5-0.5B family card,
scaled per assignment].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    norm="rmsnorm",
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    max_seq_len=32768,
    notes="20 heads do not divide the 16-way model axis → attention "
    "shards on head_dim instead (launch/shardings.py). long_500k skipped "
    "(full attention).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=120,
        n_heads=4,
        n_kv_heads=4,
        head_dim=30,
        d_ff=256,
        vocab_size=512,
        max_seq_len=256,
        dtype="float32",
    )

"""Architecture configs: the 10 assigned architectures + the paper's own."""
from .base import ArchConfig, ffn_kinds, get_config, get_reduced_config, layer_kinds, list_archs

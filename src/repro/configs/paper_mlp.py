"""Paper cfg. A/D (Appendix A, Table A1): MLP 784→512→256→128→10, ReLU,
MNIST-like data, full communication network."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="paper-mlp",
    family="paper",
    source="paper Appendix A (cfg A/D)",
    n_layers=4,
    d_model=512,
    d_ff=0,
    vocab_size=0,
    notes="image classifier; see repro.models.paper_models.init_mlp",
)


def reduced() -> ArchConfig:
    return CONFIG  # already CPU-scale

"""gemma3-4b [dense]: 34L, d_model 2560, 8H GQA(kv=4), d_ff 10240,
vocab 262144, 5:1 local(1024-window):global attention, 128k context.
Source: [hf:google/gemma-3-1b-pt family card, scaled per assignment].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,  # gemma3 fixed head_dim (not d_model // n_heads)
    d_ff=10240,
    vocab_size=262144,
    block_pattern=("swa", "swa", "swa", "swa", "swa", "attn"),  # 5:1 local:global
    sliding_window=1024,
    norm="rmsnorm",
    mlp_type="geglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq_len=131072,
    notes="34 = 5 full (swa×5+attn) units + 4 tail layers; long_500k runs "
    "natively: swa layers keep a ring-buffer window cache, global layers a full cache.",
)


def reduced() -> ArchConfig:
    """Smoke variant: same family (5:1 swa:attn, GQA, GeGLU, tied embed)."""
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        block_pattern=("swa", "attn"),
        sliding_window=16,
        max_seq_len=256,
        dtype="float32",
    )

"""musicgen-large [audio]: decoder-only transformer over EnCodec tokens —
48L, d_model 2048, 32H (MHA, kv=32), d_ff 8192, vocab 2048 (EnCodec
codebook).  Source: [arXiv:2306.05284].

Frontend stub (DESIGN.md §5): the EnCodec conv codec + T5 text conditioner
are NOT implemented; ``input_specs`` supplies (batch, n_cond, 1024)
precomputed conditioning embeddings prepended to the token stream; the
modelled stream is one codebook (the delay-pattern interleave collapses to
a flat stream for shape purposes).
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    norm="layernorm",
    mlp_type="gelu_mlp",
    rope_theta=10000.0,
    max_seq_len=32768,
    frontend="audio",
    n_frontend_tokens=256,  # conditioning embeddings (T5-large width)
    frontend_embed_dim=1024,
    notes="long_500k skipped (full attention). Decode shapes model "
    "autoregressive EnCodec-token generation.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=4,
        head_dim=32,
        d_ff=256,
        vocab_size=256,
        max_seq_len=256,
        n_frontend_tokens=8,
        frontend_embed_dim=32,
        dtype="float32",
    )

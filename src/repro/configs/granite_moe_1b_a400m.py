"""granite-moe-1b-a400m [moe]: 24L, d_model 1024, 16H GQA(kv=8), expert
d_ff 512, vocab 49155, MoE 32 experts top-8 at every layer.
Source: [hf:ibm-granite/granite-3.0-1b-a400m-base].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,  # per-expert hidden dim
    vocab_size=49155,
    n_experts=32,
    experts_per_token=8,
    moe_period=1,
    norm="rmsnorm",
    mlp_type="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    max_seq_len=4096,
    notes="vocab 49155 is not divisible by the 16-way model axis → the "
    "embedding shards on d_model instead (launch/shardings.py).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=512,
        n_experts=4,
        experts_per_token=2,
        max_seq_len=256,
        dtype="float32",
    )

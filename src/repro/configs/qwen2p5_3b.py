"""qwen2.5-3b [dense]: 36L, d_model 2048, 16H GQA(kv=2), d_ff 11008,
vocab 151936, QKV bias.  Source: [hf:Qwen/Qwen2.5-0.5B family card,
scaled per assignment].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    norm="rmsnorm",
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq_len=32768,
    notes="long_500k: native attention is quadratic-state → skipped at "
    "native config; a beyond-paper SWA-variant demo is recorded separately "
    "(see swa_variant()).",
)


def swa_variant(window: int = 8192) -> ArchConfig:
    """Beyond-paper sliding-window override enabling long_500k decode."""
    return dataclasses.replace(
        CONFIG,
        name="qwen2.5-3b-swa",
        block_pattern=("swa",),
        sliding_window=window,
        max_seq_len=524288,
        notes="demonstration variant: all layers sliding-window",
    )


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        max_seq_len=256,
        dtype="float32",
    )

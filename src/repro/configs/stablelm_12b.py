"""stablelm-12b [dense]: 40L, d_model 5120, 32H GQA(kv=8), d_ff 13824,
vocab 100352.  Source: [hf:stabilityai/stablelm-2-1_6b family card,
scaled per assignment].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    norm="layernorm",  # stablelm-2 uses LayerNorm (no bias on qkv)
    mlp_type="swiglu",
    rope_theta=10000.0,
    max_seq_len=4096,
    notes="long_500k skipped (full attention, no sub-quadratic variant).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=160,
        n_heads=4,
        n_kv_heads=2,
        head_dim=40,
        d_ff=320,
        vocab_size=512,
        max_seq_len=256,
        dtype="float32",
    )

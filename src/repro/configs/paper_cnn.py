"""Paper cfg. B (Appendix A): CNN (32/64/64 ch 3×3) + FC 128/64/17,
So2Sat-like data, BA(m=8) network, Zipf α=1.8."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paper-cnn",
    family="paper",
    source="paper Appendix A (cfg B)",
    n_layers=5,
    d_model=64,
    d_ff=0,
    vocab_size=0,
    notes="image classifier; see repro.models.paper_models.init_cnn",
)


def reduced() -> ArchConfig:
    return CONFIG

"""Architecture configuration schema + registry.

Each assigned architecture gets one module in this package defining
``CONFIG`` (the exact full-size spec, with the source citation) and
``reduced()`` (the CPU smoke-test variant: ≤2 layers, d_model ≤ 512,
≤4 experts).  ``repro.configs.registry`` maps ``--arch <id>`` to both.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

import jax.numpy as jnp

__all__ = ["ArchConfig", "get_config", "get_reduced_config", "list_archs", "layer_kinds", "ffn_kinds"]

Family = Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio", "paper"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    source: str  # citation: hf model card or arXiv id

    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0  # 0 => attention-free
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 => derive d_model // n_heads
    qkv_bias: bool = False

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1  # layer j is MoE iff (j % moe_period == moe_offset) and n_experts > 0
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # --- layer pattern ---
    block_pattern: tuple[str, ...] = ("attn",)  # cycled over layers: attn|swa|mamba|rwkv
    sliding_window: int = 0  # window size for "swa" blocks

    # --- misc structure ---
    norm: str = "rmsnorm"
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu_mlp
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    max_seq_len: int = 131072

    # --- ssm ---
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_dim: int = 64

    # --- modality frontend stub (DESIGN.md §5) ---
    frontend: str = ""  # "" | "vision" | "audio"
    n_frontend_tokens: int = 0  # patch/frame embeddings prepended to the text stream
    frontend_embed_dim: int = 0  # raw embedding dim before the learned projector

    dtype: str = "bfloat16"
    notes: str = ""
    # roofline instrumentation: unroll inner sequence-chunk scans (mamba,
    # rwkv) so XLA cost analysis counts every chunk — used by the dry-run's
    # 1-/2-period cost lowerings only (launch/roofline.py); the production
    # compile keeps lax.scan
    unroll_scans: bool = False
    # beyond-paper §Perf knobs (baseline = "full"):
    #   attn_impl  "full"    materialise (S, S) scores (XLA default)
    #              "chunked" flash-style q-chunked online softmax — O(c·S)
    #                        live scores instead of O(S²)
    #   swa_impl   "full"    windowed layers still compute (S, S) scores
    #              "blocked" band attention: each w-block attends to
    #                        [prev, self] blocks — O(S·2w) compute + memory
    attn_impl: str = "full"
    swa_impl: str = "full"
    #   attn_weight_sharding  "auto"      shard flat head dims over model
    #                                     (falls back to head_dim slices when
    #                                     heads don't divide the axis)
    #                         "replicate" keep attention weights replicated —
    #                         avoids the score all-reduce that hd-sharding
    #                         induces for small-head archs (gemma3's 8 heads)
    attn_weight_sharding: str = "auto"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.n_heads == 0:
            return 0
        return self.head_dim or self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, f = self.d_model, self.d_ff
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        kinds = layer_kinds(self)
        fkinds = ffn_kinds(self)
        hd = self.resolved_head_dim
        for kind, fk in zip(kinds, fkinds):
            if kind in ("attn", "swa"):
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                if self.qkv_bias:
                    total += hd * (self.n_heads + 2 * self.n_kv_heads)
            elif kind == "mamba":
                di = self.mamba_expand * d
                total += d * 2 * di + di * self.mamba_d_conv + di * (2 * self.mamba_d_state + 1) + di * self.mamba_d_state + di + di * d
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,g,o projections
                total += d * (self.d_ff + 1) + self.d_ff * d  # channel mix (approx; k->f, r gate, v back)
            if kind != "rwkv":  # rwkv folds its FFN into channel-mix above
                n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
                if fk == "moe":
                    total += d * self.n_experts + self.n_experts * n_mats * d * f
                else:
                    total += n_mats * d * f
            total += 2 * d  # norms
        return total

    def n_active_params(self) -> int:
        """Params touched per token (MoE counts top-k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        n_mats = 3 if self.mlp_type in ("swiglu", "geglu") else 2
        inactive = 0
        for fk in ffn_kinds(self):
            if fk == "moe":
                inactive += (self.n_experts - self.experts_per_token) * n_mats * d * f
        return self.n_params() - inactive


def layer_kinds(cfg: ArchConfig) -> list[str]:
    """Block kind per layer: the pattern is cycled (gemma3 5 swa : 1 attn,
    jamba 7 mamba : 1 attn, ...)."""
    pat = cfg.block_pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def ffn_kinds(cfg: ArchConfig) -> list[str]:
    """FFN kind per layer: "moe" or "dense" ("none" for rwkv blocks which
    carry their own channel-mix)."""
    out = []
    for j, kind in enumerate(layer_kinds(cfg)):
        if kind == "rwkv":
            out.append("none")
        elif cfg.is_moe and (j % cfg.moe_period == cfg.moe_offset):
            out.append("moe")
        else:
            out.append("dense")
    return out


# ----------------------------------------------------------------------
_ASSIGNED = [
    "gemma3_4b",
    "granite_moe_1b_a400m",
    "jamba_1p5_large_398b",
    "qwen2p5_3b",
    "llava_next_mistral_7b",
    "stablelm_12b",
    "musicgen_large",
    "qwen1p5_4b",
    "rwkv6_3b",
    "llama4_scout_17b_a16e",
]
_PAPER = ["paper_mlp", "paper_cnn", "paper_vgg16"]

_ALIASES = {
    "gemma3-4b": "gemma3_4b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "qwen2.5-3b": "qwen2p5_3b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "stablelm-12b": "stablelm_12b",
    "musicgen-large": "musicgen_large",
    "qwen1.5-4b": "qwen1p5_4b",
    "rwkv6-3b": "rwkv6_3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
}


def _module(arch: str):
    mod = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def get_reduced_config(arch: str) -> ArchConfig:
    return _module(arch).reduced()


def list_archs(include_paper: bool = False) -> list[str]:
    return list(_ASSIGNED) + (list(_PAPER) if include_paper else [])

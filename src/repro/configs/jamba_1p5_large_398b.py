"""jamba-1.5-large-398b [hybrid]: 72L, d_model 8192, 64H GQA(kv=8),
d_ff 24576, vocab 65536; Mamba:attention 7:1 interleave; MoE 16 experts
top-2 at every other layer.  Source: [arXiv:2403.19887].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    experts_per_token=2,
    moe_period=2,
    moe_offset=1,
    block_pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    norm="rmsnorm",
    mlp_type="swiglu",
    rope_theta=10000.0,
    max_seq_len=262144,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    notes="unit = 8 layers (1 attn + 7 mamba, 4 MoE); 72 = 9 units. "
    "long_500k runs natively: mamba layers carry O(1) state; the 9 attn "
    "layers keep full KV caches (9×500k×8×128).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        d_ff=256,
        vocab_size=512,
        n_experts=4,
        experts_per_token=2,
        moe_period=2,
        moe_offset=1,
        block_pattern=("attn", "mamba"),
        max_seq_len=256,
        mamba_d_state=8,
        dtype="float32",
    )

"""llama4-scout-17b-a16e [moe]: 48L, d_model 5120, 40H GQA(kv=8),
expert d_ff 8192, vocab 202048, MoE 16 experts top-1, early-fusion
multimodal.  Source: [hf:meta-llama/Llama-4-Scout-17B-16E].

Early fusion: the arch supports a vision frontend (projector initialised)
but the assigned input shapes are text-token streams, so
``n_frontend_tokens = 0`` in the specs (DESIGN.md §5).
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    experts_per_token=1,
    moe_period=1,
    norm="rmsnorm",
    mlp_type="swiglu",
    rope_theta=500_000.0,
    max_seq_len=262144,
    frontend="vision",
    n_frontend_tokens=0,  # early-fusion capable; assigned shapes are text
    frontend_embed_dim=1408,
    notes="40 heads do not divide the 16-way model axis → attention "
    "shards on head_dim (launch/shardings.py). long_500k skipped (full "
    "attention at native config).",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        n_heads=4,
        n_kv_heads=2,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        n_experts=4,
        experts_per_token=1,
        max_seq_len=256,
        n_frontend_tokens=0,
        frontend_embed_dim=32,
        dtype="float32",
    )

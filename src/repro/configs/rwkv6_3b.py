"""rwkv6-3b "Finch" [ssm]: 32L, d_model 2560, attention-free (RWKV-6
time-mix with data-dependent decay), channel-mix d_ff 8960, vocab 65536.
Source: [arXiv:2404.05892].
"""
import dataclasses

from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    n_layers=32,
    d_model=2560,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    block_pattern=("rwkv",),
    norm="layernorm",
    rwkv_head_dim=64,  # 40 heads of 64
    max_seq_len=524288,
    notes="long_500k runs natively: O(1) recurrent state (H×64×64 per "
    "layer), no KV cache.",
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=2,
        d_model=128,
        d_ff=256,
        vocab_size=512,
        rwkv_head_dim=32,
        max_seq_len=256,
        dtype="float32",
    )

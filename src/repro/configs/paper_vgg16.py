"""Paper cfg. C (Appendix A): VGG16 on CIFAR-10-like data, random
4-regular network."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paper-vgg16",
    family="paper",
    source="paper Appendix A (cfg C); arXiv:1409.1556",
    n_layers=16,
    d_model=512,
    d_ff=4096,
    vocab_size=0,
    notes="image classifier; see repro.models.paper_models.init_vgg16 "
    "(width_mult for CPU validation)",
)


def reduced() -> ArchConfig:
    return CONFIG

"""On-device random-walk degree polling (paper §3/§4.4, ref [35]).

Jitted rendering of ``core.gossip.poll_degrees``: all walkers advance one
CSR transition per ``lax.scan`` step, so a (starts × n_walks) fleet costs
O(walk_length) fused gathers instead of a Python loop.  A simple random walk
visits nodes ∝ degree (the excess-degree bias q(k)); ``correct_bias``
importance-resamples ∝ 1/k on device (``jax.random.categorical``) to recover
p(k), the distribution ``v_steady_norm_from_degree_sample`` expects.

Degree-0 guard (mirrors the host reference): a walker whose current node has
no neighbours *stays put* instead of indexing into the next node's CSR
segment, and walkers that end on such a sink are excluded from the 1/k
resample (they carry no degree information).  Start nodes are validated
host-side — they are static — because a stuck fleet would feed k = 0 into
the correction.

Failure model: pass the training ``CommPlan`` as ``plan`` and each step
draws the same per-edge/per-node Bernoullis as a training round
(``CommPlan.round_masks``); an attempted transition over a failed link (or
to/from an inactive node) keeps the walker in place for that step, so the
degree poll rides exactly the unreliable links the §4.4 contract promises.
The host numpy reference remains failure-free (statistical, not drawn-mask,
parity is what the tests assert for this pathway).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commplan import CommPlan, PlanSchedule
from repro.core.topology import Graph

__all__ = ["poll_degrees_device"]


def poll_degrees_device(
    graph: Graph,
    start: int | jax.Array,
    *,
    walk_length: int,
    n_walks: int,
    key: jax.Array,
    correct_bias: bool = True,
    plan: CommPlan | PlanSchedule | None = None,
) -> jax.Array:
    """Run ``n_walks`` walks of ``walk_length`` steps from each start node.

    ``start``: a scalar node id → returns (n_walks,) polled degrees; an (s,)
    array of ids (e.g. ``arange(n)`` for every-node-polls-itself, the truly
    uncoordinated setting) → returns (s, n_walks).  Fully traceable, so the
    fused warmup can inline it next to the push-sum phases.

    ``plan`` may be a ``PlanSchedule``: step r then transitions through the
    CSR of the plan active at round r (the walker explores the *dynamic*
    graph), failure draws fold the active plan id like every other gossip
    round, and the polled degree is the walker's final node's degree in the
    plan active at the last step — the degree a node would actually observe
    when the poll ends.
    """
    schedule = plan if isinstance(plan, PlanSchedule) and plan.k > 1 else None
    ref_graph = plan.graph if schedule is not None else graph
    indptr_np, indices_np, uid_np = ref_graph.csr()
    if len(indices_np) == 0:
        raise ValueError("poll_degrees_device: graph has no edges — nothing to poll")
    deg_np = (indptr_np[1:] - indptr_np[:-1]).astype(np.int32)
    starts_np = np.atleast_1d(np.asarray(start))
    if np.any(deg_np[starts_np] == 0):
        bad = starts_np[deg_np[starts_np] == 0]
        raise ValueError(
            f"poll_degrees_device: start node(s) {bad.tolist()} have no "
            "neighbours — every walk would be stuck and the 1/k bias "
            "correction would divide by zero"
        )
    if schedule is not None:
        csr = schedule.stacked_csr()
        with_failures = schedule.failures.active
    else:
        indptr = jnp.asarray(indptr_np[:-1])
        indices = jnp.asarray(indices_np)
        uid = jnp.asarray(uid_np)
        deg = jnp.asarray(deg_np)
        degrees = jnp.asarray(graph.degrees, jnp.float32)
        with_failures = plan is not None and plan.failures.active

    squeeze = np.ndim(start) == 0
    v = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(start, jnp.int32))[:, None],
                         (len(starts_np), n_walks))

    k_walk, k_resample = jax.random.split(key)

    def step(v, rk):
        r, k = rk
        if with_failures:
            k, k_fail = jax.random.split(k)
        u = jax.random.uniform(k, v.shape)
        if schedule is not None:
            i = schedule.plan_index(r)
            deg_r = csr["deg"][i]
            d = deg_r[v]
            idx = jnp.where(d > 0, csr["indptr"][i][v] + (u * d).astype(jnp.int32), 0)
            nxt = csr["indices"][i][idx]
            ok = d > 0
            if with_failures:
                edge_keep, active = schedule.round_masks(
                    schedule.round_key(k_fail, r)
                )
                ok = ok & edge_keep[csr["uid"][i][idx]] & active[v] & active[nxt]
            return jnp.where(ok, nxt, v), None
        d = deg[v]
        idx = jnp.where(d > 0, indptr[v] + (u * d).astype(jnp.int32), 0)
        nxt = indices[idx]
        ok = d > 0
        if with_failures:
            # one training-style failure draw per walk step: a failed link
            # (or inactive endpoint) bounces the walker back for this step
            edge_keep, active = plan.round_masks(k_fail)
            ok = ok & edge_keep[uid[idx]] & active[v] & active[nxt]
        return jnp.where(ok, nxt, v), None

    v, _ = jax.lax.scan(
        step, v, (jnp.arange(walk_length), jax.random.split(k_walk, walk_length))
    )
    if schedule is not None:
        degrees = csr["degrees"][schedule.plan_index(walk_length - 1)]
    ks = degrees[v]  # (s, n_walks)
    if correct_bias:
        # importance resample ∝ 1/k, per start row, to undo the ∝ k visit
        # bias; sink-trapped walkers (k = 0) carry no degree information and
        # are excluded via a large negative logit
        logits = jnp.where(ks > 0, -jnp.log(jnp.maximum(ks, 1e-30)), -1e30)
        rows = jax.random.split(k_resample, ks.shape[0])
        idx = jax.vmap(
            lambda k, lg: jax.random.categorical(k, lg, shape=(n_walks,))
        )(rows, logits)
        ks = jnp.take_along_axis(ks, idx, axis=1)
    return ks[0] if squeeze else ks

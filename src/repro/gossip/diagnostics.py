"""Convergence diagnostics for the gossip engine (paper §4.5).

Push-sum error contracts asymptotically like ``|λ₂|^t`` where λ₂ is the
second-largest-magnitude eigenvalue of the send operator A' — i.e. the rate
is keyed to the spectral gap ``1 - |λ₂|`` exactly like the σ_an
stabilisation time of the training dynamics (``core.mixing.spectral_gap``).
These helpers turn an engine trace into per-node relative-error curves and a
fitted per-round contraction rate so an estimation *budget* (rounds) can be
chosen per topology instead of guessed.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.commplan import CommPlan
from repro.core.mixing import spectral_gap
from repro.core.topology import Graph

from .engine import as_plan, push_sum

__all__ = [
    "relative_error_trace",
    "size_error_trace",
    "fit_contraction_rate",
    "predicted_contraction_rate",
    "convergence_report",
]


def relative_error_trace(trace, truth) -> np.ndarray:
    """(rounds, n[, k]) per-round estimates → per-node |est − truth|/|truth|."""
    tr = np.asarray(trace, dtype=np.float64)
    t = np.asarray(truth, dtype=np.float64)
    return np.abs(tr - t) / np.maximum(np.abs(t), 1e-300)


def size_error_trace(
    plan: CommPlan | Graph, rounds: int, key=None, *, leader: int = 0
) -> np.ndarray:
    """(rounds, n) relative error of every node's size estimate vs rounds.

    The canonical diagnostic: the one-hot average is the slowest-mixing
    payload (a point mass), so its error curve upper-bounds the degree /
    moment payloads sharing the same rounds.
    """
    plan = as_plan(plan)
    one_hot = jnp.zeros(plan.n, jnp.float32).at[leader].set(1.0)
    _, tr = push_sum(plan, one_hot, rounds, key, trace=True)
    n_hat = 1.0 / np.maximum(np.asarray(tr, np.float64), 1e-300)
    return relative_error_trace(n_hat, float(plan.n))


def fit_contraction_rate(max_err: np.ndarray, floor: float = 1e-6) -> float:
    """Least-squares per-round contraction from a max-over-nodes error curve.

    Fits ``log err_t ~ t·log ρ`` over the clean window: after the transient
    (first quarter) and above the fp32 noise floor.  Returns ρ (ρ < 1 means
    converging; smaller is faster).
    """
    err = np.asarray(max_err, dtype=np.float64)
    t = np.arange(len(err))
    lo = len(err) // 4
    keep = (t >= lo) & (err > floor) & np.isfinite(err)
    if keep.sum() < 2:
        return float("nan")
    slope = np.polyfit(t[keep], np.log(err[keep]), 1)[0]
    return float(np.exp(slope))


def predicted_contraction_rate(graph: Graph) -> float:
    """``|λ₂| = 1 − spectral_gap``: the asymptotic per-round factor."""
    return 1.0 - spectral_gap(graph)


def convergence_report(
    plan: CommPlan | Graph, rounds: int, key=None, *, leader: int = 0
) -> dict:
    """Measured-vs-predicted convergence of the size estimator.

    Returns ``{rel_err: (rounds, n), max_rel_err: (rounds,), fitted_rate,
    predicted_rate, rounds_to_1pct}`` — the last being the measured budget
    for every node to reach 1% relative error (or -1 if not reached).
    """
    plan = as_plan(plan)
    rel = size_error_trace(plan, rounds, key, leader=leader)
    max_err = rel.max(axis=1)
    hit = np.nonzero(max_err < 1e-2)[0]
    return {
        "rel_err": rel,
        "max_rel_err": max_err,
        "fitted_rate": fit_contraction_rate(max_err),
        "predicted_rate": predicted_contraction_rate(plan.graph),
        "rounds_to_1pct": int(hit[0]) if len(hit) else -1,
    }

"""repro.gossip — device-resident estimation engine for uncoordinated init.

Gossip protocols (push-sum, power-iteration centrality, random-walk degree
polling) executed as jitted programs over the same ``CommPlan`` mixing
backends — and the same per-edge failure draws — as DecAvg training, so the
"uncoordinated" in uncoordinated initialisation is real: every node derives
its own gain ``‖v̂_steady‖⁻¹`` from traffic on its own unreliable links.
Host numpy reference: ``repro.core.gossip``; fused estimate→init→train:
``repro.fed.executor.run_warmup_trajectory``.
"""
from .diagnostics import (
    convergence_report,
    fit_contraction_rate,
    predicted_contraction_rate,
    relative_error_trace,
    size_error_trace,
)
from .engine import (
    GossipEstimates,
    as_plan,
    estimate_all,
    estimate_mean_degree,
    estimate_size,
    estimate_size_leaderless,
    estimate_size_leaderless_events,
    gain_from_degree_sample,
    gains_from_estimates,
    make_gain_estimator,
    power_iteration_norm,
    push_sum,
    push_sum_events,
    spread_events,
    spread_rounds,
)
from .walker import poll_degrees_device

__all__ = [
    "GossipEstimates",
    "as_plan",
    "convergence_report",
    "estimate_all",
    "estimate_mean_degree",
    "estimate_size",
    "estimate_size_leaderless",
    "estimate_size_leaderless_events",
    "fit_contraction_rate",
    "gain_from_degree_sample",
    "gains_from_estimates",
    "make_gain_estimator",
    "poll_degrees_device",
    "power_iteration_norm",
    "predicted_contraction_rate",
    "push_sum",
    "push_sum_events",
    "relative_error_trace",
    "size_error_trace",
    "spread_events",
    "spread_rounds",
]

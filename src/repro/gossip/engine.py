"""Device-resident gossip estimation engine (paper §4.4) over CommPlan backends.

The paper's *uncoordinated* initialisation needs every node to estimate
``‖v_steady‖`` (or the system size n and a family exponent) from nothing but
neighbour exchanges.  ``core.gossip`` pins those protocols down as a host
numpy reference; this module is the production rendering: jitted,
``lax.scan``-chunked programs that execute over the **same** compiled
``CommPlan`` a training run uses — dense / sparse / ppermute backend, same
sharding rules, and per-edge/per-node failure draws keyed exactly like the
training round's (``CommPlan.round_masks``).  Estimation traffic therefore
rides the same unreliable links as DecAvg itself, which is the whole point
of calling the init "uncoordinated".

One gossip round is ``CommPlan.spread`` — the send-form (column-stochastic,
mass-conserving) transpose of the DecAvg receive operator; for undirected
unit-weight graphs that is exactly the paper's Eq. 3 matrix ``A'``.

Protocols
---------
``push_sum``               (s, w) ratio gossip → every node's estimate of the
                           uniform average of an arbitrary (n, k) payload.
``estimate_size``          n̂ from push-sum of a leader one-hot.
``estimate_mean_degree``   ⟨k⟩ from push-sum of local degrees.
``power_iteration_norm``   ‖v̂_steady‖ per node: power-iterate x ← A'x from
                           x₀ = 1 (mass conservation ⇒ x → n·v), then
                           push-sum the moments [x², 1_leader] so each node
                           normalises n·‖v‖² by its own size estimate.
``estimate_all``           one fused program producing (n̂, ‖v̂‖, ⟨k̂⟩).
``make_gain_estimator``    key → (n,) per-node init gains, jit-closable into
                           the fused estimate→init→train warmup
                           (``fed.executor.run_warmup_trajectory``).

Every per-round failure key is ``fold_in(key, round_index)`` with a global
round counter across phases, so a host reference can replay the exact
Bernoulli sequence (see tests/test_gossip_engine.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.commplan import CommPlan, PlanSchedule, compile_plan, compile_schedule
from repro.core.shardplan import ShardedCommPlan, shard_plan
from repro.core.topology import EventStream, Graph

from .walker import poll_degrees_device

__all__ = [
    "GossipEstimates",
    "as_plan",
    "spread_rounds",
    "push_sum",
    "estimate_size",
    "estimate_size_leaderless",
    "estimate_mean_degree",
    "power_iteration_norm",
    "estimate_all",
    "gains_from_estimates",
    "gain_from_degree_sample",
    "make_gain_estimator",
    "spread_events",
    "push_sum_events",
    "estimate_size_leaderless_events",
]

Plan = CommPlan | PlanSchedule | ShardedCommPlan

_EPS = 1e-30  # guards 1/z before mass from the leader one-hot arrives
# below this, a node's push-sum weight of the leader one-hot is "exactly
# zero up to fp32 underflow": the budget never carried the leader's mass
# there.  Reached nodes hold z ≥ (1/(Δ+1))^rounds ≫ this for any sane
# budget, so the threshold cleanly separates "no estimate yet" from "noisy
# estimate" (see ``reached`` below).
_UNREACHED = 1e-20


def as_plan(graph_or_plan: Graph | Plan, backend: str = "auto") -> Plan:
    """Estimation plans are unit-data-size: Eq. 3 weights, not |D_j|-weighted.

    (Mass conservation — hence push-sum correctness — holds for any
    transposed row-stochastic operator, but the ‖v_steady‖ the *init* needs
    is the stationary vector of the unweighted A', so the engine insists on
    it.)  A ``CommPlan`` / ``PlanSchedule`` is accepted as-is when it
    already qualifies; otherwise its graph(s)/failures are recompiled
    without data sizes.  Over a ``PlanSchedule`` every protocol round rides
    the plan active at that gossip round — estimation happens on the
    *dynamic* graph nodes actually see.
    """
    if isinstance(graph_or_plan, PlanSchedule):
        if graph_or_plan.data_sizes is None:
            return graph_or_plan
        return compile_schedule(
            [p.graph for p in graph_or_plan.plans],
            backend=graph_or_plan.backend,
            failures=graph_or_plan.failures,
            round_map=graph_or_plan.round_map,
        )
    if isinstance(graph_or_plan, ShardedCommPlan):
        # gossip over the node-sharded rendering: estimation's spread /
        # spread_min scans run through the halo-exchange collectives and
        # stay bit-identical to the single-device operator
        sp = graph_or_plan
        if sp.data_sizes is None:
            return sp
        base = compile_plan(sp.graph, backend=sp.backend, failures=sp.failures)
        return shard_plan(base, mesh=sp.mesh, axis=sp.axis)
    if isinstance(graph_or_plan, CommPlan):
        if graph_or_plan.data_sizes is None:
            return graph_or_plan
        # NOT with_options(data_sizes=None): there None means "keep current"
        return compile_plan(
            graph_or_plan.graph,
            backend=graph_or_plan.backend,
            failures=graph_or_plan.failures,
        )
    return compile_plan(graph_or_plan, backend=backend)


def _scan_rounds(
    plan: Plan,
    op: str,
    x0: jax.Array,
    rounds: int,
    key: jax.Array | None,
    round_offset: int,
    trace: bool,
    active: jax.Array | None = None,
):
    """rounds × ``plan.<op>`` as one ``lax.scan``; per-round failure key is
    ``fold_in(key, round_offset + r)`` so phases of a multi-stage protocol
    consume a single global round counter (``round_offset`` may be traced —
    a budget-dependent phase boundary).  Over a ``PlanSchedule`` the round
    index also selects the active plan (and folds its id into the key).
    ``active``, when given, is a traced live-round count ≤ rounds: rounds
    past it are identity (the swept-budget masking — one program shape for
    a whole budget grid, ``fed.executor.run_warmup_sweep``)."""
    if plan.failures.active and key is None:
        raise ValueError("failure model active: gossip needs a PRNG key")
    scheduled = isinstance(plan, PlanSchedule)

    def body(x, r):
        k = None if key is None else jax.random.fold_in(key, r)
        f = getattr(plan, op)
        x1 = f(x, r, k) if scheduled else f(x, k)
        if active is not None:
            x1 = jnp.where(r - round_offset < active, x1, x)
        return x1, (x1 if trace else None)

    steps = jnp.arange(rounds) + jnp.asarray(round_offset, jnp.int32)
    x, tr = jax.lax.scan(body, jnp.asarray(x0, jnp.float32), steps)
    return (x, tr) if trace else x


def _scan_spread(plan, x0, rounds, key, round_offset, trace, active=None):
    return _scan_rounds(plan, "spread", x0, rounds, key, round_offset, trace, active)


def _scan_spread_min(plan, x0, rounds, key, round_offset, active=None):
    return _scan_rounds(plan, "spread_min", x0, rounds, key, round_offset, False, active)


def spread_rounds(
    plan: Plan | Graph,
    values: jax.Array,
    rounds: int,
    key: jax.Array | None = None,
    *,
    round_offset: int = 0,
    trace: bool = False,
    active: jax.Array | None = None,
):
    """``rounds`` applications of the send operator to an (n,) / (n, k) payload.

    With ``trace=True`` also returns the (rounds, n[, k]) per-round states —
    the raw material of the convergence diagnostics.  ``active`` (a traced
    live-round count) freezes the tail rounds for swept-budget grids.
    """
    return _scan_spread(as_plan(plan), values, rounds, key, round_offset, trace, active)


def push_sum(
    plan: Plan | Graph,
    values: jax.Array,
    rounds: int,
    key: jax.Array | None = None,
    *,
    round_offset: int = 0,
    trace: bool = False,
    active: jax.Array | None = None,
):
    """Kempe push-sum: track (s, w), both spread with the same draws; s/w is
    every node's running estimate of the uniform average (mass conservation
    makes this exact in the limit even under per-round failure draws).

    ``values``: (n,) or (n, k).  Returns per-node averages of that shape;
    with ``trace=True`` returns (estimates, per-round estimates).
    """
    plan = as_plan(plan)
    x = jnp.asarray(values, jnp.float32)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    payload = jnp.concatenate([x, jnp.ones((x.shape[0], 1), jnp.float32)], axis=1)
    out = _scan_spread(plan, payload, rounds, key, round_offset, trace, active)
    payload, tr = out if trace else (out, None)
    ratio = payload[:, :-1] / payload[:, -1:]
    if squeeze:
        ratio = ratio[:, 0]
    if not trace:
        return ratio
    tr_ratio = tr[..., :-1] / tr[..., -1:]
    return ratio, (tr_ratio[..., 0] if squeeze else tr_ratio)


def estimate_size(
    plan: Plan | Graph,
    rounds: int,
    key: jax.Array | None = None,
    *,
    leader: int = 0,
    round_offset: int = 0,
    active: jax.Array | None = None,
) -> jax.Array:
    """Every node's n̂ after ``rounds`` of push-sum of a leader one-hot."""
    plan = as_plan(plan)
    one_hot = jnp.zeros(plan.n, jnp.float32).at[leader].set(1.0)
    avg = push_sum(plan, one_hot, rounds, key, round_offset=round_offset, active=active)
    return 1.0 / jnp.maximum(avg, _EPS)


def estimate_size_leaderless(
    plan: Plan | Graph,
    rounds: int,
    key: jax.Array,
    *,
    n_sketches: int = 32,
    round_offset: int = 0,
    active: jax.Array | None = None,
    return_sketches: bool = False,
):
    """Leaderless n̂ by extrema propagation — **no distinguished node**.

    Every node draws ``n_sketches`` iid Exp(1) values; each round is one
    ``spread_min`` exchange (coordinate-wise min over the live
    neighbourhood, same per-edge failure draws as the concurrent push
    traffic for the same key/round counter).  Once the minima have flooded
    the graph, each coordinate holds the min of n Exp(1) draws ~ Exp(n), so
    ``n̂ = (m-1) / Σ_sketches min`` is the unbiased size estimate (Baquero
    et al.'s extrema propagation; relative noise ≈ 1/√(m-2)).

    Replaces the leader-one-hot pathway of ``estimate_size``: no node is
    special, and the failure mode is graceful — a node that heard nothing
    still averages its own draws to n̂ ≈ 1, i.e. gain ≈ 1, the honest
    no-knowledge default (no ``reached`` bookkeeping needed).

    ``key`` is mandatory (the sketch draws); it splits once into
    (sketch-draw key, per-round failure key).
    """
    plan = as_plan(plan)
    if key is None:
        raise ValueError("estimate_size_leaderless draws sketches: a PRNG key is required")
    k_draw, k_round = jax.random.split(key)
    sketches = jax.random.exponential(k_draw, (plan.n, n_sketches))
    n_hat, mins = _sketch_n_hat(
        plan, sketches, rounds,
        k_round if plan.failures.active else None,
        round_offset, active,
    )
    return (n_hat, mins) if return_sketches else n_hat


def _sketch_n_hat(plan, sketches, rounds, key, round_offset=0, active=None):
    """Shared core of the leaderless estimators: propagate the (n, m) Exp(1)
    sketches by min-exchange and invert the summed minima — (n̂, mins)."""
    mins = _scan_spread_min(plan, sketches, rounds, key, round_offset, active)
    m = sketches.shape[1]
    return (m - 1) / jnp.maximum(mins.sum(axis=1), _EPS), mins


# ------------------------------------------------- event-driven (barrier-free)
def _scan_events(plan: Plan | Graph, op: str, x0: jax.Array, stream: EventStream, key):
    """``stream.envelope`` × ``plan.event_<op>`` as one ``lax.scan``; the
    per-event failure key is ``fold_in(key, event_index)`` — the event
    analogue of the per-round ``fold_in`` discipline, so a host reference
    given the realised keep flags replays the exact sequence.  Padding
    events (edge = -1) are the identity, which is what lets streams of
    different realised lengths share one compiled program.

    Over a K > 1 ``PlanSchedule`` the scan also carries the event *times*:
    each event executes under the plan active in its unit-time window
    (``PlanSchedule.event_stream`` samples streams with per-window edge
    ids) and the window's plan id folds into the per-event failure key
    (``event_key``) — the event-path mirror of ``round_key``, so resampled
    plans draw independent node/link outages."""
    plan = as_plan(plan)
    if isinstance(plan, PlanSchedule) and plan.k == 1:
        # the K = 1 contract: a size-1 schedule IS the static plan
        plan = plan.plans[0]
    if plan.failures.active and key is None:
        raise ValueError("failure model active: event gossip needs a PRNG key")
    edges = jnp.asarray(stream.edges)

    if isinstance(plan, PlanSchedule):
        times = jnp.asarray(stream.times)

        def body(x, inp):
            i, e, t = inp
            k = None if key is None else jax.random.fold_in(key, i)
            return getattr(plan, f"event_{op}")(x, e, t, k), None

        idx = jnp.arange(stream.envelope, dtype=jnp.int32)
        x, _ = jax.lax.scan(body, jnp.asarray(x0, jnp.float32), (idx, edges, times))
        return x

    def body(x, inp):
        i, e = inp
        k = None if key is None else jax.random.fold_in(key, i)
        return getattr(plan, f"event_{op}")(x, e, k), None

    idx = jnp.arange(stream.envelope, dtype=jnp.int32)
    x, _ = jax.lax.scan(body, jnp.asarray(x0, jnp.float32), (idx, edges))
    return x


def spread_events(
    plan: Plan | Graph,
    values: jax.Array,
    stream: EventStream,
    key: jax.Array | None = None,
) -> jax.Array:
    """Apply an ``EventStream`` of pairwise push exchanges to an (n,) / (n, k)
    payload — the barrier-free rendering of ``spread_rounds``: mass is
    conserved event by event, no global round counter exists, and estimation
    progresses exactly as fast as the Poisson clocks fire."""
    return _scan_events(plan, "spread", values, stream, key)


def push_sum_events(
    plan: Plan | Graph,
    values: jax.Array,
    stream: EventStream,
    key: jax.Array | None = None,
) -> jax.Array:
    """Event-driven Kempe push-sum: (s, w) ride the same pairwise exchanges,
    s/w is every node's running average estimate — uncoordinated consensus
    with no synchronisation barrier (numpy reference:
    ``core.gossip.push_sum_events_reference``)."""
    plan = as_plan(plan)
    x = jnp.asarray(values, jnp.float32)
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    payload = jnp.concatenate([x, jnp.ones((x.shape[0], 1), jnp.float32)], axis=1)
    out = _scan_events(plan, "spread", payload, stream, key)
    ratio = out[:, :-1] / jnp.maximum(out[:, -1:], _EPS)
    return ratio[:, 0] if squeeze else ratio


def estimate_size_leaderless_events(
    plan: Plan | Graph,
    stream: EventStream,
    key: jax.Array,
    *,
    n_sketches: int = 32,
    return_sketches: bool = False,
):
    """Leaderless n̂ over an event stream — fully uncoordinated estimation:
    no distinguished node *and* no round barrier.  Each node's Exp(1)
    sketches flood by pairwise min exchanges as edge clocks fire
    (``CommPlan.event_spread_min``); the estimator and its failure mode
    (unreached nodes degrade to n̂ ≈ 1 → gain ≈ 1) match
    ``estimate_size_leaderless`` sketch for sketch."""
    plan = as_plan(plan)
    if key is None:
        raise ValueError("estimate_size_leaderless_events draws sketches: a PRNG key is required")
    k_draw, k_event = jax.random.split(key)
    sketches = jax.random.exponential(k_draw, (plan.n, n_sketches))
    mins = _scan_events(
        plan, "spread_min", sketches, stream,
        k_event if plan.failures.active else None,
    )
    n_hat = (n_sketches - 1) / jnp.maximum(mins.sum(axis=1), _EPS)
    return (n_hat, mins) if return_sketches else n_hat


def estimate_mean_degree(
    plan: Plan | Graph,
    rounds: int,
    key: jax.Array | None = None,
    *,
    round_offset: int = 0,
) -> jax.Array:
    plan = as_plan(plan)
    deg = jnp.asarray(plan.graph.degrees, jnp.float32)
    return push_sum(plan, deg, rounds, key, round_offset=round_offset)


@dataclasses.dataclass(frozen=True)
class GossipEstimates:
    """Per-node estimates, every field (n,).  Registered as a pytree so a
    fused program can return it from inside jit.  ``reached`` flags nodes
    the leader's mass actually visited within the budget — estimates at
    un-reached nodes are meaningless (see ``make_gain_estimator``)."""

    n_hat: jax.Array
    vnorm: jax.Array
    mean_degree: jax.Array
    reached: jax.Array

    def tree_flatten(self):
        return (self.n_hat, self.vnorm, self.mean_degree, self.reached), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    GossipEstimates,
    GossipEstimates.tree_flatten,
    GossipEstimates.tree_unflatten,
)


def _centrality_moments(
    plan, pi_rounds, ps_rounds, key, leader, extra=None, active_pi=None, active_ps=None
):
    """Shared two-phase core of the ‖v_steady‖ estimators.

    Phase 1 — power iteration: ``x ← A'x`` from ``x₀ = 1``; A' is
    column-stochastic so ``Σx = n`` is invariant while ``A'^t → v·1ᵀ``, and
    ``x → n·v`` with no explicit normalisation.  Phase 2 — push-sum of the
    payload ``[x², 1_leader, *extra]`` under the continuing round counter
    (``round_offset=pi_rounds``, one failure-key discipline across phases).
    Returns ``(x, avg, reached, z)`` with ``z`` clamp-guarded and
    ``reached`` = the leader's mass actually arrived within the budget.
    ``active_pi``/``active_ps`` are the swept-budget live-round masks; the
    push-sum phase then starts its round counter at the *live* phase-1
    budget, so a masked run consumes exactly the failure draws a genuinely
    ``active``-round estimator would — budget-b sweep cells replay as
    standalone budget-b runs, failures included.
    """
    x = spread_rounds(plan, jnp.ones(plan.n, jnp.float32), pi_rounds, key, active=active_pi)
    one_hot = jnp.zeros(plan.n, jnp.float32).at[leader].set(1.0)
    cols = [x * x, one_hot] + ([extra] if extra is not None else [])
    avg = push_sum(
        plan, jnp.stack(cols, axis=1), ps_rounds, key,
        round_offset=pi_rounds if active_pi is None else active_pi,
        active=active_ps,
    )
    reached = avg[:, 1] > _UNREACHED
    z = jnp.maximum(avg[:, 1], _EPS)
    return x, avg, reached, z


def power_iteration_norm(
    plan: Plan | Graph,
    pi_rounds: int,
    ps_rounds: int,
    key: jax.Array | None = None,
    *,
    leader: int = 0,
    active_pi: jax.Array | None = None,
    active_ps: jax.Array | None = None,
) -> dict[str, jax.Array]:
    """Gossip estimate of ``‖v_steady‖₂`` at every node (two fused phases,
    ``_centrality_moments``): each node normalises its power-iterated
    centrality moment by its own concurrent size estimate —
    ``‖v̂‖ = √(m2·z)``, ``n̂ = 1/z``.  ``reached`` is False where the budget
    never delivered the leader's mass (the estimates there are meaningless;
    downstream gain builders fall back to 1.0).

    Over a ``PlanSchedule`` the iterated operator is the round-indexed
    product of the schedule's send matrices — the centrality of the dynamic
    graph as nodes actually experience it.

    Numpy reference: ``core.gossip.power_iteration_norm_reference`` (parity
    tested across backends, topologies and failure draws).
    """
    plan = as_plan(plan)
    x, avg, reached, z = _centrality_moments(
        plan, pi_rounds, ps_rounds, key, leader,
        active_pi=active_pi, active_ps=active_ps,
    )
    return {
        "vnorm": jnp.sqrt(jnp.maximum(avg[:, 0] * z, 0.0)),
        "n_hat": 1.0 / z,
        "x": x,
        "reached": reached,
    }


def estimate_all(
    plan: Plan | Graph,
    *,
    pi_rounds: int,
    ps_rounds: int,
    key: jax.Array | None = None,
    leader: int = 0,
) -> GossipEstimates:
    """One fused program for the full §4.4 estimate set: the power-iterated
    centrality moment, the leader one-hot and the local degrees all share a
    single push-sum phase (and its failure draws).  Over a ``PlanSchedule``
    the degree payload is the round-0 plan's — what each node locally knows
    when estimation starts."""
    plan = as_plan(plan)
    deg = jnp.asarray(plan.graph.degrees, jnp.float32)
    _, avg, reached, z = _centrality_moments(plan, pi_rounds, ps_rounds, key, leader, extra=deg)
    return GossipEstimates(
        n_hat=1.0 / z,
        vnorm=jnp.sqrt(jnp.maximum(avg[:, 0] * z, 0.0)),
        mean_degree=avg[:, 2],
        reached=reached,
    )


# ------------------------------------------------------------ gains (device)
def gains_from_estimates(
    n_hat: jax.Array,
    vnorm: jax.Array | None = None,
    family_exponent: float | None = None,
) -> jax.Array:
    """Vectorised device mirror of ``core.initialisation.gain_from_estimates``.

    Priority (and argument validation) match the host function: a direct
    ``vnorm`` estimate wins (gain = 1/‖v̂‖, per node); otherwise a family
    exponent α gives ``n̂^α`` (α = 1/2 when omitted — the homogeneous-graph
    assumption of Fig. 5).  Passing both raises, like the host.
    """
    if vnorm is not None and family_exponent is not None:
        raise ValueError(
            "give either a vnorm estimate or a family_exponent, not both — "
            "see core.initialisation.gain_from_estimates for the priority rule"
        )
    if vnorm is not None:
        return 1.0 / jnp.maximum(jnp.asarray(vnorm, jnp.float32), _EPS)
    alpha = 0.5 if family_exponent is None else family_exponent
    return jnp.asarray(n_hat, jnp.float32) ** alpha


def gain_from_degree_sample(n_hat: jax.Array, degree_sample: jax.Array) -> jax.Array:
    """Device mirror of the host degree-sample gain:
    ``‖v‖² ≈ ⟨(k+1)²⟩ / (n̂·⟨k+1⟩²)`` per node, gain = 1/‖v̂‖.

    ``n_hat``: (n,) per-node size estimates; ``degree_sample``: (m,) shared
    or (n, m) per-node polled degrees.  Rounds n̂ like the host path.
    """
    k1 = jnp.asarray(degree_sample, jnp.float32) + 1.0
    m2 = jnp.mean(k1**2, axis=-1)
    m1 = jnp.mean(k1, axis=-1)
    n_r = jnp.round(jnp.asarray(n_hat, jnp.float32))
    vnorm = jnp.sqrt(m2 / (n_r * m1**2))
    return 1.0 / jnp.maximum(vnorm, _EPS)


def make_gain_estimator(
    plan: Plan | Graph,
    *,
    pi_rounds: int,
    ps_rounds: int,
    mode: str = "vnorm",
    family_exponent: float | None = None,
    leader: int = 0,
    walk_length: int = 16,
    n_walks: int = 64,
    leaderless: bool = False,
    n_sketches: int = 32,
) -> Callable[..., jax.Array]:
    """Build the jittable ``(key[, budget]) → (n,) gains`` warmup function.

    Modes (the three §4.4 knowledge regimes):
      ``vnorm``   power-iteration ‖v̂‖ per node → gain = 1/‖v̂‖ (default);
      ``alpha``   size-only: push-sum n̂ → gain = n̂^α;
      ``degree``  push-sum n̂ + per-node on-device random-walk degree polls
                  → closed-form ‖v̂‖ (the Fig. 5 sampled-degree pathway).

    ``leaderless=True`` replaces every leader-one-hot size estimate with the
    exponential-random-minimum sketches (``estimate_size_leaderless``): no
    distinguished node, sketch traffic riding the same per-round failure
    draws as the concurrent push-sum phase, and the ``reached`` fallback
    becomes unnecessary — an unreached node's own sketches already average
    to n̂ ≈ 1, i.e. gain ≈ 1.  ``vnorm`` then normalises the power-iterated
    moment by the sketch n̂ instead of the leader column.

    ``plan`` may be a ``PlanSchedule``: all protocol rounds then follow the
    round-indexed dynamic topology (including the degree walks).

    The returned callable is pure jax — ``fed.executor.run_warmup_trajectory``
    closes over it so estimate → per-node gain → init → train compiles as
    one program with no host round-trip.  Its optional second argument is a
    *traced* gossip budget (live rounds per phase, ≤ the static
    ``pi_rounds``/``ps_rounds``): build one estimator at the grid's max
    budget and ``fed.executor.run_warmup_sweep`` vmaps a whole
    (budget × seed) grid through one program.

    Budget under-runs (leader pathways): a node the leader's mass never
    reached within ``ps_rounds`` has *no* size estimate (its push-sum weight
    is exactly zero); naively inverting the clamp would hand it an
    astronomically wrong gain that silently NaNs training.  Such nodes fall
    back to gain = 1.0 — the honest no-knowledge default (unscaled He),
    which is exactly what an uncoordinated node that heard nothing would
    use.
    """
    plan = as_plan(plan)
    if mode not in ("vnorm", "alpha", "degree"):
        raise ValueError(f"unknown gain estimator mode {mode!r}")
    if mode == "vnorm" and family_exponent is not None:
        raise ValueError("family_exponent only applies to mode='alpha'")
    scheduled = isinstance(plan, PlanSchedule)

    def estimate_gains(
        key: jax.Array | None, budget: jax.Array | None = None
    ) -> jax.Array:
        if leaderless:
            if key is None:
                raise ValueError("leaderless estimation draws sketches: key required")
            k_sketch, key = jax.random.split(key)
        k_gossip, k_walk = (
            (None, None) if key is None else tuple(jax.random.split(key))
        )

        def sketch_size(rounds, round_offset=0, active=None):
            # ride the SAME per-round keys (hence failure draws) as the
            # concurrent push-sum phase: fold the phase key stream
            sketches = jax.random.exponential(k_sketch, (plan.n, n_sketches))
            n_hat, _ = _sketch_n_hat(
                plan, sketches, rounds,
                k_gossip if plan.failures.active else None,
                round_offset, active,
            )
            return n_hat

        if mode == "vnorm":
            if leaderless:
                x = spread_rounds(
                    plan, jnp.ones(plan.n, jnp.float32), pi_rounds, k_gossip,
                    active=budget,
                )
                # phase 2's round counter starts at the LIVE phase-1 budget,
                # like _centrality_moments: masked ≡ standalone budget run
                offset2 = pi_rounds if budget is None else budget
                m2 = push_sum(
                    plan, (x * x)[:, None], ps_rounds, k_gossip,
                    round_offset=offset2, active=budget,
                )[:, 0]
                n_hat = sketch_size(ps_rounds, round_offset=offset2, active=budget)
                vnorm = jnp.sqrt(jnp.maximum(m2 / jnp.maximum(n_hat, 1.0), 0.0))
                return gains_from_estimates(n_hat, vnorm=vnorm)
            est = power_iteration_norm(
                plan, pi_rounds, ps_rounds, k_gossip, leader=leader,
                active_pi=budget, active_ps=budget,
            )
            gains = gains_from_estimates(est["n_hat"], vnorm=est["vnorm"])
            reached = est["reached"]
        else:
            if leaderless:
                n_hat = sketch_size(ps_rounds, active=budget)
                reached = None
            else:
                n_hat = estimate_size(
                    plan, ps_rounds, k_gossip, leader=leader, active=budget
                )
                reached = n_hat < 1.0 / _UNREACHED
            if mode == "alpha":
                gains = gains_from_estimates(n_hat, family_exponent=family_exponent)
            else:
                if k_walk is None:
                    k_walk = jax.random.PRNGKey(0)
                sample = poll_degrees_device(
                    plan.graph,
                    np.arange(plan.n),  # static start set: every node polls itself
                    walk_length=walk_length,
                    n_walks=n_walks,
                    key=k_walk,
                    plan=plan,  # walks ride the same failure draws as training
                )
                gains = gain_from_degree_sample(n_hat, sample)
            if reached is None:
                return gains
        return jnp.where(reached, gains, 1.0)

    return estimate_gains

"""Hand-rolled optimizers (no optax in this environment).

The paper (Appendix A, Table A1) uses SGD with momentum m = 0.5 and AdamW
(decoupled weight decay) with β₁=0.9, β₂=0.999, ε=1e-8, λ=1e-2, both at
lr = 1e-3.  Algorithm 1 line 15 *re-initialises optimizer state after every
aggregation* — ``Optimizer.init`` is therefore on the hot path and must be
jit-friendly (it is: pure tree_map of zeros_like).

API mirrors optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)``; apply with
``tree_map(lambda p, u: p + u, params, updates)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["Optimizer", "sgd", "adamw"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    name: str = "optimizer"


class SgdState(NamedTuple):
    momentum: PyTree


def sgd(learning_rate: float = 1e-3, momentum: float = 0.5) -> Optimizer:
    """SGD with (heavy-ball) momentum: v ← m·v + g;  Δ = -lr·v."""

    def init(params: PyTree) -> SgdState:
        return SgdState(momentum=jax.tree_util.tree_map(jnp.zeros_like, params))

    def update(grads: PyTree, state: SgdState, params: PyTree) -> tuple[PyTree, SgdState]:
        del params
        v = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state.momentum, grads)
        updates = jax.tree_util.tree_map(lambda m: -learning_rate * m, v)
        return updates, SgdState(momentum=v)

    return Optimizer(init=init, update=update, name=f"sgd(lr={learning_rate},m={momentum})")


class AdamWState(NamedTuple):
    step: jax.Array
    mu: PyTree
    nu: PyTree


def adamw(
    learning_rate: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 1e-2,
) -> Optimizer:
    """Adam with decoupled weight decay (Loshchilov & Hutter).

    Decay is applied to the *parameters* (decoupled), not folded into the
    gradient — matching torch.optim.AdamW that the paper used.
    """

    def init(params: PyTree) -> AdamWState:
        z = lambda t: jax.tree_util.tree_map(jnp.zeros_like, t)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z(params), nu=z(params))

    def update(grads: PyTree, state: AdamWState, params: PyTree) -> tuple[PyTree, AdamWState]:
        step = state.step + 1
        mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g), state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            return -learning_rate * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)

        updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update, name=f"adamw(lr={learning_rate},wd={weight_decay})")

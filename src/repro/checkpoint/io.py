"""Msgpack-based pytree checkpointing.

Layout: one ``.ckpt`` file = msgpack map {treedef: str, leaves: [bytes...],
meta: {...}} with each leaf serialised as (dtype, shape, raw bytes).  No
orbax offline, so this is the deployable minimum: atomic writes (tmp +
rename), dtype/shape round-trip including bf16, and a step-numbered
directory convention with a LATEST pointer.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

__all__ = ["save_pytree", "load_pytree", "save_train_state", "restore_train_state"]

_BF16 = "bfloat16"


def _pack_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return {"dtype": _BF16, "shape": list(arr.shape), "data": arr.view(np.uint16).tobytes()}
    return {"dtype": arr.dtype.str, "shape": list(arr.shape), "data": arr.tobytes()}


def _unpack_leaf(d: dict) -> np.ndarray:
    shape = tuple(d["shape"])
    if d["dtype"] == _BF16:
        return np.frombuffer(d["data"], dtype=np.uint16).reshape(shape).view(jnp.bfloat16)
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(shape)


def save_pytree(path: str, tree: PyTree, meta: dict | None = None) -> None:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payload = {
        "treedef": str(treedef),
        "structure": _structure_of(tree),
        "leaves": [_pack_leaf(x) for x in leaves],
        "meta": meta or {},
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def _structure_of(tree: PyTree):
    """JSON-able skeleton (dicts/lists/None markers) used to rebuild treedef."""
    if isinstance(tree, dict):
        return {"__kind__": "dict", "items": {k: _structure_of(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        kind = "list" if isinstance(tree, list) else "tuple"
        return {"__kind__": kind, "items": [_structure_of(v) for v in tree]}
    if hasattr(tree, "_fields"):  # NamedTuple
        return {
            "__kind__": "namedtuple",
            "name": type(tree).__name__,
            "items": {k: _structure_of(getattr(tree, k)) for k in tree._fields},
        }
    return {"__kind__": "leaf"}


def _rebuild(structure, leaves: list) -> PyTree:
    kind = structure["__kind__"]
    if kind == "leaf":
        return leaves.pop(0)
    if kind == "dict":
        return {k: _rebuild(v, leaves) for k, v in structure["items"].items()}
    if kind == "list":
        return [_rebuild(v, leaves) for v in structure["items"]]
    if kind == "tuple":
        return tuple(_rebuild(v, leaves) for v in structure["items"])
    if kind == "namedtuple":
        # restored as plain dict: callers restoring optimizer state should
        # re-wrap; training restore does this via tree_unflatten on a template
        return {k: _rebuild(v, leaves) for k, v in structure["items"].items()}
    raise ValueError(f"unknown structure kind {kind}")


def load_pytree(path: str, template: PyTree | None = None) -> tuple[PyTree, dict]:
    """Load a checkpoint.  With ``template``, leaves are unflattened into the
    template's exact treedef (NamedTuples included); without it, the stored
    dict/list skeleton is rebuilt."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    if template is not None:
        treedef = jax.tree_util.tree_structure(template)
        if treedef.num_leaves != len(leaves):
            raise ValueError(f"checkpoint has {len(leaves)} leaves, template wants {treedef.num_leaves}")
        return jax.tree_util.tree_unflatten(treedef, leaves), payload["meta"]
    return _rebuild(payload["structure"], leaves), payload["meta"]


def save_train_state(ckpt_dir: str, step: int, state: PyTree, meta: dict | None = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")
    save_pytree(path, state, meta={"step": step, **(meta or {})})
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        json.dump({"step": step, "path": path}, f)
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"), os.path.join(ckpt_dir, "LATEST"))
    return path


def restore_train_state(ckpt_dir: str, template: PyTree | None = None) -> tuple[PyTree, dict] | None:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        info = json.load(f)
    return load_pytree(info["path"], template=template)

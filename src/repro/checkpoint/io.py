"""Msgpack-based pytree checkpointing.

Layout: one ``.ckpt`` file = msgpack map {structure, leaves: [bytes...],
meta: {...}} with each leaf serialised as (dtype, shape, raw bytes).  No
orbax offline, so this is the deployable minimum: *durable* atomic writes
(write tmp → fsync → rename → fsync dir), dtype/shape round-trip including
bf16, a step-numbered directory convention with an atomically-updated
LATEST pointer, and ``keep_last=`` retention GC.

The rebuild contract is the JSON-able ``structure`` skeleton alone (no
``str(treedef)`` anywhere): dict nodes are recorded in **sorted key order**
— the order ``jax.tree_util.tree_flatten`` emits leaves in — so a
template-less ``load_pytree`` reassembles leaves correctly for any key
insertion order.  NamedTuples are restored as plain dicts unless a
``template`` supplies the exact treedef.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any

__all__ = ["save_pytree", "load_pytree", "save_train_state", "restore_train_state"]

_BF16 = "bfloat16"


def _pack_leaf(x) -> dict:
    arr = np.asarray(jax.device_get(x))
    if arr.dtype == jnp.bfloat16:
        return {"dtype": _BF16, "shape": list(arr.shape), "data": arr.view(np.uint16).tobytes()}
    return {"dtype": arr.dtype.str, "shape": list(arr.shape), "data": arr.tobytes()}


def _unpack_leaf(d: dict) -> np.ndarray:
    shape = tuple(d["shape"])
    if d["dtype"] == _BF16:
        return np.frombuffer(d["data"], dtype=np.uint16).reshape(shape).view(jnp.bfloat16)
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(shape)


def _fsync_dir(path: str) -> None:
    """Flush a directory entry (the rename) to disk — best effort: some
    filesystems refuse O_RDONLY fsync on directories."""
    try:
        fd = os.open(path or ".", os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _replace_durable(tmp: str, path: str) -> None:
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(os.path.abspath(path)))


def save_pytree(path: str, tree: PyTree, meta: dict | None = None) -> None:
    leaves, _ = jax.tree_util.tree_flatten(tree)
    payload = {
        "structure": _structure_of(tree),
        "leaves": [_pack_leaf(x) for x in leaves],
        "meta": meta or {},
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
        f.flush()
        os.fsync(f.fileno())
    _replace_durable(tmp, path)


def _structure_of(tree: PyTree):
    """JSON-able skeleton (dicts/lists/markers) used to rebuild the tree.

    Dict items are recorded in **sorted key order**, matching the order
    ``jax.tree_util.tree_flatten`` yields dict leaves in — the skeleton and
    the leaf list stay aligned for any insertion order."""
    if isinstance(tree, dict):
        return {
            "__kind__": "dict",
            "items": {k: _structure_of(tree[k]) for k in sorted(tree)},
        }
    if hasattr(tree, "_fields"):  # NamedTuple (checked before tuple)
        return {
            "__kind__": "namedtuple",
            "name": type(tree).__name__,
            "items": {k: _structure_of(getattr(tree, k)) for k in tree._fields},
        }
    if isinstance(tree, (list, tuple)):
        kind = "list" if isinstance(tree, list) else "tuple"
        return {"__kind__": kind, "items": [_structure_of(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(structure, leaves: list) -> PyTree:
    kind = structure["__kind__"]
    if kind == "leaf":
        return leaves.pop(0)
    if kind == "dict":
        return {k: _rebuild(v, leaves) for k, v in structure["items"].items()}
    if kind == "list":
        return [_rebuild(v, leaves) for v in structure["items"]]
    if kind == "tuple":
        return tuple(_rebuild(v, leaves) for v in structure["items"])
    if kind == "namedtuple":
        # restored as plain dict: callers restoring optimizer state should
        # re-wrap; training restore does this via tree_unflatten on a template
        return {k: _rebuild(v, leaves) for k, v in structure["items"].items()}
    raise ValueError(f"unknown structure kind {kind}")


def load_pytree(path: str, template: PyTree | None = None) -> tuple[PyTree, dict]:
    """Load a checkpoint.  With ``template``, leaves are unflattened into the
    template's exact treedef (NamedTuples included); without it, the stored
    dict/list skeleton is rebuilt."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = [_unpack_leaf(d) for d in payload["leaves"]]
    if template is not None:
        treedef = jax.tree_util.tree_structure(template)
        if treedef.num_leaves != len(leaves):
            raise ValueError(f"checkpoint has {len(leaves)} leaves, template wants {treedef.num_leaves}")
        return jax.tree_util.tree_unflatten(treedef, leaves), payload["meta"]
    return _rebuild(payload["structure"], leaves), payload["meta"]


def _step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}.ckpt")


def save_train_state(
    ckpt_dir: str,
    step: int,
    state: PyTree,
    meta: dict | None = None,
    keep_last: int | None = None,
) -> str:
    """Durably save ``state`` as ``step_{step}.ckpt`` and repoint LATEST.

    The LATEST pointer is written tmp + fsync + atomic replace, so a crash
    at any instant leaves either the old or the new pointer — never a torn
    one — and the checkpoint it names is already fsynced.  ``keep_last``
    (when given) garbage-collects older ``step_*.ckpt`` files, keeping the
    newest ``keep_last`` steps; the file LATEST points at is always kept.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    path = _step_path(ckpt_dir, step)
    save_pytree(path, state, meta={"step": step, **(meta or {})})
    tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(tmp, "w") as f:
        json.dump({"step": step, "path": path}, f)
        f.flush()
        os.fsync(f.fileno())
    _replace_durable(tmp, os.path.join(ckpt_dir, "LATEST"))
    if keep_last is not None and keep_last >= 1:
        kept = sorted(
            p for p in os.listdir(ckpt_dir)
            if p.startswith("step_") and p.endswith(".ckpt")
        )
        for name in kept[:-keep_last]:
            victim = os.path.join(ckpt_dir, name)
            if os.path.abspath(victim) == os.path.abspath(path):
                continue
            try:
                os.remove(victim)
            except OSError:
                pass
    return path


def restore_train_state(ckpt_dir: str, template: PyTree | None = None) -> tuple[PyTree, dict] | None:
    latest = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        info = json.load(f)
    return load_pytree(info["path"], template=template)

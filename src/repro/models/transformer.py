"""Decoder stack builder: one code path for all 10 assigned architectures.

The layer sequence is ``layer_kinds(cfg)`` (attn / swa / mamba / rwkv cycled
from ``cfg.block_pattern``) with per-layer FFN kinds from ``ffn_kinds``.  The
stack is compiled as:

    stack:  n_full repetitions of the repeating unit, parameters stacked on a
            leading period axis and executed with ``lax.scan`` (keeps HLO and
            512-device SPMD compile times tractable; DESIGN.md §8), remat
            around each unit,
    tail:   n_layers % unit leftover layers, unrolled (gemma3's 34 = 5×6 + 4).

Training/prefill = ``forward``; decode = ``decode_step`` (one token, caches
threaded through the same scan as stacked xs/ys).  Losses are computed with a
chunked fused-CE so the (B, S, vocab) logits tensor never materialises.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ffn_kinds, layer_kinds
from repro.core.initialisation import InitConfig
from .attention import (
    attention_decode,
    attention_forward,
    attention_prefill,
    init_attention,
    init_kv_cache,
)
from .common import KeyGen, dense_init, norm_apply, norm_init
from .mamba import init_mamba, init_mamba_cache, mamba_decode, mamba_forward, mamba_prefill
from .mlp import ffn_forward, init_ffn
from .moe import init_moe, moe_forward
from .rwkv import (
    init_rwkv,
    init_rwkv_cache,
    rwkv_channel_mix,
    rwkv_time_mix,
    rwkv_time_mix_step,
)

PyTree = Any

__all__ = [
    "unit_size",
    "init_params",
    "forward",
    "init_cache",
    "decode_step",
    "prefill_cache",
    "lm_loss",
    "hidden_to_logits",
]


# ----------------------------------------------------------------- structure
def _index_stack(stack: list, per: int) -> tuple:
    """Select one period's block params/caches from the stacked trees."""
    return tuple(jax.tree_util.tree_map(lambda t: t[per], p) for p in stack)


def unit_size(cfg: ArchConfig) -> int:
    """Length of the repeating layer unit (pattern period ∨ MoE period)."""
    u = len(cfg.block_pattern)
    if cfg.is_moe:
        u = math.lcm(u, cfg.moe_period)
    return min(u, cfg.n_layers)


def _split_layers(cfg: ArchConfig) -> tuple[int, int, int]:
    """(unit, n_full_periods, n_tail_layers)."""
    u = unit_size(cfg)
    n_full = cfg.n_layers // u
    tail = cfg.n_layers - n_full * u
    return u, n_full, tail


# ----------------------------------------------------------------- init
def _init_block(init_cfg: InitConfig, key: jax.Array, cfg: ArchConfig, kind: str, fk: str) -> PyTree:
    kg = KeyGen(key)
    dt = cfg.param_dtype
    p: PyTree = {"norm1": norm_init(cfg.d_model, cfg.norm, dt)}
    if kind in ("attn", "swa"):
        p["attn"] = init_attention(init_cfg, kg(), cfg)
    elif kind == "mamba":
        p["mamba"] = init_mamba(init_cfg, kg(), cfg)
    elif kind == "rwkv":
        p["rwkv"] = init_rwkv(init_cfg, kg(), cfg)
    else:
        raise ValueError(f"unknown block kind {kind}")
    if kind != "rwkv":
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dt)
        p["ffn"] = init_moe(init_cfg, kg(), cfg) if fk == "moe" else init_ffn(init_cfg, kg(), cfg)
    else:
        p["norm2"] = norm_init(cfg.d_model, cfg.norm, dt)
    return p


def init_params(key: jax.Array, cfg: ArchConfig, init_cfg: InitConfig) -> PyTree:
    kg = KeyGen(key)
    kinds = layer_kinds(cfg)
    fkinds = ffn_kinds(cfg)
    u, n_full, tail = _split_layers(cfg)

    stack = []
    for j in range(u):  # one stacked tree per position-in-unit
        keys = jax.random.split(kg(), n_full)
        stacked = jax.vmap(lambda k: _init_block(init_cfg, k, cfg, kinds[j], fkinds[j]))(keys)
        stack.append(stacked)
    tail_blocks = [
        _init_block(init_cfg, kg(), cfg, kinds[n_full * u + j], fkinds[n_full * u + j]) for j in range(tail)
    ]

    params: PyTree = {
        "embed": {"tok": dense_init(init_cfg, kg(), (cfg.vocab_size, cfg.d_model), cfg.param_dtype)},
        "stack": stack,
        "tail": tail_blocks,
        "final_norm": norm_init(cfg.d_model, cfg.norm, cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(init_cfg, kg(), (cfg.d_model, cfg.vocab_size), cfg.param_dtype)
    if cfg.frontend:
        params["frontend_proj"] = dense_init(
            init_cfg, kg(), (cfg.frontend_embed_dim, cfg.d_model), cfg.param_dtype, bias=True
        )
    return params


# ----------------------------------------------------------------- forward
def _block_forward(p: PyTree, cfg: ArchConfig, kind: str, fk: str, x: jax.Array, positions: jax.Array):
    """Residual block (training/prefill, no cache). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm1"], x, cfg.norm)
    if kind in ("attn", "swa"):
        window = cfg.sliding_window if kind == "swa" else 0
        x = x + attention_forward(p["attn"], cfg, h, positions, window)
    elif kind == "mamba":
        x = x + mamba_forward(p["mamba"], cfg, h)
    elif kind == "rwkv":
        # rwkv block: x += tmix(ln1(x)); x += cmix(ln2(x)) — zero initial
        # shift/state for training/prefill
        nh = cfg.d_model // cfg.rwkv_head_dim
        state0 = jnp.zeros(x.shape[:-2] + (nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
        prev0 = jnp.zeros(x.shape[:-2] + (1, x.shape[-1]), x.dtype)
        y_t, _, _ = rwkv_time_mix(p["rwkv"]["tmix"], cfg, h, prev0, state0)
        x = x + y_t
        h2 = norm_apply(p["norm2"], x, cfg.norm)
        y_c, _ = rwkv_channel_mix(p["rwkv"]["cmix"], h2, prev0)
        return x + y_c, aux
    h2 = norm_apply(p["norm2"], x, cfg.norm)
    if fk == "moe":
        y, aux = moe_forward(p["ffn"], cfg, h2)
        x = x + y
    elif fk == "dense":
        x = x + ffn_forward(p["ffn"], cfg, h2)
    return x, aux


def _embed(params: PyTree, cfg: ArchConfig, tokens: jax.Array, frontend_embeds: jax.Array | None):
    x = params["embed"]["tok"]["w"][tokens]
    if cfg.frontend and frontend_embeds is not None:
        proj = jnp.einsum("...ne,ed->...nd", frontend_embeds, params["frontend_proj"]["w"])
        proj = proj + params["frontend_proj"]["b"].astype(proj.dtype)
        x = jnp.concatenate([proj.astype(x.dtype), x], axis=-2)
    return x


def forward(
    params: PyTree,
    cfg: ArchConfig,
    tokens: jax.Array,
    frontend_embeds: jax.Array | None = None,
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence pass → (final hidden states (..., S, D), moe aux loss)."""
    kinds = layer_kinds(cfg)
    fkinds = ffn_kinds(cfg)
    u, n_full, tail = _split_layers(cfg)
    x = _embed(params, cfg, tokens, frontend_embeds)
    positions = jnp.arange(x.shape[-2])

    def unit_fn(x, unit_params):
        aux = jnp.zeros((), jnp.float32)
        for j in range(u):
            x, a = _block_forward(unit_params[j], cfg, kinds[j], fkinds[j], x, positions)
            aux = aux + a
        return x, aux

    body = jax.checkpoint(unit_fn) if remat else unit_fn

    if n_full > 2:
        x, auxs = jax.lax.scan(lambda c, ps: body(c, ps), x, tuple(params["stack"]))
        aux = auxs.sum()
    else:
        # unrolled path: exact HLO op counts for the roofline's two-point
        # per-period cost extrapolation (scan bodies are counted once by
        # XLA cost analysis; see launch/roofline.py)
        aux = jnp.zeros((), jnp.float32)
        for per in range(n_full):
            x, a = body(x, _index_stack(params["stack"], per))
            aux = aux + a

    for j, bp in enumerate(params["tail"]):
        x, a = _block_forward(bp, cfg, kinds[n_full * u + j], fkinds[n_full * u + j], x, positions)
        aux = aux + a
    x = norm_apply(params["final_norm"], x, cfg.norm)
    return x, aux


def hidden_to_logits(params: PyTree, cfg: ArchConfig, hidden: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...sd,vd->...sv", hidden, params["embed"]["tok"]["w"])
    return jnp.einsum("...sd,dv->...sv", hidden, params["lm_head"]["w"])


def lm_loss(
    params: PyTree,
    cfg: ArchConfig,
    hidden: jax.Array,
    targets: jax.Array,
    chunk: int = 512,
) -> jax.Array:
    """Fused chunked softmax-CE: logits materialise one sequence chunk at a
    time ((..., chunk, V) instead of (..., S, V)) — essential at V = 262k."""
    s = hidden.shape[-2]
    chunk = min(chunk, s)
    n_chunks = s // chunk
    rem = s - n_chunks * chunk

    @jax.checkpoint
    def ce(h, t):
        logits = hidden_to_logits(params, cfg, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        return (lse - picked).sum()

    # unrolled (static) chunk loop: per-chunk remat bounds the live logits to
    # one (..., chunk, V) tile, and the unrolled HLO keeps cost_analysis
    # honest (a scan here would count one chunk only — see launch/roofline)
    total = jnp.zeros((), jnp.float32)
    for i in range(n_chunks):
        total = total + ce(
            jax.lax.slice_in_dim(hidden, i * chunk, (i + 1) * chunk, axis=hidden.ndim - 2),
            jax.lax.slice_in_dim(targets, i * chunk, (i + 1) * chunk, axis=targets.ndim - 1),
        )
    if rem:
        total = total + ce(hidden[..., -rem:, :], targets[..., -rem:])
    n_tokens = math.prod(targets.shape)
    return total / n_tokens


# ----------------------------------------------------------------- decode
def _init_block_cache(cfg: ArchConfig, kind: str, batch_shape: tuple[int, ...], cache_len: int) -> PyTree:
    if kind == "attn":
        return init_kv_cache(cfg, batch_shape, cache_len)
    if kind == "swa":
        return init_kv_cache(cfg, batch_shape, min(cfg.sliding_window, cache_len))
    if kind == "mamba":
        return init_mamba_cache(cfg, batch_shape)
    if kind == "rwkv":
        return init_rwkv_cache(cfg, batch_shape)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch_shape: tuple[int, ...], cache_len: int) -> PyTree:
    kinds = layer_kinds(cfg)
    u, n_full, tail = _split_layers(cfg)

    stack = []
    for j in range(u):
        one = _init_block_cache(cfg, kinds[j], batch_shape, cache_len)
        stacked = jax.tree_util.tree_map(lambda t: jnp.broadcast_to(t, (n_full,) + t.shape).copy(), one)
        stack.append(stacked)
    tail_caches = [
        _init_block_cache(cfg, kinds[n_full * u + j], batch_shape, cache_len) for j in range(tail)
    ]
    return {"stack": stack, "tail": tail_caches}


def _block_decode(p: PyTree, cfg: ArchConfig, kind: str, fk: str, x: jax.Array, cache: PyTree, pos: jax.Array):
    h = norm_apply(p["norm1"], x, cfg.norm)
    if kind in ("attn", "swa"):
        window = cfg.sliding_window if kind == "swa" else 0
        y, cache = attention_decode(p["attn"], cfg, h, cache, pos, window)
        x = x + y
    elif kind == "mamba":
        y, cache = mamba_decode(p["mamba"], cfg, h, cache)
        x = x + y
    elif kind == "rwkv":
        y_t, tshift, state = rwkv_time_mix_step(
            p["rwkv"]["tmix"], cfg, h, cache["tshift"], cache["state"]
        )
        x = x + y_t
        h2 = norm_apply(p["norm2"], x, cfg.norm)
        y_c, cshift = rwkv_channel_mix(p["rwkv"]["cmix"], h2, cache["cshift"].astype(h2.dtype))
        x = x + y_c
        cache = {
            "tshift": tshift.astype(cache["tshift"].dtype),
            "cshift": cshift.astype(cache["cshift"].dtype),
            "state": state,
        }
        return x, cache
    h2 = norm_apply(p["norm2"], x, cfg.norm)
    if fk == "moe":
        y, _ = moe_forward(p["ffn"], cfg, h2)
        x = x + y
    elif fk == "dense":
        x = x + ffn_forward(p["ffn"], cfg, h2)
    return x, cache


def _block_prefill(
    p: PyTree,
    cfg: ArchConfig,
    kind: str,
    fk: str,
    x: jax.Array,
    positions: jax.Array,
    cache: PyTree,
):
    """Residual block over the full prompt that also fills the decode cache."""
    h = norm_apply(p["norm1"], x, cfg.norm)
    if kind in ("attn", "swa"):
        window = cfg.sliding_window if kind == "swa" else 0
        y, cache = attention_prefill(p["attn"], cfg, h, positions, cache, window)
        x = x + y
    elif kind == "mamba":
        y, cache = mamba_prefill(p["mamba"], cfg, h)
        x = x + y
    elif kind == "rwkv":
        # the full-sequence mixers already return exactly the decode cache:
        # the final wkv state and the last-token shift inputs
        nh = cfg.d_model // cfg.rwkv_head_dim
        state0 = jnp.zeros(x.shape[:-2] + (nh, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32)
        prev0 = jnp.zeros(x.shape[:-2] + (1, x.shape[-1]), x.dtype)
        y_t, tshift, state = rwkv_time_mix(p["rwkv"]["tmix"], cfg, h, prev0, state0)
        x = x + y_t
        h2 = norm_apply(p["norm2"], x, cfg.norm)
        y_c, cshift = rwkv_channel_mix(p["rwkv"]["cmix"], h2, prev0)
        x = x + y_c
        cache = {
            "tshift": tshift.astype(cache["tshift"].dtype),
            "cshift": cshift.astype(cache["cshift"].dtype),
            "state": state,
        }
        return x, cache
    h2 = norm_apply(p["norm2"], x, cfg.norm)
    if fk == "moe":
        y, _ = moe_forward(p["ffn"], cfg, h2)
        x = x + y
    elif fk == "dense":
        x = x + ffn_forward(p["ffn"], cfg, h2)
    return x, cache


def prefill_cache(
    params: PyTree,
    cfg: ArchConfig,
    tokens: jax.Array,
    cache_len: int,
    frontend_embeds: jax.Array | None = None,
) -> tuple[jax.Array, PyTree]:
    """Batched prefill: one full-sequence pass that fills the decode cache.

    tokens (..., S) int32.  Returns (last-position logits (..., V), cache
    ready for ``decode_step`` at ``pos = S``).  Mirrors ``decode_step``'s
    stack-scan / unrolled split so the cache trees line up leaf for leaf.
    """
    kinds = layer_kinds(cfg)
    fkinds = ffn_kinds(cfg)
    u, n_full, tail = _split_layers(cfg)
    cache = init_cache(cfg, tokens.shape[:-1], cache_len)
    x = _embed(params, cfg, tokens, frontend_embeds)
    positions = jnp.arange(x.shape[-2])

    def unit_fn(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = []
        for j in range(u):
            x, c = _block_prefill(
                unit_params[j], cfg, kinds[j], fkinds[j], x, positions, unit_cache[j]
            )
            new_caches.append(c)
        return x, tuple(new_caches)

    if n_full > 2:
        x, new_stack = jax.lax.scan(unit_fn, x, (tuple(params["stack"]), tuple(cache["stack"])))
        new_stack = list(new_stack)
    else:
        per_caches = []
        for per in range(n_full):
            ps = _index_stack(params["stack"], per)
            cs = _index_stack(cache["stack"], per)
            x, ncs = unit_fn(x, (ps, cs))
            per_caches.append(ncs)
        new_stack = [
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[pc[j] for pc in per_caches])
            for j in range(u)
        ]

    new_tail = []
    for j, bp in enumerate(params["tail"]):
        x, c = _block_prefill(
            bp, cfg, kinds[n_full * u + j], fkinds[n_full * u + j], x, positions, cache["tail"][j]
        )
        new_tail.append(c)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = hidden_to_logits(params, cfg, x[..., -1:, :])
    return logits[..., 0, :], {"stack": new_stack, "tail": new_tail}


def decode_step(
    params: PyTree,
    cfg: ArchConfig,
    cache: PyTree,
    tokens: jax.Array,
    pos: jax.Array,
) -> tuple[jax.Array, PyTree]:
    """One decode step. tokens (..., 1) int32; pos () int32 = absolute index.

    Returns (logits (..., 1, V), new cache).
    """
    kinds = layer_kinds(cfg)
    fkinds = ffn_kinds(cfg)
    u, n_full, tail = _split_layers(cfg)
    x = _embed(params, cfg, tokens, None)

    def unit_fn(x, scanned):
        unit_params, unit_cache = scanned
        new_caches = []
        for j in range(u):
            x, c = _block_decode(unit_params[j], cfg, kinds[j], fkinds[j], x, unit_cache[j], pos)
            new_caches.append(c)
        return x, tuple(new_caches)

    if n_full > 2:
        x, new_stack = jax.lax.scan(unit_fn, x, (tuple(params["stack"]), tuple(cache["stack"])))
        new_stack = list(new_stack)
    else:
        # unrolled path (see forward): exact op counts for roofline extrapolation
        per_caches = []
        for per in range(n_full):
            ps = _index_stack(params["stack"], per)
            cs = _index_stack(cache["stack"], per)
            x, ncs = unit_fn(x, (ps, cs))
            per_caches.append(ncs)
        new_stack = [
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[pc[j] for pc in per_caches])
            for j in range(u)
        ]

    new_tail = []
    for j, bp in enumerate(params["tail"]):
        x, c = _block_decode(bp, cfg, kinds[n_full * u + j], fkinds[n_full * u + j], x, cache["tail"][j], pos)
        new_tail.append(c)
    x = norm_apply(params["final_norm"], x, cfg.norm)
    logits = hidden_to_logits(params, cfg, x)
    return logits, {"stack": new_stack, "tail": new_tail}

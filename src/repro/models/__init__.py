"""Model zoo: unified decoder stack for the 10 assigned architectures +
the paper's own MLP/CNN/VGG16."""
from . import attention, common, mamba, mlp, moe, paper_models, rwkv, transformer
from .transformer import decode_step, forward, hidden_to_logits, init_cache, init_params, lm_loss

"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time-mix with
data-dependent decay + channel-mix FFN.

Per head (head_dim = M), with data-dependent per-channel decay w_t ∈ (0,1):

    S_t = diag(w_t) S_{t-1} + k_tᵀ v_t               S ∈ R^{M×M}
    o_t = r_t (S_{t-1} + diag(u) k_tᵀ v_t)           u = time_first "bonus"

TPU-native rendering: chunked scan — the inter-chunk state carry is a
``lax.scan``; intra-chunk work is dense matmuls with cumulative-decay
weighting (the same blocking the Pallas kernel ``repro.kernels.rwkv`` uses,
which this module's math validates against).

Structured params (decay base, bonus u, token-shift mixes) are not
gain-corrected; dense projections are (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.initialisation import InitConfig
from .common import KeyGen, dense_init, norm_apply, norm_init

PyTree = Any

__all__ = ["init_rwkv", "rwkv_time_mix", "rwkv_channel_mix", "rwkv_time_mix_step", "init_rwkv_cache"]

# chunk 32 × clamped per-step log-decay 2.72 → mid-referenced exponent span
# <= 32/2 × 2.72 ≈ 44 — comfortably inside fp32's exp range (~88)
_CHUNK = 32


def _n_heads(cfg: ArchConfig) -> int:
    assert cfg.d_model % cfg.rwkv_head_dim == 0
    return cfg.d_model // cfg.rwkv_head_dim


def init_rwkv(init_cfg: InitConfig, key: jax.Array, cfg: ArchConfig) -> PyTree:
    kg = KeyGen(key)
    d = cfg.d_model
    h = _n_heads(cfg)
    m = cfg.rwkv_head_dim
    f = cfg.d_ff
    dt = cfg.param_dtype
    lora = max(32, d // 16)  # decay LoRA rank (rwkv6 uses 64 at 2k..4k widths)
    # structured: decay base spread over channels, bonus, token-shift mixes
    ratio = jnp.arange(d, dtype=jnp.float32) / max(d - 1, 1)
    decay_base = -6.0 + 5.0 * ratio**0.7  # rwkv6 init: w in a broad range
    bonus = jnp.zeros((h, m), jnp.float32) + 0.5 * (1 - ratio).reshape(h, m)
    return {
        "tmix": {
            "mix_r": (0.5 * jnp.ones((d,), jnp.float32)).astype(dt),
            "mix_k": (0.7 * jnp.ones((d,), jnp.float32)).astype(dt),
            "mix_v": (0.7 * jnp.ones((d,), jnp.float32)).astype(dt),
            "mix_g": (0.5 * jnp.ones((d,), jnp.float32)).astype(dt),
            "mix_w": (0.6 * jnp.ones((d,), jnp.float32)).astype(dt),
            "wr": dense_init(init_cfg, kg(), (d, d), dt),
            "wk": dense_init(init_cfg, kg(), (d, d), dt),
            "wv": dense_init(init_cfg, kg(), (d, d), dt),
            "wg": dense_init(init_cfg, kg(), (d, d), dt),
            "wo": dense_init(init_cfg, kg(), (d, d), dt),
            "decay_lora_a": dense_init(init_cfg, kg(), (d, lora), dt),
            "decay_lora_b": dense_init(init_cfg, kg(), (lora, d), dt),
            "decay_base": decay_base,  # fp32 structured
            "bonus": bonus,  # fp32 structured
            "out_norm": norm_init(d, "layernorm", jnp.float32),
        },
        "cmix": {
            "mix_k": (0.7 * jnp.ones((d,), jnp.float32)).astype(dt),
            "mix_r": (0.5 * jnp.ones((d,), jnp.float32)).astype(dt),
            "wk": dense_init(init_cfg, kg(), (d, f), dt),
            "wv": dense_init(init_cfg, kg(), (f, d), dt),
            "wr": dense_init(init_cfg, kg(), (d, d), dt),
        },
    }


def _token_shift(x: jax.Array, prev: jax.Array) -> jax.Array:
    """x (..., L, D) shifted right by one; position 0 takes ``prev`` (..., 1, D)."""
    return jnp.concatenate([prev, x[..., :-1, :]], axis=-2)


def _tmix_projections(p: PyTree, x: jax.Array, xs: jax.Array, cfg: ArchConfig):
    h, m = _n_heads(cfg), cfg.rwkv_head_dim

    def lerp(mix):
        return x + (xs - x) * mix.astype(x.dtype)

    r = jnp.einsum("...ld,de->...le", lerp(p["mix_r"]), p["wr"]["w"])
    k = jnp.einsum("...ld,de->...le", lerp(p["mix_k"]), p["wk"]["w"])
    v = jnp.einsum("...ld,de->...le", lerp(p["mix_v"]), p["wv"]["w"])
    g = jax.nn.silu(jnp.einsum("...ld,de->...le", lerp(p["mix_g"]), p["wg"]["w"]))
    # data-dependent decay (the "Finch" feature): base + LoRA(x)
    dw = jnp.einsum("...le,ef->...lf", jnp.tanh(jnp.einsum("...ld,de->...le", lerp(p["mix_w"]), p["decay_lora_a"]["w"])), p["decay_lora_b"]["w"])
    # stability clamp (TPU adaptation, DESIGN.md): bounds the per-step
    # log-decay to >= -e so chunked exponent spans stay inside fp32 range
    z = jnp.clip(p["decay_base"] + dw.astype(jnp.float32), -8.0, 1.0)
    w = jnp.exp(-jnp.exp(z))  # (..., L, D) in (0, 1), per-step log-decay >= -2.72
    shp = x.shape[:-1]
    return (
        r.reshape(shp + (h, m)),
        k.reshape(shp + (h, m)),
        v.reshape(shp + (h, m)),
        g,
        w.reshape(shp + (h, m)),
    )


def _wkv_chunked(r, k, v, w, bonus, state, unroll: bool = False):
    """Chunked linear attention with per-channel decay.

    r,k,v,w: (..., L, H, M) with L a multiple of the chunk size (caller pads);
    state:   (..., H, M, M) carried across chunks (fp32).
    Returns (out (..., L, H, M), state').

    Intra-chunk (length c), with cumulative decay  W_t = Π_{τ<=t} diag(w_τ):
        contribution of state:  r_t W_{t-1} S
        intra-chunk pairs:      Σ_{s<t} r_t W_{t-1} W_s⁻¹ k_sᵀ v_s + bonus pair
    computed as dense (c×c) score matmuls — the MXU-friendly form.
    """
    lead = r.shape[:-3]
    l, h, m = r.shape[-3], r.shape[-2], r.shape[-1]
    c = min(_CHUNK, l)
    nc = l // c
    resh = lambda t: jnp.moveaxis(t.reshape(lead + (nc, c, h, m)), -4, 0)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)  # (nc, ..., c, H, M)

    def chunk(state, inputs):
        rr, kk, vv, ww = inputs  # (..., c, H, M)
        rr32, kk32, vv32, ww32 = (t.astype(jnp.float32) for t in (rr, kk, vv, ww))
        logw = jnp.log(jnp.clip(ww32, 1e-20))
        cum = jnp.cumsum(logw, axis=-3)  # log W_t, inclusive
        # state-in contribution: r_t W_{t-1} S — exponent cum_{t-1} <= 0, safe
        rq = rr32 * jnp.exp(cum - logw)
        out = jnp.einsum("...thm,...hmn->...thn", rq, state)
        # intra-chunk pairs: r_t k_s e^{cum_{t-1} - cum_s}, s < t.  Factorising
        # around the mid-chunk cumulative keeps both factors' exponents within
        # ±(span/2) — with the per-step log-decay clamp this stays inside fp32
        # range for the chunk size used here.
        mid = cum[..., c // 2 : c // 2 + 1, :, :]
        rq2 = rr32 * jnp.exp(cum - logw - mid)
        kd2 = kk32 * jnp.exp(mid - cum)
        scores = jnp.einsum("...thm,...shm->...hts", rq2, kd2)  # (..., H, c, c)
        tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)
        scores = scores * tri
        out = out + jnp.einsum("...hts,...shm->...thm", scores, vv32)
        # bonus (current-token) term: r_t diag(u) k_t^T v_t
        diag_term = jnp.einsum("...thm,hm,...thm->...th", rr32, bonus, kk32)
        out = out + jnp.einsum("...th,...thm->...thm", diag_term, vv32)
        # state update: S' = W_c S + Σ_s (W_c/W_s) k_sᵀ v_s — exponents <= 0
        wc_total = jnp.exp(cum[..., -1, :, :])  # (..., H, M)
        kfac = kk32 * jnp.exp(cum[..., -1:, :, :] - cum)
        state_new = state * wc_total[..., :, None] + jnp.einsum(
            "...shm,...shn->...hmn", kfac, vv32
        )
        return state_new, out

    if unroll:
        # roofline instrumentation: unrolled chunk loop (see configs/base.py)
        outs_list = []
        for ci in range(nc):
            state, oc = chunk(state, (rc[ci], kc[ci], vc[ci], wc[ci]))
            outs_list.append(oc)
        outs = jnp.stack(outs_list)
    else:
        state, outs = jax.lax.scan(chunk, state, (rc, kc, vc, wc))
    out = jnp.moveaxis(outs, 0, -4).reshape(lead + (l, h, m))
    return out, state


def init_rwkv_cache(cfg: ArchConfig, batch_shape: tuple[int, ...], dtype=None) -> PyTree:
    d = cfg.d_model
    h, m = _n_heads(cfg), cfg.rwkv_head_dim
    dt = dtype or cfg.param_dtype
    return {
        "tshift": jnp.zeros(batch_shape + (1, d), dt),
        "cshift": jnp.zeros(batch_shape + (1, d), dt),
        "state": jnp.zeros(batch_shape + (h, m, m), jnp.float32),
    }


def rwkv_time_mix(p: PyTree, cfg: ArchConfig, x: jax.Array, prev: jax.Array, state: jax.Array):
    """Full-sequence time-mix. Returns (y, last_token, state')."""
    unroll = cfg.unroll_scans
    h, m = _n_heads(cfg), cfg.rwkv_head_dim
    xs = _token_shift(x, prev)
    r, k, v, g, w = _tmix_projections(p, x, xs, cfg)
    l = x.shape[-2]
    c = min(_CHUNK, l)
    pad = (-l) % c
    if pad:
        padt = lambda t: jnp.pad(t, [(0, 0)] * (t.ndim - 3) + [(0, pad), (0, 0), (0, 0)])
        # pad decay with ones so padding tokens don't decay the state
        r, k, v = padt(r), padt(k), padt(v)
        w = jnp.pad(w, [(0, 0)] * (w.ndim - 3) + [(0, pad), (0, 0), (0, 0)], constant_values=1.0)
    out, state = _wkv_chunked(r, k, v, w, p["bonus"], state, unroll=unroll)
    if pad:
        out = out[..., :l, :, :]
    out = out.reshape(x.shape[:-1] + (h * m,))
    out = norm_apply(p["out_norm"], out, "layernorm")
    y = jnp.einsum("...ld,de->...le", out.astype(x.dtype) * g, p["wo"]["w"])
    return y, x[..., -1:, :], state


def rwkv_channel_mix(p: PyTree, x: jax.Array, prev: jax.Array):
    xs = _token_shift(x, prev)
    lerp = lambda mix: x + (xs - x) * mix.astype(x.dtype)
    k = jnp.einsum("...ld,df->...lf", lerp(p["mix_k"]), p["wk"]["w"])
    k = jnp.square(jax.nn.relu(k))
    v = jnp.einsum("...lf,fd->...ld", k, p["wv"]["w"])
    r = jax.nn.sigmoid(jnp.einsum("...ld,de->...le", lerp(p["mix_r"]), p["wr"]["w"]))
    return r * v, x[..., -1:, :]


def rwkv_time_mix_step(p: PyTree, cfg: ArchConfig, x: jax.Array, tshift: jax.Array, state: jax.Array):
    """Single-token time-mix (L = 1): direct recurrence, O(1) state.

    x (..., 1, D); tshift (..., 1, D) = previous token's input; state
    (..., H, M, M).  Returns (y (..., 1, D), new_tshift, new_state).
    """
    h, m = _n_heads(cfg), cfg.rwkv_head_dim
    xs = tshift.astype(x.dtype)
    r, k, v, g, w = _tmix_projections(p, x, xs, cfg)
    r32, k32, v32, w32 = (t[..., 0, :, :].astype(jnp.float32) for t in (r, k, v, w))
    kv = jnp.einsum("...hm,...hn->...hmn", k32, v32)
    out = jnp.einsum("...hm,...hmn->...hn", r32, state + p["bonus"] [..., :, None] * kv)
    new_state = state * w32[..., :, None] + kv
    out = out.reshape(x.shape[:-2] + (h * m,))
    out = norm_apply(p["out_norm"], out[..., None, :], "layernorm")
    y = jnp.einsum("...ld,de->...le", out.astype(x.dtype) * g, p["wo"]["w"])
    return y, x, new_state

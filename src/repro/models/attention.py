"""Grouped-query attention with RoPE, sliding-window option and KV cache.

The XLA einsum path below is the lowering used for dry-runs and CPU smoke
tests; ``repro.kernels.flash`` is the TPU Pallas rendering of the same math
(validated against ``repro.kernels.flash.ref`` which mirrors this module).

Shapes (node/batch axes lead and broadcast):
    x          (..., S, D)
    wq         (D, H, hd)        wk/wv (D, KVH, hd)       wo (H, hd, D)
    cache k/v  (..., S_cache, KVH, hd)
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.initialisation import InitConfig
from .common import KeyGen, apply_rope, dense_init

PyTree = Any

__all__ = [
    "init_attention",
    "attention_forward",
    "attention_decode",
    "attention_prefill",
    "init_kv_cache",
]


def init_attention(init_cfg: InitConfig, key: jax.Array, cfg: ArchConfig) -> PyTree:
    kg = KeyGen(key)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    dt = cfg.param_dtype
    p = {
        "wq": dense_init(init_cfg, kg(), (d, h * hd), dt, bias=cfg.qkv_bias),
        "wk": dense_init(init_cfg, kg(), (d, kvh * hd), dt, bias=cfg.qkv_bias),
        "wv": dense_init(init_cfg, kg(), (d, kvh * hd), dt, bias=cfg.qkv_bias),
        "wo": dense_init(init_cfg, kg(), (h * hd, d), dt, bias=False),
    }
    return p


def _project(p: PyTree, x: jax.Array, n_heads: int, hd: int) -> jax.Array:
    y = jnp.einsum("...sd,df->...sf", x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y.reshape(y.shape[:-1] + (n_heads, hd))


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array, scale: float) -> jax.Array:
    """q (...,S,H,hd), k/v (...,T,KVH,hd) -> (...,S,H,hd); GQA via head groups.

    fp32 softmax; mask is additive-bool (True = attend).
    """
    h = q.shape[-2]
    kvh = k.shape[-2]
    group = h // kvh
    qg = q.reshape(q.shape[:-2] + (kvh, group, q.shape[-1]))
    scores = jnp.einsum("...sngd,...tnd->...ngst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    scores = jnp.where(mask[..., None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("...ngst,...tnd->...sngd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(out.shape[:-3] + (h, out.shape[-1])).astype(q.dtype)


def _causal_mask(s: int, window: int = 0) -> jax.Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window > 0:
        m = m & (j > i - window)
    return m


def attention_forward(
    p: PyTree, cfg: ArchConfig, x: jax.Array, positions: jax.Array, window: int = 0
) -> jax.Array:
    """Full-sequence (training / prefill) attention; causal, optionally SWA.

    Implementation selected by the §Perf config knobs:
      * window > 0 and swa_impl == "blocked" and S a multiple of the window →
        band attention over [prev, self] window blocks (O(S·2w)),
      * attn_impl == "chunked" → flash-style q-chunked online softmax
        (O(c·S) live score memory),
      * otherwise the baseline (S, S) masked softmax.
    """
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = x.shape[-2]
    q = _project(p["wq"], x, h, hd)
    k = _project(p["wk"], x, kvh, hd)
    v = _project(p["wv"], x, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    scale = 1.0 / (hd**0.5)
    if window > 0 and cfg.swa_impl == "blocked" and s % window == 0 and s > window:
        out = _sdpa_banded(q, k, v, window, scale)
    elif cfg.attn_impl == "chunked" and s >= 512:
        out = _sdpa_chunked(q, k, v, window, scale, unroll=cfg.unroll_scans)
    else:
        mask = _causal_mask(s, window)
        out = _sdpa(q, k, v, mask, scale)
    out = out.reshape(out.shape[:-2] + (h * hd,))
    return jnp.einsum("...sf,fd->...sd", out, p["wo"]["w"])


def _sdpa_banded(q: jax.Array, k: jax.Array, v: jax.Array, window: int, scale: float) -> jax.Array:
    """Band attention for sliding-window layers (beyond-paper §Perf).

    Block the sequence into S/w blocks of the window size w; every query in
    block b can only see keys in blocks {b-1, b} (any key within w of a
    causal query lies there).  Scores are (nb, w, 2w) — compute and live
    memory O(S·2w) instead of O(S²).
    """
    lead = q.shape[:-3]
    s, h, hd = q.shape[-3], q.shape[-2], q.shape[-1]
    kvh = k.shape[-2]
    w = window
    nb = s // w
    qb = q.reshape(lead + (nb, w, h, hd))
    kb = k.reshape(lead + (nb, w, kvh, hd))
    vb = v.reshape(lead + (nb, w, kvh, hd))
    # prev-block keys: shift right by one block, zero-pad block 0
    pad = [(0, 0)] * len(lead) + [(1, 0), (0, 0), (0, 0), (0, 0)]
    kprev = jnp.pad(kb, pad)[..., :-1, :, :, :]
    vprev = jnp.pad(vb, pad)[..., :-1, :, :, :]
    k2 = jnp.concatenate([kprev, kb], axis=-3)  # (..., nb, 2w, KVH, hd)
    v2 = jnp.concatenate([vprev, vb], axis=-3)
    # relative mask within a block pair: query index i (0..w-1, absolute
    # b·w + i) vs key index j (0..2w-1, absolute (b-1)·w + j)
    i = jnp.arange(w)[:, None]
    j = jnp.arange(2 * w)[None, :]
    rel = (i + w) - j  # (absolute query) - (absolute key)
    mask = (rel >= 0) & (rel < w)  # causal ∧ within window
    # block 0 has no prev block: mask out the padded keys
    mask0 = mask & (j >= w)
    masks = jnp.where(jnp.arange(nb)[:, None, None] == 0, mask0[None], mask[None])
    out = _sdpa(qb, k2, v2, masks, scale)  # broadcasting over nb
    return out.reshape(lead + (s, h, hd))


def _sdpa_chunked(
    q: jax.Array, k: jax.Array, v: jax.Array, window: int, scale: float, chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Flash-style q-chunked attention in pure XLA (beyond-paper §Perf).

    Processes q in chunks of ``chunk`` against the full K/V with the exact
    (non-online) softmax per chunk — live score memory is (chunk, S) per
    step instead of (S, S).  ``unroll`` mirrors cfg.unroll_scans for honest
    roofline op counts.
    """
    lead = q.shape[:-3]
    s, h, hd = q.shape[-3], q.shape[-2], q.shape[-1]
    kvh = k.shape[-2]
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    qp = jnp.pad(q, [(0, 0)] * len(lead) + [(0, pad), (0, 0), (0, 0)]) if pad else q
    qc = jnp.moveaxis(qp.reshape(lead + (nc, c, h, hd)), -4, 0)  # (nc, ..., c, h, hd)

    jpos = jnp.arange(s)[None, :]

    def one(ci, qchunk):
        ipos = ci * c + jnp.arange(c)[:, None]
        mask = jpos <= ipos
        if window > 0:
            mask = mask & (jpos > ipos - window)
        return _sdpa(qchunk, k, v, mask, scale)

    if unroll:
        outs = jnp.stack([one(ci, qc[ci]) for ci in range(nc)])
    else:
        outs = jax.lax.map(lambda t: one(t[0], t[1]), (jnp.arange(nc), qc))
    out = jnp.moveaxis(outs, 0, -4).reshape(lead + (nc * c, h, hd))
    return out[..., :s, :, :]


def init_kv_cache(cfg: ArchConfig, batch_shape: tuple[int, ...], cache_len: int, dtype=None) -> PyTree:
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dt = dtype or cfg.param_dtype
    shape = batch_shape + (cache_len, kvh, hd)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def attention_prefill(
    p: PyTree,
    cfg: ArchConfig,
    x: jax.Array,
    positions: jax.Array,
    cache: PyTree,
    window: int = 0,
) -> tuple[jax.Array, PyTree]:
    """Full-prompt prefill with one batched KV-cache insert.

    x (..., S, D); positions (S,) absolute; cache k/v (..., T, KVH, hd).
    Attention is the plain causal (optionally windowed) pass; the last
    ``min(S, T)`` keys/values are then written at ``positions % T`` — the
    exact slots token-by-token ``attention_decode`` writes would have left
    (consecutive positions mod T are unique slots), so a decode resuming at
    ``pos = S`` sees an identical ring buffer.
    """
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    s = x.shape[-2]
    t = cache["k"].shape[-3]
    q = _project(p["wq"], x, h, hd)
    k = _project(p["wk"], x, kvh, hd)
    v = _project(p["wv"], x, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    mask = _causal_mask(s, window)
    out = _sdpa(q, k, v, mask, 1.0 / (hd**0.5))

    w = min(s, t)
    slots = (positions[s - w :] % t).astype(jnp.int32)
    kc = cache["k"].at[..., slots, :, :].set(k[..., s - w :, :, :].astype(cache["k"].dtype))
    vc = cache["v"].at[..., slots, :, :].set(v[..., s - w :, :, :].astype(cache["v"].dtype))

    out = out.reshape(out.shape[:-2] + (h * hd,))
    y = jnp.einsum("...sf,fd->...sd", out, p["wo"]["w"])
    return y, {"k": kc, "v": vc}


def attention_decode(
    p: PyTree,
    cfg: ArchConfig,
    x: jax.Array,
    cache: PyTree,
    pos: jax.Array,
    window: int = 0,
) -> tuple[jax.Array, PyTree]:
    """One-token decode: x (..., 1, D); cache k/v (..., T, KVH, hd); pos ().

    The new K/V is written at ``pos % T`` — a plain slot write for full
    caches (T = max context) and a *ring buffer* for sliding-window layers
    (T = window), which is what keeps gemma3 local layers O(window) at 500k.
    """
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    t = cache["k"].shape[-3]
    q = _project(p["wq"], x, h, hd)
    k_new = _project(p["wk"], x, kvh, hd)
    v_new = _project(p["wv"], x, kvh, hd)
    q = apply_rope(q, pos[None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[None], cfg.rope_theta)

    slot = (pos % t).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=-3)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=-3)

    # valid slots: absolute index of slot j is pos - ((slot - j) mod T)
    j = jnp.arange(t)
    age = jnp.mod(slot - j, t)  # 0 for the token just written
    abs_idx = pos - age
    valid = abs_idx >= 0
    if window > 0:
        valid = valid & (abs_idx > pos - window)
    mask = valid[None, :]  # (S=1, T)

    out = _sdpa(q, k, v, mask, 1.0 / (hd**0.5))
    out = out.reshape(out.shape[:-2] + (h * hd,))
    y = jnp.einsum("...sf,fd->...sd", out, p["wo"]["w"])
    return y, {"k": k, "v": v}

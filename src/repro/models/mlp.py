"""Dense FFN variants: SwiGLU / GeGLU / classic GELU MLP."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.initialisation import InitConfig
from .common import KeyGen, dense_init

PyTree = Any

__all__ = ["init_ffn", "ffn_forward"]


def init_ffn(init_cfg: InitConfig, key: jax.Array, cfg: ArchConfig) -> PyTree:
    kg = KeyGen(key)
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.param_dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(init_cfg, kg(), (d, f), dt),
            "w_in": dense_init(init_cfg, kg(), (d, f), dt),
            "w_out": dense_init(init_cfg, kg(), (f, d), dt),
        }
    if cfg.mlp_type == "gelu_mlp":
        return {
            "w_in": dense_init(init_cfg, kg(), (d, f), dt),
            "w_out": dense_init(init_cfg, kg(), (f, d), dt),
        }
    raise ValueError(f"unknown mlp_type {cfg.mlp_type}")


def ffn_forward(p: PyTree, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        g = jnp.einsum("...sd,df->...sf", x, p["w_gate"]["w"])
        h = jnp.einsum("...sd,df->...sf", x, p["w_in"]["w"])
        return jnp.einsum("...sf,fd->...sd", act(g) * h, p["w_out"]["w"])
    h = jax.nn.gelu(jnp.einsum("...sd,df->...sf", x, p["w_in"]["w"]))
    return jnp.einsum("...sf,fd->...sd", h, p["w_out"]["w"])

"""Mixture-of-Experts FFN with sort-based (capacity-bounded) dispatch.

Design note (DESIGN.md §9 / roofline honesty): the naive "run every expert on
every token and mask" formulation inflates FLOPs by E/k, and the GShard
one-hot-dispatch einsum materialises a (tokens, E, C) tensor that dwarfs the
activations.  We instead use the sort-based dropping dispatch used by
production JAX MoE stacks:

    1. router top-k over E experts (fp32 softmax),
    2. flatten (token, k) pairs, sort by expert id,
    3. scatter tokens into an (E, C, D) buffer (C = capacity; overflow drops),
    4. batched expert FFN einsum  (E, C, D) x (E, D, F),
    5. gather back and combine with the gate probabilities.

Expert weights and the (E, C, D) buffer shard over the mesh "model" axis on
the E dimension → the scatter/gather lower to all-to-alls, which is exactly
the collective pattern the roofline analysis wants to see.

Auxiliary load-balance loss (Switch-style) is returned for the training path.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.initialisation import InitConfig
from .common import KeyGen, dense_init

PyTree = Any

__all__ = ["init_moe", "moe_forward"]


def init_moe(init_cfg: InitConfig, key: jax.Array, cfg: ArchConfig) -> PyTree:
    kg = KeyGen(key)
    d, f, e, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.param_dtype
    p: PyTree = {"router": dense_init(init_cfg, kg(), (d, e), dt)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = {"w": _expert_stack(init_cfg, kg(), e, (d, f), dt)}
        p["w_in"] = {"w": _expert_stack(init_cfg, kg(), e, (d, f), dt)}
        p["w_out"] = {"w": _expert_stack(init_cfg, kg(), e, (f, d), dt)}
    else:
        p["w_in"] = {"w": _expert_stack(init_cfg, kg(), e, (d, f), dt)}
        p["w_out"] = {"w": _expert_stack(init_cfg, kg(), e, (f, d), dt)}
    return p


def _expert_stack(init_cfg: InitConfig, key: jax.Array, e: int, shape: tuple[int, ...], dt) -> jax.Array:
    from repro.core.initialisation import scaled_init

    keys = jax.random.split(key, e)
    ws = jax.vmap(lambda k: scaled_init(init_cfg, k, shape, jnp.float32))(keys)
    return ws.astype(dt)


def _capacity(cfg: ArchConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_forward(p: PyTree, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (..., S, D) -> (y, aux_loss).  Leading axes are flattened to tokens.

    Works under vmap over the node axis too (leading axes fold into T).
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    e, k = cfg.n_experts, cfg.experts_per_token
    xt = x.reshape(-1, d)  # (T, D)
    t = xt.shape[0]
    cap = _capacity(cfg, t)

    logits = jnp.einsum("td,de->te", xt, p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gate, idx = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)  # renormalise top-k

    # ---- sort-based dispatch --------------------------------------------
    flat_e = idx.reshape(-1)  # (T*k,) expert of each (token, slot) pair
    flat_tok = jnp.repeat(jnp.arange(t), k)  # token of each pair
    order = jnp.argsort(flat_e, stable=True)  # group pairs by expert
    se = flat_e[order]
    st = flat_tok[order]
    # position within expert group = rank - first_rank_of_expert
    ranks = jnp.arange(t * k)
    first = jnp.searchsorted(se, jnp.arange(e), side="left")  # (E,)
    pos_in_e = ranks - first[se]
    keep = pos_in_e < cap
    dest = se * cap + pos_in_e  # (T*k,) slot in the (E*C) buffer
    dest = jnp.where(keep, dest, e * cap)  # overflow → scratch row

    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xt[st])
    buf = buf[: e * cap].reshape(e, cap, d)

    # ---- expert FFN (batched over E) ------------------------------------
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]["w"])
        h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"]["w"])
        y = jnp.einsum("ecf,efd->ecd", act(g) * h, p["w_out"]["w"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, p["w_in"]["w"]))
        y = jnp.einsum("ecf,efd->ecd", h, p["w_out"]["w"])
    y = y.reshape(e * cap, d)

    # ---- combine ---------------------------------------------------------
    pair_gate = jnp.where(keep, gate.reshape(-1)[order], 0.0)  # dropped pairs contribute 0
    gathered = y[jnp.clip(dest, 0, e * cap - 1)] * pair_gate[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[st].add(gathered)

    # ---- Switch aux load-balance loss ------------------------------------
    me = probs.mean(axis=0)  # mean router prob per expert
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (t * k)  # fraction routed
    aux = e * jnp.sum(me * ce)

    return out.reshape(lead + (d,)), aux

"""Mamba (selective SSM) block — the "mamba" entries of jamba's 1:7 interleave.

TPU-native adaptation (DESIGN.md): the CUDA selective-scan kernel becomes a
chunked ``lax.scan`` carrying the (d_inner, d_state) state across
sequence chunks, with the intra-chunk recurrence done by
``jax.lax.associative_scan`` — O(L·d_inner·d_state) work, chunk-bounded
memory, and a single fused XLA while-loop.

Recurrence (diagonal selective SSM):
    h_t = exp(Δ_t A) ⊙ h_{t-1} + (Δ_t B_t) x_t          h ∈ R^{d_inner × N}
    y_t = C_t · h_t + D ⊙ x_t
with Δ_t = softplus(dt_proj(x)), (B_t, C_t, Δ_rank) read from x (selective).

Structured params (A_log via S4D-real, conv kernel, dt bias, D) are NOT
gain-corrected; dense projections are (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.initialisation import InitConfig
from .common import KeyGen, dense_init

PyTree = Any

__all__ = ["init_mamba", "mamba_forward", "mamba_prefill", "mamba_decode", "init_mamba_cache"]

_CHUNK = 256


def _dt_rank(cfg: ArchConfig) -> int:
    return max(1, -(-cfg.d_model // 16))  # ceil(d_model / 16), mamba default


def init_mamba(init_cfg: InitConfig, key: jax.Array, cfg: ArchConfig) -> PyTree:
    kg = KeyGen(key)
    d = cfg.d_model
    di = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dc = cfg.mamba_d_conv
    r = _dt_rank(cfg)
    dt = cfg.param_dtype
    # S4D-real structured init for A, uniform dt bias in [1e-3, 1e-1] (mamba defaults)
    a_log = jnp.log(jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)))
    dt_init = jnp.exp(
        jax.random.uniform(kg(), (di,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)
    )
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))  # inverse softplus
    return {
        "in_proj": dense_init(init_cfg, kg(), (d, 2 * di), dt),
        "conv_w": (jax.random.uniform(kg(), (dc, di), jnp.float32, -1, 1) / jnp.sqrt(dc)).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(init_cfg, kg(), (di, r + 2 * n), dt),
        "dt_proj": dense_init(init_cfg, kg(), (r, di), dt),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": a_log,  # fp32: decay spectra are precision-sensitive
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(init_cfg, kg(), (di, d), dt),
    }


def _ssm_params(p: PyTree, cfg: ArchConfig, xc: jax.Array):
    """xc (..., L, di) → decay a (..., L, di, N), drive bx (..., L, di, N), c (..., L, N)."""
    n = cfg.mamba_d_state
    r = _dt_rank(cfg)
    proj = jnp.einsum("...ld,de->...le", xc, p["x_proj"]["w"])
    dt_r, b, c = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("...lr,rd->...ld", dt_r, p["dt_proj"]["w"]).astype(jnp.float32) + p["dt_bias"]
    )  # (..., L, di)
    a = -jnp.exp(p["a_log"])  # (di, N)
    decay = jnp.exp(dt[..., None] * a)  # (..., L, di, N)
    bx = (dt * xc.astype(jnp.float32))[..., None] * b[..., None, :].astype(jnp.float32)
    return decay, bx, c.astype(jnp.float32)


def _conv1d(p: PyTree, x: jax.Array, carry: jax.Array | None = None):
    """Causal depthwise conv over seq; carry (..., dc-1, di) holds prior tokens."""
    dc = p["conv_w"].shape[0]
    if carry is None:
        carry = jnp.zeros(x.shape[:-2] + (dc - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([carry, x], axis=-2)
    out = sum(
        xp[..., i : i + x.shape[-2], :] * p["conv_w"][i].astype(x.dtype) for i in range(dc)
    ) + p["conv_b"].astype(x.dtype)
    return jax.nn.silu(out), xp[..., -(dc - 1) :, :]


def mamba_forward(p: PyTree, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """Training/prefill pass over a full sequence. x (..., L, D) -> (..., L, D)."""
    di = cfg.mamba_expand * cfg.d_model
    l = x.shape[-2]
    xz = jnp.einsum("...ld,de->...le", x, p["in_proj"]["w"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _conv1d(p, xin)

    chunk = min(_CHUNK, l)
    n_chunks = -(-l // chunk)
    pad = n_chunks * chunk - l
    if pad:
        xc = jnp.pad(xc, [(0, 0)] * (xc.ndim - 2) + [(0, pad), (0, 0)])
    lead = xc.shape[:-2]
    xcc = xc.reshape(lead + (n_chunks, chunk, di))
    xcc = jnp.moveaxis(xcc, -3, 0)  # (n_chunks, ..., chunk, di)

    def chunk_step(h, xck):
        decay, bx, c = _ssm_params(p, cfg, xck)

        def assoc(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2

        a_acc, b_acc = jax.lax.associative_scan(assoc, (decay, bx), axis=-3)
        h_all = a_acc * h[..., None, :, :] + b_acc  # (..., chunk, di, N)
        y = jnp.einsum("...lin,...ln->...li", h_all, c)
        h_next = h_all[..., -1, :, :]
        return h_next, y

    h0 = jnp.zeros(lead + (di, cfg.mamba_d_state), jnp.float32)
    if cfg.unroll_scans:
        # roofline instrumentation: unrolled chunk loop (see configs/base.py)
        h, y_list = h0, []
        for ci in range(n_chunks):
            h, yc = chunk_step(h, xcc[ci])
            y_list.append(yc)
        ys = jnp.stack(y_list)
    else:
        _, ys = jax.lax.scan(chunk_step, h0, xcc)
    y = jnp.moveaxis(ys, 0, -3).reshape(lead + (n_chunks * chunk, di))
    if pad:
        y = y[..., :l, :]
    y = y + xc.reshape(lead + (n_chunks * chunk, di))[..., :l, :].astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return jnp.einsum("...li,id->...ld", y, p["out_proj"]["w"])


def mamba_prefill(p: PyTree, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, PyTree]:
    """Full-prompt pass that also returns the decode cache. x (..., S, D).

    Single-chunk associative scan — no chunk padding, so the final SSM state
    is the exact h the recurrence reaches at the last prompt token, and the
    conv carry is the tail ``_conv1d`` leaves behind: the cache
    token-by-token ``mamba_decode`` would have produced, in one pass.
    """
    xz = jnp.einsum("...ld,de->...le", x, p["in_proj"]["w"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_carry = _conv1d(p, xin)
    decay, bx, c = _ssm_params(p, cfg, xc)

    def assoc(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h_all = jax.lax.associative_scan(assoc, (decay, bx), axis=-3)  # h0 = 0
    y = jnp.einsum("...lin,...ln->...li", h_all, c)
    h_last = h_all[..., -1, :, :]
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("...li,id->...ld", y, p["out_proj"]["w"])
    return out, {"conv": conv_carry.astype(cfg.param_dtype), "ssm": h_last}


def init_mamba_cache(cfg: ArchConfig, batch_shape: tuple[int, ...], dtype=None) -> PyTree:
    di = cfg.mamba_expand * cfg.d_model
    dt = dtype or cfg.param_dtype
    return {
        "conv": jnp.zeros(batch_shape + (cfg.mamba_d_conv - 1, di), dt),
        "ssm": jnp.zeros(batch_shape + (di, cfg.mamba_d_state), jnp.float32),
    }


def mamba_decode(p: PyTree, cfg: ArchConfig, x: jax.Array, cache: PyTree) -> tuple[jax.Array, PyTree]:
    """One-token step. x (..., 1, D); O(1) state — the long_500k path."""
    xz = jnp.einsum("...ld,de->...le", x, p["in_proj"]["w"])
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_carry = _conv1d(p, xin, cache["conv"].astype(xin.dtype))
    decay, bx, c = _ssm_params(p, cfg, xc)  # L = 1
    h = cache["ssm"] * decay[..., 0, :, :] + bx[..., 0, :, :]
    y = jnp.einsum("...in,...n->...i", h, c[..., 0, :])[..., None, :]
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("...li,id->...ld", y, p["out_proj"]["w"])
    return out, {"conv": conv_carry.astype(cache["conv"].dtype), "ssm": h}
